//! OSSH analysis walkthrough on the validation harness (DESIGN.md §11):
//! fine-tune with drift telemetry armed on every `QuantLinear`, optionally
//! break spatial stability on demand with the deterministic channel
//! relocator, and write the versioned `OSSH_report.json` artifact.
//!
//!     cargo run --release --example ossh_analysis -- [steps] \
//!         [--preset P] [--budget B] [--patience K] [--redetect] \
//!         [--drift STEP] [--shift N] [--out PATH]
//!
//! * `--drift STEP` relocates every injected outlier channel after STEP
//!   training steps — the synthetic adversarial drift of the stability
//!   test tier (`tests/ossh_stability.rs`).
//! * `--redetect` arms adaptive re-detection: when a layer's hit rate
//!   stays under `--budget` for `--patience` consecutive checks, the
//!   outlier set is re-detected and the live Quaff method's targeted
//!   channels are hot-swapped.
//! * `--out PATH` writes the report artifact (CI uploads it).

use quaff::methods::MethodKind;
use quaff::report::ossh::{write_report, OsshRun, OsshRunSpec};
use quaff::util::cli::Args;
use quaff::util::error::Result;
use std::collections::BTreeMap;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps: u64 = args
        .positional
        .first()
        .map(|s| s.parse().expect("steps must be a number"))
        .unwrap_or(24);
    let preset = args.get_or("preset", "phi-mini").to_string();
    let drift_at: Option<u64> = args.get("drift").map(|s| s.parse().expect("--drift: bad step"));
    let shift: usize = args.get_parse("shift", 17);

    let mut spec = OsshRunSpec::tiny(MethodKind::Quaff);
    spec.server.preset = preset.clone();
    spec.server.calib_samples = 32;
    spec.server.calib_batch = 8;
    spec.steps = steps;
    spec.batch = 4;
    spec.max_len = 128;
    spec.cfg.drift_budget = args.get_parse("budget", 0.45);
    spec.cfg.patience = args.get_parse("patience", 2);
    spec.cfg.redetect = args.flag("redetect");

    eprintln!("[ossh] preparing Quaff bundle on '{preset}' (calibrate → detect → quantize) …");
    let mut run = OsshRun::new(spec)?;
    eprintln!(
        "[ossh] fine-tuning {steps} steps with telemetry checks every step \
         (budget {}, patience {}, redetect {}) …",
        run.spec.cfg.drift_budget, run.spec.cfg.patience, run.spec.cfg.redetect
    );
    while !run.is_done() {
        if drift_at == Some(run.steps_done()) {
            eprintln!(
                "[ossh] injecting synthetic drift: relocating every hot channel by {shift}"
            );
            run.inject_relocation(shift);
        }
        run.step()?;
        let done = run.steps_done();
        if done % 8 == 0 || done == steps {
            eprintln!(
                "  step {done:>3}  loss {:.3}",
                run.losses().last().copied().unwrap_or(f64::NAN)
            );
        }
    }

    let report = run.report();
    println!("\nper-layer-kind OSSH hit rate (mean over layers & iterations):");
    for (kind, mean) in &report.summary.per_kind {
        let bar = "█".repeat((mean * 40.0) as usize);
        println!("  {kind:<10} {mean:.3} {bar}");
    }
    println!(
        "\noverall: mean hit {:.3}, min hit {:.3}, {} drift events, {} re-detections",
        report.summary.mean_hit,
        report.summary.min_hit,
        report.summary.drift_events,
        report.summary.swaps
    );

    println!("\nstatic-factor similarity decay (first → last check):");
    let mut decay: BTreeMap<&str, (f32, f32, usize)> = BTreeMap::new();
    for l in &report.layers {
        let (Some(&first), Some(&last)) =
            (l.similarity_series.first(), l.similarity_series.last())
        else {
            continue;
        };
        let e = decay.entry(l.kind.as_str()).or_insert((0.0, 0.0, 0));
        e.0 += first;
        e.1 += last;
        e.2 += 1;
    }
    for (kind, (first, last, n)) in &decay {
        println!("  {kind:<10} {:.3} → {:.3}", first / *n as f32, last / *n as f32);
    }

    for l in &report.layers {
        for e in &l.swap_events {
            println!(
                "re-detection: step {} {} hit {:.2} → {} channels{}",
                e.step,
                l.layer,
                e.hit_rate,
                e.new_channels.len(),
                if e.method_swapped { " (method hot-swapped)" } else { "" }
            );
        }
    }

    if let Some(out) = args.get("out") {
        let bytes = write_report(std::path::Path::new(out), &report)?;
        println!("\nwrote {out} ({bytes} bytes)");
    }
    println!(
        "\nReading: hit rates stay high (OSSH holds: indices are stable) while\n\
         factor *magnitudes* drift (similarity decays) — and when stability is\n\
         broken on purpose (--drift), the harness detects the budget breach and\n\
         re-targets the affected layers (--redetect)."
    );
    Ok(())
}
