//! OSSH analysis walkthrough: the hypothesis-validation instruments on a
//! live fine-tuning run — per-layer hit rates of the pre-identified outlier
//! set (Fig. 3) and the decay of static scaling factors (Fig. 11), side by
//! side, on one model.
//!
//!     cargo run --release --example ossh_analysis -- [steps]

use quaff::coordinator::{PreprocessServer, ServerConfig};
use quaff::data::{Sample, SynthTask};
use quaff::methods::MethodKind;
use quaff::outlier::{HitRateTracker, LayerKind, OutlierDetector};
use quaff::peft::PeftKind;
use quaff::scaling::smoothquant_factors;
use quaff::train::Trainer;
use quaff::util::error::Result;
use quaff::util::{pearson, prng::Rng};
use std::collections::BTreeMap;

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    let mut cfg = ServerConfig::default();
    cfg.preset = "phi-mini".to_string();
    let server = PreprocessServer::new(cfg.clone());
    eprintln!("[ossh] preparing Quaff bundle (calibrate → detect → quantize) …");
    let mut bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
    println!(
        "pre-identified outlier channels: {} total ({:.2}% overhead)",
        bundle.registry.total_channels(),
        bundle.outlier_overhead * 100.0
    );

    // trackers
    let detector = OutlierDetector::new(cfg.detector_tau);
    let mut hits: BTreeMap<String, HitRateTracker> = bundle
        .registry
        .layers()
        .map(|(n, s)| (n.clone(), HitRateTracker::new(n, s.clone())))
        .collect();
    // static factors snapshot (from the Quaff layers' own calibration-time
    // scaling state expanded to the full axis at step 0)
    let mut static_factors: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    let mut dynamic_series: BTreeMap<String, Vec<f32>> = BTreeMap::new();

    let task = SynthTask::by_name("oig-chip2").unwrap();
    let mut rng = Rng::new(99);
    let mut trainer = Trainer::new(2e-3, 128, 1);
    eprintln!("[ossh] fine-tuning {steps} steps with per-step detection …");
    for step in 0..steps {
        for b in &mut bundle.model.blocks {
            for l in b.linears() {
                l.start_calibration();
            }
        }
        let samples: Vec<Sample> = (0..4).map(|_| task.sample(&mut rng)).collect();
        let refs: Vec<&Sample> = samples.iter().collect();
        let stats = trainer.step(&mut bundle.model, &[refs]);
        for b in &mut bundle.model.blocks {
            for l in b.linears() {
                let s = l.take_stats().unwrap();
                let cap = (l.cin() / 8).max(4);
                let rt = detector.select(&s, cap);
                hits.get_mut(&l.name).unwrap().record(&rt);
                // SmoothQuant-style factors from the live batch (unit weight
                // reference — we only need the *shape* across channels)
                let ones = vec![1.0f32; l.cin()];
                let dynamic = smoothquant_factors(&s.abs_max, &ones, 0.5);
                let st = static_factors
                    .entry(l.name.clone())
                    .or_insert_with(|| dynamic.clone());
                dynamic_series
                    .entry(l.name.clone())
                    .or_default()
                    .push(pearson(st, &dynamic));
            }
        }
        if step % 8 == 0 {
            eprintln!("  step {step:>3}  loss {:.3}", stats.loss);
        }
    }

    println!("\nper-layer-kind OSSH hit rate (mean over layers & iterations):");
    let mut agg: BTreeMap<LayerKind, Vec<f64>> = BTreeMap::new();
    for (name, tr) in &hits {
        agg.entry(LayerKind::from_name(name)).or_default().push(tr.summary().0);
    }
    for (kind, v) in &agg {
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let bar = "█".repeat((mean * 40.0) as usize);
        println!("  {:<10} {mean:.3} {bar}", kind.label());
    }

    println!("\nstatic-factor similarity decay (first → last iteration):");
    let mut decay: BTreeMap<LayerKind, (f32, f32, usize)> = BTreeMap::new();
    for (name, series) in &dynamic_series {
        let e = decay.entry(LayerKind::from_name(name)).or_insert((0.0, 0.0, 0));
        e.0 += series.first().copied().unwrap_or(0.0);
        e.1 += series.last().copied().unwrap_or(0.0);
        e.2 += 1;
    }
    for (kind, (first, last, n)) in &decay {
        println!(
            "  {:<10} {:.3} → {:.3}",
            kind.label(),
            first / *n as f32,
            last / *n as f32
        );
    }
    println!(
        "\nReading: hit rates stay high (OSSH holds: indices are stable) while\n\
         factor *magnitudes* drift (similarity decays) — exactly the regime where\n\
         static scaling fails and Quaff's targeted momentum scaling wins."
    );
    Ok(())
}
