//! Edge-device scenario (paper Table 2): fine-tune on a memory-capped
//! "consumer GPU" through the coordinator's server–client flow — the server
//! preprocesses and distributes the quantized bundle; the client runs a
//! wall-clock-budgeted LoRA fine-tune at batch 1 with gradient
//! accumulation, as on the RTX 2080 Super.
//!
//!     cargo run --release --example edge_device -- [budget-secs]

use quaff::coordinator::{checkpoint, PreprocessServer, ServerConfig};
use quaff::data::{Sample, SynthTask};
use quaff::methods::MethodKind;
use quaff::metrics::MemoryAccountant;
use quaff::peft::PeftKind;
use quaff::train::{eval as teval, run_budgeted, Trainer};
use quaff::util::error::Result;
use quaff::util::prng::Rng;

fn main() -> Result<()> {
    let budget: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);

    // ---- server side -----------------------------------------------------
    let mut cfg = ServerConfig::default();
    cfg.preset = "phi-mini".to_string();
    let server = PreprocessServer::new(cfg);
    eprintln!("[server] calibrating + quantizing (Quaff bundle) …");
    let mut bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
    println!(
        "[server] bundle ready: payload {} (outlier overhead {:.2}%)",
        quaff::util::fmt_bytes(bundle.payload_bytes),
        bundle.outlier_overhead * 100.0
    );

    // ---- client side -----------------------------------------------------
    let mem = MemoryAccountant::account(&mut bundle.model, MethodKind::Quaff, 1, 160);
    println!(
        "[client] working set: {} (frozen {} + activations {} + optimizer {})",
        quaff::util::fmt_bytes(mem.total()),
        quaff::util::fmt_bytes(mem.frozen),
        quaff::util::fmt_bytes(mem.activations),
        quaff::util::fmt_bytes(mem.optimizer),
    );
    let task = SynthTask::by_name("oig-chip2").unwrap();
    let mut eval_rng = Rng::new(5);
    let test: Vec<Sample> = (0..6).map(|_| task.sample(&mut eval_rng)).collect();
    let mut trainer = Trainer::new(2e-3, 160, 4); // batch 1 × grad-accum 4
    let mut gen_rng = Rng::new(6);
    println!("[client] fine-tuning for {budget:.0}s (batch 1, grad-accum 4) …");
    let curve = run_budgeted(
        &mut bundle.model,
        &mut trainer,
        || (0..4).map(|_| vec![task.sample(&mut gen_rng)]).collect(),
        budget,
        5,
        |m| teval::eval_rouge(m, &test, 32),
    );
    println!("\n  elapsed   steps   ROUGE-L");
    for p in &curve {
        println!("  {:>6.1}s  {:>6}   {:.3}", p.seconds, p.steps, p.metric);
    }
    // persist only the adapters — the client never held full-precision W
    let path = std::env::temp_dir().join("quaff_edge_adapters.ckpt");
    let saved = checkpoint::save_adapters(&mut bundle.model, &path)?;
    println!(
        "\n[client] saved {} adapter params to {} — base weights stayed quantized",
        saved,
        path.display()
    );
    Ok(())
}
