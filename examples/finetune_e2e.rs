//! End-to-end three-layer validation: the Rust coordinator drives the
//! AOT-compiled JAX train step (which embeds the L1 Pallas Quaff kernel)
//! through PJRT, fine-tuning LoRA adapters of the quantized transformer on
//! the embedded real text corpus, and logs the loss curve.
//!
//! Prerequisite: `make artifacts` (python runs once, never again) and a
//! build with `--features pjrt` against real xla bindings (the default
//! vendored stub compiles but cannot execute — see DESIGN.md §PJRT).
//!
//!     cargo run --release --features pjrt --example finetune_e2e -- [steps] [artifacts-dir]
//!
//! The loss curve is appended to EXPERIMENTS.md by the Makefile target
//! `make e2e` (here it's just printed).

use quaff::data::{corpus_samples, Tokenizer};
use quaff::runtime::{Engine, TrainSession};
use quaff::util::error::Result;
use quaff::util::prng::Rng;
use std::path::PathBuf;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let dir = PathBuf::from(args.get(2).map(|s| s.as_str()).unwrap_or("artifacts"));

    eprintln!("[e2e] loading + compiling artifacts from {} …", dir.display());
    let t0 = std::time::Instant::now();
    let engine = Engine::load(&dir)?;
    eprintln!(
        "[e2e] platform={} preset={} compiled in {:.1}s",
        engine.platform(),
        engine.manifest.preset,
        t0.elapsed().as_secs_f64()
    );
    let m = engine.manifest.clone();
    let mut session = TrainSession::new(&engine)?;

    // real tiny corpus, chunked to the artifact's fixed (B, S)
    let tok = Tokenizer::new();
    let samples = corpus_samples(&tok, m.seq);
    eprintln!(
        "[e2e] corpus: {} chunks of {} tokens; training B={} for {} steps",
        samples.len(),
        m.seq,
        m.batch,
        steps
    );
    let mut rng = Rng::new(7);
    let n = m.batch * m.seq;
    let t_train = std::time::Instant::now();
    for step in 0..steps {
        let mut tokens = Vec::with_capacity(n);
        for _ in 0..m.batch {
            let s = &samples[rng.below(samples.len())];
            tokens.extend(s.target.iter().map(|&t| t as i32));
        }
        let mask = vec![1.0f32; n];
        let loss = session.step(&tokens, &mask)?;
        if step < 5 || step % 10 == 0 || step == steps - 1 {
            println!("step {step:>5}  loss {loss:.4}");
        }
    }
    let secs = t_train.elapsed().as_secs_f64();
    let first = session.losses.first().copied().unwrap_or(f64::NAN);
    let last = session.losses.last().copied().unwrap_or(f64::NAN);
    let min = session.losses.iter().copied().fold(f64::INFINITY, f64::min);
    println!("\n[e2e] {} steps in {:.1}s ({:.3}s/step, {:.0} tok/s)", steps, secs, secs / steps as f64, steps as f64 * n as f64 / secs);
    println!("[e2e] loss: first {first:.4} → last {last:.4} (min {min:.4})");
    let max_scale = session
        .scales()
        .iter()
        .flat_map(|hv| hv.as_f32().unwrap().iter().copied())
        .fold(0.0f32, f32::max);
    println!("[e2e] max momentum scale factor s_O = {max_scale:.2} (outlier suppression engaged)");
    if last >= first {
        quaff::bail!("loss did not decrease: {first} → {last}");
    }
    println!("[e2e] OK — all three layers compose: Rust coordinator → PJRT → JAX model → Pallas kernel");
    Ok(())
}
