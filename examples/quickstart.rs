//! Quickstart: quantize one linear layer under every WAQ method and compare
//! quantization error on outlier-heavy activations — the paper's Fig. 2(c)
//! story in 60 lines.
//!
//!     cargo run --release --example quickstart

use quaff::methods::{build_method, MethodConfig, MethodKind, QuantMethod};
use quaff::outlier::{ChannelStats, OutlierDetector};
use quaff::quant::error_between;
use quaff::tensor::{Matrix, Workspace};
use quaff::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let (tokens, cin, cout) = (64, 256, 256);
    let hot = [9usize, 77, 200]; // emergent outlier channels

    // activations with 100× outlier channels (paper §2.2)
    let make_x = |rng: &mut Rng| {
        let mut x = Matrix::randn(tokens, cin, rng, 1.0);
        for &c in &hot {
            for t in 0..tokens {
                let v = x.get(t, c);
                x.set(t, c, v * 100.0);
            }
        }
        x
    };

    // 1. calibration (Eq. 6): observe a few batches, pick outlier channels
    let mut stats = ChannelStats::new(cin);
    for _ in 0..8 {
        stats.observe(&make_x(&mut rng), 20.0);
    }
    let detector = OutlierDetector::new(20.0);
    let outliers = detector.select(&stats, 8);
    println!("detected outlier channels: {:?} (planted {hot:?})\n", outliers.channels);

    // 2. build every method over the same frozen weights
    let w = Matrix::randn(cin, cout, &mut rng, 0.3);
    let cfg = MethodConfig::default();
    let mut ws = Workspace::new(); // scratch arena reused across every step
    println!("{:<14} {:>12} {:>12} {:>14}", "method", "MSE", "SQNR (dB)", "weight bytes");
    for kind in MethodKind::ALL {
        let mut method = build_method(kind, w.clone(), &stats, &outliers, &cfg);
        // warm Quaff's momentum state a little (Eq. 7)
        for _ in 0..5 {
            let x = make_x(&mut rng);
            let y = method.forward(&x, &mut ws);
            ws.recycle(y);
        }
        let x = make_x(&mut rng);
        let want = x.matmul(&w);
        let got = method.forward(&x, &mut ws);
        let err = error_between(&want, &got);
        println!(
            "{:<14} {:>12.3e} {:>12.1} {:>14}",
            method.name(),
            err.mse,
            err.sqnr_db,
            quaff::util::fmt_bytes(method.weight_bytes())
        );
    }
    println!(
        "\nExpected shape (paper Fig. 1/2): FP32 exact; Quaff ≈ Smooth_D quality at\n\
         Naive-like memory; Naive/Smooth_S degraded by the outlier channels."
    );
}
