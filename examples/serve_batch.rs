//! Batched quantized serving: the coordinator's preprocess server builds a
//! Quaff bundle, then a [`BatchEngine`] serves a queue of concurrent
//! generation requests through the KV-cached decode path — the "deploy the
//! fine-tuned model on the consumer device" end of the paper's story
//! (§1 motivation; DESIGN.md §Inference).
//!
//!     cargo run --release --example serve_batch -- [requests] [slots]
//!
//! Prints each completion plus prefill/decode throughput. Tokens per
//! second land in `BENCH_infer.json` territory; this example is the
//! human-readable tour of the same machinery.

use quaff::coordinator::{PreprocessServer, ServerConfig};
use quaff::data::{SynthTask, BOS, EOS};
use quaff::infer::{BatchEngine, GenerateConfig, Request};
use quaff::methods::MethodKind;
use quaff::peft::PeftKind;
use quaff::util::prng::Rng;
use std::time::Instant;

fn main() {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8)
        .max(4);
    let slots: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4)
        .max(1);

    // server side: calibrate, detect outliers, quantize under Quaff
    let mut cfg = ServerConfig::default();
    cfg.preset = "phi-mini".to_string();
    let server = PreprocessServer::new(cfg);
    eprintln!("[server] preparing Quaff bundle (calibrate → detect → quantize) …");
    let bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
    let model = bundle.model;
    println!(
        "[server] serving {} under {} ({} outlier channels, payload {})",
        bundle.preset,
        MethodKind::Quaff.label(),
        bundle.registry.total_channels(),
        quaff::util::fmt_bytes(bundle.payload_bytes),
    );

    // client side: a queue of concurrent chat-style requests
    let task = SynthTask::by_name("oig-chip2").unwrap();
    let mut rng = Rng::new(0x5E47E);
    let requests: Vec<Request> = (0..n_requests)
        .map(|i| {
            let s = task.sample(&mut rng);
            let mut prompt = vec![BOS];
            prompt.extend_from_slice(&s.prompt);
            Request {
                id: i as u64,
                prompt,
                max_new: 24,
                tenant: None,
            }
        })
        .collect();

    let mut gen_cfg = GenerateConfig::greedy(24);
    gen_cfg.eos = Some(EOS);
    let mut engine = BatchEngine::new(&model, slots, gen_cfg);
    println!(
        "[engine] {} requests across {} slots (continuous batching) …\n",
        requests.len(),
        engine.slots()
    );
    let t0 = Instant::now();
    let completions = engine.run_requests(&model, &requests);
    let secs = t0.elapsed().as_secs_f64();

    for c in &completions {
        println!(
            "  req {:>2}  prompt {:>3} tok  → {:>2} new: {:?}",
            c.id,
            c.prompt_len,
            c.tokens.len(),
            c.tokens
        );
    }
    let s = engine.stats;
    let new_tokens: usize = completions.iter().map(|c| c.tokens.len()).sum();
    println!(
        "\n[engine] {:.2}s wall: {} prefill tok, {} decode tok over {} steps \
         (mean batch {:.2})",
        secs,
        s.prefill_tokens,
        s.decode_tokens,
        s.decode_steps,
        s.mean_batch()
    );
    println!(
        "[engine] throughput: {:.0} generated tok/s ({:.0} tok/s incl. prefill)",
        new_tokens as f64 / secs,
        (s.prefill_tokens + s.decode_tokens) as f64 / secs
    );
}
