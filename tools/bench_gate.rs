//! CI perf-regression gate.
//!
//! Compares fresh benchmark records (`BENCH_kernels.json` from
//! `bench_kernels`, `BENCH_threads.json` from `bench_threads`,
//! `BENCH_infer.json` from `bench_infer`, `BENCH_qgemm.json` from
//! `bench_qgemm`, `BENCH_serve.json` from `bench_serve`,
//! `BENCH_tenants.json` from `bench_tenants`, `BENCH_ossh.json` from
//! `bench_ossh`, `BENCH_spec.json` from `bench_spec`) against the
//! committed `BENCH_baseline.json` and fails (exit 1) when any mean
//! regresses beyond the tolerance, or when a baselined kernel disappeared
//! from the fresh records. Always writes `BENCH_gate_diff.json` so CI can
//! upload the comparison as an artifact.
//!
//! ```text
//! bench_gate [--baseline F] [--fresh F1,F2] [--tol 0.25] [--diff F] [--update] [--meta]
//! ```
//!
//! * An **empty baseline** (`"entries": {}`) puts the gate in *seeding*
//!   mode: it passes and prints how to promote the fresh numbers.
//! * `--update` rewrites the baseline from the fresh records (run benches
//!   on the reference runner class, then commit the result).
//! * Records stamped with a `meta` block (ISA / tile / threads — see
//!   `BENCH_qgemm.json`) carry their measurement context. The gate
//!   **refuses to compare** (exit 2) when the baseline and fresh records
//!   were measured under different microkernel ISAs: ns across ISAs is a
//!   machine delta, not a regression — re-seed with `--update` on the
//!   matching runner class instead. The stamp is propagated into the
//!   baseline on `--update`; unstamped legacy records compare as before.
//! * `--meta` prints each fresh record's `{isa, tile, threads}` stamp and
//!   exits non-zero when any record is missing or unstamped — CI uses it
//!   to surface the measurement context instead of grepping raw JSON.
//!
//! See DESIGN.md §CI for the refresh workflow.

use quaff::util::json::Json;
use std::collections::BTreeMap;
use std::process::ExitCode;

const DEFAULT_TOL: f64 = 0.25;

/// Gate-comparable per-record metrics. `ns_per_op` is the common key; the
/// serve record adds latency percentiles and the (deterministic) page-pool
/// high-water mark. Context fields (`tokens_per_sec`, `mean_batch`, …) are
/// deliberately not gated.
const METRICS: [&str; 6] = [
    "alloc_ns_per_op",
    "workspace_ns_per_op",
    "ns_per_op",
    "p50_ns",
    "p99_ns",
    "pages_hwm",
];

#[derive(Debug, PartialEq)]
enum Verdict {
    Ok,
    Improved,
    Regressed,
    Missing,
    New,
}

struct Finding {
    id: String,
    baseline_ns: Option<f64>,
    fresh_ns: Option<f64>,
    verdict: Verdict,
}

/// Flatten one bench record into `(id, mean_ns)` entries. Ids are
/// `<bench>/<kernel name>/<metric>` so records from several files coexist.
fn extract_entries(j: &Json) -> Vec<(String, f64)> {
    let bench = j.get("bench").and_then(Json::as_str).unwrap_or("unknown");
    let mut out = Vec::new();
    let kernels = match j.get("kernels").and_then(Json::as_arr) {
        Some(k) => k,
        None => return out,
    };
    for k in kernels {
        let name = k.get("name").and_then(Json::as_str).unwrap_or("?");
        for metric in METRICS {
            if let Some(v) = k.get(metric).and_then(Json::as_f64) {
                out.push((format!("{bench}/{name}/{metric}"), v));
            }
        }
        if let Some(legs) = k.get("legs").and_then(Json::as_arr) {
            for leg in legs {
                let (t, ns) = (
                    leg.get("threads").and_then(Json::as_f64),
                    leg.get("ns_per_op").and_then(Json::as_f64),
                );
                if let (Some(t), Some(ns)) = (t, ns) {
                    out.push((format!("{bench}/{name}/t{}", t as u64), ns));
                }
            }
        }
    }
    out
}

/// Pure comparison: every baseline entry must be present in `fresh` and not
/// regressed beyond `tol`; fresh-only entries are reported as new.
fn compare(
    baseline: &BTreeMap<String, f64>,
    fresh: &BTreeMap<String, f64>,
    tol: f64,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (id, &base) in baseline {
        match fresh.get(id) {
            None => findings.push(Finding {
                id: id.clone(),
                baseline_ns: Some(base),
                fresh_ns: None,
                verdict: Verdict::Missing,
            }),
            Some(&f) => {
                let verdict = if f > base * (1.0 + tol) {
                    Verdict::Regressed
                } else if f < base * (1.0 - tol) {
                    Verdict::Improved
                } else {
                    Verdict::Ok
                };
                findings.push(Finding {
                    id: id.clone(),
                    baseline_ns: Some(base),
                    fresh_ns: Some(f),
                    verdict,
                });
            }
        }
    }
    for (id, &f) in fresh {
        if !baseline.contains_key(id) {
            findings.push(Finding {
                id: id.clone(),
                baseline_ns: None,
                fresh_ns: Some(f),
                verdict: Verdict::New,
            });
        }
    }
    findings
}

fn findings_to_json(findings: &[Finding], tol: f64, pass: bool) -> Json {
    let items = findings.iter().map(|f| {
        Json::obj(vec![
            ("id", Json::str(f.id.clone())),
            ("baseline_ns", f.baseline_ns.map(Json::num).unwrap_or(Json::Null)),
            ("fresh_ns", f.fresh_ns.map(Json::num).unwrap_or(Json::Null)),
            ("verdict", Json::str(format!("{:?}", f.verdict).to_lowercase())),
        ])
    });
    Json::obj(vec![
        ("tolerance", Json::num(tol)),
        ("pass", Json::Bool(pass)),
        ("findings", Json::arr(items)),
    ])
}

fn baseline_json(entries: &BTreeMap<String, f64>, tol: f64, meta: Option<&Json>) -> Json {
    let mut pairs = vec![("tolerance", Json::num(tol))];
    if let Some(m) = meta {
        pairs.push(("meta", m.clone()));
    }
    pairs.push((
        "entries",
        Json::Obj(entries.iter().map(|(k, &v)| (k.clone(), Json::num(v))).collect()),
    ));
    Json::obj(pairs)
}

/// The `isa` tag of a `meta` stamp object, if present.
fn isa_of(meta: &Json) -> Option<String> {
    meta.get("isa")?.as_str().map(str::to_string)
}

/// The `meta.isa` stamp of a bench record or baseline file, if present.
fn meta_isa(j: &Json) -> Option<String> {
    j.get("meta").and_then(isa_of)
}

/// Comparing ns across microkernel ISAs is a machine delta, not a
/// regression — refuse when both sides are stamped and disagree.
/// Unstamped (`None`) legacy records compare with anything.
fn isa_conflict(baseline: Option<&str>, fresh: Option<&str>) -> bool {
    matches!((baseline, fresh), (Some(b), Some(f)) if b != f)
}

/// The full `{isa, tile, threads}` stamp of a record, or why it's unusable.
fn stamp_of(j: &Json) -> Result<(String, String, u64), String> {
    let meta = j.get("meta").ok_or_else(|| "no meta stamp".to_string())?;
    let field = |k: &str| {
        meta.get(k)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("meta stamp has no '{k}'"))
    };
    let threads = meta
        .get("threads")
        .and_then(Json::as_f64)
        .ok_or_else(|| "meta stamp has no 'threads'".to_string())?;
    Ok((field("isa")?, field("tile")?, threads as u64))
}

/// `--meta`: surface each fresh record's measurement stamp so the CI log
/// shows which ISA / tile / thread count the numbers were taken under.
/// Exits non-zero when any record is missing, unparseable or unstamped —
/// an unstamped record would otherwise compare silently across machines.
fn print_meta(paths: &[String]) -> ExitCode {
    let mut bad = 0usize;
    for path in paths {
        let stamp = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read: {e}"))
            .and_then(|text| Json::parse(&text).map_err(|e| format!("cannot parse: {e}")))
            .and_then(|j| stamp_of(&j));
        match stamp {
            Ok((isa, tile, threads)) => {
                println!("{path}: isa={isa} tile={tile} threads={threads}");
            }
            Err(e) => {
                bad += 1;
                eprintln!("bench_gate: {path}: {e}");
            }
        }
    }
    if bad == 0 {
        return ExitCode::SUCCESS;
    }
    eprintln!(
        "bench_gate: {bad} record(s) without a usable meta stamp — every bench must run and \
         stamp its measurement context (see benches/harness.rs BenchMeta)."
    );
    ExitCode::from(2)
}

struct Args {
    baseline: String,
    fresh: Vec<String>,
    tol: Option<f64>,
    diff: String,
    update: bool,
    meta: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        baseline: "BENCH_baseline.json".to_string(),
        fresh: vec![
            "BENCH_kernels.json".to_string(),
            "BENCH_threads.json".to_string(),
            "BENCH_infer.json".to_string(),
            "BENCH_qgemm.json".to_string(),
            "BENCH_serve.json".to_string(),
            "BENCH_tenants.json".to_string(),
            "BENCH_ossh.json".to_string(),
            "BENCH_spec.json".to_string(),
        ],
        tol: None,
        diff: "BENCH_gate_diff.json".to_string(),
        update: false,
        meta: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut value = |name: &str| it.next().ok_or_else(|| format!("{name} needs a value"));
        match a.as_str() {
            "--baseline" => args.baseline = value("--baseline")?,
            "--fresh" => args.fresh = value("--fresh")?.split(',').map(str::to_string).collect(),
            "--tol" => {
                args.tol = Some(
                    value("--tol")?
                        .parse::<f64>()
                        .map_err(|e| format!("bad --tol: {e}"))?,
                )
            }
            "--diff" => args.diff = value("--diff")?,
            "--update" => args.update = true,
            "--meta" => args.meta = true,
            other => return Err(format!("unknown argument '{other}'")),
        }
    }
    Ok(args)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            return ExitCode::from(2);
        }
    };

    if args.meta {
        return print_meta(&args.fresh);
    }

    // fresh records (missing files are tolerated here; the baseline check
    // below catches a silently-skipped bench)
    let mut fresh = BTreeMap::new();
    let mut fresh_meta: Option<Json> = None;
    for path in &args.fresh {
        match std::fs::read_to_string(path) {
            Ok(text) => match Json::parse(&text) {
                Ok(j) => {
                    if let Some(isa) = meta_isa(&j) {
                        let prev = fresh_meta.as_ref().and_then(isa_of);
                        if let Some(prev) = prev {
                            if prev != isa {
                                eprintln!(
                                    "bench_gate: fresh records span multiple ISAs ({prev} vs \
                                     {isa} in {path}) — run all benches in one environment"
                                );
                                return ExitCode::from(2);
                            }
                        }
                        fresh_meta = j.get("meta").cloned();
                    }
                    fresh.extend(extract_entries(&j));
                }
                Err(e) => {
                    eprintln!("bench_gate: cannot parse {path}: {e}");
                    return ExitCode::from(2);
                }
            },
            Err(e) => eprintln!("bench_gate: note: {path} not found ({e})"),
        }
    }

    // baseline
    let (baseline, file_tol, baseline_isa) = match std::fs::read_to_string(&args.baseline) {
        Ok(text) => match Json::parse(&text) {
            Ok(j) => {
                let tol = j.get("tolerance").and_then(Json::as_f64);
                let mut map = BTreeMap::new();
                if let Some(Json::Obj(entries)) = j.get("entries") {
                    for (k, v) in entries {
                        if let Some(x) = v.as_f64() {
                            map.insert(k.clone(), x);
                        }
                    }
                }
                let isa = meta_isa(&j);
                (map, tol, isa)
            }
            Err(e) => {
                eprintln!("bench_gate: cannot parse {}: {e}", args.baseline);
                return ExitCode::from(2);
            }
        },
        Err(e) => {
            eprintln!("bench_gate: cannot read {}: {e}", args.baseline);
            return ExitCode::from(2);
        }
    };
    let tol = args.tol.or(file_tol).unwrap_or(DEFAULT_TOL);

    if args.update {
        if fresh.is_empty() {
            eprintln!(
                "bench_gate: refusing --update with no fresh records — an empty baseline would \
                 disarm the gate. Run the benches from the repo root first (see DESIGN.md §CI)."
            );
            return ExitCode::from(2);
        }
        let out = baseline_json(&fresh, tol, fresh_meta.as_ref());
        if let Err(e) = std::fs::write(&args.baseline, format!("{}\n", out.to_string())) {
            eprintln!("bench_gate: cannot write {}: {e}", args.baseline);
            return ExitCode::from(2);
        }
        println!(
            "bench_gate: baseline {} updated with {} entries (tol {tol})",
            args.baseline,
            fresh.len()
        );
        return ExitCode::SUCCESS;
    }

    let fresh_isa = fresh_meta.as_ref().and_then(isa_of);
    if !baseline.is_empty() && isa_conflict(baseline_isa.as_deref(), fresh_isa.as_deref()) {
        eprintln!(
            "bench_gate: ISA mismatch — baseline was measured under '{}', fresh records under \
             '{}'. Cross-ISA ns deltas are machine differences, not regressions; refusing to \
             compare. Re-seed on the matching runner class with `bench_gate --update`.",
            baseline_isa.as_deref().unwrap_or("?"),
            fresh_isa.as_deref().unwrap_or("?")
        );
        return ExitCode::from(2);
    }

    let findings = compare(&baseline, &fresh, tol);
    let mut regressions = 0usize;
    for f in &findings {
        let (b, fr) = (f.baseline_ns.unwrap_or(f64::NAN), f.fresh_ns.unwrap_or(f64::NAN));
        match f.verdict {
            Verdict::Regressed => {
                regressions += 1;
                println!("REGRESSED  {:<60} {b:>12.1} -> {fr:>12.1} ns", f.id);
            }
            Verdict::Missing => {
                regressions += 1;
                println!("MISSING    {:<60} {b:>12.1} ns (no fresh record)", f.id);
            }
            Verdict::Improved => println!("improved   {:<60} {b:>12.1} -> {fr:>12.1} ns", f.id),
            Verdict::New => println!("new        {:<60} {fr:>27.1} ns", f.id),
            Verdict::Ok => println!("ok         {:<60} {b:>12.1} -> {fr:>12.1} ns", f.id),
        }
    }
    let pass = regressions == 0;
    let diff = findings_to_json(&findings, tol, pass);
    if let Err(e) = std::fs::write(&args.diff, format!("{}\n", diff.to_string())) {
        eprintln!("bench_gate: cannot write {}: {e}", args.diff);
        return ExitCode::from(2);
    }

    if baseline.is_empty() {
        println!(
            "bench_gate: baseline is empty (seeding mode) — {} fresh entries recorded in {}.\n\
             To arm the gate: run the benches on the reference runner, then\n\
             `cargo run --release --bin bench_gate -- --update` and commit {}.",
            fresh.len(),
            args.diff,
            args.baseline
        );
        return ExitCode::SUCCESS;
    }
    if pass {
        println!(
            "bench_gate: PASS — {} entries within ±{:.0}% of baseline",
            findings.len(),
            tol * 100.0
        );
        ExitCode::SUCCESS
    } else {
        println!(
            "bench_gate: FAIL — {regressions} regression(s)/missing record(s) beyond ±{:.0}% \
             (diff in {})",
            tol * 100.0,
            args.diff
        );
        ExitCode::from(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn compare_flags_regressions_and_passes_noise() {
        let base = map(&[("k/a/ns", 100.0), ("k/b/ns", 100.0), ("k/c/ns", 100.0)]);
        let fresh = map(&[("k/a/ns", 110.0), ("k/b/ns", 130.0), ("k/c/ns", 60.0)]);
        let f = compare(&base, &fresh, 0.25);
        let verdict = |id: &str| &f.iter().find(|x| x.id == id).unwrap().verdict;
        assert_eq!(*verdict("k/a/ns"), Verdict::Ok, "within tolerance");
        assert_eq!(*verdict("k/b/ns"), Verdict::Regressed);
        assert_eq!(*verdict("k/c/ns"), Verdict::Improved);
    }

    #[test]
    fn compare_flags_missing_and_new() {
        let base = map(&[("k/gone/ns", 50.0)]);
        let fresh = map(&[("k/added/ns", 50.0)]);
        let f = compare(&base, &fresh, 0.25);
        assert!(f.iter().any(|x| x.id == "k/gone/ns" && x.verdict == Verdict::Missing));
        assert!(f.iter().any(|x| x.id == "k/added/ns" && x.verdict == Verdict::New));
    }

    #[test]
    fn extract_reads_kernels_and_threads_schemas() {
        let kernels = Json::parse(
            r#"{"bench":"kernels","kernels":[
                {"name":"mm","alloc_ns_per_op":10.0,"workspace_ns_per_op":5.0}]}"#,
        )
        .unwrap();
        let e = extract_entries(&kernels);
        assert!(e.contains(&("kernels/mm/alloc_ns_per_op".to_string(), 10.0)));
        assert!(e.contains(&("kernels/mm/workspace_ns_per_op".to_string(), 5.0)));
        let threads = Json::parse(
            r#"{"bench":"threads","kernels":[
                {"name":"mm","legs":[{"threads":1,"ns_per_op":9.0},{"threads":4,"ns_per_op":3.0}]}]}"#,
        )
        .unwrap();
        let e = extract_entries(&threads);
        assert!(e.contains(&("threads/mm/t1".to_string(), 9.0)));
        assert!(e.contains(&("threads/mm/t4".to_string(), 3.0)));
    }

    #[test]
    fn extract_reads_serve_metrics_but_not_context_fields() {
        let serve = Json::parse(
            r#"{"bench":"serve","kernels":[
                {"name":"mixed","clients":256,"p50_ns":100.0,"p99_ns":900.0,
                 "ns_per_op":5.0,"tokens_per_sec":1.0,"mean_batch":3.2,
                 "pages_hwm":40,"preemptions":7}]}"#,
        )
        .unwrap();
        let e = extract_entries(&serve);
        assert!(e.contains(&("serve/mixed/p50_ns".to_string(), 100.0)));
        assert!(e.contains(&("serve/mixed/p99_ns".to_string(), 900.0)));
        assert!(e.contains(&("serve/mixed/ns_per_op".to_string(), 5.0)));
        assert!(e.contains(&("serve/mixed/pages_hwm".to_string(), 40.0)));
        let gated_context = e
            .iter()
            .any(|(id, _)| id.contains("tokens_per_sec") || id.contains("preemptions"));
        assert!(!gated_context, "context fields stay ungated");
    }

    #[test]
    fn stamp_of_requires_all_three_fields() {
        let full = Json::parse(
            r#"{"bench":"serve","meta":{"isa":"avx2","tile":"4x8","threads":4},"kernels":[]}"#,
        )
        .unwrap();
        assert_eq!(stamp_of(&full), Ok(("avx2".to_string(), "4x8".to_string(), 4)));
        let unstamped = Json::parse(r#"{"bench":"kernels","kernels":[]}"#).unwrap();
        assert!(stamp_of(&unstamped).unwrap_err().contains("no meta stamp"));
        let partial =
            Json::parse(r#"{"bench":"serve","meta":{"isa":"avx2","threads":4},"kernels":[]}"#)
                .unwrap();
        assert!(stamp_of(&partial).unwrap_err().contains("tile"));
    }

    #[test]
    fn baseline_roundtrips_through_json() {
        let entries = map(&[("k/a/ns", 12.5), ("t/b/t4", 7.0)]);
        let text = baseline_json(&entries, 0.25, None).to_string();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("tolerance").and_then(Json::as_f64), Some(0.25));
        assert_eq!(j.get("meta"), None, "no meta key when no stamp was supplied");
        let mut back = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("entries") {
            for (k, v) in m {
                back.insert(k.clone(), v.as_f64().unwrap());
            }
        }
        assert_eq!(back, entries);
    }

    #[test]
    fn meta_isa_reads_the_stamp_and_tolerates_legacy_records() {
        let stamped = Json::parse(
            r#"{"bench":"qgemm","meta":{"isa":"avx2","tile":"4x8","threads":8},"kernels":[]}"#,
        )
        .unwrap();
        assert_eq!(meta_isa(&stamped), Some("avx2".to_string()));
        let legacy = Json::parse(r#"{"bench":"kernels","kernels":[]}"#).unwrap();
        assert_eq!(meta_isa(&legacy), None);
        let partial =
            Json::parse(r#"{"bench":"qgemm","meta":{"threads":8},"kernels":[]}"#).unwrap();
        assert_eq!(meta_isa(&partial), None);
    }

    #[test]
    fn isa_conflict_only_when_both_stamped_and_different() {
        assert!(isa_conflict(Some("avx2"), Some("scalar")));
        assert!(!isa_conflict(Some("avx2"), Some("avx2")));
        assert!(!isa_conflict(None, Some("avx2")), "unstamped baseline compares");
        assert!(!isa_conflict(Some("avx2"), None), "unstamped fresh compares");
        assert!(!isa_conflict(None, None));
    }

    #[test]
    fn baseline_stores_the_meta_stamp_on_update() {
        let entries = map(&[("q/fused decode b1 th1/ns_per_op", 900.0)]);
        let meta = Json::parse(r#"{"isa":"avx2","tile":"4x8","threads":8}"#).unwrap();
        let text = baseline_json(&entries, 0.25, Some(&meta)).to_string();
        let j = Json::parse(&text).unwrap();
        assert_eq!(meta_isa(&j), Some("avx2".to_string()));
        assert_eq!(
            j.get("meta").and_then(|m| m.get("tile")).and_then(Json::as_str),
            Some("4x8")
        );
    }
}
