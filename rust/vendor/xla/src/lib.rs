//! Offline API stub for the `xla-rs` PJRT bindings — see README.md.
//!
//! Host-side types ([`Literal`], [`ElementType`]) are real and tested;
//! runtime entry points ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`]) fail with a descriptive [`Error`]
//! because the native XLA library is not available offline.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: carries a message explaining what would need real XLA.
pub struct Error {
    msg: String,
}

impl Error {
    fn stub(what: &str) -> Error {
        Error {
            msg: format!(
                "{what} requires the real xla-rs bindings; this build links the offline \
                 stub in rust/vendor/xla (see its README.md for how to swap in xla-rs)"
            ),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes quaff marshals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn size(&self) -> usize {
        4
    }
}

/// Element types [`Literal::to_vec`] can extract.
pub trait NativeType: Sized {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> f32 {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> i32 {
        i32::from_le_bytes(bytes)
    }
}

/// A host-side literal: dtype + dims + little-endian bytes. Fully
/// functional in the stub (it never touches native code).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product::<usize>().max(1);
        if data.len() != numel * ty.size() {
            return Err(Error {
                msg: format!(
                    "literal byte length {} does not match shape {dims:?} ({} bytes expected)",
                    data.len(),
                    numel * ty.size()
                ),
            });
        }
        Ok(Literal {
            ty,
            dims: dims.to_vec(),
            bytes: data.to_vec(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error {
                msg: format!("literal dtype {:?} does not match requested {:?}", self.ty, T::TY),
            });
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }
}

/// Opaque parsed HLO module.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// Opaque XLA computation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// Device-side buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<u8> = [1.0f32, -2.5, 3.25].iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!(lit.dims(), &[3]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_rejects_bad_length() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 4]).is_err());
    }

    #[test]
    fn runtime_entry_points_fail_descriptively() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("xla-rs"));
        assert!(HloModuleProto::from_text_file("/tmp/x").is_err());
    }
}
