//! Coordinator-driven performance grids: the accuracy / latency / memory
//! comparisons (Figs. 1, 4, 5, 6, 7; Tables 1–4).

use super::{f3, method_rows, secs, ReportOpts, Table};
use crate::coordinator::{run_job, FinetuneJob, JobReport, PreprocessServer};
use crate::data::SynthTask;
use crate::methods::MethodKind;
use crate::peft::PeftKind;
use crate::train::{eval as teval, run_budgeted, Trainer};
use crate::util::prng::Rng;

fn job(opts: &ReportOpts, id: u64, dataset: &str, method: MethodKind, peft: PeftKind) -> FinetuneJob {
    let mut j = FinetuneJob::new(id, dataset, method, peft);
    j.steps = opts.steps;
    j.batch_size = opts.batch;
    j
}

/// Report cells only reference embedded dataset names, so a lookup failure
/// here is a bug in the report code (not user input) — surface it loudly.
fn run(server: &PreprocessServer, j: &FinetuneJob) -> JobReport {
    run_job(server, j).expect("report datasets are embedded and known-good")
}

/// Fig. 1: accuracy vs latency-per-step vs memory on GPQA with the default
/// model + LoRA (the teaser scatter).
pub fn fig1(opts: &ReportOpts) -> String {
    let server = PreprocessServer::new(opts.server_cfg(&opts.preset));
    let mut t = Table::new(
        &format!(
            "Fig. 1 — GPQA-synth accuracy vs latency vs memory ({}, LoRA)",
            opts.preset
        ),
        &["Method", "Acc↑", "Latency/step", "Memory", "Mem ratio vs FP32"],
    );
    let mut fp32_mem = 0usize;
    let mut rows = Vec::new();
    for (i, method) in method_rows().into_iter().enumerate() {
        let r = run(&server, &job(opts, i as u64, "gpqa", method, PeftKind::Lora));
        if method == MethodKind::Fp32 {
            fp32_mem = r.memory.total();
        }
        rows.push(r);
    }
    for r in rows {
        t.push(vec![
            r.method.label().to_string(),
            f3(r.metric("acc")),
            secs(r.mean_step_secs),
            crate::util::fmt_bytes(r.memory.total()),
            f3(r.memory.total() as f64 / fp32_mem as f64),
        ]);
    }
    t.to_markdown()
}

/// Fig. 4: three reasoning datasets × three models, accuracy + latency and
/// memory as ratios to FP32.
pub fn fig4(opts: &ReportOpts) -> String {
    let mut out = String::new();
    for preset in ["opt-tiny", "phi-mini", "llama-tiny"] {
        let server = PreprocessServer::new(opts.server_cfg(preset));
        for dataset in ["gpqa", "mathqa", "mmlu-pro"] {
            let mut t = Table::new(
                &format!("Fig. 4 — {dataset} / {preset} (LoRA)"),
                &["Method", "Acc↑", "Latency ratio", "Memory ratio"],
            );
            let mut base_lat = 1.0;
            let mut base_mem = 1.0;
            for (i, method) in method_rows().into_iter().enumerate() {
                let r = run(&server, &job(opts, i as u64, dataset, method, PeftKind::Lora));
                if method == MethodKind::Fp32 {
                    base_lat = r.mean_step_secs;
                    base_mem = r.memory.total() as f64;
                }
                t.push(vec![
                    r.method.label().to_string(),
                    f3(r.metric("acc")),
                    f3(r.mean_step_secs / base_lat),
                    f3(r.memory.total() as f64 / base_mem),
                ]);
            }
            out.push_str(&t.to_markdown());
        }
    }
    out
}

/// Fig. 5: the four PEFT strategies × methods on GPQA.
pub fn fig5(opts: &ReportOpts) -> String {
    let server = PreprocessServer::new(opts.server_cfg(&opts.preset));
    let mut out = String::new();
    for peft in PeftKind::ALL {
        let mut t = Table::new(
            &format!("Fig. 5 — GPQA-synth with {} ({})", peft.label(), opts.preset),
            &["Method", "Acc↑", "Latency/step", "Memory"],
        );
        for (i, method) in method_rows().into_iter().enumerate() {
            let r = run(&server, &job(opts, i as u64, "gpqa", method, peft));
            t.push(vec![
                r.method.label().to_string(),
                f3(r.metric("acc")),
                secs(r.mean_step_secs),
                crate::util::fmt_bytes(r.memory.total()),
            ]);
        }
        out.push_str(&t.to_markdown());
    }
    out
}

/// Fig. 6: convergence under a wall-clock budget (ROUGE-L vs time) for the
/// efficient methods on OIG/Chip2-synth.
pub fn fig6(opts: &ReportOpts) -> String {
    let mut out = format!(
        "\n### Fig. 6 — ROUGE-L vs wall-clock (budget {:.0}s/method, {})\n\n",
        opts.budget_secs, opts.preset
    );
    let server = PreprocessServer::new(opts.server_cfg(&opts.preset));
    for method in [MethodKind::Naive, MethodKind::SmoothStatic, MethodKind::LlmInt8, MethodKind::Quaff]
    {
        let mut bundle = server.prepare(method, PeftKind::Lora);
        let task = SynthTask::by_name("oig-chip2").unwrap();
        let mut rng = Rng::new(11);
        let test: Vec<_> = (0..4).map(|_| task.sample(&mut rng)).collect();
        let mut trainer = Trainer::new(2e-3, 128, 1);
        let mut gen_rng = Rng::new(12);
        let bs = opts.batch;
        let curve = run_budgeted(
            &mut bundle.model,
            &mut trainer,
            || vec![(0..bs).map(|_| task.sample(&mut gen_rng)).collect()],
            opts.budget_secs,
            (opts.steps / 2).max(2),
            |m| teval::eval_rouge(m, &test, 32),
        );
        out.push_str(&format!("{}:", method.label()));
        for p in &curve {
            out.push_str(&format!(" ({:.1}s, step {}, R-L {:.3})", p.seconds, p.steps, p.metric));
        }
        out.push('\n');
    }
    out
}

/// Fig. 7: LAMBADA-synth long-context accuracy across models.
pub fn fig7(opts: &ReportOpts) -> String {
    let mut out = String::new();
    for preset in ["opt-tiny", "phi-mini", "llama-tiny"] {
        let server = PreprocessServer::new(opts.server_cfg(preset));
        let mut t = Table::new(
            &format!("Fig. 7 — LAMBADA-synth (ctx-scaled), {preset}"),
            &["Method", "Acc↑", "PPL↓", "Latency/step"],
        );
        for (i, method) in method_rows().into_iter().enumerate() {
            let mut j = job(opts, i as u64, "lambada", method, PeftKind::Lora);
            j.max_len = 256;
            j.batch_size = opts.batch.min(2);
            let r = run(&server, &j);
            t.push(vec![
                r.method.label().to_string(),
                f3(r.metric("acc")),
                f3(r.metric("ppl")),
                secs(r.mean_step_secs),
            ]);
        }
        out.push_str(&t.to_markdown());
    }
    out
}

/// Table 1: the four instruction-tuning datasets (ROUGE-L / PPL / Acc +
/// latency + memory).
pub fn table1(opts: &ReportOpts) -> String {
    let server = PreprocessServer::new(opts.server_cfg(&opts.preset));
    let mut out = String::new();
    for dataset in ["oasst1", "self-instruct", "finance-alpaca", "hh-rlhf"] {
        let mut t = Table::new(
            &format!("Table 1 — {dataset} ({}, LoRA)", opts.preset),
            &["Method", "Latency/step", "Memory", "ROUGE-L↑", "PPL↓", "Acc↑"],
        );
        for (i, method) in method_rows().into_iter().enumerate() {
            let r = run(&server, &job(opts, i as u64, dataset, method, PeftKind::Lora));
            t.push(vec![
                r.method.label().to_string(),
                secs(r.mean_step_secs),
                crate::util::fmt_bytes(r.memory.total()),
                f3(r.metric("rouge_l")),
                f3(r.metric("ppl")),
                f3(r.metric("acc")),
            ]);
        }
        out.push_str(&t.to_markdown());
    }
    out
}

/// Table 2: consumer-hardware run — memory-capped budget fine-tuning.
/// Methods whose working set exceeds the device cap page to shared memory;
/// the simulator applies the paper-observed ~10× step penalty.
pub fn table2(opts: &ReportOpts) -> String {
    let server = PreprocessServer::new(opts.server_cfg(&opts.preset));
    // device cap: geometric mean of Quaff and FP32 totals → Quaff fits,
    // FP32/Smooth_D page (mirrors the RTX 2080 Super 8 GB situation).
    let probe_fp32 = run(&server, &{
        let mut j = job(opts, 90, "oig-chip2", MethodKind::Fp32, PeftKind::Lora);
        j.steps = 1;
        j
    });
    let probe_quaff = run(&server, &{
        let mut j = job(opts, 91, "oig-chip2", MethodKind::Quaff, PeftKind::Lora);
        j.steps = 1;
        j
    });
    let cap = ((probe_fp32.memory.total() as f64) * (probe_quaff.memory.total() as f64)).sqrt()
        as usize;
    let mut t = Table::new(
        &format!(
            "Table 2 — edge-device budget run (cap {} ≈ 8GB-analogue, {:.0}s/method, OIG/Chip2-synth, batch 1 × accum 4)",
            crate::util::fmt_bytes(cap),
            opts.budget_secs
        ),
        &["Method", "Eff. latency/step", "Memory", "Paged?", "Steps done", "ROUGE-L↑", "PPL↓", "Acc↑"],
    );
    const PAGING_PENALTY: f64 = 10.0;
    for (i, method) in method_rows().into_iter().enumerate() {
        let mut j = job(opts, i as u64, "oig-chip2", method, PeftKind::Lora);
        j.batch_size = 1;
        j.grad_accum = 4;
        // translate the wall-clock budget into steps using a 1-step probe
        let mut probe = j.clone();
        probe.steps = 1;
        let p = run(&server, &probe);
        let paged = p.memory.total() > cap;
        let eff_step = p.mean_step_secs * if paged { PAGING_PENALTY } else { 1.0 };
        let steps = ((opts.budget_secs / eff_step).floor() as u64).clamp(1, opts.steps * 4);
        j.steps = steps;
        let r = run(&server, &j);
        t.push(vec![
            r.method.label().to_string(),
            secs(eff_step),
            crate::util::fmt_bytes(r.memory.total()),
            if paged { "yes".into() } else { "no".into() },
            steps.to_string(),
            f3(r.metric("rouge_l")),
            f3(r.metric("ppl")),
            f3(r.metric("acc")),
        ]);
    }
    t.to_markdown()
}

/// Table 3: momentum ablation across PEFT strategies on GPQA.
pub fn table3(opts: &ReportOpts) -> String {
    let server = PreprocessServer::new(opts.server_cfg(&opts.preset));
    let mut t = Table::new(
        &format!("Table 3 — momentum ablation on GPQA-synth ({})", opts.preset),
        &["Variant", "LoRA", "Prompt", "P-Tuning", "IA3"],
    );
    let baselines = [MethodKind::Naive, MethodKind::SmoothStatic, MethodKind::LlmInt8];
    let mut best_row = vec!["best baseline".to_string()];
    let mut nomom_row = vec!["Quaff w/o Mo".to_string()];
    let mut quaff_row = vec!["Quaff".to_string()];
    for peft in PeftKind::ALL {
        let mut best: f64 = 0.0;
        for (i, m) in baselines.iter().enumerate() {
            let r = run(&server, &job(opts, i as u64, "gpqa", *m, peft));
            best = best.max(r.metric("acc"));
        }
        best_row.push(f3(best));
        let r = run(&server, &job(opts, 20, "gpqa", MethodKind::QuaffNoMomentum, peft));
        nomom_row.push(f3(r.metric("acc")));
        let r = run(&server, &job(opts, 21, "gpqa", MethodKind::Quaff, peft));
        quaff_row.push(f3(r.metric("acc")));
    }
    t.push(best_row);
    t.push(nomom_row);
    t.push(quaff_row);
    t.to_markdown()
}

/// Table 4: LongForm-synth generation (context-scaled 4K → 256).
pub fn table4(opts: &ReportOpts) -> String {
    let server = PreprocessServer::new(opts.server_cfg(&opts.preset));
    let mut t = Table::new(
        &format!("Table 4 — LongForm-synth, output-scaled ({})", opts.preset),
        &["Method", "Latency/step", "Memory", "ROUGE-L↑", "PPL↓", "Acc↑"],
    );
    for (i, method) in method_rows().into_iter().enumerate() {
        let mut j = job(opts, i as u64, "longform", method, PeftKind::Lora);
        j.max_len = 256;
        j.batch_size = opts.batch.min(2);
        j.grad_accum = 2;
        let r = run(&server, &j);
        t.push(vec![
            r.method.label().to_string(),
            secs(r.mean_step_secs),
            crate::util::fmt_bytes(r.memory.total()),
            f3(r.metric("rouge_l")),
            f3(r.metric("ppl")),
            f3(r.metric("acc")),
        ]);
    }
    t.to_markdown()
}

/// Table 5: calibration-dataset cross matrix (rows: calibration set,
/// columns: fine-tuning task metric).
pub fn table5(opts: &ReportOpts) -> String {
    let mut t = Table::new(
        &format!("Table 5 — calibration × fine-tuning cross matrix (Quaff, {})", opts.preset),
        &["Calib \\ FT", "OIG/Chip2 (R-L)", "LAMBADA (acc)", "GPQA (acc)"],
    );
    for calib in ["oig-chip2", "lambada", "gpqa"] {
        let mut cfg = opts.server_cfg(&opts.preset);
        cfg.calib_task = calib.to_string();
        let server = PreprocessServer::new(cfg);
        let mut row = vec![calib.to_string()];
        for (ft, key) in [("oig-chip2", "rouge_l"), ("lambada", "acc"), ("gpqa", "acc")] {
            let mut j = job(opts, 0, ft, MethodKind::Quaff, PeftKind::Lora);
            if ft == "lambada" {
                j.max_len = 256;
                j.batch_size = opts.batch.min(2);
            }
            let r = run(&server, &j);
            row.push(f3(r.metric(key)));
        }
        t.push(row);
    }
    t.to_markdown()
}
