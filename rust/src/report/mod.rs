//! Report harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §6 for the experiment index). Each generator
//! prints a markdown table whose rows mirror the paper's, produced by
//! actually running the corresponding experiment on the simulator.
//!
//! `quaff report <id> [--steps N] [--budget-secs S] [--preset P]`

pub mod ossh;
mod perf_grid;

use crate::coordinator::ServerConfig;
use crate::methods::MethodKind;
use crate::util::cli::Args;

/// Scaling knobs shared by all reports (paper-scale runs are hours on a
/// GPU; defaults here finish in minutes on the CPU simulator).
#[derive(Clone, Debug)]
pub struct ReportOpts {
    pub steps: u64,
    pub batch: usize,
    pub budget_secs: f64,
    pub preset: String,
    pub seeds: u64,
}

impl ReportOpts {
    pub fn from_args(args: &Args) -> ReportOpts {
        ReportOpts {
            steps: args.get_parse("steps", 12),
            batch: args.get_parse("batch", 4),
            budget_secs: args.get_parse("budget-secs", 20.0),
            preset: args.get_or("preset", "phi-mini").to_string(),
            seeds: args.get_parse("seeds", 1),
        }
    }

    pub fn server_cfg(&self, preset: &str) -> ServerConfig {
        let mut cfg = ServerConfig::default();
        cfg.preset = preset.to_string();
        cfg.calib_samples = 32;
        cfg.calib_batch = 8;
        cfg
    }
}

impl Default for ReportOpts {
    fn default() -> Self {
        ReportOpts {
            steps: 12,
            batch: 4,
            budget_secs: 20.0,
            preset: "phi-mini".to_string(),
            seeds: 1,
        }
    }
}

/// Simple markdown table builder.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with 3 decimals (metric cells).
pub fn f3(x: f64) -> String {
    if x.is_nan() {
        "—".to_string()
    } else {
        format!("{x:.3}")
    }
}

/// Format seconds (latency cells).
pub fn secs(x: f64) -> String {
    format!("{x:.3}s")
}

/// All report ids.
pub const ALL_REPORTS: [&str; 18] = [
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
    "table1", "table2", "table3", "table4", "table5", "table6", "table7",
];

/// Generate one report by id; returns the markdown (also suitable for
/// inclusion in the paper-vs-measured record, DESIGN.md §Reports).
pub fn generate(id: &str, opts: &ReportOpts) -> String {
    match id {
        "fig1" => perf_grid::fig1(opts),
        "fig2" => ossh::fig2(opts),
        "fig3" => ossh::hit_rate_report("fig3", "phi-mini", "oig-chip2", "oig-chip2", false, opts),
        "fig4" => perf_grid::fig4(opts),
        "fig5" => perf_grid::fig5(opts),
        "fig6" => perf_grid::fig6(opts),
        "fig7" => perf_grid::fig7(opts),
        "fig8" => ossh::hit_rate_report("fig8", "llama-tiny", "oig-chip2", "oig-chip2", false, opts),
        "fig9" => ossh::hit_rate_report("fig9", "phi-mini", "oig-chip2", "oig-chip2", true, opts),
        "fig10" => ossh::hit_rate_report("fig10", "phi-mini", "oig-chip2", "gpqa", false, opts),
        "fig11" => ossh::fig11(opts),
        "table1" => perf_grid::table1(opts),
        "table2" => perf_grid::table2(opts),
        "table3" => perf_grid::table3(opts),
        "table4" => perf_grid::table4(opts),
        "table5" => perf_grid::table5(opts),
        "table6" => ossh::table6(opts),
        "table7" => ossh::table7(opts),
        other => format!("unknown report id '{other}'; known: {ALL_REPORTS:?}\n"),
    }
}

/// Paper-style method ordering for table rows.
pub fn method_rows() -> Vec<MethodKind> {
    vec![
        MethodKind::Fp32,
        MethodKind::LlmInt8,
        MethodKind::SmoothDynamic,
        MethodKind::Naive,
        MethodKind::SmoothStatic,
        MethodKind::Quaff,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn unknown_report_is_graceful() {
        let out = generate("fig99", &ReportOpts::default());
        assert!(out.contains("unknown report"));
    }

    #[test]
    fn formatting() {
        assert_eq!(f3(0.12345), "0.123");
        assert_eq!(f3(f64::NAN), "—");
        assert_eq!(secs(1.5), "1.500s");
    }
}
