//! OSSH-validation instruments: hit-rate curves (Figs. 3, 8, 9, 10;
//! Table 6), activation-stability traces (Fig. 2) and the Pearson
//! similarity decay of static scaling (Fig. 11).

use super::{f3, ReportOpts, Table};
use crate::coordinator::{PreprocessServer, ServerConfig};
use crate::data::{Sample, SynthTask};
use crate::methods::MethodKind;
use crate::model::{Model, ModelConfig};
use crate::outlier::{
    BudgetAllocator, BudgetPolicy, HitRateTracker, LayerKind, OutlierDetector, OutlierSet,
    SimilarityTracker,
};
use crate::peft::PeftKind;
use crate::quant;
use crate::scaling::{self, MomentumScaler};
use crate::train::Trainer;
use crate::util::prng::Rng;
use std::collections::BTreeMap;

fn batchify(task: &SynthTask, n: usize, rng: &mut Rng) -> Vec<Sample> {
    (0..n).map(|_| task.sample(rng)).collect()
}

/// Shared engine for Figs. 3 / 8 / 9 / 10 and Table 6: fine-tune under a
/// calibrated Quaff bundle, and per iteration compare the dynamically
/// detected outlier channels of every linear layer against the
/// pre-identified set.
#[allow(clippy::too_many_arguments)]
fn hit_rate_run(
    preset: &str,
    calib_task: &str,
    ft_task: &str,
    uniform: bool,
    steps: u64,
    batch: usize,
    max_len: usize,
) -> BTreeMap<LayerKind, (f64, f64)> {
    let mut cfg = ServerConfig::default();
    cfg.preset = preset.to_string();
    cfg.calib_task = calib_task.to_string();
    cfg.calib_samples = 32;
    cfg.calib_batch = 8;
    if uniform {
        cfg.budget = BudgetPolicy::Uniform(0.02);
    }
    let server = PreprocessServer::new(cfg.clone());
    let mut bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
    let model = &mut bundle.model;
    let detector = OutlierDetector::new(cfg.detector_tau);
    // trackers per linear layer
    let mut trackers: BTreeMap<String, HitRateTracker> = BTreeMap::new();
    for (name, set) in bundle.registry.layers() {
        trackers.insert(name.clone(), HitRateTracker::new(name, set.clone()));
    }
    let task = SynthTask::by_name(ft_task).unwrap();
    let mut rng = Rng::new(0xF17);
    let mut trainer = Trainer::new(2e-3, max_len, 1);
    for _ in 0..steps {
        // enable single-step taps
        for b in &mut model.blocks {
            for l in b.linears() {
                l.start_calibration();
            }
        }
        let samples = batchify(&task, batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let _ = trainer.step(model, &[refs]);
        // harvest realtime detections
        for b in &mut model.blocks {
            for l in b.linears() {
                if let Some(stats) = l.take_stats() {
                    let cap = (l.cin() / 8).max(4);
                    let realtime = detector.select(&stats, cap);
                    trackers.get_mut(&l.name).unwrap().record(&realtime);
                }
            }
        }
    }
    // aggregate per layer kind
    let mut agg: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for (name, tr) in &trackers {
        let kind = LayerKind::from_name(name);
        agg.entry(kind.label()).or_default().push(tr.summary().0);
    }
    let mut out = BTreeMap::new();
    for kind in [
        LayerKind::QProj,
        LayerKind::KProj,
        LayerKind::VProj,
        LayerKind::OProj,
        LayerKind::UpProj,
        LayerKind::DownProj,
    ] {
        if let Some(v) = agg.get(kind.label()) {
            let mean = v.iter().sum::<f64>() / v.len() as f64;
            let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
            out.insert(kind, (mean, var.sqrt()));
        }
    }
    out
}

/// Figs. 3 / 8 / 9 / 10 — per-layer hit rate of predefined vs realtime
/// outlier channels.
pub fn hit_rate_report(
    id: &str,
    preset: &str,
    calib_task: &str,
    ft_task: &str,
    uniform: bool,
    opts: &ReportOpts,
) -> String {
    let title = match id {
        "fig3" => format!("Fig. 3 — hit rate per layer ({preset}, calib {calib_task}, FT {ft_task})"),
        "fig8" => format!("Fig. 8 — hit rate per layer ({preset})"),
        "fig9" => format!("Fig. 9 — hit rate under UNIFORM budget ({preset})"),
        "fig10" => format!("Fig. 10 — cross-dataset hit rate (calib {calib_task} → FT {ft_task})"),
        _ => format!("{id} — hit rate"),
    };
    let rates = hit_rate_run(
        preset,
        calib_task,
        ft_task,
        uniform,
        (opts.steps * 2).max(8),
        opts.batch,
        160,
    );
    let mut t = Table::new(&title, &["Layer", "Mean hit rate", "Std"]);
    let mut overall = 0.0f64;
    let mut n = 0.0f64;
    for (kind, (mean, std)) in &rates {
        t.push(vec![kind.label().to_string(), f3(*mean), f3(*std)]);
        overall += mean;
        n += 1.0;
    }
    t.push(vec!["**overall**".into(), f3(overall / n.max(1.0)), String::new()]);
    t.to_markdown()
}

/// Table 6 — hit rate per layer type in the long-context setting
/// (paper: 32 K tokens; scaled here to the simulator's max sequence).
pub fn table6(opts: &ReportOpts) -> String {
    let rates = hit_rate_run(
        &opts.preset,
        "oig-chip2",
        "longform",
        false,
        opts.steps.max(6),
        2,
        320,
    );
    let mut t = Table::new(
        &format!("Table 6 — long-context hit rate ({}, ctx-scaled)", opts.preset),
        &["Layer", "Average hit rate"],
    );
    for (kind, (mean, _)) in &rates {
        t.push(vec![kind.label().to_string(), f3(*mean)]);
    }
    t.to_markdown()
}

/// Table 7 — outlier budget sweep (overall budgets 5/3/1/0.1/0 %).
pub fn table7(opts: &ReportOpts) -> String {
    let mut t = Table::new(
        "Table 7 — accuracy vs overall outlier budget",
        &["Budget", "GPQA llama-tiny", "GPQA phi-mini", "LAMBADA llama-tiny", "LAMBADA phi-mini"],
    );
    for budget_pct in [5.0, 3.0, 1.0, 0.1, 0.0] {
        let mut row = vec![format!("{budget_pct}%")];
        for (dataset, preset) in [
            ("gpqa", "llama-tiny"),
            ("gpqa", "phi-mini"),
            ("lambada", "llama-tiny"),
            ("lambada", "phi-mini"),
        ] {
            let mut cfg = opts.server_cfg(preset);
            cfg.budget = BudgetPolicy::ScaledNonUniform(budget_pct / 100.0);
            let server = PreprocessServer::new(cfg);
            let mut j = crate::coordinator::FinetuneJob::new(0, dataset, MethodKind::Quaff, PeftKind::Lora);
            j.steps = opts.steps;
            j.batch_size = if dataset == "lambada" { 2 } else { opts.batch };
            j.max_len = if dataset == "lambada" { 256 } else { 160 };
            let r = crate::coordinator::run_job(&server, &j).expect("embedded dataset");
            row.push(f3(r.metric("acc")));
        }
        t.push(row);
    }
    t.to_markdown()
}

/// Fig. 2 — (a) spatial stability of outlier channel indices,
/// (b) magnitude drift, (c) static scaling vs Quaff's targeted momentum
/// scaling under that drift.
pub fn fig2(opts: &ReportOpts) -> String {
    let mcfg = ModelConfig::preset(&opts.preset).unwrap();
    let mut model = Model::new(mcfg, 0xF16);
    model.attach_peft(PeftKind::Lora);
    let task = SynthTask::by_name("oig-chip2").unwrap();
    let mut rng = Rng::new(0xF2);
    let mut trainer = Trainer::new(2e-3, 128, 1);
    let steps = (opts.steps * 2).max(12);
    // watch the first block's down_proj input
    let mut top_indices: Vec<Vec<usize>> = Vec::new();
    let mut hot_magnitude: Vec<f32> = Vec::new();
    let mut captured: Vec<crate::tensor::Matrix> = Vec::new();
    for _ in 0..steps {
        model.blocks[0].down_proj.capture_next = true;
        let samples = batchify(&task, opts.batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let _ = trainer.step(&mut model, &[refs]);
        if let Some(x) = model.blocks[0].down_proj.captured.take() {
            let cm = x.col_abs_max();
            let mut idx: Vec<usize> = (0..cm.len()).collect();
            idx.sort_by(|&a, &b| cm[b].partial_cmp(&cm[a]).unwrap());
            top_indices.push(idx[..5].to_vec());
            hot_magnitude.push(cm[idx[0]]);
            captured.push(x);
        }
    }
    let mut out = format!("\n### Fig. 2 — outlier stability & scaling efficacy ({})\n\n", opts.preset);
    out.push_str("(a) top-5 outlier channel indices per sampled iteration:\n\n");
    for (i, idx) in top_indices.iter().enumerate().step_by((steps as usize / 6).max(1)) {
        out.push_str(&format!("  iter {i:3}: {idx:?}\n"));
    }
    let stable = {
        let mut first: Vec<usize> = top_indices[0].clone();
        first.sort_unstable();
        top_indices
            .iter()
            .filter(|v| {
                let mut s = (*v).clone();
                s.sort_unstable();
                s == first
            })
            .count() as f64
            / top_indices.len() as f64
    };
    out.push_str(&format!("\n  index-set stability across iterations: {:.1}%\n", stable * 100.0));
    out.push_str("\n(b) hottest-channel magnitude per iteration (drift):\n\n  ");
    for (i, m) in hot_magnitude.iter().enumerate() {
        if i % (steps as usize / 8).max(1) == 0 {
            out.push_str(&format!("iter {i}: {m:.1}  "));
        }
    }
    // (c) quantization error under three schemes across the drift
    let first = &captured[0];
    let o_idx = {
        let cm = first.col_abs_max();
        let mut idx: Vec<usize> = (0..cm.len()).collect();
        idx.sort_by(|&a, &b| cm[b].partial_cmp(&cm[a]).unwrap());
        OutlierSet::new(idx[..(cm.len() / 20).max(3)].to_vec())
    };
    // static factors frozen at iteration 0
    let w_row_max = vec![1.0f32; first.cols()]; // unit weights: factor = sqrt(max|X|)
    let static_s: Vec<f32> = {
        let mut s = MomentumScaler::without_momentum(0.2, o_idx.clone());
        s.update(&first.col_abs_max(), &w_row_max);
        s.factors().to_vec()
    };
    let mut quaff_s = MomentumScaler::new(0.2, o_idx.clone());
    let mut out_c = String::from("\n\n(c) per-token quantization MSE of X̂ (lower = better):\n\n");
    out_c.push_str("| iter | no scaling | static (iter-0) | Quaff momentum |\n|---|---|---|---|\n");
    for (i, x) in captured.iter().enumerate() {
        quaff_s.update(&x.col_abs_max(), &w_row_max);
        let e_none = quant::error_per_token(x).mse;
        let mut xs = x.clone();
        scaling::apply_targeted_inverse_scale(&mut xs, &o_idx, &static_s);
        let e_static = quant::error_per_token(&xs).mse;
        let mut xq = x.clone();
        scaling::apply_targeted_inverse_scale(&mut xq, &o_idx, quaff_s.factors());
        let e_quaff = quant::error_per_token(&xq).mse;
        if i % (steps as usize / 8).max(1) == 0 || i == captured.len() - 1 {
            out_c.push_str(&format!(
                "| {i} | {:.2e} | {:.2e} | {:.2e} |\n",
                e_none, e_static, e_quaff
            ));
        }
    }
    out.push_str(&out_c);
    out
}

/// Fig. 11 — Pearson similarity between static (calibration-time) and
/// dynamic (live) scaling factors over the top channels, per layer, across
/// fine-tuning iterations.
pub fn fig11(opts: &ReportOpts) -> String {
    let mcfg = ModelConfig::preset(&opts.preset).unwrap();
    let mut model = Model::new(mcfg, 0xF11);
    model.attach_peft(PeftKind::Lora);
    let task = SynthTask::by_name("oig-chip2").unwrap();
    let mut rng = Rng::new(0xF3);
    // calibration phase: collect static factors per layer
    model.start_calibration();
    for _ in 0..4 {
        let samples = batchify(&task, opts.batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let (toks, _) = crate::data::pack_batch(&refs, 128);
        let _ = model.forward(&toks, false);
    }
    let calib = model.finish_calibration();
    // per-layer: top-1% channels by calibration magnitude; w_row_max from
    // masters (model not yet quantized)
    let mut trackers: Vec<(String, SimilarityTracker, Vec<f32>)> = Vec::new();
    for b in &mut model.blocks {
        for l in b.linears() {
            let stats = &calib[&l.name];
            let w = l.master().expect("fig11 requires unquantized masters");
            let w_row_max: Vec<f32> = (0..w.rows())
                .map(|i| w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
                .collect();
            let k = (l.cin() / 100).max(2);
            let mut idx: Vec<usize> = (0..l.cin()).collect();
            idx.sort_by(|&a, &b| stats.abs_max[b].partial_cmp(&stats.abs_max[a]).unwrap());
            let channels: Vec<usize> = idx[..k].to_vec();
            let all_static = scaling::smoothquant_factors(&stats.abs_max, &w_row_max, 0.5);
            let static_sub: Vec<f32> = channels.iter().map(|&c| all_static[c]).collect();
            trackers.push((
                l.name.clone(),
                SimilarityTracker::new(&l.name, channels, static_sub),
                w_row_max,
            ));
        }
    }
    // fine-tune and track
    let mut trainer = Trainer::new(2e-3, 128, 1);
    let steps = (opts.steps * 3).max(16);
    for _ in 0..steps {
        for b in &mut model.blocks {
            for l in b.linears() {
                l.start_calibration();
            }
        }
        let samples = batchify(&task, opts.batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let _ = trainer.step(&mut model, &[refs]);
        let mut i = 0;
        for b in &mut model.blocks {
            for l in b.linears() {
                let stats = l.take_stats().unwrap();
                let (_, tr, w_row_max) = &mut trackers[i];
                let dynamic = scaling::smoothquant_factors(&stats.abs_max, w_row_max, 0.5);
                tr.record_full(&dynamic);
                i += 1;
            }
        }
    }
    // aggregate per layer kind: similarity at first / mid / last iteration
    let mut t = Table::new(
        &format!(
            "Fig. 11 — Pearson similarity static vs dynamic factors (top 1%, {})",
            opts.preset
        ),
        &["Layer", "iter 1", "mid", "final"],
    );
    let mut agg: BTreeMap<&str, Vec<(f32, f32, f32)>> = BTreeMap::new();
    for (name, tr, _) in &trackers {
        let s = tr.series();
        if s.is_empty() {
            continue;
        }
        agg.entry(LayerKind::from_name(name).label()).or_default().push((
            s[0],
            s[s.len() / 2],
            s[s.len() - 1],
        ));
    }
    for (kind, vals) in agg {
        let n = vals.len() as f32;
        let (a, b, c) = vals.iter().fold((0.0, 0.0, 0.0), |(x, y, z), v| {
            (x + v.0, y + v.1, z + v.2)
        });
        t.push(vec![
            kind.to_string(),
            f3((a / n) as f64),
            f3((b / n) as f64),
            f3((c / n) as f64),
        ]);
    }
    let alloc = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
    let _ = alloc; // (budget allocator unused here; kept for parity with fig3)
    t.to_markdown()
}
