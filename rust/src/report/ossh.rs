//! OSSH-validation instruments: hit-rate curves (Figs. 3, 8, 9, 10;
//! Table 6), activation-stability traces (Fig. 2), the Pearson similarity
//! decay of static scaling (Fig. 11) — and the **OSSH validation harness**
//! (DESIGN.md §11): long-run drift telemetry over every `QuantLinear`
//! during training, adaptive re-detection when a layer's hit rate stays
//! under a configurable budget, and the versioned `OSSH_report.json`
//! artifact.
//!
//! The harness rides the existing calibration tap ([`crate::model::linear::
//! QuantLinear::start_calibration`]): the tap only *observes* activations —
//! no RNG draws, no workspace perturbation — which is what makes
//! telemetry-on runs bit-identical to telemetry-off runs
//! (`tests/ossh_stability.rs` pins it for all six methods).

use super::{f3, ReportOpts, Table};
use crate::coordinator::{
    validate_resume, CheckpointSpec, FinetuneJob, PreprocessServer, ServerConfig,
};
use crate::data::{Sample, SynthTask};
use crate::methods::{method_from_snapshot, MethodKind};
use crate::model::{Model, ModelConfig};
use crate::outlier::{
    BudgetAllocator, BudgetPolicy, ChannelStats, HitRateTracker, LayerKind, OutlierDetector,
    OutlierRegistry, OutlierSet, SimilarityTracker,
};
use crate::peft::PeftKind;
use crate::persist;
use crate::quant;
use crate::scaling::{self, MomentumScaler};
use crate::train::Trainer;
use crate::util::codec::SectionWriter;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn batchify(task: &SynthTask, n: usize, rng: &mut Rng) -> Vec<Sample> {
    (0..n).map(|_| task.sample(rng)).collect()
}

/// Shared engine for Figs. 3 / 8 / 9 / 10 and Table 6: fine-tune under a
/// calibrated Quaff bundle, and per iteration compare the dynamically
/// detected outlier channels of every linear layer against the
/// pre-identified set.
#[allow(clippy::too_many_arguments)]
fn hit_rate_run(
    preset: &str,
    calib_task: &str,
    ft_task: &str,
    uniform: bool,
    steps: u64,
    batch: usize,
    max_len: usize,
) -> BTreeMap<LayerKind, (f64, f64)> {
    let mut cfg = ServerConfig::default();
    cfg.preset = preset.to_string();
    cfg.calib_task = calib_task.to_string();
    cfg.calib_samples = 32;
    cfg.calib_batch = 8;
    if uniform {
        cfg.budget = BudgetPolicy::Uniform(0.02);
    }
    let server = PreprocessServer::new(cfg.clone());
    let mut bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
    let model = &mut bundle.model;
    let detector = OutlierDetector::new(cfg.detector_tau);
    // trackers per linear layer
    let mut trackers: BTreeMap<String, HitRateTracker> = BTreeMap::new();
    for (name, set) in bundle.registry.layers() {
        trackers.insert(name.clone(), HitRateTracker::new(name, set.clone()));
    }
    let task = SynthTask::by_name(ft_task).unwrap();
    let mut rng = Rng::new(0xF17);
    let mut trainer = Trainer::new(2e-3, max_len, 1);
    for _ in 0..steps {
        // enable single-step taps
        for b in &mut model.blocks {
            for l in b.linears() {
                l.start_calibration();
            }
        }
        let samples = batchify(&task, batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let _ = trainer.step(model, &[refs]);
        // harvest realtime detections
        for b in &mut model.blocks {
            for l in b.linears() {
                if let Some(stats) = l.take_stats() {
                    let cap = (l.cin() / 8).max(4);
                    let realtime = detector.select(&stats, cap);
                    trackers.get_mut(&l.name).unwrap().record(&realtime);
                }
            }
        }
    }
    // aggregate per layer kind
    let mut agg: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for (name, tr) in &trackers {
        let kind = LayerKind::from_name(name);
        agg.entry(kind.label()).or_default().push(tr.summary().0);
    }
    let mut out = BTreeMap::new();
    for kind in [
        LayerKind::QProj,
        LayerKind::KProj,
        LayerKind::VProj,
        LayerKind::OProj,
        LayerKind::UpProj,
        LayerKind::DownProj,
    ] {
        if let Some(v) = agg.get(kind.label()) {
            let mean = v.iter().sum::<f64>() / v.len() as f64;
            let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
            out.insert(kind, (mean, var.sqrt()));
        }
    }
    out
}

/// Figs. 3 / 8 / 9 / 10 — per-layer hit rate of predefined vs realtime
/// outlier channels.
pub fn hit_rate_report(
    id: &str,
    preset: &str,
    calib_task: &str,
    ft_task: &str,
    uniform: bool,
    opts: &ReportOpts,
) -> String {
    let title = match id {
        "fig3" => format!("Fig. 3 — hit rate per layer ({preset}, calib {calib_task}, FT {ft_task})"),
        "fig8" => format!("Fig. 8 — hit rate per layer ({preset})"),
        "fig9" => format!("Fig. 9 — hit rate under UNIFORM budget ({preset})"),
        "fig10" => format!("Fig. 10 — cross-dataset hit rate (calib {calib_task} → FT {ft_task})"),
        _ => format!("{id} — hit rate"),
    };
    let rates = hit_rate_run(
        preset,
        calib_task,
        ft_task,
        uniform,
        (opts.steps * 2).max(8),
        opts.batch,
        160,
    );
    let mut t = Table::new(&title, &["Layer", "Mean hit rate", "Std"]);
    let mut overall = 0.0f64;
    let mut n = 0.0f64;
    for (kind, (mean, std)) in &rates {
        t.push(vec![kind.label().to_string(), f3(*mean), f3(*std)]);
        overall += mean;
        n += 1.0;
    }
    t.push(vec!["**overall**".into(), f3(overall / n.max(1.0)), String::new()]);
    t.to_markdown()
}

/// Table 6 — hit rate per layer type in the long-context setting
/// (paper: 32 K tokens; scaled here to the simulator's max sequence).
pub fn table6(opts: &ReportOpts) -> String {
    let rates = hit_rate_run(
        &opts.preset,
        "oig-chip2",
        "longform",
        false,
        opts.steps.max(6),
        2,
        320,
    );
    let mut t = Table::new(
        &format!("Table 6 — long-context hit rate ({}, ctx-scaled)", opts.preset),
        &["Layer", "Average hit rate"],
    );
    for (kind, (mean, _)) in &rates {
        t.push(vec![kind.label().to_string(), f3(*mean)]);
    }
    t.to_markdown()
}

/// Table 7 — outlier budget sweep (overall budgets 5/3/1/0.1/0 %).
pub fn table7(opts: &ReportOpts) -> String {
    let mut t = Table::new(
        "Table 7 — accuracy vs overall outlier budget",
        &["Budget", "GPQA llama-tiny", "GPQA phi-mini", "LAMBADA llama-tiny", "LAMBADA phi-mini"],
    );
    for budget_pct in [5.0, 3.0, 1.0, 0.1, 0.0] {
        let mut row = vec![format!("{budget_pct}%")];
        for (dataset, preset) in [
            ("gpqa", "llama-tiny"),
            ("gpqa", "phi-mini"),
            ("lambada", "llama-tiny"),
            ("lambada", "phi-mini"),
        ] {
            let mut cfg = opts.server_cfg(preset);
            cfg.budget = BudgetPolicy::ScaledNonUniform(budget_pct / 100.0);
            let server = PreprocessServer::new(cfg);
            let mut j = crate::coordinator::FinetuneJob::new(0, dataset, MethodKind::Quaff, PeftKind::Lora);
            j.steps = opts.steps;
            j.batch_size = if dataset == "lambada" { 2 } else { opts.batch };
            j.max_len = if dataset == "lambada" { 256 } else { 160 };
            let r = crate::coordinator::run_job(&server, &j).expect("embedded dataset");
            row.push(f3(r.metric("acc")));
        }
        t.push(row);
    }
    t.to_markdown()
}

/// Fig. 2 — (a) spatial stability of outlier channel indices,
/// (b) magnitude drift, (c) static scaling vs Quaff's targeted momentum
/// scaling under that drift.
pub fn fig2(opts: &ReportOpts) -> String {
    let mcfg = ModelConfig::preset(&opts.preset).unwrap();
    let mut model = Model::new(mcfg, 0xF16);
    model.attach_peft(PeftKind::Lora);
    let task = SynthTask::by_name("oig-chip2").unwrap();
    let mut rng = Rng::new(0xF2);
    let mut trainer = Trainer::new(2e-3, 128, 1);
    let steps = (opts.steps * 2).max(12);
    // watch the first block's down_proj input
    let mut top_indices: Vec<Vec<usize>> = Vec::new();
    let mut hot_magnitude: Vec<f32> = Vec::new();
    let mut captured: Vec<crate::tensor::Matrix> = Vec::new();
    for _ in 0..steps {
        model.blocks[0].down_proj.capture_next = true;
        let samples = batchify(&task, opts.batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let _ = trainer.step(&mut model, &[refs]);
        if let Some(x) = model.blocks[0].down_proj.captured.take() {
            let cm = x.col_abs_max();
            let mut idx: Vec<usize> = (0..cm.len()).collect();
            idx.sort_by(|&a, &b| cm[b].partial_cmp(&cm[a]).unwrap());
            top_indices.push(idx[..5].to_vec());
            hot_magnitude.push(cm[idx[0]]);
            captured.push(x);
        }
    }
    let mut out = format!("\n### Fig. 2 — outlier stability & scaling efficacy ({})\n\n", opts.preset);
    out.push_str("(a) top-5 outlier channel indices per sampled iteration:\n\n");
    for (i, idx) in top_indices.iter().enumerate().step_by((steps as usize / 6).max(1)) {
        out.push_str(&format!("  iter {i:3}: {idx:?}\n"));
    }
    let stable = {
        let mut first: Vec<usize> = top_indices[0].clone();
        first.sort_unstable();
        top_indices
            .iter()
            .filter(|v| {
                let mut s = (*v).clone();
                s.sort_unstable();
                s == first
            })
            .count() as f64
            / top_indices.len() as f64
    };
    out.push_str(&format!("\n  index-set stability across iterations: {:.1}%\n", stable * 100.0));
    out.push_str("\n(b) hottest-channel magnitude per iteration (drift):\n\n  ");
    for (i, m) in hot_magnitude.iter().enumerate() {
        if i % (steps as usize / 8).max(1) == 0 {
            out.push_str(&format!("iter {i}: {m:.1}  "));
        }
    }
    // (c) quantization error under three schemes across the drift
    let first = &captured[0];
    let o_idx = {
        let cm = first.col_abs_max();
        let mut idx: Vec<usize> = (0..cm.len()).collect();
        idx.sort_by(|&a, &b| cm[b].partial_cmp(&cm[a]).unwrap());
        OutlierSet::new(idx[..(cm.len() / 20).max(3)].to_vec())
    };
    // static factors frozen at iteration 0
    let w_row_max = vec![1.0f32; first.cols()]; // unit weights: factor = sqrt(max|X|)
    let static_s: Vec<f32> = {
        let mut s = MomentumScaler::without_momentum(0.2, o_idx.clone());
        s.update(&first.col_abs_max(), &w_row_max);
        s.factors().to_vec()
    };
    let mut quaff_s = MomentumScaler::new(0.2, o_idx.clone());
    let mut out_c = String::from("\n\n(c) per-token quantization MSE of X̂ (lower = better):\n\n");
    out_c.push_str("| iter | no scaling | static (iter-0) | Quaff momentum |\n|---|---|---|---|\n");
    for (i, x) in captured.iter().enumerate() {
        quaff_s.update(&x.col_abs_max(), &w_row_max);
        let e_none = quant::error_per_token(x).mse;
        let mut xs = x.clone();
        scaling::apply_targeted_inverse_scale(&mut xs, &o_idx, &static_s);
        let e_static = quant::error_per_token(&xs).mse;
        let mut xq = x.clone();
        scaling::apply_targeted_inverse_scale(&mut xq, &o_idx, quaff_s.factors());
        let e_quaff = quant::error_per_token(&xq).mse;
        if i % (steps as usize / 8).max(1) == 0 || i == captured.len() - 1 {
            out_c.push_str(&format!(
                "| {i} | {:.2e} | {:.2e} | {:.2e} |\n",
                e_none, e_static, e_quaff
            ));
        }
    }
    out.push_str(&out_c);
    out
}

/// Fig. 11 — Pearson similarity between static (calibration-time) and
/// dynamic (live) scaling factors over the top channels, per layer, across
/// fine-tuning iterations.
pub fn fig11(opts: &ReportOpts) -> String {
    let mcfg = ModelConfig::preset(&opts.preset).unwrap();
    let mut model = Model::new(mcfg, 0xF11);
    model.attach_peft(PeftKind::Lora);
    let task = SynthTask::by_name("oig-chip2").unwrap();
    let mut rng = Rng::new(0xF3);
    // calibration phase: collect static factors per layer
    model.start_calibration();
    for _ in 0..4 {
        let samples = batchify(&task, opts.batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let (toks, _) = crate::data::pack_batch(&refs, 128);
        let _ = model.forward(&toks, false);
    }
    let calib = model.finish_calibration();
    // per-layer: top-1% channels by calibration magnitude; w_row_max from
    // masters (model not yet quantized)
    let mut trackers: Vec<(String, SimilarityTracker, Vec<f32>)> = Vec::new();
    for b in &mut model.blocks {
        for l in b.linears() {
            let stats = &calib[&l.name];
            let w = l.master().expect("fig11 requires unquantized masters");
            let w_row_max: Vec<f32> = (0..w.rows())
                .map(|i| w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
                .collect();
            let k = (l.cin() / 100).max(2);
            let mut idx: Vec<usize> = (0..l.cin()).collect();
            idx.sort_by(|&a, &b| stats.abs_max[b].partial_cmp(&stats.abs_max[a]).unwrap());
            let channels: Vec<usize> = idx[..k].to_vec();
            let all_static = scaling::smoothquant_factors(&stats.abs_max, &w_row_max, 0.5);
            let static_sub: Vec<f32> = channels.iter().map(|&c| all_static[c]).collect();
            trackers.push((
                l.name.clone(),
                SimilarityTracker::new(&l.name, channels, static_sub),
                w_row_max,
            ));
        }
    }
    // fine-tune and track
    let mut trainer = Trainer::new(2e-3, 128, 1);
    let steps = (opts.steps * 3).max(16);
    for _ in 0..steps {
        for b in &mut model.blocks {
            for l in b.linears() {
                l.start_calibration();
            }
        }
        let samples = batchify(&task, opts.batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let _ = trainer.step(&mut model, &[refs]);
        let mut i = 0;
        for b in &mut model.blocks {
            for l in b.linears() {
                let stats = l.take_stats().unwrap();
                let (_, tr, w_row_max) = &mut trackers[i];
                let dynamic = scaling::smoothquant_factors(&stats.abs_max, w_row_max, 0.5);
                tr.record_full(&dynamic);
                i += 1;
            }
        }
    }
    // aggregate per layer kind: similarity at first / mid / last iteration
    let mut t = Table::new(
        &format!(
            "Fig. 11 — Pearson similarity static vs dynamic factors (top 1%, {})",
            opts.preset
        ),
        &["Layer", "iter 1", "mid", "final"],
    );
    let mut agg: BTreeMap<&str, Vec<(f32, f32, f32)>> = BTreeMap::new();
    for (name, tr, _) in &trackers {
        let s = tr.series();
        if s.is_empty() {
            continue;
        }
        agg.entry(LayerKind::from_name(name).label()).or_default().push((
            s[0],
            s[s.len() / 2],
            s[s.len() - 1],
        ));
    }
    for (kind, vals) in agg {
        let n = vals.len() as f32;
        let (a, b, c) = vals.iter().fold((0.0, 0.0, 0.0), |(x, y, z), v| {
            (x + v.0, y + v.1, z + v.2)
        });
        t.push(vec![
            kind.to_string(),
            f3((a / n) as f64),
            f3((b / n) as f64),
            f3((c / n) as f64),
        ]);
    }
    let alloc = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
    let _ = alloc; // (budget allocator unused here; kept for parity with fig3)
    t.to_markdown()
}

// ===================================================================
// OSSH validation harness (DESIGN.md §11)
// ===================================================================

/// Version stamp of the `OSSH_report.json` artifact (strict equality on
/// read, like the binary archive format).
pub const OSSH_REPORT_VERSION: u32 = 1;

/// Artifact-kind string of the persisted harness state
/// ([`OsshHarness::save_state`]), enforced by `persist::load_artifact`.
const OSSH_STATE_KIND: &str = "ossh-telemetry";

/// Drift-telemetry configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct OsshConfig {
    /// Run a telemetry check every N training steps (1 = every step).
    pub check_every: u64,
    /// Drift budget: a check with hit rate **strictly below** this value
    /// counts against the layer's patience.
    pub drift_budget: f64,
    /// Number of *consecutive* below-budget checks that triggers adaptive
    /// re-detection (when [`OsshConfig::redetect`] is on).
    pub patience: u32,
    /// Hot-swap the reference set (and, for Quaff layers, the live method's
    /// targeted channels) when patience runs out. Off by default: plain
    /// telemetry must never alter the training trajectory.
    pub redetect: bool,
    /// Real-time detection cap: `max(cin / cap_div, cap_min)` channels.
    pub realtime_cap_div: usize,
    pub realtime_cap_min: usize,
}

impl Default for OsshConfig {
    fn default() -> Self {
        OsshConfig {
            check_every: 1,
            drift_budget: 0.5,
            patience: 2,
            redetect: false,
            realtime_cap_div: 8,
            realtime_cap_min: 4,
        }
    }
}

/// One below-budget telemetry check.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftEvent {
    pub step: u64,
    pub layer: String,
    pub hit_rate: f64,
    /// How many consecutive below-budget checks this one makes.
    pub consecutive: u32,
}

/// One adaptive re-detection: the reference set was hot-swapped.
#[derive(Clone, Debug, PartialEq)]
pub struct SwapEvent {
    pub step: u64,
    pub layer: String,
    /// Hit rate at the check that exhausted the patience.
    pub hit_rate: f64,
    pub old_channels: Vec<usize>,
    pub new_channels: Vec<usize>,
    /// Whether the live method's targeted channel set was re-pointed too
    /// (Quaff layers; other methods carry no targeted set, so only the
    /// telemetry reference moves).
    pub method_swapped: bool,
}

/// Per-layer telemetry state.
struct LayerTelemetry {
    /// Hit rate vs the *current* reference (starts at the step-0 set;
    /// adaptive re-detection moves it).
    tracker: HitRateTracker,
    /// The immutable step-0 reference — Jaccard curves are always measured
    /// against it so stability stays comparable across swaps.
    reference0: OutlierSet,
    /// Jaccard(realtime, reference0) per check; empty-vs-empty counts 1.0.
    jaccard: Vec<f64>,
    /// Pearson similarity of SmoothQuant-style factors vs the first
    /// check's statics, over the step-0 channels (the Fig. 11 measurement,
    /// running live).
    similarity: SimilarityTracker,
    statics_ready: bool,
    /// Consecutive below-budget checks.
    below: u32,
    drift_events: Vec<DriftEvent>,
    swap_events: Vec<SwapEvent>,
}

/// The OSSH validation harness: instruments every `QuantLinear` of a
/// training run through the calibration tap, accumulates stability curves,
/// and (optionally) re-detects outliers when drift exhausts the budget.
///
/// Drive it manually with [`OsshHarness::begin_step`] /
/// [`OsshHarness::end_step`] around `Trainer::step`, or let [`OsshRun`]
/// own the whole loop.
pub struct OsshHarness {
    pub cfg: OsshConfig,
    detector: OutlierDetector,
    layers: BTreeMap<String, LayerTelemetry>,
    /// Telemetry checks completed (across resumes).
    checks: u64,
}

impl OsshHarness {
    /// One telemetry slot per registry layer; the registry's sets are the
    /// step-0 references.
    pub fn new(cfg: OsshConfig, detector_tau: f32, registry: &OutlierRegistry) -> OsshHarness {
        let mut layers = BTreeMap::new();
        for (name, set) in registry.layers() {
            layers.insert(
                name.clone(),
                LayerTelemetry {
                    tracker: HitRateTracker::new(name, set.clone()),
                    reference0: set.clone(),
                    jaccard: Vec::new(),
                    similarity: SimilarityTracker::new(name, Vec::new(), Vec::new()),
                    statics_ready: false,
                    below: 0,
                    drift_events: Vec::new(),
                    swap_events: Vec::new(),
                },
            );
        }
        OsshHarness {
            cfg,
            detector: OutlierDetector::new(detector_tau),
            layers,
            checks: 0,
        }
    }

    /// Should step `step` be a telemetry check?
    pub fn is_check_step(&self, step: u64) -> bool {
        self.cfg.check_every > 0 && step % self.cfg.check_every == 0
    }

    /// Arm the calibration taps before the training step.
    pub fn begin_step(&self, model: &mut Model) {
        for b in &mut model.blocks {
            for l in b.linears() {
                l.start_calibration();
            }
        }
    }

    /// Harvest the taps after the training step: record hit-rate/Jaccard/
    /// similarity points and, when re-detection triggers, hot-swap the
    /// layer's targeted channel set through the `MethodSnapshot` seam.
    pub fn end_step(&mut self, model: &mut Model, step: u64) {
        for b in &mut model.blocks {
            for l in b.linears() {
                let Some(stats) = l.take_stats() else { continue };
                let name = l.name.clone();
                if let Some(new_set) = self.observe(&name, &stats, step) {
                    let retargeted = l
                        .method_snapshot()
                        .and_then(|s| s.retarget_channels(&new_set));
                    if let Some(snap) = retargeted {
                        l.set_method(method_from_snapshot(snap));
                        self.mark_method_swapped(&name);
                    }
                }
            }
        }
        self.checks += 1;
    }

    /// The model-independent telemetry core — also the unit-test seam for
    /// the budget boundary semantics. Records one check for `layer` from
    /// its calibration stats; returns the re-detected reference set when
    /// the drift budget ran out of patience (the caller applies it to the
    /// live method).
    pub fn observe(
        &mut self,
        layer: &str,
        stats: &ChannelStats,
        step: u64,
    ) -> Option<OutlierSet> {
        let lt = self.layers.get_mut(layer)?;
        let cap = (stats.channels / self.cfg.realtime_cap_div.max(1)).max(self.cfg.realtime_cap_min);
        let realtime = self.detector.select(stats, cap);
        lt.tracker.record(&realtime);
        let rate = *lt.tracker.series().last().expect("just recorded");
        let inter = lt.reference0.intersection_size(&realtime);
        let union = lt.reference0.len() + realtime.len() - inter;
        lt.jaccard.push(if union == 0 {
            1.0
        } else {
            inter as f64 / union as f64
        });
        // SmoothQuant-style factors over unit weight maxima: a pure
        // function of the activation statistics, frozen on first check.
        let ones = vec![1.0f32; stats.channels];
        let factors = scaling::smoothquant_factors(&stats.abs_max, &ones, 0.5);
        if !lt.statics_ready {
            let channels: Vec<usize> = lt
                .reference0
                .channels
                .iter()
                .copied()
                .filter(|&c| c < factors.len())
                .collect();
            let statics: Vec<f32> = channels.iter().map(|&c| factors[c]).collect();
            lt.similarity = SimilarityTracker::new(layer, channels, statics);
            lt.statics_ready = true;
        }
        lt.similarity.record_full(&factors);
        if rate < self.cfg.drift_budget {
            lt.below += 1;
            lt.drift_events.push(DriftEvent {
                step,
                layer: layer.to_string(),
                hit_rate: rate,
                consecutive: lt.below,
            });
            if self.cfg.redetect && lt.below >= self.cfg.patience {
                let budget = lt.tracker.reference().len().max(self.cfg.realtime_cap_min);
                let new_set = self.detector.select(stats, budget);
                lt.swap_events.push(SwapEvent {
                    step,
                    layer: layer.to_string(),
                    hit_rate: rate,
                    old_channels: lt.tracker.reference().channels.clone(),
                    new_channels: new_set.channels.clone(),
                    method_swapped: false,
                });
                lt.tracker.set_reference(new_set.clone());
                lt.below = 0;
                return Some(new_set);
            }
        } else {
            lt.below = 0;
        }
        None
    }

    fn mark_method_swapped(&mut self, layer: &str) {
        if let Some(ev) = self
            .layers
            .get_mut(layer)
            .and_then(|lt| lt.swap_events.last_mut())
        {
            ev.method_swapped = true;
        }
    }

    /// Telemetry checks completed so far.
    pub fn checks(&self) -> u64 {
        self.checks
    }

    /// All swap events across layers, in layer order.
    pub fn swap_events(&self) -> Vec<&SwapEvent> {
        self.layers
            .values()
            .flat_map(|lt| lt.swap_events.iter())
            .collect()
    }

    /// All drift events across layers, in layer order.
    pub fn drift_events(&self) -> Vec<&DriftEvent> {
        self.layers
            .values()
            .flat_map(|lt| lt.drift_events.iter())
            .collect()
    }

    /// Persist the full telemetry state (crash-safely, versioned, CRC'd)
    /// so a checkpoint-resumed run continues its report byte-identically.
    pub fn save_state(&self, path: &Path) -> Result<usize> {
        persist::save_artifact(path, OSSH_STATE_KIND, |w| {
            let mut c = SectionWriter::new();
            c.put_u64(self.cfg.check_every);
            c.put_f64(self.cfg.drift_budget);
            c.put_u32(self.cfg.patience);
            c.put_bool(self.cfg.redetect);
            c.put_usize(self.cfg.realtime_cap_div);
            c.put_usize(self.cfg.realtime_cap_min);
            c.put_f32(self.detector.tau);
            c.put_u64(self.checks);
            w.section("ossh.cfg", c);
            let mut s = SectionWriter::new();
            s.put_u32(self.layers.len() as u32);
            for (name, lt) in &self.layers {
                s.put_str(name);
                s.put_usizes(&lt.reference0.channels);
                s.put_usizes(&lt.tracker.reference().channels);
                s.put_f64s(lt.tracker.series());
                s.put_f64s(&lt.jaccard);
                s.put_bool(lt.statics_ready);
                s.put_usizes(lt.similarity.channels());
                s.put_f32s(lt.similarity.static_factors());
                s.put_f32s(lt.similarity.series());
                s.put_u32(lt.below);
                s.put_u32(lt.drift_events.len() as u32);
                for ev in &lt.drift_events {
                    s.put_u64(ev.step);
                    s.put_f64(ev.hit_rate);
                    s.put_u32(ev.consecutive);
                }
                s.put_u32(lt.swap_events.len() as u32);
                for ev in &lt.swap_events {
                    s.put_u64(ev.step);
                    s.put_f64(ev.hit_rate);
                    s.put_usizes(&ev.old_channels);
                    s.put_usizes(&ev.new_channels);
                    s.put_bool(ev.method_swapped);
                }
            }
            w.section("ossh.layers", s);
        })
    }

    /// Restore a harness saved by [`OsshHarness::save_state`]. The caller's
    /// config and detector must match what was saved — a silent mismatch
    /// would fork the telemetry trajectory, so it is a hard error.
    pub fn load_state(path: &Path, cfg: &OsshConfig, detector_tau: f32) -> Result<OsshHarness> {
        let ar = persist::load_artifact(path, OSSH_STATE_KIND)?;
        let mut c = ar.section("ossh.cfg")?;
        let saved = OsshConfig {
            check_every: c.get_u64()?,
            drift_budget: c.get_f64()?,
            patience: c.get_u32()?,
            redetect: c.get_bool()?,
            realtime_cap_div: c.get_usize()?,
            realtime_cap_min: c.get_usize()?,
        };
        let saved_tau = c.get_f32()?;
        let checks = c.get_u64()?;
        if &saved != cfg || saved_tau.to_bits() != detector_tau.to_bits() {
            bail!("OSSH telemetry state was recorded under a different config");
        }
        let mut s = ar.section("ossh.layers")?;
        let n = s.get_u32()? as usize;
        let mut layers = BTreeMap::new();
        for _ in 0..n {
            let name = s.get_str()?;
            let reference0 = OutlierSet::new(s.get_usizes()?);
            let current = OutlierSet::new(s.get_usizes()?);
            let hits = s.get_f64s()?;
            let jaccard = s.get_f64s()?;
            let statics_ready = s.get_bool()?;
            let sim_channels = s.get_usizes()?;
            let sim_statics = s.get_f32s()?;
            let sim_series = s.get_f32s()?;
            let below = s.get_u32()?;
            let n_drift = s.get_u32()? as usize;
            let mut drift_events = Vec::with_capacity(n_drift);
            for _ in 0..n_drift {
                drift_events.push(DriftEvent {
                    step: s.get_u64()?,
                    layer: name.clone(),
                    hit_rate: s.get_f64()?,
                    consecutive: s.get_u32()?,
                });
            }
            let n_swap = s.get_u32()? as usize;
            let mut swap_events = Vec::with_capacity(n_swap);
            for _ in 0..n_swap {
                swap_events.push(SwapEvent {
                    step: s.get_u64()?,
                    layer: name.clone(),
                    hit_rate: s.get_f64()?,
                    old_channels: s.get_usizes()?,
                    new_channels: s.get_usizes()?,
                    method_swapped: s.get_bool()?,
                });
            }
            layers.insert(
                name.clone(),
                LayerTelemetry {
                    tracker: HitRateTracker::from_parts(&name, current, hits),
                    reference0,
                    jaccard,
                    similarity: SimilarityTracker::from_parts(
                        &name,
                        sim_channels,
                        sim_statics,
                        sim_series,
                    ),
                    statics_ready,
                    below,
                    drift_events,
                    swap_events,
                },
            );
        }
        Ok(OsshHarness {
            cfg: cfg.clone(),
            detector: OutlierDetector::new(detector_tau),
            layers,
            checks,
        })
    }

    /// Assemble the versioned report artifact from the accumulated curves.
    pub fn report(&self, method: MethodKind, preset: &str, steps: u64) -> OsshReport {
        let mut layers = Vec::new();
        let mut min_hit = f64::INFINITY;
        let mut mean_sum = 0.0f64;
        let mut mean_n = 0usize;
        let mut n_drift = 0usize;
        let mut n_swap = 0usize;
        let mut per_kind: BTreeMap<&'static str, (f64, usize)> = BTreeMap::new();
        for (name, lt) in &self.layers {
            let (mean_hit, std_hit) = lt.tracker.summary();
            if lt.tracker.iterations() > 0 {
                mean_sum += mean_hit;
                mean_n += 1;
                for &r in lt.tracker.series() {
                    min_hit = min_hit.min(r);
                }
                let e = per_kind.entry(LayerKind::from_name(name).label()).or_insert((0.0, 0));
                e.0 += mean_hit;
                e.1 += 1;
            }
            n_drift += lt.drift_events.len();
            n_swap += lt.swap_events.len();
            layers.push(LayerReport {
                layer: name.clone(),
                kind: LayerKind::from_name(name).label().to_string(),
                reference0: lt.reference0.channels.clone(),
                reference: lt.tracker.reference().channels.clone(),
                hit_series: lt.tracker.series().to_vec(),
                jaccard_series: lt.jaccard.clone(),
                similarity_series: lt.similarity.series().to_vec(),
                mean_hit,
                std_hit,
                drift_events: lt.drift_events.clone(),
                swap_events: lt.swap_events.clone(),
            });
        }
        let summary = OsshSummary {
            mean_hit: if mean_n == 0 { 1.0 } else { mean_sum / mean_n as f64 },
            min_hit: if min_hit.is_finite() { min_hit } else { 1.0 },
            drift_events: n_drift,
            swaps: n_swap,
            per_kind: per_kind
                .into_iter()
                .map(|(k, (sum, n))| (k.to_string(), sum / n as f64))
                .collect(),
        };
        OsshReport {
            version: OSSH_REPORT_VERSION,
            method: method.label().to_string(),
            preset: preset.to_string(),
            steps,
            checks: self.checks,
            drift_budget: self.cfg.drift_budget,
            patience: self.cfg.patience,
            layers,
            summary,
        }
    }
}

// ------------------------------------------------------------- report

/// Encode an `f64` for JSON, representing non-finite values as the string
/// markers `"NaN"` / `"Infinity"` / `"-Infinity"` (plain JSON has no
/// non-finite numbers; emitting them raw would produce unparseable text).
pub fn json_f64(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else if x.is_nan() {
        Json::str("NaN")
    } else if x > 0.0 {
        Json::str("Infinity")
    } else {
        Json::str("-Infinity")
    }
}

/// Inverse of [`json_f64`].
pub fn f64_from_json(j: &Json) -> Result<f64> {
    if let Some(x) = j.as_f64() {
        return Ok(x);
    }
    match j.as_str() {
        Some("NaN") => Ok(f64::NAN),
        Some("Infinity") => Ok(f64::INFINITY),
        Some("-Infinity") => Ok(f64::NEG_INFINITY),
        _ => bail!("expected a number or a non-finite marker, got {}", j.to_string()),
    }
}

/// Per-layer slice of the report artifact.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub layer: String,
    pub kind: String,
    pub reference0: Vec<usize>,
    pub reference: Vec<usize>,
    pub hit_series: Vec<f64>,
    pub jaccard_series: Vec<f64>,
    pub similarity_series: Vec<f32>,
    pub mean_hit: f64,
    pub std_hit: f64,
    pub drift_events: Vec<DriftEvent>,
    pub swap_events: Vec<SwapEvent>,
}

/// Cross-layer roll-up.
#[derive(Clone, Debug)]
pub struct OsshSummary {
    pub mean_hit: f64,
    pub min_hit: f64,
    pub drift_events: usize,
    pub swaps: usize,
    /// Mean hit rate per layer kind, sorted by kind label.
    pub per_kind: Vec<(String, f64)>,
}

/// The versioned `OSSH_report.json` artifact: everything the stability
/// analysis needs, rendered deterministically (object keys are sorted, so
/// equal telemetry ⇒ byte-equal JSON — the property the thread-width and
/// resume tests pin).
#[derive(Clone, Debug)]
pub struct OsshReport {
    pub version: u32,
    pub method: String,
    pub preset: String,
    pub steps: u64,
    pub checks: u64,
    pub drift_budget: f64,
    pub patience: u32,
    pub layers: Vec<LayerReport>,
    pub summary: OsshSummary,
}

fn usizes_json(xs: &[usize]) -> Json {
    Json::arr(xs.iter().map(|&x| Json::num(x as u32)))
}

fn usizes_from_json(j: &Json, what: &str) -> Result<Vec<usize>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("{what} must be an array"))?;
    arr.iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("{what} holds a non-index value")))
        .collect()
}

fn f64s_json(xs: &[f64]) -> Json {
    Json::arr(xs.iter().map(|&x| json_f64(x)))
}

fn f64s_from_json(j: &Json, what: &str) -> Result<Vec<f64>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("{what} must be an array"))?;
    arr.iter().map(f64_from_json).collect()
}

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("OSSH report is missing '{key}'"))
}

fn field_f64(j: &Json, key: &str) -> Result<f64> {
    f64_from_json(field(j, key)?)
}

fn field_usize(j: &Json, key: &str) -> Result<usize> {
    field(j, key)?
        .as_usize()
        .ok_or_else(|| anyhow!("'{key}' must be a non-negative integer"))
}

impl OsshReport {
    /// Deterministic JSON rendering (see the type docs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(self.version)),
            ("method", Json::str(self.method.clone())),
            ("preset", Json::str(self.preset.clone())),
            ("steps", Json::num(self.steps as u32)),
            ("checks", Json::num(self.checks as u32)),
            ("drift_budget", json_f64(self.drift_budget)),
            ("patience", Json::num(self.patience)),
            (
                "layers",
                Json::arr(self.layers.iter().map(|l| {
                    Json::obj(vec![
                        ("layer", Json::str(l.layer.clone())),
                        ("kind", Json::str(l.kind.clone())),
                        ("reference0", usizes_json(&l.reference0)),
                        ("reference", usizes_json(&l.reference)),
                        ("hit_series", f64s_json(&l.hit_series)),
                        ("jaccard_series", f64s_json(&l.jaccard_series)),
                        (
                            "similarity_series",
                            Json::arr(l.similarity_series.iter().map(|&x| json_f64(x as f64))),
                        ),
                        ("mean_hit", json_f64(l.mean_hit)),
                        ("std_hit", json_f64(l.std_hit)),
                        (
                            "drift_events",
                            Json::arr(l.drift_events.iter().map(|e| {
                                Json::obj(vec![
                                    ("step", Json::num(e.step as u32)),
                                    ("hit_rate", json_f64(e.hit_rate)),
                                    ("consecutive", Json::num(e.consecutive)),
                                ])
                            })),
                        ),
                        (
                            "swap_events",
                            Json::arr(l.swap_events.iter().map(|e| {
                                Json::obj(vec![
                                    ("step", Json::num(e.step as u32)),
                                    ("hit_rate", json_f64(e.hit_rate)),
                                    ("old_channels", usizes_json(&e.old_channels)),
                                    ("new_channels", usizes_json(&e.new_channels)),
                                    ("method_swapped", Json::Bool(e.method_swapped)),
                                ])
                            })),
                        ),
                    ])
                })),
            ),
            (
                "summary",
                Json::obj(vec![
                    ("mean_hit", json_f64(self.summary.mean_hit)),
                    ("min_hit", json_f64(self.summary.min_hit)),
                    ("drift_events", Json::num(self.summary.drift_events as u32)),
                    ("swaps", Json::num(self.summary.swaps as u32)),
                    (
                        "per_kind",
                        Json::obj(
                            self.summary
                                .per_kind
                                .iter()
                                .map(|(k, v)| (k.as_str(), json_f64(*v)))
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ])
    }

    /// Parse a report previously rendered by [`OsshReport::to_json`].
    /// Version mismatches and malformed documents produce readable errors.
    pub fn from_json(text: &str) -> Result<OsshReport> {
        let j = Json::parse(text).map_err(|e| anyhow!("OSSH report is not valid JSON: {e}"))?;
        let version = field_usize(&j, "version")? as u32;
        if version != OSSH_REPORT_VERSION {
            bail!(
                "unsupported OSSH report version {version} (this build reads {OSSH_REPORT_VERSION})"
            );
        }
        let mut layers = Vec::new();
        for l in field(&j, "layers")?
            .as_arr()
            .ok_or_else(|| anyhow!("'layers' must be an array"))?
        {
            let layer = field(l, "layer")?
                .as_str()
                .ok_or_else(|| anyhow!("'layer' must be a string"))?
                .to_string();
            let mut drift_events = Vec::new();
            for e in field(l, "drift_events")?
                .as_arr()
                .ok_or_else(|| anyhow!("'drift_events' must be an array"))?
            {
                drift_events.push(DriftEvent {
                    step: field_usize(e, "step")? as u64,
                    layer: layer.clone(),
                    hit_rate: field_f64(e, "hit_rate")?,
                    consecutive: field_usize(e, "consecutive")? as u32,
                });
            }
            let mut swap_events = Vec::new();
            for e in field(l, "swap_events")?
                .as_arr()
                .ok_or_else(|| anyhow!("'swap_events' must be an array"))?
            {
                swap_events.push(SwapEvent {
                    step: field_usize(e, "step")? as u64,
                    layer: layer.clone(),
                    hit_rate: field_f64(e, "hit_rate")?,
                    old_channels: usizes_from_json(field(e, "old_channels")?, "old_channels")?,
                    new_channels: usizes_from_json(field(e, "new_channels")?, "new_channels")?,
                    method_swapped: matches!(field(e, "method_swapped")?, Json::Bool(true)),
                });
            }
            layers.push(LayerReport {
                kind: field(l, "kind")?
                    .as_str()
                    .ok_or_else(|| anyhow!("'kind' must be a string"))?
                    .to_string(),
                reference0: usizes_from_json(field(l, "reference0")?, "reference0")?,
                reference: usizes_from_json(field(l, "reference")?, "reference")?,
                hit_series: f64s_from_json(field(l, "hit_series")?, "hit_series")?,
                jaccard_series: f64s_from_json(field(l, "jaccard_series")?, "jaccard_series")?,
                similarity_series: f64s_from_json(
                    field(l, "similarity_series")?,
                    "similarity_series",
                )?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
                mean_hit: field_f64(l, "mean_hit")?,
                std_hit: field_f64(l, "std_hit")?,
                drift_events,
                swap_events,
                layer,
            });
        }
        let s = field(&j, "summary")?;
        let per_kind = match field(s, "per_kind")? {
            Json::Obj(map) => {
                let mut v = Vec::new();
                for (k, val) in map {
                    v.push((k.clone(), f64_from_json(val)?));
                }
                v
            }
            _ => bail!("'per_kind' must be an object"),
        };
        Ok(OsshReport {
            version,
            method: field(&j, "method")?
                .as_str()
                .ok_or_else(|| anyhow!("'method' must be a string"))?
                .to_string(),
            preset: field(&j, "preset")?
                .as_str()
                .ok_or_else(|| anyhow!("'preset' must be a string"))?
                .to_string(),
            steps: field_usize(&j, "steps")? as u64,
            checks: field_usize(&j, "checks")? as u64,
            drift_budget: field_f64(&j, "drift_budget")?,
            patience: field_usize(&j, "patience")? as u32,
            layers,
            summary: OsshSummary {
                mean_hit: field_f64(s, "mean_hit")?,
                min_hit: field_f64(s, "min_hit")?,
                drift_events: field_usize(s, "drift_events")?,
                swaps: field_usize(s, "swaps")?,
                per_kind,
            },
        })
    }

    /// Render to the on-disk artifact bytes (trailing newline included).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = self.to_json().to_string().into_bytes();
        out.push(b'\n');
        out
    }
}

/// Write the report artifact atomically (temp file + fsync + rename, the
/// checkpoint machinery's write path).
pub fn write_report(path: &Path, report: &OsshReport) -> Result<usize> {
    let bytes = report.to_bytes();
    persist::write_atomic_rotating(path, &bytes)?;
    Ok(bytes.len())
}

/// Read a report artifact written by [`write_report`].
pub fn read_report(path: &Path) -> Result<OsshReport> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
    OsshReport::from_json(&text)
}

// ---------------------------------------------------------------- runs

/// Everything that determines an OSSH validation run's trajectory. Two
/// specs that agree on all fields produce bit-identical runs (any thread
/// width, interrupted or not).
#[derive(Clone, Debug)]
pub struct OsshRunSpec {
    pub server: ServerConfig,
    pub ft_task: String,
    pub method: MethodKind,
    pub peft: PeftKind,
    pub steps: u64,
    pub batch: usize,
    pub max_len: usize,
    pub seed: u64,
    pub lr: f32,
    /// Arm the telemetry taps. Off ⇒ the harness never observes anything
    /// (the baseline the non-perturbation test compares against).
    pub telemetry: bool,
    pub cfg: OsshConfig,
    pub checkpoint: Option<CheckpointSpec>,
}

impl OsshRunSpec {
    /// A fast test-scale spec (opt-tiny, 4 steps).
    pub fn tiny(method: MethodKind) -> OsshRunSpec {
        let mut server = ServerConfig::default();
        server.preset = "opt-tiny".to_string();
        server.calib_samples = 8;
        server.calib_batch = 4;
        OsshRunSpec {
            server,
            ft_task: "oig-chip2".to_string(),
            method,
            peft: PeftKind::Lora,
            steps: 4,
            batch: 2,
            max_len: 64,
            seed: 0x0551,
            lr: 2e-3,
            telemetry: true,
            cfg: OsshConfig::default(),
            checkpoint: None,
        }
    }

    /// The job spec persisted into checkpoints; `validate_resume` compares
    /// it against the resuming spec's, so a drifted spec cannot silently
    /// fork a resumed trajectory.
    fn job(&self) -> FinetuneJob {
        let mut j = FinetuneJob::new(0, &self.ft_task, self.method, self.peft);
        j.steps = self.steps;
        j.batch_size = self.batch;
        j.lr = self.lr;
        j.seed = self.seed;
        j.max_len = self.max_len;
        j.checkpoint = self.checkpoint.clone();
        j
    }
}

/// Sibling path holding the harness state next to a training checkpoint.
pub fn ossh_state_path(checkpoint: &Path) -> PathBuf {
    let mut os = checkpoint.as_os_str().to_os_string();
    os.push(".ossh");
    PathBuf::from(os)
}

/// One OSSH validation run: a seeded training job with the telemetry
/// harness wired around every optimizer step, periodic crash-safe
/// checkpoints (model + trainer + telemetry state), and the report
/// artifact at the end. The per-step data batch is derived statelessly
/// from `(seed, step)`, so a resumed run replays the exact stream an
/// uninterrupted run sees.
pub struct OsshRun {
    pub spec: OsshRunSpec,
    model: Model,
    trainer: Trainer,
    harness: OsshHarness,
    task: SynthTask,
    losses: Vec<f64>,
    payload_bytes: usize,
}

impl OsshRun {
    /// Prepare a fresh run: calibrate + quantize through the preprocess
    /// server, then seed the harness from the bundle's outlier registry.
    pub fn new(spec: OsshRunSpec) -> Result<OsshRun> {
        let task = SynthTask::by_name(&spec.ft_task)
            .ok_or_else(|| anyhow!("unknown task '{}'", spec.ft_task))?;
        let server = PreprocessServer::new(spec.server.clone());
        let bundle = server.prepare(spec.method, spec.peft);
        let harness = OsshHarness::new(spec.cfg.clone(), spec.server.detector_tau, &bundle.registry);
        let trainer = Trainer::new(spec.lr, spec.max_len, 1);
        Ok(OsshRun {
            model: bundle.model,
            payload_bytes: bundle.payload_bytes,
            trainer,
            harness,
            task,
            losses: Vec::new(),
            spec,
        })
    }

    /// Resume a run from its checkpoint (plus the telemetry-state sibling
    /// when telemetry is on). The stored job spec must match `spec`'s.
    pub fn resume(spec: OsshRunSpec) -> Result<OsshRun> {
        let ck = spec
            .checkpoint
            .clone()
            .ok_or_else(|| anyhow!("resume requires a checkpoint spec"))?;
        let loaded = persist::load_train_checkpoint(&ck.path)?;
        validate_resume(&loaded.ckpt.job, &spec.job())?;
        let task = SynthTask::by_name(&spec.ft_task)
            .ok_or_else(|| anyhow!("unknown task '{}'", spec.ft_task))?;
        let harness = if spec.telemetry {
            OsshHarness::load_state(
                &ossh_state_path(&ck.path),
                &spec.cfg,
                spec.server.detector_tau,
            )?
        } else {
            OsshHarness::new(spec.cfg.clone(), spec.server.detector_tau, &OutlierRegistry::new())
        };
        Ok(OsshRun {
            model: loaded.ckpt.model,
            trainer: loaded.ckpt.trainer,
            harness,
            task,
            losses: loaded.ckpt.losses,
            payload_bytes: loaded.ckpt.payload_bytes,
            spec,
        })
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> u64 {
        self.trainer.step_count
    }

    pub fn is_done(&self) -> bool {
        self.steps_done() >= self.spec.steps
    }

    /// Per-step losses (spans resumes).
    pub fn losses(&self) -> &[f64] {
        &self.losses
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Mutable model access (parameter inspection in the stability tests;
    /// `Model::visit_params` needs `&mut`).
    pub fn model_mut(&mut self) -> &mut Model {
        &mut self.model
    }

    pub fn harness(&self) -> &OsshHarness {
        &self.harness
    }

    /// Deterministically relocate every injected outlier channel by
    /// `shift` — the synthetic adversarial drift of the stability tier.
    /// Consumes no randomness, so the run stays reproducible.
    pub fn inject_relocation(&mut self, shift: usize) {
        for b in &mut self.model.blocks {
            b.inj_attn.relocate(shift);
            b.inj_o.relocate(shift);
            b.inj_mlp.relocate(shift);
            b.inj_down.relocate(shift);
        }
    }

    /// Run one optimizer step with the telemetry check around it, saving a
    /// checkpoint afterwards when the spec's cadence says so.
    pub fn step(&mut self) -> Result<()> {
        let step = self.trainer.step_count;
        let check = self.spec.telemetry && self.harness.is_check_step(step);
        if check {
            self.harness.begin_step(&mut self.model);
        }
        // Stateless per-step data stream: resume ≡ uninterrupted.
        let mut rng = Rng::new(self.spec.seed ^ (step + 1).wrapping_mul(0x9E3779B97F4A7C15));
        let samples = batchify(&self.task, self.spec.batch, &mut rng);
        let refs: Vec<&Sample> = samples.iter().collect();
        let stats = self.trainer.step(&mut self.model, &[refs]);
        self.losses.push(stats.loss);
        if check {
            self.harness.end_step(&mut self.model, step);
        }
        if let Some(ck) = self.spec.checkpoint.clone() {
            if ck.every > 0 && (step + 1) % ck.every == 0 {
                self.checkpoint(&ck)?;
            }
        }
        Ok(())
    }

    fn checkpoint(&mut self, ck: &CheckpointSpec) -> Result<()> {
        persist::save_train_checkpoint(
            &ck.path,
            &self.spec.job(),
            &mut self.model,
            &self.trainer,
            self.losses.len(),
            &self.losses,
            self.payload_bytes,
        )?;
        if self.spec.telemetry {
            self.harness.save_state(&ossh_state_path(&ck.path))?;
        }
        Ok(())
    }

    /// Drive the run to completion.
    pub fn run(&mut self) -> Result<()> {
        while !self.is_done() {
            self.step()?;
        }
        Ok(())
    }

    /// The report artifact for the run so far.
    pub fn report(&self) -> OsshReport {
        self.harness
            .report(self.spec.method, &self.spec.server.preset, self.steps_done())
    }
}
