//! INT8 matrix with i32-accumulating integer matmul — the CPU analogue of
//! the INT8 tensor-core (paper, CUDA) / MXU-int8 (our Pallas port) path.
//!
//! The packed fused-dequant matmul runs on the register-tiled,
//! ISA-dispatched microkernels in [`simd`](super::simd): weights are
//! repacked once into [`simd::NR`]-column panels and each
//! [`simd::MR`]-row activation block streams every panel through AVX2 /
//! NEON / scalar kernels selected at runtime (`QUAFF_ISA` overrides).
//! Integer accumulation is exact, and the f32 dequant epilogue is applied
//! per element in the legacy order, so every ISA and tile remainder is
//! bit-identical to the scalar reference (`tests/simd_parity.rs`).
//!
//! The matmuls are row-sharded across [`pool`](super::pool): each shard owns
//! a fixed range of activation rows and its own staging-scratch **lane**,
//! so shards never share mutable state and the result is bit-identical to
//! the serial loop (integer accumulation is exact anyway). The `_lanes_into`
//! variants take one scratch buffer per potential shard, typically drawn
//! from the workspace's lane pools.

use super::pool::{self, shard_range, SplitMut};
use super::simd;
use crate::util::prng::Rng;

/// Dense row-major i8 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct I8Matrix {
    rows: usize,
    cols: usize,
    data: Vec<i8>,
}

impl I8Matrix {
    pub fn zeros(rows: usize, cols: usize) -> I8Matrix {
        I8Matrix {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<i8>) -> I8Matrix {
        assert_eq!(data.len(), rows * cols, "from_vec shape mismatch");
        I8Matrix { rows, cols, data }
    }

    /// Uniform random int8 values in the symmetric range `[-127, 127]`
    /// (tests/benches). `below(255)` draws from `[0, 254]`, so the shift
    /// never leaves the i8 range — the old `u64 as i64 % 255` form could go
    /// negative before the modulo and wrap through `as i8`.
    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> I8Matrix {
        let data = (0..rows * cols)
            .map(|_| (rng.below(255) as i64 - 127) as i8)
            .collect();
        I8Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> i8 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[i8] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [i8] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn data(&self) -> &[i8] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [i8] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<i8> {
        self.data
    }

    /// Bytes of storage (exactly rows*cols — the memory win vs f32).
    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    /// Integer matmul `self(i8) @ other(i8) -> i32` with an i16-widening
    /// inner loop. i-k-j order so the j loop auto-vectorizes. Row-sharded
    /// for large launches (exact integer math — identical for any split).
    pub fn matmul_i32(&self, other: &I8Matrix) -> Vec<i32> {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = vec![0i32; m * n];
        let shards = pool::shards_for(m, m * k * n);
        if shards <= 1 {
            i8_matmul_rows(&self.data, &other.data, &mut out, 0, m, k, n);
            return out;
        }
        let split = SplitMut::new(&mut out);
        pool::run_shards(shards, &|s| {
            let (r0, r1) = shard_range(m, shards, s);
            let orows = unsafe { split.slice(r0 * n, (r1 - r0) * n) };
            i8_matmul_rows(&self.data, &other.data, orows, r0, r1, k, n);
        });
        out
    }

    /// Pack a weight matrix into the panel-blocked, i16-widened form the
    /// microkernels consume (§Perf: the i8→i32 sign-extension in the naive
    /// inner loop quarters the effective SIMD width; widening to i16 once
    /// enables 16-bit multiply-add pairs). Columns are grouped into panels
    /// of [`simd::NR`], elements k-pair-interleaved within each panel, and
    /// k zero-padded to even — see `tensor::simd` for the layout diagram.
    /// Built once at quantization time, reused across every token, and
    /// identical for every ISA (dispatch never repacks).
    pub fn pack_transposed(&self) -> PackedWeights {
        let (k, n) = (self.rows, self.cols);
        let kpad = k + (k & 1);
        let npanels = n.div_ceil(simd::NR);
        let mut data = vec![0i16; npanels * kpad * simd::NR];
        for kk in 0..k {
            let row = &self.data[kk * n..(kk + 1) * n];
            let (kp, r) = (kk / 2, kk & 1);
            for (j, &v) in row.iter().enumerate() {
                let (p, jj) = (j / simd::NR, j % simd::NR);
                data[p * kpad * simd::NR + kp * 2 * simd::NR + jj * 2 + r] = v as i16;
            }
        }
        PackedWeights {
            k,
            n,
            kpad,
            npanels,
            data,
        }
    }

    /// Fused dequantizing matmul against pre-packed panel weights,
    /// `out[i,j] += Δ_row[i] · dot(self[i,:], packedᵀ[:,j]) · Δ_col[j]`,
    /// with the i16 activation-staging scratch provided by the caller
    /// (resized as needed) — strictly serial. Row-sharded callers use
    /// [`Self::matmul_dequant_packed_lanes_into`]; the fused plan pipeline
    /// (`quant::pipeline`) uses the `_write` variants.
    pub fn matmul_dequant_packed_scratch_into(
        &self,
        packed: &PackedWeights,
        row_scale: &[f32],
        col_scale: &[f32],
        a16: &mut Vec<i16>,
        out: &mut [f32],
    ) {
        self.packed_checks(packed, row_scale, col_scale, out);
        packed_matmul_rows(
            &self.data, packed, row_scale, col_scale, a16, out, 0, self.rows, self.cols,
        );
    }

    /// Row-sharded [`Self::matmul_dequant_packed_scratch_into`] with one
    /// staging lane per potential shard (at most `lanes.len()` shards run;
    /// pass the workspace's per-thread lanes). Bit-identical to the serial
    /// path.
    pub fn matmul_dequant_packed_lanes_into(
        &self,
        packed: &PackedWeights,
        row_scale: &[f32],
        col_scale: &[f32],
        lanes: &mut [Vec<i16>],
        out: &mut [f32],
    ) {
        self.packed_checks(packed, row_scale, col_scale, out);
        assert!(!lanes.is_empty(), "need at least one scratch lane");
        let (m, k, n) = (self.rows, self.cols, packed.n);
        let shards = pool::shards_for(m, m * k * n).min(lanes.len());
        if shards <= 1 {
            return packed_matmul_rows(
                &self.data, packed, row_scale, col_scale, &mut lanes[0], out, 0, m, k,
            );
        }
        let out_split = SplitMut::new(out);
        let lane_split = SplitMut::new(lanes);
        pool::run_shards(shards, &|s| {
            let (r0, r1) = shard_range(m, shards, s);
            let orows = unsafe { out_split.slice(r0 * n, (r1 - r0) * n) };
            let a16 = unsafe { lane_split.at(s) };
            packed_matmul_rows(&self.data, packed, row_scale, col_scale, a16, orows, r0, r1, k);
        });
    }

    /// **Write-mode** [`Self::matmul_dequant_packed_scratch_into`]: fully
    /// overwrites `out` instead of accumulating, eliminating the caller's
    /// zero-fill pass. Bit-identical to zero-fill + accumulate (the fused
    /// qgemm pipeline's main-term contract — see `quant::pipeline`).
    pub fn matmul_dequant_packed_scratch_write(
        &self,
        packed: &PackedWeights,
        row_scale: &[f32],
        col_scale: &[f32],
        a16: &mut Vec<i16>,
        out: &mut [f32],
    ) {
        self.packed_checks(packed, row_scale, col_scale, out);
        packed_matmul_rows_core::<true>(
            &self.data, packed, row_scale, col_scale, a16, out, 0, self.rows, self.cols,
        );
    }

    /// **Write-mode** [`Self::matmul_dequant_packed_lanes_into`]: fully
    /// overwrites `out` (see [`Self::matmul_dequant_packed_scratch_write`]);
    /// row-sharded with one staging lane per potential shard.
    pub fn matmul_dequant_packed_lanes_write(
        &self,
        packed: &PackedWeights,
        row_scale: &[f32],
        col_scale: &[f32],
        lanes: &mut [Vec<i16>],
        out: &mut [f32],
    ) {
        self.packed_checks(packed, row_scale, col_scale, out);
        assert!(!lanes.is_empty(), "need at least one scratch lane");
        let (m, k, n) = (self.rows, self.cols, packed.n);
        let shards = pool::shards_for(m, m * k * n).min(lanes.len());
        if shards <= 1 {
            return packed_matmul_rows_core::<true>(
                &self.data, packed, row_scale, col_scale, &mut lanes[0], out, 0, m, k,
            );
        }
        let out_split = SplitMut::new(out);
        let lane_split = SplitMut::new(lanes);
        pool::run_shards(shards, &|s| {
            let (r0, r1) = shard_range(m, shards, s);
            let orows = unsafe { out_split.slice(r0 * n, (r1 - r0) * n) };
            let a16 = unsafe { lane_split.at(s) };
            packed_matmul_rows_core::<true>(
                &self.data, packed, row_scale, col_scale, a16, orows, r0, r1, k,
            );
        });
    }

    fn packed_checks(
        &self,
        packed: &PackedWeights,
        row_scale: &[f32],
        col_scale: &[f32],
        out: &[f32],
    ) {
        assert_eq!(packed.k, self.cols, "matmul dim mismatch");
        assert_eq!(row_scale.len(), self.rows);
        assert_eq!(col_scale.len(), packed.n);
        assert_eq!(out.len(), self.rows * packed.n);
    }

    /// Fused dequantizing matmul: `Δ_row[i] * (self @ other)[i,j] * Δ_col[j]`.
    ///
    /// This is Eq. 2 / Eq. 9's main term: per-token activation step sizes on
    /// the left, per-output-channel weight step sizes on the right, i32
    /// accumulation in the middle. Accumulates into `out` (so the outlier
    /// correction term can be fused on top).
    pub fn matmul_dequant_into(
        &self,
        other: &I8Matrix,
        row_scale: &[f32],
        col_scale: &[f32],
        out: &mut [f32],
    ) {
        let mut acc = Vec::new();
        self.matmul_dequant_scratch_into(other, row_scale, col_scale, &mut acc, out);
    }

    /// [`Self::matmul_dequant_into`] with the i32 accumulator row provided
    /// by the caller (resized as needed) — strictly serial, allocation-free
    /// on reuse. (The unpacked matmul only runs over the tiny outlier slice
    /// on the hot path, so it does not earn a sharded variant.)
    pub fn matmul_dequant_scratch_into(
        &self,
        other: &I8Matrix,
        row_scale: &[f32],
        col_scale: &[f32],
        acc: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        assert_eq!(row_scale.len(), m);
        assert_eq!(col_scale.len(), n);
        assert_eq!(out.len(), m * n);
        acc.resize(n, 0);
        for i in 0..m {
            acc.fill(0);
            let arow = &self.data[i * k..(i + 1) * k];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0 {
                    continue;
                }
                let a = a as i32;
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in acc.iter_mut().zip(brow) {
                    *o += a * b as i32;
                }
            }
            let rs = row_scale[i];
            let orow = &mut out[i * n..(i + 1) * n];
            for ((o, &a), &cs) in orow.iter_mut().zip(acc.iter()).zip(col_scale) {
                *o += rs * a as f32 * cs;
            }
        }
    }
}

/// Row-range core of [`I8Matrix::matmul_i32`]: output rows `r0..r1` into
/// `orows` (relative sub-slice). Register-tiled over [`simd::MR`]-row
/// blocks so each streamed B row is reused across the tile; the k-major
/// accumulation order per output element is unchanged (exact integer math —
/// any tiling is identical anyway).
fn i8_matmul_rows(
    ad: &[i8],
    bd: &[i8],
    orows: &mut [i32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
) {
    orows.fill(0);
    let mut i = r0;
    while i < r1 {
        let mr = (r1 - i).min(simd::MR);
        for kk in 0..k {
            let brow = &bd[kk * n..(kk + 1) * n];
            for r in 0..mr {
                let a = ad[(i + r) * k + kk];
                if a == 0 {
                    continue;
                }
                let a = a as i32;
                let orow = &mut orows[(i + r - r0) * n..(i + r - r0 + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b as i32;
                }
            }
        }
        i += mr;
    }
}

/// Row-range core of the packed fused dequantizing matmul: rows `r0..r1`
/// of the activation into the relative sub-slice `orows`.
///
/// Rows are staged into `a16` as an i16-widened [`simd::MR`]-row block
/// (k zero-padded to the pack's even `kpad`), then each weight panel is
/// streamed once per block through the ISA-dispatched microkernel
/// ([`simd::panel_dot_tile`]) — [`simd::active`] selects AVX2 / NEON /
/// scalar at runtime. The integer accumulators are exact and identical for
/// every ISA and tile remainder, and the f32 epilogue below is the same
/// per-element scalar expression as the legacy loop, so the output is
/// bit-identical across ISAs, tilings, and thread counts.
///
/// `WRITE = false` accumulates (`+=`, the legacy contract); `WRITE = true`
/// overwrites with `0.0 + term` — the explicit `0.0 +` keeps the write mode
/// bit-identical to accumulating into a zero-filled buffer (a plain `=`
/// could differ in the sign of a zero result, and LLVM cannot fold
/// `+0.0 + x` away).
#[allow(clippy::too_many_arguments)]
fn packed_matmul_rows_core<const WRITE: bool>(
    xd: &[i8],
    packed: &PackedWeights,
    row_scale: &[f32],
    col_scale: &[f32],
    a16: &mut Vec<i16>,
    orows: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
) {
    let n = packed.n;
    let kpad = packed.kpad;
    let isa = simd::active();
    a16.resize(simd::MR * kpad, 0);
    let mut i = r0;
    while i < r1 {
        let mr = (r1 - i).min(simd::MR);
        for r in 0..mr {
            let arow = &xd[(i + r) * k..(i + r + 1) * k];
            let dst = &mut a16[r * kpad..(r + 1) * kpad];
            for (d, &v) in dst.iter_mut().zip(arow) {
                *d = v as i16;
            }
            for d in dst[k..].iter_mut() {
                *d = 0;
            }
        }
        let stage = &a16[..];
        let mut acc = [[0i32; simd::NR]; simd::MR];
        for p in 0..packed.npanels {
            let panel = &packed.data[p * kpad * simd::NR..(p + 1) * kpad * simd::NR];
            simd::panel_dot_tile(isa, stage, kpad, mr, panel, &mut acc);
            let j0 = p * simd::NR;
            let jend = (j0 + simd::NR).min(n);
            for r in 0..mr {
                let rs = row_scale[i + r];
                let orow = &mut orows[(i + r - r0) * n..(i + r - r0 + 1) * n];
                let acc_row = &acc[r];
                for (jj, j) in (j0..jend).enumerate() {
                    let term = rs * acc_row[jj] as f32 * col_scale[j];
                    if WRITE {
                        orow[j] = 0.0 + term;
                    } else {
                        orow[j] += term;
                    }
                }
            }
        }
        i += mr;
    }
}

/// Accumulating (`+=`) row-range core — see [`packed_matmul_rows_core`].
#[allow(clippy::too_many_arguments)]
fn packed_matmul_rows(
    xd: &[i8],
    packed: &PackedWeights,
    row_scale: &[f32],
    col_scale: &[f32],
    a16: &mut Vec<i16>,
    orows: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
) {
    packed_matmul_rows_core::<false>(xd, packed, row_scale, col_scale, a16, orows, r0, r1, k);
}

/// Weights in transposed, i16-widened, **panel-blocked** form — built once
/// at quantization time by [`I8Matrix::pack_transposed`], consumed by the
/// ISA-dispatched microkernels (see `tensor::simd` for the layout). Columns
/// live in panels of [`simd::NR`]; `k` is zero-padded to the even `kpad`.
/// The layout is never serialized (`quant::QuantizedWeights::from_parts`
/// re-derives it), so it can evolve without a persistence migration.
#[derive(Clone, Debug)]
pub struct PackedWeights {
    k: usize,
    n: usize,
    /// `k` rounded up to even — the pair-interleaved reduction depth.
    kpad: usize,
    /// Number of [`simd::NR`]-column panels (`ceil(n / NR)`).
    npanels: usize,
    data: Vec<i16>,
}

impl PackedWeights {
    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Storage bytes (2 per element, padding included — counted as
    /// transient packing state).
    pub fn nbytes(&self) -> usize {
        self.data.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn packed_matmul_matches_unpacked() {
        prop::check("packed==unpacked", 0xB7, 20, |r| {
            let (m, k, n) = (1 + r.below(16), 1 + r.below(64), 1 + r.below(48));
            let a = I8Matrix::random(m, k, r);
            let b = I8Matrix::random(k, n, r);
            let rs: Vec<f32> = (0..m).map(|_| r.range(0.001, 0.1)).collect();
            let cs: Vec<f32> = (0..n).map(|_| r.range(0.001, 0.1)).collect();
            (a, b, rs, cs)
        }, |(a, b, rs, cs)| {
            let mut want = vec![0.0f32; a.rows() * b.cols()];
            a.matmul_dequant_into(b, rs, cs, &mut want);
            let packed = b.pack_transposed();
            let mut a16 = Vec::new();
            let mut got = vec![0.0f32; a.rows() * b.cols()];
            a.matmul_dequant_packed_scratch_into(&packed, rs, cs, &mut a16, &mut got);
            prop::all_close(&got, &want, 1e-5, 1e-5)?;
            // and the sharded variant lands the same bits
            let mut lanes: Vec<Vec<i16>> = (0..4).map(|_| Vec::new()).collect();
            let mut got_l = vec![0.0f32; a.rows() * b.cols()];
            a.matmul_dequant_packed_lanes_into(&packed, rs, cs, &mut lanes, &mut got_l);
            if got_l != got {
                return Err("lanes variant differs from serial".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn dequant_matmul_matches_f32_of_ints() {
        let mut r = Rng::new(12);
        let a = I8Matrix::random(5, 7, &mut r);
        let b = I8Matrix::random(7, 9, &mut r);
        let row_s: Vec<f32> = (0..5).map(|_| r.range(0.001, 0.1)).collect();
        let col_s: Vec<f32> = (0..9).map(|_| r.range(0.001, 0.1)).collect();
        let mut out = vec![0.0f32; 5 * 9];
        a.matmul_dequant_into(&b, &row_s, &col_s, &mut out);
        // reference: float matmul of the dequantized ints
        let mut want = vec![0.0f32; 5 * 9];
        for i in 0..5 {
            for j in 0..9 {
                let mut acc = 0.0f64;
                for k in 0..7 {
                    acc += (a.get(i, k) as f64 * row_s[i] as f64)
                        * (b.get(k, j) as f64 * col_s[j] as f64);
                }
                want[i * 9 + j] = acc as f32;
            }
        }
        prop::all_close(&out, &want, 1e-5, 1e-4).unwrap();
    }

    #[test]
    fn dequant_matmul_accumulates() {
        let a = I8Matrix::from_vec(1, 2, vec![1, 1]);
        let b = I8Matrix::from_vec(2, 1, vec![2, 3]);
        let mut out = vec![10.0f32];
        a.matmul_dequant_into(&b, &[1.0], &[1.0], &mut out);
        assert_eq!(out[0], 15.0);
    }

    #[test]
    fn nbytes_is_one_per_element() {
        assert_eq!(I8Matrix::zeros(13, 17).nbytes(), 13 * 17);
    }

    #[test]
    fn write_mode_matches_zeroed_accumulate_bitwise() {
        prop::check("packed_write==zero+acc", 0xB8, 24, |r| {
            let (m, k, n) = (1 + r.below(16), 1 + r.below(64), 1 + r.below(48));
            let a = I8Matrix::random(m, k, r);
            let b = I8Matrix::random(k, n, r);
            let rs: Vec<f32> = (0..m).map(|_| r.range(0.001, 0.1)).collect();
            let cs: Vec<f32> = (0..n).map(|_| r.range(0.001, 0.1)).collect();
            (a, b, rs, cs)
        }, |(a, b, rs, cs)| {
            let packed = b.pack_transposed();
            let mut want = vec![0.0f32; a.rows() * b.cols()];
            let mut a16_ref = Vec::new();
            a.matmul_dequant_packed_scratch_into(&packed, rs, cs, &mut a16_ref, &mut want);
            // write mode over a dirty buffer must land the same bits
            let mut scratch = vec![0i16; 1];
            let mut got = vec![777.25f32; a.rows() * b.cols()];
            a.matmul_dequant_packed_scratch_write(&packed, rs, cs, &mut scratch, &mut got);
            if got != want {
                return Err("scratch write mode differs".to_string());
            }
            let mut lanes: Vec<Vec<i16>> = (0..4).map(|_| Vec::new()).collect();
            let mut got_l = vec![-3.5f32; a.rows() * b.cols()];
            a.matmul_dequant_packed_lanes_write(&packed, rs, cs, &mut lanes, &mut got_l);
            if got_l != want {
                return Err("lanes write mode differs".to_string());
            }
            Ok(())
        });
    }
}
