//! `_into` compute kernels: the matmul / transpose / gather loops, written
//! once, targeting caller-provided output buffers.
//!
//! These are the single source of truth for the hot loops — the allocating
//! convenience methods on [`Matrix`] delegate here, and the workspace-backed
//! execution path calls them directly with pooled buffers, so both paths
//! are bit-identical by construction (asserted by `tests/workspace_kernels`).
//!
//! All kernels **overwrite** `out` completely; none of them read its prior
//! contents, so dirty recycled buffers are safe inputs.

use super::{I8Matrix, Matrix, BLOCK_J, BLOCK_K};

/// Transpose tile edge: 32×32 f32 tiles = 4 KiB read + 4 KiB write, which
/// keeps both the row-major reads and the column-major writes inside L1.
const TRANSPOSE_TILE: usize = 32;

/// `out = a @ b` — cache-blocked i-k-j kernel (LLVM vectorizes the j loop).
pub fn matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "matmul dim mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (a.rows(), b.cols()),
        "matmul out shape mismatch"
    );
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    od.fill(0.0);
    for kb in (0..k).step_by(BLOCK_K) {
        let kend = (kb + BLOCK_K).min(k);
        for jb in (0..n).step_by(BLOCK_J) {
            let jend = (jb + BLOCK_J).min(n);
            for i in 0..m {
                let arow = &ad[i * k..(i + 1) * k];
                let orow = &mut od[i * n + jb..i * n + jend];
                for kk in kb..kend {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n + jb..kk * n + jend];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
}

/// `out = a @ b.T` — the backward-pass shape `dX = dY @ W.T`.
/// Reads both operands row-wise, so no transpose materialization.
pub fn matmul_bt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "matmul_bt dim mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (a.rows(), b.rows()),
        "matmul_bt out shape mismatch"
    );
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut od[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            orow[j] = acc;
        }
    }
}

/// `out = a.T @ b` — the gradient-accumulation shape `dW = X.T @ dY`.
pub fn matmul_at_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.rows(), b.rows(), "matmul_at dim mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (a.cols(), b.cols()),
        "matmul_at out shape mismatch"
    );
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    od.fill(0.0);
    for t in 0..k {
        let arow = &ad[t * m..(t + 1) * m];
        let brow = &bd[t * n..(t + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut od[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out = src.T` — cache-blocked transpose. The naive get/set loop strides
/// the output by `rows` every element, missing cache on every write for
/// large matrices; tiling keeps both streams resident (it sits on the
/// gradient path, so this matters every step).
pub fn transpose_into(src: &Matrix, out: &mut Matrix) {
    assert_eq!(
        (out.rows(), out.cols()),
        (src.cols(), src.rows()),
        "transpose out shape mismatch"
    );
    let (r, c) = (src.rows(), src.cols());
    let sd = src.data();
    let od = out.data_mut();
    for ib in (0..r).step_by(TRANSPOSE_TILE) {
        let iend = (ib + TRANSPOSE_TILE).min(r);
        for jb in (0..c).step_by(TRANSPOSE_TILE) {
            let jend = (jb + TRANSPOSE_TILE).min(c);
            for i in ib..iend {
                let srow = &sd[i * c..(i + 1) * c];
                for j in jb..jend {
                    od[j * r + i] = srow[j];
                }
            }
        }
    }
}

/// Per-column absolute maxima into `out` (length `src.cols()`, fully
/// overwritten) — the channel statistic the whole paper is built on,
/// shared by `Matrix::col_abs_max`, LLM.int8's detector, and the per-OC
/// quantizer so the reduction exists exactly once.
pub fn col_abs_max_into(src: &Matrix, out: &mut [f32]) {
    assert_eq!(out.len(), src.cols(), "col_abs_max out length mismatch");
    out.fill(0.0);
    for i in 0..src.rows() {
        for (m, &v) in out.iter_mut().zip(src.row(i)) {
            let a = v.abs();
            if a > *m {
                *m = a;
            }
        }
    }
}

/// Gather columns `idx` of `src` into `out` (`rows × idx.len()`).
pub fn select_cols_into(src: &Matrix, idx: &[usize], out: &mut Matrix) {
    assert_eq!(
        (out.rows(), out.cols()),
        (src.rows(), idx.len()),
        "select_cols out shape mismatch"
    );
    for i in 0..src.rows() {
        let row = src.row(i);
        let orow = out.row_mut(i);
        for (o, &j) in orow.iter_mut().zip(idx) {
            *o = row[j];
        }
    }
}

/// Gather columns `idx` of an i8 matrix (`x̂_int = [X̂_int]_{:,O}`).
pub fn select_cols_i8_into(src: &I8Matrix, idx: &[usize], out: &mut I8Matrix) {
    assert_eq!(
        (out.rows(), out.cols()),
        (src.rows(), idx.len()),
        "select_cols_i8 out shape mismatch"
    );
    for i in 0..src.rows() {
        let row = src.row(i);
        let orow = out.row_mut(i);
        for (o, &j) in orow.iter_mut().zip(idx) {
            *o = row[j];
        }
    }
}
