//! `_into` compute kernels: the matmul / transpose / gather loops, written
//! once, targeting caller-provided output buffers.
//!
//! These are the single source of truth for the hot loops — the allocating
//! convenience methods on [`Matrix`] delegate here, and the workspace-backed
//! execution path calls them directly with pooled buffers, so both paths
//! are bit-identical by construction (asserted by `tests/workspace_kernels`).
//!
//! All kernels **overwrite** `out` completely; none of them read its prior
//! contents, so dirty recycled buffers are safe inputs.
//!
//! Hot kernels are **row-sharded** across the [`pool`](super::pool): each
//! shard owns a fixed contiguous range of output rows and runs the same
//! row-range core the serial path runs, so threaded results are
//! bit-identical to single-threaded ones for *any* thread count (asserted by
//! `tests/thread_determinism.rs`). Small launches (decode shapes, tiny
//! matrices) fall below [`pool::MIN_SHARD_WORK`] and stay serial.
//!
//! The f32 kernels here rely on LLVM auto-vectorization of the blocked
//! loops. The **int8 matmul tier** does not: its panel microkernels live in
//! [`simd`](super::simd) with explicit runtime ISA dispatch (AVX2 / NEON /
//! scalar), reached through the packed `I8Matrix` matmuls — this file only
//! keeps the int8 *gather* ([`select_cols_i8_into`]), which is pure data
//! movement and ISA-independent.

use super::pool::{self, shard_range, SplitMut};
use super::{I8Matrix, Matrix, BLOCK_J, BLOCK_K};

/// Transpose tile edge: 32×32 f32 tiles = 4 KiB read + 4 KiB write, which
/// keeps both the row-major reads and the column-major writes inside L1.
const TRANSPOSE_TILE: usize = 32;

/// Row-range core of [`matmul_into`]: compute output rows `r0..r1` into
/// `orows` (the sub-slice for exactly those rows). Per-row accumulation
/// order is fixed (kb → jb → kk), independent of the range split.
fn matmul_rows(
    ad: &[f32],
    bd: &[f32],
    orows: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
) {
    orows.fill(0.0);
    for kb in (0..k).step_by(BLOCK_K) {
        let kend = (kb + BLOCK_K).min(k);
        for jb in (0..n).step_by(BLOCK_J) {
            let jend = (jb + BLOCK_J).min(n);
            for i in r0..r1 {
                let arow = &ad[i * k..(i + 1) * k];
                let base = (i - r0) * n;
                let orow = &mut orows[base + jb..base + jend];
                for kk in kb..kend {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n + jb..kk * n + jend];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
}

/// `out = a @ b` — cache-blocked i-k-j kernel (LLVM vectorizes the j loop),
/// row-sharded across the pool for large launches.
pub fn matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "matmul dim mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (a.rows(), b.cols()),
        "matmul out shape mismatch"
    );
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    let shards = pool::shards_for(m, m * k * n);
    if shards <= 1 {
        return matmul_rows(ad, bd, od, 0, m, k, n);
    }
    let split = SplitMut::new(od);
    pool::run_shards(shards, &|s| {
        let (r0, r1) = shard_range(m, shards, s);
        let orows = unsafe { split.slice(r0 * n, (r1 - r0) * n) };
        matmul_rows(ad, bd, orows, r0, r1, k, n);
    });
}

/// Row-range core of [`matmul_bt_into`].
fn matmul_bt_rows(
    ad: &[f32],
    bd: &[f32],
    orows: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
) {
    for i in r0..r1 {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut orows[(i - r0) * n..(i - r0 + 1) * n];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            orow[j] = acc;
        }
    }
}

/// `out = a @ b.T` — the backward-pass shape `dX = dY @ W.T`.
/// Reads both operands row-wise, so no transpose materialization.
pub fn matmul_bt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "matmul_bt dim mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (a.rows(), b.rows()),
        "matmul_bt out shape mismatch"
    );
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    let shards = pool::shards_for(m, m * k * n);
    if shards <= 1 {
        return matmul_bt_rows(ad, bd, od, 0, m, k, n);
    }
    let split = SplitMut::new(od);
    pool::run_shards(shards, &|s| {
        let (r0, r1) = shard_range(m, shards, s);
        let orows = unsafe { split.slice(r0 * n, (r1 - r0) * n) };
        matmul_bt_rows(ad, bd, orows, r0, r1, k, n);
    });
}

/// Row-range core of [`matmul_at_into`]: output rows `c0..c1` (columns of
/// `a`). Per-output-row accumulation order over `t` is fixed.
fn matmul_at_rows(
    ad: &[f32],
    bd: &[f32],
    orows: &mut [f32],
    c0: usize,
    c1: usize,
    k: usize,
    m: usize,
    n: usize,
) {
    orows.fill(0.0);
    for t in 0..k {
        let arow = &ad[t * m + c0..t * m + c1];
        let brow = &bd[t * n..(t + 1) * n];
        for (ii, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut orows[ii * n..(ii + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out = a.T @ b` — the gradient-accumulation shape `dW = X.T @ dY`.
/// Sharded over output rows (columns of `a`), so no write races.
pub fn matmul_at_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.rows(), b.rows(), "matmul_at dim mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (a.cols(), b.cols()),
        "matmul_at out shape mismatch"
    );
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    let shards = pool::shards_for(m, k * m * n);
    if shards <= 1 {
        return matmul_at_rows(ad, bd, od, 0, m, k, m, n);
    }
    let split = SplitMut::new(od);
    pool::run_shards(shards, &|s| {
        let (c0, c1) = shard_range(m, shards, s);
        let orows = unsafe { split.slice(c0 * n, (c1 - c0) * n) };
        matmul_at_rows(ad, bd, orows, c0, c1, k, m, n);
    });
}

/// `out = src.T` — cache-blocked transpose. The naive get/set loop strides
/// the output by `rows` every element, missing cache on every write for
/// large matrices; tiling keeps both streams resident (it sits on the
/// gradient path, so this matters every step).
pub fn transpose_into(src: &Matrix, out: &mut Matrix) {
    assert_eq!(
        (out.rows(), out.cols()),
        (src.cols(), src.rows()),
        "transpose out shape mismatch"
    );
    let (r, c) = (src.rows(), src.cols());
    let sd = src.data();
    let od = out.data_mut();
    for ib in (0..r).step_by(TRANSPOSE_TILE) {
        let iend = (ib + TRANSPOSE_TILE).min(r);
        for jb in (0..c).step_by(TRANSPOSE_TILE) {
            let jend = (jb + TRANSPOSE_TILE).min(c);
            for i in ib..iend {
                let srow = &sd[i * c..(i + 1) * c];
                for j in jb..jend {
                    od[j * r + i] = srow[j];
                }
            }
        }
    }
}

/// Row-range core of the column-max reduction: maxima of rows `r0..r1` into
/// `out` (length `cols`, fully overwritten).
fn col_abs_max_rows(src: &Matrix, out: &mut [f32], r0: usize, r1: usize) {
    out.fill(0.0);
    for i in r0..r1 {
        for (m, &v) in out.iter_mut().zip(src.row(i)) {
            let a = v.abs();
            if a > *m {
                *m = a;
            }
        }
    }
}

/// Shard `src`'s rows, reduce per-shard partial maxima, then merge the
/// lanes **in fixed shard order**. `partials` must hold
/// `(shards - 1) * cols` values (shard 0 reduces straight into `out`).
/// `max` is exact, so the tree reduction is bit-identical to the serial
/// loop for any shard count.
fn col_abs_max_sharded(src: &Matrix, out: &mut [f32], partials: &mut [f32], shards: usize) {
    let (rows, cols) = (src.rows(), src.cols());
    debug_assert!(partials.len() >= (shards - 1) * cols);
    let out_split = SplitMut::new(&mut *out);
    let lane_split = SplitMut::new(&mut *partials);
    pool::run_shards(shards, &|s| {
        let (r0, r1) = shard_range(rows, shards, s);
        let dst = unsafe {
            if s == 0 {
                out_split.slice(0, cols)
            } else {
                lane_split.slice((s - 1) * cols, cols)
            }
        };
        col_abs_max_rows(src, dst, r0, r1);
    });
    for s in 1..shards {
        let lane = &partials[(s - 1) * cols..s * cols];
        for (m, &v) in out.iter_mut().zip(lane) {
            if v > *m {
                *m = v;
            }
        }
    }
}

/// Per-column absolute maxima into `out` (length `src.cols()`, fully
/// overwritten) — the channel statistic the whole paper is built on,
/// shared by `Matrix::col_abs_max`, LLM.int8's detector, and the per-OC
/// quantizer so the reduction exists exactly once. Large inputs reduce
/// per-shard partials merged in fixed order (lane scratch allocated here;
/// hot-path callers use [`col_abs_max_ws`]).
pub fn col_abs_max_into(src: &Matrix, out: &mut [f32]) {
    assert_eq!(out.len(), src.cols(), "col_abs_max out length mismatch");
    let rows = src.rows();
    let shards = pool::shards_for(rows, rows * src.cols());
    if shards <= 1 {
        return col_abs_max_rows(src, out, 0, rows);
    }
    let mut partials = vec![0.0f32; (shards - 1) * src.cols()];
    col_abs_max_sharded(src, out, &mut partials, shards);
}

/// [`col_abs_max_into`] with the per-shard partial lanes in an explicit
/// caller-provided scratch buffer (resized here) — the compiled-plan hot
/// path passes a slot-backed buffer so the reduction needs neither an
/// allocation nor a string-keyed workspace lookup.
pub fn col_abs_max_scratch(src: &Matrix, out: &mut [f32], scratch: &mut Vec<f32>) {
    assert_eq!(out.len(), src.cols(), "col_abs_max out length mismatch");
    let rows = src.rows();
    let shards = pool::shards_for(rows, rows * src.cols());
    if shards <= 1 {
        return col_abs_max_rows(src, out, 0, rows);
    }
    scratch.resize((shards - 1) * src.cols(), 0.0);
    col_abs_max_sharded(src, out, scratch, shards);
}

/// [`col_abs_max_into`] with the per-shard partial lanes drawn from the
/// workspace — allocation-free at steady state.
pub fn col_abs_max_ws(src: &Matrix, out: &mut [f32], ws: &mut super::Workspace) {
    assert_eq!(out.len(), src.cols(), "col_abs_max out length mismatch");
    let rows = src.rows();
    let shards = pool::shards_for(rows, rows * src.cols());
    if shards <= 1 {
        return col_abs_max_rows(src, out, 0, rows);
    }
    let mut partials = ws.take_f32("kern.camax.lanes", (shards - 1) * src.cols());
    col_abs_max_sharded(src, out, &mut partials, shards);
    ws.put_f32("kern.camax.lanes", partials);
}

/// Gather columns `idx` of `src` into `out` (`rows × idx.len()`).
pub fn select_cols_into(src: &Matrix, idx: &[usize], out: &mut Matrix) {
    assert_eq!(
        (out.rows(), out.cols()),
        (src.rows(), idx.len()),
        "select_cols out shape mismatch"
    );
    for i in 0..src.rows() {
        let row = src.row(i);
        let orow = out.row_mut(i);
        for (o, &j) in orow.iter_mut().zip(idx) {
            *o = row[j];
        }
    }
}

/// Gather columns `idx` of an i8 matrix (`x̂_int = [X̂_int]_{:,O}`).
/// Register-tiled over [`simd::MR`](super::simd::MR)-row blocks so each
/// gather index is resolved once per block instead of once per row.
pub fn select_cols_i8_into(src: &I8Matrix, idx: &[usize], out: &mut I8Matrix) {
    assert_eq!(
        (out.rows(), out.cols()),
        (src.rows(), idx.len()),
        "select_cols_i8 out shape mismatch"
    );
    let (m, k, n) = (src.rows(), src.cols(), idx.len());
    assert!(idx.iter().all(|&j| j < k), "gather index out of range");
    let (sd, od) = (src.data(), out.data_mut());
    let mut i = 0;
    while i < m {
        let mr = (m - i).min(super::simd::MR);
        for (c, &j) in idx.iter().enumerate() {
            for r in 0..mr {
                od[(i + r) * n + c] = sd[(i + r) * k + j];
            }
        }
        i += mr;
    }
}
