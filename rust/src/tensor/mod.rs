//! Dense row-major tensor substrate.
//!
//! The paper's compute lives almost entirely in 2-D matmuls over
//! `(tokens × c_in) @ (c_in × c_out)`; this module provides exactly that:
//! an f32 matrix, an i8 matrix with i32-accumulating integer matmul (the CPU
//! analogue of the INT8 tensor-core / MXU path), and the handful of
//! elementwise/reduction ops the transformer and the quantizers need.
//!
//! Everything is cache-blocked and written so LLVM auto-vectorizes the
//! inner loops; the packed int8 matmul additionally runs on explicit
//! register-tiled microkernels with runtime ISA dispatch ([`simd`]:
//! AVX2 / NEON / scalar, `QUAFF_ISA` to override — bit-identical across
//! ISAs). The hot kernels are **row-sharded** across the
//! hand-rolled [`pool`] thread pool (`QUAFF_THREADS` / available
//! parallelism): shards own fixed disjoint output ranges and run the same
//! row-range cores as the serial path, so threaded results are
//! bit-identical to single-threaded ones. See `DESIGN.md` §Threading.
//!
//! The execution-engine layer lives here too: [`kernels`] holds the `_into`
//! variants of every hot loop (they write into caller-provided buffers) and
//! [`Workspace`] is the keyed, grow-only scratch arena those buffers come
//! from — including per-thread scratch *lanes* for the sharded kernels — so
//! the fine-tuning hot path stops allocating at steady state.
//! See `DESIGN.md` §Execution engine.

mod i8mat;
pub mod kernels;
mod matrix;
pub mod pool;
pub mod simd;
mod workspace;

pub use i8mat::{I8Matrix, PackedWeights};
pub use matrix::Matrix;
pub use workspace::{Workspace, WsF32, WsF32Lanes, WsI16, WsI16Lanes, WsI32, WsI8, WsIdx, WsKey};

/// Matmul kernel block sizes (tuned by the `bench_blocks` sweep).
pub(crate) const BLOCK_K: usize = 64;
pub(crate) const BLOCK_J: usize = 256;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f32;
                for k in 0..a.cols() {
                    acc += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, acc);
            }
        }
        c
    }

    #[test]
    fn blocked_matmul_matches_naive() {
        prop::check("matmul==naive", 0xA1, 24, |r| {
            let (m, k, n) = (1 + r.below(40), 1 + r.below(70), 1 + r.below(90));
            let a = Matrix::randn(m, k, r, 1.0);
            let b = Matrix::randn(k, n, r, 1.0);
            (a, b)
        }, |(a, b)| {
            let fast = a.matmul(b);
            let slow = naive_matmul(a, b);
            prop::all_close(fast.data(), slow.data(), 1e-4, 1e-4)
        });
    }

    #[test]
    fn matmul_bt_is_b_transposed() {
        let mut r = Rng::new(3);
        let a = Matrix::randn(7, 5, &mut r, 1.0);
        let b = Matrix::randn(9, 5, &mut r, 1.0);
        let direct = a.matmul(&b.transpose());
        let fused = a.matmul_bt(&b);
        prop::all_close(direct.data(), fused.data(), 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn matmul_at_is_a_transposed() {
        let mut r = Rng::new(4);
        let a = Matrix::randn(6, 8, &mut r, 1.0);
        let b = Matrix::randn(6, 4, &mut r, 1.0);
        let direct = a.transpose().matmul(&b);
        let fused = a.matmul_at(&b);
        prop::all_close(direct.data(), fused.data(), 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn i8_matmul_matches_i32_reference() {
        prop::check("i8matmul==ref", 0xB2, 24, |r| {
            let (m, k, n) = (1 + r.below(20), 1 + r.below(40), 1 + r.below(50));
            let a = I8Matrix::random(m, k, r);
            let b = I8Matrix::random(k, n, r);
            (a, b)
        }, |(a, b)| {
            let fast = a.matmul_i32(b);
            for i in 0..a.rows() {
                for j in 0..b.cols() {
                    let mut acc = 0i32;
                    for kk in 0..a.cols() {
                        acc += a.get(i, kk) as i32 * b.get(kk, j) as i32;
                    }
                    if acc != fast[i * b.cols() + j] {
                        return Err(format!("mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut r = Rng::new(5);
        let mut m = Matrix::randn(10, 33, &mut r, 3.0);
        m.softmax_rows();
        for i in 0..10 {
            let s: f32 = (0..33).map(|j| m.get(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut m = Matrix::from_vec(1, 3, vec![1000.0, 1000.0, -1000.0]);
        m.softmax_rows();
        assert!((m.get(0, 0) - 0.5).abs() < 1e-5);
        assert!(m.get(0, 2) < 1e-6);
        assert!(m.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn transpose_roundtrip() {
        let mut r = Rng::new(6);
        let m = Matrix::randn(11, 7, &mut r, 1.0);
        let back = m.transpose().transpose();
        assert_eq!(m.data(), back.data());
    }

    #[test]
    fn col_abs_max() {
        let m = Matrix::from_vec(2, 3, vec![1.0, -5.0, 2.0, -3.0, 4.0, 0.5]);
        assert_eq!(m.col_abs_max(), vec![3.0, 5.0, 2.0]);
    }

    #[test]
    fn row_abs_max() {
        let m = Matrix::from_vec(2, 3, vec![1.0, -5.0, 2.0, -3.0, 4.0, 0.5]);
        assert_eq!(m.row_abs_max(), vec![5.0, 4.0]);
    }

    #[test]
    fn select_cols_picks_submatrix() {
        let m = Matrix::from_vec(2, 4, vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let s = m.select_cols(&[1, 3]);
        assert_eq!(s.data(), &[1., 3., 5., 7.]);
        assert_eq!((s.rows(), s.cols()), (2, 2));
    }

    #[test]
    fn select_rows_picks_submatrix() {
        let m = Matrix::from_vec(3, 2, vec![0., 1., 2., 3., 4., 5.]);
        let s = m.select_rows(&[0, 2]);
        assert_eq!(s.data(), &[0., 1., 4., 5.]);
    }

    #[test]
    #[should_panic(expected = "matmul dim mismatch")]
    fn matmul_shape_check() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
