//! `tensor::pool` — the hand-rolled, zero-dependency thread pool behind the
//! sharded kernels.
//!
//! Design (see DESIGN.md §Threading):
//!
//! * **Spawn-once.** A global pool is built lazily on first use and lives for
//!   the process. Size comes from, in priority order: [`init`] (the
//!   [`ThreadConfig`] API), the `QUAFF_THREADS` environment variable, then
//!   `std::thread::available_parallelism()`.
//! * **Channel of closures.** Each worker owns an `mpsc` receiver; a kernel
//!   launch broadcasts one small [`Job`] per participating worker. A job is a
//!   pointer to a stack-allocated scope descriptor (shard counter + latch +
//!   the borrowed closure), so launches are cheap — no per-shard boxing.
//! * **Scoped.** [`ThreadPool::run`] does not return until every broadcast
//!   worker has finished the scope, so the closure may borrow locals; the
//!   `'static`-erasure is contained in this module.
//! * **Work-stealing shards.** Shards are claimed from an atomic counter, but
//!   every shard maps to a *fixed* output range ([`shard_range`]), so results
//!   never depend on which thread ran which shard.
//! * **Deterministic by construction.** The sharded kernels either write
//!   disjoint fixed row ranges (bit-identical to the serial loop for any
//!   shard count) or reduce per-unit partials merged in fixed order.
//! * **No nesting.** A launch from inside a pool scope (worker thread, or a
//!   re-entrant call on the launching thread) runs its shards inline — the
//!   kernels compose without deadlock and without oversubscription.
//!
//! The pool size is fixed at spawn, but the *active* width is adjustable at
//! runtime ([`set_active_threads`]) — `bench_threads` sweeps 1/2/4/8 over one
//! pool, and `QUAFF_THREADS=1` forces every kernel down the serial path.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::OnceLock;
use std::thread::{self, Thread};

/// Minimum work (in rough fused-op equivalents) per shard before a kernel
/// splits. Below ~64k ops the broadcast + wakeup overhead (a few µs) is not
/// worth it — decode-shape (`t = 1`) launches always stay serial.
pub const MIN_SHARD_WORK: usize = 1 << 16;

/// Pool sizing, set via [`init`] before first kernel use.
#[derive(Clone, Copy, Debug)]
pub struct ThreadConfig {
    /// Total threads participating in sharded kernels (callers + workers).
    pub threads: usize,
}

impl ThreadConfig {
    /// Resolve from the environment: `QUAFF_THREADS` if set to a positive
    /// integer, else the machine's available parallelism.
    pub fn from_env() -> ThreadConfig {
        let env = std::env::var("QUAFF_THREADS").ok();
        ThreadConfig {
            threads: parse_threads(env.as_deref()),
        }
    }
}

/// `QUAFF_THREADS` parsing: positive integers win; unset/garbage falls back
/// to available parallelism (≥ 1).
fn parse_threads(val: Option<&str>) -> usize {
    match val.and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// One kernel launch, shared between the launching thread and the workers it
/// messaged. Lives on the launcher's stack for the duration of the scope.
struct Scope {
    /// The sharded closure, lifetime-erased; [`ThreadPool::run`] guarantees
    /// it outlives every job that references this scope.
    f: *const (dyn Fn(usize) + Sync),
    /// Next shard index to claim.
    next: AtomicUsize,
    n_shards: usize,
    /// Workers that have not yet finished the scope.
    pending: AtomicUsize,
    panicked: AtomicBool,
    /// The launching thread, parked until `pending` drains.
    waiter: Thread,
}

impl Scope {
    /// Claim and run shards until the counter runs out.
    fn drain(&self) {
        // Safety: `ThreadPool::run` keeps the closure alive until the scope
        // latch opens, and never returns before that.
        let f = unsafe { &*self.f };
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_shards {
                break;
            }
            if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
                self.panicked.store(true, Ordering::Release);
            }
        }
    }

    /// Worker-side completion. The `fetch_sub` is this thread's **last**
    /// access to the scope: the waiter handle is cloned out first, because
    /// the instant `pending` hits zero the launcher may return and free the
    /// stack-allocated scope. (A Mutex/Condvar latch would have exactly that
    /// use-after-free window between its decrement and its lock.)
    fn finish_one(&self) {
        let waiter = self.waiter.clone();
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            waiter.unpark();
        }
    }

    /// Launcher-side wait for every messaged worker. `unpark` before `park`
    /// leaves a token, so the wakeup cannot be lost; spurious wakeups just
    /// re-check the latch.
    fn wait(&self) {
        while self.pending.load(Ordering::Acquire) != 0 {
            thread::park();
        }
    }
}

/// A type-erased pointer to a [`Scope`]; sent over the worker channels.
struct Job(*const Scope);

// Safety: the referenced Scope outlives the job (scoped execution), and all
// of its shared state is atomics plus a `Thread` handle (Send + Sync); the
// closure it carries is required to be Sync by `ThreadPool::run`'s signature.
unsafe impl Send for Job {}

thread_local! {
    /// True while this thread is executing inside a pool scope (worker body
    /// or a launching thread mid-`run`). Re-entrant launches go serial.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// The spawn-once pool. One instance lives in a process-global
/// [`OnceLock`]; explicit instances exist for the pool's own tests.
pub struct ThreadPool {
    senders: Vec<Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool where `threads` total threads (the caller plus
    /// `threads - 1` workers) cooperate on each launch.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let mut senders = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 1..threads {
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            let handle = thread::Builder::new()
                .name(format!("quaff-pool-{i}"))
                .spawn(move || worker_loop(rx))
                .expect("spawning pool worker");
            handles.push(handle);
        }
        ThreadPool {
            senders,
            handles,
            threads,
        }
    }

    /// Total cooperating threads (callers + workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(0..n_shards)` across up to `n_shards` threads; returns
    /// after every shard completed. Shards are claimed dynamically but each
    /// shard index owns a fixed slice of the output, so scheduling never
    /// changes results. Panics (after completing the scope) if a shard
    /// panicked.
    pub fn run(&self, n_shards: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_shards == 0 {
            return;
        }
        // Wake at most one worker per spare shard; run serial when there is
        // nobody to share with or we are already inside a pool scope.
        let workers = self.senders.len().min(n_shards - 1);
        if workers == 0 || IN_POOL.with(|c| c.get()) {
            for i in 0..n_shards {
                f(i);
            }
            return;
        }
        let scope = Scope {
            f: f as *const (dyn Fn(usize) + Sync),
            next: AtomicUsize::new(0),
            n_shards,
            pending: AtomicUsize::new(workers),
            panicked: AtomicBool::new(false),
            waiter: thread::current(),
        };
        for s in &self.senders[..workers] {
            s.send(Job(&scope as *const Scope))
                .expect("pool worker channel closed");
        }
        IN_POOL.with(|c| c.set(true));
        scope.drain(); // the launcher participates
        IN_POOL.with(|c| c.set(false));
        scope.wait();
        if scope.panicked.load(Ordering::Acquire) {
            panic!("tensor::pool: a sharded kernel closure panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.senders.clear(); // disconnect → workers observe Err and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Receiver<Job>) {
    IN_POOL.with(|c| c.set(true));
    while let Ok(job) = rx.recv() {
        // Safety: the launching thread keeps the scope alive until `pending`
        // reaches zero, which happens only after this `finish_one`.
        let scope = unsafe { &*job.0 };
        scope.drain();
        scope.finish_one();
    }
}

static POOL: OnceLock<ThreadPool> = OnceLock::new();
static REQUESTED: AtomicUsize = AtomicUsize::new(0);
static ACTIVE: AtomicUsize = AtomicUsize::new(0);

/// Request a pool size before first use (the `ThreadConfig` API). Returns
/// `false` if the global pool was already spawned (the request is ignored —
/// use `QUAFF_THREADS` or call earlier).
pub fn init(cfg: ThreadConfig) -> bool {
    REQUESTED.store(cfg.threads.max(1), Ordering::Relaxed);
    POOL.get().is_none()
}

/// The process-global pool, spawned on first use.
pub fn global() -> &'static ThreadPool {
    POOL.get_or_init(|| {
        let requested = REQUESTED.load(Ordering::Relaxed);
        let threads = if requested == 0 {
            ThreadConfig::from_env().threads
        } else {
            requested
        };
        ThreadPool::new(threads)
    })
}

/// Threads kernels may currently use (≤ the pool size).
pub fn active_threads() -> usize {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => {
            let n = global().threads();
            ACTIVE.store(n, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

/// Cap the number of threads kernels use without respawning the pool
/// (clamped to `[1, pool size]`); returns the effective width. Benches sweep
/// this; `QUAFF_THREADS=1` makes the default width 1.
pub fn set_active_threads(n: usize) -> usize {
    let eff = n.clamp(1, global().threads());
    ACTIVE.store(eff, Ordering::Relaxed);
    eff
}

/// Run `f(shard)` for `shard ∈ 0..n_shards` on the global pool.
pub fn run_shards(n_shards: usize, f: &(dyn Fn(usize) + Sync)) {
    global().run(n_shards, f);
}

/// Shard count for a kernel over `rows` independent rows costing `work`
/// rough fused-ops in total: enough shards to keep each above
/// [`MIN_SHARD_WORK`], capped by the active width and the row count.
/// Returns 1 (serial) for small launches.
pub fn shards_for(rows: usize, work: usize) -> usize {
    if rows < 2 {
        return 1;
    }
    let by_work = work / MIN_SHARD_WORK;
    if by_work <= 1 {
        return 1;
    }
    active_threads().min(rows).min(by_work)
}

/// The fixed, balanced range of shard `i` of `shards` over `total` items:
/// contiguous, disjoint, covering `0..total` exactly.
pub fn shard_range(total: usize, shards: usize, i: usize) -> (usize, usize) {
    let base = total / shards;
    let rem = total % shards;
    let start = i * base + i.min(rem);
    let len = base + usize::from(i < rem);
    (start, start + len)
}

/// Raw view of a mutable slice that sharded closures can carve disjoint
/// sub-slices from. The borrow checker cannot see the disjointness of
/// per-shard ranges, so the split is expressed with one contained `unsafe`.
pub struct SplitMut<T> {
    ptr: *mut T,
    len: usize,
}

// Safety: SplitMut hands out access to T values across threads; requiring
// T: Send matches what std's split_at_mut-based scoped threading would need.
unsafe impl<T: Send> Send for SplitMut<T> {}
unsafe impl<T: Send> Sync for SplitMut<T> {}

impl<T> SplitMut<T> {
    pub fn new(slice: &mut [T]) -> SplitMut<T> {
        SplitMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
        }
    }

    /// Borrow `[off, off + len)` mutably.
    ///
    /// # Safety
    /// Ranges handed to concurrently running shards must be disjoint, and
    /// the underlying slice must outlive the use (guaranteed inside a
    /// [`ThreadPool::run`] scope over a caller-owned buffer).
    #[allow(clippy::mut_from_ref)] // the whole point: checked disjoint split
    pub unsafe fn slice(&self, off: usize, len: usize) -> &mut [T] {
        assert!(off + len <= self.len, "SplitMut range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(off), len)
    }

    /// Borrow element `i` mutably (per-shard lane access).
    ///
    /// # Safety
    /// As for [`Self::slice`]: one shard per index.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn at(&self, i: usize) -> &mut T {
        assert!(i < self.len, "SplitMut index out of bounds");
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_range_partitions_exactly() {
        for total in [0usize, 1, 2, 7, 64, 1000] {
            for shards in 1..=8usize {
                let mut covered = 0;
                let mut prev_end = 0;
                for i in 0..shards {
                    let (s, e) = shard_range(total, shards, i);
                    assert_eq!(s, prev_end, "gap at shard {i} of {shards}/{total}");
                    assert!(e >= s);
                    covered += e - s;
                    prev_end = e;
                }
                assert_eq!(covered, total, "{shards} shards over {total}");
                assert_eq!(prev_end, total);
            }
        }
    }

    #[test]
    fn explicit_pool_runs_all_shards_once() {
        let pool = ThreadPool::new(3);
        assert_eq!(pool.threads(), 3);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.run(64, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "shard {i}");
        }
    }

    #[test]
    fn split_mut_disjoint_writes_land() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u32; 1000];
        let split = SplitMut::new(&mut data);
        pool.run(5, &|s| {
            let (r0, r1) = shard_range(1000, 5, s);
            let chunk = unsafe { split.slice(r0, r1 - r0) };
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (r0 + k) as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v as usize, i);
        }
    }

    #[test]
    fn nested_launches_run_inline_and_complete() {
        let pool = ThreadPool::new(4);
        let outer: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.run(4, &|s| {
            // a re-entrant launch from inside a scope must not deadlock
            let inner = AtomicUsize::new(0);
            global().run(8, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
            outer[s].store(inner.load(Ordering::Relaxed), Ordering::Relaxed);
        });
        for o in &outer {
            assert_eq!(o.load(Ordering::Relaxed), 8);
        }
    }

    #[test]
    fn zero_and_one_shard_are_noop_and_serial() {
        let pool = ThreadPool::new(2);
        pool.run(0, &|_| panic!("no shards should run"));
        let ran = AtomicUsize::new(0);
        pool.run(1, &|i| {
            assert_eq!(i, 0);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    #[should_panic(expected = "sharded kernel closure panicked")]
    fn shard_panic_propagates_after_scope() {
        let pool = ThreadPool::new(3);
        pool.run(6, &|i| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn parse_threads_rules() {
        assert_eq!(parse_threads(Some("4")), 4);
        assert_eq!(parse_threads(Some(" 2 ")), 2);
        assert!(parse_threads(Some("0")) >= 1); // falls back
        assert!(parse_threads(Some("banana")) >= 1);
        assert!(parse_threads(None) >= 1);
    }

    #[test]
    fn shards_for_thresholds() {
        assert_eq!(shards_for(1, usize::MAX), 1, "single row is serial");
        assert_eq!(shards_for(512, 100), 1, "tiny work is serial");
        let s = shards_for(512, MIN_SHARD_WORK * 64);
        assert!(s >= 1 && s <= 512.min(active_threads()).max(1));
    }
}
