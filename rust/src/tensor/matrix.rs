//! Row-major f32 matrix with the ops the transformer + quantizers need.
//!
//! The hot loops (matmul family, transpose) live in [`super::kernels`] as
//! `_into` kernels; the allocating methods here are thin wrappers so both
//! the convenience API and the workspace-backed path share one
//! implementation.

use super::kernels;
use crate::util::prng::Rng;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "from_vec shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Gaussian init with std `std` (used for weight init and test data).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng, std: f32) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// `self @ other` — cache-blocked i-k-j kernel (LLVM vectorizes the j loop).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols());
        kernels::matmul_into(self, other, &mut out);
        out
    }

    /// `self @ other.T` — the backward-pass shape `dX = dY @ W.T`.
    /// Reads both operands row-wise, so no transpose materialization.
    pub fn matmul_bt(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.rows());
        kernels::matmul_bt_into(self, other, &mut out);
        out
    }

    /// `self.T @ other` — the gradient-accumulation shape `dW = X.T @ dY`.
    pub fn matmul_at(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.cols, other.cols());
        kernels::matmul_at_into(self, other, &mut out);
        out
    }

    /// Cache-blocked transpose (see [`kernels::transpose_into`]).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        kernels::transpose_into(self, &mut out);
        out
    }

    /// Elementwise in-place `self += other`.
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise in-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Multiply each column `j` by `scales[j]` (broadcast over rows).
    pub fn scale_cols(&mut self, scales: &[f32]) {
        assert_eq!(scales.len(), self.cols);
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for (x, &s) in row.iter_mut().zip(scales) {
                *x *= s;
            }
        }
    }

    /// Multiply each row `i` by `scales[i]` (broadcast over columns).
    pub fn scale_rows(&mut self, scales: &[f32]) {
        assert_eq!(scales.len(), self.rows);
        for i in 0..self.rows {
            let s = scales[i];
            for x in self.row_mut(i) {
                *x *= s;
            }
        }
    }

    /// Per-column absolute maxima — the channel statistic everything in the
    /// paper is built on (`max(|X_:,i|)`).
    pub fn col_abs_max(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        kernels::col_abs_max_into(self, &mut out);
        out
    }

    /// Per-row absolute maxima (`max(|X_t,:|)`, the per-token statistic).
    pub fn row_abs_max(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().fold(0.0f32, |m, &x| m.max(x.abs())))
            .collect()
    }

    /// Global absolute maximum.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Gather columns `idx` into a new `(rows × idx.len())` matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        kernels::select_cols_into(self, idx, &mut out);
        out
    }

    /// Gather rows `idx` into a new `(idx.len() × cols)` matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (oi, &i) in idx.iter().enumerate() {
            out.row_mut(oi).copy_from_slice(self.row(i));
        }
        out
    }

    /// In-place numerically-stable row softmax.
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let mut sum = 0.0f32;
            for x in row.iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            let inv = 1.0 / sum;
            for x in row.iter_mut() {
                *x *= inv;
            }
        }
    }

    /// Frobenius-norm squared.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Mean squared error vs another matrix.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / n as f64
    }
}
