//! Reusable scratch arena for the execution hot path.
//!
//! Every matmul / quantize / dequantize on the fine-tuning hot path needs
//! transient buffers. Allocating them per call is what the §Perf profile
//! shows as steady-state churn; the [`Workspace`] keeps them alive across
//! steps instead. Two access tiers share one arena:
//!
//! * **String-keyed** (`take_*`/`put_*`): buffers keyed by a
//!   `&'static str` so each call site gets a stable buffer back (plain
//!   moves, no RefCell, no borrow gymnastics). A take scans the keyed pool
//!   — fine on cold paths, but a per-call cost on hot loops.
//! * **Slot-keyed** (`bind_*` once → `take_slot_*`/`put_slot_*` per call):
//!   pre-resolved handles ([`WsF32`] and friends) that index straight into
//!   a slot table — **O(1), no string comparison at all**. The compiled
//!   execution plans (`quant::pipeline`, DESIGN.md §7) bind their slots
//!   once per layer and run every subsequent forward through handles only;
//!   [`Workspace::keyed_takes`] counts string-keyed takes so tests can pin
//!   "zero string lookups" on the plan-driven path. Slots are
//!   [`Workspace`]-tagged: using a handle against a different workspace, or
//!   taking a slot that is already checked out (two plans claiming one
//!   slot), trips a debug assertion.
//!
//! All buffers are **grow-only**: a take that needs more capacity than the
//! pooled buffer reallocates once, after which the larger buffer stays.
//! Outputs handed to a caller come back via [`Workspace::recycle`] into a
//! shared **donor pool** (no key, no string — capacity best-fit) that both
//! keyed misses and [`Workspace::take_donor_matrix`] draw from, so a
//! consumer never needs to know the producer's key.
//!
//! After a warm-up step with fixed shapes, every take is served from the
//! arena: the hot path performs **zero heap allocations** at steady state
//! (`fresh_allocs` stops moving — asserted by `tests/zero_alloc.rs` with a
//! counting global allocator).

use super::{I8Matrix, Matrix};
use std::any::Any;
use std::sync::atomic::{AtomicU32, Ordering};

/// Donor-pool saturation bound. The transformer layers donate more buffers
/// per step than takes consume (LayerNorm/injection/attention outputs are
/// recycled too), so an uncapped pool would grow without bound across a
/// long run. Beyond this many parked donors, further donations are simply
/// dropped — takes still find a donor (the working set is far smaller than
/// the cap), so the steady-state zero-allocation property is unaffected.
const MAX_DONORS: usize = 64;

/// Tag source for workspace identity (see [`WsKey`]).
static NEXT_WS_TAG: AtomicU32 = AtomicU32::new(1);

/// Pre-resolved slot handle: an index into one workspace's slot table plus
/// the tag of the workspace that issued it. Typed wrappers ([`WsF32`],
/// [`WsI8`], …) prevent a handle from being used against the wrong pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WsKey {
    idx: u32,
    ws: u32,
}

macro_rules! slot_key {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        pub struct $name(WsKey);
    };
}

slot_key!(
    /// Handle to an f32 slot.
    WsF32
);
slot_key!(
    /// Handle to an i8 slot.
    WsI8
);
slot_key!(
    /// Handle to an i16 slot.
    WsI16
);
slot_key!(
    /// Handle to an i32 slot.
    WsI32
);
slot_key!(
    /// Handle to an index (usize) slot.
    WsIdx
);
slot_key!(
    /// Handle to an f32 lane-set slot.
    WsF32Lanes
);
slot_key!(
    /// Handle to an i16 lane-set slot.
    WsI16Lanes
);

/// One slot: a named parking spot for exactly one buffer. `None` while the
/// buffer is checked out.
struct Slot<T> {
    name: &'static str,
    buf: Option<T>,
}

/// Take the buffer out of slot `idx`. A slot that is already empty means
/// two users claimed one slot (or a `put_slot` is missing) — debug-asserted,
/// with a graceful fresh-default fallback in release builds.
fn slot_take<T: Default>(slots: &mut [Slot<T>], idx: u32) -> T {
    let e = &mut slots[idx as usize];
    if let Some(b) = e.buf.take() {
        return b;
    }
    if cfg!(debug_assertions) {
        panic!(
            "workspace slot '{}' (#{idx}) claimed while already taken — \
             two plans sharing one slot id, or a missing put_slot",
            e.name
        );
    }
    T::default()
}

fn slot_put<T>(slots: &mut [Slot<T>], idx: u32, buf: T) {
    let e = &mut slots[idx as usize];
    debug_assert!(
        e.buf.is_none(),
        "double put into workspace slot '{}' (#{idx})",
        e.name
    );
    e.buf = Some(buf);
}

/// Keyed + slot-keyed, grow-only scratch arena. See the module docs.
pub struct Workspace {
    f32s: Vec<(&'static str, Vec<f32>)>,
    i8s: Vec<(&'static str, Vec<i8>)>,
    i16s: Vec<(&'static str, Vec<i16>)>,
    i32s: Vec<(&'static str, Vec<i32>)>,
    idxs: Vec<(&'static str, Vec<usize>)>,
    /// Per-thread scratch **lanes** for the sharded kernels: a pooled
    /// `Vec<Vec<T>>` with one buffer per shard, so parallel shards stay
    /// zero-alloc without sharing mutable scratch. Lane sets only ever grow
    /// (a narrower take hands back the wider set), so warmed inner buffers
    /// survive shard-count fluctuations.
    i16_lanes: Vec<(&'static str, Vec<Vec<i16>>)>,
    i32_lanes: Vec<(&'static str, Vec<Vec<i32>>)>,
    /// f32 lane sets: per-shard score scratch for the cached-attention
    /// kernel and the K/V cache's per-layer backing buffers (see
    /// `infer::KvCache`), pooled so caches are reused across requests.
    f32_lanes: Vec<(&'static str, Vec<Vec<f32>>)>,
    /// Unkeyed donated buffers ([`Workspace::recycle`]); served by capacity
    /// best-fit to keyed misses and [`Workspace::take_donor_f32`].
    donors: Vec<Vec<f32>>,
    /// Slot tables (pre-resolved handles; see module docs).
    slot_f32: Vec<Slot<Vec<f32>>>,
    slot_i8: Vec<Slot<Vec<i8>>>,
    slot_i16: Vec<Slot<Vec<i16>>>,
    slot_i32: Vec<Slot<Vec<i32>>>,
    slot_idx: Vec<Slot<Vec<usize>>>,
    slot_f32_lanes: Vec<Slot<Vec<Vec<f32>>>>,
    slot_i16_lanes: Vec<Slot<Vec<Vec<i16>>>>,
    /// Compiled per-layer execution plans, keyed by the owner's plan id
    /// (`quant::pipeline::PlanId`). Type-erased so the arena stays free of
    /// upward dependencies.
    plans: Vec<(u64, Box<dyn Any + Send>)>,
    /// This workspace's identity tag (embedded in every issued [`WsKey`]).
    tag: u32,
    /// Buffers that had to be freshly allocated (or regrown). Stops
    /// increasing once the arena is warm — the zero-alloc invariant.
    pub fresh_allocs: u64,
    /// Takes served entirely from pooled capacity.
    pub reuses: u64,
    /// String-keyed takes (`take_*`, not `take_slot_*`/`take_donor_*`).
    /// Stops increasing on a fully plan-driven hot loop — the zero
    /// string-lookup invariant (`tests/zero_alloc.rs`).
    pub keyed_takes: u64,
}

impl Default for Workspace {
    fn default() -> Workspace {
        Workspace {
            f32s: Vec::new(),
            i8s: Vec::new(),
            i16s: Vec::new(),
            i32s: Vec::new(),
            idxs: Vec::new(),
            i16_lanes: Vec::new(),
            i32_lanes: Vec::new(),
            f32_lanes: Vec::new(),
            donors: Vec::new(),
            slot_f32: Vec::new(),
            slot_i8: Vec::new(),
            slot_i16: Vec::new(),
            slot_i32: Vec::new(),
            slot_idx: Vec::new(),
            slot_f32_lanes: Vec::new(),
            slot_i16_lanes: Vec::new(),
            plans: Vec::new(),
            tag: NEXT_WS_TAG.fetch_add(1, Ordering::Relaxed),
            fresh_allocs: 0,
            reuses: 0,
            keyed_takes: 0,
        }
    }
}

impl std::fmt::Debug for Workspace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Workspace")
            .field("tag", &self.tag)
            .field("pooled", &self.pooled())
            .field("fresh_allocs", &self.fresh_allocs)
            .field("reuses", &self.reuses)
            .field("keyed_takes", &self.keyed_takes)
            .finish()
    }
}

/// Take a buffer from the string-keyed `pool`: exact key match, else a
/// fresh allocation (the f32 pool additionally falls back on the donor pool
/// — see [`Workspace::take_f32`]). The returned buffer has length `len` and
/// **unspecified contents** — callers that accumulate must `fill` it
/// themselves.
fn take_from<T: Clone + Default>(
    pool: &mut Vec<(&'static str, Vec<T>)>,
    fresh: &mut u64,
    reuses: &mut u64,
    key: &'static str,
    len: usize,
) -> Vec<T> {
    match pool.iter().position(|(k, _)| *k == key) {
        Some(i) => {
            let (_, mut v) = pool.swap_remove(i);
            if v.capacity() >= len {
                *reuses += 1;
            } else {
                *fresh += 1;
            }
            v.resize(len, T::default());
            v
        }
        None => {
            *fresh += 1;
            vec![T::default(); len]
        }
    }
}

/// Resize a slot-taken plain buffer to `len`, counting reuse vs regrowth.
fn size_taken<T: Clone + Default>(
    mut v: Vec<T>,
    fresh: &mut u64,
    reuses: &mut u64,
    len: usize,
) -> Vec<T> {
    if v.capacity() >= len {
        *reuses += 1;
    } else {
        *fresh += 1;
    }
    v.resize(len, T::default());
    v
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Identity tag of this arena (embedded in issued slot handles).
    pub fn tag(&self) -> u32 {
        self.tag
    }

    #[inline]
    fn check_key(&self, k: WsKey) {
        debug_assert_eq!(
            k.ws, self.tag,
            "workspace slot handle used against a different Workspace than the one that bound it"
        );
    }

    /// f32 scratch of length `len`, contents unspecified.
    pub fn take_f32(&mut self, key: &'static str, len: usize) -> Vec<f32> {
        self.keyed_takes += 1;
        if let Some(i) = self.f32s.iter().position(|(k, _)| *k == key) {
            let (_, v) = self.f32s.swap_remove(i);
            return size_taken(v, &mut self.fresh_allocs, &mut self.reuses, len);
        }
        self.donor_f32(len)
    }

    pub fn put_f32(&mut self, key: &'static str, v: Vec<f32>) {
        self.f32s.push((key, v));
    }

    pub fn take_i8(&mut self, key: &'static str, len: usize) -> Vec<i8> {
        self.keyed_takes += 1;
        take_from(&mut self.i8s, &mut self.fresh_allocs, &mut self.reuses, key, len)
    }

    pub fn put_i8(&mut self, key: &'static str, v: Vec<i8>) {
        self.i8s.push((key, v));
    }

    pub fn take_i16(&mut self, key: &'static str, len: usize) -> Vec<i16> {
        self.keyed_takes += 1;
        take_from(&mut self.i16s, &mut self.fresh_allocs, &mut self.reuses, key, len)
    }

    pub fn put_i16(&mut self, key: &'static str, v: Vec<i16>) {
        self.i16s.push((key, v));
    }

    pub fn take_i32(&mut self, key: &'static str, len: usize) -> Vec<i32> {
        self.keyed_takes += 1;
        take_from(&mut self.i32s, &mut self.fresh_allocs, &mut self.reuses, key, len)
    }

    pub fn put_i32(&mut self, key: &'static str, v: Vec<i32>) {
        self.i32s.push((key, v));
    }

    /// At least `n` i16 scratch lanes (one per shard of a sharded kernel),
    /// each with unspecified contents and retained capacity. Lane sets are
    /// **grow-only**: a take after a wider launch hands back the wider set
    /// (callers use the first `n`), so shard-count fluctuations never drop
    /// warmed lane buffers.
    pub fn take_i16_lanes(&mut self, key: &'static str, n: usize) -> Vec<Vec<i16>> {
        self.keyed_takes += 1;
        take_lanes_from(&mut self.i16_lanes, &mut self.fresh_allocs, &mut self.reuses, key, n)
    }

    pub fn put_i16_lanes(&mut self, key: &'static str, v: Vec<Vec<i16>>) {
        self.i16_lanes.push((key, v));
    }

    /// At least `n` i32 scratch lanes — see [`Workspace::take_i16_lanes`].
    pub fn take_i32_lanes(&mut self, key: &'static str, n: usize) -> Vec<Vec<i32>> {
        self.keyed_takes += 1;
        take_lanes_from(&mut self.i32_lanes, &mut self.fresh_allocs, &mut self.reuses, key, n)
    }

    pub fn put_i32_lanes(&mut self, key: &'static str, v: Vec<Vec<i32>>) {
        self.i32_lanes.push((key, v));
    }

    /// At least `n` f32 scratch lanes — see [`Workspace::take_i16_lanes`].
    pub fn take_f32_lanes(&mut self, key: &'static str, n: usize) -> Vec<Vec<f32>> {
        self.keyed_takes += 1;
        take_lanes_from(&mut self.f32_lanes, &mut self.fresh_allocs, &mut self.reuses, key, n)
    }

    pub fn put_f32_lanes(&mut self, key: &'static str, v: Vec<Vec<f32>>) {
        self.f32_lanes.push((key, v));
    }

    /// Cleared index scratch (length 0; push into it).
    pub fn take_idx(&mut self, key: &'static str) -> Vec<usize> {
        self.keyed_takes += 1;
        let mut v =
            take_from(&mut self.idxs, &mut self.fresh_allocs, &mut self.reuses, key, 0);
        v.clear();
        v
    }

    pub fn put_idx(&mut self, key: &'static str, v: Vec<usize>) {
        self.idxs.push((key, v));
    }

    /// `rows × cols` matrix, contents unspecified.
    pub fn take_matrix(&mut self, key: &'static str, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_f32(key, rows * cols))
    }

    /// `rows × cols` matrix, zero-filled.
    pub fn take_matrix_zeroed(&mut self, key: &'static str, rows: usize, cols: usize) -> Matrix {
        let mut m = self.take_matrix(key, rows, cols);
        m.data_mut().fill(0.0);
        m
    }

    pub fn put_matrix(&mut self, key: &'static str, m: Matrix) {
        self.put_f32(key, m.into_vec());
    }

    pub fn take_i8_matrix(&mut self, key: &'static str, rows: usize, cols: usize) -> I8Matrix {
        I8Matrix::from_vec(rows, cols, self.take_i8(key, rows * cols))
    }

    pub fn put_i8_matrix(&mut self, key: &'static str, m: I8Matrix) {
        self.put_i8(key, m.into_vec());
    }

    // ---- donor pool (no keys, no strings) -------------------------------

    /// Donate a matrix whose producer the caller does not know; keyed f32
    /// misses and [`Workspace::take_donor_matrix`] fall back on these
    /// donors. Donations beyond [`MAX_DONORS`] parked entries are dropped
    /// (see the constant's docs).
    pub fn recycle(&mut self, m: Matrix) {
        self.recycle_f32(m.into_vec());
    }

    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        if self.donors.len() < MAX_DONORS {
            self.donors.push(v);
        }
    }

    /// Best-fit donor take: the smallest parked donor whose capacity covers
    /// `len`, else the largest one (it grows once and then sticks), else a
    /// fresh allocation. Contents unspecified. No string comparison — this
    /// is the plan-driven path's output-buffer source.
    pub fn take_donor_f32(&mut self, len: usize) -> Vec<f32> {
        self.donor_f32(len)
    }

    fn donor_f32(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        let mut largest: Option<usize> = None;
        for (i, v) in self.donors.iter().enumerate() {
            let cap = v.capacity();
            if cap >= len && best.map_or(true, |b| cap < self.donors[b].capacity()) {
                best = Some(i);
            }
            if largest.map_or(true, |l| cap > self.donors[l].capacity()) {
                largest = Some(i);
            }
        }
        match best.or(largest) {
            Some(i) => {
                let v = self.donors.swap_remove(i);
                size_taken(v, &mut self.fresh_allocs, &mut self.reuses, len)
            }
            None => {
                self.fresh_allocs += 1;
                vec![0.0; len]
            }
        }
    }

    /// `rows × cols` matrix from the donor pool (see
    /// [`Workspace::take_donor_f32`]); contents unspecified.
    pub fn take_donor_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.donor_f32(rows * cols))
    }

    // ---- slot handles (pre-resolved, O(1), string-free) -----------------

    /// Bind a new f32 slot named `name`, pre-sized to `cap` elements.
    /// Binding is the cold path (it allocates); the returned handle makes
    /// every subsequent take/put an O(1) table access.
    pub fn bind_f32(&mut self, name: &'static str, cap: usize) -> WsF32 {
        self.fresh_allocs += 1;
        let idx = self.slot_f32.len() as u32;
        self.slot_f32.push(Slot { name, buf: Some(Vec::with_capacity(cap)) });
        WsF32(WsKey { idx, ws: self.tag })
    }

    pub fn bind_i8(&mut self, name: &'static str, cap: usize) -> WsI8 {
        self.fresh_allocs += 1;
        let idx = self.slot_i8.len() as u32;
        self.slot_i8.push(Slot { name, buf: Some(Vec::with_capacity(cap)) });
        WsI8(WsKey { idx, ws: self.tag })
    }

    pub fn bind_i16(&mut self, name: &'static str, cap: usize) -> WsI16 {
        self.fresh_allocs += 1;
        let idx = self.slot_i16.len() as u32;
        self.slot_i16.push(Slot { name, buf: Some(Vec::with_capacity(cap)) });
        WsI16(WsKey { idx, ws: self.tag })
    }

    pub fn bind_i32(&mut self, name: &'static str, cap: usize) -> WsI32 {
        self.fresh_allocs += 1;
        let idx = self.slot_i32.len() as u32;
        self.slot_i32.push(Slot { name, buf: Some(Vec::with_capacity(cap)) });
        WsI32(WsKey { idx, ws: self.tag })
    }

    pub fn bind_idx(&mut self, name: &'static str) -> WsIdx {
        self.fresh_allocs += 1;
        let idx = self.slot_idx.len() as u32;
        self.slot_idx.push(Slot { name, buf: Some(Vec::new()) });
        WsIdx(WsKey { idx, ws: self.tag })
    }

    /// Bind an f32 lane-set slot with `n` lanes, each pre-sized to `cap`.
    pub fn bind_f32_lanes(&mut self, name: &'static str, n: usize, cap: usize) -> WsF32Lanes {
        self.fresh_allocs += 1;
        let mut lanes = Vec::with_capacity(n);
        lanes.resize_with(n, || Vec::with_capacity(cap));
        let idx = self.slot_f32_lanes.len() as u32;
        self.slot_f32_lanes.push(Slot { name, buf: Some(lanes) });
        WsF32Lanes(WsKey { idx, ws: self.tag })
    }

    pub fn bind_i16_lanes(&mut self, name: &'static str, n: usize, cap: usize) -> WsI16Lanes {
        self.fresh_allocs += 1;
        let mut lanes = Vec::with_capacity(n);
        lanes.resize_with(n, || Vec::with_capacity(cap));
        let idx = self.slot_i16_lanes.len() as u32;
        self.slot_i16_lanes.push(Slot { name, buf: Some(lanes) });
        WsI16Lanes(WsKey { idx, ws: self.tag })
    }

    /// Slot take of length `len`, contents unspecified (grow-only).
    pub fn take_slot_f32(&mut self, key: WsF32, len: usize) -> Vec<f32> {
        self.check_key(key.0);
        let v = slot_take(&mut self.slot_f32, key.0.idx);
        size_taken(v, &mut self.fresh_allocs, &mut self.reuses, len)
    }

    pub fn put_slot_f32(&mut self, key: WsF32, v: Vec<f32>) {
        self.check_key(key.0);
        slot_put(&mut self.slot_f32, key.0.idx, v);
    }

    pub fn take_slot_i8(&mut self, key: WsI8, len: usize) -> Vec<i8> {
        self.check_key(key.0);
        let v = slot_take(&mut self.slot_i8, key.0.idx);
        size_taken(v, &mut self.fresh_allocs, &mut self.reuses, len)
    }

    pub fn put_slot_i8(&mut self, key: WsI8, v: Vec<i8>) {
        self.check_key(key.0);
        slot_put(&mut self.slot_i8, key.0.idx, v);
    }

    pub fn take_slot_i16(&mut self, key: WsI16, len: usize) -> Vec<i16> {
        self.check_key(key.0);
        let v = slot_take(&mut self.slot_i16, key.0.idx);
        size_taken(v, &mut self.fresh_allocs, &mut self.reuses, len)
    }

    pub fn put_slot_i16(&mut self, key: WsI16, v: Vec<i16>) {
        self.check_key(key.0);
        slot_put(&mut self.slot_i16, key.0.idx, v);
    }

    pub fn take_slot_i32(&mut self, key: WsI32, len: usize) -> Vec<i32> {
        self.check_key(key.0);
        let v = slot_take(&mut self.slot_i32, key.0.idx);
        size_taken(v, &mut self.fresh_allocs, &mut self.reuses, len)
    }

    pub fn put_slot_i32(&mut self, key: WsI32, v: Vec<i32>) {
        self.check_key(key.0);
        slot_put(&mut self.slot_i32, key.0.idx, v);
    }

    /// Cleared index scratch from a slot.
    pub fn take_slot_idx(&mut self, key: WsIdx) -> Vec<usize> {
        self.check_key(key.0);
        let mut v = slot_take(&mut self.slot_idx, key.0.idx);
        self.reuses += 1;
        v.clear();
        v
    }

    pub fn put_slot_idx(&mut self, key: WsIdx, v: Vec<usize>) {
        self.check_key(key.0);
        slot_put(&mut self.slot_idx, key.0.idx, v);
    }

    /// At least `n` f32 lanes from a slot (grow-only, like
    /// [`Workspace::take_f32_lanes`]).
    pub fn take_slot_f32_lanes(&mut self, key: WsF32Lanes, n: usize) -> Vec<Vec<f32>> {
        self.check_key(key.0);
        let mut v = slot_take(&mut self.slot_f32_lanes, key.0.idx);
        if v.len() < n {
            self.fresh_allocs += 1;
            v.resize_with(n, Vec::new);
        } else {
            self.reuses += 1;
        }
        v
    }

    pub fn put_slot_f32_lanes(&mut self, key: WsF32Lanes, v: Vec<Vec<f32>>) {
        self.check_key(key.0);
        slot_put(&mut self.slot_f32_lanes, key.0.idx, v);
    }

    pub fn take_slot_i16_lanes(&mut self, key: WsI16Lanes, n: usize) -> Vec<Vec<i16>> {
        self.check_key(key.0);
        let mut v = slot_take(&mut self.slot_i16_lanes, key.0.idx);
        if v.len() < n {
            self.fresh_allocs += 1;
            v.resize_with(n, Vec::new);
        } else {
            self.reuses += 1;
        }
        v
    }

    pub fn put_slot_i16_lanes(&mut self, key: WsI16Lanes, v: Vec<Vec<i16>>) {
        self.check_key(key.0);
        slot_put(&mut self.slot_i16_lanes, key.0.idx, v);
    }

    /// `rows × cols` matrix from an f32 slot, contents unspecified.
    pub fn take_slot_matrix(&mut self, key: WsF32, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_slot_f32(key, rows * cols))
    }

    pub fn put_slot_matrix(&mut self, key: WsF32, m: Matrix) {
        self.put_slot_f32(key, m.into_vec());
    }

    pub fn take_slot_i8_matrix(&mut self, key: WsI8, rows: usize, cols: usize) -> I8Matrix {
        I8Matrix::from_vec(rows, cols, self.take_slot_i8(key, rows * cols))
    }

    pub fn put_slot_i8_matrix(&mut self, key: WsI8, m: I8Matrix) {
        self.put_slot_i8(key, m.into_vec());
    }

    // ---- compiled-plan table --------------------------------------------

    /// Remove and return the compiled plan stored under `id`, if any. Plans
    /// are checked out for the duration of a forward (so the plan and the
    /// arena can be borrowed independently) and stored back afterwards.
    pub fn take_plan(&mut self, id: u64) -> Option<Box<dyn Any + Send>> {
        self.plans
            .iter()
            .position(|(pid, _)| *pid == id)
            .map(|i| self.plans.swap_remove(i).1)
    }

    /// Store a compiled plan under `id` (one plan per id).
    pub fn put_plan(&mut self, id: u64, plan: Box<dyn Any + Send>) {
        debug_assert!(
            self.plans.iter().all(|(pid, _)| *pid != id),
            "plan id {id} stored twice"
        );
        self.plans.push((id, plan));
    }

    // ---- diagnostics ----------------------------------------------------

    /// Number of buffers currently parked in the arena (all tiers).
    pub fn pooled(&self) -> usize {
        self.f32s.len()
            + self.i8s.len()
            + self.i16s.len()
            + self.i32s.len()
            + self.idxs.len()
            + self.i16_lanes.len()
            + self.i32_lanes.len()
            + self.f32_lanes.len()
            + self.donors.len()
            + self.slot_f32.iter().filter(|s| s.buf.is_some()).count()
            + self.slot_i8.iter().filter(|s| s.buf.is_some()).count()
            + self.slot_i16.iter().filter(|s| s.buf.is_some()).count()
            + self.slot_i32.iter().filter(|s| s.buf.is_some()).count()
            + self.slot_idx.iter().filter(|s| s.buf.is_some()).count()
            + self.slot_f32_lanes.iter().filter(|s| s.buf.is_some()).count()
            + self.slot_i16_lanes.iter().filter(|s| s.buf.is_some()).count()
    }

    /// Total bytes of pooled capacity (diagnostics).
    pub fn pooled_bytes(&self) -> usize {
        self.f32s.iter().map(|(_, v)| v.capacity() * 4).sum::<usize>()
            + self.i8s.iter().map(|(_, v)| v.capacity()).sum::<usize>()
            + self.i16s.iter().map(|(_, v)| v.capacity() * 2).sum::<usize>()
            + self.i32s.iter().map(|(_, v)| v.capacity() * 4).sum::<usize>()
            + self.idxs.iter().map(|(_, v)| v.capacity() * 8).sum::<usize>()
            + lane_bytes(&self.i16_lanes, 2)
            + lane_bytes(&self.i32_lanes, 4)
            + lane_bytes(&self.f32_lanes, 4)
            + self.donors.iter().map(|v| v.capacity() * 4).sum::<usize>()
            + slot_vec_bytes(&self.slot_f32, 4)
            + slot_vec_bytes(&self.slot_i8, 1)
            + slot_vec_bytes(&self.slot_i16, 2)
            + slot_vec_bytes(&self.slot_i32, 4)
            + slot_vec_bytes(&self.slot_idx, 8)
            + slot_lane_bytes(&self.slot_f32_lanes, 4)
            + slot_lane_bytes(&self.slot_i16_lanes, 2)
    }
}

/// Take a lane set (`Vec<Vec<T>>`) of at least `n` lanes from `pool`:
/// exact key match reused (grown with empty lanes if the launch got wider,
/// **never shrunk** — truncating would free warmed inner buffers whenever
/// the shard count fluctuates), else a fresh set of `n` empty lanes.
fn take_lanes_from<T>(
    pool: &mut Vec<(&'static str, Vec<Vec<T>>)>,
    fresh: &mut u64,
    reuses: &mut u64,
    key: &'static str,
    n: usize,
) -> Vec<Vec<T>> {
    match pool.iter().position(|(k, _)| *k == key) {
        Some(i) => {
            let (_, mut v) = pool.swap_remove(i);
            if v.len() < n {
                *fresh += 1;
                v.resize_with(n, Vec::new);
            } else {
                *reuses += 1;
            }
            v
        }
        None => {
            *fresh += 1;
            let mut v = Vec::with_capacity(n);
            v.resize_with(n, Vec::new);
            v
        }
    }
}

/// Pooled capacity of a lane pool (inner buffers only; the outer vecs are
/// a few pointers each).
fn lane_bytes<T>(pool: &[(&'static str, Vec<Vec<T>>)], elem: usize) -> usize {
    pool.iter()
        .map(|(_, lanes)| lanes.iter().map(|l| l.capacity() * elem).sum::<usize>())
        .sum()
}

fn slot_vec_bytes<T>(slots: &[Slot<Vec<T>>], elem: usize) -> usize {
    slots
        .iter()
        .filter_map(|s| s.buf.as_ref().map(|v| v.capacity() * elem))
        .sum()
}

fn slot_lane_bytes<T>(slots: &[Slot<Vec<Vec<T>>>], elem: usize) -> usize {
    slots
        .iter()
        .filter_map(|s| {
            s.buf
                .as_ref()
                .map(|lanes| lanes.iter().map(|l| l.capacity() * elem).sum::<usize>())
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_take_put_reuses_outer_and_inner_capacity() {
        let mut ws = Workspace::new();
        let mut lanes = ws.take_i16_lanes("l", 4);
        assert_eq!(lanes.len(), 4);
        for l in &mut lanes {
            l.resize(100, 0); // simulate kernel growing its lane
        }
        ws.put_i16_lanes("l", lanes);
        let frozen = ws.fresh_allocs;
        let lanes = ws.take_i16_lanes("l", 4);
        assert_eq!(ws.fresh_allocs, frozen, "steady lane take must reuse");
        assert!(lanes.iter().all(|l| l.capacity() >= 100));
        ws.put_i16_lanes("l", lanes);
        // a narrower launch must NOT shrink the set (warmed lanes survive)
        let lanes = ws.take_i16_lanes("l", 2);
        assert_eq!(lanes.len(), 4, "lane set is grow-only");
        assert_eq!(ws.fresh_allocs, frozen);
        ws.put_i16_lanes("l", lanes);
        // a wider launch grows it with fresh empty lanes
        let lanes = ws.take_i16_lanes("l", 6);
        assert_eq!(lanes.len(), 6);
        assert!(lanes[..4].iter().all(|l| l.capacity() >= 100));
        ws.put_i16_lanes("l", lanes);
    }

    #[test]
    fn keyed_take_put_reuses_capacity() {
        let mut ws = Workspace::new();
        let v = ws.take_f32("a", 100);
        assert_eq!(v.len(), 100);
        assert_eq!(ws.fresh_allocs, 1);
        ws.put_f32("a", v);
        let v = ws.take_f32("a", 64);
        assert_eq!(v.len(), 64);
        assert_eq!(ws.fresh_allocs, 1, "shrinking take must reuse");
        assert_eq!(ws.reuses, 1);
        ws.put_f32("a", v);
    }

    #[test]
    fn grow_only_realloc_counted_once() {
        let mut ws = Workspace::new();
        let v = ws.take_f32("a", 10);
        ws.put_f32("a", v);
        let v = ws.take_f32("a", 1000);
        assert_eq!(v.len(), 1000);
        assert_eq!(ws.fresh_allocs, 2);
        ws.put_f32("a", v);
        let v = ws.take_f32("a", 1000);
        assert_eq!(ws.fresh_allocs, 2, "second large take must reuse");
        ws.put_f32("a", v);
    }

    #[test]
    fn recycled_donor_serves_unknown_keys() {
        let mut ws = Workspace::new();
        let m = ws.take_matrix("producer", 8, 8);
        ws.recycle(m);
        let _ = ws.take_matrix("consumer", 8, 8);
        assert_eq!(ws.fresh_allocs, 1, "donor pool should serve the miss");
        assert_eq!(ws.reuses, 1);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_donor() {
        let mut ws = Workspace::new();
        let big = ws.take_f32("b", 1000);
        let small = ws.take_f32("s", 10);
        ws.recycle_f32(big);
        ws.recycle_f32(small);
        let v = ws.take_f32("x", 10);
        assert!(v.capacity() < 1000, "should pick the small donor");
        ws.recycle_f32(v);
    }

    #[test]
    fn i8_matrix_roundtrip() {
        let mut ws = Workspace::new();
        let m = ws.take_i8_matrix("q", 4, 4);
        assert_eq!((m.rows(), m.cols()), (4, 4));
        ws.put_i8_matrix("q", m);
        let _ = ws.take_i8_matrix("q", 4, 4);
        assert_eq!(ws.fresh_allocs, 1);
    }

    #[test]
    fn donor_pool_is_bounded() {
        let mut ws = Workspace::new();
        for _ in 0..(MAX_DONORS * 3) {
            ws.recycle(Matrix::zeros(4, 4));
        }
        assert!(ws.pooled() <= MAX_DONORS, "donor pool grew past the cap");
        // keyed entries are unaffected by the cap
        let v = ws.take_f32("keyed", 8);
        ws.put_f32("keyed", v);
        assert!(ws.pooled() <= MAX_DONORS + 1);
    }

    #[test]
    fn idx_take_is_cleared() {
        let mut ws = Workspace::new();
        let mut v = ws.take_idx("i");
        v.extend([1usize, 2, 3]);
        ws.put_idx("i", v);
        let v = ws.take_idx("i");
        assert!(v.is_empty());
        ws.put_idx("i", v);
    }

    #[test]
    fn steady_state_is_alloc_free() {
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let a = ws.take_matrix("a", 16, 16);
            let b = ws.take_i8_matrix("b", 16, 16);
            ws.put_matrix("a", a);
            ws.put_i8_matrix("b", b);
        }
        let frozen = ws.fresh_allocs;
        for _ in 0..10 {
            let a = ws.take_matrix("a", 16, 16);
            let b = ws.take_i8_matrix("b", 16, 16);
            ws.put_matrix("a", a);
            ws.put_i8_matrix("b", b);
        }
        assert_eq!(ws.fresh_allocs, frozen);
    }

    #[test]
    fn slot_take_put_is_string_free_and_reuses() {
        let mut ws = Workspace::new();
        let key = ws.bind_f32("slot.a", 64);
        let qkey = ws.bind_i8("slot.q", 16);
        let keyed = ws.keyed_takes;
        // warm take: served from the pre-sized bind, no string lookup
        let v = ws.take_slot_f32(key, 64);
        assert_eq!(v.len(), 64);
        ws.put_slot_f32(key, v);
        let q = ws.take_slot_i8_matrix(qkey, 4, 4);
        ws.put_slot_i8_matrix(qkey, q);
        assert_eq!(ws.keyed_takes, keyed, "slot takes must not hit the string tier");
        let frozen = ws.fresh_allocs;
        for _ in 0..5 {
            let v = ws.take_slot_f32(key, 64);
            ws.put_slot_f32(key, v);
        }
        assert_eq!(ws.fresh_allocs, frozen, "steady slot takes must reuse");
        // growth beyond the bound capacity is counted once, then sticks
        let v = ws.take_slot_f32(key, 256);
        ws.put_slot_f32(key, v);
        assert_eq!(ws.fresh_allocs, frozen + 1);
        let v = ws.take_slot_f32(key, 256);
        ws.put_slot_f32(key, v);
        assert_eq!(ws.fresh_allocs, frozen + 1);
    }

    #[test]
    fn slot_lanes_are_grow_only() {
        let mut ws = Workspace::new();
        let key = ws.bind_f32_lanes("slot.lanes", 2, 8);
        let mut lanes = ws.take_slot_f32_lanes(key, 2);
        assert_eq!(lanes.len(), 2);
        for l in &mut lanes {
            l.resize(50, 0.0);
        }
        ws.put_slot_f32_lanes(key, lanes);
        let lanes = ws.take_slot_f32_lanes(key, 1);
        assert_eq!(lanes.len(), 2, "lane slot is grow-only");
        ws.put_slot_f32_lanes(key, lanes);
        let lanes = ws.take_slot_f32_lanes(key, 4);
        assert_eq!(lanes.len(), 4);
        assert!(lanes[..2].iter().all(|l| l.capacity() >= 50));
        ws.put_slot_f32_lanes(key, lanes);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "claimed while already taken")]
    fn double_slot_claim_is_detected() {
        let mut ws = Workspace::new();
        let key = ws.bind_f32("slot.dup", 4);
        let _a = ws.take_slot_f32(key, 4);
        // a second claim without a put — two plans sharing one slot
        let _b = ws.take_slot_f32(key, 4);
    }

    #[test]
    fn plan_table_roundtrip() {
        let mut ws = Workspace::new();
        assert!(ws.take_plan(7).is_none());
        ws.put_plan(7, Box::new(42usize));
        let p = ws.take_plan(7).expect("stored plan");
        assert_eq!(*p.downcast::<usize>().unwrap(), 42);
        assert!(ws.take_plan(7).is_none(), "take removes the plan");
    }

    #[test]
    fn donor_take_is_string_free() {
        let mut ws = Workspace::new();
        ws.recycle(Matrix::zeros(6, 6));
        let keyed = ws.keyed_takes;
        let m = ws.take_donor_matrix(6, 6);
        assert_eq!(ws.keyed_takes, keyed);
        assert_eq!(ws.reuses, 1);
        ws.recycle(m);
    }
}
