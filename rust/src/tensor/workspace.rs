//! Reusable scratch arena for the execution hot path.
//!
//! Every matmul / quantize / dequantize on the fine-tuning hot path needs
//! transient buffers. Allocating them per call is what the §Perf profile
//! shows as steady-state churn; the [`Workspace`] keeps them alive across
//! steps instead:
//!
//! * buffers are **keyed** by a `&'static str` so each call site gets a
//!   stable buffer back (`take_*` removes it from the arena, `put_*`
//!   returns it — plain moves, no RefCell, no borrow gymnastics);
//! * buffers are **grow-only**: a take that needs more capacity than the
//!   pooled buffer reallocates once, after which the larger buffer stays;
//! * outputs handed to a caller come back via [`Workspace::recycle`] into a
//!   shared donor pool that keyed takes fall back on (best capacity fit),
//!   so a consumer never needs to know the producer's key.
//!
//! After a warm-up step with fixed shapes, every take is served from the
//! arena: the hot path performs **zero heap allocations** at steady state
//! (`fresh_allocs` stops moving — asserted by `tests/zero_alloc.rs` with a
//! counting global allocator).

use super::{I8Matrix, Matrix};

/// Key under which [`Workspace::recycle`] parks donated buffers.
const RECYCLED: &str = "__recycled";

/// Donor-pool saturation bound. The transformer layers donate more buffers
/// per step than keyed takes consume (LayerNorm/injection/attention outputs
/// are recycled too), so an uncapped pool would grow without bound across a
/// long run. Beyond this many parked donors, further donations are simply
/// dropped — takes still find a donor (the working set is far smaller than
/// the cap), so the steady-state zero-allocation property is unaffected.
const MAX_DONORS: usize = 64;

/// Keyed, grow-only scratch arena. See the module docs.
#[derive(Debug, Default)]
pub struct Workspace {
    f32s: Vec<(&'static str, Vec<f32>)>,
    i8s: Vec<(&'static str, Vec<i8>)>,
    i16s: Vec<(&'static str, Vec<i16>)>,
    i32s: Vec<(&'static str, Vec<i32>)>,
    idxs: Vec<(&'static str, Vec<usize>)>,
    /// Per-thread scratch **lanes** for the sharded kernels: a pooled
    /// `Vec<Vec<T>>` with one buffer per shard, so parallel shards stay
    /// zero-alloc without sharing mutable scratch. Lane sets only ever grow
    /// (a narrower take hands back the wider set), so warmed inner buffers
    /// survive shard-count fluctuations.
    i16_lanes: Vec<(&'static str, Vec<Vec<i16>>)>,
    i32_lanes: Vec<(&'static str, Vec<Vec<i32>>)>,
    /// f32 lane sets: per-shard score scratch for the cached-attention
    /// kernel and the K/V cache's per-layer backing buffers (see
    /// `infer::KvCache`), pooled so caches are reused across requests.
    f32_lanes: Vec<(&'static str, Vec<Vec<f32>>)>,
    /// Buffers that had to be freshly allocated (or regrown). Stops
    /// increasing once the arena is warm — the zero-alloc invariant.
    pub fresh_allocs: u64,
    /// Takes served entirely from pooled capacity.
    pub reuses: u64,
}

/// Take a buffer from `pool`: exact key match first, then the best-fitting
/// donor from the recycled pool, else a fresh allocation. The returned
/// buffer has length `len` and **unspecified contents** — callers that
/// accumulate must `fill` it themselves.
fn take_from<T: Clone + Default>(
    pool: &mut Vec<(&'static str, Vec<T>)>,
    fresh: &mut u64,
    reuses: &mut u64,
    key: &'static str,
    len: usize,
) -> Vec<T> {
    let pos = pool.iter().position(|(k, _)| *k == key).or_else(|| {
        // Best-fit donor: smallest recycled buffer whose capacity suffices,
        // else the largest recycled one (it will grow once and then stick).
        let mut best_fit: Option<usize> = None;
        let mut largest: Option<usize> = None;
        for (i, (k, v)) in pool.iter().enumerate() {
            if *k != RECYCLED {
                continue;
            }
            let cap = v.capacity();
            if cap >= len && best_fit.map_or(true, |b| cap < pool[b].1.capacity()) {
                best_fit = Some(i);
            }
            if largest.map_or(true, |l| cap > pool[l].1.capacity()) {
                largest = Some(i);
            }
        }
        best_fit.or(largest)
    });
    match pos {
        Some(i) => {
            let (_, mut v) = pool.swap_remove(i);
            if v.capacity() >= len {
                *reuses += 1;
            } else {
                *fresh += 1;
            }
            v.resize(len, T::default());
            v
        }
        None => {
            *fresh += 1;
            vec![T::default(); len]
        }
    }
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// f32 scratch of length `len`, contents unspecified.
    pub fn take_f32(&mut self, key: &'static str, len: usize) -> Vec<f32> {
        take_from(&mut self.f32s, &mut self.fresh_allocs, &mut self.reuses, key, len)
    }

    pub fn put_f32(&mut self, key: &'static str, v: Vec<f32>) {
        self.f32s.push((key, v));
    }

    pub fn take_i8(&mut self, key: &'static str, len: usize) -> Vec<i8> {
        take_from(&mut self.i8s, &mut self.fresh_allocs, &mut self.reuses, key, len)
    }

    pub fn put_i8(&mut self, key: &'static str, v: Vec<i8>) {
        self.i8s.push((key, v));
    }

    pub fn take_i16(&mut self, key: &'static str, len: usize) -> Vec<i16> {
        take_from(&mut self.i16s, &mut self.fresh_allocs, &mut self.reuses, key, len)
    }

    pub fn put_i16(&mut self, key: &'static str, v: Vec<i16>) {
        self.i16s.push((key, v));
    }

    pub fn take_i32(&mut self, key: &'static str, len: usize) -> Vec<i32> {
        take_from(&mut self.i32s, &mut self.fresh_allocs, &mut self.reuses, key, len)
    }

    pub fn put_i32(&mut self, key: &'static str, v: Vec<i32>) {
        self.i32s.push((key, v));
    }

    /// At least `n` i16 scratch lanes (one per shard of a sharded kernel),
    /// each with unspecified contents and retained capacity. Lane sets are
    /// **grow-only**: a take after a wider launch hands back the wider set
    /// (callers use the first `n`), so shard-count fluctuations never drop
    /// warmed lane buffers.
    pub fn take_i16_lanes(&mut self, key: &'static str, n: usize) -> Vec<Vec<i16>> {
        take_lanes_from(&mut self.i16_lanes, &mut self.fresh_allocs, &mut self.reuses, key, n)
    }

    pub fn put_i16_lanes(&mut self, key: &'static str, v: Vec<Vec<i16>>) {
        self.i16_lanes.push((key, v));
    }

    /// At least `n` i32 scratch lanes — see [`Workspace::take_i16_lanes`].
    pub fn take_i32_lanes(&mut self, key: &'static str, n: usize) -> Vec<Vec<i32>> {
        take_lanes_from(&mut self.i32_lanes, &mut self.fresh_allocs, &mut self.reuses, key, n)
    }

    pub fn put_i32_lanes(&mut self, key: &'static str, v: Vec<Vec<i32>>) {
        self.i32_lanes.push((key, v));
    }

    /// At least `n` f32 scratch lanes — see [`Workspace::take_i16_lanes`].
    pub fn take_f32_lanes(&mut self, key: &'static str, n: usize) -> Vec<Vec<f32>> {
        take_lanes_from(&mut self.f32_lanes, &mut self.fresh_allocs, &mut self.reuses, key, n)
    }

    pub fn put_f32_lanes(&mut self, key: &'static str, v: Vec<Vec<f32>>) {
        self.f32_lanes.push((key, v));
    }

    /// Cleared index scratch (length 0; push into it).
    pub fn take_idx(&mut self, key: &'static str) -> Vec<usize> {
        let mut v = take_from(&mut self.idxs, &mut self.fresh_allocs, &mut self.reuses, key, 0);
        v.clear();
        v
    }

    pub fn put_idx(&mut self, key: &'static str, v: Vec<usize>) {
        self.idxs.push((key, v));
    }

    /// `rows × cols` matrix, contents unspecified.
    pub fn take_matrix(&mut self, key: &'static str, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_f32(key, rows * cols))
    }

    /// `rows × cols` matrix, zero-filled.
    pub fn take_matrix_zeroed(&mut self, key: &'static str, rows: usize, cols: usize) -> Matrix {
        let mut m = self.take_matrix(key, rows, cols);
        m.data_mut().fill(0.0);
        m
    }

    pub fn put_matrix(&mut self, key: &'static str, m: Matrix) {
        self.put_f32(key, m.into_vec());
    }

    pub fn take_i8_matrix(&mut self, key: &'static str, rows: usize, cols: usize) -> I8Matrix {
        I8Matrix::from_vec(rows, cols, self.take_i8(key, rows * cols))
    }

    pub fn put_i8_matrix(&mut self, key: &'static str, m: I8Matrix) {
        self.put_i8(key, m.into_vec());
    }

    /// Donate a matrix whose producer key the caller does not know; keyed
    /// takes fall back on these donors. Donations beyond [`MAX_DONORS`]
    /// parked entries are dropped (see the constant's docs).
    pub fn recycle(&mut self, m: Matrix) {
        self.recycle_f32(m.into_vec());
    }

    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        if self.f32s.iter().filter(|(k, _)| *k == RECYCLED).count() < MAX_DONORS {
            self.put_f32(RECYCLED, v);
        }
    }

    /// Number of buffers currently parked in the arena (all types).
    pub fn pooled(&self) -> usize {
        self.f32s.len()
            + self.i8s.len()
            + self.i16s.len()
            + self.i32s.len()
            + self.idxs.len()
            + self.i16_lanes.len()
            + self.i32_lanes.len()
            + self.f32_lanes.len()
    }

    /// Total bytes of pooled capacity (diagnostics).
    pub fn pooled_bytes(&self) -> usize {
        self.f32s.iter().map(|(_, v)| v.capacity() * 4).sum::<usize>()
            + self.i8s.iter().map(|(_, v)| v.capacity()).sum::<usize>()
            + self.i16s.iter().map(|(_, v)| v.capacity() * 2).sum::<usize>()
            + self.i32s.iter().map(|(_, v)| v.capacity() * 4).sum::<usize>()
            + self.idxs.iter().map(|(_, v)| v.capacity() * 8).sum::<usize>()
            + lane_bytes(&self.i16_lanes, 2)
            + lane_bytes(&self.i32_lanes, 4)
            + lane_bytes(&self.f32_lanes, 4)
    }
}

/// Take a lane set (`Vec<Vec<T>>`) of at least `n` lanes from `pool`:
/// exact key match reused (grown with empty lanes if the launch got wider,
/// **never shrunk** — truncating would free warmed inner buffers whenever
/// the shard count fluctuates), else a fresh set of `n` empty lanes.
fn take_lanes_from<T>(
    pool: &mut Vec<(&'static str, Vec<Vec<T>>)>,
    fresh: &mut u64,
    reuses: &mut u64,
    key: &'static str,
    n: usize,
) -> Vec<Vec<T>> {
    match pool.iter().position(|(k, _)| *k == key) {
        Some(i) => {
            let (_, mut v) = pool.swap_remove(i);
            if v.len() < n {
                *fresh += 1;
                v.resize_with(n, Vec::new);
            } else {
                *reuses += 1;
            }
            v
        }
        None => {
            *fresh += 1;
            let mut v = Vec::with_capacity(n);
            v.resize_with(n, Vec::new);
            v
        }
    }
}

/// Pooled capacity of a lane pool (inner buffers only; the outer vecs are
/// a few pointers each).
fn lane_bytes<T>(pool: &[(&'static str, Vec<Vec<T>>)], elem: usize) -> usize {
    pool.iter()
        .map(|(_, lanes)| lanes.iter().map(|l| l.capacity() * elem).sum::<usize>())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_take_put_reuses_outer_and_inner_capacity() {
        let mut ws = Workspace::new();
        let mut lanes = ws.take_i16_lanes("l", 4);
        assert_eq!(lanes.len(), 4);
        for l in &mut lanes {
            l.resize(100, 0); // simulate kernel growing its lane
        }
        ws.put_i16_lanes("l", lanes);
        let frozen = ws.fresh_allocs;
        let lanes = ws.take_i16_lanes("l", 4);
        assert_eq!(ws.fresh_allocs, frozen, "steady lane take must reuse");
        assert!(lanes.iter().all(|l| l.capacity() >= 100));
        ws.put_i16_lanes("l", lanes);
        // a narrower launch must NOT shrink the set (warmed lanes survive)
        let lanes = ws.take_i16_lanes("l", 2);
        assert_eq!(lanes.len(), 4, "lane set is grow-only");
        assert_eq!(ws.fresh_allocs, frozen);
        ws.put_i16_lanes("l", lanes);
        // a wider launch grows it with fresh empty lanes
        let lanes = ws.take_i16_lanes("l", 6);
        assert_eq!(lanes.len(), 6);
        assert!(lanes[..4].iter().all(|l| l.capacity() >= 100));
        ws.put_i16_lanes("l", lanes);
    }

    #[test]
    fn keyed_take_put_reuses_capacity() {
        let mut ws = Workspace::new();
        let v = ws.take_f32("a", 100);
        assert_eq!(v.len(), 100);
        assert_eq!(ws.fresh_allocs, 1);
        ws.put_f32("a", v);
        let v = ws.take_f32("a", 64);
        assert_eq!(v.len(), 64);
        assert_eq!(ws.fresh_allocs, 1, "shrinking take must reuse");
        assert_eq!(ws.reuses, 1);
        ws.put_f32("a", v);
    }

    #[test]
    fn grow_only_realloc_counted_once() {
        let mut ws = Workspace::new();
        let v = ws.take_f32("a", 10);
        ws.put_f32("a", v);
        let v = ws.take_f32("a", 1000);
        assert_eq!(v.len(), 1000);
        assert_eq!(ws.fresh_allocs, 2);
        ws.put_f32("a", v);
        let v = ws.take_f32("a", 1000);
        assert_eq!(ws.fresh_allocs, 2, "second large take must reuse");
        ws.put_f32("a", v);
    }

    #[test]
    fn recycled_donor_serves_unknown_keys() {
        let mut ws = Workspace::new();
        let m = ws.take_matrix("producer", 8, 8);
        ws.recycle(m);
        let _ = ws.take_matrix("consumer", 8, 8);
        assert_eq!(ws.fresh_allocs, 1, "donor pool should serve the miss");
        assert_eq!(ws.reuses, 1);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_donor() {
        let mut ws = Workspace::new();
        let big = ws.take_f32("b", 1000);
        let small = ws.take_f32("s", 10);
        ws.recycle_f32(big);
        ws.recycle_f32(small);
        let v = ws.take_f32("x", 10);
        assert!(v.capacity() < 1000, "should pick the small donor");
        ws.recycle_f32(v);
    }

    #[test]
    fn i8_matrix_roundtrip() {
        let mut ws = Workspace::new();
        let m = ws.take_i8_matrix("q", 4, 4);
        assert_eq!((m.rows(), m.cols()), (4, 4));
        ws.put_i8_matrix("q", m);
        let _ = ws.take_i8_matrix("q", 4, 4);
        assert_eq!(ws.fresh_allocs, 1);
    }

    #[test]
    fn donor_pool_is_bounded() {
        let mut ws = Workspace::new();
        for _ in 0..(MAX_DONORS * 3) {
            ws.recycle(Matrix::zeros(4, 4));
        }
        assert!(ws.pooled() <= MAX_DONORS, "donor pool grew past the cap");
        // keyed entries are unaffected by the cap
        let v = ws.take_f32("keyed", 8);
        ws.put_f32("keyed", v);
        assert!(ws.pooled() <= MAX_DONORS + 1);
    }

    #[test]
    fn idx_take_is_cleared() {
        let mut ws = Workspace::new();
        let mut v = ws.take_idx("i");
        v.extend([1usize, 2, 3]);
        ws.put_idx("i", v);
        let v = ws.take_idx("i");
        assert!(v.is_empty());
        ws.put_idx("i", v);
    }

    #[test]
    fn steady_state_is_alloc_free() {
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let a = ws.take_matrix("a", 16, 16);
            let b = ws.take_i8_matrix("b", 16, 16);
            ws.put_matrix("a", a);
            ws.put_i8_matrix("b", b);
        }
        let frozen = ws.fresh_allocs;
        for _ in 0..10 {
            let a = ws.take_matrix("a", 16, 16);
            let b = ws.take_i8_matrix("b", 16, 16);
            ws.put_matrix("a", a);
            ws.put_i8_matrix("b", b);
        }
        assert_eq!(ws.fresh_allocs, frozen);
    }
}
