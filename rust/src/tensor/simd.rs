//! Register-tiled int8→i32 panel microkernels with runtime ISA dispatch.
//!
//! The packed fused-dequant matmul (`i8mat`) funnels every forward of every
//! method through one inner loop. This module holds that loop's
//! microkernels: each computes the i32 dot products of one (or a tile of
//! [`MR`]) i16-widened activation rows against one **column panel** of the
//! panel-blocked [`PackedWeights`](super::PackedWeights) layout.
//!
//! # Panel layout
//!
//! Weights are repacked **once** at quantization time into panels of
//! [`NR`] = 8 output columns. Within a panel, elements are stored in
//! *k-pair-interleaved* order (k is padded to even, `kpad`, with zeros):
//!
//! ```text
//! panel p (columns j0 = 8p .. 8p+7), one 16-element group per k-pair kp:
//!   [ w(2kp, j0) w(2kp+1, j0) | w(2kp, j0+1) w(2kp+1, j0+1) | … | w(2kp, j0+7) w(2kp+1, j0+7) ]
//! ```
//!
//! One group is exactly one 256-bit AVX2 lane: `_mm256_madd_epi16` against a
//! broadcast activation pair `[a(2kp), a(2kp+1)]×8` yields the 8 per-column
//! partial dots in one instruction. The same groups feed NEON (`vmlal_s16`
//! on 4-column halves, one pairwise fold at the end) and the scalar
//! reference (an 8-accumulator register tile) — the layout is
//! ISA-independent, so the active ISA can change at runtime without
//! repacking.
//!
//! # Bit-identity contract
//!
//! Every kernel here produces the **same i32 accumulators** as the scalar
//! reference: i16×i16 products (|a|,|b| ≤ 128, so each ≤ 16384) accumulated
//! in i32 never overflow for any realistic k, and integer addition is
//! associative — reassociating across SIMD lanes or tile shapes cannot
//! change the result. The f32 work (`rs * acc * col_scale[j]`) stays a
//! per-element scalar epilogue in `i8mat`, so *every* ISA, tile remainder,
//! and thread count is bitwise identical to the legacy serial loop. Pinned
//! by `tests/simd_parity.rs`.
//!
//! # Dispatch
//!
//! The active ISA is detected once ([`detect_best`]) on first use:
//! AVX2 on x86_64 (runtime `is_x86_feature_detected!`), NEON on aarch64
//! (architecturally mandatory), scalar elsewhere. `QUAFF_ISA`
//! (`scalar`/`avx2`/`neon`) overrides detection — unknown or unavailable
//! values panic loudly rather than silently falling back — and
//! [`force`] switches in-process (parity tests, A/B benches).

use std::sync::atomic::{AtomicU8, Ordering};

/// Columns per packed panel (output-channel tile width).
pub const NR: usize = 8;

/// Activation rows per microkernel tile.
pub const MR: usize = 4;

/// Length of the row-staging scratch the packed matmul needs for a given
/// reduction depth `k`: [`MR`] rows of `k` rounded up to even.
pub fn packed_a16_len(k: usize) -> usize {
    MR * (k + (k & 1))
}

/// Instruction-set architecture of the packed-matmul microkernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Isa {
    /// Portable reference (8-accumulator register tile, auto-vectorizable).
    Scalar = 1,
    /// x86_64 AVX2 (`_mm256_madd_epi16`).
    Avx2 = 2,
    /// aarch64 NEON (`vmlal_s16` + pairwise fold).
    Neon = 3,
}

impl Isa {
    /// Stable lowercase tag — the `QUAFF_ISA` vocabulary, also surfaced in
    /// the runtime backend name and the bench metadata.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

/// `0` = not yet initialized; otherwise an `Isa` discriminant.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn decode(v: u8) -> Isa {
    match v {
        2 => Isa::Avx2,
        3 => Isa::Neon,
        _ => Isa::Scalar,
    }
}

/// Is `isa` usable on this machine?
pub fn available(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => true,
        #[allow(unreachable_patterns)] // covers the foreign-arch variants
        _ => false,
    }
}

/// Best ISA this machine supports (ignores `QUAFF_ISA`).
#[allow(unreachable_code)] // the aarch64 arm returns early
pub fn detect_best() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Isa::Neon;
    }
    Isa::Scalar
}

fn parse(tag: &str) -> Option<Isa> {
    match tag.to_ascii_lowercase().as_str() {
        "scalar" => Some(Isa::Scalar),
        "avx2" => Some(Isa::Avx2),
        "neon" => Some(Isa::Neon),
        _ => None,
    }
}

fn init_from_env() -> Isa {
    match std::env::var("QUAFF_ISA") {
        Ok(tag) if !tag.trim().is_empty() => {
            let tag = tag.trim();
            let isa = parse(tag).unwrap_or_else(|| {
                panic!("QUAFF_ISA='{tag}' is not one of scalar/avx2/neon")
            });
            assert!(
                available(isa),
                "QUAFF_ISA='{tag}' requested but {} is not available on this machine",
                isa.name()
            );
            isa
        }
        _ => detect_best(),
    }
}

/// The active ISA: `QUAFF_ISA` if set, otherwise [`detect_best`], resolved
/// once on first call and cached.
pub fn active() -> Isa {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => {
            let isa = init_from_env();
            ACTIVE.store(isa as u8, Ordering::Relaxed);
            isa
        }
        v => decode(v),
    }
}

/// Force the active ISA in-process (parity tests, A/B benches). Returns the
/// previously active ISA so callers can restore it.
///
/// Panics if `isa` is not [`available`] on this machine. Not meant to be
/// raced against in-flight matmuls — flip it between launches.
pub fn force(isa: Isa) -> Isa {
    assert!(available(isa), "cannot force unavailable ISA {}", isa.name());
    let prev = active();
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    prev
}

/// Scalar reference microkernel: dots of one widened activation row
/// (`a.len()` = kpad, even) against one panel (`panel.len()` = kpad·NR).
/// An 8-wide accumulator register tile reading the panel sequentially —
/// every other kernel must reproduce these exact i32 values.
pub fn panel_dot_scalar(a: &[i16], panel: &[i16], acc: &mut [i32; NR]) {
    *acc = [0; NR];
    for (kp, grp) in panel.chunks_exact(2 * NR).enumerate() {
        let a0 = a[2 * kp] as i32;
        let a1 = a[2 * kp + 1] as i32;
        for (jj, d) in acc.iter_mut().enumerate() {
            *d += a0 * grp[2 * jj] as i32 + a1 * grp[2 * jj + 1] as i32;
        }
    }
}

/// The broadcast activation k-pair `[a(2kp), a(2kp+1)]` as one i32 word
/// (little-endian lane order: low half = even-k element).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn pair_word(a: &[i16], kp: usize) -> i32 {
    ((a[2 * kp] as u16 as u32) | ((a[2 * kp + 1] as u16 as u32) << 16)) as i32
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{pair_word, NR};
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 is available (`super::available`).
    /// `a.len()` must be even and `panel.len() == a.len() * NR`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn panel_dot(a: &[i16], panel: &[i16], acc: &mut [i32; NR]) {
        let bp = panel.as_ptr();
        let mut v = _mm256_setzero_si256();
        for kp in 0..a.len() / 2 {
            let av = _mm256_set1_epi32(pair_word(a, kp));
            let bv = _mm256_loadu_si256(bp.add(kp * 2 * NR) as *const __m256i);
            v = _mm256_add_epi32(v, _mm256_madd_epi16(av, bv));
        }
        _mm256_storeu_si256(acc.as_mut_ptr() as *mut __m256i, v);
    }

    /// Four activation rows (stride `kpad` in `a`) against one panel,
    /// sharing each panel-group load across the row tile.
    ///
    /// # Safety
    /// As [`panel_dot`]; additionally `a.len() >= 4 * kpad`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn panel_dot4(a: &[i16], kpad: usize, panel: &[i16], acc: &mut [[i32; NR]; 4]) {
        let bp = panel.as_ptr();
        let r0 = &a[..kpad];
        let r1 = &a[kpad..2 * kpad];
        let r2 = &a[2 * kpad..3 * kpad];
        let r3 = &a[3 * kpad..4 * kpad];
        let mut v0 = _mm256_setzero_si256();
        let mut v1 = _mm256_setzero_si256();
        let mut v2 = _mm256_setzero_si256();
        let mut v3 = _mm256_setzero_si256();
        for kp in 0..kpad / 2 {
            let bv = _mm256_loadu_si256(bp.add(kp * 2 * NR) as *const __m256i);
            v0 = _mm256_add_epi32(v0, _mm256_madd_epi16(_mm256_set1_epi32(pair_word(r0, kp)), bv));
            v1 = _mm256_add_epi32(v1, _mm256_madd_epi16(_mm256_set1_epi32(pair_word(r1, kp)), bv));
            v2 = _mm256_add_epi32(v2, _mm256_madd_epi16(_mm256_set1_epi32(pair_word(r2, kp)), bv));
            v3 = _mm256_add_epi32(v3, _mm256_madd_epi16(_mm256_set1_epi32(pair_word(r3, kp)), bv));
        }
        _mm256_storeu_si256(acc[0].as_mut_ptr() as *mut __m256i, v0);
        _mm256_storeu_si256(acc[1].as_mut_ptr() as *mut __m256i, v1);
        _mm256_storeu_si256(acc[2].as_mut_ptr() as *mut __m256i, v2);
        _mm256_storeu_si256(acc[3].as_mut_ptr() as *mut __m256i, v3);
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{pair_word, NR};
    use std::arch::aarch64::*;

    /// # Safety
    /// NEON is architecturally mandatory on aarch64; `a.len()` must be even
    /// and `panel.len() == a.len() * NR` (raw pointer loads).
    pub unsafe fn panel_dot(a: &[i16], panel: &[i16], acc: &mut [i32; NR]) {
        let bp = panel.as_ptr();
        // Four widening accumulators keep the a0·w(2kp,·) / a1·w(2kp+1,·)
        // partials in interleaved lane position; one pairwise fold at the
        // end turns them into the 8 column dots.
        let mut acc01 = vdupq_n_s32(0);
        let mut acc23 = vdupq_n_s32(0);
        let mut acc45 = vdupq_n_s32(0);
        let mut acc67 = vdupq_n_s32(0);
        for kp in 0..a.len() / 2 {
            let av = vreinterpret_s16_s32(vdup_n_s32(pair_word(a, kp)));
            let b0 = vld1q_s16(bp.add(kp * 2 * NR));
            let b1 = vld1q_s16(bp.add(kp * 2 * NR + 8));
            acc01 = vmlal_s16(acc01, vget_low_s16(b0), av);
            acc23 = vmlal_s16(acc23, vget_high_s16(b0), av);
            acc45 = vmlal_s16(acc45, vget_low_s16(b1), av);
            acc67 = vmlal_s16(acc67, vget_high_s16(b1), av);
        }
        vst1q_s32(acc.as_mut_ptr(), vpaddq_s32(acc01, acc23));
        vst1q_s32(acc.as_mut_ptr().add(4), vpaddq_s32(acc45, acc67));
    }
}

/// ISA-dispatched single-row microkernel. `a.len()` must be even (the kpad
/// contract) and `panel.len() == a.len() * NR`.
#[inline]
pub(crate) fn panel_dot(isa: Isa, a: &[i16], panel: &[i16], acc: &mut [i32; NR]) {
    debug_assert_eq!(a.len() % 2, 0);
    debug_assert_eq!(panel.len(), a.len() * NR);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::panel_dot(a, panel, acc) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::panel_dot(a, panel, acc) },
        _ => panel_dot_scalar(a, panel, acc),
    }
}

/// ISA-dispatched row-tile microkernel: `mr` (≤ [`MR`]) staged rows of
/// stride `kpad` in `a` against one panel. Only `acc[..mr]` is written.
#[inline]
pub(crate) fn panel_dot_tile(
    isa: Isa,
    a: &[i16],
    kpad: usize,
    mr: usize,
    panel: &[i16],
    acc: &mut [[i32; NR]; MR],
) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 && mr == MR {
        unsafe { x86::panel_dot4(a, kpad, panel, acc) };
        return;
    }
    for (r, acc_row) in acc.iter_mut().enumerate().take(mr) {
        panel_dot(isa, &a[r * kpad..(r + 1) * kpad], panel, acc_row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_roundtrips_through_parse() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert_eq!(parse(isa.name()), Some(isa));
        }
        assert_eq!(parse("sse9"), None);
    }

    #[test]
    fn detect_best_is_available_and_active_is_stable() {
        assert!(available(detect_best()));
        assert!(available(Isa::Scalar));
        let a = active();
        assert_eq!(active(), a, "active ISA must be cached");
    }

    #[test]
    fn scalar_kernel_matches_naive_dot() {
        // 3 k-pairs, saturated corners included
        let a: Vec<i16> = vec![127, -128, 5, 0, -127, 127];
        let mut panel = vec![0i16; a.len() * NR];
        for kk in 0..a.len() {
            for jj in 0..NR {
                panel[(kk / 2) * 2 * NR + jj * 2 + (kk & 1)] = ((kk * NR + jj) as i16) - 11;
            }
        }
        let mut acc = [7i32; NR];
        panel_dot_scalar(&a, &panel, &mut acc);
        for (jj, &got) in acc.iter().enumerate() {
            let want: i32 = (0..a.len())
                .map(|kk| a[kk] as i32 * ((kk * NR + jj) as i32 - 11))
                .sum();
            assert_eq!(got, want, "column {jj}");
        }
    }
}
