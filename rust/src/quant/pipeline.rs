//! Compiled per-layer execution plans with a fused
//! quantize→matmul→epilogue pipeline — the shared hot path every
//! [`QuantMethod`](crate::methods::QuantMethod) forward routes through
//! (DESIGN.md §7).
//!
//! Before this layer, every quantized linear ran scale/quantize, the int8
//! matmul, the i32→f32 dequant and the correction/adapter adds as separate
//! passes over memory, re-resolving each scratch buffer through string-keyed
//! [`Workspace`] lookups on every forward — and the whole shape was
//! hand-duplicated across six methods × the train and infer paths. The plan
//! layer replaces that with:
//!
//! * **[`QgemmPlan`]** — built **once** per layer per workspace: it binds
//!   every hot-loop buffer to a pre-resolved workspace slot (no string
//!   hashing on the hot path — `Workspace::keyed_takes` stays frozen) and
//!   pre-sizes them for the layer's shapes, so the steady state is
//!   allocation-free from the first plan-driven step. Plans live *in* the
//!   workspace (keyed by the owning layer's [`PlanId`]), because slots are
//!   workspace-local; a layer used with two arenas simply compiles one plan
//!   per arena.
//! * **Fused scale→quantize** ([`QgemmPlan::quantize`]) — the method's
//!   activation transform (Quaff's targeted momentum factors, SmoothQuant's
//!   static factors, LLM.int8's outlier masking, or identity) is applied
//!   per row *while* quantizing, in one read pass over `X`: no scaled-copy
//!   `X̂` matrix is ever materialized. Each shard stages one row in an L1-
//!   resident lane buffer, so the arithmetic — and therefore every bit of
//!   the output — is exactly the legacy copy-whole-matrix-then-quantize
//!   sequence (`tests/qgemm_parity.rs` is the referee).
//! * **Fused matmul epilogue** ([`QgemmPlan::matmul_write`]) — the packed
//!   int8 matmul dequantizes and **writes** the f32 output directly
//!   (`0.0 + Δ_x·acc·Δ_w`, bit-identical to the old zero-fill + accumulate
//!   contract while eliminating the `take_matrix_zeroed` pass). The matmul
//!   itself runs on the register-tiled, ISA-dispatched panel microkernels
//!   (`tensor::simd`: AVX2 / NEON / scalar, selected at runtime), so every
//!   method's forward inherits the SIMD path through this one choke point —
//!   with bit-identical output on every ISA. Method
//!   corrections (Quaff's `x̂·ŵ` term, LLM.int8's f32 slice) and the LoRA
//!   delta then accumulate into that same buffer, in the legacy order:
//!   main term → method correction → adapter delta. No bias term exists in
//!   this model family; a bias would be one more epilogue accumulation.
//!
//! The epilogue contract, precisely: `out = (0.0 + main) ⊕ correction ⊕
//! adapter-delta`, where `⊕` is in-place `+=` in that fixed order — the
//! same float-add sequence as the unfused pipeline, which is what keeps
//! the existing `thread_determinism` / `decode_parity` / `persist_resume`
//! suites passing unchanged on top of the fused path.

use super::{step_size, QuantizedWeights, QMAX};
use crate::tensor::pool::{self, shard_range, SplitMut};
use crate::tensor::{
    kernels, simd, I8Matrix, Matrix, Workspace, WsF32, WsF32Lanes, WsI16, WsI16Lanes, WsI32, WsI8,
    WsIdx,
};
use std::sync::atomic::{AtomicU64, Ordering};

/// Unique identity of one plan-owning layer (a `QuantMethod` instance).
/// Allocated at method construction; keys the compiled plan inside each
/// [`Workspace`] the layer runs against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanId(u64);

static NEXT_PLAN: AtomicU64 = AtomicU64::new(1);

impl PlanId {
    /// A process-unique plan id.
    pub fn fresh() -> PlanId {
        PlanId(NEXT_PLAN.fetch_add(1, Ordering::Relaxed))
    }
}

/// The activation transform fused into the quantization read pass.
/// Every variant reproduces the corresponding legacy pre-pass bit-for-bit,
/// applied per row instead of to a materialized copy of the whole matrix.
pub enum ScaleOp<'a> {
    /// No transform (Naive W8A8, Quaff with an empty outlier set).
    Identity,
    /// Divide the listed absolute channel columns by their factors —
    /// Quaff's targeted inverse scaling `X̂ = X` with `[X]_{:,O} / s_O`
    /// (`scaling::apply_targeted_inverse_scale`, row-local form).
    DivCols {
        /// Outlier channel indices.
        channels: &'a [usize],
        /// One factor per channel, aligned with `channels`.
        factors: &'a [f32],
    },
    /// Multiply every column by a precomputed reciprocal factor —
    /// SmoothQuant's full-axis `X̂ = X · s^{-1}` (`Matrix::scale_cols`).
    MulPerCol {
        /// `s^{-1}`, length `c_in`.
        inv: &'a [f32],
    },
    /// Zero the listed columns — LLM.int8's training-path outlier masking.
    ZeroCols {
        /// Detected outlier columns.
        cols: &'a [usize],
    },
    /// Zero entries with `|x| > sigma` — LLM.int8's row-local inference
    /// detection.
    ZeroAbsAbove {
        /// Detection threshold σ.
        sigma: f32,
    },
}

/// Apply `op` to one staged activation row (bit-identical to the legacy
/// whole-matrix pre-pass, restricted to this row).
fn apply_row(op: &ScaleOp<'_>, row: &mut [f32]) {
    match op {
        ScaleOp::Identity => {}
        ScaleOp::DivCols { channels, factors } => {
            for (k, &ch) in channels.iter().enumerate() {
                row[ch] /= factors[k];
            }
        }
        ScaleOp::MulPerCol { inv } => {
            for (v, &s) in row.iter_mut().zip(*inv) {
                *v *= s;
            }
        }
        ScaleOp::ZeroCols { cols } => {
            for &c in *cols {
                row[c] = 0.0;
            }
        }
        ScaleOp::ZeroAbsAbove { sigma } => {
            for v in row.iter_mut() {
                if v.abs() > *sigma {
                    *v = 0.0;
                }
            }
        }
    }
}

/// Quantize one (already scaled) row: symmetric RTN with the row's own Δ —
/// exactly the `ptok_rows` arithmetic in `quant`.
#[inline]
fn quantize_row(row: &[f32], dst: &mut [i8], delta: &mut f32) {
    let m = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let d = step_size(m);
    *delta = d;
    if d == 0.0 {
        dst.fill(0);
    } else {
        let inv = 1.0 / d;
        for (o, &v) in dst.iter_mut().zip(row) {
            *o = (v * inv).round().clamp(-QMAX, QMAX) as i8;
        }
    }
}

/// Row-range core of the fused scale→quantize pass: rows `r0..r1` of `x`
/// into the relative sub-slices `xi`/`deltas`, staging each row in `buf`
/// when a transform is active (identity reads `x` directly, like the
/// legacy standalone quantizer).
fn scale_quantize_rows(
    x: &Matrix,
    op: &ScaleOp<'_>,
    buf: &mut Vec<f32>,
    xi: &mut [i8],
    deltas: &mut [f32],
    r0: usize,
    r1: usize,
) {
    let cols = x.cols();
    if matches!(op, ScaleOp::Identity) {
        for i in r0..r1 {
            let dst = &mut xi[(i - r0) * cols..(i - r0 + 1) * cols];
            quantize_row(x.row(i), dst, &mut deltas[i - r0]);
        }
        return;
    }
    buf.resize(cols, 0.0);
    for i in r0..r1 {
        buf.copy_from_slice(x.row(i));
        apply_row(op, buf);
        let dst = &mut xi[(i - r0) * cols..(i - r0 + 1) * cols];
        quantize_row(buf, dst, &mut deltas[i - r0]);
    }
}

/// Number of general-purpose auxiliary f32 slots per plan (method
/// correction stages index these with local constants).
pub const AUX_F32_SLOTS: usize = 6;
/// Number of auxiliary i8 slots per plan.
pub const AUX_I8_SLOTS: usize = 2;

/// The fused scale→quantize product, checked out of the plan's slots:
/// per-token int8 activations plus their step sizes `Δ_X̂`. Hand it back
/// via [`QgemmPlan::release`] once the correction stages are done with it.
pub struct QuantizedAct {
    /// `X̂_int` (t × c_in).
    pub x_int: I8Matrix,
    /// Per-token step sizes, length t.
    pub dx: Vec<f32>,
}

/// A compiled execution plan for one quantized linear layer: every
/// hot-loop buffer pre-bound to a workspace slot, pre-sized for the
/// layer's shapes. Built once per layer per workspace ([`plan_for`]),
/// checked out for the duration of a forward, stored back afterwards
/// ([`store_plan`]).
pub struct QgemmPlan {
    cin: usize,
    cout: usize,
    /// Quantized-activation store (t × c_in).
    x_int: WsI8,
    /// Per-token step sizes Δ_X̂.
    dx: WsF32,
    /// Per-shard row-staging lanes for the fused scale→quantize pass.
    rows: WsF32Lanes,
    /// Serial widening scratch for the packed matmul (decode shapes).
    a16: WsI16,
    /// Per-shard widening lanes for the sharded packed matmul.
    a16_lanes: WsI16Lanes,
    /// General-purpose f32 slots for method correction stages (Quaff's
    /// `s_O`/`ŵ`/Δ_ŵ, LLM.int8's column maxima and f32 slice, …).
    pub aux_f32: [WsF32; AUX_F32_SLOTS],
    /// General-purpose i8 slots (Quaff's `ŵ_int` and gathered `x̂_int`).
    pub aux_i8: [WsI8; AUX_I8_SLOTS],
    /// i32 accumulator slot (the unpacked correction matmul's scratch row).
    pub aux_i32: WsI32,
    /// Index scratch slot (LLM.int8's detected-column list).
    pub aux_idx: WsIdx,
}

impl QgemmPlan {
    /// Compile a plan for a `c_in × c_out` layer, pre-sizing the slots for
    /// batches of `m_hint` token rows. This is the cold path: it allocates;
    /// everything after it runs on pre-resolved handles.
    pub fn build(ws: &mut Workspace, cin: usize, cout: usize, m_hint: usize) -> QgemmPlan {
        let lanes = pool::active_threads().max(1);
        QgemmPlan {
            cin,
            cout,
            x_int: ws.bind_i8("qgemm.xint", m_hint * cin),
            dx: ws.bind_f32("qgemm.dx", m_hint),
            rows: ws.bind_f32_lanes("qgemm.rows", lanes, cin),
            a16: ws.bind_i16("qgemm.a16", simd::packed_a16_len(cin)),
            a16_lanes: ws.bind_i16_lanes("qgemm.a16.lanes", lanes, simd::packed_a16_len(cin)),
            aux_f32: [
                ws.bind_f32("qgemm.aux_f32.0", 0),
                ws.bind_f32("qgemm.aux_f32.1", 0),
                ws.bind_f32("qgemm.aux_f32.2", 0),
                ws.bind_f32("qgemm.aux_f32.3", 0),
                ws.bind_f32("qgemm.aux_f32.4", 0),
                ws.bind_f32("qgemm.aux_f32.5", 0),
            ],
            aux_i8: [ws.bind_i8("qgemm.aux_i8.0", 0), ws.bind_i8("qgemm.aux_i8.1", 0)],
            aux_i32: ws.bind_i32("qgemm.acc", cout),
            aux_idx: ws.bind_idx("qgemm.idx"),
        }
    }

    /// Input-channel count the plan was compiled for.
    pub fn cin(&self) -> usize {
        self.cin
    }

    /// Output-channel count the plan was compiled for.
    pub fn cout(&self) -> usize {
        self.cout
    }

    /// Fused scale→quantize: apply `op` and per-token-quantize `x` in one
    /// read pass (row-sharded exactly like the standalone quantizer, so
    /// results are bit-identical for any thread count).
    pub fn quantize(&self, x: &Matrix, op: &ScaleOp<'_>, ws: &mut Workspace) -> QuantizedAct {
        let (t, cin) = (x.rows(), x.cols());
        assert_eq!(cin, self.cin, "qgemm plan c_in mismatch");
        let mut x_int = ws.take_slot_i8_matrix(self.x_int, t, cin);
        let mut dx = ws.take_slot_f32(self.dx, t);
        let shards = pool::shards_for(t, t * cin * 2);
        if shards <= 1 {
            let mut lanes = ws.take_slot_f32_lanes(self.rows, 1);
            scale_quantize_rows(x, op, &mut lanes[0], x_int.data_mut(), &mut dx, 0, t);
            ws.put_slot_f32_lanes(self.rows, lanes);
        } else {
            let mut lanes = ws.take_slot_f32_lanes(self.rows, shards);
            let xi = SplitMut::new(x_int.data_mut());
            let dl = SplitMut::new(&mut dx[..]);
            let lane_split = SplitMut::new(&mut lanes[..]);
            pool::run_shards(shards, &|s| {
                let (r0, r1) = shard_range(t, shards, s);
                let xis = unsafe { xi.slice(r0 * cin, (r1 - r0) * cin) };
                let dls = unsafe { dl.slice(r0, r1 - r0) };
                let buf = unsafe { lane_split.at(s) };
                scale_quantize_rows(x, op, buf, xis, dls, r0, r1);
            });
            ws.put_slot_f32_lanes(self.rows, lanes);
        }
        QuantizedAct { x_int, dx }
    }

    /// Fused matmul + dequant epilogue: `out[i,j] = 0.0 + Δ_x[i]·acc·Δ_w[j]`
    /// written directly (no pre-zeroing pass; bit-identical to zero-fill +
    /// accumulate). Row-sharded with slot-backed widening lanes.
    pub fn matmul_write(
        &self,
        qa: &QuantizedAct,
        qw: &QuantizedWeights,
        ws: &mut Workspace,
        out: &mut [f32],
    ) {
        let (m, k, n) = (qa.x_int.rows(), qa.x_int.cols(), qw.packed.n());
        assert_eq!(out.len(), m * n, "qgemm output length mismatch");
        let shards = pool::shards_for(m, m * k * n);
        if shards <= 1 {
            let mut a16 = ws.take_slot_i16(self.a16, 0);
            qa.x_int
                .matmul_dequant_packed_scratch_write(&qw.packed, &qa.dx, &qw.deltas, &mut a16, out);
            ws.put_slot_i16(self.a16, a16);
        } else {
            let mut lanes = ws.take_slot_i16_lanes(self.a16_lanes, shards);
            qa.x_int
                .matmul_dequant_packed_lanes_write(&qw.packed, &qa.dx, &qw.deltas, &mut lanes, out);
            ws.put_slot_i16_lanes(self.a16_lanes, lanes);
        }
    }

    /// The FP32 leg of the shared pipeline (the full-precision reference
    /// method): a plain blocked matmul writing `out` directly.
    pub fn matmul_f32(&self, x: &Matrix, w: &Matrix, out: &mut Matrix) {
        kernels::matmul_into(x, w, out);
    }

    /// Hand the quantized activations back to their slots.
    pub fn release(&self, qa: QuantizedAct, ws: &mut Workspace) {
        ws.put_slot_i8_matrix(self.x_int, qa.x_int);
        ws.put_slot_f32(self.dx, qa.dx);
    }
}

/// Fetch the compiled plan for `id` out of `ws`, building (and pre-sizing)
/// it on first use with this workspace — or rebuilding if the stored plan
/// was compiled for different layer shapes. The plan is *checked out* of
/// the workspace so plan and arena borrow independently; hand it back with
/// [`store_plan`] at the end of the forward. The plan stays boxed across
/// the round-trip, so the steady-state fetch/store cycle performs no heap
/// allocation (the zero-alloc invariant covers the plan machinery too).
pub fn plan_for(
    ws: &mut Workspace,
    id: PlanId,
    cin: usize,
    cout: usize,
    m_hint: usize,
) -> Box<QgemmPlan> {
    match ws.take_plan(id.0) {
        Some(b) => match b.downcast::<QgemmPlan>() {
            Ok(p) if p.cin == cin && p.cout == cout => p,
            _ => Box::new(QgemmPlan::build(ws, cin, cout, m_hint)),
        },
        None => Box::new(QgemmPlan::build(ws, cin, cout, m_hint)),
    }
}

/// Store a checked-out plan back under its id (an unsizing move — no
/// allocation).
pub fn store_plan(ws: &mut Workspace, id: PlanId, plan: Box<QgemmPlan>) {
    ws.put_plan(id.0, plan);
}

/// Pre-compile (warm) the plan for `id` without running anything — the
/// model/engine layers call this at construction so the first prefill,
/// decode step or train step is already plan-driven.
pub fn warm(ws: &mut Workspace, id: PlanId, cin: usize, cout: usize, m_hint: usize) {
    let plan = plan_for(ws, id, cin, cout, m_hint);
    store_plan(ws, id, plan);
}

/// Gather `rows` of `src` into `dst` (fully overwritten;
/// `dst.rows() == rows.len()`). The multi-tenant serving path uses this
/// to stack one tenant's rows out of a mixed decode batch before running
/// that tenant's adapter delta as one matmul
/// (`QuantLinear::infer_rows`).
pub fn gather_rows(src: &Matrix, rows: &[usize], dst: &mut Matrix) {
    assert_eq!(dst.rows(), rows.len(), "gather destination row mismatch");
    assert_eq!(dst.cols(), src.cols(), "gather destination col mismatch");
    for (i, &r) in rows.iter().enumerate() {
        dst.row_mut(i).copy_from_slice(src.row(r));
    }
}

/// Scatter-accumulate `delta` into `out`: row `i` of `delta` is `+=`ed
/// into row `rows[i]` of `out` — the adapter-delta leg of the epilogue
/// contract (`⊕ adapter-delta`), applied to one tenant's row group of a
/// mixed batch. Each output row receives exactly one accumulation of
/// exactly the row the whole-batch `add_assign` would have added (the
/// delta matmul is row-local), so gathered-then-scattered adapter
/// application is bit-identical to the attached-adapter path.
pub fn scatter_add_rows(out: &mut Matrix, delta: &Matrix, rows: &[usize]) {
    assert_eq!(delta.rows(), rows.len(), "scatter delta row mismatch");
    assert_eq!(delta.cols(), out.cols(), "scatter delta col mismatch");
    for (i, &r) in rows.iter().enumerate() {
        for (o, &d) in out.row_mut(r).iter_mut().zip(delta.row(i)) {
            *o += d;
        }
    }
}

/// One-call fused pipeline for methods without a correction stage:
/// scale→quantize → matmul+dequant, writing `out` directly.
pub fn qgemm_into(
    x: &Matrix,
    op: &ScaleOp<'_>,
    qw: &QuantizedWeights,
    plan: &QgemmPlan,
    ws: &mut Workspace,
    out: &mut [f32],
) {
    let qa = plan.quantize(x, op, ws);
    plan.matmul_write(&qa, qw, ws, out);
    plan.release(qa, ws);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::util::prng::Rng;

    fn qpt(x: &Matrix) -> (I8Matrix, Vec<f32>) {
        let mut q = I8Matrix::zeros(x.rows(), x.cols());
        let mut d = Vec::with_capacity(x.rows());
        quant::quantize_per_token_into(x, &mut q, &mut d);
        (q, d)
    }

    #[test]
    fn fused_identity_matches_standalone_quantizer_and_matmul() {
        let mut r = Rng::new(0x91);
        let mut ws = Workspace::new();
        let x = Matrix::randn(9, 40, &mut r, 1.0);
        let w = Matrix::randn(40, 24, &mut r, 0.4);
        let qw = QuantizedWeights::quantize(&w);
        let plan = QgemmPlan::build(&mut ws, 40, 24, 9);
        let mut got = vec![-1.5f32; 9 * 24];
        qgemm_into(&x, &ScaleOp::Identity, &qw, &plan, &mut ws, &mut got);
        let (xi, dx) = qpt(&x);
        let mut want = vec![0.0f32; 9 * 24];
        qw.matmul_into(&xi, &dx, &mut want);
        assert_eq!(got, want, "fused identity path diverged");
    }

    #[test]
    fn fused_scale_ops_match_legacy_prepass() {
        let mut r = Rng::new(0x92);
        let mut ws = Workspace::new();
        let (t, cin, cout) = (7, 24, 12);
        let x = Matrix::randn(t, cin, &mut r, 2.0);
        let w = Matrix::randn(cin, cout, &mut r, 0.4);
        let qw = QuantizedWeights::quantize(&w);
        let plan = QgemmPlan::build(&mut ws, cin, cout, t);

        // DivCols vs apply_targeted_inverse_scale
        let channels = [2usize, 11, 17];
        let factors = [3.0f32, 1.5, 8.0];
        let oset = crate::outlier::OutlierSet::new(channels.to_vec());
        let mut got = vec![0.0f32; t * cout];
        qgemm_into(
            &x,
            &ScaleOp::DivCols { channels: &channels, factors: &factors },
            &qw,
            &plan,
            &mut ws,
            &mut got,
        );
        let mut x_hat = x.clone();
        crate::scaling::apply_targeted_inverse_scale(&mut x_hat, &oset, &factors);
        let (xi, dx) = qpt(&x_hat);
        let mut want = vec![0.0f32; t * cout];
        qw.matmul_into(&xi, &dx, &mut want);
        assert_eq!(got, want, "DivCols diverged from targeted scaling");

        // MulPerCol vs scale_cols
        let inv: Vec<f32> = (0..cin).map(|i| 1.0 / (1.0 + i as f32 * 0.1)).collect();
        let mut got = vec![0.0f32; t * cout];
        qgemm_into(&x, &ScaleOp::MulPerCol { inv: &inv }, &qw, &plan, &mut ws, &mut got);
        let mut x_hat = x.clone();
        x_hat.scale_cols(&inv);
        let (xi, dx) = qpt(&x_hat);
        let mut want = vec![0.0f32; t * cout];
        qw.matmul_into(&xi, &dx, &mut want);
        assert_eq!(got, want, "MulPerCol diverged from scale_cols");

        // ZeroCols / ZeroAbsAbove vs explicit masking
        let cols = [1usize, 13];
        let mut got = vec![0.0f32; t * cout];
        qgemm_into(&x, &ScaleOp::ZeroCols { cols: &cols }, &qw, &plan, &mut ws, &mut got);
        let mut x_hat = x.clone();
        for ti in 0..t {
            for &c in &cols {
                x_hat.row_mut(ti)[c] = 0.0;
            }
        }
        let (xi, dx) = qpt(&x_hat);
        let mut want = vec![0.0f32; t * cout];
        qw.matmul_into(&xi, &dx, &mut want);
        assert_eq!(got, want, "ZeroCols diverged from masking");

        let mut got = vec![0.0f32; t * cout];
        qgemm_into(&x, &ScaleOp::ZeroAbsAbove { sigma: 1.0 }, &qw, &plan, &mut ws, &mut got);
        let mut x_hat = x.clone();
        for v in x_hat.data_mut() {
            if v.abs() > 1.0 {
                *v = 0.0;
            }
        }
        let (xi, dx) = qpt(&x_hat);
        let mut want = vec![0.0f32; t * cout];
        qw.matmul_into(&xi, &dx, &mut want);
        assert_eq!(got, want, "ZeroAbsAbove diverged from masking");
    }

    #[test]
    fn gather_scatter_matches_whole_batch_accumulate() {
        let mut r = Rng::new(0x93);
        let x = Matrix::randn(6, 10, &mut r, 1.0);
        let delta = Matrix::randn(6, 10, &mut r, 0.5);
        // reference: whole-batch += (the attached-adapter epilogue)
        let mut want = x.clone();
        want.add_assign(&delta);
        // per-group gather → scatter over an interleaved 2-"tenant" split
        let mut got = x.clone();
        for rows in [vec![0usize, 2, 4], vec![1usize, 3, 5]] {
            let mut dg = Matrix::zeros(rows.len(), 10);
            gather_rows(&delta, &rows, &mut dg);
            scatter_add_rows(&mut got, &dg, &rows);
        }
        assert_eq!(got.data(), want.data(), "scatter-add diverged from +=");
    }

    #[test]
    fn plan_roundtrips_through_workspace() {
        let mut ws = Workspace::new();
        let id = PlanId::fresh();
        let plan = plan_for(&mut ws, id, 8, 4, 2);
        assert_eq!((plan.cin(), plan.cout()), (8, 4));
        store_plan(&mut ws, id, plan);
        let frozen = ws.fresh_allocs;
        // same shapes: the stored plan comes back, nothing is rebuilt
        let plan = plan_for(&mut ws, id, 8, 4, 2);
        assert_eq!(ws.fresh_allocs, frozen, "plan refetch must not rebuild");
        store_plan(&mut ws, id, plan);
        // different shapes: a fresh plan is compiled
        let plan = plan_for(&mut ws, id, 16, 4, 2);
        assert_eq!(plan.cin(), 16);
        assert!(ws.fresh_allocs > frozen, "shape change must recompile");
        store_plan(&mut ws, id, plan);
    }
}
