//! Symmetric round-to-nearest INT8 quantization (paper Eq. 1) at the three
//! hardware-efficient granularities of Appendix F: per-tensor, per-token
//! (activation rows) and per-output-channel (weight columns).
//!
//! `X_int = round(X / Δ)`, `Δ = max|X| / (2^{N-1} − 1)` with N = 8 → 127.
//!
//! The per-token / per-OC loops are row-sharded across the tensor
//! [`pool`]: every row's Δ and quantized values depend only on that row, so
//! the threaded paths are bit-identical to the serial ones for any thread
//! count (small launches stay serial under [`pool::MIN_SHARD_WORK`]).

pub mod pipeline;

use crate::tensor::pool::{self, shard_range, SplitMut};
use crate::tensor::{kernels, I8Matrix, Matrix, Workspace};

/// Symmetric INT8 full-scale value: `2^{8−1} − 1`.
pub const QMAX: f32 = 127.0;

/// Quantization granularity (Appendix F). Only the hardware-efficient ones:
/// per-input-channel and per-group cannot feed an integer matmul directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One Δ for the whole tensor.
    PerTensor,
    /// One Δ per activation row (token).
    PerToken,
    /// One Δ per weight column (output channel).
    PerOutChannel,
}

/// Quantize a scalar range: map `x` with step `delta` to i8.
#[inline]
pub fn quantize_value(x: f32, delta: f32) -> i8 {
    if delta == 0.0 {
        return 0;
    }
    let q = (x / delta).round();
    q.clamp(-QMAX, QMAX) as i8
}

/// Step size for symmetric RTN given the absolute max (Eq. 1).
#[inline]
pub fn step_size(abs_max: f32) -> f32 {
    abs_max / QMAX
}

/// Per-tensor quantization: `(X_int, Δ)`.
pub fn quantize_per_tensor(x: &Matrix) -> (I8Matrix, f32) {
    let delta = step_size(x.abs_max());
    let data = x.data().iter().map(|&v| quantize_value(v, delta)).collect();
    (I8Matrix::from_vec(x.rows(), x.cols(), data), delta)
}

/// Per-token (per-row) quantization of activations into caller-provided
/// buffers: `x_int` must match `x`'s shape; `deltas` is cleared and
/// refilled. Allocation-free on reuse; row-sharded for large activations
/// (each row's Δ and values are local to the row, so the split never
/// changes results). The hot path runs the fused scale→quantize variant in
/// [`pipeline`] instead; this standalone form serves calibration, tests and
/// benches. (The old allocating `quantize_per_token` wrapper is gone —
/// callers provide buffers.)
pub fn quantize_per_token_into(x: &Matrix, x_int: &mut I8Matrix, deltas: &mut Vec<f32>) {
    assert_eq!(
        (x_int.rows(), x_int.cols()),
        (x.rows(), x.cols()),
        "quantize_per_token_into shape mismatch"
    );
    let (rows, cols) = (x.rows(), x.cols());
    deltas.clear();
    deltas.resize(rows, 0.0);
    let shards = pool::shards_for(rows, rows * cols * 2);
    if shards <= 1 {
        return ptok_rows(x, x_int.data_mut(), deltas, 0, rows);
    }
    let xi = SplitMut::new(x_int.data_mut());
    let dl = SplitMut::new(&mut deltas[..]);
    pool::run_shards(shards, &|s| {
        let (r0, r1) = shard_range(rows, shards, s);
        let xis = unsafe { xi.slice(r0 * cols, (r1 - r0) * cols) };
        let dls = unsafe { dl.slice(r0, r1 - r0) };
        ptok_rows(x, xis, dls, r0, r1);
    });
}

/// Row-range core of [`quantize_per_token_into`]: rows `r0..r1` into the
/// relative sub-slices `xi` / `deltas`.
fn ptok_rows(x: &Matrix, xi: &mut [i8], deltas: &mut [f32], r0: usize, r1: usize) {
    let cols = x.cols();
    for i in r0..r1 {
        let row = x.row(i);
        let m = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let d = step_size(m);
        deltas[i - r0] = d;
        let dst = &mut xi[(i - r0) * cols..(i - r0 + 1) * cols];
        if d == 0.0 {
            dst.fill(0);
        } else {
            let inv = 1.0 / d;
            for (o, &v) in dst.iter_mut().zip(row) {
                *o = (v * inv).round().clamp(-QMAX, QMAX) as i8;
            }
        }
    }
}

/// Per-output-channel (per-column) quantization of weights:
/// `(W_int, Δ ∈ R^{c_out})`.
pub fn quantize_per_oc(w: &Matrix) -> (I8Matrix, Vec<f32>) {
    let mut w_int = I8Matrix::zeros(w.rows(), w.cols());
    let mut deltas = Vec::with_capacity(w.cols());
    let mut inv = Vec::with_capacity(w.cols());
    quantize_per_oc_core(w, &mut w_int, &mut deltas, &mut inv);
    (w_int, deltas)
}

/// [`quantize_per_oc`] into caller-provided buffers, with the reciprocal
/// and reduction-lane scratch provided explicitly — the per-step `ŵ`
/// quantization on Quaff's plan-driven hot path passes slot-backed buffers
/// (no allocation, no string-keyed lookup).
pub fn quantize_per_oc_scratch(
    w: &Matrix,
    w_int: &mut I8Matrix,
    deltas: &mut Vec<f32>,
    inv: &mut Vec<f32>,
    camax_lanes: &mut Vec<f32>,
) {
    assert_eq!(
        (w_int.rows(), w_int.cols()),
        (w.rows(), w.cols()),
        "quantize_per_oc shape mismatch"
    );
    deltas.clear();
    deltas.resize(w.cols(), 0.0);
    kernels::col_abs_max_scratch(w, deltas, camax_lanes);
    oc_finish(w, w_int, deltas, inv);
}

fn quantize_per_oc_core(
    w: &Matrix,
    w_int: &mut I8Matrix,
    deltas: &mut Vec<f32>,
    inv: &mut Vec<f32>,
) {
    assert_eq!(
        (w_int.rows(), w_int.cols()),
        (w.rows(), w.cols()),
        "quantize_per_oc shape mismatch"
    );
    deltas.clear();
    deltas.resize(w.cols(), 0.0);
    kernels::col_abs_max_into(w, deltas);
    oc_finish(w, w_int, deltas, inv);
}

/// Shared tail of the per-OC quantizer: turn column maxima into step sizes
/// + reciprocals, then quantize the rows (sharded — each output row only
/// reads `inv`, so the split never changes results).
fn oc_finish(w: &Matrix, w_int: &mut I8Matrix, deltas: &mut [f32], inv: &mut Vec<f32>) {
    for d in deltas.iter_mut() {
        *d = step_size(*d);
    }
    inv.clear();
    inv.extend(deltas.iter().map(|&d| if d == 0.0 { 0.0 } else { 1.0 / d }));
    let (rows, cols) = (w.rows(), w.cols());
    let shards = pool::shards_for(rows, rows * cols * 2);
    if shards <= 1 {
        return oc_rows(w, w_int.data_mut(), inv, 0, rows);
    }
    let wi = SplitMut::new(w_int.data_mut());
    let inv = &inv[..];
    pool::run_shards(shards, &|s| {
        let (r0, r1) = shard_range(rows, shards, s);
        let wis = unsafe { wi.slice(r0 * cols, (r1 - r0) * cols) };
        oc_rows(w, wis, inv, r0, r1);
    });
}

/// Row-range core of the per-OC quantizer.
fn oc_rows(w: &Matrix, wi: &mut [i8], inv: &[f32], r0: usize, r1: usize) {
    let cols = w.cols();
    for i in r0..r1 {
        let row = w.row(i);
        let dst = &mut wi[(i - r0) * cols..(i - r0 + 1) * cols];
        for ((o, &v), &iv) in dst.iter_mut().zip(row).zip(inv.iter()) {
            *o = (v * iv).round().clamp(-QMAX, QMAX) as i8;
        }
    }
}

/// Dequantize a per-token-quantized activation matrix into a
/// caller-provided matrix (fully overwritten — dirty recycled buffers are
/// fine). Row-sharded. (The allocating wrapper is gone; callers provide
/// the output.)
pub fn dequantize_per_token_into(x: &I8Matrix, deltas: &[f32], out: &mut Matrix) {
    assert_eq!(deltas.len(), x.rows());
    assert_eq!((out.rows(), out.cols()), (x.rows(), x.cols()));
    let (rows, cols) = (x.rows(), x.cols());
    let od = out.data_mut();
    let shards = pool::shards_for(rows, rows * cols);
    if shards <= 1 {
        return dtok_rows(x, deltas, od, 0, rows);
    }
    let split = SplitMut::new(od);
    pool::run_shards(shards, &|s| {
        let (r0, r1) = shard_range(rows, shards, s);
        let orows = unsafe { split.slice(r0 * cols, (r1 - r0) * cols) };
        dtok_rows(x, deltas, orows, r0, r1);
    });
}

fn dtok_rows(x: &I8Matrix, deltas: &[f32], orows: &mut [f32], r0: usize, r1: usize) {
    let cols = x.cols();
    for i in r0..r1 {
        let d = deltas[i];
        let dst = &mut orows[(i - r0) * cols..(i - r0 + 1) * cols];
        for (o, &q) in dst.iter_mut().zip(x.row(i)) {
            *o = q as f32 * d;
        }
    }
}

/// Dequantize a per-output-channel-quantized weight matrix into a
/// caller-provided matrix. Row-sharded.
pub fn dequantize_per_oc_into(w: &I8Matrix, deltas: &[f32], out: &mut Matrix) {
    assert_eq!(deltas.len(), w.cols());
    assert_eq!((out.rows(), out.cols()), (w.rows(), w.cols()));
    let (rows, cols) = (w.rows(), w.cols());
    let od = out.data_mut();
    let shards = pool::shards_for(rows, rows * cols);
    if shards <= 1 {
        return doc_rows(w, deltas, od, 0, rows);
    }
    let split = SplitMut::new(od);
    pool::run_shards(shards, &|s| {
        let (r0, r1) = shard_range(rows, shards, s);
        let orows = unsafe { split.slice(r0 * cols, (r1 - r0) * cols) };
        doc_rows(w, deltas, orows, r0, r1);
    });
}

fn doc_rows(w: &I8Matrix, deltas: &[f32], orows: &mut [f32], r0: usize, r1: usize) {
    let cols = w.cols();
    for i in r0..r1 {
        let dst = &mut orows[(i - r0) * cols..(i - r0 + 1) * cols];
        for ((o, &q), &d) in dst.iter_mut().zip(w.row(i)).zip(deltas) {
            *o = q as f32 * d;
        }
    }
}

/// Dequantize selected *rows* of a per-OC-quantized weight matrix into a
/// caller-provided matrix (LLM.int8's "retrieve W_O" step — paper Eq. 10
/// discussion).
pub fn dequantize_rows_per_oc_into(
    w: &I8Matrix,
    deltas: &[f32],
    rows: &[usize],
    out: &mut Matrix,
) {
    assert_eq!((out.rows(), out.cols()), (rows.len(), w.cols()));
    for (oi, &i) in rows.iter().enumerate() {
        let dst = out.row_mut(oi);
        for ((o, &q), &d) in dst.iter_mut().zip(w.row(i)).zip(deltas) {
            *o = q as f32 * d;
        }
    }
}

/// Quantization error metrics between a reference f32 tensor and its
/// quantize→dequantize round-trip.
#[derive(Debug, Clone, Copy)]
pub struct QuantError {
    pub mse: f64,
    /// Signal-to-quantization-noise ratio in dB (higher = better).
    pub sqnr_db: f64,
}

/// Measure round-trip error of per-token quantization (diagnostics-tier:
/// allocates its own scratch).
pub fn error_per_token(x: &Matrix) -> QuantError {
    let mut q = I8Matrix::zeros(x.rows(), x.cols());
    let mut d = Vec::with_capacity(x.rows());
    quantize_per_token_into(x, &mut q, &mut d);
    let mut back = Matrix::zeros(x.rows(), x.cols());
    dequantize_per_token_into(&q, &d, &mut back);
    error_between(x, &back)
}

/// Error metrics between reference and reconstruction.
pub fn error_between(reference: &Matrix, reconstructed: &Matrix) -> QuantError {
    let mse = reference.mse(reconstructed);
    let sig = reference.sq_norm() / reference.data().len().max(1) as f64;
    let sqnr_db = if mse > 0.0 {
        10.0 * (sig / mse).log10()
    } else {
        f64::INFINITY
    };
    QuantError { mse, sqnr_db }
}

/// Pre-quantized frozen weights of one linear layer: the static part of
/// Eq. 4/5 that Quaff produces once at preprocessing time.
///
/// Alongside the canonical int8 store this keeps a transposed i16 "packed"
/// copy for the fast CPU integer matmul (§Perf). The packed copy is a
/// CPU-substrate execution detail — GPU/TPU int8 GEMMs consume `w_int`
/// directly — so it is excluded from the device-memory model.
#[derive(Clone, Debug)]
pub struct QuantizedWeights {
    pub w_int: I8Matrix,
    /// Per-output-channel step sizes `Δ_W`.
    pub deltas: Vec<f32>,
    /// Transposed i16 form for the vectorized matmul.
    pub packed: crate::tensor::PackedWeights,
}

impl QuantizedWeights {
    pub fn quantize(w: &Matrix) -> QuantizedWeights {
        let (w_int, deltas) = quantize_per_oc(w);
        let packed = w_int.pack_transposed();
        QuantizedWeights {
            w_int,
            deltas,
            packed,
        }
    }

    /// Rebuild from a persisted int8 store + per-OC step sizes (the
    /// `persist` tier stores exactly these). The packed transposed form is
    /// a pure layout cache and is re-derived, so a round-tripped store is
    /// bit-identical to the original in every matmul.
    pub fn from_parts(w_int: I8Matrix, deltas: Vec<f32>) -> QuantizedWeights {
        assert_eq!(deltas.len(), w_int.cols(), "Δ_W length must match c_out");
        let packed = w_int.pack_transposed();
        QuantizedWeights {
            w_int,
            deltas,
            packed,
        }
    }

    /// Fused `out += Δ_x·(X_int·W_int)·Δ_W` via the packed fast path
    /// (row-sharded internally for large launches). Test/diagnostics tier:
    /// allocates its own staging lanes per call; hot paths use
    /// [`Self::matmul_ws`] or the `quant::pipeline` plan slots.
    pub fn matmul_into(&self, x_int: &I8Matrix, dx: &[f32], out: &mut [f32]) {
        let n_lanes = pool::active_threads().max(1);
        let mut lanes: Vec<Vec<i16>> = (0..n_lanes).map(|_| Vec::new()).collect();
        x_int.matmul_dequant_packed_lanes_into(&self.packed, dx, &self.deltas, &mut lanes, out);
    }

    /// [`Self::matmul_into`] with the per-shard widening scratch drawn from
    /// the workspace's lane pool — zero allocations at steady state, serial
    /// single-scratch path for small (decode-shape) launches.
    pub fn matmul_ws(&self, x_int: &I8Matrix, dx: &[f32], ws: &mut Workspace, out: &mut [f32]) {
        let (m, k, n) = (x_int.rows(), x_int.cols(), self.packed.n());
        let shards = pool::shards_for(m, m * k * n);
        if shards <= 1 {
            let mut a16 = ws.take_i16("qw.a16", 0);
            x_int.matmul_dequant_packed_scratch_into(&self.packed, dx, &self.deltas, &mut a16, out);
            ws.put_i16("qw.a16", a16);
        } else {
            let mut lanes = ws.take_i16_lanes("qw.a16.lanes", shards);
            x_int.matmul_dequant_packed_lanes_into(&self.packed, dx, &self.deltas, &mut lanes, out);
            ws.put_i16_lanes("qw.a16.lanes", lanes);
        }
    }

    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.w_int.rows(), self.w_int.cols());
        dequantize_per_oc_into(&self.w_int, &self.deltas, &mut out);
        out
    }

    /// Device bytes: int8 weights + f32 step sizes.
    pub fn nbytes(&self) -> usize {
        self.w_int.nbytes() + self.deltas.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    /// Test-local allocating wrappers over the `_into` kernels (the old
    /// convenience functions, kept only where tests want fresh buffers).
    fn qpt(x: &Matrix) -> (I8Matrix, Vec<f32>) {
        let mut q = I8Matrix::zeros(x.rows(), x.cols());
        let mut d = Vec::with_capacity(x.rows());
        quantize_per_token_into(x, &mut q, &mut d);
        (q, d)
    }

    fn dqt(q: &I8Matrix, d: &[f32]) -> Matrix {
        let mut out = Matrix::zeros(q.rows(), q.cols());
        dequantize_per_token_into(q, d, &mut out);
        out
    }

    #[test]
    fn per_tensor_roundtrip_error_bounded() {
        // RTN error per element is at most Δ/2 when no clipping occurs.
        prop::check("pt-roundtrip", 0xC1, 32, |r| {
            let std = r.range(0.1, 10.0);
            Matrix::randn(4 + r.below(20), 4 + r.below(40), r, std)
        }, |x| {
            let (q, d) = quantize_per_tensor(x);
            for (i, (&v, &qv)) in x.data().iter().zip(q.data()).enumerate() {
                let back = qv as f32 * d;
                if (v - back).abs() > d * 0.5 + 1e-6 {
                    return Err(format!("elem {i}: |{v} - {back}| > Δ/2 = {}", d * 0.5));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn per_token_roundtrip_error_bounded_by_row_delta() {
        prop::check("ptok-roundtrip", 0xC2, 32, |r| {
            Matrix::randn(2 + r.below(16), 2 + r.below(64), r, 1.0)
        }, |x| {
            let (q, deltas) = qpt(x);
            let back = dqt(&q, &deltas);
            for i in 0..x.rows() {
                for j in 0..x.cols() {
                    let err = (x.get(i, j) - back.get(i, j)).abs();
                    if err > deltas[i] * 0.5 + 1e-6 {
                        return Err(format!("({i},{j}): err {err} > Δ/2"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn per_oc_full_scale_uses_127() {
        let w = Matrix::from_vec(2, 2, vec![1.0, 10.0, -2.0, -5.0]);
        let (q, d) = quantize_per_oc(&w);
        // col 0 max=2 -> Δ=2/127; value -2 -> -127
        assert_eq!(q.get(1, 0), -127);
        assert!((d[0] - 2.0 / 127.0).abs() < 1e-7);
        // col 1 max=10 -> 10 -> 127
        assert_eq!(q.get(0, 1), 127);
    }

    #[test]
    fn zero_tensor_quantizes_to_zero() {
        let x = Matrix::zeros(3, 3);
        let (q, d) = quantize_per_tensor(&x);
        assert_eq!(d, 0.0);
        assert!(q.data().iter().all(|&v| v == 0));
        let (q2, d2) = qpt(&x);
        assert!(d2.iter().all(|&v| v == 0.0));
        assert!(q2.data().iter().all(|&v| v == 0));
    }

    #[test]
    fn outliers_inflate_per_token_error() {
        // The paper's core failure mode: one 100x outlier channel makes the
        // per-token Δ 100x larger, wrecking precision for normal channels.
        let mut r = Rng::new(77);
        let clean = Matrix::randn(8, 64, &mut r, 1.0);
        let mut dirty = clean.clone();
        for i in 0..8 {
            let v = dirty.get(i, 3);
            dirty.set(i, 3, v * 100.0);
        }
        let e_clean = error_per_token(&clean);
        let e_dirty = error_per_token(&dirty);
        assert!(
            e_dirty.mse > e_clean.mse * 100.0,
            "outliers should inflate error: {} vs {}",
            e_dirty.mse,
            e_clean.mse
        );
    }

    #[test]
    fn sqnr_improves_without_outliers() {
        let mut r = Rng::new(78);
        let x = Matrix::randn(16, 128, &mut r, 1.0);
        let e = error_per_token(&x);
        // INT8 RTN on Gaussian data ~ >30 dB SQNR
        assert!(e.sqnr_db > 30.0, "sqnr = {}", e.sqnr_db);
    }

    #[test]
    fn dequantize_rows_matches_full_dequant() {
        let mut r = Rng::new(79);
        let w = Matrix::randn(10, 6, &mut r, 1.0);
        let qw = QuantizedWeights::quantize(&w);
        let full = qw.dequantize();
        let rows = [1usize, 4, 9];
        let mut sel = Matrix::zeros(rows.len(), qw.w_int.cols());
        dequantize_rows_per_oc_into(&qw.w_int, &qw.deltas, &rows, &mut sel);
        for (oi, &i) in rows.iter().enumerate() {
            assert_eq!(sel.row(oi), full.row(i));
        }
    }

    #[test]
    fn quantized_weights_bytes() {
        let w = Matrix::zeros(100, 50);
        let qw = QuantizedWeights::quantize(&w);
        assert_eq!(qw.nbytes(), 100 * 50 + 50 * 4);
    }
}
