//! Task evaluation: perplexity, MCQ accuracy (the paper's reasoning
//! metric), teacher-forced exact-match accuracy (LAMBADA-style), and
//! ROUGE-L over greedy generations (instruction / long-form tasks).

use super::cross_entropy;
use crate::data::{pack_batch, Sample, SynthTask, EOS};
use crate::metrics::{perplexity, rouge_l};
use crate::model::Model;

/// Index of the maximum of a logit row, keeping the **last** maximal element
/// on ties (the `Iterator::max_by` convention the previous implementation
/// had, so tied-logit predictions are unchanged). Total — no `unwrap` on the
/// evaluation path: an empty or all-NaN row yields index 0 instead of a
/// panic mid-eval. One shared implementation lives in [`crate::infer`] so
/// greedy decoding and teacher-forced scoring cannot drift apart.
fn argmax(row: &[f32]) -> usize {
    crate::infer::argmax(row) as usize
}

/// Mean NLL + perplexity over a sample set (teacher forcing).
pub fn eval_ppl(model: &mut Model, samples: &[Sample], batch: usize, max_len: usize) -> (f64, f64) {
    let mut total = 0.0f64;
    let mut n = 0usize;
    for chunk in samples.chunks(batch) {
        let refs: Vec<&Sample> = chunk.iter().collect();
        let (toks, masks) = pack_batch(&refs, max_len);
        let (logits, cache) = model.forward(&toks, false);
        let (loss, _) = cross_entropy(&logits, &toks, &masks, &cache);
        total += loss * chunk.len() as f64;
        n += chunk.len();
    }
    let mean = if n > 0 { total / n as f64 } else { 0.0 };
    (mean, perplexity(mean))
}

/// MCQ accuracy: at the answer-letter position, compare the argmax over the
/// four option-letter tokens with the gold letter (paper's reasoning
/// benchmarks: GPQA / MathQA / MMLU-Pro).
pub fn eval_mcq_accuracy(model: &mut Model, samples: &[Sample], max_len: usize) -> f64 {
    let letters = SynthTask::option_letter_tokens();
    let offset = SynthTask::mcq_letter_offset();
    let mut hit = 0usize;
    let mut total = 0usize;
    for chunk in samples.chunks(4) {
        let refs: Vec<&Sample> = chunk.iter().collect();
        let (toks, _) = pack_batch(&refs, max_len);
        let (logits, cache) = model.forward(&toks, false);
        let nv = cache.n_virtual;
        let sp = cache.seq;
        for (b, s) in chunk.iter().enumerate() {
            // packed row: BOS + prompt + target; letter at 1+len(prompt)+offset
            let letter_pos = 1 + s.prompt.len() + offset;
            if letter_pos >= sp - nv {
                continue; // truncated
            }
            let gold = s.target[offset] as u32;
            // the row predicting position `letter_pos` is `letter_pos - 1`
            let row = logits.row(b * sp + nv + letter_pos - 1);
            // argmax restricted to the option-letter tokens (total, no
            // panic; `>=` keeps the last tied letter like `max_by` did)
            let mut pred = letters[0];
            let mut best = f32::NEG_INFINITY;
            for &l in letters.iter() {
                let v = row[l as usize];
                if v >= best {
                    best = v;
                    pred = l;
                }
            }
            if pred == gold {
                hit += 1;
            }
            total += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        hit as f64 / total as f64
    }
}

/// Teacher-forced token accuracy over target positions (the "Acc" column
/// of the instruction-tuning tables, and exact-match for LAMBADA when
/// aggregated per sample).
pub fn eval_token_accuracy(model: &mut Model, samples: &[Sample], max_len: usize) -> f64 {
    let mut hit = 0usize;
    let mut total = 0usize;
    for chunk in samples.chunks(4) {
        let refs: Vec<&Sample> = chunk.iter().collect();
        let (toks, masks) = pack_batch(&refs, max_len);
        let (logits, cache) = model.forward(&toks, false);
        let nv = cache.n_virtual;
        let sp = cache.seq;
        let s_len = sp - nv;
        for (b, (seq_toks, seq_mask)) in toks.iter().zip(&masks).enumerate() {
            for i in 0..s_len.saturating_sub(1) {
                if !seq_mask[i] {
                    continue;
                }
                let row = logits.row(b * sp + nv + i);
                let pred = argmax(row) as u32;
                if pred == seq_toks[i + 1] {
                    hit += 1;
                }
                total += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        hit as f64 / total as f64
    }
}

/// Per-sample exact match under teacher forcing (LAMBADA last-word metric).
pub fn eval_exact_match(model: &mut Model, samples: &[Sample], max_len: usize) -> f64 {
    let mut hit = 0usize;
    for s in samples {
        let refs = [s];
        let (toks, masks) = pack_batch(&refs, max_len);
        let (logits, cache) = model.forward(&toks, false);
        let nv = cache.n_virtual;
        let sp = cache.seq;
        let s_len = sp - nv;
        let mut all = true;
        let mut any = false;
        for i in 0..s_len.saturating_sub(1) {
            if !masks[0][i] {
                continue;
            }
            any = true;
            let row = logits.row(nv + i);
            let pred = argmax(row) as u32;
            if pred != toks[0][i + 1] {
                all = false;
                break;
            }
        }
        if any && all {
            hit += 1;
        }
    }
    if samples.is_empty() {
        0.0
    } else {
        hit as f64 / samples.len() as f64
    }
}

/// Mean ROUGE-L of greedy generations against references.
pub fn eval_rouge(model: &mut Model, samples: &[Sample], max_new_cap: usize) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut total = 0.0f64;
    for s in samples {
        let mut prompt = vec![crate::data::BOS];
        prompt.extend_from_slice(&s.prompt);
        let max_new = (s.target.len() + 8).min(max_new_cap);
        let gen = model.generate(&prompt, max_new, EOS);
        total += rouge_l(&gen, &s.target);
    }
    total / samples.len() as f64
}

/// [`eval_rouge`] over the shared KV-cached decode path (`infer`): frozen
/// method state, O(1) work per generated token instead of a full
/// re-forward. Takes `&Model` — scoring never mutates the model.
pub fn eval_rouge_decode(model: &Model, samples: &[Sample], max_new_cap: usize) -> f64 {
    use crate::infer::{generate_cached, GenerateConfig, KvCache};
    if samples.is_empty() {
        return 0.0;
    }
    let mut ws = crate::tensor::Workspace::new();
    let mut kv = KvCache::for_model(model, 1, &mut ws);
    let mut total = 0.0f64;
    for s in samples {
        let mut prompt = vec![crate::data::BOS];
        prompt.extend_from_slice(&s.prompt);
        let mut cfg = GenerateConfig::greedy((s.target.len() + 8).min(max_new_cap));
        cfg.eos = Some(EOS);
        let gen = generate_cached(model, &prompt, &cfg, &mut kv, 0, &mut ws);
        total += rouge_l(&gen, &s.target);
    }
    kv.release(&mut ws);
    total / samples.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Model, ModelConfig};
    use crate::peft::PeftKind;
    use crate::train::Trainer;
    use crate::util::prng::Rng;

    fn model() -> Model {
        let cfg = ModelConfig {
            vocab: crate::data::VOCAB_SIZE,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq: 160,
            ln_eps: 1e-5,
            inject_outliers: false,
            lora_rank: 4,
            lora_alpha: 8.0,
            lora_dropout: 0.0,
            n_virtual: 4,
        };
        Model::new(cfg, 21)
    }

    #[test]
    fn mcq_accuracy_in_unit_range_and_improves() {
        let mut m = model();
        m.attach_peft(PeftKind::Lora);
        let task = SynthTask::by_name("gpqa").unwrap();
        let mut rng = Rng::new(22);
        let test: Vec<_> = (0..12).map(|_| task.sample(&mut rng)).collect();
        let acc0 = eval_mcq_accuracy(&mut m, &test, 160);
        assert!((0.0..=1.0).contains(&acc0));
        // a handful of steps on the same distribution should not break it
        let train: Vec<_> = (0..8).map(|_| task.sample(&mut rng)).collect();
        let refs: Vec<&Sample> = train.iter().collect();
        let mut tr = Trainer::new(5e-3, 160, 1);
        for _ in 0..10 {
            let _ = tr.step(&mut m, &[refs.clone()]);
        }
        let acc1 = eval_mcq_accuracy(&mut m, &test, 160);
        assert!((0.0..=1.0).contains(&acc1));
    }

    #[test]
    fn ppl_finite_and_positive() {
        let mut m = model();
        let task = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(23);
        let test: Vec<_> = (0..6).map(|_| task.sample(&mut rng)).collect();
        let (nll, ppl) = eval_ppl(&mut m, &test, 3, 96);
        assert!(nll > 0.0 && ppl.is_finite());
        assert!(ppl > 1.0);
    }

    #[test]
    fn token_accuracy_bounds() {
        let mut m = model();
        let task = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(24);
        let test: Vec<_> = (0..6).map(|_| task.sample(&mut rng)).collect();
        let a = eval_token_accuracy(&mut m, &test, 96);
        assert!((0.0..=1.0).contains(&a));
    }

    #[test]
    fn rouge_eval_runs() {
        let mut m = model();
        let task = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(25);
        let test: Vec<_> = (0..2).map(|_| task.sample(&mut rng)).collect();
        let r = eval_rouge(&mut m, &test, 16);
        assert!((0.0..=1.0).contains(&r));
    }

    #[test]
    fn rouge_decode_eval_runs() {
        let m = model();
        let task = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(25);
        let test: Vec<_> = (0..2).map(|_| task.sample(&mut rng)).collect();
        let r = eval_rouge_decode(&m, &test, 16);
        assert!((0.0..=1.0).contains(&r));
    }

    #[test]
    fn exact_match_bounds() {
        let mut m = model();
        let task = SynthTask::by_name("lambada").unwrap();
        let mut rng = Rng::new(26);
        let test: Vec<_> = (0..3).map(|_| task.sample(&mut rng)).collect();
        let a = eval_exact_match(&mut m, &test, 160);
        assert!((0.0..=1.0).contains(&a));
    }
}
