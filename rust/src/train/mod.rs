//! Fine-tuning: masked next-token cross-entropy, Adam over PEFT adapters,
//! the train-step driver with gradient accumulation, and the
//! wall-clock-budgeted runner used for the convergence experiments
//! (Fig. 6 / Table 2's "24 hours of fine-tuning", scaled).
//!
//! The step is **sharded** (see DESIGN.md §Threading): micro-batch
//! forward/backward run the pool-sharded kernels, the cross-entropy shards
//! per *sequence* with partials merged in fixed batch order, and Adam
//! shards elementwise — so gradient accumulation and the loss are
//! bit-identical for any `QUAFF_THREADS`.

pub mod eval;

use crate::data::{pack_batch, Sample};
use crate::model::param::Param;
use crate::model::{Model, ModelCache};
use crate::tensor::pool::{self, shard_range, SplitMut};
use crate::tensor::{Matrix, Workspace};
use std::collections::BTreeMap;
use std::time::Instant;

/// Masked next-token cross-entropy.
///
/// `logits` rows are `(batch · seq')` with `seq' = n_virtual + seq`;
/// `mask[b][i]` marks positions whose next token carries loss. Returns the
/// mean NLL over masked positions and dL/dlogits.
///
/// Sharded per sequence: each sequence's NLL/count partials and dlogits
/// block are computed independently and the partials are merged **in batch
/// order**, so the loss is bit-identical for any shard count.
pub fn cross_entropy(
    logits: &Matrix,
    tokens: &[Vec<u32>],
    masks: &[Vec<bool>],
    cache: &ModelCache,
) -> (f64, Matrix) {
    let nv = cache.n_virtual;
    let sp = cache.seq;
    let vocab = logits.cols();
    let mut dlogits = Matrix::zeros(logits.rows(), vocab);
    let b_count = tokens.len();
    let mut nll = vec![0.0f64; b_count];
    let mut cnt = vec![0usize; b_count];
    let shards = pool::shards_for(b_count, logits.rows() * vocab * 8);
    if shards <= 1 {
        let dd = dlogits.data_mut();
        for b in 0..b_count {
            let block = &mut dd[b * sp * vocab..(b + 1) * sp * vocab];
            let (n, c) = ce_sequence(logits, &tokens[b], &masks[b], b, nv, sp, block);
            nll[b] = n;
            cnt[b] = c;
        }
    } else {
        let dsplit = SplitMut::new(dlogits.data_mut());
        let nsplit = SplitMut::new(&mut nll);
        let csplit = SplitMut::new(&mut cnt);
        pool::run_shards(shards, &|sh| {
            let (b0, b1) = shard_range(b_count, shards, sh);
            for b in b0..b1 {
                let block = unsafe { dsplit.slice(b * sp * vocab, sp * vocab) };
                let (n, c) = ce_sequence(logits, &tokens[b], &masks[b], b, nv, sp, block);
                unsafe {
                    *nsplit.at(b) = n;
                    *csplit.at(b) = c;
                }
            }
        });
    }
    // fixed-order reduction over sequences
    let total_nll: f64 = nll.iter().sum();
    let count: usize = cnt.iter().sum();
    if count > 0 {
        let inv = 1.0 / count as f32;
        dlogits.scale(inv);
        (total_nll / count as f64, dlogits)
    } else {
        (0.0, dlogits)
    }
}

/// One sequence's cross-entropy: fills its `sp × vocab` dlogits block
/// (rows outside masked positions stay zero) and returns (nll, count).
fn ce_sequence(
    logits: &Matrix,
    seq_toks: &[u32],
    seq_mask: &[bool],
    b: usize,
    nv: usize,
    sp: usize,
    dblock: &mut [f32],
) -> (f64, usize) {
    let s = sp - nv;
    let vocab = logits.cols();
    let mut total_nll = 0.0f64;
    let mut count = 0usize;
    for i in 0..s.saturating_sub(1) {
        if !seq_mask[i] {
            continue;
        }
        let row_idx = b * sp + nv + i;
        let target = seq_toks[i + 1] as usize;
        let row = logits.row(row_idx);
        // stable log-softmax
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f64;
        for &x in row {
            sum += ((x - mx) as f64).exp();
        }
        let log_z = sum.ln() + mx as f64;
        total_nll += log_z - row[target] as f64;
        // dlogits = softmax - onehot (normalized later)
        let drow = &mut dblock[(nv + i) * vocab..(nv + i + 1) * vocab];
        for (j, &x) in row.iter().enumerate() {
            drow[j] = (((x as f64 - log_z).exp()) as f32) - if j == target { 1.0 } else { 0.0 };
        }
        count += 1;
    }
    (total_nll, count)
}

/// Adam optimizer over the model's trainable (adapter) parameters.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    state: BTreeMap<String, (Matrix, Matrix)>,
}

impl Adam {
    /// Paper hyper-parameters: lr 2e-4 (Appendix E).
    pub fn new(lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            state: BTreeMap::new(),
        }
    }

    /// Apply one update from the accumulated gradients, then zero them.
    /// Large parameters shard elementwise across the pool (each index is
    /// independent, so the update is bit-identical for any thread count);
    /// adapter-sized parameters stay serial under the work threshold.
    pub fn step(&mut self, model: &mut Model) {
        self.t += 1;
        let t = self.t as f64;
        let bc1 = 1.0 - (self.beta1 as f64).powf(t);
        let bc2 = 1.0 - (self.beta2 as f64).powf(t);
        let (b1, b2, lr, eps) = (self.beta1, self.beta2, self.lr, self.eps);
        let state = &mut self.state;
        model.visit_params(&mut |name: &str, p: &mut Param| {
            let (m, v) = state.entry(name.to_string()).or_insert_with(|| {
                (
                    Matrix::zeros(p.value.rows(), p.value.cols()),
                    Matrix::zeros(p.value.rows(), p.value.cols()),
                )
            });
            let g = p.grad.data();
            let md = m.data_mut();
            let vd = v.data_mut();
            let pv = p.value.data_mut();
            let len = g.len();
            let shards = pool::shards_for(len, len * 8);
            if shards <= 1 {
                adam_update(g, md, vd, pv, (b1, b2, lr, eps), (bc1, bc2));
            } else {
                let ms = SplitMut::new(md);
                let vs = SplitMut::new(vd);
                let ps = SplitMut::new(pv);
                pool::run_shards(shards, &|s| {
                    let (r0, r1) = shard_range(len, shards, s);
                    let (mc, vc, pc) = unsafe {
                        (ms.slice(r0, r1 - r0), vs.slice(r0, r1 - r0), ps.slice(r0, r1 - r0))
                    };
                    adam_update(&g[r0..r1], mc, vc, pc, (b1, b2, lr, eps), (bc1, bc2));
                });
            }
            p.zero_grad();
        });
    }

    /// Adam's bias-correction timestep `t` (persistence).
    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Restore the bias-correction timestep of a checkpointed run — with
    /// the moments reinserted via [`Adam::insert_state`], the next `step`
    /// is bit-identical to the uninterrupted run's.
    pub fn set_timestep(&mut self, t: u64) {
        self.t = t;
    }

    /// Visit the per-parameter first/second moments in name order
    /// (persistence; the order is stable because the state is a BTreeMap).
    pub fn visit_state(&self, f: &mut dyn FnMut(&str, &Matrix, &Matrix)) {
        for (name, (m, v)) in &self.state {
            f(name, m, v);
        }
    }

    /// Reinsert a persisted parameter's moments (checkpoint loading).
    pub fn insert_state(&mut self, name: &str, m: Matrix, v: Matrix) {
        self.state.insert(name.to_string(), (m, v));
    }

    /// Optimizer state bytes (m+v per param).
    pub fn state_bytes(&self) -> usize {
        self.state
            .values()
            .map(|(m, v)| (m.data().len() + v.data().len()) * 4)
            .sum()
    }
}

/// Elementwise Adam update over pre-sliced ranges — one index, one update;
/// trivially deterministic under sharding.
fn adam_update(
    g: &[f32],
    md: &mut [f32],
    vd: &mut [f32],
    pv: &mut [f32],
    (b1, b2, lr, eps): (f32, f32, f32, f32),
    (bc1, bc2): (f64, f64),
) {
    for i in 0..g.len() {
        md[i] = b1 * md[i] + (1.0 - b1) * g[i];
        vd[i] = b2 * vd[i] + (1.0 - b2) * g[i] * g[i];
        let mh = md[i] as f64 / bc1;
        let vh = vd[i] as f64 / bc2;
        pv[i] -= lr * (mh / (vh.sqrt() + eps as f64)) as f32;
    }
}

/// Statistics from one optimizer step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f64,
    pub seconds: f64,
    pub tokens: usize,
}

/// The fine-tuning driver: micro-batches with gradient accumulation, outlier
/// drift ticks, and per-step latency measurement. Owns the scratch
/// [`Workspace`] threaded through every forward/backward, so buffers are
/// reused across the whole run rather than reallocated per step.
///
/// Execution is sharded *inside* each micro-batch: every linear's kernels
/// split token rows across the pool, the loss shards per sequence, and Adam
/// shards elementwise — while micro-batches themselves accumulate gradients
/// in fixed submission order. That keeps the gradient reduction
/// deterministic (bit-identical for any `QUAFF_THREADS`) without
/// replicating model state per thread.
pub struct Trainer {
    pub opt: Adam,
    pub max_len: usize,
    pub grad_accum: usize,
    pub step_count: u64,
    pub ws: Workspace,
}

impl Trainer {
    pub fn new(lr: f32, max_len: usize, grad_accum: usize) -> Trainer {
        Trainer {
            opt: Adam::new(lr),
            max_len,
            grad_accum,
            step_count: 0,
            ws: Workspace::new(),
        }
    }

    /// One optimizer step over `micro_batches` (each a slice of samples).
    pub fn step(&mut self, model: &mut Model, micro_batches: &[Vec<&Sample>]) -> StepStats {
        let t0 = Instant::now();
        let mut loss_sum = 0.0;
        let mut tokens = 0usize;
        for mb in micro_batches {
            let (toks, masks) = pack_batch(mb, self.max_len);
            tokens += toks.len() * toks[0].len();
            let (logits, cache) = model.forward_with(&toks, true, &mut self.ws);
            let (loss, dlogits) = cross_entropy(&logits, &toks, &masks, &cache);
            model.backward_with(&dlogits, &cache, &mut self.ws);
            self.ws.recycle(logits);
            self.ws.recycle(dlogits);
            loss_sum += loss;
        }
        self.opt.step(model);
        model.tick_outliers();
        self.step_count += 1;
        StepStats {
            loss: loss_sum / micro_batches.len().max(1) as f64,
            seconds: t0.elapsed().as_secs_f64(),
            tokens,
        }
    }
}

/// A point on a convergence curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub seconds: f64,
    pub steps: u64,
    pub metric: f64,
}

/// Run fine-tuning under a wall-clock budget, evaluating `eval` every
/// `eval_every` steps — the scaled analogue of the paper's 24-hour runs.
pub fn run_budgeted<F>(
    model: &mut Model,
    trainer: &mut Trainer,
    mut next_batch: impl FnMut() -> Vec<Vec<Sample>>,
    budget_secs: f64,
    eval_every: u64,
    mut eval: F,
) -> Vec<CurvePoint>
where
    F: FnMut(&mut Model) -> f64,
{
    let t0 = Instant::now();
    let mut curve = Vec::new();
    loop {
        let owned = next_batch();
        let micro: Vec<Vec<&Sample>> = owned.iter().map(|b| b.iter().collect()).collect();
        let _ = trainer.step(model, &micro);
        if trainer.step_count % eval_every == 0 {
            let m = eval(model);
            curve.push(CurvePoint {
                seconds: t0.elapsed().as_secs_f64(),
                steps: trainer.step_count,
                metric: m,
            });
        }
        if t0.elapsed().as_secs_f64() >= budget_secs {
            break;
        }
    }
    if curve.is_empty() || curve.last().unwrap().steps != trainer.step_count {
        let m = eval(model);
        curve.push(CurvePoint {
            seconds: t0.elapsed().as_secs_f64(),
            steps: trainer.step_count,
            metric: m,
        });
    }
    curve
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthTask, Tokenizer};
    use crate::model::{Model, ModelConfig};
    use crate::peft::PeftKind;
    use crate::util::prng::Rng;

    fn tiny_model() -> Model {
        let cfg = ModelConfig {
            vocab: crate::data::VOCAB_SIZE,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq: 96,
            ln_eps: 1e-5,
            inject_outliers: false,
            lora_rank: 4,
            lora_alpha: 8.0,
            lora_dropout: 0.0,
            n_virtual: 4,
        };
        let mut m = Model::new(cfg, 3);
        m.attach_peft(PeftKind::Lora);
        m
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        let mut m = tiny_model();
        let toks = vec![vec![5u32, 6, 7, 8]];
        let masks = vec![vec![true, true, true, false]];
        let (logits, cache) = m.forward(&toks, false);
        let zero_logits = Matrix::zeros(logits.rows(), logits.cols());
        let (loss, dl) = cross_entropy(&zero_logits, &toks, &masks, &cache);
        // uniform: loss = ln(vocab)
        assert!((loss - (crate::data::VOCAB_SIZE as f64).ln()).abs() < 1e-6);
        // gradient rows sum ≈ 0 (softmax minus onehot)
        for i in 0..dl.rows() {
            let s: f32 = dl.row(i).iter().sum();
            assert!(s.abs() < 1e-4);
        }
    }

    #[test]
    fn training_reduces_loss_on_fixed_batch() {
        let mut m = tiny_model();
        let task = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(9);
        let samples: Vec<_> = (0..4).map(|_| task.sample(&mut rng)).collect();
        let mut trainer = Trainer::new(1e-2, 96, 1);
        let refs: Vec<&crate::data::Sample> = samples.iter().collect();
        let first = trainer.step(&mut m, &[refs.clone()]).loss;
        let mut last = first;
        for _ in 0..100 {
            last = trainer.step(&mut m, &[refs.clone()]).loss;
        }
        // LoRA-only adaptation of a frozen *random* base is slow by design;
        // we assert steady optimization, not memorization (integration tests
        // train for longer and check task metrics).
        assert!(
            last < first - 0.3,
            "loss should drop on a memorizable batch: {first} → {last}"
        );
    }

    #[test]
    fn adam_updates_only_adapters() {
        let mut m = tiny_model();
        let w_before = m.blocks[0].q_proj.master().unwrap().clone();
        let emb_before = m.emb.tok.clone();
        let task = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(10);
        let samples: Vec<_> = (0..2).map(|_| task.sample(&mut rng)).collect();
        let refs: Vec<&crate::data::Sample> = samples.iter().collect();
        let mut trainer = Trainer::new(1e-3, 96, 1);
        for _ in 0..3 {
            let _ = trainer.step(&mut m, &[refs.clone()]);
        }
        assert_eq!(m.blocks[0].q_proj.master().unwrap().data(), w_before.data());
        assert_eq!(m.emb.tok.data(), emb_before.data());
        // but LoRA B moved
        let b = &m.blocks[0].q_proj.lora.as_ref().unwrap().b.value;
        assert!(b.sq_norm() > 0.0);
    }

    #[test]
    fn grad_accum_equivalent_token_count() {
        let mut m = tiny_model();
        let task = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(11);
        let samples: Vec<_> = (0..4).map(|_| task.sample(&mut rng)).collect();
        let refs: Vec<&crate::data::Sample> = samples.iter().collect();
        let mut trainer = Trainer::new(1e-3, 96, 2);
        let stats = trainer.step(&mut m, &[refs[..2].to_vec(), refs[2..].to_vec()]);
        assert!(stats.tokens > 0);
        assert!(stats.loss.is_finite());
    }

    #[test]
    fn budgeted_run_respects_budget_and_returns_curve() {
        let mut m = tiny_model();
        let task = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(12);
        let mut trainer = Trainer::new(1e-3, 96, 1);
        let t0 = std::time::Instant::now();
        let curve = run_budgeted(
            &mut m,
            &mut trainer,
            || vec![(0..2).map(|_| task.sample(&mut rng)).collect()],
            0.5,
            2,
            |_| 0.42,
        );
        assert!(t0.elapsed().as_secs_f64() < 30.0);
        assert!(!curve.is_empty());
        assert!(curve.last().unwrap().steps >= 1);
        let _ = Tokenizer::new();
    }
}
