//! ROUGE-L: longest-common-subsequence F-measure between a candidate and a
//! reference token sequence — the generation-quality metric of Tables 1/2/4.

/// LCS length via the classic O(n·m) DP (sequences here are ≤ a few hundred
/// tokens, so quadratic is fine; rows are rolled to keep memory O(m)).
fn lcs_len<T: PartialEq>(a: &[T], b: &[T]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let m = b.len();
    let mut prev = vec![0usize; m + 1];
    let mut curr = vec![0usize; m + 1];
    for x in a {
        for (j, y) in b.iter().enumerate() {
            curr[j + 1] = if x == y {
                prev[j] + 1
            } else {
                prev[j + 1].max(curr[j])
            };
        }
        std::mem::swap(&mut prev, &mut curr);
    }
    prev[m]
}

/// ROUGE-L F1 (β = 1) between candidate and reference token sequences.
pub fn rouge_l<T: PartialEq>(candidate: &[T], reference: &[T]) -> f64 {
    if candidate.is_empty() || reference.is_empty() {
        return 0.0;
    }
    let lcs = lcs_len(candidate, reference) as f64;
    if lcs == 0.0 {
        return 0.0;
    }
    let p = lcs / candidate.len() as f64;
    let r = lcs / reference.len() as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences_score_one() {
        let a = [1u32, 2, 3, 4];
        assert!((rouge_l(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_sequences_score_zero() {
        assert_eq!(rouge_l(&[1u32, 2], &[3u32, 4]), 0.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(rouge_l::<u32>(&[], &[1]), 0.0);
        assert_eq!(rouge_l::<u32>(&[1], &[]), 0.0);
    }

    #[test]
    fn known_value() {
        // cand = [a b c d], ref = [a c d e]: LCS = 3 (a c d)
        // P = 3/4, R = 3/4 → F1 = 3/4
        let c = ["a", "b", "c", "d"];
        let r = ["a", "c", "d", "e"];
        assert!((rouge_l(&c, &r) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn subsequence_not_substring() {
        // LCS handles gaps: [a x b y c] vs [a b c] → LCS 3
        let c = ["a", "x", "b", "y", "c"];
        let r = ["a", "b", "c"];
        let lcs = lcs_len(&c, &r);
        assert_eq!(lcs, 3);
    }

    #[test]
    fn order_sensitivity() {
        // reversed reference shares only a length-1 subsequence pattern
        let c = [1u32, 2, 3, 4, 5];
        let r = [5u32, 4, 3, 2, 1];
        assert_eq!(lcs_len(&c, &r), 1);
    }
}
