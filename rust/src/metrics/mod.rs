//! Evaluation metrics and cost accounting: ROUGE-L, perplexity, accuracy,
//! latency timers, and the analytic device-memory model used to reproduce
//! the paper's memory columns.

mod memory;
mod rouge;

pub use memory::{MemoryAccountant, MemoryBreakdown};
pub use rouge::rouge_l;

use crate::util::Stats;
use std::time::Instant;

/// Perplexity from a mean cross-entropy (nats).
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

/// Token-level accuracy: fraction of positions where `pred == target`,
/// counting only masked-in positions.
pub fn token_accuracy(preds: &[u32], targets: &[u32], mask: &[bool]) -> f64 {
    assert_eq!(preds.len(), targets.len());
    assert_eq!(preds.len(), mask.len());
    let mut hit = 0usize;
    let mut total = 0usize;
    for i in 0..preds.len() {
        if mask[i] {
            total += 1;
            if preds[i] == targets[i] {
                hit += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        hit as f64 / total as f64
    }
}

/// Wall-clock latency accumulator for per-step measurements
/// (the paper's "average latency per step" columns).
#[derive(Debug, Default)]
pub struct LatencyTimer {
    stats: Stats,
    current: Option<Instant>,
}

impl LatencyTimer {
    pub fn new() -> Self {
        LatencyTimer {
            stats: Stats::new(),
            current: None,
        }
    }

    pub fn start(&mut self) {
        self.current = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.current.take() {
            self.stats.push(t0.elapsed().as_secs_f64());
        }
    }

    /// Record an externally-measured duration.
    pub fn record(&mut self, seconds: f64) {
        self.stats.push(seconds);
    }

    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    pub fn std(&self) -> f64 {
        self.stats.std()
    }

    pub fn count(&self) -> u64 {
        self.stats.count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        // uniform over V: nll = ln V → ppl = V
        let v = 64.0f64;
        assert!((perplexity(v.ln()) - v).abs() < 1e-9);
    }

    #[test]
    fn token_accuracy_masked() {
        let preds = [1u32, 2, 3, 4];
        let tgts = [1u32, 9, 3, 9];
        let mask = [true, true, true, false];
        assert!((token_accuracy(&preds, &tgts, &mask) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn token_accuracy_empty_mask() {
        assert_eq!(token_accuracy(&[1], &[1], &[false]), 0.0);
    }

    #[test]
    fn latency_timer_accumulates() {
        let mut t = LatencyTimer::new();
        t.record(0.1);
        t.record(0.3);
        assert_eq!(t.count(), 2);
        assert!((t.mean() - 0.2).abs() < 1e-12);
    }
}
