//! Analytic device-memory model.
//!
//! The paper reports "maximum GPU memory usage during fine-tuning"; on this
//! CPU testbed we account the same quantities exactly: frozen weights (in
//! the representation each method stores), PEFT adapters + their Adam
//! state, peak activation memory of one forward/backward, and per-method
//! transient buffers (Smooth_D's full requantization copies, LLM.int8's
//! dequantized rows). Ratios between methods reproduce the paper's memory
//! columns; absolute GB obviously scale with model size.

use crate::methods::MethodKind;
use crate::model::{Model, ModelConfig};

/// Memory breakdown in bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryBreakdown {
    /// Frozen base weights in the method's storage format.
    pub frozen: usize,
    /// Embeddings / LM head / LayerNorms (FP32 in every method).
    pub fp32_common: usize,
    /// Trainable adapter parameters.
    pub adapters: usize,
    /// Optimizer state: Adam m+v plus the gradient buffer.
    pub optimizer: usize,
    /// Peak activation + cache memory of one train step.
    pub activations: usize,
    /// Per-step transient buffers specific to the method.
    pub transient: usize,
}

impl MemoryBreakdown {
    pub fn total(&self) -> usize {
        self.frozen
            + self.fp32_common
            + self.adapters
            + self.optimizer
            + self.activations
            + self.transient
    }
}

/// Computes [`MemoryBreakdown`]s for a model under a given method.
pub struct MemoryAccountant;

impl MemoryAccountant {
    /// Account a live model (uses each layer's actual storage bytes).
    pub fn account(
        model: &mut Model,
        kind: MethodKind,
        batch: usize,
        seq: usize,
    ) -> MemoryBreakdown {
        let cfg = model.cfg.clone();
        let frozen = model.frozen_linear_bytes();
        let adapters = model.trainable_params() * 4;
        let fp32_common = Self::fp32_common_bytes(&cfg);
        let optimizer = adapters * 3; // grad + m + v
        let activations = Self::activation_bytes(&cfg, batch, seq);
        let transient = Self::transient_bytes(&cfg, kind);
        MemoryBreakdown {
            frozen,
            fp32_common,
            adapters,
            optimizer,
            activations,
            transient,
        }
    }

    fn fp32_common_bytes(cfg: &ModelConfig) -> usize {
        let d = cfg.d_model;
        let emb = cfg.vocab * d + cfg.max_seq * d + d * cfg.vocab;
        let lns = cfg.n_layers * 2 * 2 * d + 2 * d;
        (emb + lns) * 4
    }

    /// Peak activation memory: per-block caches held for backward
    /// (inputs of each linear, attention probabilities, GELU inputs)
    /// plus the logits block.
    pub fn activation_bytes(cfg: &ModelConfig, batch: usize, seq: usize) -> usize {
        let t = batch * seq;
        let d = cfg.d_model;
        let ff = cfg.d_ff;
        // per block: ~8 d-wide tensors (x, ln-out, q,k,v, attn-out, o, mlp-in)
        // + 2 ff-wide (u, gelu) + attention probs (batch·heads·seq²)
        let per_block = 8 * t * d + 2 * t * ff + batch * cfg.n_heads * seq * seq;
        (cfg.n_layers * per_block + t * cfg.vocab) * 4
    }

    /// Transient per-step buffers characteristic of each method.
    fn transient_bytes(cfg: &ModelConfig, kind: MethodKind) -> usize {
        let d = cfg.d_model;
        let ff = cfg.d_ff;
        let per_layer_w: usize = 4 * d * d + 2 * d * ff; // elements across a block
        match kind {
            // Smooth_D rescales + requantizes the whole block's weights each
            // step: one f32 scaled copy + one int8 quantized copy in flight.
            MethodKind::SmoothDynamic => cfg.n_layers * per_layer_w * 5,
            // LLM.int8 dequantizes detected outlier rows; worst observed in
            // the paper is card(O) → c_in, bound here at 25 % of rows.
            MethodKind::LlmInt8 => cfg.n_layers * per_layer_w, // 25% of rows in f32 = w/4*4
            // Quaff quantizes only the tiny ŵ slice (≤5 % of rows).
            MethodKind::Quaff | MethodKind::QuaffNoMomentum => {
                cfg.n_layers * per_layer_w / 5
            }
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::MethodConfig;
    use crate::outlier::{BudgetAllocator, BudgetPolicy, OutlierDetector};
    use crate::peft::PeftKind;
    use crate::util::prng::Rng;

    fn quantized_model(kind: MethodKind) -> Model {
        let cfg = ModelConfig::preset("opt-tiny").unwrap();
        let mut m = Model::new(cfg, 1);
        m.attach_peft(PeftKind::Lora);
        let mut r = Rng::new(2);
        m.start_calibration();
        let toks: Vec<Vec<u32>> = (0..2)
            .map(|_| (0..16).map(|_| r.below(288) as u32).collect())
            .collect();
        let _ = m.forward(&toks, false);
        let calib = m.finish_calibration();
        let alloc = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
        let det = OutlierDetector::new(20.0);
        let _ = m.apply_method(kind, &calib, &alloc, &MethodConfig::default(), &det);
        m
    }

    #[test]
    fn quantized_total_below_fp32() {
        let mut fp = quantized_model(MethodKind::Fp32);
        let mut nv = quantized_model(MethodKind::Naive);
        let a = MemoryAccountant::account(&mut fp, MethodKind::Fp32, 4, 32);
        let b = MemoryAccountant::account(&mut nv, MethodKind::Naive, 4, 32);
        assert!(b.total() < a.total(), "naive {} < fp32 {}", b.total(), a.total());
        assert!(b.frozen < a.frozen / 3);
    }

    #[test]
    fn smooth_dynamic_at_least_fp32() {
        let mut fp = quantized_model(MethodKind::Fp32);
        let mut sd = quantized_model(MethodKind::SmoothDynamic);
        let a = MemoryAccountant::account(&mut fp, MethodKind::Fp32, 4, 32);
        let b = MemoryAccountant::account(&mut sd, MethodKind::SmoothDynamic, 4, 32);
        assert!(b.total() >= a.total(), "Smooth_D must not be below FP32");
    }

    #[test]
    fn quaff_close_to_naive() {
        let mut nv = quantized_model(MethodKind::Naive);
        let mut qf = quantized_model(MethodKind::Quaff);
        let a = MemoryAccountant::account(&mut nv, MethodKind::Naive, 4, 32).total();
        let b = MemoryAccountant::account(&mut qf, MethodKind::Quaff, 4, 32).total();
        // paper: 14.6 GB vs 14.9 GB → within a few percent
        let ratio = b as f64 / a as f64;
        assert!(ratio < 1.10, "quaff/naive memory ratio {ratio}");
    }

    #[test]
    fn activations_scale_with_batch() {
        let cfg = ModelConfig::preset("phi-mini").unwrap();
        let a = MemoryAccountant::activation_bytes(&cfg, 1, 64);
        let b = MemoryAccountant::activation_bytes(&cfg, 4, 64);
        assert!(b > 3 * a && b < 5 * a);
    }
}
