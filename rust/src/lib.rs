//! # Quaff — Quantized Parameter-Efficient Fine-Tuning under OSSH
//!
//! A full-system reproduction of *"Quaff: Quantized Parameter-Efficient
//! Fine-Tuning under Outlier Spatial Stability Hypothesis"* (ACL 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the runtime: INT8 quantization substrate, the six
//!   WAQ methods (FP32 / Naive / LLM.int8 / Smooth_S / Smooth_D / Quaff), a
//!   trainable decoder-only transformer with PEFT adapters, the KV-cached
//!   batched inference engine (`infer`), the calibration + server–client
//!   coordinator, the crash-safe checkpoint/resume + quantized-bundle
//!   persistence tier (`persist`, on the `util::codec` binary format), the
//!   PJRT runtime that executes AOT-compiled JAX artifacts, and the report
//!   harness regenerating every paper table and figure.
//! * **L2 (`python/compile/model.py`)** — the JAX model + LoRA train step,
//!   lowered once to HLO text by `python/compile/aot.py`.
//! * **L1 (`python/compile/kernels/`)** — the fused Pallas quantized-linear
//!   kernel (interpret mode on CPU; MXU-shaped block specs for TPU).
//!
//! See `DESIGN.md` for the system inventory, the execution-engine /
//! workspace architecture, the `tensor::pool` threading model
//! (`QUAFF_THREADS`, deterministic row-sharding), the compiled per-layer
//! execution plans every quantized linear runs on (`quant::pipeline`,
//! DESIGN.md §7), and the `pjrt` feature; `BENCH_kernels.json` /
//! `BENCH_threads.json` / `BENCH_qgemm.json` (emitted by `cargo bench`)
//! record the perf trajectory guarded by the CI bench gate.

pub mod coordinator;
pub mod data;
pub mod infer;
pub mod methods;
pub mod metrics;
pub mod model;
pub mod outlier;
pub mod peft;
pub mod persist;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod scaling;
pub mod tensor;
pub mod train;
pub mod util;
