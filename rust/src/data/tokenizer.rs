//! Byte-level tokenizer with special tokens.
//!
//! Token ids 0–255 are raw bytes; PAD/BOS/EOS live above. The model vocab
//! (288) leaves headroom for future specials. Byte-level keeps the
//! tokenizer dependency-free and exactly reversible — dataset difficulty is
//! controlled by the synthetic generators, not the vocabulary.

/// Raw byte range size.
pub const BYTE_TOKENS: u32 = 256;
pub const PAD: u32 = 256;
pub const BOS: u32 = 257;
pub const EOS: u32 = 258;
/// Model vocabulary size (power-of-two-ish headroom above specials).
pub const VOCAB_SIZE: usize = 288;

/// Byte-level tokenizer.
#[derive(Clone, Debug, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Tokenizer {
        Tokenizer
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32).collect()
    }

    /// Decode ids back to text; specials and out-of-range ids are dropped,
    /// invalid UTF-8 is replaced.
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| t < BYTE_TOKENS)
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new();
        let s = "Q: what is 2+2? A: four.";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = Tokenizer::new();
        let s = "héllo — ∑";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let t = Tokenizer::new();
        let mut ids = t.encode("ab");
        ids.insert(0, BOS);
        ids.push(EOS);
        ids.push(PAD);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn specials_fit_vocab() {
        assert!((PAD as usize) < VOCAB_SIZE);
        assert!((BOS as usize) < VOCAB_SIZE);
        assert!((EOS as usize) < VOCAB_SIZE);
    }
}
