//! Synthetic benchmark families standing in for the paper's ten datasets.
//!
//! Each family generates a *learnable* supervised mapping whose difficulty
//! and sequence profile mirrors the benchmark it substitutes (DESIGN.md §2):
//!
//! * [`TaskFamily::Instruction`] — Oasst1 / Self-Instruct / Finance-Alpaca /
//!   HH-RLHF / OIG-Chip2 analogues: "Q: … A: …" pairs where the answer is a
//!   domain-specific lexical transformation of the question words. Domains
//!   differ by seed (vocabulary + substitution table), giving four/five
//!   distinct distributions like Table 1's columns.
//! * [`TaskFamily::Mcq`] — GPQA / MathQA / MMLU-Pro analogues: a stem plus
//!   four options in the paper's prompt format; the correct option is the
//!   domain transform of the stem keyword; the reference text is
//!   "The answer is X" so accuracy is measured at the letter position.
//! * [`TaskFamily::Lambada`] — long-context last-word prediction: the final
//!   word repeats a word introduced early in a long filler context.
//! * [`TaskFamily::LongForm`] — instruction → long structured generation
//!   (pattern expansion), for the 4K-generation table.

use super::tokenizer::Tokenizer;
use super::Sample;
use crate::util::prng::Rng;

/// Which benchmark family to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskFamily {
    Instruction,
    Mcq,
    Lambada,
    LongForm,
}

/// A synthetic benchmark: family + domain seed + size profile.
#[derive(Clone, Debug)]
pub struct SynthTask {
    pub name: String,
    pub family: TaskFamily,
    /// Domain seed: different seeds → different vocab/mapping (different
    /// "datasets" of the same family).
    pub domain_seed: u64,
    /// Approximate context length in tokens (Lambada/LongForm use this).
    pub context_len: usize,
    tok: Tokenizer,
    /// Domain word list.
    words: Vec<String>,
    /// Lexical substitution table: words[i] → words[sub[i]].
    sub: Vec<usize>,
}

/// Named dataset analogues (paper §4.1).
pub const INSTRUCTION_SETS: [&str; 5] =
    ["oasst1", "self-instruct", "finance-alpaca", "hh-rlhf", "oig-chip2"];
pub const REASONING_SETS: [&str; 3] = ["gpqa", "mathqa", "mmlu-pro"];
pub const LONGTEXT_SETS: [&str; 2] = ["longform", "lambada"];

impl SynthTask {
    pub fn new(name: &str, family: TaskFamily, domain_seed: u64, context_len: usize) -> SynthTask {
        let mut rng = Rng::new(domain_seed ^ 0x5EED_F00D);
        // Domain vocabulary: short pronounceable words, domain-specific.
        let consonants = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z"];
        let vowels = ["a", "e", "i", "o", "u"];
        let mut words = Vec::with_capacity(24);
        while words.len() < 24 {
            let w = format!(
                "{}{}{}{}",
                rng.pick(&consonants),
                rng.pick(&vowels),
                rng.pick(&consonants),
                rng.pick(&vowels)
            );
            if !words.contains(&w) {
                words.push(w);
            }
        }
        // Substitution table: a random derangement-ish permutation.
        let mut sub: Vec<usize> = (0..words.len()).collect();
        rng.shuffle(&mut sub);
        SynthTask {
            name: name.to_string(),
            family,
            domain_seed,
            context_len,
            tok: Tokenizer::new(),
            words,
            sub,
        }
    }

    /// Standard instances by dataset name (maps the paper's ten benchmarks).
    pub fn by_name(name: &str) -> Option<SynthTask> {
        let inst = |n: &str, seed| Some(SynthTask::new(n, TaskFamily::Instruction, seed, 64));
        match name {
            "oasst1" => inst(name, 101),
            "self-instruct" => inst(name, 102),
            "finance-alpaca" => inst(name, 103),
            "hh-rlhf" => inst(name, 104),
            "oig-chip2" => inst(name, 105),
            "gpqa" => Some(SynthTask::new(name, TaskFamily::Mcq, 201, 96)),
            "mathqa" => Some(SynthTask::new(name, TaskFamily::Mcq, 202, 96)),
            "mmlu-pro" => Some(SynthTask::new(name, TaskFamily::Mcq, 203, 96)),
            "lambada" => Some(SynthTask::new(name, TaskFamily::Lambada, 301, 192)),
            "longform" => Some(SynthTask::new(name, TaskFamily::LongForm, 302, 192)),
            _ => None,
        }
    }

    fn word(&self, i: usize) -> &str {
        &self.words[i % self.words.len()]
    }

    /// The learnable transform: word i → word sub[i].
    fn transform(&self, i: usize) -> &str {
        &self.words[self.sub[i % self.words.len()]]
    }

    /// Generate one sample.
    pub fn sample(&self, rng: &mut Rng) -> Sample {
        match self.family {
            TaskFamily::Instruction => self.gen_instruction(rng),
            TaskFamily::Mcq => self.gen_mcq(rng),
            TaskFamily::Lambada => self.gen_lambada(rng),
            TaskFamily::LongForm => self.gen_longform(rng),
        }
    }

    fn gen_instruction(&self, rng: &mut Rng) -> Sample {
        let n = 2 + rng.below(4);
        let idxs: Vec<usize> = (0..n).map(|_| rng.below(self.words.len())).collect();
        let q: Vec<&str> = idxs.iter().map(|&i| self.word(i)).collect();
        let a: Vec<&str> = idxs.iter().map(|&i| self.transform(i)).collect();
        Sample {
            prompt: self.tok.encode(&format!("Q: {} A:", q.join(" "))),
            target: self.tok.encode(&format!(" {}", a.join(" "))),
        }
    }

    /// Paper's reasoning prompt format:
    /// "#Input Please select one of the following options: (A)… (D)…"
    /// reference: "The answer is #Correct."
    fn gen_mcq(&self, rng: &mut Rng) -> Sample {
        let stem_i = rng.below(self.words.len());
        let correct = self.transform(stem_i).to_string();
        // distractors: three other words
        let mut opts: Vec<String> = vec![correct.clone()];
        while opts.len() < 4 {
            let w = self.word(rng.below(self.words.len())).to_string();
            if !opts.contains(&w) {
                opts.push(w);
            }
        }
        rng.shuffle(&mut opts);
        let correct_pos = opts.iter().position(|w| *w == correct).unwrap();
        let letter = ["A", "B", "C", "D"][correct_pos];
        let prompt = format!(
            "#{} Please select one of the following options: (A) {}. (B) {}. (C) {}. (D) {}.",
            self.word(stem_i),
            opts[0],
            opts[1],
            opts[2],
            opts[3]
        );
        Sample {
            prompt: self.tok.encode(&prompt),
            target: self.tok.encode(&format!(" The answer is {letter}.")),
        }
    }

    fn gen_lambada(&self, rng: &mut Rng) -> Sample {
        // a "story" of filler words; one keyword planted early; the final
        // word must repeat the keyword (long-range retrieval).
        let key_i = rng.below(self.words.len());
        let key = self.word(key_i).to_string();
        let filler_n = (self.context_len / 5).max(8);
        let mut parts: Vec<String> = Vec::with_capacity(filler_n + 2);
        parts.push(format!("the {key} said"));
        for _ in 0..filler_n {
            parts.push(self.word(rng.below(self.words.len())).to_string());
        }
        let ctx = parts.join(" ");
        Sample {
            prompt: self.tok.encode(&format!("{ctx} . so spoke the")),
            target: self.tok.encode(&format!(" {key}")),
        }
    }

    fn gen_longform(&self, rng: &mut Rng) -> Sample {
        // "expand <w> x<n>" → the transform of w repeated n times with
        // separators: long, fully-determined output.
        let i = rng.below(self.words.len());
        let reps = (self.context_len / (self.words[0].len() + 2)).clamp(4, 64);
        let out: Vec<&str> = (0..reps).map(|_| self.transform(i)).collect();
        Sample {
            prompt: self.tok.encode(&format!("expand {} x{} :", self.word(i), reps)),
            target: self.tok.encode(&format!(" {}", out.join(", "))),
        }
    }

    /// For MCQ eval: the four option-letter token ids (byte tokens).
    pub fn option_letter_tokens() -> [u32; 4] {
        [b'A' as u32, b'B' as u32, b'C' as u32, b'D' as u32]
    }

    /// For MCQ eval: position offset of the letter within the target
    /// (" The answer is X." → index of X).
    pub fn mcq_letter_offset() -> usize {
        " The answer is ".len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_domain_seed() {
        let a = SynthTask::new("x", TaskFamily::Instruction, 7, 64);
        let b = SynthTask::new("x", TaskFamily::Instruction, 7, 64);
        let c = SynthTask::new("x", TaskFamily::Instruction, 8, 64);
        assert_eq!(a.words, b.words);
        assert_ne!(a.words, c.words);
    }

    #[test]
    fn instruction_mapping_consistent() {
        let t = SynthTask::by_name("oasst1").unwrap();
        let mut rng = Rng::new(1);
        // same question words always map to the same answer words
        let tok = Tokenizer::new();
        let s1 = t.sample(&mut rng);
        let q = tok.decode(&s1.prompt);
        let a = tok.decode(&s1.target);
        assert!(q.starts_with("Q: ") && q.ends_with(" A:"), "{q}");
        assert!(!a.is_empty());
        // transform is a function: generate many, build map, check consistency
        let mut map = std::collections::HashMap::new();
        for _ in 0..200 {
            let s = t.sample(&mut rng);
            let qs = tok.decode(&s.prompt);
            let as_ = tok.decode(&s.target);
            let qw: Vec<&str> = qs[3..qs.len() - 3].split(' ').collect();
            let aw: Vec<&str> = as_.trim().split(' ').collect();
            assert_eq!(qw.len(), aw.len());
            for (q, a) in qw.iter().zip(&aw) {
                let prev = map.insert(q.to_string(), a.to_string());
                if let Some(p) = prev {
                    assert_eq!(&p, a, "mapping must be a function: {q}");
                }
            }
        }
        assert!(map.len() > 10);
    }

    #[test]
    fn mcq_has_exactly_one_correct_letter() {
        let t = SynthTask::by_name("gpqa").unwrap();
        let tok = Tokenizer::new();
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let s = t.sample(&mut rng);
            let target = tok.decode(&s.target);
            assert!(target.starts_with(" The answer is "));
            let letter = target.as_bytes()[SynthTask::mcq_letter_offset()] as char;
            assert!(('A'..='D').contains(&letter), "{target}");
            let prompt = tok.decode(&s.prompt);
            assert!(prompt.contains("(A)") && prompt.contains("(D)"));
        }
    }

    #[test]
    fn mcq_answer_follows_transform_rule() {
        let t = SynthTask::by_name("gpqa").unwrap();
        let tok = Tokenizer::new();
        let mut rng = Rng::new(3);
        let s = t.sample(&mut rng);
        let prompt = tok.decode(&s.prompt);
        let target = tok.decode(&s.target);
        // stem word
        let stem = prompt[1..].split(' ').next().unwrap();
        let stem_idx = t.words.iter().position(|w| w == stem).unwrap();
        let expect = t.transform(stem_idx);
        // the lettered option equals the transform
        let letter = target.as_bytes()[SynthTask::mcq_letter_offset()] as char;
        let marker = format!("({letter}) {expect}.");
        assert!(prompt.contains(&marker), "{prompt} :: {marker}");
    }

    #[test]
    fn lambada_key_planted_early_and_answer_matches() {
        let t = SynthTask::by_name("lambada").unwrap();
        let tok = Tokenizer::new();
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let s = t.sample(&mut rng);
            let prompt = tok.decode(&s.prompt);
            let key = tok.decode(&s.target);
            let key = key.trim();
            assert!(prompt.starts_with(&format!("the {key} said")), "{prompt}");
            assert!(prompt.ends_with("so spoke the"));
            assert!(s.prompt.len() > 100, "long context expected");
        }
    }

    #[test]
    fn longform_output_is_long_and_regular() {
        let t = SynthTask::by_name("longform").unwrap();
        let mut rng = Rng::new(5);
        let s = t.sample(&mut rng);
        assert!(s.target.len() > 100);
        let tok = Tokenizer::new();
        let out = tok.decode(&s.target);
        let parts: Vec<&str> = out.trim().split(", ").collect();
        assert!(parts.len() >= 4);
        assert!(parts.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn all_named_benchmarks_resolve() {
        for n in INSTRUCTION_SETS.iter().chain(&REASONING_SETS).chain(&LONGTEXT_SETS) {
            assert!(SynthTask::by_name(n).is_some(), "{n}");
        }
        assert!(SynthTask::by_name("imagenet").is_none());
    }
}
