//! Data pipeline: byte-level tokenizer, the synthetic benchmark-family
//! generators standing in for the paper's ten datasets (DESIGN.md §2), the
//! embedded tiny corpus for the end-to-end run, and batching/calibration
//! sampling utilities.

mod synth;
mod tokenizer;

pub use synth::{SynthTask, TaskFamily, INSTRUCTION_SETS, LONGTEXT_SETS, REASONING_SETS};
pub use tokenizer::{Tokenizer, BOS, EOS, PAD, VOCAB_SIZE};

use crate::util::prng::Rng;

/// One supervised sample: `prompt` tokens conditioned on, `target` tokens
/// carrying the loss (instruction-tuning style).
#[derive(Clone, Debug)]
pub struct Sample {
    pub prompt: Vec<u32>,
    pub target: Vec<u32>,
}

impl Sample {
    /// Total sequence length once packed (prompt + target + EOS).
    pub fn packed_len(&self) -> usize {
        self.prompt.len() + self.target.len() + 1
    }
}

/// A train/test split of samples.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub name: String,
    pub train: Vec<Sample>,
    pub test: Vec<Sample>,
}

impl Dataset {
    /// Random 80/20 split (the paper's protocol for datasets without a
    /// predefined split).
    pub fn from_samples(name: &str, mut samples: Vec<Sample>, rng: &mut Rng) -> Dataset {
        rng.shuffle(&mut samples);
        let n_train = samples.len() * 4 / 5;
        let test = samples.split_off(n_train);
        Dataset {
            name: name.to_string(),
            train: samples,
            test,
        }
    }

    /// Cyclic mini-batch iterator state.
    pub fn batches(&self, batch_size: usize) -> BatchIter<'_> {
        BatchIter {
            samples: &self.train,
            batch_size,
            cursor: 0,
        }
    }
}

/// Cycles through training samples in fixed-size batches.
pub struct BatchIter<'a> {
    samples: &'a [Sample],
    batch_size: usize,
    cursor: usize,
}

impl<'a> BatchIter<'a> {
    pub fn next_batch(&mut self) -> Vec<&'a Sample> {
        let mut out = Vec::with_capacity(self.batch_size);
        for _ in 0..self.batch_size {
            out.push(&self.samples[self.cursor]);
            self.cursor = (self.cursor + 1) % self.samples.len();
        }
        out
    }

    /// Current position in the cyclic pool (persistence).
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// Restore a persisted position, so a resumed run draws exactly the
    /// batches the uninterrupted run would have drawn next.
    pub fn seek(&mut self, cursor: usize) {
        self.cursor = if self.samples.is_empty() {
            0
        } else {
            cursor % self.samples.len()
        };
    }
}

/// Pack a batch of samples into padded token rows + loss masks.
/// Row layout: `BOS prompt… target… EOS PAD…`; the mask is true exactly on
/// positions whose *next-token prediction target* is a target token or the
/// EOS closing it.
pub fn pack_batch(samples: &[&Sample], max_len: usize) -> (Vec<Vec<u32>>, Vec<Vec<bool>>) {
    let longest = samples
        .iter()
        .map(|s| s.packed_len() + 1) // + BOS
        .max()
        .unwrap_or(1)
        .min(max_len);
    let mut tokens = Vec::with_capacity(samples.len());
    let mut masks = Vec::with_capacity(samples.len());
    for s in samples {
        let mut row = Vec::with_capacity(longest);
        row.push(BOS);
        row.extend_from_slice(&s.prompt);
        let target_start = row.len(); // first target position
        row.extend_from_slice(&s.target);
        row.push(EOS);
        row.truncate(longest);
        // mask[i] == true ⇔ position i's next token (i+1) is target/EOS
        let mut mask = vec![false; longest];
        for i in 0..longest.saturating_sub(1) {
            let next = i + 1;
            if next >= target_start && next < row.len() {
                mask[i] = true;
            }
        }
        while row.len() < longest {
            row.push(PAD);
        }
        tokens.push(row);
        masks.push(mask);
    }
    (tokens, masks)
}

/// The calibration sampler: `n` prompts drawn from a task family
/// (paper: 512 samples of OIG/Chip2).
pub fn calibration_batches(
    task: &SynthTask,
    n_samples: usize,
    batch_size: usize,
    max_len: usize,
    rng: &mut Rng,
) -> Vec<Vec<Vec<u32>>> {
    let samples: Vec<Sample> = (0..n_samples).map(|_| task.sample(rng)).collect();
    samples
        .chunks(batch_size)
        .map(|chunk| {
            let refs: Vec<&Sample> = chunk.iter().collect();
            pack_batch(&refs, max_len).0
        })
        .collect()
}

/// Embedded tiny plain-text corpus for the end-to-end language-modeling
/// example (public-domain-style prose, a few KB).
pub const TINY_CORPUS: &str = include_str!("tiny_corpus.txt");

/// Chunk the embedded corpus into LM samples of `seq_len` bytes.
pub fn corpus_samples(tok: &Tokenizer, seq_len: usize) -> Vec<Sample> {
    let ids = tok.encode(TINY_CORPUS);
    ids.chunks(seq_len)
        .filter(|c| c.len() == seq_len)
        .map(|c| Sample {
            prompt: Vec::new(),
            target: c.to_vec(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_masks_target_positions_only() {
        let s = Sample {
            prompt: vec![10, 11],
            target: vec![20, 21],
        };
        let (toks, masks) = pack_batch(&[&s], 32);
        let row = &toks[0];
        let mask = &masks[0];
        assert_eq!(row[0], BOS);
        assert_eq!(&row[1..3], &[10, 11]);
        assert_eq!(&row[3..5], &[20, 21]);
        assert_eq!(row[5], EOS);
        // row: BOS 10 11 20 21 EOS → target_start = 3
        // mask[i] ⇔ next position (i+1) ∈ {3,4,5} (targets + EOS)
        assert_eq!(&mask[..6], &[false, false, true, true, true, false]);
    }

    #[test]
    fn pack_mask_semantics() {
        let s = Sample {
            prompt: vec![10],
            target: vec![20],
        };
        let (toks, masks) = pack_batch(&[&s], 8);
        // row: BOS 10 20 EOS → target_start = 2
        // mask[1] (predicting row[2]=20) and mask[2] (predicting EOS) true
        assert_eq!(toks[0][..4], [BOS, 10, 20, EOS]);
        assert_eq!(&masks[0][..4], &[false, true, true, false]);
    }

    #[test]
    fn pack_pads_to_longest() {
        let a = Sample {
            prompt: vec![1],
            target: vec![2],
        };
        let b = Sample {
            prompt: vec![1, 2, 3, 4],
            target: vec![5, 6],
        };
        let (toks, _) = pack_batch(&[&a, &b], 64);
        assert_eq!(toks[0].len(), toks[1].len());
        assert!(toks[0].iter().rev().take(3).all(|&t| t == PAD));
    }

    #[test]
    fn pack_truncates_at_max_len() {
        let s = Sample {
            prompt: (0..100).collect(),
            target: (0..100).collect(),
        };
        let (toks, masks) = pack_batch(&[&s], 32);
        assert_eq!(toks[0].len(), 32);
        assert_eq!(masks[0].len(), 32);
    }

    #[test]
    fn split_is_80_20_and_disjoint() {
        let mut rng = Rng::new(1);
        let samples: Vec<Sample> = (0..100)
            .map(|i| Sample {
                prompt: vec![i],
                target: vec![i + 1000],
            })
            .collect();
        let ds = Dataset::from_samples("t", samples, &mut rng);
        assert_eq!(ds.train.len(), 80);
        assert_eq!(ds.test.len(), 20);
        let train_ids: std::collections::HashSet<u32> =
            ds.train.iter().map(|s| s.prompt[0]).collect();
        assert!(ds.test.iter().all(|s| !train_ids.contains(&s.prompt[0])));
    }

    #[test]
    fn batch_iter_cycles() {
        let rng = Rng::new(2);
        let samples: Vec<Sample> = (0..5)
            .map(|i| Sample {
                prompt: vec![i],
                target: vec![0],
            })
            .collect();
        let ds = Dataset {
            name: "t".into(),
            train: samples,
            test: vec![],
        };
        let mut it = ds.batches(3);
        let b1 = it.next_batch();
        let b2 = it.next_batch();
        assert_eq!(b1.len(), 3);
        assert_eq!(b2[0].prompt[0], 3);
        assert_eq!(b2[2].prompt[0], 0); // wrapped
        let _ = rng;
    }

    #[test]
    fn corpus_nonempty_and_chunks() {
        let tok = Tokenizer::new();
        assert!(TINY_CORPUS.len() > 2000, "corpus too small");
        let samples = corpus_samples(&tok, 64);
        assert!(samples.len() > 10);
        assert!(samples.iter().all(|s| s.target.len() == 64));
    }
}
