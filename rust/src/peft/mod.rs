//! Parameter-efficient fine-tuning methods (§4.1: LoRA, Prompt tuning,
//! P-tuning, IA3) — the trainable state Quaff fine-tunes around the frozen,
//! quantized base weights.

use crate::model::param::Param;
use crate::tensor::{kernels, Matrix, Workspace};
use crate::util::prng::Rng;

/// PEFT strategy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeftKind {
    /// LoRA on q_proj/v_proj, rank 16, α 16 (paper hyper-params).
    Lora,
    /// Prompt tuning: 20 learnable virtual token embeddings.
    Prompt,
    /// P-tuning: virtual tokens produced by a learnable MLP encoder.
    PTuning,
    /// IA3: learned rescaling of K, V and FFN activations.
    Ia3,
}

impl PeftKind {
    pub const ALL: [PeftKind; 4] = [
        PeftKind::Lora,
        PeftKind::Prompt,
        PeftKind::PTuning,
        PeftKind::Ia3,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            PeftKind::Lora => "LoRA",
            PeftKind::Prompt => "Prompt",
            PeftKind::PTuning => "P-Tuning",
            PeftKind::Ia3 => "IA3",
        }
    }

    pub fn parse(s: &str) -> Option<PeftKind> {
        match s.to_ascii_lowercase().as_str() {
            "lora" => Some(PeftKind::Lora),
            "prompt" => Some(PeftKind::Prompt),
            "ptuning" | "p-tuning" | "p_tuning" => Some(PeftKind::PTuning),
            "ia3" => Some(PeftKind::Ia3),
            _ => None,
        }
    }
}

/// LoRA adapter for one linear layer: `ΔY = (X·A)·B · (α/r)`.
/// A: (c_in × r) Gaussian init, B: (r × c_out) zero init (so ΔY starts at 0).
pub struct LoraAdapter {
    pub a: Param,
    pub b: Param,
    pub scale: f32,
    pub dropout: f32,
}

/// Forward cache for the adapter backward pass.
pub struct LoraCache {
    /// Input X (t × c_in) — needed for dA.
    x: Matrix,
    /// Hidden X·A (t × r) — needed for dB.
    h: Matrix,
}

impl LoraAdapter {
    pub fn new(cin: usize, cout: usize, rank: usize, alpha: f32, dropout: f32, rng: &mut Rng) -> Self {
        let std = 1.0 / (cin as f32).sqrt();
        LoraAdapter {
            a: Param::new(Matrix::randn(cin, rank, rng, std)),
            b: Param::zeros(rank, cout),
            scale: alpha / rank as f32,
            dropout,
        }
    }

    /// ΔY for input `x`; dropout is applied to the adapter input during
    /// training (inverted dropout, like the HF PEFT implementation).
    pub fn forward(&self, x: &Matrix, train: bool, rng: &mut Rng) -> (Matrix, LoraCache) {
        let xd = if train && self.dropout > 0.0 {
            let keep = 1.0 - self.dropout;
            let mut xd = x.clone();
            for v in xd.data_mut() {
                if rng.chance(self.dropout) {
                    *v = 0.0;
                } else {
                    *v /= keep;
                }
            }
            xd
        } else {
            x.clone()
        };
        let h = xd.matmul(&self.a.value);
        let mut dy = h.matmul(&self.b.value);
        dy.scale(self.scale);
        (dy, LoraCache { x: xd, h })
    }

    /// Inference-mode ΔY: no dropout, no cache, no RNG — bit-identical to
    /// [`LoraAdapter::forward`] with `train = false`. Buffers come from the
    /// workspace; callers hand the returned delta back via
    /// [`Workspace::recycle`].
    pub fn delta_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let mut h = ws.take_matrix("lora.inf.h", x.rows(), self.a.value.cols());
        kernels::matmul_into(x, &self.a.value, &mut h);
        let mut dy = ws.take_matrix("lora.inf.dy", x.rows(), self.b.value.cols());
        kernels::matmul_into(&h, &self.b.value, &mut dy);
        dy.scale(self.scale);
        ws.put_matrix("lora.inf.h", h);
        dy
    }

    /// Backward: accumulates dA, dB; returns the adapter's contribution to
    /// dX (to be added to the frozen path's input gradient).
    pub fn backward(&mut self, d_out: &Matrix, cache: &LoraCache) -> Matrix {
        // dB += (X·A)ᵀ · dY · scale
        let mut db = cache.h.matmul_at(d_out);
        db.scale(self.scale);
        self.b.accumulate(&db);
        // dH = dY · Bᵀ · scale
        let mut dh = d_out.matmul_bt(&self.b.value);
        dh.scale(self.scale);
        // dA += Xᵀ · dH
        let da = cache.x.matmul_at(&dh);
        self.a.accumulate(&da);
        // dX = dH · Aᵀ
        dh.matmul_bt(&self.a.value)
    }

    pub fn trainable_params(&self) -> usize {
        self.a.numel() + self.b.numel()
    }
}

/// IA3 learned per-channel scaling vector: `Y = X ∘ l` (broadcast rows).
/// Init at 1 so the model starts unmodified.
pub struct Ia3Vector {
    pub l: Param,
}

impl Ia3Vector {
    pub fn new(dim: usize) -> Self {
        Ia3Vector {
            l: Param::new(Matrix::from_vec(1, dim, vec![1.0; dim])),
        }
    }

    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut y = x.clone();
        y.scale_cols(self.l.value.row(0));
        y
    }

    /// Accumulates dl and returns dX.
    pub fn backward(&mut self, dy: &Matrix, x: &Matrix) -> Matrix {
        let dim = x.cols();
        let mut dl = vec![0.0f32; dim];
        for t in 0..x.rows() {
            let xr = x.row(t);
            let dr = dy.row(t);
            for j in 0..dim {
                dl[j] += xr[j] * dr[j];
            }
        }
        self.l.accumulate(&Matrix::from_vec(1, dim, dl));
        let mut dx = dy.clone();
        dx.scale_cols(self.l.value.row(0));
        dx
    }
}

/// Prompt tuning state: `n_virtual` learnable embeddings prepended to the
/// input sequence (positions shift right; virtual positions carry no loss).
pub struct PromptTuning {
    pub embeddings: Param,
}

impl PromptTuning {
    pub fn new(n_virtual: usize, d: usize, rng: &mut Rng) -> Self {
        PromptTuning {
            embeddings: Param::new(Matrix::randn(n_virtual, d, rng, 0.02)),
        }
    }

    pub fn n_virtual(&self) -> usize {
        self.embeddings.value.rows()
    }

    /// Virtual token block for one batch element.
    pub fn virtual_block(&self) -> Matrix {
        self.embeddings.value.clone()
    }

    /// Accumulate gradient from the virtual-token positions of one batch
    /// element's input gradient.
    pub fn accumulate(&mut self, d_virtual: &Matrix) {
        self.embeddings.accumulate(d_virtual);
    }
}

/// Per-block adapter pair for one tenant: LoRA deltas on the q/v
/// projections — the only layers [`PeftKind::Lora`] adapts.
pub struct TenantBlockAdapters {
    pub q: Option<LoraAdapter>,
    pub v: Option<LoraAdapter>,
}

/// One tenant's detachable adapter stack over a shared frozen base:
/// per-block LoRA q/v adapters plus an optional prompt-tuning block.
/// Detached from a fine-tuned model (`Model::detach_adapters`), installed
/// into an `infer::AdapterRegistry`, and applied per decode row in the
/// qgemm epilogue — many tenants share one quantized base with no f32
/// weight rematerialization. The scope is LoRA + Prompt: IA3/P-Tuning
/// reshape shared activations (diagonal gains / encoder forward), which
/// is not row-local per tenant and therefore not batch-mixable.
pub struct TenantAdapters {
    /// One entry per model block, indexed by layer.
    pub blocks: Vec<TenantBlockAdapters>,
    /// Tenant-owned virtual token embeddings (prompt tuning). When set,
    /// the tenant's requests carry `n_virtual()` virtual rows; the shared
    /// base itself stays bare.
    pub prompt: Option<PromptTuning>,
}

impl TenantAdapters {
    /// An adapter-free stack for a model of `n_blocks` layers (a tenant
    /// that decodes the bare base).
    pub fn empty(n_blocks: usize) -> TenantAdapters {
        TenantAdapters {
            blocks: (0..n_blocks)
                .map(|_| TenantBlockAdapters { q: None, v: None })
                .collect(),
            prompt: None,
        }
    }

    /// Virtual tokens this tenant's requests prepend (0 without prompt
    /// tuning).
    pub fn n_virtual(&self) -> usize {
        self.prompt.as_ref().map(|p| p.n_virtual()).unwrap_or(0)
    }

    /// Does the stack carry any adapter at all?
    pub fn is_empty(&self) -> bool {
        self.prompt.is_none() && self.blocks.iter().all(|b| b.q.is_none() && b.v.is_none())
    }

    /// Bytes of per-tenant adapter state (f32) — the marginal cost of one
    /// more tenant on a shared base, reported by `bench_tenants`.
    pub fn adapter_bytes(&self) -> usize {
        let lora: usize = self
            .blocks
            .iter()
            .map(|b| {
                b.q.as_ref().map(|a| a.trainable_params()).unwrap_or(0)
                    + b.v.as_ref().map(|a| a.trainable_params()).unwrap_or(0)
            })
            .sum();
        let prompt = self
            .prompt
            .as_ref()
            .map(|p| p.embeddings.numel())
            .unwrap_or(0);
        (lora + prompt) * 4
    }
}

/// P-tuning: virtual tokens are produced by a 2-layer MLP "prompt encoder"
/// over learnable seeds — `P = W2·tanh(W1·E)` (per virtual token).
pub struct PTuningEncoder {
    pub seeds: Param,
    pub w1: Param,
    pub w2: Param,
    hidden: usize,
}

/// Cache for the P-tuning encoder backward.
pub struct PTuningCache {
    h_pre: Matrix,
    h_act: Matrix,
}

impl PTuningEncoder {
    pub fn new(n_virtual: usize, d: usize, hidden: usize, rng: &mut Rng) -> Self {
        PTuningEncoder {
            seeds: Param::new(Matrix::randn(n_virtual, d, rng, 0.02)),
            w1: Param::new(Matrix::randn(d, hidden, rng, (1.0 / d as f32).sqrt())),
            w2: Param::new(Matrix::randn(hidden, d, rng, (1.0 / hidden as f32).sqrt())),
            hidden,
        }
    }

    pub fn n_virtual(&self) -> usize {
        self.seeds.value.rows()
    }

    pub fn forward(&self) -> (Matrix, PTuningCache) {
        let h_pre = self.seeds.value.matmul(&self.w1.value);
        let mut h_act = h_pre.clone();
        for v in h_act.data_mut() {
            *v = v.tanh();
        }
        let p = h_act.matmul(&self.w2.value);
        (p, PTuningCache { h_pre, h_act })
    }

    /// Backward from dP (gradient at the virtual-token block).
    pub fn backward(&mut self, dp: &Matrix, cache: &PTuningCache) {
        // dW2 += h_actᵀ dP
        let dw2 = cache.h_act.matmul_at(dp);
        self.w2.accumulate(&dw2);
        // dh_act = dP W2ᵀ; dh_pre = dh_act ∘ (1 - tanh²)
        let mut dh = dp.matmul_bt(&self.w2.value);
        for (g, &pre) in dh.data_mut().iter_mut().zip(cache.h_pre.data()) {
            let t = pre.tanh();
            *g *= 1.0 - t * t;
        }
        // dW1 += seedsᵀ dh_pre ; dseeds = dh_pre W1ᵀ
        let dw1 = self.seeds.value.matmul_at(&dh);
        self.w1.accumulate(&dw1);
        let dseeds = dh.matmul_bt(&self.w1.value);
        self.seeds.accumulate(&dseeds);
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn lora_starts_at_zero_delta() {
        let mut r = Rng::new(1);
        let lora = LoraAdapter::new(16, 8, 4, 16.0, 0.0, &mut r);
        let x = Matrix::randn(3, 16, &mut r, 1.0);
        let (dy, _) = lora.forward(&x, false, &mut r);
        assert!(dy.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lora_gradcheck() {
        let mut r = Rng::new(2);
        let mut lora = LoraAdapter::new(10, 6, 3, 3.0, 0.0, &mut r);
        // make B nonzero so gradients flow both ways
        lora.b.value = Matrix::randn(3, 6, &mut r, 0.3);
        let x = Matrix::randn(4, 10, &mut r, 1.0);
        let dy = Matrix::randn(4, 6, &mut r, 1.0);
        let (_, cache) = lora.forward(&x, false, &mut r);
        let dx = lora.backward(&dy, &cache);
        // finite-diff on A[0,0]
        let eps = 1e-3;
        let loss = |l: &LoraAdapter, rng: &mut Rng| -> f32 {
            let (y, _) = l.forward(&x, false, rng);
            y.data().iter().zip(dy.data()).map(|(a, b)| a * b).sum()
        };
        let mut lp = LoraAdapter::new(10, 6, 3, 3.0, 0.0, &mut Rng::new(2));
        lp.a.value = lora.a.value.clone();
        lp.b.value = lora.b.value.clone();
        let base_a = lp.a.value.get(0, 0);
        lp.a.value.set(0, 0, base_a + eps);
        let up = loss(&lp, &mut r);
        lp.a.value.set(0, 0, base_a - eps);
        let dn = loss(&lp, &mut r);
        let num = (up - dn) / (2.0 * eps);
        prop::close(lora.a.grad.get(0, 0), num, 1e-2, 1e-2).unwrap();
        // dX finite-diff at (1,2)
        let mut xp = x.clone();
        xp.set(1, 2, x.get(1, 2) + eps);
        let (yp, _) = lora.forward(&xp, false, &mut r);
        let mut xm = x.clone();
        xm.set(1, 2, x.get(1, 2) - eps);
        let (ym, _) = lora.forward(&xm, false, &mut r);
        let num_dx: f32 = yp
            .data()
            .iter()
            .zip(ym.data())
            .zip(dy.data())
            .map(|((a, b), g)| (a - b) / (2.0 * eps) * g)
            .sum();
        prop::close(dx.get(1, 2), num_dx, 1e-2, 1e-2).unwrap();
    }

    #[test]
    fn lora_dropout_zeroes_and_rescales() {
        let mut r = Rng::new(3);
        let mut lora = LoraAdapter::new(8, 4, 2, 2.0, 0.5, &mut r);
        lora.b.value = Matrix::randn(2, 4, &mut r, 1.0);
        let x = Matrix::from_vec(1, 8, vec![1.0; 8]);
        // train=false: no dropout
        let (y_eval, _) = lora.forward(&x, false, &mut r);
        let (y_eval2, _) = lora.forward(&x, false, &mut r);
        assert_eq!(y_eval.data(), y_eval2.data());
        // train=true: stochastic
        let (y_a, _) = lora.forward(&x, true, &mut r);
        let (y_b, _) = lora.forward(&x, true, &mut r);
        assert_ne!(y_a.data(), y_b.data());
    }

    #[test]
    fn ia3_identity_at_init() {
        let mut r = Rng::new(4);
        let ia3 = Ia3Vector::new(12);
        let x = Matrix::randn(3, 12, &mut r, 1.0);
        assert_eq!(ia3.forward(&x).data(), x.data());
    }

    #[test]
    fn ia3_gradcheck() {
        let mut r = Rng::new(5);
        let mut ia3 = Ia3Vector::new(6);
        ia3.l.value = Matrix::randn(1, 6, &mut r, 1.0);
        let x = Matrix::randn(4, 6, &mut r, 1.0);
        let dy = Matrix::randn(4, 6, &mut r, 1.0);
        let dx = ia3.backward(&dy, &x);
        // dl[j] = Σ_t x[t,j] dy[t,j]
        for j in 0..6 {
            let want: f32 = (0..4).map(|t| x.get(t, j) * dy.get(t, j)).sum();
            prop::close(ia3.l.grad.get(0, j), want, 1e-5, 1e-5).unwrap();
            for t in 0..4 {
                prop::close(dx.get(t, j), dy.get(t, j) * ia3.l.value.get(0, j), 1e-6, 1e-6)
                    .unwrap();
            }
        }
    }

    #[test]
    fn ptuning_gradcheck_seeds() {
        let mut r = Rng::new(6);
        let mut enc = PTuningEncoder::new(3, 8, 16, &mut r);
        let dp = Matrix::randn(3, 8, &mut r, 1.0);
        let (_, cache) = enc.forward();
        enc.backward(&dp, &cache);
        // finite-diff seeds[0,0]
        let eps = 1e-3;
        let probe = |e: &PTuningEncoder| -> f32 {
            let (p, _) = e.forward();
            p.data().iter().zip(dp.data()).map(|(a, b)| a * b).sum()
        };
        let base = enc.seeds.value.get(0, 0);
        enc.seeds.value.set(0, 0, base + eps);
        let up = probe(&enc);
        enc.seeds.value.set(0, 0, base - eps);
        let dn = probe(&enc);
        enc.seeds.value.set(0, 0, base);
        let num = (up - dn) / (2.0 * eps);
        prop::close(enc.seeds.grad.get(0, 0), num, 1e-2, 2e-2).unwrap();
    }

    #[test]
    fn peft_kind_parse() {
        for k in PeftKind::ALL {
            assert_eq!(PeftKind::parse(k.label()), Some(k));
        }
        assert_eq!(PeftKind::parse("adapters"), None);
    }
}
