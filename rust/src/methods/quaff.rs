//! Quaff's decoupled quantized linear layer (§3.3, Eqs. 5–9).
//!
//! Preprocessing (once): quantize the full frozen `W` to `W_int, Δ_W`
//! (per-OC) and keep only the tiny outlier slice `W_O` (rows at the
//! pre-identified channels `O`) in full precision.
//!
//! Per step:
//!   1. update the momentum factors `s_O` from the live batch (Eqs. 7–8);
//!   2. targeted inverse scaling `X̂ = X` with outlier columns `/ s_O`;
//!   3. per-token quantize `X̂` → `X̂_int, Δ_X̂`;
//!   4. main term `Δ_X̂ · X̂_int W_int · Δ_W` (integer matmul);
//!   5. build `ŵ = (s_O − 1)·W_O`, quantize it per-OC (tiny), gather
//!      `x̂_int = [X̂_int]_{:,O}` (inherits `Δ_X̂` — Eq. 9, zero overhead),
//!      and fuse the correction `Δ_X̂ · x̂_int ŵ_int · Δ_ŵ` into the output.
//!
//! No full-precision master weight, no global rescaling, no requantization
//! of `W_int` — the decoupling that resolves the trilemma. Every per-step
//! buffer (X̂, X̂_int, ŵ, the gathered outlier slice, the i32 accumulator)
//! comes from the caller's [`Workspace`], so the steady-state step is
//! allocation-free — the "lightweight operations" the paper promises.

use super::{ste_backward_ws, MethodSnapshot, QuantMethod};
use crate::outlier::OutlierSet;
use crate::quant::pipeline::{self, PlanId, ScaleOp};
use crate::quant::{self, QuantizedWeights};
use crate::scaling::{self, MomentumScaler};
use crate::tensor::{kernels, I8Matrix, Matrix, Workspace};

/// Plan aux-slot roles for the Quaff correction stage (see
/// `quant::pipeline::QgemmPlan::aux_f32`).
const AX_WHAT: usize = 0; // ŵ = (s_O−1)·W_O
const AX_DWHAT: usize = 1; // Δ_ŵ
const AX_OC_INV: usize = 2; // per-OC quantizer reciprocals
const AX_OC_LANES: usize = 3; // col_abs_max reduction lanes
const AX_COLMAX: usize = 4; // momentum-update targeted column maxima

/// Quaff quantized linear layer.
pub struct QuaffLinear {
    qw: QuantizedWeights,
    /// Full-precision outlier rows `W_O` (|O| × c_out) — the ≤5 % overhead.
    w_o: Matrix,
    /// Static per-input-channel weight maxima `max|W_i,:|` for Eq. 8.
    w_row_max: Vec<f32>,
    scaler: MomentumScaler,
    /// Identity of this layer's compiled execution plan (one per workspace).
    plan: PlanId,
    cin: usize,
    cout: usize,
}

impl QuaffLinear {
    pub fn new(w: Matrix, outliers: OutlierSet, gamma: f32, momentum: bool) -> Self {
        let cin = w.rows();
        let cout = w.cols();
        let w_row_max: Vec<f32> = (0..cin)
            .map(|i| w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect();
        let w_o = w.select_rows(&outliers.channels);
        let scaler = if momentum {
            MomentumScaler::new(gamma, outliers)
        } else {
            MomentumScaler::without_momentum(gamma, outliers)
        };
        QuaffLinear {
            qw: QuantizedWeights::quantize(&w),
            w_o,
            w_row_max,
            scaler,
            plan: PlanId::fresh(),
            cin,
            cout,
        }
    }

    /// Rebuild from persisted state: int8 store, f32 outlier slice, the
    /// static per-channel weight maxima (not derivable once the f32 master
    /// is gone), and the momentum scaler mid-stream — the restored layer's
    /// next momentum update and forward are bit-identical to the original's.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        w_int: I8Matrix,
        deltas: Vec<f32>,
        w_o: Matrix,
        w_row_max: Vec<f32>,
        channels: Vec<usize>,
        s_o: Vec<f32>,
        gamma: f32,
        momentum: bool,
    ) -> Self {
        let cin = w_int.rows();
        let cout = w_int.cols();
        assert_eq!(w_row_max.len(), cin, "w_row_max must cover every input channel");
        assert_eq!(w_o.rows(), channels.len(), "W_O must have one row per outlier");
        assert!(w_o.rows() == 0 || w_o.cols() == cout, "W_O width must match c_out");
        let outliers = OutlierSet::new(channels);
        assert_eq!(outliers.len(), w_o.rows(), "outlier channels must be distinct");
        let scaler = MomentumScaler::from_parts(gamma, outliers, s_o, momentum);
        QuaffLinear {
            qw: QuantizedWeights::from_parts(w_int, deltas),
            w_o,
            w_row_max,
            scaler,
            plan: PlanId::fresh(),
            cin,
            cout,
        }
    }

    /// The current momentum factors over outlier channels.
    pub fn outlier_factors(&self) -> &[f32] {
        self.scaler.factors()
    }

    pub fn outlier_set(&self) -> &OutlierSet {
        &self.scaler.outliers
    }

    /// Column maxima restricted to outlier channels, written into `maxima`
    /// (length c_in, zeroed here) — cheaper than a full `col_abs_max` when
    /// |O| ≪ c_in (perf: targeted statistics).
    fn outlier_col_max_into(&self, x: &Matrix, maxima: &mut [f32]) {
        maxima.fill(0.0);
        for &ch in &self.scaler.outliers.channels {
            let mut m = 0.0f32;
            for t in 0..x.rows() {
                let a = x.get(t, ch).abs();
                if a > m {
                    m = a;
                }
            }
            maxima[ch] = m;
        }
    }
}

impl QuantMethod for QuaffLinear {
    fn name(&self) -> &'static str {
        if self.scaler.momentum_enabled {
            "Quaff"
        } else {
            "Quaff w/o Mo"
        }
    }

    fn forward(&mut self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        // 1. momentum update from targeted statistics (Eqs. 7–8); the rest
        // of the step is the frozen-state plan pipeline below.
        if !self.scaler.outliers.is_empty() {
            let plan = pipeline::plan_for(ws, self.plan, self.cin, self.cout, x.rows());
            let mut col_max = ws.take_slot_f32(plan.aux_f32[AX_COLMAX], self.cin);
            self.outlier_col_max_into(x, &mut col_max);
            self.scaler.update(&col_max, &self.w_row_max);
            ws.put_slot_f32(plan.aux_f32[AX_COLMAX], col_max);
            pipeline::store_plan(ws, self.plan, plan);
        }
        self.forward_infer(x, ws)
    }

    /// Steps 2–5 of the per-step pipeline with the momentum factors frozen
    /// at their current values — row-local, so KV-cached decode matches a
    /// full re-forward bit-for-bit. Runs entirely on the compiled plan:
    /// fused scale+quantize (no X̂ materialization), fused matmul epilogue
    /// (no zeroed output pass), slot-resolved buffers (no string lookups).
    fn forward_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let t = x.rows();
        let n_out = self.scaler.outliers.len();
        let plan = pipeline::plan_for(ws, self.plan, self.cin, self.cout, t);
        let mut y = ws.take_donor_matrix(t, self.cout);
        if n_out == 0 {
            // Degenerate case (budget 0): Quaff reduces to Naive W8A8.
            pipeline::qgemm_into(x, &ScaleOp::Identity, &self.qw, &plan, ws, y.data_mut());
            pipeline::store_plan(ws, self.plan, plan);
            return y;
        }
        let s_o = self.scaler.factors();
        // 2+3. fused targeted inverse scaling + per-token quantization,
        // 4. main integer matmul written straight into y
        let qa = plan.quantize(
            x,
            &ScaleOp::DivCols { channels: &self.scaler.outliers.channels, factors: s_o },
            ws,
        );
        plan.matmul_write(&qa, &self.qw, ws, y.data_mut());
        // 5. outlier correction: ŵ = (s_O−1)·W_O, x̂_int = [X̂_int]_{:,O},
        // fused into the epilogue buffer
        let mut w_hat = ws.take_slot_matrix(plan.aux_f32[AX_WHAT], n_out, self.cout);
        scaling::build_outlier_correction_from_slice_into(&self.w_o, s_o, &mut w_hat);
        let mut w_hat_int = ws.take_slot_i8_matrix(plan.aux_i8[0], n_out, self.cout);
        let mut d_what = ws.take_slot_f32(plan.aux_f32[AX_DWHAT], self.cout);
        let mut oc_inv = ws.take_slot_f32(plan.aux_f32[AX_OC_INV], 0);
        let mut oc_lanes = ws.take_slot_f32(plan.aux_f32[AX_OC_LANES], 0);
        quant::quantize_per_oc_scratch(
            &w_hat,
            &mut w_hat_int,
            &mut d_what,
            &mut oc_inv,
            &mut oc_lanes,
        );
        let mut x_o_int = ws.take_slot_i8_matrix(plan.aux_i8[1], t, n_out);
        kernels::select_cols_i8_into(&qa.x_int, &self.scaler.outliers.channels, &mut x_o_int);
        let mut acc = ws.take_slot_i32(plan.aux_i32, 0);
        x_o_int.matmul_dequant_scratch_into(&w_hat_int, &qa.dx, &d_what, &mut acc, y.data_mut());
        ws.put_slot_matrix(plan.aux_f32[AX_WHAT], w_hat);
        ws.put_slot_i8_matrix(plan.aux_i8[0], w_hat_int);
        ws.put_slot_f32(plan.aux_f32[AX_DWHAT], d_what);
        ws.put_slot_f32(plan.aux_f32[AX_OC_INV], oc_inv);
        ws.put_slot_f32(plan.aux_f32[AX_OC_LANES], oc_lanes);
        ws.put_slot_i8_matrix(plan.aux_i8[1], x_o_int);
        ws.put_slot_i32(plan.aux_i32, acc);
        plan.release(qa, ws);
        pipeline::store_plan(ws, self.plan, plan);
        y
    }

    fn warm_plan(&self, m_hint: usize, ws: &mut Workspace) {
        pipeline::warm(ws, self.plan, self.cin, self.cout, m_hint);
    }

    fn backward_input(&self, dy: &Matrix, ws: &mut Workspace) -> Matrix {
        // STE through the Eq. 5 identity: the decomposition reconstructs
        // X·W, so dX = dY·Wᵀ with the int8 store (+ exact outlier rows).
        let mut dx = ste_backward_ws(dy, &self.qw.w_int, &self.qw.deltas, ws);
        // refine outlier rows with the exact f32 slice we already hold
        if !self.scaler.outliers.is_empty() {
            let mut exact = ws.take_matrix("quaff.bwd.exact", dy.rows(), self.w_o.rows());
            kernels::matmul_bt_into(dy, &self.w_o, &mut exact); // (t × |O|)
            for ti in 0..dy.rows() {
                let row = dx.row_mut(ti);
                for (k, &ch) in self.scaler.outliers.channels.iter().enumerate() {
                    row[ch] = exact.get(ti, k);
                }
            }
            ws.put_matrix("quaff.bwd.exact", exact);
        }
        dx
    }

    fn weight_bytes(&self) -> usize {
        // int8 main store + Δ_W + f32 W_O slice + momentum state
        self.qw.nbytes() + self.w_o.data().len() * 4 + self.scaler.factors().len() * 4
    }

    fn cin(&self) -> usize {
        self.cin
    }

    fn cout(&self) -> usize {
        self.cout
    }

    fn scaling_factors(&self) -> Option<Vec<f32>> {
        Some(self.scaler.full_factors(self.cin))
    }

    fn snapshot(&self) -> MethodSnapshot {
        MethodSnapshot::Quaff {
            w_int: self.qw.w_int.clone(),
            deltas: self.qw.deltas.clone(),
            w_o: self.w_o.clone(),
            w_row_max: self.w_row_max.clone(),
            channels: self.scaler.outliers.channels.clone(),
            s_o: self.scaler.factors().to_vec(),
            gamma: self.scaler.gamma,
            momentum: self.scaler.momentum_enabled,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error_between;
    use crate::util::prng::Rng;

    fn planted(rng: &mut Rng, t: usize, cin: usize, hot: &[usize], gain: f32) -> Matrix {
        let mut x = Matrix::randn(t, cin, rng, 1.0);
        for &c in hot {
            for ti in 0..t {
                let v = x.get(ti, c);
                x.set(ti, c, v * gain);
            }
        }
        x
    }

    #[test]
    fn zero_budget_equals_naive() {
        let mut r = Rng::new(41);
        let mut ws = Workspace::new();
        let w = Matrix::randn(32, 16, &mut r, 0.3);
        let x = Matrix::randn(4, 32, &mut r, 1.0);
        let mut quaff = QuaffLinear::new(w.clone(), OutlierSet::default(), 0.2, true);
        let mut naive = super::super::NaiveW8A8Linear::new(w);
        assert_eq!(
            quaff.forward(&x, &mut ws).data(),
            naive.forward(&x, &mut ws).data()
        );
    }

    #[test]
    fn suppresses_planted_outliers() {
        let mut r = Rng::new(42);
        let mut ws = Workspace::new();
        let hot = vec![3, 20];
        let w = Matrix::randn(64, 32, &mut r, 0.3);
        let mut m = QuaffLinear::new(w.clone(), OutlierSet::new(hot.clone()), 0.2, true);
        // warm up momentum
        for _ in 0..10 {
            let x = planted(&mut r, 8, 64, &hot, 100.0);
            let y = m.forward(&x, &mut ws);
            ws.recycle(y);
        }
        let x = planted(&mut r, 8, 64, &hot, 100.0);
        let want = x.matmul(&w);
        let got = m.forward(&x, &mut ws);
        let err = error_between(&want, &got);
        assert!(err.sqnr_db > 25.0, "sqnr {:.1}", err.sqnr_db);
        // factors should have moved well above 1 on the hot channels
        assert!(m.outlier_factors().iter().all(|&s| s > 2.0));
    }

    #[test]
    fn factors_smooth_under_transient_spike() {
        // Momentum must damp a one-step activation spike (the paper's
        // "prevents overreaction to transient activation shifts").
        let mut r = Rng::new(43);
        let mut ws = Workspace::new();
        let hot = vec![5];
        let w = Matrix::randn(32, 16, &mut r, 0.3);
        let mut with_mo = QuaffLinear::new(w.clone(), OutlierSet::new(hot.clone()), 0.9, true);
        let mut no_mo = QuaffLinear::new(w, OutlierSet::new(hot.clone()), 0.9, false);
        // steady state at gain 50
        for _ in 0..30 {
            let x = planted(&mut r, 8, 32, &hot, 50.0);
            let y = with_mo.forward(&x, &mut ws);
            ws.recycle(y);
            let y = no_mo.forward(&x, &mut ws);
            ws.recycle(y);
        }
        let steady = with_mo.outlier_factors()[0];
        // one spike at gain 5000
        let spike = planted(&mut r, 8, 32, &hot, 5000.0);
        let _ = with_mo.forward(&spike, &mut ws);
        let _ = no_mo.forward(&spike, &mut ws);
        let jump_mo = with_mo.outlier_factors()[0] / steady;
        let jump_nomo = no_mo.outlier_factors()[0] / steady;
        assert!(
            jump_mo < jump_nomo * 0.5,
            "momentum jump {jump_mo} should be well under no-momentum {jump_nomo}"
        );
    }

    #[test]
    fn weight_bytes_overhead_under_budget() {
        let mut r = Rng::new(44);
        let cin = 1000;
        let cout = 512;
        let w = Matrix::randn(cin, cout, &mut r, 0.3);
        let o = OutlierSet::new((0..50).collect()); // 5%
        let m = QuaffLinear::new(w, o, 0.2, true);
        let naive_bytes = cin * cout + cout * 4;
        let overhead = m.weight_bytes() - naive_bytes;
        // W_O is 5% of rows in f32 = 20% of the int8 store; paper's "<5%"
        // is relative to *total fine-tuning memory*, dominated by
        // activations/optimizer — at layer granularity we assert the slice
        // is exactly |O|·c_out·4 + state.
        assert_eq!(overhead, 50 * cout * 4 + 50 * 4);
    }

    #[test]
    fn backward_exact_on_outlier_channels() {
        let mut r = Rng::new(45);
        let mut ws = Workspace::new();
        let w = Matrix::randn(16, 8, &mut r, 0.5);
        let o = OutlierSet::new(vec![2, 9]);
        let m = QuaffLinear::new(w.clone(), o, 0.2, true);
        let dy = Matrix::randn(3, 8, &mut r, 1.0);
        let dx = m.backward_input(&dy, &mut ws);
        let exact = dy.matmul_bt(&w);
        for t in 0..3 {
            for &ch in &[2usize, 9] {
                assert!(
                    (dx.get(t, ch) - exact.get(t, ch)).abs() < 1e-5,
                    "outlier channel backward should be exact"
                );
            }
        }
    }

    #[test]
    fn select_cols_i8_gathers() {
        use crate::tensor::I8Matrix;
        let x = I8Matrix::from_vec(2, 4, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let mut s = I8Matrix::zeros(2, 2);
        kernels::select_cols_i8_into(&x, &[1, 3], &mut s);
        assert_eq!(s.data(), &[1, 3, 5, 7]);
    }

    #[test]
    fn forward_steady_state_allocates_nothing_from_arena() {
        // After one warm step, every take must be served from the arena.
        let mut r = Rng::new(46);
        let mut ws = Workspace::new();
        let hot = vec![2, 11];
        let w = Matrix::randn(32, 24, &mut r, 0.3);
        let mut m = QuaffLinear::new(w, OutlierSet::new(hot.clone()), 0.2, true);
        for _ in 0..2 {
            let x = planted(&mut r, 6, 32, &hot, 60.0);
            let y = m.forward(&x, &mut ws);
            ws.recycle(y);
            let dy = Matrix::randn(6, 24, &mut r, 1.0);
            let dx = m.backward_input(&dy, &mut ws);
            ws.recycle(dx);
        }
        let frozen = ws.fresh_allocs;
        for _ in 0..5 {
            let x = planted(&mut r, 6, 32, &hot, 60.0);
            let y = m.forward(&x, &mut ws);
            ws.recycle(y);
            let dy = Matrix::randn(6, 24, &mut r, 1.0);
            let dx = m.backward_input(&dy, &mut ws);
            ws.recycle(dx);
        }
        assert_eq!(
            ws.fresh_allocs, frozen,
            "steady-state forward/backward must not grow the arena"
        );
    }
}
