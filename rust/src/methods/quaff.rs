//! Quaff's decoupled quantized linear layer (§3.3, Eqs. 5–9).
//!
//! Preprocessing (once): quantize the full frozen `W` to `W_int, Δ_W`
//! (per-OC) and keep only the tiny outlier slice `W_O` (rows at the
//! pre-identified channels `O`) in full precision.
//!
//! Per step:
//!   1. update the momentum factors `s_O` from the live batch (Eqs. 7–8);
//!   2. targeted inverse scaling `X̂ = X` with outlier columns `/ s_O`;
//!   3. per-token quantize `X̂` → `X̂_int, Δ_X̂`;
//!   4. main term `Δ_X̂ · X̂_int W_int · Δ_W` (integer matmul);
//!   5. build `ŵ = (s_O − 1)·W_O`, quantize it per-OC (tiny), gather
//!      `x̂_int = [X̂_int]_{:,O}` (inherits `Δ_X̂` — Eq. 9, zero overhead),
//!      and fuse the correction `Δ_X̂ · x̂_int ŵ_int · Δ_ŵ` into the output.
//!
//! No full-precision master weight, no global rescaling, no requantization
//! of `W_int` — the decoupling that resolves the trilemma.

use super::{ste_backward, QuantMethod};
use crate::outlier::OutlierSet;
use crate::quant::{self, QuantizedWeights};
use crate::scaling::{self, MomentumScaler};
use crate::tensor::{I8Matrix, Matrix};

/// Quaff quantized linear layer.
pub struct QuaffLinear {
    qw: QuantizedWeights,
    /// Full-precision outlier rows `W_O` (|O| × c_out) — the ≤5 % overhead.
    w_o: Matrix,
    /// Static per-input-channel weight maxima `max|W_i,:|` for Eq. 8.
    w_row_max: Vec<f32>,
    scaler: MomentumScaler,
    cin: usize,
    cout: usize,
}

impl QuaffLinear {
    pub fn new(w: Matrix, outliers: OutlierSet, gamma: f32, momentum: bool) -> Self {
        let cin = w.rows();
        let cout = w.cols();
        let w_row_max: Vec<f32> = (0..cin)
            .map(|i| w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect();
        let w_o = w.select_rows(&outliers.channels);
        let scaler = if momentum {
            MomentumScaler::new(gamma, outliers)
        } else {
            MomentumScaler::without_momentum(gamma, outliers)
        };
        QuaffLinear {
            qw: QuantizedWeights::quantize(&w),
            w_o,
            w_row_max,
            scaler,
            cin,
            cout,
        }
    }

    /// The current momentum factors over outlier channels.
    pub fn outlier_factors(&self) -> &[f32] {
        self.scaler.factors()
    }

    pub fn outlier_set(&self) -> &OutlierSet {
        &self.scaler.outliers
    }

    /// Column maxima restricted to outlier channels — cheaper than a full
    /// `col_abs_max` when |O| ≪ c_in (perf: targeted statistics).
    fn outlier_col_max(&self, x: &Matrix) -> Vec<f32> {
        let mut maxima = vec![0.0f32; self.cin];
        for &ch in &self.scaler.outliers.channels {
            let mut m = 0.0f32;
            for t in 0..x.rows() {
                let a = x.get(t, ch).abs();
                if a > m {
                    m = a;
                }
            }
            maxima[ch] = m;
        }
        maxima
    }
}

impl QuantMethod for QuaffLinear {
    fn name(&self) -> &'static str {
        if self.scaler.momentum_enabled {
            "Quaff"
        } else {
            "Quaff w/o Mo"
        }
    }

    fn forward(&mut self, x: &Matrix) -> Matrix {
        let t = x.rows();
        let n_out = self.scaler.outliers.len();
        if n_out == 0 {
            // Degenerate case (budget 0): Quaff reduces to Naive W8A8.
            let (x_int, dx) = quant::quantize_per_token(x);
            let mut out = vec![0.0f32; t * self.cout];
            self.qw.matmul_into(&x_int, &dx, &mut out);
            return Matrix::from_vec(t, self.cout, out);
        }
        // 1. momentum update from targeted statistics (Eqs. 7–8)
        let col_max = self.outlier_col_max(x);
        self.scaler.update(&col_max, &self.w_row_max);
        let s_o = self.scaler.factors().to_vec();
        // 2. targeted inverse scaling
        let mut x_hat = x.clone();
        scaling::apply_targeted_inverse_scale(&mut x_hat, &self.scaler.outliers, &s_o);
        // 3. per-token quantization
        let (x_int, dx) = quant::quantize_per_token(&x_hat);
        // 4. main integer matmul
        let mut out = vec![0.0f32; t * self.cout];
        self.qw.matmul_into(&x_int, &dx, &mut out);
        // 5. outlier correction: ŵ = (s_O−1)·W_O, x̂_int = [X̂_int]_{:,O}
        let w_hat = scaling::build_outlier_correction_from_slice(&self.w_o, &s_o);
        let (w_hat_int, d_what) = quant::quantize_per_oc(&w_hat);
        let x_o_int = select_cols_i8(&x_int, &self.scaler.outliers.channels);
        x_o_int.matmul_dequant_into(&w_hat_int, &dx, &d_what, &mut out);
        Matrix::from_vec(t, self.cout, out)
    }

    fn backward_input(&self, dy: &Matrix) -> Matrix {
        // STE through the Eq. 5 identity: the decomposition reconstructs
        // X·W, so dX = dY·Wᵀ with the int8 store (+ exact outlier rows).
        let mut dx = ste_backward(dy, &self.qw.w_int, &self.qw.deltas);
        // refine outlier rows with the exact f32 slice we already hold
        if !self.scaler.outliers.is_empty() {
            let exact = dy.matmul_bt(&self.w_o); // (t × |O|)
            for ti in 0..dy.rows() {
                let row = dx.row_mut(ti);
                for (k, &ch) in self.scaler.outliers.channels.iter().enumerate() {
                    row[ch] = exact.get(ti, k);
                }
            }
        }
        dx
    }

    fn weight_bytes(&self) -> usize {
        // int8 main store + Δ_W + f32 W_O slice + momentum state
        self.qw.nbytes() + self.w_o.data().len() * 4 + self.scaler.factors().len() * 4
    }

    fn cin(&self) -> usize {
        self.cin
    }

    fn cout(&self) -> usize {
        self.cout
    }

    fn scaling_factors(&self) -> Option<Vec<f32>> {
        Some(self.scaler.full_factors(self.cin))
    }
}

/// Gather columns of an i8 matrix (x̂_int = [X̂_int]_{:,O}).
fn select_cols_i8(x: &I8Matrix, idx: &[usize]) -> I8Matrix {
    let mut data = Vec::with_capacity(x.rows() * idx.len());
    for t in 0..x.rows() {
        let row = x.row(t);
        data.extend(idx.iter().map(|&j| row[j]));
    }
    I8Matrix::from_vec(x.rows(), idx.len(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error_between;
    use crate::util::prng::Rng;

    fn planted(rng: &mut Rng, t: usize, cin: usize, hot: &[usize], gain: f32) -> Matrix {
        let mut x = Matrix::randn(t, cin, rng, 1.0);
        for &c in hot {
            for ti in 0..t {
                let v = x.get(ti, c);
                x.set(ti, c, v * gain);
            }
        }
        x
    }

    #[test]
    fn zero_budget_equals_naive() {
        let mut r = Rng::new(41);
        let w = Matrix::randn(32, 16, &mut r, 0.3);
        let x = Matrix::randn(4, 32, &mut r, 1.0);
        let mut quaff = QuaffLinear::new(w.clone(), OutlierSet::default(), 0.2, true);
        let mut naive = super::super::NaiveW8A8Linear::new(w);
        assert_eq!(quaff.forward(&x).data(), naive.forward(&x).data());
    }

    #[test]
    fn suppresses_planted_outliers() {
        let mut r = Rng::new(42);
        let hot = vec![3, 20];
        let w = Matrix::randn(64, 32, &mut r, 0.3);
        let mut m = QuaffLinear::new(w.clone(), OutlierSet::new(hot.clone()), 0.2, true);
        // warm up momentum
        for _ in 0..10 {
            let x = planted(&mut r, 8, 64, &hot, 100.0);
            let _ = m.forward(&x);
        }
        let x = planted(&mut r, 8, 64, &hot, 100.0);
        let want = x.matmul(&w);
        let got = m.forward(&x);
        let err = error_between(&want, &got);
        assert!(err.sqnr_db > 25.0, "sqnr {:.1}", err.sqnr_db);
        // factors should have moved well above 1 on the hot channels
        assert!(m.outlier_factors().iter().all(|&s| s > 2.0));
    }

    #[test]
    fn factors_smooth_under_transient_spike() {
        // Momentum must damp a one-step activation spike (the paper's
        // "prevents overreaction to transient activation shifts").
        let mut r = Rng::new(43);
        let hot = vec![5];
        let w = Matrix::randn(32, 16, &mut r, 0.3);
        let mut with_mo = QuaffLinear::new(w.clone(), OutlierSet::new(hot.clone()), 0.9, true);
        let mut no_mo = QuaffLinear::new(w, OutlierSet::new(hot.clone()), 0.9, false);
        // steady state at gain 50
        for _ in 0..30 {
            let x = planted(&mut r, 8, 32, &hot, 50.0);
            let _ = with_mo.forward(&x);
            let _ = no_mo.forward(&x);
        }
        let steady = with_mo.outlier_factors()[0];
        // one spike at gain 5000
        let spike = planted(&mut r, 8, 32, &hot, 5000.0);
        let _ = with_mo.forward(&spike);
        let _ = no_mo.forward(&spike);
        let jump_mo = with_mo.outlier_factors()[0] / steady;
        let jump_nomo = no_mo.outlier_factors()[0] / steady;
        assert!(
            jump_mo < jump_nomo * 0.5,
            "momentum jump {jump_mo} should be well under no-momentum {jump_nomo}"
        );
    }

    #[test]
    fn weight_bytes_overhead_under_budget() {
        let mut r = Rng::new(44);
        let cin = 1000;
        let cout = 512;
        let w = Matrix::randn(cin, cout, &mut r, 0.3);
        let o = OutlierSet::new((0..50).collect()); // 5%
        let m = QuaffLinear::new(w, o, 0.2, true);
        let naive_bytes = cin * cout + cout * 4;
        let overhead = m.weight_bytes() - naive_bytes;
        // W_O is 5% of rows in f32 = 20% of the int8 store; paper's "<5%"
        // is relative to *total fine-tuning memory*, dominated by
        // activations/optimizer — at layer granularity we assert the slice
        // is exactly |O|·c_out·4 + state.
        assert_eq!(overhead, 50 * cout * 4 + 50 * 4);
    }

    #[test]
    fn backward_exact_on_outlier_channels() {
        let mut r = Rng::new(45);
        let w = Matrix::randn(16, 8, &mut r, 0.5);
        let o = OutlierSet::new(vec![2, 9]);
        let m = QuaffLinear::new(w.clone(), o, 0.2, true);
        let dy = Matrix::randn(3, 8, &mut r, 1.0);
        let dx = m.backward_input(&dy);
        let exact = dy.matmul_bt(&w);
        for t in 0..3 {
            for &ch in &[2usize, 9] {
                assert!(
                    (dx.get(t, ch) - exact.get(t, ch)).abs() < 1e-5,
                    "outlier channel backward should be exact"
                );
            }
        }
    }

    #[test]
    fn select_cols_i8_gathers() {
        let x = I8Matrix::from_vec(2, 4, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let s = select_cols_i8(&x, &[1, 3]);
        assert_eq!(s.data(), &[1, 3, 5, 7]);
    }
}
