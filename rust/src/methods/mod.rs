//! Weight–activation quantization methods for a frozen linear layer —
//! the paper's comparison set (§4.1 baselines + Quaff itself).
//!
//! Every method implements [`QuantMethod`]: it owns the frozen weight in
//! whatever representation the method prescribes, and its `forward`
//! faithfully performs the *work the paper attributes to the method*:
//!
//! | method      | weights stored        | per-step extra work              |
//! |-------------|-----------------------|----------------------------------|
//! | `Fp32`      | f32                   | —                                |
//! | `Naive`     | int8 + Δ              | per-token act quant              |
//! | `LLM.int8`  | int8 + Δ              | realtime outlier detect + row **dequant** (Eq. 10/11) |
//! | `Smooth_S`  | int8(sW) + Δ, static s| full-axis activation rescale     |
//! | `Smooth_D`  | **f32** (must keep!)  | recompute s, rescale + **requantize W** |
//! | `Quaff`     | int8 + Δ + f32 `W_O`  | momentum s_O, quantize tiny ŵ, fused correction (Eq. 9) |
//!
//! Backward passes use the straight-through estimator: `dX = dY · Wᵀ` with
//! the stored (de)quantized weights, frozen weights get no gradient — the
//! PEFT adapters around the layer (see `peft`) carry all trainable state.
//!
//! Every method's `forward`/`forward_infer` routes through **one shared
//! compiled execution plan** (`quant::pipeline`, DESIGN.md §7): fused
//! scale→quantize, a matmul+dequant epilogue that writes the output
//! directly, and pre-resolved workspace slots instead of string-keyed
//! lookups. `tests/qgemm_parity.rs` pins the fused path bit-identical to
//! the unfused reference pipeline for all six methods.

mod baselines;
mod quaff;

pub use baselines::{Fp32Linear, LlmInt8Linear, NaiveW8A8Linear, SmoothDynamicLinear, SmoothStaticLinear};
pub use quaff::QuaffLinear;

use crate::outlier::{ChannelStats, OutlierSet};
use crate::tensor::{I8Matrix, Matrix, Workspace};

/// A frozen-weight linear operator under some quantization scheme.
///
/// Forward/backward draw every transient buffer — and the returned output
/// matrix — from the caller's [`Workspace`], so a warm arena makes the
/// per-step path allocation-free. Callers that are done with the returned
/// matrix should hand it back via [`Workspace::recycle`].
pub trait QuantMethod: Send {
    /// Display name matching the paper's tables.
    fn name(&self) -> &'static str;

    /// `Y ≈ X · W` under the method's quantization scheme.
    /// `&mut self` because dynamic methods update per-step state (scaling
    /// factors, requantized weights).
    fn forward(&mut self, x: &Matrix, ws: &mut Workspace) -> Matrix;

    /// Inference-mode forward: like [`QuantMethod::forward`] but **frozen**
    /// (no per-step state updates — Quaff's momentum, Smooth_D's factors,
    /// and LLM.int8's detection statistics stay fixed) and **row-local**
    /// (each output row depends only on the matching input row and frozen
    /// state). Row-locality is what makes KV-cached incremental decoding
    /// bit-identical to a full re-forward — `tests/decode_parity.rs` pins
    /// it for every method. No gradient bookkeeping happens on this path.
    fn forward_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix;

    /// Pre-compile this layer's execution plan (`quant::pipeline`) in `ws`,
    /// pre-sized for batches of `m_hint` token rows. Optional — forwards
    /// build the plan lazily on first use with a workspace — but the model,
    /// decode and serving layers call it at construction so the first step
    /// is already plan-driven.
    fn warm_plan(&self, _m_hint: usize, _ws: &mut Workspace) {}

    /// Straight-through `dX = dY · Wᵀ` using the stored representation.
    fn backward_input(&self, dy: &Matrix, ws: &mut Workspace) -> Matrix;

    /// Bytes of device memory held for the frozen weight + method state.
    fn weight_bytes(&self) -> usize;

    /// Input-channel count.
    fn cin(&self) -> usize;

    /// Output-channel count.
    fn cout(&self) -> usize;

    /// Current full-axis scaling factors (1.0 where unscaled), if the
    /// method scales activations — used by the OSSH instruments.
    fn scaling_factors(&self) -> Option<Vec<f32>> {
        None
    }

    /// Complete persistable state — frozen representation **and** per-step
    /// mutable state (Quaff momentum factors, Smooth_D's last factors,
    /// LLM.int8 detection counters). [`method_from_snapshot`] rebuilds a
    /// method whose every future forward/backward is bit-identical to this
    /// one's, which is what makes checkpoint/resume exact (`persist`).
    fn snapshot(&self) -> MethodSnapshot;
}

/// Owned state captured by [`QuantMethod::snapshot`]. One variant per
/// method, holding exactly what that method stores: quantized
/// representations stay quantized (the int8 store round-trips disk without
/// ever touching f32 weights), f32-keeping methods (FP32, Smooth_D) keep
/// their f32 master, and all per-step mutable state rides along.
#[derive(Clone, Debug)]
pub enum MethodSnapshot {
    /// Full-precision weight.
    Fp32 { w: Matrix },
    /// Int8 store + per-OC step sizes.
    Naive { w_int: I8Matrix, deltas: Vec<f32> },
    /// Int8 store + detection threshold and lifetime counters.
    LlmInt8 {
        w_int: I8Matrix,
        deltas: Vec<f32>,
        sigma: f32,
        dequant_rows_total: u64,
        steps: u64,
    },
    /// Int8 store of the **scaled** weight + the static factors.
    SmoothStatic {
        w_int: I8Matrix,
        deltas: Vec<f32>,
        s: Vec<f32>,
    },
    /// F32 master (the method's semantic cost) + last dynamic factors.
    SmoothDynamic {
        w_full: Matrix,
        alpha: f32,
        last_s: Vec<f32>,
    },
    /// Int8 store + f32 outlier slice + momentum scaler state.
    Quaff {
        w_int: I8Matrix,
        deltas: Vec<f32>,
        w_o: Matrix,
        w_row_max: Vec<f32>,
        channels: Vec<usize>,
        s_o: Vec<f32>,
        gamma: f32,
        momentum: bool,
    },
}

impl MethodSnapshot {
    /// The [`MethodKind`] this snapshot rebuilds into.
    pub fn kind(&self) -> MethodKind {
        match self {
            MethodSnapshot::Fp32 { .. } => MethodKind::Fp32,
            MethodSnapshot::Naive { .. } => MethodKind::Naive,
            MethodSnapshot::LlmInt8 { .. } => MethodKind::LlmInt8,
            MethodSnapshot::SmoothStatic { .. } => MethodKind::SmoothStatic,
            MethodSnapshot::SmoothDynamic { .. } => MethodKind::SmoothDynamic,
            MethodSnapshot::Quaff { momentum, .. } => {
                if *momentum {
                    MethodKind::Quaff
                } else {
                    MethodKind::QuaffNoMomentum
                }
            }
        }
    }

    /// Input-channel count of the layer this snapshot belongs to.
    pub fn cin(&self) -> usize {
        match self {
            MethodSnapshot::Fp32 { w } => w.rows(),
            MethodSnapshot::Naive { w_int, .. }
            | MethodSnapshot::LlmInt8 { w_int, .. }
            | MethodSnapshot::SmoothStatic { w_int, .. }
            | MethodSnapshot::Quaff { w_int, .. } => w_int.rows(),
            MethodSnapshot::SmoothDynamic { w_full, .. } => w_full.rows(),
        }
    }

    /// Output-channel count of the layer this snapshot belongs to.
    pub fn cout(&self) -> usize {
        match self {
            MethodSnapshot::Fp32 { w } => w.cols(),
            MethodSnapshot::Naive { w_int, .. }
            | MethodSnapshot::LlmInt8 { w_int, .. }
            | MethodSnapshot::SmoothStatic { w_int, .. }
            | MethodSnapshot::Quaff { w_int, .. } => w_int.cols(),
            MethodSnapshot::SmoothDynamic { w_full, .. } => w_full.cols(),
        }
    }

    /// Re-target a Quaff snapshot at a new outlier channel set — the
    /// adaptive re-detection hot-swap (report::ossh). Channels retained
    /// from the old set keep their exact `W_O` row and momentum factor, so
    /// their arithmetic is bit-identical before and after the swap; newly
    /// admitted channels take their row from the dequantized int8 store
    /// (`w_int · Δ`, the best representation available without the f32
    /// master, which a served bundle no longer holds) with a fresh factor
    /// of 1.0. Returns `None` for non-Quaff snapshots — no other method
    /// carries a targeted channel set to swap.
    pub fn retarget_channels(&self, new_set: &OutlierSet) -> Option<MethodSnapshot> {
        let MethodSnapshot::Quaff {
            w_int,
            deltas,
            w_o,
            w_row_max,
            channels,
            s_o,
            gamma,
            momentum,
        } = self
        else {
            return None;
        };
        let cout = w_int.cols();
        let mut new_w_o = Matrix::zeros(new_set.len(), cout);
        let mut new_s_o = Vec::with_capacity(new_set.len());
        for (i, &ch) in new_set.channels.iter().enumerate() {
            assert!(ch < w_int.rows(), "retarget channel {ch} out of range");
            if let Some(old_i) = channels.iter().position(|&c| c == ch) {
                for j in 0..cout {
                    new_w_o.set(i, j, w_o.get(old_i, j));
                }
                new_s_o.push(s_o[old_i]);
            } else {
                for j in 0..cout {
                    new_w_o.set(i, j, w_int.get(ch, j) as f32 * deltas[j]);
                }
                new_s_o.push(1.0);
            }
        }
        Some(MethodSnapshot::Quaff {
            w_int: w_int.clone(),
            deltas: deltas.clone(),
            w_o: new_w_o,
            w_row_max: w_row_max.clone(),
            channels: new_set.channels.clone(),
            s_o: new_s_o,
            gamma: *gamma,
            momentum: *momentum,
        })
    }
}

/// Rebuild a live method from a snapshot. The inverse of
/// [`QuantMethod::snapshot`]: `method_from_snapshot(m.snapshot())` behaves
/// bit-identically to `m` on every input.
pub fn method_from_snapshot(snap: MethodSnapshot) -> Box<dyn QuantMethod> {
    match snap {
        MethodSnapshot::Fp32 { w } => Box::new(Fp32Linear::new(w)),
        MethodSnapshot::Naive { w_int, deltas } => {
            Box::new(NaiveW8A8Linear::from_parts(w_int, deltas))
        }
        MethodSnapshot::LlmInt8 {
            w_int,
            deltas,
            sigma,
            dequant_rows_total,
            steps,
        } => Box::new(LlmInt8Linear::from_parts(
            w_int,
            deltas,
            sigma,
            dequant_rows_total,
            steps,
        )),
        MethodSnapshot::SmoothStatic { w_int, deltas, s } => {
            Box::new(SmoothStaticLinear::from_parts(w_int, deltas, s))
        }
        MethodSnapshot::SmoothDynamic {
            w_full,
            alpha,
            last_s,
        } => Box::new(SmoothDynamicLinear::from_parts(w_full, alpha, last_s)),
        MethodSnapshot::Quaff {
            w_int,
            deltas,
            w_o,
            w_row_max,
            channels,
            s_o,
            gamma,
            momentum,
        } => Box::new(QuaffLinear::from_parts(
            w_int, deltas, w_o, w_row_max, channels, s_o, gamma, momentum,
        )),
    }
}

/// Method selector (CLI + reports).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    Fp32,
    Naive,
    LlmInt8,
    SmoothStatic,
    SmoothDynamic,
    Quaff,
    /// Table 3 ablation: Quaff with the momentum mechanism disabled.
    QuaffNoMomentum,
}

impl MethodKind {
    pub const ALL: [MethodKind; 6] = [
        MethodKind::Fp32,
        MethodKind::LlmInt8,
        MethodKind::SmoothDynamic,
        MethodKind::Naive,
        MethodKind::SmoothStatic,
        MethodKind::Quaff,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            MethodKind::Fp32 => "FP32",
            MethodKind::Naive => "Naive",
            MethodKind::LlmInt8 => "LLM.int8",
            MethodKind::SmoothStatic => "Smooth_S",
            MethodKind::SmoothDynamic => "Smooth_D",
            MethodKind::Quaff => "Quaff",
            MethodKind::QuaffNoMomentum => "Quaff w/o Mo",
        }
    }

    pub fn parse(s: &str) -> Option<MethodKind> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" => Some(MethodKind::Fp32),
            "naive" => Some(MethodKind::Naive),
            "llmint8" | "llm.int8" | "llm_int8" => Some(MethodKind::LlmInt8),
            "smooth_s" | "smooths" | "smooth-static" => Some(MethodKind::SmoothStatic),
            "smooth_d" | "smoothd" | "smooth-dynamic" => Some(MethodKind::SmoothDynamic),
            "quaff" => Some(MethodKind::Quaff),
            "quaff-nomom" | "quaff_no_momentum" => Some(MethodKind::QuaffNoMomentum),
            _ => None,
        }
    }

    /// Is this one of the paper's "efficient" (pink-background) methods?
    pub fn is_efficient(&self) -> bool {
        !matches!(self, MethodKind::Fp32 | MethodKind::SmoothDynamic | MethodKind::LlmInt8)
    }
}

/// Configuration shared by method construction.
#[derive(Clone, Debug)]
pub struct MethodConfig {
    /// Quaff momentum γ (paper: 0.2).
    pub gamma: f32,
    /// SmoothQuant α (paper baselines: 0.5).
    pub alpha: f32,
    /// LLM.int8 outlier threshold σ on activation magnitude.
    pub llmint8_sigma: f32,
}

impl Default for MethodConfig {
    fn default() -> Self {
        MethodConfig {
            gamma: 0.2,
            alpha: 0.5,
            llmint8_sigma: 6.0,
        }
    }
}

/// Build a method instance for a layer with frozen weights `w`
/// (c_in × c_out), given calibration statistics and the pre-identified
/// outlier set (used by Smooth_S for its static factors and by Quaff for O).
pub fn build_method(
    kind: MethodKind,
    w: Matrix,
    calib: &ChannelStats,
    outliers: &OutlierSet,
    cfg: &MethodConfig,
) -> Box<dyn QuantMethod> {
    match kind {
        MethodKind::Fp32 => Box::new(Fp32Linear::new(w)),
        MethodKind::Naive => Box::new(NaiveW8A8Linear::new(w)),
        MethodKind::LlmInt8 => Box::new(LlmInt8Linear::new(w, cfg.llmint8_sigma)),
        MethodKind::SmoothStatic => Box::new(SmoothStaticLinear::new(w, calib, cfg.alpha)),
        MethodKind::SmoothDynamic => Box::new(SmoothDynamicLinear::new(w, cfg.alpha)),
        MethodKind::Quaff => Box::new(QuaffLinear::new(w, outliers.clone(), cfg.gamma, true)),
        MethodKind::QuaffNoMomentum => {
            Box::new(QuaffLinear::new(w, outliers.clone(), cfg.gamma, false))
        }
    }
}

/// `dX = (dY ∘ Δ_w) · W_intᵀ` — shared STE backward for all int8-weight
/// methods. Reads the int8 weights row-wise, never materializing an f32 W.
pub(crate) fn ste_backward(dy: &Matrix, w_int: &I8Matrix, w_deltas: &[f32]) -> Matrix {
    ste_backward_ws(dy, w_int, w_deltas, &mut Workspace::new())
}

/// [`ste_backward`] on the workspace: the Δ-scaled dY scratch comes from —
/// and goes back to — the arena; the returned dX is arena-backed too.
/// Sharded over the token rows of dX (each row reads the shared int8
/// weights, writes only itself — bit-identical for any thread count).
pub(crate) fn ste_backward_ws(
    dy: &Matrix,
    w_int: &I8Matrix,
    w_deltas: &[f32],
    ws: &mut Workspace,
) -> Matrix {
    use crate::tensor::pool::{self, shard_range, SplitMut};
    let (t, cout) = (dy.rows(), dy.cols());
    let cin = w_int.rows();
    assert_eq!(w_int.cols(), cout);
    assert_eq!(w_deltas.len(), cout);
    // scale dY columns by Δ_w once
    let mut dys = ws.take_matrix("ste.dys", t, cout);
    dys.data_mut().copy_from_slice(dy.data());
    dys.scale_cols(w_deltas);
    let mut out = ws.take_matrix("ste.dx", t, cin);
    let shards = pool::shards_for(t, t * cout * cin);
    if shards <= 1 {
        ste_rows(&dys, w_int, out.data_mut(), 0, t);
    } else {
        let split = SplitMut::new(out.data_mut());
        let dys_ref = &dys;
        pool::run_shards(shards, &|s| {
            let (r0, r1) = shard_range(t, shards, s);
            let orows = unsafe { split.slice(r0 * cin, (r1 - r0) * cin) };
            ste_rows(dys_ref, w_int, orows, r0, r1);
        });
    }
    ws.put_matrix("ste.dys", dys);
    out
}

/// Row-range core of the STE backward: dX rows `r0..r1`.
fn ste_rows(dys: &Matrix, w_int: &I8Matrix, orows: &mut [f32], r0: usize, r1: usize) {
    let cin = w_int.rows();
    for ti in r0..r1 {
        let drow = dys.row(ti);
        let orow = &mut orows[(ti - r0) * cin..(ti - r0 + 1) * cin];
        for (i, o) in orow.iter_mut().enumerate() {
            let wrow = w_int.row(i);
            let mut acc = 0.0f32;
            for (&d, &q) in drow.iter().zip(wrow) {
                acc += d * q as f32;
            }
            *o = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::util::prng::Rng;
    use crate::util::prop;

    pub(crate) fn make_calib(
        rng: &mut Rng,
        cin: usize,
        hot: &[usize],
        gain: f32,
        samples: usize,
    ) -> (ChannelStats, OutlierSet) {
        let mut stats = ChannelStats::new(cin);
        for _ in 0..samples {
            let mut x = Matrix::randn(16, cin, rng, 1.0);
            for &c in hot {
                for t in 0..16 {
                    let v = x.get(t, c);
                    x.set(t, c, v * gain);
                }
            }
            stats.observe(&x, 50.0);
        }
        let det = crate::outlier::OutlierDetector::new(50.0);
        let set = det.select(&stats, hot.len());
        (stats, set)
    }

    /// Activations with the same planted outlier channels as calibration.
    fn make_acts(rng: &mut Rng, t: usize, cin: usize, hot: &[usize], gain: f32) -> Matrix {
        let mut x = Matrix::randn(t, cin, rng, 1.0);
        for &c in hot {
            for ti in 0..t {
                let v = x.get(ti, c);
                x.set(ti, c, v * gain);
            }
        }
        x
    }

    #[test]
    fn all_methods_approximate_fp32() {
        let mut rng = Rng::new(21);
        let cin = 64;
        let cout = 48;
        let hot = vec![5, 33];
        let (calib, oset) = make_calib(&mut rng, cin, &hot, 120.0, 8);
        assert_eq!(oset.channels, hot);
        let w = Matrix::randn(cin, cout, &mut rng, 0.3);
        let x = make_acts(&mut rng, 12, cin, &hot, 120.0);
        let want = x.matmul(&w);
        let cfg = MethodConfig::default();
        let mut ws = Workspace::new();
        for kind in [
            MethodKind::Naive,
            MethodKind::LlmInt8,
            MethodKind::SmoothStatic,
            MethodKind::SmoothDynamic,
            MethodKind::Quaff,
            MethodKind::QuaffNoMomentum,
        ] {
            let mut m = build_method(kind, w.clone(), &calib, &oset, &cfg);
            let got = m.forward(&x, &mut ws);
            let err = quant::error_between(&want, &got);
            assert!(
                err.sqnr_db > 15.0,
                "{}: SQNR {:.1} dB too low (mse {})",
                m.name(),
                err.sqnr_db,
                err.mse
            );
        }
    }

    #[test]
    fn quaff_beats_naive_on_outlier_activations() {
        // The headline claim: with outlier channels present, Quaff's targeted
        // scaling yields lower quantization error than naive W8A8.
        let mut rng = Rng::new(22);
        let cin = 128;
        let cout = 96;
        let hot = vec![9, 70, 100];
        let (calib, oset) = make_calib(&mut rng, cin, &hot, 100.0, 8);
        let w = Matrix::randn(cin, cout, &mut rng, 0.3);
        let cfg = MethodConfig::default();
        let mut quaff = build_method(MethodKind::Quaff, w.clone(), &calib, &oset, &cfg);
        let mut naive = build_method(MethodKind::Naive, w.clone(), &calib, &oset, &cfg);
        let mut ws = Workspace::new();
        let mut q_mse = 0.0;
        let mut n_mse = 0.0;
        for _ in 0..6 {
            let x = make_acts(&mut rng, 16, cin, &hot, 100.0);
            let want = x.matmul(&w);
            q_mse += quant::error_between(&want, &quaff.forward(&x, &mut ws)).mse;
            n_mse += quant::error_between(&want, &naive.forward(&x, &mut ws)).mse;
        }
        assert!(
            q_mse < n_mse * 0.25,
            "quaff mse {q_mse} should be well below naive {n_mse}"
        );
    }

    #[test]
    fn memory_ordering_matches_paper() {
        // Smooth_D and FP32 hold f32 weights; int8 methods hold ~1/4;
        // Quaff adds only the small W_O slice on top of Naive.
        let mut rng = Rng::new(23);
        let cin = 256;
        let cout = 256;
        let hot = vec![3, 100, 200];
        let (calib, oset) = make_calib(&mut rng, cin, &hot, 100.0, 4);
        let w = Matrix::randn(cin, cout, &mut rng, 0.3);
        let cfg = MethodConfig::default();
        let bytes = |k| build_method(k, w.clone(), &calib, &oset, &cfg).weight_bytes();
        let fp32 = bytes(MethodKind::Fp32);
        let naive = bytes(MethodKind::Naive);
        let quaff = bytes(MethodKind::Quaff);
        let smooth_d = bytes(MethodKind::SmoothDynamic);
        assert!(naive < fp32 / 3, "naive {naive} vs fp32 {fp32}");
        assert!(quaff >= naive && quaff < naive + naive / 4, "quaff {quaff} naive {naive}");
        assert!(smooth_d >= fp32, "smooth_d must keep f32 weights");
    }

    #[test]
    fn ste_backward_matches_dequant_matmul() {
        prop::check("ste-bwd", 0xE1, 16, |r| {
            let t = 1 + r.below(8);
            let cin = 2 + r.below(24);
            let cout = 2 + r.below(24);
            let w = Matrix::randn(cin, cout, r, 0.5);
            let dy = Matrix::randn(t, cout, r, 1.0);
            (w, dy)
        }, |(w, dy)| {
            let qw = quant::QuantizedWeights::quantize(w);
            let got = ste_backward(dy, &qw.w_int, &qw.deltas);
            let wdq = qw.dequantize();
            let want = dy.matmul_bt(&wdq); // dY @ Wᵀ
            prop::all_close(got.data(), want.data(), 1e-4, 1e-3)
        });
    }

    #[test]
    fn snapshot_roundtrip_is_bit_identical_for_every_method() {
        let mut rng = Rng::new(0x5A07);
        let cin = 48;
        let cout = 32;
        let hot = vec![7, 30];
        let (calib, oset) = make_calib(&mut rng, cin, &hot, 90.0, 6);
        let w = Matrix::randn(cin, cout, &mut rng, 0.3);
        let cfg = MethodConfig::default();
        let mut ws = Workspace::new();
        for kind in [
            MethodKind::Fp32,
            MethodKind::Naive,
            MethodKind::LlmInt8,
            MethodKind::SmoothStatic,
            MethodKind::SmoothDynamic,
            MethodKind::Quaff,
            MethodKind::QuaffNoMomentum,
        ] {
            let mut original = build_method(kind, w.clone(), &calib, &oset, &cfg);
            // advance per-step state so the snapshot carries live momentum /
            // dynamic factors, not just the post-construction defaults
            for _ in 0..3 {
                let x = Matrix::randn(5, cin, &mut rng, 1.0);
                let y = original.forward(&x, &mut ws);
                ws.recycle(y);
            }
            let snap = original.snapshot();
            assert_eq!(snap.kind(), kind, "{}", original.name());
            assert_eq!((snap.cin(), snap.cout()), (cin, cout));
            let mut restored = method_from_snapshot(snap);
            assert_eq!(restored.name(), original.name());
            assert_eq!(restored.weight_bytes(), original.weight_bytes());
            // both continue bit-identically — forward (including further
            // per-step state updates) and backward
            for _ in 0..2 {
                let x = Matrix::randn(5, cin, &mut rng, 1.0);
                let ya = original.forward(&x, &mut ws);
                let yb = restored.forward(&x, &mut ws);
                assert_eq!(ya.data(), yb.data(), "{kind:?} forward diverged");
                ws.recycle(ya);
                ws.recycle(yb);
                let dy = Matrix::randn(5, cout, &mut rng, 1.0);
                let da = original.backward_input(&dy, &mut ws);
                let db = restored.backward_input(&dy, &mut ws);
                assert_eq!(da.data(), db.data(), "{kind:?} backward diverged");
                ws.recycle(da);
                ws.recycle(db);
            }
        }
    }

    #[test]
    fn retarget_keeps_retained_rows_and_dequantizes_new_ones() {
        let mut rng = Rng::new(0x0557);
        let cin = 48;
        let cout = 32;
        let hot = vec![7, 30];
        let (calib, oset) = make_calib(&mut rng, cin, &hot, 90.0, 6);
        let w = Matrix::randn(cin, cout, &mut rng, 0.3);
        let cfg = MethodConfig::default();
        let mut m = build_method(MethodKind::Quaff, w, &calib, &oset, &cfg);
        // advance momentum so retained factors are non-trivial
        let mut ws = Workspace::new();
        for _ in 0..2 {
            let x = Matrix::randn(4, cin, &mut rng, 1.0);
            let y = m.forward(&x, &mut ws);
            ws.recycle(y);
        }
        let snap = m.snapshot();
        let MethodSnapshot::Quaff { ref w_o, ref s_o, ref w_int, ref deltas, .. } = snap else {
            panic!("quaff snapshot expected");
        };
        let (old_w_o, old_s_o) = (w_o.clone(), s_o.clone());
        let (w_int, deltas) = (w_int.clone(), deltas.clone());
        // keep channel 30 (old index 1), drop 7, admit 11
        let new_set = OutlierSet::new(vec![11, 30]);
        let re = snap.retarget_channels(&new_set).expect("quaff retargets");
        let MethodSnapshot::Quaff { w_o, channels, s_o, .. } = &re else {
            panic!("retarget stays quaff");
        };
        assert_eq!(channels, &vec![11, 30]);
        assert_eq!(s_o.len(), 2);
        // retained channel 30 → exact old row + factor (now at index 1)
        for j in 0..cout {
            assert_eq!(w_o.get(1, j), old_w_o.get(1, j));
        }
        assert_eq!(s_o[1], old_s_o[1]);
        // new channel 11 → dequantized int8 row, fresh factor
        for j in 0..cout {
            assert_eq!(w_o.get(0, j), w_int.get(11, j) as f32 * deltas[j]);
        }
        assert_eq!(s_o[0], 1.0);
        // the retargeted snapshot rebuilds into a live method
        let rebuilt = method_from_snapshot(re);
        assert_eq!((rebuilt.cin(), rebuilt.cout()), (cin, cout));
        // non-Quaff snapshots refuse
        let naive = MethodSnapshot::Naive {
            w_int: w_int.clone(),
            deltas: deltas.clone(),
        };
        assert!(naive.retarget_channels(&new_set).is_none());
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in MethodKind::ALL {
            // every label should parse back (modulo case/punctuation)
            let parsed = MethodKind::parse(k.label());
            assert_eq!(parsed, Some(k), "label {}", k.label());
        }
        assert_eq!(MethodKind::parse("nope"), None);
    }

    #[test]
    fn efficiency_categorization() {
        assert!(MethodKind::Quaff.is_efficient());
        assert!(MethodKind::Naive.is_efficient());
        assert!(!MethodKind::Fp32.is_efficient());
        assert!(!MethodKind::SmoothDynamic.is_efficient());
    }
}
