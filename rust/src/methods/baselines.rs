//! Baseline WAQ methods: FP32, Naive W8A8, LLM.int8, SmoothQuant
//! static/dynamic — each performing exactly the per-step work the paper
//! attributes to it (§2.3, Appendix A).
//!
//! All transient buffers come from the caller's [`Workspace`]; after a
//! warm-up step the forwards/backwards are allocation-free — except where a
//! method's *semantic* cost is itself an allocation (Smooth_D's per-step
//! weight requantization), which stays, because that cost is the point of
//! the comparison.

use super::{ste_backward_ws, MethodSnapshot, QuantMethod};
use crate::outlier::ChannelStats;
use crate::quant::pipeline::{self, PlanId, ScaleOp};
use crate::quant::{self, QuantizedWeights};
use crate::scaling;
use crate::tensor::{kernels, I8Matrix, Matrix, Workspace};

/// Plan aux-slot roles for the LLM.int8 training-path correction stage.
const AX_COLMAX: usize = 0; // detection column maxima
const AX_CAMAX: usize = 1; // col_abs_max reduction lanes
const AX_XO: usize = 2; // gathered outlier activations (f32)
const AX_WO: usize = 3; // per-step dequantized weight rows
const AX_CORR: usize = 4; // f32 correction product

/// Full-precision reference: `Y = X · W` in f32.
pub struct Fp32Linear {
    w: Matrix,
    plan: PlanId,
}

impl Fp32Linear {
    pub fn new(w: Matrix) -> Self {
        Fp32Linear { w, plan: PlanId::fresh() }
    }
}

impl QuantMethod for Fp32Linear {
    fn name(&self) -> &'static str {
        "FP32"
    }

    fn forward(&mut self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        self.forward_infer(x, ws)
    }

    fn forward_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let plan = pipeline::plan_for(ws, self.plan, self.w.rows(), self.w.cols(), x.rows());
        let mut y = ws.take_donor_matrix(x.rows(), self.w.cols());
        plan.matmul_f32(x, &self.w, &mut y);
        pipeline::store_plan(ws, self.plan, plan);
        y
    }

    fn warm_plan(&self, m_hint: usize, ws: &mut Workspace) {
        pipeline::warm(ws, self.plan, self.w.rows(), self.w.cols(), m_hint);
    }

    fn backward_input(&self, dy: &Matrix, ws: &mut Workspace) -> Matrix {
        let mut dx = ws.take_matrix("fp32.dx", dy.rows(), self.w.rows());
        kernels::matmul_bt_into(dy, &self.w, &mut dx);
        dx
    }

    fn weight_bytes(&self) -> usize {
        self.w.data().len() * 4
    }

    fn cin(&self) -> usize {
        self.w.rows()
    }

    fn cout(&self) -> usize {
        self.w.cols()
    }

    fn snapshot(&self) -> MethodSnapshot {
        MethodSnapshot::Fp32 { w: self.w.clone() }
    }
}

/// Naive W8A8 (Eq. 2): per-OC weight quant once, per-token activation quant
/// each step, integer matmul. Fast and small, but outliers inflate Δ_X.
pub struct NaiveW8A8Linear {
    qw: QuantizedWeights,
    plan: PlanId,
}

impl NaiveW8A8Linear {
    pub fn new(w: Matrix) -> Self {
        NaiveW8A8Linear {
            qw: QuantizedWeights::quantize(&w),
            plan: PlanId::fresh(),
        }
    }

    /// Rebuild from a persisted int8 store (no f32 master ever exists).
    pub fn from_parts(w_int: I8Matrix, deltas: Vec<f32>) -> Self {
        NaiveW8A8Linear {
            qw: QuantizedWeights::from_parts(w_int, deltas),
            plan: PlanId::fresh(),
        }
    }
}

impl QuantMethod for NaiveW8A8Linear {
    fn name(&self) -> &'static str {
        "Naive"
    }

    fn forward(&mut self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        self.forward_infer(x, ws)
    }

    fn forward_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let (t, cout) = (x.rows(), self.qw.w_int.cols());
        let plan = pipeline::plan_for(ws, self.plan, x.cols(), cout, t);
        let mut y = ws.take_donor_matrix(t, cout);
        pipeline::qgemm_into(x, &ScaleOp::Identity, &self.qw, &plan, ws, y.data_mut());
        pipeline::store_plan(ws, self.plan, plan);
        y
    }

    fn warm_plan(&self, m_hint: usize, ws: &mut Workspace) {
        pipeline::warm(ws, self.plan, self.qw.w_int.rows(), self.qw.w_int.cols(), m_hint);
    }

    fn backward_input(&self, dy: &Matrix, ws: &mut Workspace) -> Matrix {
        ste_backward_ws(dy, &self.qw.w_int, &self.qw.deltas, ws)
    }

    fn weight_bytes(&self) -> usize {
        self.qw.nbytes()
    }

    fn cin(&self) -> usize {
        self.qw.w_int.rows()
    }

    fn cout(&self) -> usize {
        self.qw.w_int.cols()
    }

    fn snapshot(&self) -> MethodSnapshot {
        MethodSnapshot::Naive {
            w_int: self.qw.w_int.clone(),
            deltas: self.qw.deltas.clone(),
        }
    }
}

/// LLM.int8 (Eq. 10/11): per-step *dynamic* outlier detection by absolute
/// threshold σ; outlier columns run in f32 against weight rows **dequantized
/// from the int8 store on every step** (the latency cost the paper calls
/// out); the rest runs int8.
pub struct LlmInt8Linear {
    qw: QuantizedWeights,
    sigma: f32,
    plan: PlanId,
    /// Running count of dequantized rows (diagnostics: card(O) growth).
    pub dequant_rows_total: u64,
    pub steps: u64,
}

impl LlmInt8Linear {
    pub fn new(w: Matrix, sigma: f32) -> Self {
        LlmInt8Linear {
            qw: QuantizedWeights::quantize(&w),
            sigma,
            plan: PlanId::fresh(),
            dequant_rows_total: 0,
            steps: 0,
        }
    }

    /// Rebuild from a persisted int8 store, threshold, and the lifetime
    /// detection counters (so diagnostics continue across a resume).
    pub fn from_parts(
        w_int: I8Matrix,
        deltas: Vec<f32>,
        sigma: f32,
        dequant_rows_total: u64,
        steps: u64,
    ) -> Self {
        LlmInt8Linear {
            qw: QuantizedWeights::from_parts(w_int, deltas),
            sigma,
            plan: PlanId::fresh(),
            dequant_rows_total,
            steps,
        }
    }

    /// Mean detected-outlier count per step.
    pub fn mean_outlier_cols(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.dequant_rows_total as f64 / self.steps as f64
        }
    }
}

impl QuantMethod for LlmInt8Linear {
    fn name(&self) -> &'static str {
        "LLM.int8"
    }

    fn forward(&mut self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let t = x.rows();
        let cout = self.qw.w_int.cols();
        let plan = pipeline::plan_for(ws, self.plan, x.cols(), cout, t);
        // 1. dynamic detection: columns whose |max| exceeds σ (slot-backed
        // reduction lanes — no string lookup, no allocation)
        let mut col_max = ws.take_slot_f32(plan.aux_f32[AX_COLMAX], x.cols());
        let mut camax = ws.take_slot_f32(plan.aux_f32[AX_CAMAX], 0);
        kernels::col_abs_max_scratch(x, &mut col_max, &mut camax);
        let mut outlier_cols = ws.take_slot_idx(plan.aux_idx);
        outlier_cols.extend((0..x.cols()).filter(|&c| col_max[c] > self.sigma));
        self.dequant_rows_total += outlier_cols.len() as u64;
        self.steps += 1;
        // 2. regular part: outlier columns zeroed *while* quantizing (no
        // masked X copy), matmul+dequant written straight into y
        let mut y = ws.take_donor_matrix(t, cout);
        pipeline::qgemm_into(
            x,
            &ScaleOp::ZeroCols { cols: &outlier_cols },
            &self.qw,
            &plan,
            ws,
            y.data_mut(),
        );
        // 3. outlier part in f32 — requires dequantizing W rows *every step*
        if !outlier_cols.is_empty() {
            let mut x_o = ws.take_slot_matrix(plan.aux_f32[AX_XO], t, outlier_cols.len());
            kernels::select_cols_into(x, &outlier_cols, &mut x_o);
            let mut w_o = ws.take_slot_matrix(plan.aux_f32[AX_WO], outlier_cols.len(), cout);
            quant::dequantize_rows_per_oc_into(&self.qw.w_int, &self.qw.deltas, &outlier_cols, &mut w_o);
            let mut corr = ws.take_slot_matrix(plan.aux_f32[AX_CORR], t, cout);
            kernels::matmul_into(&x_o, &w_o, &mut corr);
            y.add_assign(&corr);
            ws.put_slot_matrix(plan.aux_f32[AX_XO], x_o);
            ws.put_slot_matrix(plan.aux_f32[AX_WO], w_o);
            ws.put_slot_matrix(plan.aux_f32[AX_CORR], corr);
        }
        ws.put_slot_f32(plan.aux_f32[AX_COLMAX], col_max);
        ws.put_slot_f32(plan.aux_f32[AX_CAMAX], camax);
        ws.put_slot_idx(plan.aux_idx, outlier_cols);
        pipeline::store_plan(ws, self.plan, plan);
        y
    }

    /// Inference mode detects outliers **per token row** (columns of that
    /// row whose |x| exceeds σ) instead of per batch column, so each output
    /// row depends only on its own input row — the row-locality incremental
    /// decoding needs. The detection counters stay frozen.
    fn forward_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let t = x.rows();
        let cout = self.qw.w_int.cols();
        let plan = pipeline::plan_for(ws, self.plan, x.cols(), cout, t);
        // 1. regular part: this row's outlier entries zeroed while
        // quantizing (row-local, no masked X copy), fused matmul into y
        let mut y = ws.take_donor_matrix(t, cout);
        pipeline::qgemm_into(
            x,
            &ScaleOp::ZeroAbsAbove { sigma: self.sigma },
            &self.qw,
            &plan,
            ws,
            y.data_mut(),
        );
        pipeline::store_plan(ws, self.plan, plan);
        // 2. outlier part in f32: per row, dequantize the hit weight rows
        // from the int8 store (the method's per-step latency cost)
        for ti in 0..t {
            let xr = x.row(ti);
            let yr = y.row_mut(ti);
            for (c, &xv) in xr.iter().enumerate() {
                if xv.abs() <= self.sigma {
                    continue;
                }
                let wrow = self.qw.w_int.row(c);
                for ((o, &q), &d) in yr.iter_mut().zip(wrow).zip(self.qw.deltas.iter()) {
                    *o += xv * q as f32 * d;
                }
            }
        }
        y
    }

    fn warm_plan(&self, m_hint: usize, ws: &mut Workspace) {
        pipeline::warm(ws, self.plan, self.qw.w_int.rows(), self.qw.w_int.cols(), m_hint);
    }

    fn backward_input(&self, dy: &Matrix, ws: &mut Workspace) -> Matrix {
        ste_backward_ws(dy, &self.qw.w_int, &self.qw.deltas, ws)
    }

    fn weight_bytes(&self) -> usize {
        self.qw.nbytes()
    }

    fn cin(&self) -> usize {
        self.qw.w_int.rows()
    }

    fn cout(&self) -> usize {
        self.qw.w_int.cols()
    }

    fn snapshot(&self) -> MethodSnapshot {
        MethodSnapshot::LlmInt8 {
            w_int: self.qw.w_int.clone(),
            deltas: self.qw.deltas.clone(),
            sigma: self.sigma,
            dequant_rows_total: self.dequant_rows_total,
            steps: self.steps,
        }
    }
}

/// SmoothQuant **static** (Smooth_S): factors fixed from calibration data;
/// `Ŵ = s·W` quantized once; activations rescaled by `s^{-1}` every step.
/// Cheap, but mismatched once the activation distribution shifts (Fig. 11).
pub struct SmoothStaticLinear {
    qw_scaled: QuantizedWeights,
    s: Vec<f32>,
    /// Precomputed `s^{-1}` so the per-step rescale never allocates.
    inv_s: Vec<f32>,
    plan: PlanId,
}

impl SmoothStaticLinear {
    pub fn new(w: Matrix, calib: &ChannelStats, alpha: f32) -> Self {
        // per-input-channel weight max = max over row i of |W|
        let w_row_max: Vec<f32> = (0..w.rows())
            .map(|i| w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect();
        let s = scaling::smoothquant_factors(&calib.abs_max, &w_row_max, alpha);
        let inv_s: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        let mut w_scaled = w;
        scaling::apply_row_scale(&mut w_scaled, &s);
        SmoothStaticLinear {
            qw_scaled: QuantizedWeights::quantize(&w_scaled),
            s,
            inv_s,
            plan: PlanId::fresh(),
        }
    }

    /// Rebuild from the persisted **scaled** int8 store + static factors;
    /// the reciprocals are a pure derivation (recomputed exactly as the
    /// constructor does).
    pub fn from_parts(w_int: I8Matrix, deltas: Vec<f32>, s: Vec<f32>) -> Self {
        assert_eq!(s.len(), w_int.rows(), "factor count must match c_in");
        let inv_s: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        SmoothStaticLinear {
            qw_scaled: QuantizedWeights::from_parts(w_int, deltas),
            s,
            inv_s,
            plan: PlanId::fresh(),
        }
    }
}

impl QuantMethod for SmoothStaticLinear {
    fn name(&self) -> &'static str {
        "Smooth_S"
    }

    fn forward(&mut self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        self.forward_infer(x, ws)
    }

    fn forward_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let (t, cout) = (x.rows(), self.qw_scaled.w_int.cols());
        let plan = pipeline::plan_for(ws, self.plan, x.cols(), cout, t);
        let mut y = ws.take_donor_matrix(t, cout);
        pipeline::qgemm_into(
            x,
            &ScaleOp::MulPerCol { inv: &self.inv_s },
            &self.qw_scaled,
            &plan,
            ws,
            y.data_mut(),
        );
        pipeline::store_plan(ws, self.plan, plan);
        y
    }

    fn warm_plan(&self, m_hint: usize, ws: &mut Workspace) {
        pipeline::warm(
            ws,
            self.plan,
            self.qw_scaled.w_int.rows(),
            self.qw_scaled.w_int.cols(),
            m_hint,
        );
    }

    fn backward_input(&self, dy: &Matrix, ws: &mut Workspace) -> Matrix {
        // d(X)= dY·Ŵᵀ ∘ s^{-1}  (chain rule through X̂ = X·s^{-1}, Y = X̂Ŵ)
        let mut dx = ste_backward_ws(dy, &self.qw_scaled.w_int, &self.qw_scaled.deltas, ws);
        dx.scale_cols(&self.inv_s);
        dx
    }

    fn weight_bytes(&self) -> usize {
        self.qw_scaled.nbytes() + self.s.len() * 4
    }

    fn cin(&self) -> usize {
        self.qw_scaled.w_int.rows()
    }

    fn cout(&self) -> usize {
        self.qw_scaled.w_int.cols()
    }

    fn scaling_factors(&self) -> Option<Vec<f32>> {
        Some(self.s.clone())
    }

    fn snapshot(&self) -> MethodSnapshot {
        MethodSnapshot::SmoothStatic {
            w_int: self.qw_scaled.w_int.clone(),
            deltas: self.qw_scaled.deltas.clone(),
            s: self.s.clone(),
        }
    }
}

/// SmoothQuant **dynamic** (Smooth_D): recompute `s` from the *current*
/// batch, rescale and **requantize the full weight matrix every step** —
/// which forces keeping W in f32 (the memory cost) and paying a full
/// quantization pass per step (the latency cost). The requantization
/// deliberately stays off the workspace: its allocations ARE the method's
/// per-step cost the paper measures.
pub struct SmoothDynamicLinear {
    w_full: Matrix,
    w_row_max: Vec<f32>,
    alpha: f32,
    last_s: Vec<f32>,
    plan: PlanId,
}

impl SmoothDynamicLinear {
    pub fn new(w: Matrix, alpha: f32) -> Self {
        let w_row_max: Vec<f32> = (0..w.rows())
            .map(|i| w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect();
        let cin = w.rows();
        SmoothDynamicLinear {
            w_full: w,
            w_row_max,
            alpha,
            last_s: vec![1.0; cin],
            plan: PlanId::fresh(),
        }
    }

    /// Rebuild from the persisted f32 master (the method must keep one —
    /// that memory cost is its point in the comparison) + the factors of
    /// the last step taken, so a resumed `forward_infer` is bit-identical.
    pub fn from_parts(w_full: Matrix, alpha: f32, last_s: Vec<f32>) -> Self {
        assert_eq!(last_s.len(), w_full.rows(), "factor count must match c_in");
        let w_row_max: Vec<f32> = (0..w_full.rows())
            .map(|i| w_full.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect();
        SmoothDynamicLinear {
            w_full,
            w_row_max,
            alpha,
            last_s,
            plan: PlanId::fresh(),
        }
    }

    /// Shared tail of both Smooth_D forwards: requantize the full weight
    /// under `s` (the method's deliberate per-step cost — the allocations
    /// here ARE what the paper measures), then run the activation side
    /// through the shared fused plan.
    fn coupled_forward(&self, x: &Matrix, s: &[f32], ws: &mut Workspace) -> Matrix {
        let (t, cout) = (x.rows(), self.w_full.cols());
        let mut w_scaled = self.w_full.clone();
        scaling::apply_row_scale(&mut w_scaled, s);
        let qw = QuantizedWeights::quantize(&w_scaled);
        // the reciprocal vector matches what apply_full_inverse_scale
        // computed per step (an allocation the method semantically owns)
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        let plan = pipeline::plan_for(ws, self.plan, x.cols(), cout, t);
        let mut y = ws.take_donor_matrix(t, cout);
        pipeline::qgemm_into(x, &ScaleOp::MulPerCol { inv: &inv }, &qw, &plan, ws, y.data_mut());
        pipeline::store_plan(ws, self.plan, plan);
        y
    }
}

impl QuantMethod for SmoothDynamicLinear {
    fn name(&self) -> &'static str {
        "Smooth_D"
    }

    fn forward(&mut self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        // 1. dynamic factors from the live batch; 2. the coupling
        // bottleneck: rescale + requantize the FULL weight; 3. scaled
        // activation path through the shared fused plan
        let s = scaling::smoothquant_factors(&x.col_abs_max(), &self.w_row_max, self.alpha);
        let y = self.coupled_forward(x, &s, ws);
        self.last_s = s;
        y
    }

    /// Inference mode freezes the factors at their most recent per-step
    /// values (`last_s`; all-ones if the layer never stepped) — the weights
    /// are still rescaled and requantized per call, because that coupling
    /// is the cost the method is measured for.
    fn forward_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        self.coupled_forward(x, &self.last_s, ws)
    }

    fn warm_plan(&self, m_hint: usize, ws: &mut Workspace) {
        pipeline::warm(ws, self.plan, self.w_full.rows(), self.w_full.cols(), m_hint);
    }

    fn backward_input(&self, dy: &Matrix, ws: &mut Workspace) -> Matrix {
        // keeps full-precision W anyway, so the backward is exact
        let mut dx = ws.take_matrix("smoothd.dx_bwd", dy.rows(), self.w_full.rows());
        kernels::matmul_bt_into(dy, &self.w_full, &mut dx);
        dx
    }

    fn weight_bytes(&self) -> usize {
        // full-precision master + the transient scaled/quantized copies
        self.w_full.data().len() * 4
    }

    fn cin(&self) -> usize {
        self.w_full.rows()
    }

    fn cout(&self) -> usize {
        self.w_full.cols()
    }

    fn scaling_factors(&self) -> Option<Vec<f32>> {
        Some(self.last_s.clone())
    }

    fn snapshot(&self) -> MethodSnapshot {
        MethodSnapshot::SmoothDynamic {
            w_full: self.w_full.clone(),
            alpha: self.alpha,
            last_s: self.last_s.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error_between;
    use crate::util::prng::Rng;

    #[test]
    fn fp32_is_exact() {
        let mut r = Rng::new(31);
        let mut ws = Workspace::new();
        let w = Matrix::randn(16, 8, &mut r, 0.5);
        let x = Matrix::randn(4, 16, &mut r, 1.0);
        let mut m = Fp32Linear::new(w.clone());
        let y = m.forward(&x, &mut ws);
        assert_eq!(y.data(), x.matmul(&w).data());
        assert_eq!(m.weight_bytes(), 16 * 8 * 4);
    }

    #[test]
    fn llmint8_detects_and_corrects_outliers() {
        let mut r = Rng::new(32);
        let mut ws = Workspace::new();
        let w = Matrix::randn(32, 16, &mut r, 0.3);
        let mut x = Matrix::randn(8, 32, &mut r, 1.0);
        // plant a hot column above sigma
        for t in 0..8 {
            x.set(t, 5, 80.0 + t as f32);
        }
        let want = x.matmul(&w);
        let mut m = LlmInt8Linear::new(w, 6.0);
        let y = m.forward(&x, &mut ws);
        assert_eq!(m.dequant_rows_total, 1);
        let err = error_between(&want, &y);
        assert!(err.sqnr_db > 25.0, "sqnr {}", err.sqnr_db);
    }

    #[test]
    fn llmint8_outlier_count_grows_with_hot_columns() {
        let mut r = Rng::new(33);
        let mut ws = Workspace::new();
        let w = Matrix::randn(64, 16, &mut r, 0.3);
        let mut m = LlmInt8Linear::new(w, 6.0);
        for hot_n in [0usize, 4, 16] {
            let mut x = Matrix::randn(4, 64, &mut r, 1.0);
            for c in 0..hot_n {
                for t in 0..4 {
                    x.set(t, c * 3, 50.0);
                }
            }
            let _ = m.forward(&x, &mut ws);
        }
        assert!(m.dequant_rows_total >= 4 + 16);
        assert_eq!(m.steps, 3);
    }

    #[test]
    fn smooth_dynamic_tracks_current_batch() {
        let mut r = Rng::new(34);
        let mut ws = Workspace::new();
        let w = Matrix::randn(32, 16, &mut r, 0.3);
        let mut m = SmoothDynamicLinear::new(w, 0.5);
        let mut x = Matrix::randn(4, 32, &mut r, 1.0);
        for t in 0..4 {
            x.set(t, 7, 100.0);
        }
        let _ = m.forward(&x, &mut ws);
        let s = m.scaling_factors().unwrap();
        // channel 7's factor should dominate all others
        let max_other = (0..32)
            .filter(|&c| c != 7)
            .map(|c| s[c])
            .fold(0.0f32, f32::max);
        assert!(s[7] > 2.0 * max_other, "s7={} max_other={}", s[7], max_other);
    }

    #[test]
    fn smooth_static_factors_fixed_across_steps() {
        let mut r = Rng::new(35);
        let mut ws = Workspace::new();
        let w = Matrix::randn(32, 16, &mut r, 0.3);
        let mut calib = ChannelStats::new(32);
        for _ in 0..4 {
            calib.observe(&Matrix::randn(8, 32, &mut r, 1.0), 100.0);
        }
        let mut m = SmoothStaticLinear::new(w, &calib, 0.5);
        let s0 = m.scaling_factors().unwrap();
        let _ = m.forward(&Matrix::randn(4, 32, &mut r, 5.0), &mut ws);
        let s1 = m.scaling_factors().unwrap();
        assert_eq!(s0, s1);
    }

    #[test]
    fn backward_shapes() {
        let mut r = Rng::new(36);
        let mut ws = Workspace::new();
        let w = Matrix::randn(24, 10, &mut r, 0.3);
        let dy = Matrix::randn(3, 10, &mut r, 1.0);
        let calib = {
            let mut c = ChannelStats::new(24);
            c.observe(&Matrix::randn(4, 24, &mut r, 1.0), 100.0);
            c
        };
        let methods: Vec<Box<dyn QuantMethod>> = vec![
            Box::new(Fp32Linear::new(w.clone())),
            Box::new(NaiveW8A8Linear::new(w.clone())),
            Box::new(LlmInt8Linear::new(w.clone(), 6.0)),
            Box::new(SmoothStaticLinear::new(w.clone(), &calib, 0.5)),
            Box::new(SmoothDynamicLinear::new(w.clone(), 0.5)),
        ];
        for m in &methods {
            let dx = m.backward_input(&dy, &mut ws);
            assert_eq!((dx.rows(), dx.cols()), (3, 24), "{}", m.name());
            ws.recycle(dx);
        }
    }
}
