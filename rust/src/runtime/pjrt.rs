//! PJRT backend (behind the `pjrt` cargo feature): loads the AOT-compiled
//! HLO-text artifacts produced by `python/compile/aot.py`, compiles them
//! once on the PJRT CPU client, and executes them from the L3 hot path.
//!
//! The default offline build links the API-compatible stub crate in
//! `rust/vendor/xla` (whose client constructor returns a descriptive
//! error); deployments with the real `xla-rs` bindings swap it via a
//! `[patch]` entry — see `DESIGN.md` §PJRT.

use super::{ArraySpec, ExecBackend, HostValue, Manifest};
use crate::util::error::Result;
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::path::Path;

impl HostValue {
    fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, dims, bytes): (xla::ElementType, &[usize], Vec<u8>) = match self {
            HostValue::F32(shape, data) => (
                xla::ElementType::F32,
                shape,
                data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            HostValue::I32(shape, data) => (
                xla::ElementType::S32,
                shape,
                data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, dims, &bytes)
            .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    fn from_literal(lit: &xla::Literal, spec: &ArraySpec) -> Result<HostValue> {
        match spec.dtype.as_str() {
            "float32" => Ok(HostValue::F32(
                spec.shape.clone(),
                lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            )),
            "int32" => Ok(HostValue::I32(
                spec.shape.clone(),
                lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            )),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }
}

/// The engine: a PJRT CPU client plus compiled executables, keyed by
/// artifact name.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Compile seconds per artifact (diagnostics).
    pub compile_secs: BTreeMap<String, f64>,
}

impl Engine {
    /// Load the manifest and compile every artifact.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut executables = BTreeMap::new();
        let mut compile_secs = BTreeMap::new();
        for (name, entry) in &manifest.artifacts {
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&entry.path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", entry.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            compile_secs.insert(name.clone(), t0.elapsed().as_secs_f64());
            executables.insert(name.clone(), exe);
        }
        Ok(Engine {
            manifest,
            client,
            executables,
            compile_secs,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute an artifact with ordered inputs; returns ordered outputs.
    pub fn execute(&self, name: &str, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        let entry = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (hv, spec) in inputs.iter().zip(&entry.inputs) {
            if hv.shape() != spec.shape.as_slice() {
                bail!(
                    "artifact {name}: input '{}' shape {:?} != manifest {:?}",
                    spec.name,
                    hv.shape(),
                    spec.shape
                );
            }
        }
        let exe = &self.executables[name];
        let literals = inputs
            .iter()
            .map(HostValue::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        // aot.py lowers with return_tuple=True: one tuple of N outputs
        let parts = tuple.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "artifact {name}: expected {} outputs, got {}",
                entry.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| HostValue::from_literal(lit, spec))
            .collect()
    }
}

impl ExecBackend for Engine {
    fn platform(&self) -> String {
        Engine::platform(self)
    }

    fn entry_points(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }

    fn execute(&self, name: &str, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        Engine::execute(self, name, inputs)
    }
}

/// Threads fine-tuning state across `train_step` executions.
///
/// Input order (from `aot.py`): `tokens, mask, t, lora…, m…, v…, scales…`.
/// Output order: `loss, t, lora…, m…, v…, scales…`.
pub struct TrainSession<'e> {
    engine: &'e Engine,
    /// Persistent state: everything after (tokens, mask) in input order.
    state: Vec<HostValue>,
    pub steps: u64,
    pub losses: Vec<f64>,
}

impl<'e> TrainSession<'e> {
    /// Initialize state from the manifest specs (zeros — matching aot.py's
    /// zero-initialized Adam moments and LoRA-B, ones for scales).
    pub fn new(engine: &'e Engine) -> Result<TrainSession<'e>> {
        let entry = engine
            .manifest
            .artifacts
            .get("train_step")
            .ok_or_else(|| anyhow!("no train_step artifact"))?;
        let mut state = Vec::new();
        for spec in &entry.inputs[2..] {
            let n = spec.numel();
            let hv = match spec.name.as_str() {
                s if s.starts_with("scales.") => HostValue::F32(spec.shape.clone(), vec![1.0; n]),
                s if s.starts_with("lora.") && s.ends_with("lora_a") => {
                    // Gaussian init matching aot.py's seed is impossible from
                    // here; instead load from the artifact goldens if needed.
                    // Zero init for A is also valid (B is zero ⇒ ΔY = 0).
                    HostValue::F32(spec.shape.clone(), vec![0.0; n])
                }
                _ => HostValue::F32(spec.shape.clone(), vec![0.0; n]),
            };
            state.push(hv);
        }
        // seed lora_a with a deterministic small init so training can move
        let mut k = 0x9E3779B97F4A7C15u64;
        for (hv, spec) in state.iter_mut().zip(&entry.inputs[2..]) {
            if spec.name.starts_with("lora.") && spec.name.ends_with("lora_a") {
                if let HostValue::F32(shape, data) = hv {
                    let cin = shape[0] as f32;
                    for v in data.iter_mut() {
                        k ^= k << 13;
                        k ^= k >> 7;
                        k ^= k << 17;
                        let u = (k >> 40) as f32 / (1u64 << 24) as f32;
                        *v = (u - 0.5) * 2.0 / cin.sqrt();
                    }
                }
            }
        }
        Ok(TrainSession {
            engine,
            state,
            steps: 0,
            losses: Vec::new(),
        })
    }

    /// One training step; returns the loss.
    pub fn step(&mut self, tokens: &[i32], mask: &[f32]) -> Result<f64> {
        let m = &self.engine.manifest;
        let mut inputs = Vec::with_capacity(2 + self.state.len());
        inputs.push(HostValue::I32(vec![m.batch, m.seq], tokens.to_vec()));
        inputs.push(HostValue::F32(vec![m.batch, m.seq], mask.to_vec()));
        inputs.extend(self.state.iter().cloned());
        let outputs = self.engine.execute("train_step", &inputs)?;
        let loss = outputs[0]
            .as_f32()
            .and_then(|v| v.first().copied())
            .ok_or_else(|| anyhow!("loss missing"))? as f64;
        // outputs: loss, t, lora…, m…, v…, scales… → state = outputs[1..]
        self.state = outputs[1..].to_vec();
        self.steps += 1;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Evaluate: returns (loss, predictions).
    pub fn eval(&self, tokens: &[i32], mask: &[f32]) -> Result<(f64, Vec<i32>)> {
        let m = &self.engine.manifest;
        let entry = self
            .engine
            .manifest
            .artifacts
            .get("eval_step")
            .ok_or_else(|| anyhow!("no eval_step artifact"))?;
        // eval inputs: tokens, mask, lora…, scales…
        let n_lora = entry
            .inputs
            .iter()
            .filter(|s| s.name.starts_with("lora."))
            .count();
        let mut inputs = Vec::new();
        inputs.push(HostValue::I32(vec![m.batch, m.seq], tokens.to_vec()));
        inputs.push(HostValue::F32(vec![m.batch, m.seq], mask.to_vec()));
        // state order: t is state[0]; lora = state[1..1+n_lora]
        inputs.extend(self.state[1..1 + n_lora].iter().cloned());
        let n_scales = entry
            .inputs
            .iter()
            .filter(|s| s.name.starts_with("scales."))
            .count();
        let scales_start = self.state.len() - n_scales;
        inputs.extend(self.state[scales_start..].iter().cloned());
        let outputs = self.engine.execute("eval_step", &inputs)?;
        let loss = outputs[0].as_f32().and_then(|v| v.first().copied()).unwrap_or(f32::NAN) as f64;
        let preds = outputs[1].as_i32().unwrap_or(&[]).to_vec();
        Ok((loss, preds))
    }

    /// Current momentum scale vectors (diagnostics).
    pub fn scales(&self) -> Vec<&HostValue> {
        let entry = &self.engine.manifest.artifacts["train_step"];
        entry.inputs[2..]
            .iter()
            .zip(&self.state)
            .filter(|(s, _)| s.name.starts_with("scales."))
            .map(|(_, v)| v)
            .collect()
    }
}

#[cfg(test)]
mod literal_roundtrip_tests {
    use super::*;

    #[test]
    fn untyped_literal_roundtrip() {
        let hv = HostValue::F32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = hv.to_literal().unwrap();
        let back = lit.to_vec::<f32>().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let hv = HostValue::I32(vec![4], vec![7, -8, 9, 10]);
        let lit = hv.to_literal().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, -8, 9, 10]);
    }
}
