//! Execution-engine runtime: the artifact/marshaling contract plus a
//! pluggable [`ExecBackend`].
//!
//! Two backends implement the trait:
//!
//! * [`NativeBackend`] — pure Rust, always available: a registry of named
//!   kernels executed on the L3 tensor substrate (the same workspace-backed
//!   int8 path the trainer uses). This is what `cargo build` gives you
//!   offline, with zero external dependencies.
//! * `Engine` (behind the **`pjrt`** cargo feature, in [`pjrt`]) — loads
//!   the AOT-compiled HLO-text artifacts produced by `python/compile/aot.py`,
//!   compiles them once on the PJRT CPU client, and executes them from the
//!   L3 hot path. Python never runs here. The feature is off by default so
//!   the default build needs neither network nor the native `xla` library;
//!   see `DESIGN.md` §PJRT for how to enable it against real bindings.
//!
//! The artifact contract is `artifacts/manifest.json`: flattened, ordered
//! input/output specs for each HLO module; `TrainSession` (pjrt) threads
//! training state through successive `train_step` executions.

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Engine, TrainSession};

use crate::quant;
use crate::tensor::{kernels, Matrix, Workspace};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// dtype + shape of one marshaled array.
#[derive(Clone, Debug, PartialEq)]
pub struct ArraySpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl ArraySpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<ArraySpec> {
        Ok(ArraySpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("spec missing name"))?
                .to_string(),
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("spec missing dtype"))?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("spec missing shape"))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
        })
    }
}

/// One lowered HLO module + its marshaling contract.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub path: PathBuf,
    pub inputs: Vec<ArraySpec>,
    pub outputs: Vec<ArraySpec>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub gamma: f64,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let cfg = j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("artifacts") {
            for (name, entry) in map {
                let inputs = entry
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(ArraySpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = entry
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(ArraySpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let rel = entry
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name} missing path"))?;
                artifacts.insert(
                    name.clone(),
                    ArtifactEntry {
                        path: dir.join(rel),
                        inputs,
                        outputs,
                    },
                );
            }
        }
        let get = |k: &str| cfg.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        Ok(Manifest {
            preset: j
                .get("preset")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            batch: get("batch") as usize,
            seq: get("seq") as usize,
            vocab: get("vocab") as usize,
            gamma: get("gamma"),
            artifacts,
        })
    }
}

/// A host-side array crossing the backend boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostValue {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostValue {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(s, _) | HostValue::I32(s, _) => s,
        }
    }

    pub fn scalar_f32(x: f32) -> HostValue {
        HostValue::F32(vec![], vec![x])
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostValue::F32(_, v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostValue::I32(_, v) => Some(v),
            _ => None,
        }
    }

    /// View a 2-D f32 value as a [`Matrix`] (copies into row-major storage).
    pub fn to_matrix(&self) -> Result<Matrix> {
        match self {
            HostValue::F32(shape, data) if shape.len() == 2 => {
                Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
            }
            HostValue::F32(shape, _) => bail!("expected 2-D f32, got shape {shape:?}"),
            HostValue::I32(..) => bail!("expected f32, got i32"),
        }
    }

    pub fn from_matrix(m: &Matrix) -> HostValue {
        HostValue::F32(vec![m.rows(), m.cols()], m.data().to_vec())
    }
}

/// A pluggable executor of named entry points with [`HostValue`] I/O.
///
/// Implementations: [`NativeBackend`] (always), `Engine` (pjrt feature).
pub trait ExecBackend {
    /// Human-readable platform tag ("native-cpu", "cpu" via PJRT, ...).
    fn platform(&self) -> String;

    /// Entry points this backend can execute.
    fn entry_points(&self) -> Vec<String>;

    /// Execute `name` with ordered inputs; returns ordered outputs.
    fn execute(&self, name: &str, inputs: &[HostValue]) -> Result<Vec<HostValue>>;
}

/// A named native kernel: ordered [`HostValue`] inputs → ordered outputs.
pub type NativeKernel = Box<dyn Fn(&[HostValue]) -> Result<Vec<HostValue>> + Send + Sync>;

/// Pure-Rust [`ExecBackend`]: a registry of named kernels running on the L3
/// tensor substrate — every registered kernel executes on the sharded
/// `tensor::pool` paths (`QUAFF_THREADS` wide), so the backend abstraction
/// exposes the thread pool without touching the `pjrt` feature path. Ships
/// with the quantized-linear hot path built in, so the abstraction is
/// exercised end-to-end without PJRT:
///
/// * `"matmul"` — `(A [m,k], B [k,n]) → [m,n]` f32, cache-blocked,
///   row-sharded.
/// * `"quant_linear"` — `(X [t,cin], W [cin,cout]) → [t,cout]`: per-token
///   quantize X, per-OC quantize W, packed int8 matmul with fused dequant —
///   the legacy unfused kernel sequence, kept as the comparison reference.
/// * `"qgemm"` — same contract as `quant_linear`, executed through the
///   compiled-plan **fused** pipeline (`quant::pipeline`): one-pass
///   scale+quantize, matmul epilogue writing the output directly, slots
///   resolved once and cached in a backend-owned workspace. Bit-identical
///   to `quant_linear`; this is the first-class fused entry point the
///   serving/training layers run on.
/// * `"col_abs_max"` — `(X [r,c]) → [c]`: the pooled tree-reduced channel
///   statistic.
/// * `"attn_decode"` — `(q [1,d], K [len,d], V [len,d], n_heads []) →
///   [1,d]`: one cached-attention decode step (the `infer` subsystem's
///   core), exposed so backends can serve incremental decoding.
pub struct NativeBackend {
    kernels: BTreeMap<String, NativeKernel>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        let mut b = NativeBackend {
            kernels: BTreeMap::new(),
        };
        b.register("matmul", Box::new(native_matmul));
        b.register("quant_linear", Box::new(native_quant_linear));
        b.register("qgemm", native_qgemm_kernel());
        b.register("col_abs_max", Box::new(native_col_abs_max));
        b.register("attn_decode", Box::new(native_attn_decode));
        b
    }

    /// Register (or replace) a kernel under `name`.
    pub fn register(&mut self, name: &str, k: NativeKernel) {
        self.kernels.insert(name.to_string(), k);
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl ExecBackend for NativeBackend {
    fn platform(&self) -> String {
        format!(
            "native-cpu/{}t/{}",
            crate::tensor::pool::active_threads(),
            crate::tensor::simd::active().name()
        )
    }

    fn entry_points(&self) -> Vec<String> {
        self.kernels.keys().cloned().collect()
    }

    fn execute(&self, name: &str, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        let k = self
            .kernels
            .get(name)
            .ok_or_else(|| anyhow!("unknown native kernel '{name}'"))?;
        k(inputs)
    }
}

fn native_matmul(inputs: &[HostValue]) -> Result<Vec<HostValue>> {
    if inputs.len() != 2 {
        bail!("matmul expects 2 inputs, got {}", inputs.len());
    }
    let a = inputs[0].to_matrix().context("matmul input A")?;
    let b = inputs[1].to_matrix().context("matmul input B")?;
    if a.cols() != b.rows() {
        bail!("matmul shape mismatch: {}x{} @ {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    }
    let mut y = Matrix::zeros(a.rows(), b.cols());
    kernels::matmul_into(&a, &b, &mut y);
    Ok(vec![HostValue::from_matrix(&y)])
}

fn native_col_abs_max(inputs: &[HostValue]) -> Result<Vec<HostValue>> {
    if inputs.len() != 1 {
        bail!("col_abs_max expects 1 input, got {}", inputs.len());
    }
    let x = inputs[0].to_matrix().context("col_abs_max input X")?;
    let mut out = vec![0.0f32; x.cols()];
    kernels::col_abs_max_into(&x, &mut out);
    Ok(vec![HostValue::F32(vec![x.cols()], out)])
}

fn native_quant_linear(inputs: &[HostValue]) -> Result<Vec<HostValue>> {
    if inputs.len() != 2 {
        bail!("quant_linear expects 2 inputs (X, W), got {}", inputs.len());
    }
    let x = inputs[0].to_matrix().context("quant_linear input X")?;
    let w = inputs[1].to_matrix().context("quant_linear input W")?;
    if x.cols() != w.rows() {
        bail!("quant_linear shape mismatch: X cols {} vs W rows {}", x.cols(), w.rows());
    }
    let mut ws = Workspace::new();
    let qw = quant::QuantizedWeights::quantize(&w);
    let mut x_int = ws.take_i8_matrix("native.xint", x.rows(), x.cols());
    let mut dx = ws.take_f32("native.dx", x.rows());
    quant::quantize_per_token_into(&x, &mut x_int, &mut dx);
    let mut y = ws.take_matrix_zeroed("native.y", x.rows(), w.cols());
    qw.matmul_ws(&x_int, &dx, &mut ws, y.data_mut());
    Ok(vec![HostValue::from_matrix(&y)])
}

/// The fused plan-driven qgemm entry point: same `(X, W) → Y` contract as
/// `quant_linear`, but executed through `quant::pipeline` against a
/// backend-owned workspace. Plans are keyed **per layer shape** — each
/// distinct `(c_in, c_out)` compiles once and is reused on every later
/// call with that shape (alternating shapes must not recompile per call:
/// a recompile strands the old plan's bound slots, so shape-keying is
/// what keeps the persistent workspace bounded) — while staying
/// bit-identical to the unfused kernel.
fn native_qgemm_kernel() -> NativeKernel {
    use crate::quant::pipeline::{self, PlanId, ScaleOp};
    use std::sync::Mutex;
    type PlanTable = Vec<((usize, usize), PlanId)>;
    let state: Mutex<(Workspace, PlanTable)> = Mutex::new((Workspace::new(), Vec::new()));
    Box::new(move |inputs: &[HostValue]| {
        if inputs.len() != 2 {
            bail!("qgemm expects 2 inputs (X, W), got {}", inputs.len());
        }
        let x = inputs[0].to_matrix().context("qgemm input X")?;
        let w = inputs[1].to_matrix().context("qgemm input W")?;
        if x.cols() != w.rows() {
            bail!("qgemm shape mismatch: X cols {} vs W rows {}", x.cols(), w.rows());
        }
        let qw = quant::QuantizedWeights::quantize(&w);
        let shape = (x.cols(), w.cols());
        let mut guard = state.lock().map_err(|_| anyhow!("qgemm workspace poisoned"))?;
        let (ws, ids) = &mut *guard;
        let id = match ids.iter().find(|(s, _)| *s == shape) {
            Some((_, id)) => *id,
            None => {
                let id = PlanId::fresh();
                ids.push((shape, id));
                id
            }
        };
        let plan = pipeline::plan_for(ws, id, shape.0, shape.1, x.rows());
        let mut y = Matrix::zeros(x.rows(), w.cols());
        pipeline::qgemm_into(&x, &ScaleOp::Identity, &qw, &plan, ws, y.data_mut());
        pipeline::store_plan(ws, id, plan);
        Ok(vec![HostValue::from_matrix(&y)])
    })
}

fn native_attn_decode(inputs: &[HostValue]) -> Result<Vec<HostValue>> {
    if inputs.len() != 4 {
        bail!("attn_decode expects 4 inputs (q, K, V, n_heads), got {}", inputs.len());
    }
    let q = inputs[0].to_matrix().context("attn_decode input q")?;
    let k = inputs[1].to_matrix().context("attn_decode input K")?;
    let v = inputs[2].to_matrix().context("attn_decode input V")?;
    let n_heads = inputs[3]
        .as_f32()
        .and_then(|s| s.first().copied())
        .ok_or_else(|| anyhow!("attn_decode expects a scalar n_heads"))? as usize;
    let d = q.cols();
    if q.rows() != 1 {
        bail!("attn_decode takes a single query row, got {}", q.rows());
    }
    if (k.rows(), k.cols()) != (v.rows(), v.cols()) || k.cols() != d || k.rows() == 0 {
        bail!(
            "attn_decode K/V shape mismatch: K {}x{}, V {}x{}, d {}",
            k.rows(), k.cols(), v.rows(), v.cols(), d
        );
    }
    if n_heads == 0 || d % n_heads != 0 {
        bail!("attn_decode: d {d} not divisible by n_heads {n_heads}");
    }
    let mut out = Matrix::zeros(1, d);
    let mut scores = Vec::new();
    // contiguous K/V: a one-page identity table covering all rows
    crate::model::decode::attend_cached(
        q.row(0),
        k.data(),
        v.data(),
        &[0],
        k.rows(),
        k.rows() - 1,
        d,
        n_heads,
        &mut scores,
        out.row_mut(0),
    );
    Ok(vec![HostValue::from_matrix(&out)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join("quaff_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"preset":"small","config":{"batch":4,"seq":64,"vocab":288,"gamma":0.2},
                "artifacts":{"train_step":{"path":"train_step.hlo.txt",
                  "inputs":[{"name":"tokens","dtype":"int32","shape":[4,64]}],
                  "outputs":[{"name":"loss","dtype":"float32","shape":[]}]}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "small");
        assert_eq!(m.batch, 4);
        assert_eq!(m.artifacts["train_step"].inputs[0].shape, vec![4, 64]);
        assert_eq!(m.artifacts["train_step"].outputs[0].numel(), 1);
    }

    #[test]
    fn hostvalue_shapes() {
        let v = HostValue::F32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(HostValue::scalar_f32(1.0).shape(), &[] as &[usize]);
        assert!(v.as_f32().is_some());
        assert!(v.as_i32().is_none());
    }

    #[test]
    fn spec_numel_scalar_is_one() {
        let s = ArraySpec {
            name: "loss".into(),
            dtype: "float32".into(),
            shape: vec![],
        };
        assert_eq!(s.numel(), 1);
    }

    #[test]
    fn native_backend_matmul_matches_tensor_path() {
        use crate::util::prng::Rng;
        let mut r = Rng::new(7);
        let a = Matrix::randn(5, 8, &mut r, 1.0);
        let b = Matrix::randn(8, 3, &mut r, 1.0);
        let backend = NativeBackend::new();
        assert!(
            backend.platform().starts_with("native-cpu"),
            "platform should name the native substrate (got {})",
            backend.platform()
        );
        assert!(backend.entry_points().contains(&"matmul".to_string()));
        assert!(backend.entry_points().contains(&"col_abs_max".to_string()));
        let out = backend
            .execute(
                "matmul",
                &[HostValue::from_matrix(&a), HostValue::from_matrix(&b)],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[5, 3]);
        assert_eq!(out[0].as_f32().unwrap(), a.matmul(&b).data());
    }

    #[test]
    fn native_backend_quant_linear_approximates_f32() {
        use crate::quant::error_between;
        use crate::util::prng::Rng;
        let mut r = Rng::new(8);
        let x = Matrix::randn(12, 32, &mut r, 1.0);
        let w = Matrix::randn(32, 16, &mut r, 0.3);
        let backend = NativeBackend::new();
        let out = backend
            .execute(
                "quant_linear",
                &[HostValue::from_matrix(&x), HostValue::from_matrix(&w)],
            )
            .unwrap();
        let y = out[0].to_matrix().unwrap();
        let want = x.matmul(&w);
        let err = error_between(&want, &y);
        assert!(err.sqnr_db > 20.0, "int8 path too lossy: {} dB", err.sqnr_db);
    }

    #[test]
    fn native_backend_qgemm_matches_unfused_quant_linear_bitwise() {
        use crate::util::prng::Rng;
        let mut r = Rng::new(10);
        let backend = NativeBackend::new();
        assert!(backend.entry_points().contains(&"qgemm".to_string()));
        // several calls, including shape changes and a return to the first
        // shape (per-shape plans must reuse, never recompile-per-call),
        // against the backend's persistent plan workspace — every one must
        // match the unfused path
        for (t, cin, cout) in
            [(12usize, 32usize, 16usize), (12, 32, 16), (3, 20, 24), (12, 32, 16)]
        {
            let x = Matrix::randn(t, cin, &mut r, 1.0);
            let w = Matrix::randn(cin, cout, &mut r, 0.3);
            let inputs = [HostValue::from_matrix(&x), HostValue::from_matrix(&w)];
            let fused = backend.execute("qgemm", &inputs).unwrap();
            let unfused = backend.execute("quant_linear", &inputs).unwrap();
            assert_eq!(
                fused[0].as_f32().unwrap(),
                unfused[0].as_f32().unwrap(),
                "fused qgemm diverged from quant_linear at {t}x{cin}x{cout}"
            );
        }
        assert!(backend.execute("qgemm", &[]).is_err());
    }

    #[test]
    fn native_backend_col_abs_max_matches_tensor_path() {
        use crate::util::prng::Rng;
        let mut r = Rng::new(9);
        let x = Matrix::randn(17, 11, &mut r, 2.0);
        let backend = NativeBackend::new();
        let out = backend
            .execute("col_abs_max", &[HostValue::from_matrix(&x)])
            .unwrap();
        assert_eq!(out[0].shape(), &[11]);
        assert_eq!(out[0].as_f32().unwrap(), x.col_abs_max());
        assert!(backend.execute("col_abs_max", &[]).is_err());
    }

    #[test]
    fn native_backend_attn_decode_matches_full_attention() {
        use crate::model::layers::attention_forward;
        use crate::util::prng::Rng;
        let mut r = Rng::new(11);
        let (s, h, d) = (5usize, 2usize, 8usize);
        let q = Matrix::randn(s, d, &mut r, 1.0);
        let k = Matrix::randn(s, d, &mut r, 1.0);
        let v = Matrix::randn(s, d, &mut r, 1.0);
        let (full, _) = attention_forward(&q, &k, &v, 1, s, h);
        let backend = NativeBackend::new();
        let q_last = Matrix::from_vec(1, d, q.row(s - 1).to_vec());
        let out = backend
            .execute(
                "attn_decode",
                &[
                    HostValue::from_matrix(&q_last),
                    HostValue::from_matrix(&k),
                    HostValue::from_matrix(&v),
                    HostValue::scalar_f32(h as f32),
                ],
            )
            .unwrap();
        assert_eq!(out[0].shape(), &[1, d]);
        assert_eq!(out[0].as_f32().unwrap(), full.row(s - 1));
        // malformed calls are rejected, not panicked on
        assert!(backend.execute("attn_decode", &[HostValue::from_matrix(&q_last)]).is_err());
    }

    #[test]
    fn native_backend_rejects_unknown_and_bad_shapes() {
        let backend = NativeBackend::new();
        assert!(backend.execute("nope", &[]).is_err());
        let a = HostValue::F32(vec![2, 3], vec![0.0; 6]);
        let b = HostValue::F32(vec![4, 2], vec![0.0; 8]);
        assert!(backend.execute("matmul", &[a.clone(), b]).is_err());
        assert!(backend.execute("matmul", &[a]).is_err());
    }

    #[test]
    fn custom_kernel_registration() {
        let mut backend = NativeBackend::new();
        backend.register(
            "double",
            Box::new(|inputs: &[HostValue]| {
                let m = inputs[0].to_matrix()?;
                let mut d = m.clone();
                d.scale(2.0);
                Ok(vec![HostValue::from_matrix(&d)])
            }),
        );
        let m = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let out = backend.execute("double", &[HostValue::from_matrix(&m)]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 4.0]);
    }
}
