//! `quaff` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   report <id|all>   regenerate a paper table/figure (see DESIGN.md §6)
//!   finetune          run one fine-tuning job through the coordinator
//!   calibrate         run calibration only; print the outlier registry
//!   runtime           drive the AOT JAX artifacts through PJRT
//!   info              presets and environment
//!
//! Examples:
//!   quaff report fig1 --steps 12
//!   quaff finetune --dataset gpqa --method quaff --peft lora --steps 30
//!   quaff runtime --artifacts artifacts --steps 20

use quaff::coordinator::{run_job, FinetuneJob, PreprocessServer, ServerConfig};
use quaff::methods::MethodKind;
use quaff::model::ModelConfig;
use quaff::peft::PeftKind;
use quaff::report::{self, ReportOpts};
use quaff::util::cli::Args;
use quaff::util::error::{Context, Result};
use quaff::{anyhow, bail};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command() {
        Some("report") => cmd_report(&args),
        Some("finetune") => cmd_finetune(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("runtime") => cmd_runtime(&args),
        Some("info") | None => cmd_info(),
        Some(other) => {
            bail!("unknown command '{other}'; try: report, finetune, calibrate, runtime, info")
        }
    }
}

fn cmd_report(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: quaff report <id|all>"))?;
    let opts = ReportOpts::from_args(args);
    let ids: Vec<&str> = if id == "all" {
        report::ALL_REPORTS.to_vec()
    } else {
        vec![id.as_str()]
    };
    let mut all = String::new();
    for id in ids {
        eprintln!("[report] generating {id} …");
        let (md, secs) = quaff::util::timed(|| report::generate(id, &opts));
        eprintln!("[report] {id} done in {secs:.1}s");
        print!("{md}");
        all.push_str(&md);
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, &all).with_context(|| format!("writing report to {path}"))?;
        eprintln!("[report] written to {path}");
    }
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "gpqa").to_string();
    let method = MethodKind::parse(args.get_or("method", "quaff"))
        .ok_or_else(|| anyhow!("bad --method"))?;
    let peft = PeftKind::parse(args.get_or("peft", "lora")).ok_or_else(|| anyhow!("bad --peft"))?;
    let mut server_cfg = ServerConfig::default();
    server_cfg.preset = args.get_or("preset", "phi-mini").to_string();
    server_cfg.calib_task = args.get_or("calib-task", "oig-chip2").to_string();
    let server = PreprocessServer::new(server_cfg);
    let mut job = FinetuneJob::new(0, &dataset, method, peft);
    job.steps = args.get_parse("steps", 30);
    job.batch_size = args.get_parse("batch", 8);
    job.lr = args.get_parse("lr", 2e-3);
    job.seed = args.get_parse("seed", 7);
    eprintln!(
        "[finetune] {dataset} with {} + {} for {} steps …",
        method.label(),
        peft.label(),
        job.steps
    );
    let r = run_job(&server, &job)?;
    println!("dataset        : {}", r.dataset);
    println!("method / peft  : {} / {}", r.method.label(), r.peft.label());
    println!("steps          : {}", r.steps);
    println!("final loss     : {:.4}", r.final_loss);
    for (k, v) in &r.metrics {
        println!("{k:<15}: {v:.4}");
    }
    println!("latency/step   : {:.3}s", r.mean_step_secs);
    println!("memory total   : {}", quaff::util::fmt_bytes(r.memory.total()));
    println!("bundle payload : {}", quaff::util::fmt_bytes(r.payload_bytes));
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let mut cfg = ServerConfig::default();
    cfg.preset = args.get_or("preset", "phi-mini").to_string();
    cfg.calib_task = args.get_or("calib-task", "oig-chip2").to_string();
    cfg.calib_samples = args.get_parse("samples", 64);
    let server = PreprocessServer::new(cfg);
    let bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
    println!("preset           : {}", bundle.preset);
    println!(
        "payload bytes    : {}",
        quaff::util::fmt_bytes(bundle.payload_bytes)
    );
    println!("outlier overhead : {:.3}%", bundle.outlier_overhead * 100.0);
    println!("layers:");
    for (name, set) in bundle.registry.layers() {
        println!("  {name:<32} |O| = {:<3} {:?}", set.len(), set.channels);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_runtime(_args: &Args) -> Result<()> {
    bail!(
        "the `runtime` command drives AOT JAX artifacts through PJRT and needs the \
         `pjrt` cargo feature: rebuild with `cargo build --release --features pjrt` \
         (see DESIGN.md §PJRT)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_runtime(args: &Args) -> Result<()> {
    use quaff::data::{corpus_samples, Tokenizer};
    use quaff::runtime::{Engine, TrainSession};
    use quaff::util::prng::Rng;

    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let steps: u64 = args.get_parse("steps", 10);
    eprintln!("[runtime] loading artifacts from {} …", dir.display());
    let engine = Engine::load(&dir)?;
    println!("platform : {}", engine.platform());
    println!("preset   : {}", engine.manifest.preset);
    for (name, secs) in &engine.compile_secs {
        println!("compiled {name:<14} in {secs:.2}s");
    }
    let m = engine.manifest.clone();
    let mut session = TrainSession::new(&engine)?;
    // batches from the embedded tiny corpus (real text), padded to B×S
    let tok = Tokenizer::new();
    let samples = corpus_samples(&tok, m.seq);
    let mut rng = Rng::new(1);
    let n = m.batch * m.seq;
    println!(
        "training {} steps on the embedded corpus (B={} S={}) …",
        steps, m.batch, m.seq
    );
    for step in 0..steps {
        let mut tokens = Vec::with_capacity(n);
        for _ in 0..m.batch {
            let s = &samples[rng.below(samples.len())];
            tokens.extend(s.target.iter().map(|&t| t as i32));
        }
        let mask = vec![1.0f32; n];
        let loss = session.step(&tokens, &mask)?;
        println!("step {step:>4}  loss {loss:.4}");
    }
    let eval_tokens: Vec<i32> = samples[0]
        .target
        .iter()
        .map(|&t| t as i32)
        .cycle()
        .take(n)
        .collect();
    let (eval_loss, _) = session.eval(&eval_tokens, &vec![1.0; n])?;
    println!("eval loss: {eval_loss:.4}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("quaff — Quantized PEFT under OSSH (ACL 2025 reproduction)");
    println!("\nmodel presets:");
    for name in ["opt-tiny", "phi-mini", "llama-tiny", "e2e-small"] {
        let cfg = ModelConfig::preset(name).unwrap();
        println!(
            "  {name:<12} d={:<4} L={:<2} h={:<2} ff={:<5} ≈{} params",
            cfg.d_model,
            cfg.n_layers,
            cfg.n_heads,
            cfg.d_ff,
            cfg.base_params()
        );
    }
    println!("\nmethods: fp32 naive llmint8 smooth_s smooth_d quaff quaff-nomom");
    println!("peft   : lora prompt ptuning ia3");
    println!("reports: {}", report::ALL_REPORTS.join(" "));
    Ok(())
}
