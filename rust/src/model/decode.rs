//! KV-cached autoregressive decoding — the incremental inference path.
//!
//! Training runs full-sequence teacher-forced passes; serving runs one new
//! token per request per step. This module gives [`Model`] that second
//! shape of execution on top of the same quantized substrate:
//!
//! * [`Model::forward_infer`] — full-sequence **frozen-state** forward (no
//!   backward caches, no calibration taps, no momentum updates). This is
//!   the reference the cached path is proven against.
//! * [`Model::prefill`] — run a whole prompt through the blocks once,
//!   writing every layer's K/V rows into a [`KvCache`] slot, and return the
//!   last position's logits.
//! * [`Model::decode_step`] — extend several slots by one token each: the
//!   new rows of all active requests are stacked into one `(n × d)` batch
//!   so the quantized linear kernels (and their `tensor::pool` sharding)
//!   see a real batch, while attention reads each slot's cached K/V.
//!
//! **Bit-parity invariant.** Every op on this path is *row-local* — an
//! output row depends only on its own input row plus frozen state (LN,
//! GELU, diagonal gains, per-token quantization, the int8 matmuls, and
//! [`attend_cached`], which reproduces `layers::attention_forward`'s
//! per-row arithmetic exactly, including the softmax evaluation order).
//! Therefore prefill + N decode steps produce byte-identical logits to N
//! full re-forwards over the growing sequence, for every quantization
//! method and any `QUAFF_THREADS` width (`tests/decode_parity.rs`). The
//! same argument covers the cache's page geometry: [`attend_cached`]
//! reads logical rows through the slot's page table, which relocates rows
//! without changing their values or read order, so paged ≡ contiguous
//! decode is also bitwise (`tests/serve_parity.rs`).

use super::layers::{attention_forward, gelu_forward};
use super::{Block, Model};
use crate::infer::KvCache;
use crate::peft::{LoraAdapter, TenantAdapters};
use crate::tensor::pool::{self, shard_range, SplitMut};
use crate::tensor::{kernels, Matrix, Workspace};

/// Causal attention for **one query row** against a slot's cached K/V rows
/// `0..=pos`. `k_lane`/`v_lane` are row-major `[rows × d]` buffers;
/// `pages`/`page_rows` are the slot's page table ([`KvCache::table`]):
/// logical row `j` lives at physical row
/// `pages[j / page_rows] · page_rows + j % page_rows` (for a plain
/// contiguous matrix pass `&[0]` with `page_rows = rows`). `scores` is
/// caller scratch (resized here); `out_row` (length `d`) is fully
/// overwritten.
///
/// The arithmetic mirrors `layers::attention_forward` row `pos` exactly —
/// same dot-product order, same max/exp/normalize sequence, same
/// skip-zero context accumulation. The page table only *relocates* rows;
/// they are read in the same logical order with the same values, so
/// cached ≡ uncached and paged ≡ contiguous attention are both
/// bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn attend_cached(
    q_row: &[f32],
    k_lane: &[f32],
    v_lane: &[f32],
    pages: &[usize],
    page_rows: usize,
    pos: usize,
    d: usize,
    n_heads: usize,
    scores: &mut Vec<f32>,
    out_row: &mut [f32],
) {
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    out_row.fill(0.0);
    scores.clear();
    scores.resize(pos + 1, 0.0);
    for h in 0..n_heads {
        let off = h * dh;
        let qh = &q_row[off..off + dh];
        for (j, s) in scores.iter_mut().enumerate() {
            let prow = pages[j / page_rows] * page_rows + j % page_rows;
            let krow = &k_lane[prow * d + off..prow * d + off + dh];
            let mut acc = 0.0f32;
            for t in 0..dh {
                acc += qh[t] * krow[t];
            }
            *s = acc * scale;
        }
        // softmax over 0..=pos (mirrors Matrix::softmax_rows; the masked
        // positions of the uncached path contribute exact 0.0 terms)
        let mx = scores.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - mx).exp();
            sum += *s;
        }
        let inv = 1.0 / sum;
        for s in scores.iter_mut() {
            *s *= inv;
        }
        let orow = &mut out_row[off..off + dh];
        for (j, &pv) in scores.iter().enumerate() {
            if pv == 0.0 {
                continue;
            }
            let prow = pages[j / page_rows] * page_rows + j % page_rows;
            let vrow = &v_lane[prow * d + off..prow * d + off + dh];
            for t in 0..dh {
                orow[t] += pv * vrow[t];
            }
        }
    }
}

/// [`attend_cached`] with a **split** row lookup for speculative drafting:
/// logical rows `0..base` resolve through the slot's main page table
/// (`pages`) and rows `base..=pos` through its draft table
/// (`draft_pages`, packed relative to `base`). The dot-product / softmax /
/// context arithmetic is byte-for-byte the same as [`attend_cached`] —
/// only row *location* differs — so draft attention over an accepted
/// prefix reads exactly the values the full model wrote there.
#[allow(clippy::too_many_arguments)]
pub fn attend_cached_split(
    q_row: &[f32],
    k_lane: &[f32],
    v_lane: &[f32],
    pages: &[usize],
    draft_pages: &[usize],
    page_rows: usize,
    base: usize,
    pos: usize,
    d: usize,
    n_heads: usize,
    scores: &mut Vec<f32>,
    out_row: &mut [f32],
) {
    let locate = |j: usize| -> usize {
        if j < base {
            pages[j / page_rows] * page_rows + j % page_rows
        } else {
            let rel = j - base;
            draft_pages[rel / page_rows] * page_rows + rel % page_rows
        }
    };
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    out_row.fill(0.0);
    scores.clear();
    scores.resize(pos + 1, 0.0);
    for h in 0..n_heads {
        let off = h * dh;
        let qh = &q_row[off..off + dh];
        for (j, s) in scores.iter_mut().enumerate() {
            let prow = locate(j);
            let krow = &k_lane[prow * d + off..prow * d + off + dh];
            let mut acc = 0.0f32;
            for t in 0..dh {
                acc += qh[t] * krow[t];
            }
            *s = acc * scale;
        }
        let mx = scores.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - mx).exp();
            sum += *s;
        }
        let inv = 1.0 / sum;
        for s in scores.iter_mut() {
            *s *= inv;
        }
        let orow = &mut out_row[off..off + dh];
        for (j, &pv) in scores.iter().enumerate() {
            if pv == 0.0 {
                continue;
            }
            let prow = locate(j);
            let vrow = &v_lane[prow * d + off..prow * d + off + dh];
            for t in 0..dh {
                orow[t] += pv * vrow[t];
            }
        }
    }
}

impl Block {
    /// Full-sequence inference forward: frozen state, no backward caches.
    pub(crate) fn forward_infer(
        &self,
        x: &Matrix,
        batch: usize,
        seq: usize,
        ws: &mut Workspace,
    ) -> Matrix {
        let (q, k, v) = self.project_qkv(x, &[], &[], ws);
        let (attn_out, _) = attention_forward(&q, &k, &v, batch, seq, self.n_heads);
        ws.recycle(q);
        ws.recycle(k);
        ws.recycle(v);
        self.finish_infer(x, attn_out, ws)
    }

    /// Cache-filling inference forward: row `r` of `x` belongs to
    /// `rows[r] = (slot, pos)`. Writes each row's K/V into the cache, then
    /// attends over the slot's cached prefix `0..=pos`. Attention is
    /// sharded over the stacked rows (disjoint output rows, one score lane
    /// per shard — bit-identical for any width).
    ///
    /// `tenants` carries each row's tenant adapter stack for multi-tenant
    /// batches (empty = no per-row adapters, the single-tenant fast path):
    /// the q/v projections then apply each tenant's LoRA delta to its own
    /// rows only, in the qgemm epilogue (`QuantLinear::infer_rows`).
    pub(crate) fn forward_cached(
        &self,
        x: &Matrix,
        layer: usize,
        rows: &[(usize, usize)],
        tenants: &[Option<&TenantAdapters>],
        kv: &mut KvCache,
        ws: &mut Workspace,
    ) -> Matrix {
        let (q, k, v) = if tenants.is_empty() {
            self.project_qkv(x, &[], &[], ws)
        } else {
            debug_assert_eq!(tenants.len(), rows.len(), "one tenant entry per row");
            let q_ads: Vec<Option<&LoraAdapter>> = tenants
                .iter()
                .map(|t| t.and_then(|t| t.blocks[layer].q.as_ref()))
                .collect();
            let v_ads: Vec<Option<&LoraAdapter>> = tenants
                .iter()
                .map(|t| t.and_then(|t| t.blocks[layer].v.as_ref()))
                .collect();
            self.project_qkv(x, &q_ads, &v_ads, ws)
        };
        for (r, &(slot, pos)) in rows.iter().enumerate() {
            kv.write_row(layer, slot, pos, k.row(r), v.row(r));
        }
        ws.recycle(k);
        ws.recycle(v);
        let d = x.cols();
        let t = rows.len();
        let mut attn_out = ws.take_matrix("blk.dec.attn", t, d);
        let kvr: &KvCache = kv;
        let page_rows = kvr.page_rows();
        let (k_lane, v_lane) = kvr.lanes(layer);
        let work: usize = rows.iter().map(|&(_, p)| (p + 1) * d * 2).sum();
        let shards = pool::shards_for(t, work);
        if shards <= 1 {
            let mut scores = ws.take_f32("infer.attn.scores", 0);
            for (r, &(slot, pos)) in rows.iter().enumerate() {
                attend_cached(
                    q.row(r),
                    k_lane,
                    v_lane,
                    kvr.table(slot),
                    page_rows,
                    pos,
                    d,
                    self.n_heads,
                    &mut scores,
                    attn_out.row_mut(r),
                );
            }
            ws.put_f32("infer.attn.scores", scores);
        } else {
            let mut lanes = ws.take_f32_lanes("infer.attn.lanes", shards);
            let split = SplitMut::new(attn_out.data_mut());
            let lane_split = SplitMut::new(&mut lanes[..]);
            let q_ref = &q;
            let n_heads = self.n_heads;
            pool::run_shards(shards, &|s| {
                let (r0, r1) = shard_range(t, shards, s);
                let orows = unsafe { split.slice(r0 * d, (r1 - r0) * d) };
                let scores = unsafe { lane_split.at(s) };
                for r in r0..r1 {
                    let (slot, pos) = rows[r];
                    attend_cached(
                        q_ref.row(r),
                        k_lane,
                        v_lane,
                        kvr.table(slot),
                        page_rows,
                        pos,
                        d,
                        n_heads,
                        scores,
                        &mut orows[(r - r0) * d..(r - r0 + 1) * d],
                    );
                }
            });
            ws.put_f32_lanes("infer.attn.lanes", lanes);
        }
        ws.recycle(q);
        self.finish_infer(x, attn_out, ws)
    }

    /// Draft-cache-filling forward for speculative decoding: row `r` of
    /// `x` belongs to `rows[r] = (slot, pos)` with `pos ≥
    /// draft_base(slot)`. K/V land in the slot's **draft** page table
    /// ([`KvCache::draft_write_row`]); attention reads the accepted prefix
    /// through the main table and this round's draft rows through the
    /// draft table ([`attend_cached_split`]). Attention runs serially —
    /// draft batches are one row per spec-active slot at truncated depth,
    /// and attention values are row-local and width-independent anyway.
    pub(crate) fn forward_draft(
        &self,
        x: &Matrix,
        layer: usize,
        rows: &[(usize, usize)],
        kv: &mut KvCache,
        ws: &mut Workspace,
    ) -> Matrix {
        let (q, k, v) = self.project_qkv(x, &[], &[], ws);
        for (r, &(slot, pos)) in rows.iter().enumerate() {
            kv.draft_write_row(layer, slot, pos, k.row(r), v.row(r));
        }
        ws.recycle(k);
        ws.recycle(v);
        let d = x.cols();
        let t = rows.len();
        let mut attn_out = ws.take_matrix("blk.dec.attn", t, d);
        let kvr: &KvCache = kv;
        let page_rows = kvr.page_rows();
        let (k_lane, v_lane) = kvr.lanes(layer);
        let mut scores = ws.take_f32("infer.attn.scores", 0);
        for (r, &(slot, pos)) in rows.iter().enumerate() {
            attend_cached_split(
                q.row(r),
                k_lane,
                v_lane,
                kvr.table(slot),
                kvr.draft_table(slot),
                page_rows,
                kvr.draft_base(slot),
                pos,
                d,
                self.n_heads,
                &mut scores,
                attn_out.row_mut(r),
            );
        }
        ws.put_f32("infer.attn.scores", scores);
        ws.recycle(q);
        self.finish_infer(x, attn_out, ws)
    }

    /// LN → injection → q/k/v projections → IA3 on k/v (shared head of the
    /// inference forwards). `q_ads`/`v_ads` are per-row tenant LoRA
    /// adapters (empty slices = the single-tenant path, which runs the
    /// plain `infer` call — literally the pre-tenancy code).
    fn project_qkv(
        &self,
        x: &Matrix,
        q_ads: &[Option<&LoraAdapter>],
        v_ads: &[Option<&LoraAdapter>],
        ws: &mut Workspace,
    ) -> (Matrix, Matrix, Matrix) {
        let h1 = self.ln1.forward_infer(x, ws);
        let a_in = self.inj_attn.apply(&h1);
        ws.recycle(h1);
        let q = if q_ads.is_empty() {
            self.q_proj.infer(&a_in, ws)
        } else {
            self.q_proj.infer_rows(&a_in, q_ads, ws)
        };
        let k0 = self.k_proj.infer(&a_in, ws);
        let v0 = if v_ads.is_empty() {
            self.v_proj.infer(&a_in, ws)
        } else {
            self.v_proj.infer_rows(&a_in, v_ads, ws)
        };
        ws.recycle(a_in);
        let k = match &self.ia3_k {
            Some(ia3) => {
                let r = ia3.forward(&k0);
                ws.recycle(k0);
                r
            }
            None => k0,
        };
        let v = match &self.ia3_v {
            Some(ia3) => {
                let r = ia3.forward(&v0);
                ws.recycle(v0);
                r
            }
            None => v0,
        };
        (q, k, v)
    }

    /// o-projection + residual + MLP sub-layer (shared tail of the
    /// inference forwards; mirrors [`Block`]'s training forward).
    fn finish_infer(&self, x: &Matrix, attn_out: Matrix, ws: &mut Workspace) -> Matrix {
        let o_in = self.inj_o.apply(&attn_out);
        ws.recycle(attn_out);
        let o = self.o_proj.infer(&o_in, ws);
        ws.recycle(o_in);
        let mut x2 = ws.take_matrix("blk.x2", x.rows(), x.cols());
        x2.data_mut().copy_from_slice(x.data());
        x2.add_assign(&o);
        ws.recycle(o);
        let h2 = self.ln2.forward_infer(&x2, ws);
        let m_in = self.inj_mlp.apply(&h2);
        ws.recycle(h2);
        let u = self.up_proj.infer(&m_in, ws);
        ws.recycle(m_in);
        let g0 = gelu_forward(&u);
        ws.recycle(u);
        let g = match &self.ia3_ff {
            Some(ia3) => {
                let r = ia3.forward(&g0);
                ws.recycle(g0);
                r
            }
            None => g0,
        };
        let d_in = self.inj_down.apply(&g);
        ws.recycle(g);
        let dn = self.down_proj.infer(&d_in, ws);
        ws.recycle(d_in);
        let mut out = x2;
        out.add_assign(&dn);
        ws.recycle(dn);
        out
    }
}

impl Model {
    /// Pre-compile every linear layer's execution plan in `ws`
    /// (`quant::pipeline`), pre-sized for `rows` stacked token rows — the
    /// serving layers call this once at engine construction so the first
    /// prefill/decode_step already runs plan-driven (no lazy compile, no
    /// string-keyed lookups on the hot path).
    pub fn warm_plans(&self, rows: usize, ws: &mut Workspace) {
        for b in &self.blocks {
            for l in b.linears_ref() {
                l.warm_plan(rows, ws);
            }
        }
    }

    /// Full-sequence **frozen-state** forward: logits
    /// `(batch·(n_virtual+seq) × vocab)` with no backward caches, no
    /// calibration taps, and no per-step method-state updates. The
    /// reference decode path compares against this (`generate_uncached`).
    pub fn forward_infer(&self, tokens: &[Vec<u32>], ws: &mut Workspace) -> Matrix {
        let batch = tokens.len();
        let s = tokens[0].len();
        let sp = self.n_virtual() + s;
        let (mut x, _ptc) = self.embed(tokens);
        for blk in &self.blocks {
            let nx = blk.forward_infer(&x, batch, sp, ws);
            ws.recycle(std::mem::replace(&mut x, nx));
        }
        let h = self.final_ln.forward_infer(&x, ws);
        ws.recycle(x);
        let mut logits = ws.take_matrix("infer.logits", h.rows(), self.lm_head.cols());
        kernels::matmul_into(&h, &self.lm_head, &mut logits);
        ws.recycle(h);
        logits
    }

    /// Run `prompt` (plus any PEFT virtual tokens) through the model once,
    /// filling `slot`'s K/V rows in every block, and return the **last
    /// position's logits** `(1 × vocab)`. The slot must be reset
    /// (`kv.len(slot) == 0`).
    pub fn prefill(
        &self,
        prompt: &[u32],
        slot: usize,
        kv: &mut KvCache,
        ws: &mut Workspace,
    ) -> Matrix {
        self.prefill_tenant(prompt, None, slot, kv, ws)
    }

    /// [`Model::prefill`] with an explicit tenant adapter stack. `None`
    /// runs the model's own adapters/prompt (bit-identical to `prefill`);
    /// `Some(t)` embeds the tenant's soft prompt (replacing the model's
    /// virtual tokens for this slot) and applies the tenant's LoRA deltas
    /// to every prompt row, on top of any model-attached adapters.
    pub fn prefill_tenant(
        &self,
        prompt: &[u32],
        tenant: Option<&TenantAdapters>,
        slot: usize,
        kv: &mut KvCache,
        ws: &mut Workspace,
    ) -> Matrix {
        assert!(!prompt.is_empty(), "prefill requires a non-empty prompt");
        assert_eq!(kv.len(slot), 0, "prefill requires a reset slot");
        let mut x = match tenant {
            None => self.embed(&[prompt.to_vec()]).0,
            Some(t) => self.embed_tenant(prompt, t),
        };
        let t = x.rows(); // n_virtual + prompt.len()
        assert!(
            kv.reserve(slot, t),
            "page pool exhausted prefilling slot {slot} ({t} rows) — admit \
             through KvCache::can_admit first"
        );
        let rows: Vec<(usize, usize)> = (0..t).map(|p| (slot, p)).collect();
        let tenants: Vec<Option<&TenantAdapters>> = match tenant {
            None => Vec::new(),
            Some(t) => vec![Some(t); rows.len()],
        };
        for (l, blk) in self.blocks.iter().enumerate() {
            let nx = blk.forward_cached(&x, l, &rows, &tenants, kv, ws);
            ws.recycle(std::mem::replace(&mut x, nx));
        }
        kv.advance(slot, t);
        let mut last = ws.take_matrix("infer.last", 1, x.cols());
        last.data_mut().copy_from_slice(x.row(t - 1));
        ws.recycle(x);
        let h = self.final_ln.forward_infer(&last, ws);
        ws.recycle(last);
        let mut logits = ws.take_matrix("infer.logits", 1, self.lm_head.cols());
        kernels::matmul_into(&h, &self.lm_head, &mut logits);
        ws.recycle(h);
        logits
    }

    /// One incremental decode step: feed `tokens[i]` to slot `slots[i]`
    /// (distinct, already prefilled) and return the next-token logits
    /// `(slots.len() × vocab)`. All active rows run the linear layers as
    /// one stacked batch; attention reads each slot's cached prefix.
    pub fn decode_step(
        &self,
        tokens: &[u32],
        slots: &[usize],
        kv: &mut KvCache,
        ws: &mut Workspace,
    ) -> Matrix {
        self.decode_step_tenants(tokens, slots, &[], kv, ws)
    }

    /// [`Model::decode_step`] with per-row tenant tags: `tenants[i]` is
    /// slot `i`'s adapter stack (`None` = base/model-attached path). An
    /// empty slice means no tenancy at all and is bit-identical to
    /// `decode_step`. Mixed-tenant rows still run the quantized linears as
    /// ONE stacked batch — the shared int8 qgemm executes once per layer;
    /// only the per-tenant LoRA deltas are applied row-selectively in the
    /// epilogue, which is bitwise-equal to each tenant decoding solo
    /// (row-local ops, one accumulate per output row).
    pub fn decode_step_tenants(
        &self,
        tokens: &[u32],
        slots: &[usize],
        tenants: &[Option<&TenantAdapters>],
        kv: &mut KvCache,
        ws: &mut Workspace,
    ) -> Matrix {
        assert_eq!(tokens.len(), slots.len(), "one token per active slot");
        let counts = vec![1usize; slots.len()];
        self.verify_step_tenants(tokens, slots, &counts, tenants, kv, ws)
    }

    /// Stacked **multi-row** cached forward — the speculative-decode
    /// verify pass, and the general form [`Model::decode_step_tenants`]
    /// is the `counts = [1, 1, …]` case of. Slot `slots[i]` consumes the
    /// next `counts[i]` tokens of `tokens` (slot-major flattening) at
    /// consecutive cache positions `len(slot)..len(slot)+counts[i]`, all
    /// rows run the quantized linears as ONE stacked batch, and row `r`'s
    /// logits are the full model's next-token distribution after its
    /// token. K/V for every row is written to the **main** table before
    /// any attention read (same-pass rows at earlier positions are
    /// visible), so verifying `k+1` stacked positions is bitwise equal to
    /// `k+1` sequential [`Model::decode_step`] calls — the whole
    /// speculative-decoding parity argument rests on this one row-local
    /// pass (`tests/spec_parity.rs`).
    pub fn verify_step_tenants(
        &self,
        tokens: &[u32],
        slots: &[usize],
        counts: &[usize],
        tenants: &[Option<&TenantAdapters>],
        kv: &mut KvCache,
        ws: &mut Workspace,
    ) -> Matrix {
        assert_eq!(counts.len(), slots.len(), "one row count per active slot");
        assert!(
            tenants.is_empty() || tenants.len() == slots.len(),
            "one tenant entry per active slot"
        );
        let n = tokens.len();
        assert!(n > 0, "decode needs at least one active row");
        assert_eq!(
            counts.iter().sum::<usize>(),
            n,
            "row counts must sum to the token count"
        );
        // duplicate slots would stack two rows on one cache position and
        // silently corrupt the prefix — reject them even in release builds
        // (the quadratic scan over the active batch is noise next to the
        // block forwards)
        assert!(
            slots.iter().all(|s| slots.iter().filter(|t| *t == s).count() == 1),
            "duplicate slot in decode batch"
        );
        let d = self.cfg.d_model;
        let mut x = ws.take_matrix("infer.dec.x", n, d);
        let mut rows = Vec::with_capacity(n);
        let mut r = 0usize;
        for (i, &slot) in slots.iter().enumerate() {
            let c = counts[i];
            assert!(c > 0, "decode needs at least one token per slot");
            let pos0 = kv.len(slot);
            assert!(pos0 > 0, "decode_step on slot {slot} before prefill");
            assert!(
                pos0 + c <= self.cfg.max_seq,
                "slot {slot} ran out of positions"
            );
            assert!(
                kv.reserve(slot, c),
                "page pool exhausted extending slot {slot} — the scheduler \
                 must reserve (and preempt on failure) before decode_step"
            );
            for j in 0..c {
                let pos = pos0 + j;
                let row = x.row_mut(r);
                let te = self.emb.tok.row(tokens[r] as usize);
                let pe = self.emb.pos.row(pos);
                for t in 0..d {
                    row[t] = te[t] + pe[t];
                }
                rows.push((slot, pos));
                r += 1;
            }
        }
        // expand per-slot tenant stacks to per-row entries
        let row_tenants: Vec<Option<&TenantAdapters>> = if tenants.is_empty() {
            Vec::new()
        } else {
            let mut v = Vec::with_capacity(n);
            for (i, &c) in counts.iter().enumerate() {
                for _ in 0..c {
                    v.push(tenants[i]);
                }
            }
            v
        };
        for (l, blk) in self.blocks.iter().enumerate() {
            let nx = blk.forward_cached(&x, l, &rows, &row_tenants, kv, ws);
            ws.recycle(std::mem::replace(&mut x, nx));
        }
        for (i, &slot) in slots.iter().enumerate() {
            kv.advance(slot, counts[i]);
        }
        let h = self.final_ln.forward_infer(&x, ws);
        ws.recycle(x);
        let mut logits = ws.take_matrix("infer.logits", n, self.lm_head.cols());
        kernels::matmul_into(&h, &self.lm_head, &mut logits);
        ws.recycle(h);
        logits
    }

    /// One speculative **draft** step: feed `tokens[i]` to slot
    /// `slots[i]` at its next draft position, running only the first
    /// `draft_layers` blocks, then the final LayerNorm + lm head on the
    /// mid-layer representation. K/V rows land in each slot's draft page
    /// table; the main cache is untouched. Requires an open draft round
    /// ([`KvCache::begin_draft`]) with the step's row already
    /// [`KvCache::draft_reserve`]d. Returns `(slots.len() × vocab)` draft
    /// logits — proposals only; acceptance is decided by the full-model
    /// verify pass, so draft quality affects speed, never output.
    pub fn draft_step(
        &self,
        tokens: &[u32],
        slots: &[usize],
        draft_layers: usize,
        kv: &mut KvCache,
        ws: &mut Workspace,
    ) -> Matrix {
        assert_eq!(tokens.len(), slots.len(), "one token per drafting slot");
        let n = tokens.len();
        assert!(n > 0, "draft_step needs at least one drafting slot");
        assert!(
            draft_layers >= 1 && draft_layers <= self.blocks.len(),
            "draft_layers must be in 1..=n_layers"
        );
        assert!(
            slots.iter().all(|s| slots.iter().filter(|t| *t == s).count() == 1),
            "duplicate slot in draft batch"
        );
        let d = self.cfg.d_model;
        let mut x = ws.take_matrix("infer.dec.x", n, d);
        let mut rows = Vec::with_capacity(n);
        for (i, (&tok, &slot)) in tokens.iter().zip(slots).enumerate() {
            let pos = kv.len(slot) + kv.draft_len(slot);
            assert!(pos > 0, "draft_step on slot {slot} before prefill");
            assert!(pos < self.cfg.max_seq, "slot {slot} ran out of positions");
            let row = x.row_mut(i);
            let te = self.emb.tok.row(tok as usize);
            let pe = self.emb.pos.row(pos);
            for j in 0..d {
                row[j] = te[j] + pe[j];
            }
            rows.push((slot, pos));
        }
        for (l, blk) in self.blocks.iter().take(draft_layers).enumerate() {
            let nx = blk.forward_draft(&x, l, &rows, kv, ws);
            ws.recycle(std::mem::replace(&mut x, nx));
        }
        for &slot in slots {
            kv.draft_advance(slot, 1);
        }
        let h = self.final_ln.forward_infer(&x, ws);
        ws.recycle(x);
        let mut logits = ws.take_matrix("infer.logits", n, self.lm_head.cols());
        kernels::matmul_into(&h, &self.lm_head, &mut logits);
        ws.recycle(h);
        logits
    }
}
