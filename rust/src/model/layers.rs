//! Transformer building blocks with explicit forward/backward passes.
//!
//! All activations flow as `(batch*seq × features)` row-major matrices;
//! attention reshapes per (batch, head) internally. Base weights are frozen
//! (PEFT regime) so backward passes only produce input gradients — adapter
//! gradients are handled by the wrappers in `model::linear` / `peft`.

use crate::tensor::{Matrix, Workspace};
use crate::util::prng::Rng;

/// LayerNorm with gain+bias (frozen; gains carry the planted outlier
/// amplification of the simulator, see `model::inject`).
#[derive(Clone, Debug)]
pub struct LayerNorm {
    pub gain: Vec<f32>,
    pub bias: Vec<f32>,
    pub eps: f32,
}

/// Cache for LayerNorm backward.
pub struct LnCache {
    /// Normalized pre-gain activations x̂.
    xhat: Matrix,
    /// 1/std per row.
    inv_std: Vec<f32>,
}

impl LayerNorm {
    pub fn new(dim: usize, eps: f32) -> LayerNorm {
        LayerNorm {
            gain: vec![1.0; dim],
            bias: vec![0.0; dim],
            eps,
        }
    }

    pub fn forward(&self, x: &Matrix) -> (Matrix, LnCache) {
        let (t, d) = (x.rows(), x.cols());
        let mut out = Matrix::zeros(t, d);
        let mut xhat = Matrix::zeros(t, d);
        let mut inv_std = vec![0.0f32; t];
        for i in 0..t {
            let row = x.row(i);
            let mean = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            let istd = 1.0 / (var + self.eps).sqrt();
            inv_std[i] = istd;
            let xh = xhat.row_mut(i);
            let o = &mut out.data_mut()[i * d..(i + 1) * d];
            for j in 0..d {
                let h = (row[j] - mean) * istd;
                xh[j] = h;
                o[j] = h * self.gain[j] + self.bias[j];
            }
        }
        (out, LnCache { xhat, inv_std })
    }

    /// Inference-mode forward: no backward cache, output drawn from the
    /// workspace. Row-local and arithmetically identical to
    /// [`LayerNorm::forward`] (same mean/var/normalize sequence), so the
    /// cached decode path matches the training-path forward bit-for-bit.
    pub fn forward_infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let (t, d) = (x.rows(), x.cols());
        let mut out = ws.take_matrix("ln.inf.y", t, d);
        for i in 0..t {
            let row = x.row(i);
            let mean = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            let istd = 1.0 / (var + self.eps).sqrt();
            let o = out.row_mut(i);
            for j in 0..d {
                o[j] = (row[j] - mean) * istd * self.gain[j] + self.bias[j];
            }
        }
        out
    }

    /// dL/dx given dL/dy (standard LayerNorm backward; gain/bias frozen).
    pub fn backward(&self, dy: &Matrix, cache: &LnCache) -> Matrix {
        let (t, d) = (dy.rows(), dy.cols());
        let mut dx = Matrix::zeros(t, d);
        for i in 0..t {
            let dyr = dy.row(i);
            let xh = cache.xhat.row(i);
            let istd = cache.inv_std[i];
            // dxhat = dy * gain
            let mut sum_dxh = 0.0f32;
            let mut sum_dxh_xh = 0.0f32;
            for j in 0..d {
                let dxh = dyr[j] * self.gain[j];
                sum_dxh += dxh;
                sum_dxh_xh += dxh * xh[j];
            }
            let n = d as f32;
            let o = dx.row_mut(i);
            for j in 0..d {
                let dxh = dyr[j] * self.gain[j];
                o[j] = istd * (dxh - sum_dxh / n - xh[j] * sum_dxh_xh / n);
            }
        }
        dx
    }
}

/// GELU (tanh approximation) with derivative for backward.
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    let x3 = 0.044715 * x * x * x;
    let t = (C * (x + x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Apply GELU elementwise, returning output + input copy for backward.
pub fn gelu_forward(x: &Matrix) -> Matrix {
    let mut out = x.clone();
    for v in out.data_mut() {
        *v = gelu(*v);
    }
    out
}

/// dL/dx = dL/dy ∘ gelu'(x).
pub fn gelu_backward(dy: &Matrix, x: &Matrix) -> Matrix {
    let mut dx = dy.clone();
    for (d, &v) in dx.data_mut().iter_mut().zip(x.data()) {
        *d *= gelu_grad(v);
    }
    dx
}

/// Token + learned positional embedding (frozen base).
#[derive(Clone, Debug)]
pub struct Embedding {
    /// (vocab × d)
    pub tok: Matrix,
    /// (max_seq × d)
    pub pos: Matrix,
}

impl Embedding {
    pub fn new(vocab: usize, max_seq: usize, d: usize, rng: &mut Rng) -> Embedding {
        Embedding {
            tok: Matrix::randn(vocab, d, rng, 0.02),
            pos: Matrix::randn(max_seq, d, rng, 0.02),
        }
    }

    /// Embed `(batch × seq)` token ids into `(batch*seq × d)`.
    pub fn forward(&self, tokens: &[Vec<u32>]) -> Matrix {
        let b = tokens.len();
        let s = tokens[0].len();
        let d = self.tok.cols();
        let mut out = Matrix::zeros(b * s, d);
        for (bi, seq) in tokens.iter().enumerate() {
            assert_eq!(seq.len(), s, "ragged batch");
            for (si, &t) in seq.iter().enumerate() {
                let row = out.row_mut(bi * s + si);
                let te = self.tok.row(t as usize);
                let pe = self.pos.row(si);
                for j in 0..d {
                    row[j] = te[j] + pe[j];
                }
            }
        }
        out
    }
}

/// Multi-head causal self-attention cache for backward.
pub struct AttnCache {
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
    /// Softmax probabilities per (batch, head): vec of (seq × seq).
    pub probs: Vec<Matrix>,
    pub batch: usize,
    pub seq: usize,
}

/// Causal softmax attention core (no projections — those live in
/// `model::linear`). Takes packed Q,K,V `(batch*seq × d)` and head count.
pub fn attention_forward(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    batch: usize,
    seq: usize,
    n_heads: usize,
) -> (Matrix, AttnCache) {
    let d = q.cols();
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Matrix::zeros(batch * seq, d);
    let mut probs = Vec::with_capacity(batch * n_heads);
    for b in 0..batch {
        for h in 0..n_heads {
            let off = h * dh;
            // scores (seq × seq), causal
            let mut p = Matrix::zeros(seq, seq);
            for i in 0..seq {
                let qrow = &q.row(b * seq + i)[off..off + dh];
                let prow = p.row_mut(i);
                for j in 0..=i {
                    let krow = &k.row(b * seq + j)[off..off + dh];
                    let mut acc = 0.0f32;
                    for t in 0..dh {
                        acc += qrow[t] * krow[t];
                    }
                    prow[j] = acc * scale;
                }
                for j in (i + 1)..seq {
                    prow[j] = f32::NEG_INFINITY;
                }
            }
            p.softmax_rows();
            // ctx = P @ V_h
            for i in 0..seq {
                let prow = p.row(i);
                let orow = &mut out.row_mut(b * seq + i)[off..off + dh];
                for j in 0..=i {
                    let pv = prow[j];
                    if pv == 0.0 {
                        continue;
                    }
                    let vrow = &v.row(b * seq + j)[off..off + dh];
                    for t in 0..dh {
                        orow[t] += pv * vrow[t];
                    }
                }
            }
            probs.push(p);
        }
    }
    let cache = AttnCache {
        q: q.clone(),
        k: k.clone(),
        v: v.clone(),
        probs,
        batch,
        seq,
    };
    (out, cache)
}

/// Backward of the attention core: returns (dQ, dK, dV).
pub fn attention_backward(dy: &Matrix, cache: &AttnCache, n_heads: usize) -> (Matrix, Matrix, Matrix) {
    let (batch, seq) = (cache.batch, cache.seq);
    let d = cache.q.cols();
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = Matrix::zeros(batch * seq, d);
    let mut dk = Matrix::zeros(batch * seq, d);
    let mut dv = Matrix::zeros(batch * seq, d);
    for b in 0..batch {
        for h in 0..n_heads {
            let off = h * dh;
            let p = &cache.probs[b * n_heads + h];
            // dV_h = P^T @ dY_h ; dP = dY_h @ V_h^T
            let mut dp = Matrix::zeros(seq, seq);
            for i in 0..seq {
                let dyrow = &dy.row(b * seq + i)[off..off + dh];
                let prow = p.row(i);
                let dprow = dp.row_mut(i);
                for j in 0..=i {
                    // dV[j] += P[i,j] * dY[i]
                    let pv = prow[j];
                    let vrow = &cache.v.row(b * seq + j)[off..off + dh];
                    let dvrow = &mut dv.row_mut(b * seq + j)[off..off + dh];
                    let mut acc = 0.0f32;
                    for t in 0..dh {
                        dvrow[t] += pv * dyrow[t];
                        acc += dyrow[t] * vrow[t];
                    }
                    dprow[j] = acc;
                }
            }
            // softmax backward: dS[i,j] = P[i,j] * (dP[i,j] - Σ_k dP[i,k] P[i,k])
            for i in 0..seq {
                let prow = p.row(i);
                let dprow = dp.row(i);
                let dot: f32 = (0..=i).map(|j| dprow[j] * prow[j]).sum();
                // dS row scaled; then dQ[i] += dS[i,j]*K[j]*scale, dK[j] += dS[i,j]*Q[i]*scale
                let qrow: Vec<f32> = cache.q.row(b * seq + i)[off..off + dh].to_vec();
                let dqrow = &mut dq.row_mut(b * seq + i)[off..off + dh];
                for j in 0..=i {
                    let ds = prow[j] * (dprow[j] - dot) * scale;
                    if ds == 0.0 {
                        continue;
                    }
                    let krow = &cache.k.row(b * seq + j)[off..off + dh];
                    let dkrow = &mut dk.row_mut(b * seq + j)[off..off + dh];
                    for t in 0..dh {
                        dqrow[t] += ds * krow[t];
                        dkrow[t] += ds * qrow[t];
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn finite_diff_check<F>(f: F, x: &Matrix, dy: &Matrix, dx_analytic: &Matrix, tol: f32)
    where
        F: Fn(&Matrix) -> Matrix,
    {
        // check d<f(x), dy>/dx_i ≈ dx_analytic_i on a handful of coordinates
        let eps = 1e-3f32;
        let mut r = Rng::new(123);
        for _ in 0..12 {
            let i = r.below(x.rows());
            let j = r.below(x.cols());
            let mut xp = x.clone();
            xp.set(i, j, x.get(i, j) + eps);
            let mut xm = x.clone();
            xm.set(i, j, x.get(i, j) - eps);
            let fp = f(&xp);
            let fm = f(&xm);
            let mut num = 0.0f32;
            for (a, (b, &g)) in fp.data().iter().zip(fm.data().iter().zip(dy.data())) {
                num += (a - b) / (2.0 * eps) * g;
            }
            let ana = dx_analytic.get(i, j);
            assert!(
                (num - ana).abs() < tol * (1.0 + ana.abs().max(num.abs())),
                "fd {num} vs analytic {ana} at ({i},{j})"
            );
        }
    }

    #[test]
    fn layernorm_forward_normalizes() {
        let mut r = Rng::new(1);
        let ln = LayerNorm::new(16, 1e-5);
        let x = Matrix::randn(5, 16, &mut r, 3.0);
        let (y, _) = ln.forward(&x);
        for i in 0..5 {
            let row = y.row(i);
            let mean = row.iter().sum::<f32>() / 16.0;
            let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn layernorm_backward_matches_finite_diff() {
        let mut r = Rng::new(2);
        let mut ln = LayerNorm::new(8, 1e-5);
        for g in ln.gain.iter_mut() {
            *g = 1.0 + r.uniform();
        }
        let x = Matrix::randn(4, 8, &mut r, 1.0);
        let dy = Matrix::randn(4, 8, &mut r, 1.0);
        let (_, cache) = ln.forward(&x);
        let dx = ln.backward(&dy, &cache);
        let lnc = ln.clone();
        finite_diff_check(move |x| lnc.forward(x).0, &x, &dy, &dx, 2e-2);
    }

    #[test]
    fn gelu_matches_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-6);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_finite_diff() {
        prop::check("gelu-grad", 0xF1, 64, |r| r.range(-4.0, 4.0), |&x| {
            let eps = 1e-3;
            let num = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            prop::close(gelu_grad(x), num, 1e-3, 1e-2)
        });
    }

    #[test]
    fn embedding_adds_positions() {
        let mut r = Rng::new(3);
        let emb = Embedding::new(10, 4, 6, &mut r);
        let x = emb.forward(&[vec![1, 2], vec![3, 1]]);
        assert_eq!((x.rows(), x.cols()), (4, 6));
        // (b=1, s=1) row = tok[1] + pos[1]
        for j in 0..6 {
            assert!((x.get(3, j) - (emb.tok.get(1, j) + emb.pos.get(1, j))).abs() < 1e-6);
        }
    }

    #[test]
    fn attention_is_causal() {
        let mut r = Rng::new(4);
        let (b, s, h, d) = (1, 6, 2, 8);
        let q = Matrix::randn(b * s, d, &mut r, 1.0);
        let k = Matrix::randn(b * s, d, &mut r, 1.0);
        let mut v = Matrix::randn(b * s, d, &mut r, 1.0);
        let (y1, _) = attention_forward(&q, &k, &v, b, s, h);
        // perturbing a FUTURE value must not change earlier outputs
        for j in 0..d {
            v.set(5, j, v.get(5, j) + 100.0);
        }
        let (y2, _) = attention_forward(&q, &k, &v, b, s, h);
        for i in 0..5 {
            prop::all_close(y1.row(i), y2.row(i), 1e-6, 1e-6).unwrap();
        }
        // ...but it must change the last position
        let diff: f32 = y1
            .row(5)
            .iter()
            .zip(y2.row(5))
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn attention_rows_convex_combination() {
        // First token attends only to itself: out[0] == v[0] per head.
        let mut r = Rng::new(5);
        let (b, s, h, d) = (2, 4, 2, 8);
        let q = Matrix::randn(b * s, d, &mut r, 1.0);
        let k = Matrix::randn(b * s, d, &mut r, 1.0);
        let v = Matrix::randn(b * s, d, &mut r, 1.0);
        let (y, _) = attention_forward(&q, &k, &v, b, s, h);
        for bi in 0..b {
            prop::all_close(y.row(bi * s), v.row(bi * s), 1e-5, 1e-5).unwrap();
        }
    }

    #[test]
    fn attention_backward_matches_finite_diff_q() {
        let mut r = Rng::new(6);
        let (b, s, h, d) = (1, 5, 1, 6);
        let q = Matrix::randn(b * s, d, &mut r, 0.7);
        let k = Matrix::randn(b * s, d, &mut r, 0.7);
        let v = Matrix::randn(b * s, d, &mut r, 0.7);
        let dy = Matrix::randn(b * s, d, &mut r, 1.0);
        let (_, cache) = attention_forward(&q, &k, &v, b, s, h);
        let (dq, dk, dv) = attention_backward(&dy, &cache, h);
        let kk = k.clone();
        let vv = v.clone();
        finite_diff_check(
            move |qq| attention_forward(qq, &kk, &vv, b, s, h).0,
            &q,
            &dy,
            &dq,
            3e-2,
        );
        let qq = q.clone();
        let vv2 = v.clone();
        finite_diff_check(
            move |kx| attention_forward(&qq, kx, &vv2, b, s, h).0,
            &k,
            &dy,
            &dk,
            3e-2,
        );
        let qq2 = q.clone();
        let kk2 = k.clone();
        finite_diff_check(
            move |vx| attention_forward(&qq2, &kk2, vx, b, s, h).0,
            &v,
            &dy,
            &dv,
            3e-2,
        );
    }

    use crate::util::prng::Rng;
}
