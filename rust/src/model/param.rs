//! Trainable parameter: value + gradient accumulator.

use crate::tensor::Matrix;

/// A trainable matrix parameter with its gradient accumulator.
#[derive(Clone, Debug)]
pub struct Param {
    pub value: Matrix,
    pub grad: Matrix,
}

impl Param {
    pub fn new(value: Matrix) -> Param {
        let grad = Matrix::zeros(value.rows(), value.cols());
        Param { value, grad }
    }

    pub fn zeros(rows: usize, cols: usize) -> Param {
        Param::new(Matrix::zeros(rows, cols))
    }

    pub fn zero_grad(&mut self) {
        self.grad.data_mut().fill(0.0);
    }

    pub fn numel(&self) -> usize {
        self.value.data().len()
    }

    /// Accumulate `g` into the gradient.
    pub fn accumulate(&mut self, g: &Matrix) {
        self.grad.add_assign(g);
    }
}

/// Visitor over a model's trainable parameters (name, param).
pub trait VisitParams {
    fn visit_params(&mut self, f: &mut dyn FnMut(&str, &mut Param));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_grad_clears() {
        let mut p = Param::zeros(2, 2);
        p.grad.set(0, 0, 3.0);
        p.zero_grad();
        assert!(p.grad.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn accumulate_adds() {
        let mut p = Param::zeros(1, 2);
        let g = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        p.accumulate(&g);
        p.accumulate(&g);
        assert_eq!(p.grad.data(), &[2.0, 4.0]);
    }
}
