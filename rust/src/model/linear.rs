//! Quantized linear layer wrapper: frozen base weight under a pluggable
//! [`QuantMethod`], plus optional LoRA adapter, plus the calibration tap.

use crate::methods::{build_method, MethodConfig, MethodKind, MethodSnapshot, QuantMethod};
use crate::outlier::{ChannelStats, LayerKind, OutlierSet};
use crate::peft::{LoraAdapter, LoraCache};
use crate::quant::pipeline;
use crate::tensor::{kernels, Matrix, Workspace};
use crate::util::prng::Rng;

/// One linear layer of the model.
pub struct QuantLinear {
    pub name: String,
    pub kind: LayerKind,
    /// Full-precision master, present until `apply_method` converts it.
    w_master: Option<Matrix>,
    method: Option<Box<dyn QuantMethod>>,
    pub lora: Option<LoraAdapter>,
    /// Calibration tap: when Some, forward observes inputs.
    pub stats: Option<ChannelStats>,
    /// Eq. 6 dominance ratio for the tap.
    pub tap_tau: f32,
    /// One-shot activation capture for the OSSH instruments (Fig. 2):
    /// set `capture_next`; the next forward stores its input matrix.
    pub capture_next: bool,
    pub captured: Option<Matrix>,
    cin: usize,
    cout: usize,
}

/// Forward cache for backward.
pub struct LinCache {
    pub lora: Option<LoraCache>,
}

impl QuantLinear {
    pub fn new(name: &str, cin: usize, cout: usize, rng: &mut Rng) -> QuantLinear {
        // He-style init for the frozen base
        let std = (2.0 / (cin + cout) as f32).sqrt();
        QuantLinear {
            name: name.to_string(),
            kind: LayerKind::from_name(name),
            w_master: Some(Matrix::randn(cin, cout, rng, std)),
            method: None,
            lora: None,
            stats: None,
            tap_tau: 20.0,
            capture_next: false,
            captured: None,
            cin,
            cout,
        }
    }

    pub fn cin(&self) -> usize {
        self.cin
    }

    pub fn cout(&self) -> usize {
        self.cout
    }

    /// Borrow the full-precision master (pre-conversion only).
    pub fn master(&self) -> Option<&Matrix> {
        self.w_master.as_ref()
    }

    /// Overwrite the master weights (checkpoint loading).
    pub fn set_master(&mut self, w: Matrix) {
        assert_eq!((w.rows(), w.cols()), (self.cin, self.cout));
        self.w_master = Some(w);
        self.method = None;
    }

    /// Enable the calibration tap.
    pub fn start_calibration(&mut self) {
        self.stats = Some(ChannelStats::new(self.cin));
    }

    /// Take the collected stats (ends calibration).
    pub fn take_stats(&mut self) -> Option<ChannelStats> {
        self.stats.take()
    }

    /// Convert the layer to quantized execution under `kind`, using the
    /// pre-identified outlier set. Consumes the f32 master unless the
    /// method itself keeps one (FP32, Smooth_D hold their own copy).
    pub fn apply_method(
        &mut self,
        kind: MethodKind,
        calib: &ChannelStats,
        outliers: &OutlierSet,
        cfg: &MethodConfig,
    ) {
        let w = self
            .w_master
            .take()
            .expect("apply_method requires master weights");
        self.method = Some(build_method(kind, w, calib, outliers, cfg));
    }

    /// Is the layer converted to a quantized method yet?
    pub fn is_quantized(&self) -> bool {
        self.method.is_some()
    }

    /// Persistable state of the converted method, if any (see
    /// [`MethodSnapshot`]): the full frozen representation plus per-step
    /// mutable state, captured by the `persist` tier.
    pub fn method_snapshot(&self) -> Option<MethodSnapshot> {
        self.method.as_ref().map(|m| m.snapshot())
    }

    /// Install a restored method (checkpoint/bundle loading). Replaces any
    /// master weights — the layer runs quantized from here on, exactly as
    /// the snapshotted layer did.
    pub fn set_method(&mut self, method: Box<dyn QuantMethod>) {
        assert_eq!(
            (method.cin(), method.cout()),
            (self.cin, self.cout),
            "restored method shape mismatch for {}",
            self.name
        );
        self.method = Some(method);
        self.w_master = None;
    }

    pub fn method_name(&self) -> &'static str {
        self.method.as_ref().map(|m| m.name()).unwrap_or("master")
    }

    /// Pre-compile the converted method's execution plan in `ws`
    /// (`quant::pipeline`), pre-sized for batches of `m_hint` token rows.
    /// No-op for unconverted (master-weight) layers — the FP32 master path
    /// has no quantization pipeline to plan.
    pub fn warm_plan(&self, m_hint: usize, ws: &mut Workspace) {
        if let Some(m) = &self.method {
            m.warm_plan(m_hint, ws);
        }
    }

    /// Current activation scaling factors, if the method scales.
    pub fn scaling_factors(&self) -> Option<Vec<f32>> {
        self.method.as_ref().and_then(|m| m.scaling_factors())
    }

    /// Frozen-weight memory footprint in bytes.
    pub fn weight_bytes(&self) -> usize {
        match (&self.method, &self.w_master) {
            (Some(m), _) => m.weight_bytes(),
            (None, Some(w)) => w.data().len() * 4,
            _ => 0,
        }
    }

    /// Forward `Y = X·W (+ LoRA ΔY)`. Observes the calibration tap if on.
    /// The output matrix is drawn from `ws`; callers that are done with it
    /// should hand it back via [`Workspace::recycle`].
    pub fn forward(
        &mut self,
        x: &Matrix,
        train: bool,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> (Matrix, LinCache) {
        if let Some(stats) = self.stats.as_mut() {
            stats.observe(x, self.tap_tau);
        }
        if self.capture_next {
            self.captured = Some(x.clone());
            self.capture_next = false;
        }
        let mut y = match (&mut self.method, &self.w_master) {
            (Some(m), _) => m.forward(x, ws),
            (None, Some(w)) => {
                let mut y = ws.take_matrix("lin.master.y", x.rows(), w.cols());
                kernels::matmul_into(x, w, &mut y);
                y
            }
            _ => unreachable!("linear layer with neither method nor master"),
        };
        let lora_cache = if let Some(lora) = &self.lora {
            let (dy, cache) = lora.forward(x, train, rng);
            y.add_assign(&dy);
            ws.recycle(dy);
            Some(cache)
        } else {
            None
        };
        (y, LinCache { lora: lora_cache })
    }

    /// Inference-mode forward: frozen method state (no momentum updates, no
    /// calibration tap, no capture), no backward cache, LoRA applied without
    /// dropout. Row-local, which is what lets the KV-cached decode path in
    /// `model::decode` reuse this layer incrementally. The output comes from
    /// `ws`; hand it back via [`Workspace::recycle`] when done.
    pub fn infer(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let mut y = match (&self.method, &self.w_master) {
            (Some(m), _) => m.forward_infer(x, ws),
            (None, Some(w)) => {
                let mut y = ws.take_matrix("lin.master.y", x.rows(), w.cols());
                kernels::matmul_into(x, w, &mut y);
                y
            }
            _ => unreachable!("linear layer with neither method nor master"),
        };
        if let Some(lora) = &self.lora {
            let dy = lora.delta_infer(x, ws);
            y.add_assign(&dy);
            ws.recycle(dy);
        }
        y
    }

    /// Multi-tenant inference forward: the shared base (frozen quantized
    /// qgemm, plus this layer's own adapter if attached) runs **once** for
    /// the whole stacked batch, then `adapters[r]` — each row's tenant
    /// adapter, resolved by the serving layer — is applied per row in the
    /// epilogue. Rows sharing an adapter are gathered into one stacked
    /// delta matmul and scattered back
    /// (`quant::pipeline::{gather_rows, scatter_add_rows}`), so each
    /// output row receives exactly one `+=` of exactly the delta row the
    /// solo attached-adapter path would add — mixed-tenant batches are
    /// bit-identical to solo decodes (`tests/tenant_parity.rs`). With all
    /// entries `None` this is [`QuantLinear::infer`] plus a scan.
    pub fn infer_rows(
        &self,
        x: &Matrix,
        adapters: &[Option<&LoraAdapter>],
        ws: &mut Workspace,
    ) -> Matrix {
        assert_eq!(adapters.len(), x.rows(), "one adapter entry per row");
        let mut y = self.infer(x, ws);
        // group rows by adapter identity (tiny n: the batch is the active
        // decode set) so each tenant's delta runs as one stacked matmul
        let mut groups: Vec<(&LoraAdapter, Vec<usize>)> = Vec::new();
        for (r, a) in adapters.iter().enumerate() {
            if let Some(a) = a {
                match groups.iter_mut().find(|(g, _)| std::ptr::eq(*g, *a)) {
                    Some((_, rows)) => rows.push(r),
                    None => groups.push((a, vec![r])),
                }
            }
        }
        for (adapter, rows) in groups {
            if rows.len() == x.rows() {
                // single-tenant batch: whole-matrix delta, no gather — the
                // exact arithmetic of the attached-adapter path above
                let dy = adapter.delta_infer(x, ws);
                y.add_assign(&dy);
                ws.recycle(dy);
            } else {
                let mut xg = ws.take_matrix("lin.tenant.xg", rows.len(), x.cols());
                pipeline::gather_rows(x, &rows, &mut xg);
                let dy = adapter.delta_infer(&xg, ws);
                pipeline::scatter_add_rows(&mut y, &dy, &rows);
                ws.put_matrix("lin.tenant.xg", xg);
                ws.recycle(dy);
            }
        }
        y
    }

    /// Backward: returns dX (workspace-backed); accumulates adapter grads.
    pub fn backward(&mut self, dy: &Matrix, cache: &LinCache, ws: &mut Workspace) -> Matrix {
        let mut dx = match (&self.method, &self.w_master) {
            (Some(m), _) => m.backward_input(dy, ws),
            (None, Some(w)) => {
                let mut dx = ws.take_matrix("lin.master.dx", dy.rows(), w.rows());
                kernels::matmul_bt_into(dy, w, &mut dx);
                dx
            }
            _ => unreachable!(),
        };
        if let (Some(lora), Some(lc)) = (self.lora.as_mut(), cache.lora.as_ref()) {
            let dx_lora = lora.backward(dy, lc);
            dx.add_assign(&dx_lora);
            ws.recycle(dx_lora);
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::MethodConfig;
    use crate::util::prop;

    #[test]
    fn master_forward_then_quantized_close() {
        let mut r = Rng::new(51);
        let mut ws = Workspace::new();
        let mut lin = QuantLinear::new("blocks.0.mlp.up_proj", 32, 24, &mut r);
        assert_eq!(lin.kind, LayerKind::UpProj);
        let x = Matrix::randn(4, 32, &mut r, 1.0);
        let (y0, _) = lin.forward(&x, false, &mut r, &mut ws);
        // calibrate + convert to naive
        lin.start_calibration();
        let _ = lin.forward(&x, false, &mut r, &mut ws);
        let stats = lin.take_stats().unwrap();
        lin.apply_method(MethodKind::Naive, &stats, &OutlierSet::default(), &MethodConfig::default());
        assert!(lin.is_quantized());
        let (y1, _) = lin.forward(&x, false, &mut r, &mut ws);
        prop::all_close(y0.data(), y1.data(), 0.05, 0.05).unwrap();
    }

    #[test]
    fn lora_adds_delta_after_training_b() {
        let mut r = Rng::new(52);
        let mut ws = Workspace::new();
        let mut lin = QuantLinear::new("l.q_proj", 16, 16, &mut r);
        lin.lora = Some(LoraAdapter::new(16, 16, 4, 8.0, 0.0, &mut r));
        let x = Matrix::randn(2, 16, &mut r, 1.0);
        let (y0, _) = lin.forward(&x, false, &mut r, &mut ws);
        // poke B so the adapter contributes
        lin.lora.as_mut().unwrap().b.value = Matrix::randn(4, 16, &mut r, 0.5);
        let (y1, _) = lin.forward(&x, false, &mut r, &mut ws);
        let diff: f32 = y0
            .data()
            .iter()
            .zip(y1.data())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.1);
    }

    #[test]
    fn backward_includes_lora_path() {
        let mut r = Rng::new(53);
        let mut ws = Workspace::new();
        let mut lin = QuantLinear::new("l.v_proj", 12, 10, &mut r);
        lin.lora = Some(LoraAdapter::new(12, 10, 3, 3.0, 0.0, &mut r));
        lin.lora.as_mut().unwrap().b.value = Matrix::randn(3, 10, &mut r, 0.5);
        let x = Matrix::randn(3, 12, &mut r, 1.0);
        let dy = Matrix::randn(3, 10, &mut r, 1.0);
        let (_, cache) = lin.forward(&x, false, &mut r, &mut ws);
        let dx = lin.backward(&dy, &cache, &mut ws);
        // compare against manual: dX = dY Wᵀ + lora-path
        let w = lin.master().unwrap().clone();
        let want_frozen = dy.matmul_bt(&w);
        // lora contribution is nonzero, so dx != frozen path alone
        let diff: f32 = dx
            .data()
            .iter()
            .zip(want_frozen.data())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-3);
        // grads accumulated
        let lora = lin.lora.as_ref().unwrap();
        assert!(lora.a.grad.sq_norm() > 0.0);
        assert!(lora.b.grad.sq_norm() > 0.0);
    }

    #[test]
    fn calibration_tap_collects() {
        let mut r = Rng::new(54);
        let mut ws = Workspace::new();
        let mut lin = QuantLinear::new("l.k_proj", 8, 8, &mut r);
        lin.start_calibration();
        for _ in 0..3 {
            let x = Matrix::randn(2, 8, &mut r, 1.0);
            let _ = lin.forward(&x, false, &mut r, &mut ws);
        }
        let stats = lin.take_stats().unwrap();
        assert_eq!(stats.samples, 3);
        assert!(lin.stats.is_none());
    }
}
