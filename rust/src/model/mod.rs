//! Decoder-only transformer with pluggable per-linear quantization methods,
//! PEFT adapters, the outlier-injection substrate, and explicit
//! forward/backward passes (manual autodiff — the offline environment has
//! no autograd framework, and the backward structure is fixed).
//!
//! Layer layout mirrors the six linear types the paper distinguishes
//! (q/k/v/o projections, up/down FFN projections); LayerNorm → attention →
//! residual → LayerNorm → GELU-MLP → residual; learned positional
//! embeddings; tied-free FP32 LM head (excluded from quantization, as in
//! the paper's bitsandbytes setup which quantizes `nn.Linear` blocks only).
//!
//! Besides the teacher-forced training forward, the model has a frozen-
//! state inference surface in [`decode`]: `forward_infer`, KV-cached
//! `prefill`/`decode_step`, bit-identical to each other per
//! `tests/decode_parity.rs`.

pub mod decode;
pub mod inject;
pub mod layers;
pub mod linear;
pub mod param;

use crate::methods::{MethodConfig, MethodKind};
use crate::outlier::{BudgetAllocator, ChannelStats, OutlierDetector, OutlierRegistry};
use crate::peft::{
    Ia3Vector, LoraAdapter, PTuningCache, PTuningEncoder, PeftKind, PromptTuning,
    TenantAdapters, TenantBlockAdapters,
};
use crate::tensor::{Matrix, Workspace};
use crate::util::prng::Rng;
use inject::{DiagGain, InjectConfig};
use layers::{
    attention_backward, attention_forward, gelu_backward, gelu_forward, AttnCache, Embedding,
    LayerNorm, LnCache,
};
use linear::{LinCache, QuantLinear};
use param::Param;
use std::collections::BTreeMap;

/// Model hyper-parameters.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub ln_eps: f32,
    /// Plant emergent-outlier statistics (see `inject`).
    pub inject_outliers: bool,
    /// LoRA rank/alpha/dropout (paper: 16/16/0.1).
    pub lora_rank: usize,
    pub lora_alpha: f32,
    pub lora_dropout: f32,
    /// Virtual tokens for Prompt/P-tuning (paper: 20).
    pub n_virtual: usize,
}

impl ModelConfig {
    /// Named presets — laptop-scale analogues of the paper's models
    /// (OPT-1.3B / Phi3-3.8B / LLaMA2-7B). See DESIGN.md §2.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        let (d_model, n_layers, n_heads, d_ff) = match name {
            "opt-tiny" => (96, 3, 3, 384),
            "phi-mini" => (128, 4, 4, 512),
            "llama-tiny" => (192, 6, 6, 512),
            "e2e-small" => (256, 8, 8, 1024),
            _ => return None,
        };
        Some(ModelConfig {
            vocab: 288,
            d_model,
            n_layers,
            n_heads,
            d_ff,
            max_seq: 512,
            ln_eps: 1e-5,
            inject_outliers: true,
            lora_rank: 16,
            lora_alpha: 16.0,
            lora_dropout: 0.1,
            n_virtual: 20,
        })
    }

    /// Total frozen base parameters.
    pub fn base_params(&self) -> usize {
        let d = self.d_model;
        let per_block = 4 * d * d + 2 * d * self.d_ff;
        self.vocab * d + self.max_seq * d + self.n_layers * per_block + d * self.vocab
    }
}

/// One decoder block.
pub struct Block {
    pub ln1: LayerNorm,
    pub ln2: LayerNorm,
    pub q_proj: QuantLinear,
    pub k_proj: QuantLinear,
    pub v_proj: QuantLinear,
    pub o_proj: QuantLinear,
    pub up_proj: QuantLinear,
    pub down_proj: QuantLinear,
    pub inj_attn: DiagGain,
    pub inj_o: DiagGain,
    pub inj_mlp: DiagGain,
    pub inj_down: DiagGain,
    pub ia3_k: Option<Ia3Vector>,
    pub ia3_v: Option<Ia3Vector>,
    pub ia3_ff: Option<Ia3Vector>,
    n_heads: usize,
}

/// Per-block forward cache.
pub struct BlockCache {
    ln1c: LnCache,
    qc: LinCache,
    kc: LinCache,
    vc: LinCache,
    k_raw: Option<Matrix>,
    v_raw: Option<Matrix>,
    attn: AttnCache,
    oc: LinCache,
    ln2c: LnCache,
    upc: LinCache,
    u: Matrix,
    g_post: Option<Matrix>,
    downc: LinCache,
}

impl Block {
    fn new(idx: usize, cfg: &ModelConfig, rng: &mut Rng) -> Block {
        let d = cfg.d_model;
        let ff = cfg.d_ff;
        let name = |suffix: &str| format!("blocks.{idx}.{suffix}");
        let (ia, io, im, idn) = if cfg.inject_outliers {
            (
                InjectConfig::stable(1.max(d / 256)),
                InjectConfig::volatile(1.max(d * 2 / 100)),
                InjectConfig::stable(1.max(d / 256)),
                InjectConfig::dynamic(1.max(ff * 5 / 100)),
            )
        } else {
            (
                InjectConfig::none(),
                InjectConfig::none(),
                InjectConfig::none(),
                InjectConfig::none(),
            )
        };
        Block {
            ln1: LayerNorm::new(d, cfg.ln_eps),
            ln2: LayerNorm::new(d, cfg.ln_eps),
            q_proj: QuantLinear::new(&name("attn.q_proj"), d, d, rng),
            k_proj: QuantLinear::new(&name("attn.k_proj"), d, d, rng),
            v_proj: QuantLinear::new(&name("attn.v_proj"), d, d, rng),
            o_proj: QuantLinear::new(&name("attn.o_proj"), d, d, rng),
            up_proj: QuantLinear::new(&name("mlp.up_proj"), d, ff, rng),
            down_proj: QuantLinear::new(&name("mlp.down_proj"), ff, d, rng),
            inj_attn: DiagGain::new(d, ia, rng),
            inj_o: DiagGain::new(d, io, rng),
            inj_mlp: DiagGain::new(d, im, rng),
            inj_down: DiagGain::new(ff, idn, rng),
            ia3_k: None,
            ia3_v: None,
            ia3_ff: None,
            n_heads: cfg.n_heads,
        }
    }

    /// All six linear layers, for uniform iteration.
    pub fn linears(&mut self) -> [&mut QuantLinear; 6] {
        [
            &mut self.q_proj,
            &mut self.k_proj,
            &mut self.v_proj,
            &mut self.o_proj,
            &mut self.up_proj,
            &mut self.down_proj,
        ]
    }

    pub fn linears_ref(&self) -> [&QuantLinear; 6] {
        [
            &self.q_proj,
            &self.k_proj,
            &self.v_proj,
            &self.o_proj,
            &self.up_proj,
            &self.down_proj,
        ]
    }

    fn forward(
        &mut self,
        x: &Matrix,
        batch: usize,
        seq: usize,
        train: bool,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> (Matrix, BlockCache) {
        // attention sub-layer
        let (h1, ln1c) = self.ln1.forward(x);
        let a_in = self.inj_attn.apply(&h1);
        ws.recycle(h1);
        let (q, qc) = self.q_proj.forward(&a_in, train, rng, ws);
        let (k0, kc) = self.k_proj.forward(&a_in, train, rng, ws);
        let (v0, vc) = self.v_proj.forward(&a_in, train, rng, ws);
        ws.recycle(a_in);
        let (k, k_raw) = match &self.ia3_k {
            Some(ia3) => (ia3.forward(&k0), Some(k0)),
            None => (k0, None),
        };
        let (v, v_raw) = match &self.ia3_v {
            Some(ia3) => (ia3.forward(&v0), Some(v0)),
            None => (v0, None),
        };
        let (attn_out, attn) = attention_forward(&q, &k, &v, batch, seq, self.n_heads);
        ws.recycle(q);
        ws.recycle(k);
        ws.recycle(v);
        let o_in = self.inj_o.apply(&attn_out);
        ws.recycle(attn_out);
        let (o, oc) = self.o_proj.forward(&o_in, train, rng, ws);
        ws.recycle(o_in);
        let mut x2 = ws.take_matrix("blk.x2", x.rows(), x.cols());
        x2.data_mut().copy_from_slice(x.data());
        x2.add_assign(&o);
        ws.recycle(o);
        // MLP sub-layer
        let (h2, ln2c) = self.ln2.forward(&x2);
        let m_in = self.inj_mlp.apply(&h2);
        ws.recycle(h2);
        let (u, upc) = self.up_proj.forward(&m_in, train, rng, ws);
        ws.recycle(m_in);
        let g0 = gelu_forward(&u);
        let (g, g_post) = match &self.ia3_ff {
            Some(ia3) => (ia3.forward(&g0), Some(g0)),
            None => (g0, None),
        };
        let d_in = self.inj_down.apply(&g);
        ws.recycle(g);
        let (dn, downc) = self.down_proj.forward(&d_in, train, rng, ws);
        ws.recycle(d_in);
        let mut out = x2;
        out.add_assign(&dn);
        ws.recycle(dn);
        (
            out,
            BlockCache {
                ln1c,
                qc,
                kc,
                vc,
                k_raw,
                v_raw,
                attn,
                oc,
                ln2c,
                upc,
                u,
                g_post,
                downc,
            },
        )
    }

    fn backward(&mut self, dout: &Matrix, cache: &BlockCache, ws: &mut Workspace) -> Matrix {
        // out = x2 + dn
        let mut d_x2 = ws.take_matrix("blk.dx2", dout.rows(), dout.cols());
        d_x2.data_mut().copy_from_slice(dout.data());
        let d_d_in = self.down_proj.backward(dout, &cache.downc, ws);
        let d_g = self.inj_down.backward(&d_d_in);
        ws.recycle(d_d_in);
        let d_g0 = match (self.ia3_ff.as_mut(), cache.g_post.as_ref()) {
            (Some(ia3), Some(g0)) => {
                let r = ia3.backward(&d_g, g0);
                ws.recycle(d_g);
                r
            }
            _ => d_g,
        };
        let d_u = gelu_backward(&d_g0, &cache.u);
        ws.recycle(d_g0);
        let d_m_in = self.up_proj.backward(&d_u, &cache.upc, ws);
        ws.recycle(d_u);
        let d_h2 = self.inj_mlp.backward(&d_m_in);
        ws.recycle(d_m_in);
        let t_ln2 = self.ln2.backward(&d_h2, &cache.ln2c);
        d_x2.add_assign(&t_ln2);
        ws.recycle(t_ln2);
        ws.recycle(d_h2);
        // x2 = x + o
        let mut d_x = ws.take_matrix("blk.dx", d_x2.rows(), d_x2.cols());
        d_x.data_mut().copy_from_slice(d_x2.data());
        let d_o_in = self.o_proj.backward(&d_x2, &cache.oc, ws);
        ws.recycle(d_x2);
        let d_attn_out = self.inj_o.backward(&d_o_in);
        ws.recycle(d_o_in);
        let (dq, dk, dv) = attention_backward(&d_attn_out, &cache.attn, self.n_heads);
        ws.recycle(d_attn_out);
        let dk0 = match (self.ia3_k.as_mut(), cache.k_raw.as_ref()) {
            (Some(ia3), Some(kr)) => {
                let r = ia3.backward(&dk, kr);
                ws.recycle(dk);
                r
            }
            _ => dk,
        };
        let dv0 = match (self.ia3_v.as_mut(), cache.v_raw.as_ref()) {
            (Some(ia3), Some(vr)) => {
                let r = ia3.backward(&dv, vr);
                ws.recycle(dv);
                r
            }
            _ => dv,
        };
        let mut d_a_in = self.q_proj.backward(&dq, &cache.qc, ws);
        ws.recycle(dq);
        let t_k = self.k_proj.backward(&dk0, &cache.kc, ws);
        d_a_in.add_assign(&t_k);
        ws.recycle(t_k);
        ws.recycle(dk0);
        let t_v = self.v_proj.backward(&dv0, &cache.vc, ws);
        d_a_in.add_assign(&t_v);
        ws.recycle(t_v);
        ws.recycle(dv0);
        let d_h1 = self.inj_attn.backward(&d_a_in);
        ws.recycle(d_a_in);
        let t_ln1 = self.ln1.backward(&d_h1, &cache.ln1c);
        d_x.add_assign(&t_ln1);
        ws.recycle(t_ln1);
        ws.recycle(d_h1);
        d_x
    }
}

/// Model-level forward cache.
pub struct ModelCache {
    blocks: Vec<BlockCache>,
    final_lnc: LnCache,
    /// Post-final-LN hidden states (for diagnostics; lm_head is frozen).
    pub h_final: Matrix,
    ptuning: Option<PTuningCache>,
    pub batch: usize,
    /// Sequence length *including* virtual tokens.
    pub seq: usize,
    pub n_virtual: usize,
}

/// The full model.
pub struct Model {
    pub cfg: ModelConfig,
    pub emb: Embedding,
    pub blocks: Vec<Block>,
    pub final_ln: LayerNorm,
    /// (d_model × vocab), frozen FP32.
    pub lm_head: Matrix,
    pub peft: Option<PeftKind>,
    pub prompt: Option<PromptTuning>,
    pub ptuning: Option<PTuningEncoder>,
    /// Dropout / simulation randomness.
    pub rng: Rng,
    /// Scratch arena used by [`Model::forward`]/[`Model::backward`] when the
    /// caller does not thread its own (see [`Model::forward_with`]).
    pub ws: Workspace,
}

impl Model {
    pub fn new(cfg: ModelConfig, seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        let emb = Embedding::new(cfg.vocab, cfg.max_seq, cfg.d_model, &mut rng);
        let blocks = (0..cfg.n_layers)
            .map(|i| Block::new(i, &cfg, &mut rng))
            .collect();
        let final_ln = LayerNorm::new(cfg.d_model, cfg.ln_eps);
        let lm_head = Matrix::randn(cfg.d_model, cfg.vocab, &mut rng, 0.02);
        Model {
            cfg,
            emb,
            blocks,
            final_ln,
            lm_head,
            peft: None,
            prompt: None,
            ptuning: None,
            rng,
            ws: Workspace::new(),
        }
    }

    /// Attach a PEFT strategy (trainable adapters).
    pub fn attach_peft(&mut self, kind: PeftKind) {
        self.peft = Some(kind);
        let cfg = self.cfg.clone();
        match kind {
            PeftKind::Lora => {
                for b in &mut self.blocks {
                    let rank = cfg.lora_rank.min(cfg.d_model / 2).max(1);
                    b.q_proj.lora = Some(LoraAdapter::new(
                        cfg.d_model,
                        cfg.d_model,
                        rank,
                        cfg.lora_alpha,
                        cfg.lora_dropout,
                        &mut self.rng,
                    ));
                    b.v_proj.lora = Some(LoraAdapter::new(
                        cfg.d_model,
                        cfg.d_model,
                        rank,
                        cfg.lora_alpha,
                        cfg.lora_dropout,
                        &mut self.rng,
                    ));
                }
            }
            PeftKind::Prompt => {
                self.prompt = Some(PromptTuning::new(cfg.n_virtual, cfg.d_model, &mut self.rng));
            }
            PeftKind::PTuning => {
                self.ptuning = Some(PTuningEncoder::new(
                    cfg.n_virtual,
                    cfg.d_model,
                    2 * cfg.d_model,
                    &mut self.rng,
                ));
            }
            PeftKind::Ia3 => {
                for b in &mut self.blocks {
                    b.ia3_k = Some(Ia3Vector::new(cfg.d_model));
                    b.ia3_v = Some(Ia3Vector::new(cfg.d_model));
                    b.ia3_ff = Some(Ia3Vector::new(cfg.d_ff));
                }
            }
        }
    }

    /// Detach the model's LoRA/Prompt adapter stack into a portable
    /// [`TenantAdapters`], leaving a **bare shared base** (no per-layer
    /// adapters, no virtual tokens). The frozen quantized weights are
    /// untouched; the detached stack can be installed into an
    /// `infer::AdapterRegistry` and applied per decode row, or re-attached
    /// with [`Model::attach_adapters`]. Moving the adapters preserves
    /// their bits exactly, so detached-then-per-row application is
    /// bit-identical to the attached path (`tests/tenant_parity.rs`).
    pub fn detach_adapters(&mut self) -> TenantAdapters {
        let blocks = self
            .blocks
            .iter_mut()
            .map(|b| TenantBlockAdapters {
                q: b.q_proj.lora.take(),
                v: b.v_proj.lora.take(),
            })
            .collect();
        let prompt = self.prompt.take();
        self.peft = None;
        TenantAdapters { blocks, prompt }
    }

    /// Re-attach a detached adapter stack (inverse of
    /// [`Model::detach_adapters`]): per-block LoRA adapters go back onto
    /// q/v projections and the prompt block becomes the model's own.
    pub fn attach_adapters(&mut self, t: TenantAdapters) {
        assert_eq!(
            t.blocks.len(),
            self.blocks.len(),
            "adapter stack depth does not match the model"
        );
        for (b, ba) in self.blocks.iter_mut().zip(t.blocks) {
            b.q_proj.lora = ba.q;
            b.v_proj.lora = ba.v;
        }
        self.prompt = t.prompt;
    }

    /// Number of virtual tokens prepended by the active PEFT method.
    pub fn n_virtual(&self) -> usize {
        if self.prompt.is_some() || self.ptuning.is_some() {
            self.cfg.n_virtual
        } else {
            0
        }
    }

    /// Embed a padded batch, prepending virtual tokens when active.
    /// Returns (x, ptuning_cache).
    fn embed(&self, tokens: &[Vec<u32>]) -> (Matrix, Option<PTuningCache>) {
        let b = tokens.len();
        let s = tokens[0].len();
        let nv = self.n_virtual();
        let d = self.cfg.d_model;
        assert!(nv + s <= self.cfg.max_seq, "sequence too long: {} > {}", nv + s, self.cfg.max_seq);
        let (virt, ptc): (Option<Matrix>, Option<PTuningCache>) = if let Some(p) = &self.prompt {
            (Some(p.virtual_block()), None)
        } else if let Some(p) = &self.ptuning {
            let (v, c) = p.forward();
            (Some(v), Some(c))
        } else {
            (None, None)
        };
        let sp = nv + s;
        let mut x = Matrix::zeros(b * sp, d);
        for (bi, seq) in tokens.iter().enumerate() {
            assert_eq!(seq.len(), s, "ragged batch");
            if let Some(vb) = &virt {
                for vi in 0..nv {
                    x.row_mut(bi * sp + vi).copy_from_slice(vb.row(vi));
                }
            }
            for (si, &t) in seq.iter().enumerate() {
                let row = x.row_mut(bi * sp + nv + si);
                let te = self.emb.tok.row(t as usize);
                let pe = self.emb.pos.row(nv + si);
                for j in 0..d {
                    row[j] = te[j] + pe[j];
                }
            }
        }
        (x, ptc)
    }

    /// Embed one prompt with a *tenant's* virtual tokens instead of the
    /// model's own — the per-tenant prefill path. Mirrors [`Model::embed`]
    /// for a single sequence bit-for-bit: same virtual-row copy, same
    /// `te + pe` arithmetic with token positions offset by the tenant's
    /// virtual count.
    fn embed_tenant(&self, prompt: &[u32], tenant: &TenantAdapters) -> Matrix {
        let nv = tenant.n_virtual();
        let s = prompt.len();
        let d = self.cfg.d_model;
        assert!(nv + s <= self.cfg.max_seq, "sequence too long: {} > {}", nv + s, self.cfg.max_seq);
        let mut x = Matrix::zeros(nv + s, d);
        if let Some(p) = &tenant.prompt {
            let vb = p.virtual_block();
            for vi in 0..nv {
                x.row_mut(vi).copy_from_slice(vb.row(vi));
            }
        }
        for (si, &t) in prompt.iter().enumerate() {
            let row = x.row_mut(nv + si);
            let te = self.emb.tok.row(t as usize);
            let pe = self.emb.pos.row(nv + si);
            for j in 0..d {
                row[j] = te[j] + pe[j];
            }
        }
        x
    }

    /// Full forward pass using the model's own scratch arena. Returns
    /// logits `(batch·seq' × vocab)` and the cache for backward
    /// (`seq' = n_virtual + seq`).
    pub fn forward(&mut self, tokens: &[Vec<u32>], train: bool) -> (Matrix, ModelCache) {
        let mut ws = std::mem::take(&mut self.ws);
        let out = self.forward_with(tokens, train, &mut ws);
        self.ws = ws;
        out
    }

    /// Full forward pass drawing every hot-path buffer from `ws` — the
    /// train loop threads one arena through every step so the linear-layer
    /// path stops allocating at steady state.
    pub fn forward_with(
        &mut self,
        tokens: &[Vec<u32>],
        train: bool,
        ws: &mut Workspace,
    ) -> (Matrix, ModelCache) {
        let batch = tokens.len();
        let s = tokens[0].len();
        let nv = self.n_virtual();
        let sp = nv + s;
        let (mut x, ptc) = self.embed(tokens);
        let mut caches = Vec::with_capacity(self.blocks.len());
        let mut rng = self.rng.clone();
        for blk in &mut self.blocks {
            let (nx, c) = blk.forward(&x, batch, sp, train, &mut rng, ws);
            ws.recycle(std::mem::replace(&mut x, nx));
            caches.push(c);
        }
        self.rng = rng;
        let (h, final_lnc) = self.final_ln.forward(&x);
        ws.recycle(x);
        let logits = h.matmul(&self.lm_head);
        (
            logits,
            ModelCache {
                blocks: caches,
                final_lnc,
                h_final: h,
                ptuning: ptc,
                batch,
                seq: sp,
                n_virtual: nv,
            },
        )
    }

    /// Backward pass from dL/dlogits using the model's own scratch arena;
    /// accumulates adapter gradients.
    pub fn backward(&mut self, dlogits: &Matrix, cache: &ModelCache) {
        let mut ws = std::mem::take(&mut self.ws);
        self.backward_with(dlogits, cache, &mut ws);
        self.ws = ws;
    }

    /// Backward pass drawing every hot-path buffer from `ws`.
    pub fn backward_with(&mut self, dlogits: &Matrix, cache: &ModelCache, ws: &mut Workspace) {
        // logits = h @ lm_head  (frozen) → dh = dlogits @ lm_headᵀ
        let dh = dlogits.matmul_bt(&self.lm_head);
        let mut dx = self.final_ln.backward(&dh, &cache.final_lnc);
        ws.recycle(dh);
        for (blk, bc) in self.blocks.iter_mut().zip(cache.blocks.iter()).rev() {
            let next = blk.backward(&dx, bc, ws);
            ws.recycle(std::mem::replace(&mut dx, next));
        }
        // virtual-token gradients
        let nv = cache.n_virtual;
        if nv > 0 {
            let d = self.cfg.d_model;
            let mut dvirt = Matrix::zeros(nv, d);
            for bi in 0..cache.batch {
                for vi in 0..nv {
                    let src = dx.row(bi * cache.seq + vi);
                    let dst = dvirt.row_mut(vi);
                    for j in 0..d {
                        dst[j] += src[j];
                    }
                }
            }
            if let Some(p) = &mut self.prompt {
                p.accumulate(&dvirt);
            } else if let (Some(p), Some(ptc)) = (self.ptuning.as_mut(), cache.ptuning.as_ref()) {
                p.backward(&dvirt, ptc);
            }
        }
        ws.recycle(dx);
    }

    /// Visit every trainable parameter (adapters only — base is frozen).
    pub fn visit_params(&mut self, f: &mut dyn FnMut(&str, &mut Param)) {
        for (i, b) in self.blocks.iter_mut().enumerate() {
            if let Some(l) = &mut b.q_proj.lora {
                f(&format!("blocks.{i}.q_proj.lora_a"), &mut l.a);
                f(&format!("blocks.{i}.q_proj.lora_b"), &mut l.b);
            }
            if let Some(l) = &mut b.v_proj.lora {
                f(&format!("blocks.{i}.v_proj.lora_a"), &mut l.a);
                f(&format!("blocks.{i}.v_proj.lora_b"), &mut l.b);
            }
            if let Some(v) = &mut b.ia3_k {
                f(&format!("blocks.{i}.ia3_k"), &mut v.l);
            }
            if let Some(v) = &mut b.ia3_v {
                f(&format!("blocks.{i}.ia3_v"), &mut v.l);
            }
            if let Some(v) = &mut b.ia3_ff {
                f(&format!("blocks.{i}.ia3_ff"), &mut v.l);
            }
        }
        if let Some(p) = &mut self.prompt {
            f("prompt.embeddings", &mut p.embeddings);
        }
        if let Some(p) = &mut self.ptuning {
            f("ptuning.seeds", &mut p.seeds);
            f("ptuning.w1", &mut p.w1);
            f("ptuning.w2", &mut p.w2);
        }
    }

    /// Count trainable parameters.
    pub fn trainable_params(&mut self) -> usize {
        let mut n = 0;
        self.visit_params(&mut |_, p| n += p.numel());
        n
    }

    /// Enable the calibration tap on every linear layer.
    pub fn start_calibration(&mut self) {
        for b in &mut self.blocks {
            for l in b.linears() {
                l.start_calibration();
            }
        }
    }

    /// Collect calibration statistics from every linear layer.
    pub fn finish_calibration(&mut self) -> BTreeMap<String, ChannelStats> {
        let mut out = BTreeMap::new();
        for b in &mut self.blocks {
            for l in b.linears() {
                if let Some(s) = l.take_stats() {
                    out.insert(l.name.clone(), s);
                }
            }
        }
        out
    }

    /// Convert every linear layer to quantized execution under `kind`,
    /// selecting outliers per the budget policy. Returns the registry of
    /// pre-identified outlier sets (the OSSH instruments consume it).
    pub fn apply_method(
        &mut self,
        kind: MethodKind,
        calib: &BTreeMap<String, ChannelStats>,
        allocator: &BudgetAllocator,
        mcfg: &MethodConfig,
        detector: &OutlierDetector,
    ) -> OutlierRegistry {
        let mut registry = OutlierRegistry::new();
        for b in &mut self.blocks {
            for l in b.linears() {
                let stats = calib
                    .get(&l.name)
                    .unwrap_or_else(|| panic!("no calibration stats for {}", l.name));
                let budget = allocator.channels_for(l.kind, l.cin());
                let oset = detector.select(stats, budget);
                registry.insert(&l.name, oset.clone());
                l.apply_method(kind, stats, &oset, mcfg);
            }
        }
        registry
    }

    /// Advance the outlier simulator by one training iteration.
    pub fn tick_outliers(&mut self) {
        let mut rng = self.rng.clone();
        for b in &mut self.blocks {
            b.inj_attn.tick(&mut rng);
            b.inj_o.tick(&mut rng);
            b.inj_mlp.tick(&mut rng);
            b.inj_down.tick(&mut rng);
        }
        self.rng = rng;
    }

    /// Greedy decoding: extend `prompt` by up to `max_new` tokens.
    pub fn generate(&mut self, prompt: &[u32], max_new: usize, eos: u32) -> Vec<u32> {
        let mut seq: Vec<u32> = prompt.to_vec();
        let nv = self.n_virtual();
        for _ in 0..max_new {
            if seq.len() + nv >= self.cfg.max_seq {
                break;
            }
            let (logits, cache) = self.forward(&[seq.clone()], false);
            let last = logits.row(cache.seq - 1);
            let next = last
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as u32)
                .unwrap();
            if next == eos {
                break;
            }
            seq.push(next);
        }
        seq[prompt.len()..].to_vec()
    }

    /// Bytes held in frozen weights across all linear layers (the
    /// method-dependent part of the paper's memory columns).
    pub fn frozen_linear_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.linears_ref().iter().map(|l| l.weight_bytes()).sum::<usize>())
            .sum()
    }

    /// All `(layer-kind, c_in)` pairs, for budget-envelope checks.
    pub fn layer_shapes(&self) -> Vec<(crate::outlier::LayerKind, usize)> {
        self.blocks
            .iter()
            .flat_map(|b| {
                b.linears_ref()
                    .iter()
                    .map(|l| (l.kind, l.cin()))
                    .collect::<Vec<_>>()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outlier::BudgetPolicy;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq: 64,
            ln_eps: 1e-5,
            inject_outliers: true,
            lora_rank: 4,
            lora_alpha: 8.0,
            lora_dropout: 0.0,
            n_virtual: 4,
        }
    }

    fn batch(rng: &mut Rng, b: usize, s: usize, vocab: usize) -> Vec<Vec<u32>> {
        (0..b)
            .map(|_| (0..s).map(|_| rng.below(vocab) as u32).collect())
            .collect()
    }

    #[test]
    fn forward_shapes() {
        let mut m = Model::new(tiny_cfg(), 7);
        let mut r = Rng::new(8);
        let toks = batch(&mut r, 2, 10, 64);
        let (logits, cache) = m.forward(&toks, false);
        assert_eq!((logits.rows(), logits.cols()), (20, 64));
        assert_eq!(cache.seq, 10);
        assert_eq!(cache.n_virtual, 0);
    }

    #[test]
    fn prompt_tuning_extends_sequence() {
        let mut m = Model::new(tiny_cfg(), 7);
        m.attach_peft(PeftKind::Prompt);
        let mut r = Rng::new(8);
        let toks = batch(&mut r, 2, 10, 64);
        let (logits, cache) = m.forward(&toks, false);
        assert_eq!(cache.n_virtual, 4);
        assert_eq!(cache.seq, 14);
        assert_eq!(logits.rows(), 2 * 14);
    }

    #[test]
    fn lora_gradients_flow_end_to_end() {
        let mut m = Model::new(tiny_cfg(), 9);
        m.attach_peft(PeftKind::Lora);
        // poke the LoRA Bs so the adapter output is nonzero (otherwise dA=0)
        let mut r = Rng::new(10);
        for b in &mut m.blocks {
            if let Some(l) = &mut b.q_proj.lora {
                l.b.value = Matrix::randn(4, 32, &mut r, 0.1);
            }
        }
        let toks = batch(&mut r, 2, 8, 64);
        let (logits, cache) = m.forward(&toks, true);
        let dlogits = Matrix::randn(logits.rows(), logits.cols(), &mut r, 0.1);
        m.backward(&dlogits, &cache);
        let mut total_grad = 0.0f64;
        m.visit_params(&mut |_, p| total_grad += p.grad.sq_norm());
        assert!(total_grad > 0.0, "no gradient reached the adapters");
    }

    #[test]
    fn every_peft_kind_has_trainable_params_and_grads() {
        for kind in PeftKind::ALL {
            let mut m = Model::new(tiny_cfg(), 11);
            m.attach_peft(kind);
            assert!(m.trainable_params() > 0, "{kind:?}");
            let mut r = Rng::new(12);
            let toks = batch(&mut r, 1, 6, 64);
            let (logits, cache) = m.forward(&toks, true);
            let dlogits = Matrix::randn(logits.rows(), logits.cols(), &mut r, 0.1);
            m.backward(&dlogits, &cache);
            let mut g = 0.0f64;
            m.visit_params(&mut |_, p| g += p.grad.sq_norm());
            assert!(g > 0.0, "{kind:?}: no gradient");
        }
    }

    #[test]
    fn calibration_and_quantization_pipeline() {
        let mut m = Model::new(tiny_cfg(), 13);
        let mut r = Rng::new(14);
        m.start_calibration();
        for _ in 0..4 {
            let toks = batch(&mut r, 2, 8, 64);
            let _ = m.forward(&toks, false);
        }
        let calib = m.finish_calibration();
        assert_eq!(calib.len(), 12); // 2 blocks × 6 linears
        let alloc = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
        let det = OutlierDetector::new(20.0);
        let registry = m.apply_method(
            MethodKind::Quaff,
            &calib,
            &alloc,
            &MethodConfig::default(),
            &det,
        );
        assert_eq!(registry.len(), 12);
        // planted outliers should be discovered in at least the down_proj taps
        let found: usize = registry
            .layers()
            .filter(|(name, set)| name.contains("down_proj") && !set.is_empty())
            .count();
        assert!(found > 0, "no outliers detected in any down_proj");
        // quantized forward still runs
        let toks = batch(&mut r, 1, 8, 64);
        let (logits, _) = m.forward(&toks, false);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_model_close_to_master() {
        let cfg = tiny_cfg();
        let mut r = Rng::new(15);
        let toks = batch(&mut r, 2, 8, 64);
        let mut m = Model::new(cfg.clone(), 16);
        let (ref_logits, _) = m.forward(&toks, false);
        m.start_calibration();
        let _ = m.forward(&toks, false);
        let calib = m.finish_calibration();
        let alloc = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
        let det = OutlierDetector::new(20.0);
        let _ = m.apply_method(MethodKind::Quaff, &calib, &alloc, &MethodConfig::default(), &det);
        let (q_logits, _) = m.forward(&toks, false);
        // INT8 through 2 blocks: modest tolerance, but must correlate highly
        let corr = crate::util::pearson(ref_logits.data(), q_logits.data());
        assert!(corr > 0.98, "quantized logits decorrelated: r={corr}");
    }

    #[test]
    fn generate_produces_tokens_and_respects_eos() {
        let mut m = Model::new(tiny_cfg(), 17);
        let out = m.generate(&[1, 2, 3], 5, u32::MAX);
        assert!(!out.is_empty() && out.len() <= 5);
        assert!(out.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn tick_outliers_drifts_gains() {
        let mut m = Model::new(tiny_cfg(), 18);
        let g0 = m.blocks[0].inj_down.max_gain();
        for _ in 0..100 {
            m.tick_outliers();
        }
        let g1 = m.blocks[0].inj_down.max_gain();
        assert_ne!(g0, g1);
    }

    #[test]
    fn preset_shapes() {
        for name in ["opt-tiny", "phi-mini", "llama-tiny", "e2e-small"] {
            let cfg = ModelConfig::preset(name).unwrap();
            assert_eq!(cfg.d_model % cfg.n_heads, 0, "{name}");
            assert!(cfg.base_params() > 100_000, "{name}");
        }
        assert!(ModelConfig::preset("gpt5").is_none());
    }

    use crate::util::prng::Rng;
}
