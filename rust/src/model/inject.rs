//! Outlier injection simulator.
//!
//! Billion-parameter pretrained LLMs exhibit *emergent* channel-wise
//! activation outliers (paper §2.2, Fig. 2). Laptop-scale models trained
//! from scratch do not, so this substrate plants the same statistics at the
//! input of every linear layer: a sparse set of channels is amplified
//! 30–120×, with (a) slow multiplicative magnitude drift across training
//! iterations — reproducing the distribution shift of Fig. 2(b) that breaks
//! static scaling — and (b) rare index churn, concentrated on the layer
//! types the paper identifies as volatile (`o_proj`, and especially
//! `down_proj`, Appendix B), which is what keeps hit rates below 100 % in
//! Figs. 3/8 and drives the uniform-budget failure of Fig. 9.
//!
//! The injection is a fixed diagonal gain on the activations — equivalent
//! to a (frozen) reparameterization of the preceding layer — so gradients
//! pass through it exactly and every quantization method sees identical
//! inputs.

use crate::tensor::Matrix;
use crate::util::prng::Rng;

/// Per-injection-point configuration.
#[derive(Clone, Copy, Debug)]
pub struct InjectConfig {
    /// Number of amplified (hot) channels.
    pub n_hot: usize,
    /// Log-normal amplitude parameters: `amp = exp(N(mu, sigma))`.
    pub amp_mu: f32,
    pub amp_sigma: f32,
    /// Per-step multiplicative drift: `amp *= exp(N(0, drift_sigma))`.
    pub drift_sigma: f32,
    /// Per-step probability that one hot channel migrates to a new index.
    pub churn_prob: f32,
}

impl InjectConfig {
    /// No injection at all.
    pub fn none() -> InjectConfig {
        InjectConfig {
            n_hot: 0,
            amp_mu: 0.0,
            amp_sigma: 0.0,
            drift_sigma: 0.0,
            churn_prob: 0.0,
        }
    }

    /// Stable layer inputs (q/k/v/up): few channels, effectively no churn.
    pub fn stable(n_hot: usize) -> InjectConfig {
        InjectConfig {
            n_hot,
            amp_mu: 4.1, // e^4.1 ≈ 60×
            amp_sigma: 0.4,
            drift_sigma: 0.02,
            churn_prob: 0.0,
        }
    }

    /// Volatile inputs (o_proj): mild churn.
    pub fn volatile(n_hot: usize) -> InjectConfig {
        InjectConfig {
            n_hot,
            amp_mu: 3.9,
            amp_sigma: 0.5,
            drift_sigma: 0.03,
            churn_prob: 0.002,
        }
    }

    /// Highly dynamic inputs (down_proj): strongest drift + churn.
    pub fn dynamic(n_hot: usize) -> InjectConfig {
        InjectConfig {
            n_hot,
            amp_mu: 3.7,
            amp_sigma: 0.6,
            drift_sigma: 0.05,
            churn_prob: 0.01,
        }
    }
}

/// One injection point: a diagonal gain over `dim` channels, hot on a
/// sparse drifting subset.
#[derive(Clone, Debug)]
pub struct DiagGain {
    /// Full gain vector (1.0 on normal channels).
    pub gains: Vec<f32>,
    /// Current hot channel indices (sorted).
    pub hot: Vec<usize>,
    cfg: InjectConfig,
}

impl DiagGain {
    pub fn new(dim: usize, cfg: InjectConfig, rng: &mut Rng) -> DiagGain {
        let n_hot = cfg.n_hot.min(dim);
        let hot = rng.sample_indices(dim, n_hot);
        let mut gains = vec![1.0f32; dim];
        for &c in &hot {
            gains[c] = rng.lognormal(cfg.amp_mu, cfg.amp_sigma);
        }
        DiagGain { gains, hot, cfg }
    }

    /// Identity injection (for disabled simulation).
    pub fn identity(dim: usize) -> DiagGain {
        DiagGain {
            gains: vec![1.0; dim],
            hot: Vec::new(),
            cfg: InjectConfig::none(),
        }
    }

    /// Apply the gain: `y = x ∘ g`.
    pub fn apply(&self, x: &Matrix) -> Matrix {
        if self.hot.is_empty() {
            return x.clone();
        }
        let mut y = x.clone();
        // only hot channels differ from 1 — touch those columns only
        for t in 0..y.rows() {
            let row = y.row_mut(t);
            for &c in &self.hot {
                row[c] *= self.gains[c];
            }
        }
        y
    }

    /// Backward through the diagonal: `dx = dy ∘ g`.
    pub fn backward(&self, dy: &Matrix) -> Matrix {
        self.apply(dy)
    }

    /// Advance one training iteration: drift magnitudes, maybe churn one
    /// channel.
    pub fn tick(&mut self, rng: &mut Rng) {
        if self.hot.is_empty() {
            return;
        }
        if self.cfg.drift_sigma > 0.0 {
            for &c in &self.hot {
                let f = (rng.normal() * self.cfg.drift_sigma).exp();
                // keep amplitudes in a plausible envelope (10x .. 500x)
                self.gains[c] = (self.gains[c] * f).clamp(10.0, 500.0);
            }
        }
        if self.cfg.churn_prob > 0.0 && rng.chance(self.cfg.churn_prob) {
            let dim = self.gains.len();
            let victim_pos = rng.below(self.hot.len());
            let old = self.hot[victim_pos];
            // find a currently-cold channel
            for _ in 0..16 {
                let cand = rng.below(dim);
                if !self.hot.contains(&cand) {
                    self.gains[cand] = self.gains[old];
                    self.gains[old] = 1.0;
                    self.hot[victim_pos] = cand;
                    self.hot.sort_unstable();
                    break;
                }
            }
        }
    }

    /// Amplitude of the hottest channel (diagnostics / Fig. 2).
    pub fn max_gain(&self) -> f32 {
        self.hot.iter().map(|&c| self.gains[c]).fold(1.0, f32::max)
    }

    /// Deterministically relocate every hot channel by `shift` positions
    /// (mod dim) — the synthetic adversarial drift used by the OSSH
    /// stability tier to break spatial stability on demand. Unlike
    /// [`DiagGain::tick`], this consumes no randomness, so a run with a
    /// relocation at step `s` stays bit-reproducible. When two old
    /// channels collide on one destination the larger gain wins.
    pub fn relocate(&mut self, shift: usize) {
        if self.hot.is_empty() {
            return;
        }
        let dim = self.gains.len();
        let moved: Vec<(usize, f32)> = self.hot.iter().map(|&c| (c, self.gains[c])).collect();
        for &(c, _) in &moved {
            self.gains[c] = 1.0;
        }
        let mut new_hot = Vec::with_capacity(moved.len());
        for (c, g) in moved {
            let dst = (c + shift) % dim;
            self.gains[dst] = self.gains[dst].max(g);
            new_hot.push(dst);
        }
        new_hot.sort_unstable();
        new_hot.dedup();
        self.hot = new_hot;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_scales_only_hot_channels() {
        let mut r = Rng::new(1);
        let g = DiagGain::new(16, InjectConfig::stable(2), &mut r);
        let x = Matrix::from_vec(1, 16, vec![1.0; 16]);
        let y = g.apply(&x);
        for c in 0..16 {
            if g.hot.contains(&c) {
                assert!(y.get(0, c) > 10.0, "hot channel {c} gain {}", y.get(0, c));
            } else {
                assert_eq!(y.get(0, c), 1.0);
            }
        }
    }

    #[test]
    fn identity_is_noop() {
        let mut r = Rng::new(2);
        let g = DiagGain::identity(8);
        let x = Matrix::randn(3, 8, &mut r, 1.0);
        assert_eq!(g.apply(&x).data(), x.data());
    }

    #[test]
    fn drift_changes_magnitude_but_not_indices() {
        let mut r = Rng::new(3);
        let mut g = DiagGain::new(32, InjectConfig::stable(3), &mut r);
        let hot0 = g.hot.clone();
        let amp0: Vec<f32> = hot0.iter().map(|&c| g.gains[c]).collect();
        for _ in 0..200 {
            g.tick(&mut r);
        }
        assert_eq!(g.hot, hot0, "stable config must not churn");
        let amp1: Vec<f32> = hot0.iter().map(|&c| g.gains[c]).collect();
        assert_ne!(amp0, amp1, "drift must move magnitudes");
    }

    #[test]
    fn churn_eventually_moves_channels() {
        let mut r = Rng::new(4);
        let mut g = DiagGain::new(64, InjectConfig::dynamic(4), &mut r);
        let hot0 = g.hot.clone();
        for _ in 0..2000 {
            g.tick(&mut r);
        }
        assert_ne!(g.hot, hot0, "dynamic config should churn over 2000 steps");
        // invariants: still 4 hot channels, gains consistent
        assert_eq!(g.hot.len(), 4);
        for (c, &gain) in g.gains.iter().enumerate() {
            if g.hot.contains(&c) {
                assert!(gain >= 10.0);
            } else {
                assert_eq!(gain, 1.0, "cold channel {c} has gain {gain}");
            }
        }
    }

    #[test]
    fn relocate_shifts_every_hot_channel_without_randomness() {
        let mut r = Rng::new(6);
        let mut g = DiagGain::new(32, InjectConfig::stable(3), &mut r);
        let hot0 = g.hot.clone();
        let gains0: Vec<f32> = hot0.iter().map(|&c| g.gains[c]).collect();
        let state_before = r.state();
        g.relocate(5);
        assert_eq!(r.state(), state_before, "relocate must not consume randomness");
        let expect: Vec<usize> = {
            let mut v: Vec<usize> = hot0.iter().map(|&c| (c + 5) % 32).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(g.hot, expect);
        for (&c0, &g0) in hot0.iter().zip(&gains0) {
            assert_eq!(g.gains[(c0 + 5) % 32], g0);
            if !g.hot.contains(&c0) {
                assert_eq!(g.gains[c0], 1.0, "old channel {c0} must cool down");
            }
        }
        // relocating twice by dim is a no-op on indices
        let hot1 = g.hot.clone();
        g.relocate(32);
        assert_eq!(g.hot, hot1);
        // identity injections stay inert
        let mut id = DiagGain::identity(8);
        id.relocate(3);
        assert!(id.hot.is_empty());
    }

    #[test]
    fn backward_equals_apply() {
        let mut r = Rng::new(5);
        let g = DiagGain::new(8, InjectConfig::volatile(2), &mut r);
        let x = Matrix::randn(2, 8, &mut r, 1.0);
        assert_eq!(g.apply(&x).data(), g.backward(&x).data());
    }
}
