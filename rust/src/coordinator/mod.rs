//! L3 coordinator: the server–client fine-tuning service.
//!
//! A [`PreprocessServer`] (bundle.rs) plays the paper's "public server":
//! calibrate → identify outlier channels → quantize → distribute. The
//! [`Coordinator`] runs a thread-based event loop accepting
//! [`FinetuneJob`]s ("clients"), executes each against a freshly prepared
//! [`DistributionBundle`], and returns [`JobReport`]s with task metrics,
//! per-step latency and the memory breakdown — the measurement engine
//! behind every table and figure in `report`.

pub mod bundle;
pub mod checkpoint;

pub use bundle::{DistributionBundle, PreprocessServer, ServerConfig};

use crate::anyhow;
use crate::data::{
    Dataset, Sample, SynthTask, TaskFamily, INSTRUCTION_SETS, LONGTEXT_SETS, REASONING_SETS,
};
use crate::methods::MethodKind;
use crate::metrics::{LatencyTimer, MemoryAccountant, MemoryBreakdown};
use crate::peft::PeftKind;
use crate::train::{eval as teval, Trainer};
use crate::util::error::{Context, Result};
use crate::util::prng::Rng;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// One fine-tuning request.
#[derive(Clone, Debug)]
pub struct FinetuneJob {
    pub id: u64,
    /// Benchmark name (see `data::synth::SynthTask::by_name`).
    pub dataset: String,
    pub method: MethodKind,
    pub peft: PeftKind,
    pub steps: u64,
    pub batch_size: usize,
    pub grad_accum: usize,
    pub lr: f32,
    pub seed: u64,
    pub train_pool: usize,
    pub eval_samples: usize,
    pub max_len: usize,
}

impl FinetuneJob {
    /// Paper-default job: LoRA fine-tuning, batch 16 scaled down to the
    /// simulator (batch 8), Adam lr 2e-4.
    pub fn new(id: u64, dataset: &str, method: MethodKind, peft: PeftKind) -> FinetuneJob {
        FinetuneJob {
            id,
            dataset: dataset.to_string(),
            method,
            peft,
            steps: 30,
            batch_size: 8,
            grad_accum: 1,
            lr: 2e-3,
            seed: 7,
            train_pool: 64,
            eval_samples: 24,
            max_len: 160,
        }
    }
}

/// Completed-job metrics.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub id: u64,
    pub dataset: String,
    pub method: MethodKind,
    pub peft: PeftKind,
    pub steps: u64,
    pub final_loss: f64,
    /// Task metrics: keys among {"ppl", "acc", "rouge_l", "exact"}.
    pub metrics: BTreeMap<String, f64>,
    pub mean_step_secs: f64,
    pub memory: MemoryBreakdown,
    pub payload_bytes: usize,
}

impl JobReport {
    pub fn metric(&self, key: &str) -> f64 {
        self.metrics.get(key).copied().unwrap_or(f64::NAN)
    }
}

/// Execute one job against a prepared bundle (the worker body; exposed so
/// reports/benches can run cells synchronously without the queue). A job
/// naming an unknown dataset is a readable [`Err`], not a panic — bad task
/// names come straight from CLI flags.
pub fn run_job(server: &PreprocessServer, job: &FinetuneJob) -> Result<JobReport> {
    let task = SynthTask::by_name(&job.dataset).with_context(|| {
        format!(
            "unknown dataset '{}' (known: {})",
            job.dataset,
            INSTRUCTION_SETS
                .iter()
                .chain(&REASONING_SETS)
                .chain(&LONGTEXT_SETS)
                .copied()
                .collect::<Vec<_>>()
                .join(", ")
        )
    })?;
    let mut rng = Rng::new(job.seed);
    let samples: Vec<Sample> = (0..job.train_pool + job.eval_samples)
        .map(|_| task.sample(&mut rng))
        .collect();
    let ds = Dataset::from_samples(&job.dataset, samples, &mut rng);

    let mut bundle = server.prepare(job.method, job.peft);
    let model = &mut bundle.model;
    let mut trainer = Trainer::new(job.lr, job.max_len, job.grad_accum);
    let mut timer = LatencyTimer::new();
    let mut iter = ds.batches(job.batch_size);
    let mut final_loss = f64::NAN;
    for _ in 0..job.steps {
        let mut micro = Vec::with_capacity(job.grad_accum);
        for _ in 0..job.grad_accum {
            micro.push(iter.next_batch());
        }
        let stats = trainer.step(model, &micro);
        timer.record(stats.seconds);
        final_loss = stats.loss;
    }
    // evaluation by task family
    let test: Vec<Sample> = ds.test.iter().take(job.eval_samples).cloned().collect();
    let mut metrics = BTreeMap::new();
    let (_nll, ppl) = teval::eval_ppl(model, &test, job.batch_size, job.max_len);
    metrics.insert("ppl".to_string(), ppl);
    match task.family {
        TaskFamily::Mcq => {
            metrics.insert(
                "acc".to_string(),
                teval::eval_mcq_accuracy(model, &test, job.max_len),
            );
        }
        TaskFamily::Lambada => {
            metrics.insert(
                "acc".to_string(),
                teval::eval_token_accuracy(model, &test, job.max_len),
            );
            metrics.insert(
                "exact".to_string(),
                teval::eval_exact_match(model, &test, job.max_len),
            );
        }
        TaskFamily::Instruction | TaskFamily::LongForm => {
            metrics.insert(
                "acc".to_string(),
                teval::eval_token_accuracy(model, &test, job.max_len),
            );
            let n_rouge = test.len().min(6);
            metrics.insert(
                "rouge_l".to_string(),
                teval::eval_rouge(model, &test[..n_rouge], 48),
            );
        }
    }
    let memory = MemoryAccountant::account(model, job.method, job.batch_size, job.max_len);
    Ok(JobReport {
        id: job.id,
        dataset: job.dataset.clone(),
        method: job.method,
        peft: job.peft,
        steps: trainer.step_count,
        final_loss,
        metrics,
        mean_step_secs: timer.mean(),
        memory,
        payload_bytes: bundle.payload_bytes,
    })
}

enum Msg {
    Submit(FinetuneJob, mpsc::Sender<Result<JobReport>>),
    Shutdown,
}

/// The coordinator service: a job queue drained by worker threads, each
/// holding a reference to the shared preprocessing server.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
    submitted: u64,
}

impl Coordinator {
    pub fn new(server_cfg: ServerConfig, n_workers: usize) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let server = Arc::new(PreprocessServer::new(server_cfg));
        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let rx = Arc::clone(&rx);
            let server = Arc::clone(&server);
            workers.push(thread::spawn(move || loop {
                let msg = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match msg {
                    Ok(Msg::Submit(job, reply)) => {
                        let report = run_job(&server, &job);
                        let _ = reply.send(report);
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                }
            }));
        }
        Coordinator {
            tx,
            workers,
            submitted: 0,
        }
    }

    /// Submit a job; returns a receiver for its (fallible) report.
    pub fn submit(&mut self, job: FinetuneJob) -> mpsc::Receiver<Result<JobReport>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submitted += 1;
        self.tx
            .send(Msg::Submit(job, reply_tx))
            .expect("coordinator workers gone");
        reply_rx
    }

    /// Submit a batch and wait for all reports (returned in submit order);
    /// the first failing job (e.g. an unknown dataset name) surfaces as a
    /// readable error.
    pub fn run_all(&mut self, jobs: Vec<FinetuneJob>) -> Result<Vec<JobReport>> {
        let receivers: Vec<_> = jobs.into_iter().map(|j| self.submit(j)).collect();
        receivers
            .into_iter()
            .map(|rx| match rx.recv() {
                Ok(report) => report,
                Err(_) => Err(anyhow!("coordinator worker dropped its reply")),
            })
            .collect()
    }

    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Graceful shutdown.
    pub fn shutdown(mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_server_cfg() -> ServerConfig {
        let mut cfg = ServerConfig::default();
        cfg.preset = "opt-tiny".to_string();
        cfg.calib_samples = 8;
        cfg.calib_batch = 4;
        cfg
    }

    fn tiny_job(id: u64, method: MethodKind) -> FinetuneJob {
        let mut j = FinetuneJob::new(id, "gpqa", method, PeftKind::Lora);
        j.steps = 2;
        j.batch_size = 2;
        j.train_pool = 8;
        j.eval_samples = 4;
        j.max_len = 128;
        j
    }

    #[test]
    fn unknown_dataset_is_a_readable_error_not_a_panic() {
        let server = PreprocessServer::new(tiny_server_cfg());
        let mut job = tiny_job(1, MethodKind::Naive);
        job.dataset = "definitely-not-a-task".to_string();
        let err = run_job(&server, &job).unwrap_err().to_string();
        assert!(err.contains("unknown dataset 'definitely-not-a-task'"), "{err}");
        assert!(err.contains("gpqa"), "should list known tasks: {err}");
        // ...and through the queue as well
        let mut coord = Coordinator::new(tiny_server_cfg(), 1);
        let err = coord.run_all(vec![job]).unwrap_err().to_string();
        assert!(err.contains("unknown dataset"), "{err}");
        coord.shutdown();
    }

    #[test]
    fn run_job_produces_complete_report() {
        let server = PreprocessServer::new(tiny_server_cfg());
        let report = run_job(&server, &tiny_job(1, MethodKind::Quaff)).expect("known dataset");
        assert_eq!(report.id, 1);
        assert_eq!(report.steps, 2);
        assert!(report.final_loss.is_finite());
        assert!(report.metric("ppl") > 1.0);
        assert!((0.0..=1.0).contains(&report.metric("acc")));
        assert!(report.mean_step_secs > 0.0);
        assert!(report.memory.total() > 0);
    }

    #[test]
    fn coordinator_returns_reports_in_submit_order() {
        let mut coord = Coordinator::new(tiny_server_cfg(), 1);
        let jobs = vec![
            tiny_job(10, MethodKind::Naive),
            tiny_job(11, MethodKind::Quaff),
            tiny_job(12, MethodKind::Fp32),
        ];
        let reports = coord.run_all(jobs).expect("known datasets");
        assert_eq!(
            reports.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![10, 11, 12]
        );
        assert_eq!(coord.submitted(), 3);
        coord.shutdown();
    }

    #[test]
    fn memory_report_orders_methods_correctly() {
        let server = PreprocessServer::new(tiny_server_cfg());
        let fp32 = run_job(&server, &tiny_job(1, MethodKind::Fp32)).unwrap();
        let quaff = run_job(&server, &tiny_job(2, MethodKind::Quaff)).unwrap();
        let smooth_d = run_job(&server, &tiny_job(3, MethodKind::SmoothDynamic)).unwrap();
        assert!(quaff.memory.total() < fp32.memory.total());
        assert!(smooth_d.memory.total() >= fp32.memory.total());
    }
}
