//! L3 coordinator: the server–client fine-tuning service.
//!
//! A [`PreprocessServer`] (bundle.rs) plays the paper's "public server":
//! calibrate → identify outlier channels → quantize → distribute. The
//! [`Coordinator`] runs a thread-based event loop accepting
//! [`FinetuneJob`]s ("clients"), executes each against a freshly prepared
//! [`DistributionBundle`], and returns [`JobReport`]s with task metrics,
//! per-step latency and the memory breakdown — the measurement engine
//! behind every table and figure in `report`.
//!
//! Long-running jobs are the common case on consumer hardware, so jobs can
//! carry a [`CheckpointSpec`]: `run_job` then writes the **full** training
//! state (quantized base weights, Quaff momentum, adapters, Adam moments,
//! PRNG streams, data cursor, loss log) crash-safely every N steps via
//! [`crate::persist`], resumes from an existing checkpoint automatically,
//! and [`resumable_jobs`] + [`Coordinator::run_all`] pick up every
//! interrupted job in a directory. Resume is **bit-identical** to the
//! uninterrupted run (`tests/persist_resume.rs`).
//!
//! Job execution itself is factored into [`JobRun`] — an incremental
//! start/step/finish state machine — so the same per-step body serves two
//! drivers: [`run_job`] (run to completion, the original behaviour) and
//! the [`Scheduler`], which **interleaves** several concurrent jobs
//! round-robin over a bounded set of resident runs, preempting the
//! least-recently-run job to a checkpoint when `max_resident` is
//! exceeded and resuming it later. Because preemption is exactly the
//! crash-safe persist path, an interleaved schedule produces
//! byte-identical checkpoints and loss logs to running the same jobs
//! sequentially (`tests/tenant_parity.rs`), and a finished run's adapter
//! stack can be handed straight to the serving tier
//! ([`Scheduler::take_adapters`] →
//! [`crate::infer::AdapterRegistry`]) — train-while-serve lives in
//! [`Scheduler::run_with`], which yields to a caller-supplied pump
//! between rounds.

pub mod bundle;
pub mod checkpoint;

pub use bundle::{DistributionBundle, PreprocessServer, ServerConfig};

use crate::data::{
    Dataset, Sample, SynthTask, TaskFamily, INSTRUCTION_SETS, LONGTEXT_SETS, REASONING_SETS,
};
use crate::methods::MethodKind;
use crate::metrics::{LatencyTimer, MemoryAccountant, MemoryBreakdown};
use crate::model::Model;
use crate::peft::{PeftKind, TenantAdapters};
use crate::persist;
use crate::train::{eval as teval, Trainer};
use crate::util::error::{Context, Result};
use crate::util::prng::Rng;
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Periodic full-state checkpointing policy for a job.
///
/// When set on a [`FinetuneJob`], `run_job` writes the complete training
/// state to `path` every `every` optimizer steps (and after the final
/// step), crash-safely — temp file + fsync + atomic rename, with the
/// previous generation retained at `<path>.prev` for corrupt-tail
/// recovery. If `path` (or its previous generation) already holds a
/// checkpoint when the job starts, the job **resumes** from it instead of
/// starting over, after validating that the stored job spec matches.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    /// Archive location; by convention named `*.qckpt` so directory scans
    /// ([`resumable_jobs`]) can discover it.
    pub path: PathBuf,
    /// Save every N steps; 0 disables saving (resume-only).
    pub every: u64,
}

/// One fine-tuning request.
#[derive(Clone, Debug)]
pub struct FinetuneJob {
    pub id: u64,
    /// Benchmark name (see `data::synth::SynthTask::by_name`).
    pub dataset: String,
    pub method: MethodKind,
    pub peft: PeftKind,
    pub steps: u64,
    pub batch_size: usize,
    pub grad_accum: usize,
    pub lr: f32,
    pub seed: u64,
    pub train_pool: usize,
    pub eval_samples: usize,
    pub max_len: usize,
    /// Periodic checkpoint/resume policy (None = run in memory only).
    pub checkpoint: Option<CheckpointSpec>,
}

impl FinetuneJob {
    /// Paper-default job: LoRA fine-tuning, batch 16 scaled down to the
    /// simulator (batch 8), Adam lr 2e-4.
    pub fn new(id: u64, dataset: &str, method: MethodKind, peft: PeftKind) -> FinetuneJob {
        FinetuneJob {
            id,
            dataset: dataset.to_string(),
            method,
            peft,
            steps: 30,
            batch_size: 8,
            grad_accum: 1,
            lr: 2e-3,
            seed: 7,
            train_pool: 64,
            eval_samples: 24,
            max_len: 160,
            checkpoint: None,
        }
    }
}

/// Completed-job metrics.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub id: u64,
    pub dataset: String,
    pub method: MethodKind,
    pub peft: PeftKind,
    pub steps: u64,
    pub final_loss: f64,
    /// Every per-step loss, in step order (spans resumes: a resumed job's
    /// log continues the interrupted run's — bit-identical to an
    /// uninterrupted run's log).
    pub losses: Vec<f64>,
    /// `Some(k)` when the job resumed from a checkpoint taken at step `k`.
    pub resumed_from: Option<u64>,
    /// Task metrics: keys among {"ppl", "acc", "rouge_l", "exact"}.
    pub metrics: BTreeMap<String, f64>,
    pub mean_step_secs: f64,
    pub memory: MemoryBreakdown,
    pub payload_bytes: usize,
}

impl JobReport {
    pub fn metric(&self, key: &str) -> f64 {
        self.metrics.get(key).copied().unwrap_or(f64::NAN)
    }
}

/// Verify that a checkpoint's recorded job spec matches the job asking to
/// resume from it. `steps` (extendable), `id`, and the checkpoint policy
/// itself may differ; everything that determines the training trajectory
/// must match, or the resumed run would silently diverge. Public so other
/// run drivers (e.g. the OSSH validation harness, `report::ossh`) enforce
/// the same compatibility contract when they resume their own checkpoints.
pub fn validate_resume(saved: &FinetuneJob, job: &FinetuneJob) -> Result<()> {
    let mut diffs: Vec<&str> = Vec::new();
    if saved.dataset != job.dataset {
        diffs.push("dataset");
    }
    if saved.method != job.method {
        diffs.push("method");
    }
    if saved.peft != job.peft {
        diffs.push("peft");
    }
    if saved.batch_size != job.batch_size {
        diffs.push("batch_size");
    }
    if saved.grad_accum != job.grad_accum {
        diffs.push("grad_accum");
    }
    if saved.lr.to_bits() != job.lr.to_bits() {
        diffs.push("lr");
    }
    if saved.seed != job.seed {
        diffs.push("seed");
    }
    if saved.train_pool != job.train_pool {
        diffs.push("train_pool");
    }
    if saved.eval_samples != job.eval_samples {
        diffs.push("eval_samples");
    }
    if saved.max_len != job.max_len {
        diffs.push("max_len");
    }
    if !diffs.is_empty() {
        bail!(
            "checkpoint belongs to a different job (mismatched: {})",
            diffs.join(", ")
        );
    }
    Ok(())
}

/// One job's training run as an incremental state machine:
/// [`JobRun::start`] prepares (or resumes) it, each [`JobRun::step`] runs
/// exactly one optimizer step, and [`JobRun::finish`] evaluates and emits
/// the [`JobReport`] plus the trained adapter stack. [`run_job`] drives a
/// run to completion in one call; the [`Scheduler`] interleaves many.
///
/// The per-step body is *identical* no matter who drives it or how steps
/// are spread over time: the data cursor fully determines the batch
/// iterator's state, so re-seeking each step replays exactly the stream a
/// single long-lived iterator would produce. That structural sharing is
/// what makes interleaved scheduling bit-identical to sequential
/// execution.
pub struct JobRun {
    job: FinetuneJob,
    task: SynthTask,
    ds: Dataset,
    model: Model,
    trainer: Trainer,
    losses: Vec<f64>,
    cursor: usize,
    payload_bytes: usize,
    resumed_from: Option<u64>,
    timer: LatencyTimer,
}

impl JobRun {
    /// Prepare a run: sample the dataset, then either resume from the
    /// job's checkpoint (if one exists at its path) or prepare a fresh
    /// bundle from the server. A job naming an unknown dataset is a
    /// readable [`Err`], not a panic — bad task names come straight from
    /// CLI flags.
    pub fn start(server: &PreprocessServer, job: &FinetuneJob) -> Result<JobRun> {
        let task = SynthTask::by_name(&job.dataset).with_context(|| {
            format!(
                "unknown dataset '{}' (known: {})",
                job.dataset,
                INSTRUCTION_SETS
                    .iter()
                    .chain(&REASONING_SETS)
                    .chain(&LONGTEXT_SETS)
                    .copied()
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
        let mut rng = Rng::new(job.seed);
        let samples: Vec<Sample> = (0..job.train_pool + job.eval_samples)
            .map(|_| task.sample(&mut rng))
            .collect();
        let ds = Dataset::from_samples(&job.dataset, samples, &mut rng);
        // Resume from an existing checkpoint, or prepare a fresh bundle.
        let mut resumed_from = None;
        let (model, payload_bytes, trainer, losses, cursor) = match &job.checkpoint {
            Some(spec) if persist::checkpoint_exists(&spec.path) => {
                let loaded = persist::load_train_checkpoint(&spec.path)
                    .with_context(|| format!("resume job {}", job.id))?;
                validate_resume(&loaded.ckpt.job, job)?;
                let ck = loaded.ckpt;
                resumed_from = Some(ck.steps_done);
                (ck.model, ck.payload_bytes, ck.trainer, ck.losses, ck.cursor)
            }
            _ => {
                let bundle = server.prepare(job.method, job.peft);
                let payload = bundle.payload_bytes;
                (
                    bundle.model,
                    payload,
                    Trainer::new(job.lr, job.max_len, job.grad_accum),
                    Vec::new(),
                    0,
                )
            }
        };
        Ok(JobRun {
            job: job.clone(),
            task,
            ds,
            model,
            trainer,
            losses,
            cursor,
            payload_bytes,
            resumed_from,
            timer: LatencyTimer::new(),
        })
    }

    /// The job this run executes.
    pub fn job(&self) -> &FinetuneJob {
        &self.job
    }

    /// The job's id.
    pub fn id(&self) -> u64 {
        self.job.id
    }

    /// Optimizer steps completed so far (spans resumes).
    pub fn steps_done(&self) -> u64 {
        self.trainer.step_count
    }

    /// True once the job's target step count is reached (a resumed run
    /// can be done immediately; it then just re-evaluates and reports).
    pub fn is_done(&self) -> bool {
        self.trainer.step_count >= self.job.steps
    }

    /// Run exactly one optimizer step (`grad_accum` micro-batches), then
    /// write the job's periodic checkpoint if one is due.
    pub fn step(&mut self) -> Result<()> {
        let mut iter = self.ds.batches(self.job.batch_size);
        iter.seek(self.cursor);
        let mut micro = Vec::with_capacity(self.job.grad_accum);
        for _ in 0..self.job.grad_accum {
            micro.push(iter.next_batch());
        }
        self.cursor = iter.cursor();
        let stats = self.trainer.step(&mut self.model, &micro);
        self.timer.record(stats.seconds);
        self.losses.push(stats.loss);
        let due = match &self.job.checkpoint {
            Some(spec) => {
                spec.every > 0
                    && (self.trainer.step_count % spec.every == 0
                        || self.trainer.step_count == self.job.steps)
            }
            None => false,
        };
        if due {
            let path = self.job.checkpoint.as_ref().expect("due implies spec").path.clone();
            let step = self.trainer.step_count;
            self.checkpoint_to(&path)
                .with_context(|| format!("checkpoint job {} at step {}", self.job.id, step))?;
        }
        Ok(())
    }

    /// Write the full training state to `path` (crash-safe; same archive
    /// the periodic policy writes). This is also the scheduler's
    /// preemption primitive: a spilled run is exactly a checkpointed one.
    pub fn checkpoint_to(&mut self, path: &Path) -> Result<usize> {
        persist::save_train_checkpoint(
            path,
            &self.job,
            &mut self.model,
            &self.trainer,
            self.cursor,
            &self.losses,
            self.payload_bytes,
        )
    }

    /// Evaluate by task family and emit the report, handing back the
    /// trained adapter stack (detached from the model) so the caller can
    /// install it into a serving [`crate::infer::AdapterRegistry`].
    pub fn finish(mut self) -> Result<(JobReport, TenantAdapters)> {
        let final_loss = self.losses.last().copied().unwrap_or(f64::NAN);
        let job = &self.job;
        let test: Vec<Sample> = self.ds.test.iter().take(job.eval_samples).cloned().collect();
        let mut metrics = BTreeMap::new();
        let (_nll, ppl) = teval::eval_ppl(&mut self.model, &test, job.batch_size, job.max_len);
        metrics.insert("ppl".to_string(), ppl);
        match self.task.family {
            TaskFamily::Mcq => {
                metrics.insert(
                    "acc".to_string(),
                    teval::eval_mcq_accuracy(&mut self.model, &test, job.max_len),
                );
            }
            TaskFamily::Lambada => {
                metrics.insert(
                    "acc".to_string(),
                    teval::eval_token_accuracy(&mut self.model, &test, job.max_len),
                );
                metrics.insert(
                    "exact".to_string(),
                    teval::eval_exact_match(&mut self.model, &test, job.max_len),
                );
            }
            TaskFamily::Instruction | TaskFamily::LongForm => {
                metrics.insert(
                    "acc".to_string(),
                    teval::eval_token_accuracy(&mut self.model, &test, job.max_len),
                );
                let n_rouge = test.len().min(6);
                metrics.insert(
                    "rouge_l".to_string(),
                    teval::eval_rouge(&mut self.model, &test[..n_rouge], 48),
                );
            }
        }
        let memory =
            MemoryAccountant::account(&mut self.model, job.method, job.batch_size, job.max_len);
        let report = JobReport {
            id: job.id,
            dataset: job.dataset.clone(),
            method: job.method,
            peft: job.peft,
            steps: self.trainer.step_count,
            final_loss,
            losses: self.losses.clone(),
            resumed_from: self.resumed_from,
            metrics,
            mean_step_secs: self.timer.mean(),
            memory,
            payload_bytes: self.payload_bytes,
        };
        let adapters = self.model.detach_adapters();
        Ok((report, adapters))
    }
}

/// Execute one job against a prepared bundle (the worker body; exposed so
/// reports/benches can run cells synchronously without the queue). A job
/// naming an unknown dataset is a readable [`Err`], not a panic — bad task
/// names come straight from CLI flags.
///
/// When the job carries a [`CheckpointSpec`] and a checkpoint already
/// exists at its path, the run **resumes** from it — model, optimizer,
/// PRNG streams, data cursor and loss log all continue mid-stream, so the
/// completed run is bit-identical to one that was never interrupted.
pub fn run_job(server: &PreprocessServer, job: &FinetuneJob) -> Result<JobReport> {
    let mut run = JobRun::start(server, job)?;
    while !run.is_done() {
        run.step()?;
    }
    Ok(run.finish()?.0)
}

/// Scan `dir` for training checkpoints (`*.qckpt`) and return their
/// recorded job specs wired to resume in place — feeding the result to
/// [`Coordinator::run_all`] picks up every interrupted job where it left
/// off (jobs already at their target step count just re-evaluate and
/// report). Paths are scanned in sorted order for determinism.
pub fn resumable_jobs(dir: &Path) -> Result<Vec<FinetuneJob>> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| anyhow!("scan {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let path = entry.map_err(|e| anyhow!("scan {}: {e}", dir.display()))?.path();
        let is_ckpt = path
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.ends_with(".qckpt"));
        if is_ckpt {
            paths.push(path);
        }
    }
    paths.sort();
    let mut jobs = Vec::new();
    for path in paths {
        // skip other archive kinds that share the extension (e.g. a saved
        // DistributionBundle) — only corrupt/unreadable files are errors
        let is_ckpt = persist::is_train_checkpoint(&path)
            .with_context(|| format!("scan {}", path.display()))?;
        if !is_ckpt {
            continue;
        }
        let (mut job, _steps_done) =
            persist::peek_job(&path).with_context(|| format!("scan {}", path.display()))?;
        job.checkpoint = Some(CheckpointSpec { path, every: 1 });
        jobs.push(job);
    }
    Ok(jobs)
}

/// Scheduling policy for the interleaving [`Scheduler`].
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Most [`JobRun`]s held in memory at once; admitting beyond this
    /// preempts the least-recently-run resident to a checkpoint.
    pub max_resident: usize,
    /// Optimizer steps each job advances per round-robin visit.
    pub quantum: u64,
    /// Where to checkpoint a preempted job that has no [`CheckpointSpec`]
    /// of its own (`<spill_dir>/job<id>.qckpt`). With `None`, preempting
    /// a spec-less job is a readable error.
    pub spill_dir: Option<PathBuf>,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            max_resident: 2,
            quantum: 1,
            spill_dir: None,
        }
    }
}

/// Where a submitted job currently lives in the scheduler.
enum SchedSlot {
    /// Submitted, never started.
    Pending(FinetuneJob),
    /// In memory, stepping.
    Resident(Box<JobRun>),
    /// Preempted to a checkpoint; the stored job's spec points at it.
    Spilled(FinetuneJob),
    /// Finished and reported.
    Done(Box<JobReport>),
    /// Transient marker while a slot changes state.
    Moving,
}

/// Round-robin interleaver over concurrent [`FinetuneJob`]s sharing one
/// [`PreprocessServer`] (and hence one `tensor::pool` thread team; each
/// resident run owns its private `Workspace` inside its model).
///
/// Each [`Scheduler::step_round`] visits every unfinished job in
/// submission order, makes it resident — preempting the least-recently-run
/// resident through the crash-safe checkpoint path when `max_resident`
/// would be exceeded — and advances it `quantum` optimizer steps.
/// Because [`JobRun`] re-derives its batch iterator from the persisted
/// cursor every step, and preemption/resume is exactly
/// save/load_train_checkpoint (bit-identical by `tests/persist_resume.rs`),
/// the interleaved execution produces **byte-identical checkpoints and
/// loss logs** to running the same jobs back-to-back
/// (`tests/tenant_parity.rs`).
///
/// Finished jobs hand their adapter stacks to
/// [`Scheduler::take_adapters`] for installation into a serving
/// [`crate::infer::AdapterRegistry`]; [`Scheduler::run_with`] yields to a
/// caller callback between rounds (train-while-serve: pump a
/// [`crate::infer::Server`] there).
pub struct Scheduler<'a> {
    server: &'a PreprocessServer,
    cfg: SchedulerConfig,
    slots: Vec<SchedSlot>,
    /// Resident slot indices, least-recently-run first (eviction order).
    lru: Vec<usize>,
    /// Adapter stacks of finished jobs, keyed by job id.
    adapters: BTreeMap<u64, TenantAdapters>,
    rounds: u64,
}

impl<'a> Scheduler<'a> {
    pub fn new(server: &'a PreprocessServer, cfg: SchedulerConfig) -> Scheduler<'a> {
        assert!(cfg.max_resident >= 1, "scheduler needs at least one resident slot");
        assert!(cfg.quantum >= 1, "scheduler quantum must be at least one step");
        Scheduler {
            server,
            cfg,
            slots: Vec::new(),
            lru: Vec::new(),
            adapters: BTreeMap::new(),
            rounds: 0,
        }
    }

    /// Enqueue a job; it first runs during the next round. Returns its
    /// slot index (submission order, which [`Scheduler::reports`] keeps).
    pub fn submit(&mut self, job: FinetuneJob) -> usize {
        self.slots.push(SchedSlot::Pending(job));
        self.slots.len() - 1
    }

    /// Rounds completed so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// One round-robin pass: every unfinished job becomes resident and
    /// advances up to `quantum` steps; jobs reaching their target are
    /// finished (evaluated, reported, adapters banked). Returns `true`
    /// while any job is unfinished.
    pub fn step_round(&mut self) -> Result<bool> {
        self.rounds += 1;
        let mut any_open = false;
        for i in 0..self.slots.len() {
            if matches!(self.slots[i], SchedSlot::Done(_)) {
                continue;
            }
            self.make_resident(i)?;
            let done = {
                let run = match &mut self.slots[i] {
                    SchedSlot::Resident(r) => r,
                    _ => unreachable!("make_resident leaves the slot resident"),
                };
                let mut q = 0;
                while q < self.cfg.quantum && !run.is_done() {
                    run.step()?;
                    q += 1;
                }
                run.is_done()
            };
            if done {
                self.lru.retain(|&j| j != i);
                let run = match std::mem::replace(&mut self.slots[i], SchedSlot::Moving) {
                    SchedSlot::Resident(r) => *r,
                    _ => unreachable!("checked resident above"),
                };
                let (report, adapters) = run.finish()?;
                self.adapters.insert(report.id, adapters);
                self.slots[i] = SchedSlot::Done(Box::new(report));
            } else {
                // most-recently-run goes to the back of the eviction order
                self.lru.retain(|&j| j != i);
                self.lru.push(i);
                any_open = true;
            }
        }
        Ok(any_open)
    }

    /// Ensure slot `i` holds a resident run, evicting least-recently-run
    /// residents through [`Scheduler::spill`] to respect `max_resident`.
    fn make_resident(&mut self, i: usize) -> Result<()> {
        if matches!(self.slots[i], SchedSlot::Resident(_)) {
            return Ok(());
        }
        while self.lru.len() >= self.cfg.max_resident {
            let victim = self.lru.remove(0);
            self.spill(victim)?;
        }
        let job = match std::mem::replace(&mut self.slots[i], SchedSlot::Moving) {
            SchedSlot::Pending(j) | SchedSlot::Spilled(j) => j,
            _ => unreachable!("resident and done slots never reach here"),
        };
        let run = JobRun::start(self.server, &job)
            .with_context(|| format!("admit job {}", job.id))?;
        self.slots[i] = SchedSlot::Resident(Box::new(run));
        self.lru.push(i);
        Ok(())
    }

    /// Preempt resident slot `i`: checkpoint its full training state (to
    /// the job's own spec path, or `spill_dir/job<id>.qckpt` for
    /// spec-less jobs) and drop the in-memory run. Resume is
    /// [`JobRun::start`]'s ordinary checkpoint path — bit-identical.
    fn spill(&mut self, i: usize) -> Result<()> {
        let run = match &self.slots[i] {
            SchedSlot::Resident(r) => r,
            _ => return Ok(()),
        };
        let (path, every) = match (&run.job().checkpoint, &self.cfg.spill_dir) {
            (Some(spec), _) => (spec.path.clone(), spec.every),
            (None, Some(dir)) => (dir.join(format!("job{}.qckpt", run.id())), 0),
            (None, None) => bail!(
                "cannot preempt job {}: it has no CheckpointSpec and the scheduler \
                 has no spill_dir",
                run.id()
            ),
        };
        let mut run = match std::mem::replace(&mut self.slots[i], SchedSlot::Moving) {
            SchedSlot::Resident(r) => r,
            _ => unreachable!("checked resident above"),
        };
        run.checkpoint_to(&path)
            .with_context(|| format!("spill job {} at step {}", run.id(), run.steps_done()))?;
        let mut job = run.job().clone();
        job.checkpoint = Some(CheckpointSpec { path, every });
        self.slots[i] = SchedSlot::Spilled(job);
        Ok(())
    }

    /// Run every submitted job to completion; reports in submission order.
    pub fn run(&mut self) -> Result<Vec<JobReport>> {
        self.run_with(|_| {})
    }

    /// [`Scheduler::run`], yielding to `on_round(rounds_so_far)` after
    /// every round — the train-while-serve hook: pump a serving
    /// [`crate::infer::Server`] there and install finished jobs' adapters
    /// as they appear.
    pub fn run_with(&mut self, mut on_round: impl FnMut(u64)) -> Result<Vec<JobReport>> {
        loop {
            let more = self.step_round()?;
            on_round(self.rounds);
            if !more {
                break;
            }
        }
        Ok(self.reports())
    }

    /// Reports of finished jobs, in submission order.
    pub fn reports(&self) -> Vec<JobReport> {
        self.slots
            .iter()
            .filter_map(|s| match s {
                SchedSlot::Done(r) => Some((**r).clone()),
                _ => None,
            })
            .collect()
    }

    /// Take the trained adapter stack of finished job `job_id` (once) —
    /// ready to install into an [`crate::infer::AdapterRegistry`].
    pub fn take_adapters(&mut self, job_id: u64) -> Option<TenantAdapters> {
        self.adapters.remove(&job_id)
    }
}

enum Msg {
    Submit(FinetuneJob, mpsc::Sender<Result<JobReport>>),
    Shutdown,
}

/// The coordinator service: a job queue drained by worker threads, each
/// holding a reference to the shared preprocessing server.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
    submitted: u64,
}

impl Coordinator {
    pub fn new(server_cfg: ServerConfig, n_workers: usize) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let server = Arc::new(PreprocessServer::new(server_cfg));
        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let rx = Arc::clone(&rx);
            let server = Arc::clone(&server);
            workers.push(thread::spawn(move || loop {
                let msg = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match msg {
                    Ok(Msg::Submit(job, reply)) => {
                        let report = run_job(&server, &job);
                        let _ = reply.send(report);
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                }
            }));
        }
        Coordinator {
            tx,
            workers,
            submitted: 0,
        }
    }

    /// Submit a job; returns a receiver for its (fallible) report.
    pub fn submit(&mut self, job: FinetuneJob) -> mpsc::Receiver<Result<JobReport>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submitted += 1;
        self.tx
            .send(Msg::Submit(job, reply_tx))
            .expect("coordinator workers gone");
        reply_rx
    }

    /// Submit a batch and wait for all reports (returned in submit order);
    /// the first failing job (e.g. an unknown dataset name) surfaces as a
    /// readable error.
    pub fn run_all(&mut self, jobs: Vec<FinetuneJob>) -> Result<Vec<JobReport>> {
        let receivers: Vec<_> = jobs.into_iter().map(|j| self.submit(j)).collect();
        receivers
            .into_iter()
            .map(|rx| match rx.recv() {
                Ok(report) => report,
                Err(_) => Err(anyhow!("coordinator worker dropped its reply")),
            })
            .collect()
    }

    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Graceful shutdown.
    pub fn shutdown(mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_server_cfg() -> ServerConfig {
        let mut cfg = ServerConfig::default();
        cfg.preset = "opt-tiny".to_string();
        cfg.calib_samples = 8;
        cfg.calib_batch = 4;
        cfg
    }

    fn tiny_job(id: u64, method: MethodKind) -> FinetuneJob {
        let mut j = FinetuneJob::new(id, "gpqa", method, PeftKind::Lora);
        j.steps = 2;
        j.batch_size = 2;
        j.train_pool = 8;
        j.eval_samples = 4;
        j.max_len = 128;
        j
    }

    #[test]
    fn unknown_dataset_is_a_readable_error_not_a_panic() {
        let server = PreprocessServer::new(tiny_server_cfg());
        let mut job = tiny_job(1, MethodKind::Naive);
        job.dataset = "definitely-not-a-task".to_string();
        let err = run_job(&server, &job).unwrap_err().to_string();
        assert!(err.contains("unknown dataset 'definitely-not-a-task'"), "{err}");
        assert!(err.contains("gpqa"), "should list known tasks: {err}");
        // ...and through the queue as well
        let mut coord = Coordinator::new(tiny_server_cfg(), 1);
        let err = coord.run_all(vec![job]).unwrap_err().to_string();
        assert!(err.contains("unknown dataset"), "{err}");
        coord.shutdown();
    }

    #[test]
    fn run_job_produces_complete_report() {
        let server = PreprocessServer::new(tiny_server_cfg());
        let report = run_job(&server, &tiny_job(1, MethodKind::Quaff)).expect("known dataset");
        assert_eq!(report.id, 1);
        assert_eq!(report.steps, 2);
        assert!(report.final_loss.is_finite());
        assert!(report.metric("ppl") > 1.0);
        assert!((0.0..=1.0).contains(&report.metric("acc")));
        assert!(report.mean_step_secs > 0.0);
        assert!(report.memory.total() > 0);
    }

    #[test]
    fn coordinator_returns_reports_in_submit_order() {
        let mut coord = Coordinator::new(tiny_server_cfg(), 1);
        let jobs = vec![
            tiny_job(10, MethodKind::Naive),
            tiny_job(11, MethodKind::Quaff),
            tiny_job(12, MethodKind::Fp32),
        ];
        let reports = coord.run_all(jobs).expect("known datasets");
        assert_eq!(
            reports.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![10, 11, 12]
        );
        assert_eq!(coord.submitted(), 3);
        coord.shutdown();
    }

    #[test]
    fn interrupted_jobs_are_scanned_and_picked_up_by_run_all() {
        let dir = std::env::temp_dir().join(format!("quaff_coord_resume_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let server = PreprocessServer::new(tiny_server_cfg());
        // "interrupt" a job by running only 1 of its 2 steps, checkpointing
        let path = dir.join("job7.qckpt");
        let mut j = tiny_job(7, MethodKind::Quaff);
        j.steps = 1;
        j.checkpoint = Some(CheckpointSpec { path: path.clone(), every: 1 });
        let partial = run_job(&server, &j).unwrap();
        assert_eq!(partial.steps, 1);
        assert!(partial.resumed_from.is_none());
        // a saved bundle sharing the extension must be skipped, not fatal
        let mut bundle = server.prepare(MethodKind::Naive, PeftKind::Lora);
        bundle.save(&dir.join("bundle.qckpt")).unwrap();
        // the scanner finds the interrupted job with its recorded spec
        let jobs = resumable_jobs(&dir).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].id, 7);
        assert_eq!(jobs[0].dataset, "gpqa");
        // extend to the full length and let the queue pick it up
        let mut resumed = jobs;
        resumed[0].steps = 2;
        let mut coord = Coordinator::new(tiny_server_cfg(), 1);
        let reports = coord.run_all(resumed).unwrap();
        assert_eq!(reports[0].resumed_from, Some(1));
        assert_eq!(reports[0].steps, 2);
        assert_eq!(reports[0].losses.len(), 2);
        assert_eq!(reports[0].losses[0], partial.losses[0], "loss log must continue");
        coord.shutdown();
        // a mismatched job spec is rejected readably
        let mut wrong = tiny_job(8, MethodKind::Naive);
        wrong.steps = 2;
        wrong.checkpoint = Some(CheckpointSpec { path, every: 1 });
        let err = run_job(&server, &wrong).unwrap_err().to_string();
        assert!(err.contains("different job"), "{err}");
        assert!(err.contains("method"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_report_orders_methods_correctly() {
        let server = PreprocessServer::new(tiny_server_cfg());
        let fp32 = run_job(&server, &tiny_job(1, MethodKind::Fp32)).unwrap();
        let quaff = run_job(&server, &tiny_job(2, MethodKind::Quaff)).unwrap();
        let smooth_d = run_job(&server, &tiny_job(3, MethodKind::SmoothDynamic)).unwrap();
        assert!(quaff.memory.total() < fp32.memory.total());
        assert!(smooth_d.memory.total() >= fp32.memory.total());
    }
}
