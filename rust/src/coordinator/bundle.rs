//! The server side of the paper's deployment paradigm (§3.3):
//! "public servers preprocess and distribute quantized model weights
//! `W_int` and outlier weights `W_O`, while clients perform personalized
//! quantized fine-tuning without needing full-precision weights."
//!
//! [`PreprocessServer`] owns the full-precision base checkpoint (here:
//! deterministic from a seed), runs calibration on a public corpus,
//! identifies outlier channels under the non-uniform budget, quantizes,
//! and hands clients a [`DistributionBundle`] — a ready-to-fine-tune model
//! whose linear layers hold only the quantized representation.

use crate::data::{calibration_batches, SynthTask};
use crate::methods::{MethodConfig, MethodKind};
use crate::model::{Model, ModelConfig};
use crate::outlier::{BudgetAllocator, BudgetPolicy, OutlierDetector, OutlierRegistry};
use crate::peft::PeftKind;
use crate::persist;
use crate::util::error::Result;
use crate::util::prng::Rng;
use std::path::Path;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Base-model preset name.
    pub preset: String,
    /// Base checkpoint seed (stands in for the pretrained weights).
    pub base_seed: u64,
    /// Calibration corpus (paper: OIG/Chip2) and sample count (paper: 512).
    pub calib_task: String,
    pub calib_samples: usize,
    pub calib_batch: usize,
    pub budget: BudgetPolicy,
    pub detector_tau: f32,
    pub method_cfg: MethodConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            preset: "phi-mini".to_string(),
            base_seed: 0xBA5E,
            calib_task: "oig-chip2".to_string(),
            calib_samples: 64,
            calib_batch: 8,
            budget: BudgetPolicy::PaperNonUniform,
            detector_tau: 20.0,
            method_cfg: MethodConfig::default(),
        }
    }
}

/// What the server distributes: a quantized, adapter-ready model plus the
/// outlier registry and provenance metadata.
pub struct DistributionBundle {
    pub model: Model,
    pub registry: OutlierRegistry,
    pub method: MethodKind,
    pub preset: String,
    /// Bytes a client must download (quantized weights + common fp32 parts).
    pub payload_bytes: usize,
    /// Outlier overhead fraction actually achieved (≤5 % check).
    pub outlier_overhead: f64,
}

impl DistributionBundle {
    /// Persist the bundle crash-safely (see [`crate::persist`]): the int8
    /// stores, per-channel scales, Quaff momentum state, adapters, and the
    /// outlier registry all round-trip disk **without ever materializing
    /// f32 base weights**. Returns the archive size in bytes.
    pub fn save(&mut self, path: &Path) -> Result<usize> {
        persist::save_bundle(path, self)
    }

    /// Load a bundle saved by [`DistributionBundle::save`]. The restored
    /// model is bit-identical in every forward — fine-tuning can continue
    /// on it, and an [`infer::BatchEngine`](crate::infer::BatchEngine) can
    /// serve from it directly (`tests/persist_resume.rs` pins both).
    pub fn load(path: &Path) -> Result<DistributionBundle> {
        persist::load_bundle(path)
    }
}

/// The preprocessing server.
pub struct PreprocessServer {
    pub cfg: ServerConfig,
}

impl PreprocessServer {
    pub fn new(cfg: ServerConfig) -> PreprocessServer {
        PreprocessServer { cfg }
    }

    /// Build the base FP32 model (the "pretrained checkpoint").
    fn base_model(&self) -> Model {
        let mc =
            ModelConfig::preset(&self.cfg.preset).unwrap_or_else(|| {
                panic!("unknown preset {}", self.cfg.preset)
            });
        Model::new(mc, self.cfg.base_seed)
    }

    /// Calibrate + quantize a fresh bundle for `method`, with `peft`
    /// adapters attached (clients receive a ready-to-train package).
    pub fn prepare(&self, method: MethodKind, peft: PeftKind) -> DistributionBundle {
        let mut model = self.base_model();
        // 1. calibration pass on the public corpus
        let task = SynthTask::by_name(&self.cfg.calib_task)
            .unwrap_or_else(|| panic!("unknown calibration task {}", self.cfg.calib_task));
        let mut rng = Rng::new(self.cfg.base_seed ^ 0xCA11B);
        let max_len = model.cfg.max_seq - model.cfg.n_virtual;
        let batches = calibration_batches(
            &task,
            self.cfg.calib_samples,
            self.cfg.calib_batch,
            max_len,
            &mut rng,
        );
        model.start_calibration();
        for batch in &batches {
            let _ = model.forward(batch, false);
        }
        let calib = model.finish_calibration();
        // 2. outlier identification + quantization
        let allocator = BudgetAllocator::new(self.cfg.budget);
        let detector = OutlierDetector::new(self.cfg.detector_tau);
        let registry =
            model.apply_method(method, &calib, &allocator, &self.cfg.method_cfg, &detector);
        // 3. adapters
        model.attach_peft(peft);
        let total_cin: usize = model.layer_shapes().iter().map(|&(_, c)| c).sum();
        let overhead = registry.overhead_fraction(total_cin);
        let payload = model.frozen_linear_bytes();
        DistributionBundle {
            model,
            registry,
            method,
            preset: self.cfg.preset.clone(),
            payload_bytes: payload,
            outlier_overhead: overhead,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_server() -> PreprocessServer {
        let mut cfg = ServerConfig::default();
        cfg.preset = "opt-tiny".to_string();
        cfg.calib_samples = 16;
        cfg.calib_batch = 4;
        PreprocessServer::new(cfg)
    }

    #[test]
    fn bundle_has_quantized_layers_and_adapters() {
        let server = small_server();
        let mut bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
        assert_eq!(bundle.method, MethodKind::Quaff);
        for b in &mut bundle.model.blocks {
            for l in b.linears() {
                assert!(l.is_quantized());
                assert_eq!(l.method_name(), "Quaff");
            }
        }
        assert!(bundle.model.trainable_params() > 0);
        assert!(bundle.payload_bytes > 0);
    }

    #[test]
    fn outlier_overhead_within_budget_envelope() {
        let server = small_server();
        let bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
        // ≤ 5% paper envelope, with slack for min-1-channel rounding on
        // tiny layers
        assert!(
            bundle.outlier_overhead < 0.08,
            "overhead {}",
            bundle.outlier_overhead
        );
    }

    #[test]
    fn bundles_are_deterministic_per_seed() {
        let server = small_server();
        let a = server.prepare(MethodKind::Quaff, PeftKind::Lora);
        let b = server.prepare(MethodKind::Quaff, PeftKind::Lora);
        // same registry, same payload
        assert_eq!(a.payload_bytes, b.payload_bytes);
        let ra: Vec<_> = a.registry.layers().collect();
        let rb: Vec<_> = b.registry.layers().collect();
        assert_eq!(ra, rb);
    }

    #[test]
    fn bundle_roundtrips_disk_without_f32_weights_and_forwards_identically() {
        let dir = std::env::temp_dir().join(format!("quaff_bundle_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("quaff.qckpt");
        let server = small_server();
        let mut bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
        let bytes = bundle.save(&path).unwrap();
        assert!(bytes > 0);
        let mut loaded = DistributionBundle::load(&path).unwrap();
        assert_eq!(loaded.preset, bundle.preset);
        assert_eq!(loaded.method, MethodKind::Quaff);
        assert_eq!(loaded.payload_bytes, bundle.payload_bytes);
        assert_eq!(
            bundle.registry.layers().collect::<Vec<_>>(),
            loaded.registry.layers().collect::<Vec<_>>()
        );
        // every linear comes back quantized — no f32 master anywhere
        for b in &mut loaded.model.blocks {
            for l in b.linears() {
                assert!(l.is_quantized());
                assert!(l.master().is_none());
                assert_eq!(l.method_name(), "Quaff");
            }
        }
        // and the forward pass is bit-identical to the never-persisted model
        let toks = vec![vec![1u32, 2, 3, 4, 5, 6]];
        let (la, _) = bundle.model.forward(&toks, false);
        let (lb, _) = loaded.model.forward(&toks, false);
        assert_eq!(la.data(), lb.data());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quantized_bundle_smaller_than_fp32() {
        let server = small_server();
        let q = server.prepare(MethodKind::Quaff, PeftKind::Lora);
        let f = server.prepare(MethodKind::Fp32, PeftKind::Lora);
        assert!(
            q.payload_bytes < f.payload_bytes / 2,
            "quantized payload {} vs fp32 {}",
            q.payload_bytes,
            f.payload_bytes
        );
    }
}
