//! Adapter-only checkpointing: clients persist just their PEFT state (the
//! point of the server–client split — base weights never leave the bundle).
//!
//! Format: a tiny self-describing binary — magic, count, then per-param
//! (name-len, name, rows, cols, f32 data). No serde in the vendor set.
//!
//! This is the lightweight *export* format for handing adapters around.
//! For crash-safe **full-state** checkpoint/resume (int8 base weights,
//! Quaff momentum, Adam moments, PRNG streams, loss log — bit-identical
//! resume) use [`crate::persist`] via [`CheckpointSpec`](super::CheckpointSpec)
//! on a job, and [`DistributionBundle::save`](super::DistributionBundle::save)
//! for whole quantized bundles.

use crate::model::Model;
use crate::util::error::Result;
use crate::{anyhow, bail};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"QUAFFCK1";

/// Serialize all trainable parameters of `model` to `path`.
pub fn save_adapters(model: &mut Model, path: &Path) -> Result<usize> {
    let mut entries: Vec<(String, usize, usize, Vec<f32>)> = Vec::new();
    model.visit_params(&mut |name, p| {
        entries.push((
            name.to_string(),
            p.value.rows(),
            p.value.cols(),
            p.value.data().to_vec(),
        ));
    });
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(entries.len() as u32).to_le_bytes())?;
    let mut total = 0usize;
    for (name, rows, cols, data) in &entries {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(*rows as u32).to_le_bytes())?;
        f.write_all(&(*cols as u32).to_le_bytes())?;
        for v in data {
            f.write_all(&v.to_le_bytes())?;
        }
        total += data.len();
    }
    Ok(total)
}

/// Load adapter parameters into `model`. Every parameter in the checkpoint
/// must exist in the model with a matching shape; model params missing from
/// the file are left untouched.
pub fn load_adapters(model: &mut Model, path: &Path) -> Result<usize> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a quaff checkpoint: bad magic");
    }
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    let mut loaded: std::collections::BTreeMap<String, (usize, usize, Vec<f32>)> =
        std::collections::BTreeMap::new();
    for _ in 0..count {
        f.read_exact(&mut u32buf)?;
        let name_len = u32::from_le_bytes(u32buf) as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|_| anyhow!("bad param name"))?;
        f.read_exact(&mut u32buf)?;
        let rows = u32::from_le_bytes(u32buf) as usize;
        f.read_exact(&mut u32buf)?;
        let cols = u32::from_le_bytes(u32buf) as usize;
        let mut data = vec![0f32; rows * cols];
        let mut fbuf = [0u8; 4];
        for v in data.iter_mut() {
            f.read_exact(&mut fbuf)?;
            *v = f32::from_le_bytes(fbuf);
        }
        loaded.insert(name, (rows, cols, data));
    }
    let mut applied = 0usize;
    let mut err: Option<String> = None;
    model.visit_params(&mut |name, p| {
        if let Some((rows, cols, data)) = loaded.remove(name) {
            if (rows, cols) != (p.value.rows(), p.value.cols()) {
                err = Some(format!(
                    "shape mismatch for {name}: file ({rows},{cols}) vs model ({},{})",
                    p.value.rows(),
                    p.value.cols()
                ));
                return;
            }
            p.value.data_mut().copy_from_slice(&data);
            applied += data.len();
        }
    });
    if let Some(e) = err {
        bail!("{e}");
    }
    if !loaded.is_empty() {
        bail!(
            "checkpoint params not present in model: {:?}",
            loaded.keys().collect::<Vec<_>>()
        );
    }
    Ok(applied)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::peft::PeftKind;

    fn model(peft: PeftKind) -> Model {
        let mut cfg = ModelConfig::preset("opt-tiny").unwrap();
        cfg.n_layers = 2;
        let mut m = Model::new(cfg, 5);
        m.attach_peft(peft);
        m
    }

    #[test]
    fn roundtrip_preserves_values() {
        let dir = std::env::temp_dir().join("quaff_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let mut m = model(PeftKind::Lora);
        // perturb params so they're nontrivial
        m.visit_params(&mut |_, p| {
            for (i, v) in p.value.data_mut().iter_mut().enumerate() {
                *v = (i % 7) as f32 * 0.1 - 0.3;
            }
        });
        let saved = save_adapters(&mut m, &path).unwrap();
        assert!(saved > 0);
        let mut m2 = model(PeftKind::Lora);
        let loaded = load_adapters(&mut m2, &path).unwrap();
        assert_eq!(saved, loaded);
        let mut ok = true;
        let mut vals = Vec::new();
        m.visit_params(&mut |_, p| vals.push(p.value.clone()));
        let mut i = 0;
        m2.visit_params(&mut |_, p| {
            if p.value.data() != vals[i].data() {
                ok = false;
            }
            i += 1;
        });
        assert!(ok);
    }

    #[test]
    fn rejects_peft_mismatch() {
        let dir = std::env::temp_dir().join("quaff_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        let mut m = model(PeftKind::Lora);
        save_adapters(&mut m, &path).unwrap();
        let mut other = model(PeftKind::Ia3);
        assert!(load_adapters(&mut other, &path).is_err());
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("quaff_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let mut m = model(PeftKind::Lora);
        assert!(load_adapters(&mut m, &path).is_err());
    }
}
