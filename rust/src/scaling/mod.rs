//! Channel-wise scaling machinery.
//!
//! * [`MomentumScaler`] — Quaff's targeted momentum scaling (Eqs. 7–8):
//!   `s_t = γ·s_{t−1} + (1−γ)·β`, with `β_i = max(1, sqrt(max|X_:,i| /
//!   max|W_i|))` on outlier channels and `β_i = 1` elsewhere.
//! * [`smoothquant_factors`] — SmoothQuant's α-balanced factors
//!   `s_i = max|X_i|^α / max|W_i|^{1−α}` used by the Smooth_S / Smooth_D
//!   baselines (Eq. 3).
//! * Decomposition helpers for Eq. 4/5: building `ŵ = (s_O − 1)·W_O` and
//!   applying `X̂ = X·s^{-1}` only on outlier columns.

use crate::outlier::OutlierSet;
use crate::tensor::Matrix;

/// Quaff's momentum scaling state for one linear layer (Eqs. 7–8).
#[derive(Clone, Debug)]
pub struct MomentumScaler {
    /// Update inertia γ ∈ [0,1] (paper uses γ = 0.2).
    pub gamma: f32,
    /// Outlier channel set O.
    pub outliers: OutlierSet,
    /// Current factors s_t over outlier channels only (aligned with
    /// `outliers.channels`). Non-outlier channels implicitly have s = 1.
    s: Vec<f32>,
    /// Momentum disabled ⇒ s_t = β_t (the "Quaff w/o Mo" ablation, Table 3).
    pub momentum_enabled: bool,
}

impl MomentumScaler {
    pub fn new(gamma: f32, outliers: OutlierSet) -> Self {
        assert!((0.0..=1.0).contains(&gamma), "gamma must be in [0,1]");
        let n = outliers.len();
        MomentumScaler {
            gamma,
            outliers,
            s: vec![1.0; n],
            momentum_enabled: true,
        }
    }

    pub fn without_momentum(gamma: f32, outliers: OutlierSet) -> Self {
        let mut m = Self::new(gamma, outliers);
        m.momentum_enabled = false;
        m
    }

    /// Rebuild a scaler at a previously captured state (persistence): the
    /// factors continue from exactly where the checkpointed run left them,
    /// so the next momentum update is bit-identical to the uninterrupted
    /// run's.
    pub fn from_parts(
        gamma: f32,
        outliers: OutlierSet,
        s: Vec<f32>,
        momentum_enabled: bool,
    ) -> Self {
        assert!((0.0..=1.0).contains(&gamma), "gamma must be in [0,1]");
        assert_eq!(s.len(), outliers.len(), "factor count must match outlier set");
        MomentumScaler {
            gamma,
            outliers,
            s,
            momentum_enabled,
        }
    }

    /// Current factors over outlier channels (aligned with the set).
    pub fn factors(&self) -> &[f32] {
        &self.s
    }

    /// Compute β for the outlier channels from the current batch (Eq. 8)
    /// and fold into s_t (Eq. 7). `x_col_max[i]` is `max|X̂_:,i|` over the
    /// *unscaled* activations; `w_row_max[i]` is `max|W_i,:|` for the same
    /// absolute channel index.
    pub fn update(&mut self, x_col_max: &[f32], w_row_max: &[f32]) {
        for (k, &ch) in self.outliers.channels.iter().enumerate() {
            let xm = x_col_max[ch];
            let wm = w_row_max[ch].max(1e-12);
            let beta = (xm / wm).sqrt().max(1.0);
            self.s[k] = if self.momentum_enabled {
                self.gamma * self.s[k] + (1.0 - self.gamma) * beta
            } else {
                beta
            };
        }
    }

    /// Expand factors to the full channel axis (1.0 off-outliers) — used by
    /// the similarity tracker and tests.
    pub fn full_factors(&self, cin: usize) -> Vec<f32> {
        let mut out = vec![1.0f32; cin];
        for (k, &ch) in self.outliers.channels.iter().enumerate() {
            out[ch] = self.s[k];
        }
        out
    }
}

/// SmoothQuant factors over ALL channels:
/// `s_i = max|X_i|^α / max|W_i|^{1−α}`, clamped ≥ small-positive.
/// α = 0.5 is the SmoothQuant default the paper's baselines use.
pub fn smoothquant_factors(x_col_max: &[f32], w_row_max: &[f32], alpha: f32) -> Vec<f32> {
    assert_eq!(x_col_max.len(), w_row_max.len());
    x_col_max
        .iter()
        .zip(w_row_max)
        .map(|(&xm, &wm)| {
            let num = xm.max(1e-6).powf(alpha);
            let den = wm.max(1e-6).powf(1.0 - alpha);
            (num / den).max(1e-6)
        })
        .collect()
}

/// Build `ŵ = (s_O − 1) ∘ W_O` (Eq. 5): rows of `W` at outlier channels,
/// each row `k` scaled by `(s_O[k] − 1)`.
pub fn build_outlier_correction(w: &Matrix, outliers: &OutlierSet, s_o: &[f32]) -> Matrix {
    assert_eq!(outliers.len(), s_o.len());
    let mut w_hat = w.select_rows(&outliers.channels);
    for (k, &s) in s_o.iter().enumerate() {
        let factor = s - 1.0;
        for v in w_hat.row_mut(k) {
            *v *= factor;
        }
    }
    w_hat
}

/// Same as [`build_outlier_correction`] but starting from an already-sliced
/// `W_O` (|O| × c_out) — the representation Quaff actually stores.
pub fn build_outlier_correction_from_slice(w_o: &Matrix, s_o: &[f32]) -> Matrix {
    let mut w_hat = Matrix::zeros(w_o.rows(), w_o.cols());
    build_outlier_correction_from_slice_into(w_o, s_o, &mut w_hat);
    w_hat
}

/// [`build_outlier_correction_from_slice`] into a caller-provided matrix
/// (fully overwritten) — the per-step `ŵ` build on Quaff's hot path.
pub fn build_outlier_correction_from_slice_into(w_o: &Matrix, s_o: &[f32], out: &mut Matrix) {
    assert_eq!(w_o.rows(), s_o.len());
    assert_eq!((out.rows(), out.cols()), (w_o.rows(), w_o.cols()));
    for (k, &s) in s_o.iter().enumerate() {
        let factor = s - 1.0;
        for (o, &v) in out.row_mut(k).iter_mut().zip(w_o.row(k)) {
            *o = v * factor;
        }
    }
}

/// Apply `X̂ = X·s^{-1}` **only on outlier columns** (targeted scaling):
/// divides column `ch` by `s_O[k]` in place.
pub fn apply_targeted_inverse_scale(x: &mut Matrix, outliers: &OutlierSet, s_o: &[f32]) {
    assert_eq!(outliers.len(), s_o.len());
    for t in 0..x.rows() {
        let row = x.row_mut(t);
        for (k, &ch) in outliers.channels.iter().enumerate() {
            row[ch] /= s_o[k];
        }
    }
}

/// Apply full channel-wise inverse scaling `X̂ = X·s^{-1}` (SmoothQuant).
pub fn apply_full_inverse_scale(x: &mut Matrix, s: &[f32]) {
    assert_eq!(s.len(), x.cols());
    let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
    x.scale_cols(&inv);
}

/// Scale weight rows by `s` (`Ŵ = s·W`, SmoothQuant's weight side).
pub fn apply_row_scale(w: &mut Matrix, s: &[f32]) {
    assert_eq!(s.len(), w.rows());
    w.scale_rows(s);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn beta_floor_is_one() {
        // Channels where activations are smaller than weights must not be
        // scaled below 1 (Eq. 8's max(1, ·)).
        let o = OutlierSet::new(vec![0, 1]);
        let mut m = MomentumScaler::new(0.0, o); // γ=0 ⇒ s = β directly
        m.update(&[0.01, 4.0], &[1.0, 1.0]);
        assert_eq!(m.factors()[0], 1.0);
        assert!((m.factors()[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn momentum_blends_history() {
        let o = OutlierSet::new(vec![0]);
        let mut m = MomentumScaler::new(0.2, o);
        // β = sqrt(100/1) = 10; s1 = 0.2*1 + 0.8*10 = 8.2
        m.update(&[100.0], &[1.0]);
        assert!((m.factors()[0] - 8.2).abs() < 1e-5);
        // again: s2 = 0.2*8.2 + 0.8*10 = 9.64
        m.update(&[100.0], &[1.0]);
        assert!((m.factors()[0] - 9.64).abs() < 1e-5);
    }

    #[test]
    fn momentum_converges_to_beta_fixed_point() {
        // Property: with constant β the iteration converges to β for any γ<1.
        prop::check("momentum-fixpoint", 0xD1, 32, |r| {
            (r.range(0.0, 0.99), r.range(1.0, 50.0))
        }, |&(gamma, beta_sq)| {
            let o = OutlierSet::new(vec![0]);
            let mut m = MomentumScaler::new(gamma, o);
            for _ in 0..400 {
                m.update(&[beta_sq * beta_sq], &[1.0]);
            }
            prop::close(m.factors()[0], beta_sq, 1e-2, 1e-2)
        });
    }

    #[test]
    fn without_momentum_tracks_beta_instantly() {
        let o = OutlierSet::new(vec![0]);
        let mut m = MomentumScaler::without_momentum(0.2, o);
        m.update(&[100.0], &[1.0]);
        assert!((m.factors()[0] - 10.0).abs() < 1e-5);
        m.update(&[4.0], &[1.0]);
        assert!((m.factors()[0] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn full_factors_one_off_outliers() {
        let o = OutlierSet::new(vec![2, 5]);
        let mut m = MomentumScaler::new(0.0, o);
        m.update(&[0., 0., 9., 0., 0., 16.], &[1.; 6]);
        let f = m.full_factors(6);
        assert_eq!(f, vec![1.0, 1.0, 3.0, 1.0, 1.0, 4.0]);
    }

    #[test]
    fn smoothquant_alpha_half_balances() {
        let s = smoothquant_factors(&[16.0], &[4.0], 0.5);
        assert!((s[0] - 2.0).abs() < 1e-5); // sqrt(16)/sqrt(4)
    }

    #[test]
    fn decomposition_identity_exact_in_f32() {
        // Core algebraic invariant of Eq. 4/5 (before quantization):
        //   X̂·W + X̂_:,O·(s_O−1)·W_O == X·W  when X̂ = X with outlier columns
        //   divided by s, because dividing then multiplying back restores X
        //   exactly on outlier rows of W.
        prop::check("eq5-identity", 0xD2, 24, |r| {
            let t = 2 + r.below(10);
            let cin = 8 + r.below(32);
            let cout = 4 + r.below(24);
            let x = Matrix::randn(t, cin, r, 1.0);
            let w = Matrix::randn(cin, cout, r, 0.5);
            let k = 1 + r.below(4.min(cin - 1));
            let chans = r.sample_indices(cin, k);
            let s: Vec<f32> = (0..k).map(|_| r.range(1.0, 20.0)).collect();
            (x, w, OutlierSet::new(chans), s)
        }, |(x, w, o, s)| {
            let want = x.matmul(w);
            let mut x_hat = x.clone();
            apply_targeted_inverse_scale(&mut x_hat, o, s);
            let main = x_hat.matmul(w);
            let x_o = x_hat.select_cols(&o.channels);
            let w_hat = build_outlier_correction(w, o, s);
            let corr = x_o.matmul(&w_hat);
            let mut got = main;
            got.add_assign(&corr);
            prop::all_close(got.data(), want.data(), 1e-3, 1e-3)
        });
    }

    #[test]
    fn targeted_scale_only_touches_outlier_columns() {
        let mut r = Rng::new(99);
        let x = Matrix::randn(4, 8, &mut r, 1.0);
        let mut scaled = x.clone();
        let o = OutlierSet::new(vec![1, 6]);
        apply_targeted_inverse_scale(&mut scaled, &o, &[2.0, 4.0]);
        for t in 0..4 {
            for c in 0..8 {
                let expect = match c {
                    1 => x.get(t, c) / 2.0,
                    6 => x.get(t, c) / 4.0,
                    _ => x.get(t, c),
                };
                assert!((scaled.get(t, c) - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn smoothquant_identity_full_scaling() {
        // (X s^{-1})(s W) == X W in f32.
        let mut r = Rng::new(100);
        let x = Matrix::randn(5, 12, &mut r, 1.0);
        let w = Matrix::randn(12, 7, &mut r, 1.0);
        let s = smoothquant_factors(&x.col_abs_max(), &w.transpose().col_abs_max(), 0.5);
        // w_row_max: max |W_i,:| per input channel = per row of W
        let mut xh = x.clone();
        apply_full_inverse_scale(&mut xh, &s);
        let mut wh = w.clone();
        apply_row_scale(&mut wh, &s);
        let got = xh.matmul(&wh);
        let want = x.matmul(&w);
        prop::all_close(got.data(), want.data(), 1e-3, 1e-3).unwrap();
    }
}
