//! Minimal error plumbing — `anyhow` is not in the offline vendor set, so
//! this provides the small slice of its API the crate uses: a string-y
//! [`Error`], the [`anyhow!`](crate::anyhow)/[`bail!`](crate::bail) macros,
//! and a [`Context`] extension for `Result`/`Option`.

use std::fmt;

/// A message-carrying error. Context added via [`Context`] is prepended
/// `outer: inner` style, like anyhow's display chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug doubles as Display so `fn main() -> Result<()>` prints cleanly.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` for results and options.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (drop-in for `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn option_context() {
        let o: Option<u32> = None;
        let e = o.with_context(|| "missing thing").unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn macros_format() {
        let e = crate::anyhow!("bad {} at {}", "value", 3);
        assert_eq!(e.to_string(), "bad value at 3");
        fn f() -> Result<()> {
            crate::bail!("nope {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
