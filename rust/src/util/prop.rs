//! Mini property-testing harness.
//!
//! `proptest` is not available in the offline vendor set, so this module
//! provides the core loop we need: run a property over `N` randomized cases
//! drawn from a seeded [`Rng`](crate::util::prng::Rng); on failure report the
//! case index and seed so the exact case can be replayed deterministically.

use crate::util::prng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` over `cases` randomized inputs produced by `gen`.
///
/// Panics with the failing case index + seed on the first violation, so a
/// failure is reproducible by re-running with the same seed.
pub fn check<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        // Derive a per-case seed so any single case replays independently.
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {case_seed:#x}): \
                 {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Convenience: run with [`DEFAULT_CASES`].
pub fn check_default<T, G, P>(name: &str, seed: u64, gen: G, prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    check(name, seed, DEFAULT_CASES, gen, prop)
}

/// Assert two floats are close (absolute + relative tolerance).
pub fn close(a: f32, b: f32, atol: f32, rtol: f32) -> Result<(), String> {
    let diff = (a - b).abs();
    let bound = atol + rtol * b.abs().max(a.abs());
    if diff <= bound || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {diff} > {bound}"))
    }
}

/// Assert two slices are element-wise close.
pub fn all_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        close(x, y, atol, rtol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 1, 50, |r| r.uniform(), |_x| {
            Ok(())
        });
        // a second property that counts
        check("count", 1, 50, |r| r.uniform(), |_x| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 2, 10, |r| r.below(10), |_x| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-6, 0.0).is_ok());
        assert!(close(1.0, 1.1, 1e-6, 0.0).is_err());
        assert!(close(100.0, 100.5, 0.0, 0.01).is_ok());
    }

    #[test]
    fn all_close_reports_index() {
        let e = all_close(&[1.0, 2.0], &[1.0, 3.0], 1e-6, 0.0).unwrap_err();
        assert!(e.contains("index 1"), "{e}");
    }
}
