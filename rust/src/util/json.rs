//! Minimal JSON writer + reader.
//!
//! The offline build environment has no `serde`/`serde_json`, so this module
//! provides the small subset the project needs: emitting report/metric files
//! and parsing the artifact `manifest.json` written by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `BTreeMap` keeps object keys ordered for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Access an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Index into an array.
    pub fn at(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error message on malformed input.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "bad utf8")?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::str("quaff")),
            ("version", Json::num(1.0)),
            ("tags", Json::arr(vec![Json::str("int8"), Json::str("peft")])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true))])),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "artifacts": [
                {"name": "train_step", "path": "train_step.hlo.txt",
                 "inputs": [[8, 128], [8, 128]], "outputs": 3}
            ],
            "d_model": 256
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("d_model").unwrap().as_usize(), Some(256));
        let a = j.get("artifacts").unwrap().at(0).unwrap();
        assert_eq!(a.get("name").unwrap().as_str(), Some("train_step"));
        assert_eq!(
            a.get("inputs").unwrap().at(1).unwrap().at(1).unwrap().as_usize(),
            Some(128)
        );
    }

    #[test]
    fn escapes() {
        let j = Json::str("a\"b\\c\nd\te");
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn numbers() {
        for (txt, want) in [("0", 0.0), ("-1.5", -1.5), ("3e2", 300.0), ("2.5e-1", 0.25)] {
            assert_eq!(Json::parse(txt).unwrap().as_f64(), Some(want));
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse("\"héllo \\u00e9\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo é"));
    }
}
