//! Shared utilities: PRNG, JSON, the versioned binary codec behind the
//! persistence tier, CLI parsing, property-test harness, error plumbing,
//! timing.

pub mod cli;
pub mod codec;
pub mod error;
pub mod json;
pub mod prng;
pub mod prop;

use std::time::Instant;

/// Measure wall-clock time of `f`, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Simple streaming mean/std accumulator (Welford).
#[derive(Debug, Default, Clone)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Pearson correlation between two equal-length series.
/// Returns 0.0 for degenerate (constant) inputs.
pub fn pearson(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        let dx = x as f64 - ma;
        let dy = y as f64 - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    (cov / (va.sqrt() * vb.sqrt())) as f32
}

/// Format a byte count using binary units (the way the paper reports GB).
pub fn fmt_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = bytes as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes}B")
    } else {
        format!("{x:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_welford() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-6);
        let c = [-1.0f32, -2.0, -3.0, -4.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn pearson_degenerate() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(1536), "1.50KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
    }
}
