//! Small, fast, reproducible PRNG (xoshiro256**) used everywhere randomness
//! is needed: synthetic data generation, weight init, property tests.
//!
//! We deliberately avoid external crates (the build environment is offline);
//! xoshiro256** has excellent statistical quality for simulation workloads
//! and is trivially seedable for reproducibility.

/// xoshiro256** PRNG state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a PRNG from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Raw generator state, for persistence: a stream restored via
    /// [`Rng::from_state`] continues at exactly this position, which is what
    /// makes checkpoint-resumed runs bit-identical to uninterrupted ones.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at a previously captured stream position.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f32 {
        // 24 mantissa bits of a u64 -> [0,1)
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f32, sigma: f32) -> f32 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), in sorted order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // Floyd's algorithm for distinct sampling.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream_exactly() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let k = r.below(50);
            let idx = r.sample_indices(50, k);
            assert_eq!(idx.len(), k);
            for w in idx.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(idx.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn sample_indices_full_range() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(10, 10);
        assert_eq!(idx, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
