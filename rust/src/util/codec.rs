//! Zero-dependency versioned binary codec for on-disk artifacts
//! (checkpoints, distribution bundles).
//!
//! An archive is a flat list of named **sections**, each protected by its
//! own CRC-32, behind a magic/version header:
//!
//! ```text
//! magic   8 bytes  b"QUAFFAR1"
//! version u32 LE   format version (strict equality on read)
//! count   u32 LE   number of sections
//! section (repeated `count` times):
//!   name_len u32 LE, name bytes (UTF-8)
//!   payload_len u64 LE, payload bytes
//!   crc u32 LE       CRC-32 (IEEE) over name bytes ++ payload bytes
//! ```
//!
//! Every numeric value is little-endian; floats are stored as their raw IEEE
//! bits, so NaN payloads and signed infinities round-trip **bit-exactly** —
//! the property the persistence tier's bit-identical-resume invariant rests
//! on. Reads are total: truncation, trailing garbage, a wrong magic/version,
//! and any single bit flip (the CRC covers section names too) surface as a
//! readable [`Err`], never as a panic or as silently wrong data.
//! `util::prop` round-trip/corruption properties pin this (see the tests
//! below).
//!
//! ```
//! use quaff::util::codec::{Archive, SectionWriter, Writer};
//!
//! let mut w = Writer::new(3);
//! let mut s = SectionWriter::new();
//! s.put_f32s(&[1.0, f32::NAN, f32::NEG_INFINITY]);
//! w.section("scales", s);
//! let bytes = w.finish();
//!
//! let ar = Archive::from_bytes(&bytes).unwrap();
//! assert_eq!(ar.version(), 3);
//! let got = ar.section("scales").unwrap().get_f32s().unwrap();
//! assert_eq!(got[0].to_bits(), 1.0f32.to_bits());
//! assert!(got[1].is_nan());
//! ```

use crate::tensor::{I8Matrix, Matrix};
use crate::util::error::Result;
use crate::{anyhow, bail};

/// Archive magic: identifies the container, not the payload kind (archives
/// carry a `meta` section naming what they hold).
pub const MAGIC: [u8; 8] = *b"QUAFFAR1";

const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

fn crc_update(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state = CRC_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

/// CRC-32 (IEEE 802.3 polynomial) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    crc_update(0xFFFF_FFFF, bytes) ^ 0xFFFF_FFFF
}

fn crc32_named(name: &[u8], payload: &[u8]) -> u32 {
    crc_update(crc_update(0xFFFF_FFFF, name), payload) ^ 0xFFFF_FFFF
}

/// Append-only body of one section: a sequence of primitive puts whose
/// order the matching [`SectionReader`] gets must mirror.
#[derive(Debug, Default)]
pub struct SectionWriter {
    buf: Vec<u8>,
}

impl SectionWriter {
    pub fn new() -> SectionWriter {
        SectionWriter::default()
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Raw IEEE bits — NaN/±inf round-trip exactly.
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 slice (raw bits).
    pub fn put_f32s(&mut self, xs: &[f32]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed f64 slice (raw bits).
    pub fn put_f64s(&mut self, xs: &[f64]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed i8 slice.
    pub fn put_i8s(&mut self, xs: &[i8]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.buf.push(x as u8);
        }
    }

    /// Length-prefixed index slice (each as u64).
    pub fn put_usizes(&mut self, xs: &[usize]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.put_u64(x as u64);
        }
    }

    /// Shape-prefixed dense f32 matrix.
    pub fn put_matrix(&mut self, m: &Matrix) {
        self.put_u32(m.rows() as u32);
        self.put_u32(m.cols() as u32);
        for &x in m.data() {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Shape-prefixed dense i8 matrix.
    pub fn put_i8_matrix(&mut self, m: &I8Matrix) {
        self.put_u32(m.rows() as u32);
        self.put_u32(m.cols() as u32);
        for &x in m.data() {
            self.buf.push(x as u8);
        }
    }

    fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Archive builder: named sections are appended, then [`Writer::finish`]
/// serializes the header + CRC-protected section stream.
#[derive(Debug)]
pub struct Writer {
    version: u32,
    sections: Vec<(String, Vec<u8>)>,
}

impl Writer {
    pub fn new(version: u32) -> Writer {
        Writer {
            version,
            sections: Vec::new(),
        }
    }

    /// Append a named section (order is preserved; names should be unique —
    /// lookups return the first match).
    pub fn section(&mut self, name: &str, body: SectionWriter) {
        self.sections.push((name.to_string(), body.into_bytes()));
    }

    /// Serialize the archive.
    pub fn finish(self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, payload) in &self.sections {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(payload);
            out.extend_from_slice(&crc32_named(name.as_bytes(), payload).to_le_bytes());
        }
        out
    }
}

/// A parsed archive: header validated, every section CRC-checked, no
/// trailing bytes.
#[derive(Debug)]
pub struct Archive {
    version: u32,
    sections: Vec<(String, Vec<u8>)>,
}

impl Archive {
    /// Parse and validate. Any defect — short buffer, wrong magic, section
    /// running past the end, CRC mismatch, trailing garbage — is an error.
    pub fn from_bytes(bytes: &[u8]) -> Result<Archive> {
        fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
            if n > bytes.len() - *pos {
                bail!(
                    "truncated archive: wanted {} bytes at offset {}, have {}",
                    n,
                    *pos,
                    bytes.len() - *pos
                );
            }
            let s = &bytes[*pos..*pos + n];
            *pos += n;
            Ok(s)
        }
        let mut pos = 0usize;
        let magic = take(bytes, &mut pos, 8)?;
        if magic != MAGIC.as_slice() {
            bail!("not a quaff archive: bad magic");
        }
        let version = u32::from_le_bytes(take(bytes, &mut pos, 4)?.try_into().unwrap());
        let count = u32::from_le_bytes(take(bytes, &mut pos, 4)?.try_into().unwrap()) as usize;
        let mut sections = Vec::new();
        for i in 0..count {
            let name_len =
                u32::from_le_bytes(take(bytes, &mut pos, 4)?.try_into().unwrap()) as usize;
            let name_bytes = take(bytes, &mut pos, name_len)?.to_vec();
            let name = String::from_utf8(name_bytes)
                .map_err(|_| anyhow!("section {i}: name is not UTF-8"))?;
            let payload_len =
                u64::from_le_bytes(take(bytes, &mut pos, 8)?.try_into().unwrap()) as usize;
            let payload = take(bytes, &mut pos, payload_len)?.to_vec();
            let crc = u32::from_le_bytes(take(bytes, &mut pos, 4)?.try_into().unwrap());
            let want = crc32_named(name.as_bytes(), &payload);
            if crc != want {
                bail!("section '{name}': CRC mismatch (stored {crc:#010x}, computed {want:#010x})");
            }
            sections.push((name, payload));
        }
        if pos != bytes.len() {
            bail!("trailing garbage: {} bytes past the last section", bytes.len() - pos);
        }
        Ok(Archive { version, sections })
    }

    pub fn version(&self) -> u32 {
        self.version
    }

    /// Raw payload of a section, if present.
    pub fn section_bytes(&self, name: &str) -> Option<&[u8]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, b)| b.as_slice())
    }

    /// Cursor over a section's payload.
    pub fn section(&self, name: &str) -> Result<SectionReader<'_>> {
        let bytes = self
            .section_bytes(name)
            .ok_or_else(|| anyhow!("archive has no section '{name}'"))?;
        Ok(SectionReader { buf: bytes, pos: 0 })
    }

    /// All sections in file order as (name, payload) pairs.
    pub fn sections(&self) -> impl Iterator<Item = (&str, &[u8])> {
        self.sections.iter().map(|(n, b)| (n.as_str(), b.as_slice()))
    }
}

/// Sequential reader over one section's payload; every `get` checks bounds
/// and returns a readable error on shortfall.
#[derive(Debug)]
pub struct SectionReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SectionReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.buf.len() - self.pos {
            bail!(
                "truncated section: wanted {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Unread bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        Ok(self.get_u8()? != 0)
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        Ok(self.get_u64()? as usize)
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| anyhow!("string is not UTF-8"))
    }

    pub fn get_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.get_u64()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("f32 slice length overflow"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn get_f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.get_u64()? as usize;
        let raw = self.take(n.checked_mul(8).ok_or_else(|| anyhow!("f64 slice length overflow"))?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn get_i8s(&mut self) -> Result<Vec<i8>> {
        let n = self.get_u64()? as usize;
        let raw = self.take(n)?;
        Ok(raw.iter().map(|&b| b as i8).collect())
    }

    pub fn get_usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.get_u64()? as usize;
        let len = n
            .checked_mul(8)
            .ok_or_else(|| anyhow!("index slice length overflow"))?;
        let raw = self.take(len)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect())
    }

    pub fn get_matrix(&mut self) -> Result<Matrix> {
        let rows = self.get_u32()? as usize;
        let cols = self.get_u32()? as usize;
        let n = rows
            .checked_mul(cols)
            .and_then(|n| n.checked_mul(4))
            .ok_or_else(|| anyhow!("matrix shape overflow"))?;
        let raw = self.take(n)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Matrix::from_vec(rows, cols, data))
    }

    pub fn get_i8_matrix(&mut self) -> Result<I8Matrix> {
        let rows = self.get_u32()? as usize;
        let cols = self.get_u32()? as usize;
        let n = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow!("matrix shape overflow"))?;
        let raw = self.take(n)?;
        Ok(I8Matrix::from_vec(rows, cols, raw.iter().map(|&b| b as i8).collect()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn rand_matrix(r: &mut Rng, special: bool) -> Matrix {
        let rows = r.below(6);
        let cols = if rows == 0 { 0 } else { r.below(8) };
        let mut m = Matrix::randn(rows, cols, r, 1.0);
        if special && !m.data().is_empty() {
            // plant NaN / ±inf payloads — they must round-trip bit-exactly
            let n = m.data().len();
            m.data_mut()[r.below(n)] = f32::NAN;
            m.data_mut()[r.below(n)] = f32::INFINITY;
            m.data_mut()[r.below(n)] = f32::NEG_INFINITY;
        }
        m
    }

    fn build_archive(m: &Matrix, qi: &I8Matrix, scales: &[f32], version: u32) -> Vec<u8> {
        let mut w = Writer::new(version);
        let mut s = SectionWriter::new();
        s.put_matrix(m);
        s.put_i8_matrix(qi);
        s.put_f32s(scales);
        s.put_str("label");
        s.put_u64(42);
        w.section("payload", s);
        let mut meta = SectionWriter::new();
        meta.put_str("test");
        w.section("meta", meta);
        w.finish()
    }

    #[test]
    fn roundtrip_matrices_scales_including_empty_and_nonfinite() {
        prop::check(
            "codec-roundtrip",
            0xC0DEC,
            48,
            |r| {
                let special = r.chance(0.5);
                let m = rand_matrix(r, special);
                let qrows = r.below(5);
                let qcols = if qrows == 0 { 0 } else { r.below(7) };
                let qi = I8Matrix::random(qrows, qcols, r);
                let n_scales = r.below(6);
                let mut scales: Vec<f32> = (0..n_scales).map(|_| r.normal()).collect();
                if !scales.is_empty() && r.chance(0.3) {
                    scales[0] = f32::NAN;
                }
                (m, qi, scales)
            },
            |(m, qi, scales)| {
                let bytes = build_archive(m, qi, scales, 7);
                let ar = Archive::from_bytes(&bytes).map_err(|e| e.to_string())?;
                if ar.version() != 7 {
                    return Err("version mismatch".into());
                }
                let mut r = ar.section("payload").map_err(|e| e.to_string())?;
                let m2 = r.get_matrix().map_err(|e| e.to_string())?;
                let qi2 = r.get_i8_matrix().map_err(|e| e.to_string())?;
                let s2 = r.get_f32s().map_err(|e| e.to_string())?;
                if (m2.rows(), m2.cols()) != (m.rows(), m.cols()) {
                    return Err("matrix shape changed".into());
                }
                for (a, b) in m.data().iter().zip(m2.data()) {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("f32 bits changed: {a} vs {b}"));
                    }
                }
                if qi2.data() != qi.data() || (qi2.rows(), qi2.cols()) != (qi.rows(), qi.cols()) {
                    return Err("i8 matrix changed".into());
                }
                if s2.len() != scales.len()
                    || s2.iter().zip(scales).any(|(a, b)| a.to_bits() != b.to_bits())
                {
                    return Err("scales changed".into());
                }
                if r.get_str().map_err(|e| e.to_string())? != "label" {
                    return Err("string changed".into());
                }
                if r.get_u64().map_err(|e| e.to_string())? != 42 {
                    return Err("u64 changed".into());
                }
                if r.remaining() != 0 {
                    return Err("leftover bytes".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn any_truncation_is_rejected() {
        prop::check(
            "codec-truncation",
            0x7A6C,
            64,
            |r| {
                let m = rand_matrix(r, true);
                let qi = I8Matrix::random(2, 3, r);
                let bytes = build_archive(&m, &qi, &[1.0, 2.0], 1);
                let cut = r.below(bytes.len());
                (bytes, cut)
            },
            |(bytes, cut)| match Archive::from_bytes(&bytes[..*cut]) {
                Ok(_) => Err(format!("truncation to {cut}/{} parsed", bytes.len())),
                Err(_) => Ok(()),
            },
        );
    }

    #[test]
    fn any_single_bit_flip_is_rejected() {
        // Every flip must surface: as a parse error (framing or CRC — the
        // CRC covers section names and payloads), or — for the 4 header
        // version bytes, which carry no CRC — as a changed version, which
        // the load path rejects by strict equality.
        prop::check(
            "codec-bitflip",
            0xF11B,
            64,
            |r| {
                let m = rand_matrix(r, false);
                let qi = I8Matrix::random(3, 2, r);
                let bytes = build_archive(&m, &qi, &[0.5; 4], 1);
                let byte = r.below(bytes.len());
                let bit = r.below(8) as u32;
                (bytes, byte, bit)
            },
            |(bytes, byte, bit)| {
                let mut c = bytes.clone();
                c[*byte] ^= 1u8 << bit;
                match Archive::from_bytes(&c) {
                    Err(_) => Ok(()),
                    Ok(ar) if (8..12).contains(byte) && ar.version() != 1 => Ok(()),
                    Ok(_) => Err(format!("bit flip at byte {byte} bit {bit} parsed cleanly")),
                }
            },
        );
    }

    #[test]
    fn wrong_magic_and_missing_section_are_readable_errors() {
        let e = Archive::from_bytes(b"NOTQUAFFxxxxxxxxxxxx").unwrap_err().to_string();
        assert!(e.contains("bad magic"), "{e}");
        let bytes = Writer::new(1).finish();
        let ar = Archive::from_bytes(&bytes).unwrap();
        let e = ar.section("nope").unwrap_err().to_string();
        assert!(e.contains("no section 'nope'"), "{e}");
    }

    #[test]
    fn crc_reference_vector() {
        // classic check value for the IEEE polynomial
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
