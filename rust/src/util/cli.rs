//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! which is all the `quaff` binary and examples need.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options (last occurrence wins).
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// First positional argument (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option lookup with default; panics with a readable message on a
    /// malformed value (CLI misuse should fail loudly, not silently).
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|_| panic!("--{name}: cannot parse '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("report fig4 --model phi-mini --steps=200 --verbose");
        assert_eq!(a.command(), Some("report"));
        assert_eq!(a.positional[1], "fig4");
        assert_eq!(a.get("model"), Some("phi-mini"));
        assert_eq!(a.get_parse::<usize>("steps", 0), 200);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn last_option_wins() {
        let a = parse("--x 1 --x 2");
        assert_eq!(a.get_parse::<i32>("x", 0), 2);
    }

    #[test]
    fn flag_at_end_and_before_flag() {
        let a = parse("--a --b v --c");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
        assert!(a.flag("c"));
    }

    #[test]
    #[should_panic(expected = "--steps")]
    fn bad_parse_panics() {
        let a = parse("--steps abc");
        let _: usize = a.get_parse("steps", 0);
    }
}
