//! Crash-safe full-state persistence: training checkpoints and quantized
//! distribution bundles.
//!
//! Built on the [`util::codec`](crate::util::codec) archive (length-prefixed
//! CRC-protected sections behind a magic/version header), this module
//! captures **everything** a run mutates, so that interrupt-at-any-step +
//! resume is *bit-identical* to the uninterrupted run
//! (`tests/persist_resume.rs` pins this for all six quantization methods ×
//! PEFT kinds × thread widths):
//!
//! * the int8 base weights + per-channel scales of every linear, via
//!   [`MethodSnapshot`] — including Quaff's momentum factors, Smooth_D's
//!   last dynamic factors, and LLM.int8's detection counters;
//! * LoRA / Prompt / P-tuning / IA3 adapter parameters (every trainable
//!   param the model visits);
//! * Adam first/second moments and the bias-correction timestep;
//! * the outlier-injection simulator's drifting gains and hot sets;
//! * `util::prng` stream positions (model RNG) and the data cursor;
//! * job spec + progress (step count, every logged loss, payload bytes).
//!
//! **Crash model.** [`write_atomic_rotating`] writes a temp file, fsyncs it,
//! rotates any existing checkpoint to a `.prev` sibling, then atomically
//! renames the temp into place (and fsyncs the directory). A crash mid-write
//! leaves either the old generation intact or a torn `.tmp` that is never
//! read; a corrupt tail (truncation, bit rot — both CRC-detected) falls back
//! to the retained previous generation on load
//! ([`load_train_checkpoint`] reports which generation served the load).
//!
//! Bundles ([`save_bundle`]/[`load_bundle`], surfaced as
//! `DistributionBundle::save`/`load`) persist a server-prepared quantized
//! model so a fine-tuned artifact round-trips disk → `infer::BatchEngine`
//! serving without ever materializing f32 base weights.

use crate::coordinator::{DistributionBundle, FinetuneJob};
use crate::methods::{method_from_snapshot, MethodKind, MethodSnapshot};
use crate::model::{Model, ModelConfig};
use crate::outlier::{OutlierRegistry, OutlierSet};
use crate::peft::PeftKind;
use crate::tensor::Matrix;
use crate::train::Trainer;
use crate::util::codec::{Archive, SectionReader, SectionWriter, Writer};
use crate::util::error::Result;
use crate::util::prng::Rng;
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::fs::{self, File};
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// On-disk format version (strict equality on read).
pub const FORMAT_VERSION: u32 = 1;

const KIND_CHECKPOINT: &str = "train-checkpoint";
const KIND_BUNDLE: &str = "distribution-bundle";

/// Section names shared by checkpoints and bundles.
mod sec {
    pub const META: &str = "meta";
    pub const CFG: &str = "model.cfg";
    pub const FROZEN: &str = "model.frozen";
    pub const METHODS: &str = "model.methods";
    pub const INJECT: &str = "model.inject";
    pub const PARAMS: &str = "model.params";
    pub const RNG: &str = "model.rng";
    pub const JOB: &str = "job";
    pub const PROGRESS: &str = "progress";
    pub const OPTIM: &str = "optim";
    pub const BUNDLE: &str = "bundle.info";
    pub const REGISTRY: &str = "bundle.registry";
}

fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(suffix);
    PathBuf::from(os)
}

/// Path of the retained previous checkpoint generation for `path`.
pub fn previous_generation(path: &Path) -> PathBuf {
    sibling(path, ".prev")
}

/// Crash-safe write: temp file + fsync + (rotate old generation to
/// `.prev`) + atomic rename + directory fsync. After any crash, `path`
/// holds either the old bytes or the new bytes — never a torn mix — and
/// the previous generation survives for corrupt-tail recovery.
///
/// Only a *valid* current generation is rotated: if `path` holds a corrupt
/// archive (e.g. the very file a resume just recovered *from* `.prev`
/// around), it is dropped instead, so a good previous generation is never
/// overwritten by garbage.
pub fn write_atomic_rotating(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = sibling(path, ".tmp");
    {
        let mut f = File::create(&tmp)
            .map_err(|e| anyhow!("create {}: {e}", tmp.display()))?;
        f.write_all(bytes)
            .map_err(|e| anyhow!("write {}: {e}", tmp.display()))?;
        f.sync_all()
            .map_err(|e| anyhow!("fsync {}: {e}", tmp.display()))?;
    }
    if path.exists() {
        let current_valid = fs::read(path)
            .ok()
            .is_some_and(|b| Archive::from_bytes(&b).is_ok());
        if current_valid {
            let prev = previous_generation(path);
            fs::rename(path, &prev)
                .map_err(|e| anyhow!("rotate {} -> {}: {e}", path.display(), prev.display()))?;
        } else {
            fs::remove_file(path)
                .map_err(|e| anyhow!("drop corrupt {}: {e}", path.display()))?;
        }
    }
    fs::rename(&tmp, path)
        .map_err(|e| anyhow!("rename {} -> {}: {e}", tmp.display(), path.display()))?;
    // Durability of the renames themselves; best-effort (not all platforms
    // allow opening a directory for sync).
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Does a loadable generation exist at `path` (current or previous)?
pub fn checkpoint_exists(path: &Path) -> bool {
    path.exists() || previous_generation(path).exists()
}

fn read_archive_with_recovery(path: &Path) -> Result<(Archive, bool, Option<String>)> {
    let primary = fs::read(path)
        .map_err(|e| anyhow!("read {}: {e}", path.display()))
        .and_then(|b| Archive::from_bytes(&b));
    match primary {
        Ok(ar) => Ok((ar, false, None)),
        Err(e) => {
            let prev = previous_generation(path);
            let bytes = fs::read(&prev).map_err(|pe| {
                anyhow!(
                    "checkpoint {} unusable ({e}); previous generation {} unreadable ({pe})",
                    path.display(),
                    prev.display()
                )
            })?;
            let ar = Archive::from_bytes(&bytes).map_err(|pe| {
                anyhow!(
                    "checkpoint {} unusable ({e}); previous generation {} corrupt ({pe})",
                    path.display(),
                    prev.display()
                )
            })?;
            Ok((ar, true, Some(e.to_string())))
        }
    }
}

fn check_header(ar: &Archive, kind: &str) -> Result<()> {
    if ar.version() != FORMAT_VERSION {
        bail!(
            "unsupported archive version {} (this build reads {FORMAT_VERSION})",
            ar.version()
        );
    }
    let mut meta = ar.section(sec::META)?;
    let k = meta.get_str()?;
    if k != kind {
        bail!("archive holds a '{k}', expected a '{kind}'");
    }
    Ok(())
}

// ---------------------------------------------------------------- tags

fn method_tag(k: MethodKind) -> u8 {
    match k {
        MethodKind::Fp32 => 1,
        MethodKind::Naive => 2,
        MethodKind::LlmInt8 => 3,
        MethodKind::SmoothStatic => 4,
        MethodKind::SmoothDynamic => 5,
        MethodKind::Quaff => 6,
        MethodKind::QuaffNoMomentum => 7,
    }
}

fn method_from_tag(t: u8) -> Result<MethodKind> {
    Ok(match t {
        1 => MethodKind::Fp32,
        2 => MethodKind::Naive,
        3 => MethodKind::LlmInt8,
        4 => MethodKind::SmoothStatic,
        5 => MethodKind::SmoothDynamic,
        6 => MethodKind::Quaff,
        7 => MethodKind::QuaffNoMomentum,
        _ => bail!("unknown method tag {t}"),
    })
}

fn peft_tag(p: PeftKind) -> u8 {
    match p {
        PeftKind::Lora => 1,
        PeftKind::Prompt => 2,
        PeftKind::PTuning => 3,
        PeftKind::Ia3 => 4,
    }
}

fn peft_from_tag(t: u8) -> Result<PeftKind> {
    Ok(match t {
        1 => PeftKind::Lora,
        2 => PeftKind::Prompt,
        3 => PeftKind::PTuning,
        4 => PeftKind::Ia3,
        _ => bail!("unknown peft tag {t}"),
    })
}

// ----------------------------------------------------- method snapshots

fn put_layer_state(s: &mut SectionWriter, snap: Option<MethodSnapshot>, master: Option<&Matrix>) {
    match snap {
        None => {
            s.put_u8(0);
            s.put_matrix(master.expect("linear layer with neither method nor master"));
        }
        Some(MethodSnapshot::Fp32 { w }) => {
            s.put_u8(1);
            s.put_matrix(&w);
        }
        Some(MethodSnapshot::Naive { w_int, deltas }) => {
            s.put_u8(2);
            s.put_i8_matrix(&w_int);
            s.put_f32s(&deltas);
        }
        Some(MethodSnapshot::LlmInt8 {
            w_int,
            deltas,
            sigma,
            dequant_rows_total,
            steps,
        }) => {
            s.put_u8(3);
            s.put_i8_matrix(&w_int);
            s.put_f32s(&deltas);
            s.put_f32(sigma);
            s.put_u64(dequant_rows_total);
            s.put_u64(steps);
        }
        Some(MethodSnapshot::SmoothStatic { w_int, deltas, s: factors }) => {
            s.put_u8(4);
            s.put_i8_matrix(&w_int);
            s.put_f32s(&deltas);
            s.put_f32s(&factors);
        }
        Some(MethodSnapshot::SmoothDynamic {
            w_full,
            alpha,
            last_s,
        }) => {
            s.put_u8(5);
            s.put_matrix(&w_full);
            s.put_f32(alpha);
            s.put_f32s(&last_s);
        }
        Some(MethodSnapshot::Quaff {
            w_int,
            deltas,
            w_o,
            w_row_max,
            channels,
            s_o,
            gamma,
            momentum,
        }) => {
            s.put_u8(6);
            s.put_i8_matrix(&w_int);
            s.put_f32s(&deltas);
            s.put_matrix(&w_o);
            s.put_f32s(&w_row_max);
            s.put_usizes(&channels);
            s.put_f32s(&s_o);
            s.put_f32(gamma);
            s.put_bool(momentum);
        }
    }
}

enum LayerState {
    Master(Matrix),
    Quantized(MethodSnapshot),
}

fn get_layer_state(r: &mut SectionReader<'_>) -> Result<LayerState> {
    let tag = r.get_u8()?;
    Ok(match tag {
        0 => LayerState::Master(r.get_matrix()?),
        1 => LayerState::Quantized(MethodSnapshot::Fp32 { w: r.get_matrix()? }),
        2 => LayerState::Quantized(MethodSnapshot::Naive {
            w_int: r.get_i8_matrix()?,
            deltas: r.get_f32s()?,
        }),
        3 => LayerState::Quantized(MethodSnapshot::LlmInt8 {
            w_int: r.get_i8_matrix()?,
            deltas: r.get_f32s()?,
            sigma: r.get_f32()?,
            dequant_rows_total: r.get_u64()?,
            steps: r.get_u64()?,
        }),
        4 => LayerState::Quantized(MethodSnapshot::SmoothStatic {
            w_int: r.get_i8_matrix()?,
            deltas: r.get_f32s()?,
            s: r.get_f32s()?,
        }),
        5 => LayerState::Quantized(MethodSnapshot::SmoothDynamic {
            w_full: r.get_matrix()?,
            alpha: r.get_f32()?,
            last_s: r.get_f32s()?,
        }),
        6 => LayerState::Quantized(MethodSnapshot::Quaff {
            w_int: r.get_i8_matrix()?,
            deltas: r.get_f32s()?,
            w_o: r.get_matrix()?,
            w_row_max: r.get_f32s()?,
            channels: r.get_usizes()?,
            s_o: r.get_f32s()?,
            gamma: r.get_f32()?,
            momentum: r.get_bool()?,
        }),
        t => bail!("unknown layer-state tag {t}"),
    })
}

/// Internal-consistency checks on a decoded snapshot, so a CRC-valid but
/// malformed archive (a buggy or foreign producer — the CRC only protects
/// against *corruption*) surfaces as a readable error from the load path
/// instead of tripping the `from_parts` invariant asserts (a panic).
fn validate_snapshot(snap: &MethodSnapshot) -> Result<()> {
    let deltas_ok = |deltas: &[f32], cout: usize| -> Result<()> {
        if deltas.len() != cout {
            bail!("method state: {} step sizes for {cout} output channels", deltas.len());
        }
        Ok(())
    };
    match snap {
        MethodSnapshot::Fp32 { .. } => {}
        MethodSnapshot::Naive { w_int, deltas } => deltas_ok(deltas, w_int.cols())?,
        MethodSnapshot::LlmInt8 { w_int, deltas, .. } => deltas_ok(deltas, w_int.cols())?,
        MethodSnapshot::SmoothStatic { w_int, deltas, s } => {
            deltas_ok(deltas, w_int.cols())?;
            if s.len() != w_int.rows() {
                bail!("Smooth_S state: {} factors for {} input channels", s.len(), w_int.rows());
            }
        }
        MethodSnapshot::SmoothDynamic { w_full, last_s, .. } => {
            if last_s.len() != w_full.rows() {
                bail!(
                    "Smooth_D state: {} factors for {} input channels",
                    last_s.len(),
                    w_full.rows()
                );
            }
        }
        MethodSnapshot::Quaff {
            w_int,
            deltas,
            w_o,
            w_row_max,
            channels,
            s_o,
            gamma,
            ..
        } => {
            deltas_ok(deltas, w_int.cols())?;
            if w_row_max.len() != w_int.rows() {
                bail!(
                    "Quaff state: {} row maxima for {} input channels",
                    w_row_max.len(),
                    w_int.rows()
                );
            }
            let sorted_unique = channels.windows(2).all(|w| w[0] < w[1]);
            let in_range = channels.iter().all(|&c| c < w_int.rows());
            if !sorted_unique || !in_range {
                bail!("Quaff state: outlier channels must be sorted, distinct, and in range");
            }
            if s_o.len() != channels.len() || w_o.rows() != channels.len() {
                bail!(
                    "Quaff state: {} factors / {} W_O rows for {} outlier channels",
                    s_o.len(),
                    w_o.rows(),
                    channels.len()
                );
            }
            if w_o.rows() > 0 && w_o.cols() != w_int.cols() {
                bail!(
                    "Quaff state: W_O width {} does not match c_out {}",
                    w_o.cols(),
                    w_int.cols()
                );
            }
            if !(0.0..=1.0).contains(gamma) {
                bail!("Quaff state: gamma {gamma} outside [0, 1]");
            }
        }
    }
    Ok(())
}

// ------------------------------------------------------- model state

fn encode_model(w: &mut Writer, model: &mut Model) {
    // cfg + attached PEFT kind
    let mut c = SectionWriter::new();
    let cfg = &model.cfg;
    c.put_usize(cfg.vocab);
    c.put_usize(cfg.d_model);
    c.put_usize(cfg.n_layers);
    c.put_usize(cfg.n_heads);
    c.put_usize(cfg.d_ff);
    c.put_usize(cfg.max_seq);
    c.put_f32(cfg.ln_eps);
    c.put_bool(cfg.inject_outliers);
    c.put_usize(cfg.lora_rank);
    c.put_f32(cfg.lora_alpha);
    c.put_f32(cfg.lora_dropout);
    c.put_usize(cfg.n_virtual);
    c.put_u8(model.peft.map(peft_tag).unwrap_or(0));
    w.section(sec::CFG, c);
    // frozen common parts
    let mut f = SectionWriter::new();
    f.put_matrix(&model.emb.tok);
    f.put_matrix(&model.emb.pos);
    f.put_matrix(&model.lm_head);
    for b in &model.blocks {
        f.put_f32s(&b.ln1.gain);
        f.put_f32s(&b.ln1.bias);
        f.put_f32s(&b.ln2.gain);
        f.put_f32s(&b.ln2.bias);
    }
    f.put_f32s(&model.final_ln.gain);
    f.put_f32s(&model.final_ln.bias);
    w.section(sec::FROZEN, f);
    // per-linear quantized state (or the pre-conversion master)
    let mut m = SectionWriter::new();
    for b in &model.blocks {
        for lin in b.linears_ref() {
            put_layer_state(&mut m, lin.method_snapshot(), lin.master());
        }
    }
    w.section(sec::METHODS, m);
    // outlier-injection simulator state (drifts every training step)
    let mut inj = SectionWriter::new();
    for b in &model.blocks {
        for g in [&b.inj_attn, &b.inj_o, &b.inj_mlp, &b.inj_down] {
            inj.put_f32s(&g.gains);
            inj.put_usizes(&g.hot);
        }
    }
    w.section(sec::INJECT, inj);
    // every trainable parameter (adapters, prompt/p-tuning, IA3) — one
    // pass counts, one pass serializes straight into the buffer, so
    // periodic checkpoints never clone a tensor
    let mut count: u32 = 0;
    model.visit_params(&mut |_, _| count += 1);
    let mut ps = SectionWriter::new();
    ps.put_u32(count);
    model.visit_params(&mut |name, p| {
        ps.put_str(name);
        ps.put_matrix(&p.value);
    });
    w.section(sec::PARAMS, ps);
    // PRNG stream position
    let mut rs = SectionWriter::new();
    for v in model.rng.state() {
        rs.put_u64(v);
    }
    w.section(sec::RNG, rs);
}

fn ensure_mat(name: &str, m: &Matrix, rows: usize, cols: usize) -> Result<()> {
    if (m.rows(), m.cols()) != (rows, cols) {
        bail!(
            "{name}: archive shape ({}, {}) does not match model ({rows}, {cols})",
            m.rows(),
            m.cols()
        );
    }
    Ok(())
}

fn decode_model(ar: &Archive) -> Result<Model> {
    let mut c = ar.section(sec::CFG)?;
    let cfg = ModelConfig {
        vocab: c.get_usize()?,
        d_model: c.get_usize()?,
        n_layers: c.get_usize()?,
        n_heads: c.get_usize()?,
        d_ff: c.get_usize()?,
        max_seq: c.get_usize()?,
        ln_eps: c.get_f32()?,
        inject_outliers: c.get_bool()?,
        lora_rank: c.get_usize()?,
        lora_alpha: c.get_f32()?,
        lora_dropout: c.get_f32()?,
        n_virtual: c.get_usize()?,
    };
    let peft_tag_v = c.get_u8()?;
    let mut model = Model::new(cfg, 0);
    if peft_tag_v != 0 {
        model.attach_peft(peft_from_tag(peft_tag_v)?);
    }
    let d = model.cfg.d_model;
    // frozen common parts
    let mut f = ar.section(sec::FROZEN)?;
    let tok = f.get_matrix()?;
    ensure_mat("emb.tok", &tok, model.emb.tok.rows(), model.emb.tok.cols())?;
    model.emb.tok = tok;
    let pos = f.get_matrix()?;
    ensure_mat("emb.pos", &pos, model.emb.pos.rows(), model.emb.pos.cols())?;
    model.emb.pos = pos;
    let head = f.get_matrix()?;
    ensure_mat("lm_head", &head, model.lm_head.rows(), model.lm_head.cols())?;
    model.lm_head = head;
    for i in 0..model.blocks.len() {
        let b = &mut model.blocks[i];
        for (label, slot) in [
            ("ln1.gain", &mut b.ln1.gain),
            ("ln1.bias", &mut b.ln1.bias),
            ("ln2.gain", &mut b.ln2.gain),
            ("ln2.bias", &mut b.ln2.bias),
        ] {
            let v = f.get_f32s()?;
            if v.len() != d {
                bail!("blocks.{i}.{label}: length {} != d_model {d}", v.len());
            }
            *slot = v;
        }
    }
    for (label, slot) in [
        ("final_ln.gain", &mut model.final_ln.gain),
        ("final_ln.bias", &mut model.final_ln.bias),
    ] {
        let v = f.get_f32s()?;
        if v.len() != d {
            bail!("{label}: length {} != d_model {d}", v.len());
        }
        *slot = v;
    }
    // per-linear state
    let mut ms = ar.section(sec::METHODS)?;
    for i in 0..model.blocks.len() {
        for lin in model.blocks[i].linears() {
            match get_layer_state(&mut ms)? {
                LayerState::Master(w) => {
                    ensure_mat(&format!("{} master", lin.name), &w, lin.cin(), lin.cout())?;
                    lin.set_master(w);
                }
                LayerState::Quantized(snap) => {
                    validate_snapshot(&snap)?;
                    if (snap.cin(), snap.cout()) != (lin.cin(), lin.cout()) {
                        bail!(
                            "{}: archive method shape ({}, {}) does not match layer ({}, {})",
                            lin.name,
                            snap.cin(),
                            snap.cout(),
                            lin.cin(),
                            lin.cout()
                        );
                    }
                    lin.set_method(method_from_snapshot(snap));
                }
            }
        }
    }
    // injection simulator
    let mut inj = ar.section(sec::INJECT)?;
    for i in 0..model.blocks.len() {
        let b = &mut model.blocks[i];
        for g in [&mut b.inj_attn, &mut b.inj_o, &mut b.inj_mlp, &mut b.inj_down] {
            let gains = inj.get_f32s()?;
            if gains.len() != g.gains.len() {
                bail!("blocks.{i}: injection gain length {} != {}", gains.len(), g.gains.len());
            }
            let hot = inj.get_usizes()?;
            if hot.iter().any(|&c| c >= gains.len()) {
                bail!("blocks.{i}: injection hot channel out of range");
            }
            g.gains = gains;
            g.hot = hot;
        }
    }
    // trainable parameters
    let mut ps = ar.section(sec::PARAMS)?;
    let count = ps.get_u32()? as usize;
    let mut loaded: BTreeMap<String, Matrix> = BTreeMap::new();
    for _ in 0..count {
        let name = ps.get_str()?;
        let value = ps.get_matrix()?;
        loaded.insert(name, value);
    }
    let mut err: Option<String> = None;
    model.visit_params(&mut |name, p| match loaded.remove(name) {
        Some(value) => {
            if (value.rows(), value.cols()) != (p.value.rows(), p.value.cols()) {
                err.get_or_insert(format!(
                    "param {name}: archive shape ({}, {}) does not match model ({}, {})",
                    value.rows(),
                    value.cols(),
                    p.value.rows(),
                    p.value.cols()
                ));
                return;
            }
            p.value = value;
            p.zero_grad();
        }
        None => {
            err.get_or_insert(format!("model param {name} missing from archive"));
        }
    });
    if let Some(e) = err {
        bail!("{e}");
    }
    if !loaded.is_empty() {
        bail!(
            "archive params not present in model: {:?}",
            loaded.keys().collect::<Vec<_>>()
        );
    }
    // PRNG stream
    let mut rs = ar.section(sec::RNG)?;
    let state = [rs.get_u64()?, rs.get_u64()?, rs.get_u64()?, rs.get_u64()?];
    model.rng = Rng::from_state(state);
    Ok(model)
}

// ------------------------------------------------------------ job spec

fn put_job(s: &mut SectionWriter, job: &FinetuneJob) {
    s.put_u64(job.id);
    s.put_str(&job.dataset);
    s.put_u8(method_tag(job.method));
    s.put_u8(peft_tag(job.peft));
    s.put_u64(job.steps);
    s.put_usize(job.batch_size);
    s.put_usize(job.grad_accum);
    s.put_f32(job.lr);
    s.put_u64(job.seed);
    s.put_usize(job.train_pool);
    s.put_usize(job.eval_samples);
    s.put_usize(job.max_len);
}

fn get_job(s: &mut SectionReader<'_>) -> Result<FinetuneJob> {
    Ok(FinetuneJob {
        id: s.get_u64()?,
        dataset: s.get_str()?,
        method: method_from_tag(s.get_u8()?)?,
        peft: peft_from_tag(s.get_u8()?)?,
        steps: s.get_u64()?,
        batch_size: s.get_usize()?,
        grad_accum: s.get_usize()?,
        lr: s.get_f32()?,
        seed: s.get_u64()?,
        train_pool: s.get_usize()?,
        eval_samples: s.get_usize()?,
        max_len: s.get_usize()?,
        checkpoint: None,
    })
}

// -------------------------------------------------------- checkpoints

/// Everything a resumed `run_job` needs, fully restored.
pub struct TrainCheckpoint {
    /// The job spec as recorded at save time (`checkpoint` cleared).
    pub job: FinetuneJob,
    /// Optimizer steps completed (== `trainer.step_count`).
    pub steps_done: u64,
    /// Data-iterator cursor after the last completed step.
    pub cursor: usize,
    /// Every per-step loss logged so far.
    pub losses: Vec<f64>,
    /// Distribution payload bytes recorded at preparation time.
    pub payload_bytes: usize,
    /// The model, bit-identical to the checkpointed one.
    pub model: Model,
    /// Trainer with Adam moments/timestep and step count restored.
    pub trainer: Trainer,
}

/// A loaded checkpoint plus which generation served it.
pub struct LoadedCheckpoint {
    pub ckpt: TrainCheckpoint,
    /// True when the current generation was corrupt/missing and the
    /// retained `.prev` generation was used instead.
    pub recovered_from_previous: bool,
    /// The current generation's error, when recovery happened.
    pub primary_error: Option<String>,
}

/// Serialize the full training state to `path` crash-safely (see the
/// module docs for the crash model). Returns the archive size in bytes.
pub fn save_train_checkpoint(
    path: &Path,
    job: &FinetuneJob,
    model: &mut Model,
    trainer: &Trainer,
    cursor: usize,
    losses: &[f64],
    payload_bytes: usize,
) -> Result<usize> {
    let mut w = Writer::new(FORMAT_VERSION);
    let mut meta = SectionWriter::new();
    meta.put_str(KIND_CHECKPOINT);
    w.section(sec::META, meta);
    let mut js = SectionWriter::new();
    put_job(&mut js, job);
    w.section(sec::JOB, js);
    let mut pg = SectionWriter::new();
    pg.put_u64(trainer.step_count);
    pg.put_usize(cursor);
    pg.put_f64s(losses);
    pg.put_usize(payload_bytes);
    w.section(sec::PROGRESS, pg);
    encode_model(&mut w, model);
    let mut os = SectionWriter::new();
    os.put_u64(trainer.opt.timestep());
    let mut count: u32 = 0;
    trainer.opt.visit_state(&mut |_, _, _| count += 1);
    os.put_u32(count);
    trainer.opt.visit_state(&mut |name, m, v| {
        os.put_str(name);
        os.put_matrix(m);
        os.put_matrix(v);
    });
    w.section(sec::OPTIM, os);
    let bytes = w.finish();
    write_atomic_rotating(path, &bytes)?;
    Ok(bytes.len())
}

/// Load a checkpoint, falling back to the previous generation when the
/// current one is truncated or bit-rotted (CRC), and reporting which
/// generation served the load.
pub fn load_train_checkpoint(path: &Path) -> Result<LoadedCheckpoint> {
    let (ar, recovered, primary_error) = read_archive_with_recovery(path)?;
    check_header(&ar, KIND_CHECKPOINT)?;
    let mut js = ar.section(sec::JOB)?;
    let job = get_job(&mut js)?;
    let mut pg = ar.section(sec::PROGRESS)?;
    let steps_done = pg.get_u64()?;
    let cursor = pg.get_usize()?;
    let losses = pg.get_f64s()?;
    let payload_bytes = pg.get_usize()?;
    if losses.len() as u64 != steps_done {
        bail!(
            "checkpoint inconsistent: {} losses for {steps_done} steps",
            losses.len()
        );
    }
    let model = decode_model(&ar)?;
    let mut trainer = Trainer::new(job.lr, job.max_len, job.grad_accum);
    trainer.step_count = steps_done;
    let mut os = ar.section(sec::OPTIM)?;
    trainer.opt.set_timestep(os.get_u64()?);
    let n = os.get_u32()? as usize;
    for _ in 0..n {
        let name = os.get_str()?;
        let m = os.get_matrix()?;
        let v = os.get_matrix()?;
        trainer.opt.insert_state(&name, m, v);
    }
    Ok(LoadedCheckpoint {
        ckpt: TrainCheckpoint {
            job,
            steps_done,
            cursor,
            losses,
            payload_bytes,
            model,
            trainer,
        },
        recovered_from_previous: recovered,
        primary_error,
    })
}

/// Does `path` hold a *training checkpoint* (as opposed to some other
/// archive kind, e.g. a saved distribution bundle that also ends in
/// `.qckpt`)? Unreadable/corrupt archives (both generations) and
/// unsupported versions are errors; a readable archive of another kind is
/// `Ok(false)` — directory scans skip those rather than failing wholesale.
pub fn is_train_checkpoint(path: &Path) -> Result<bool> {
    let (ar, _, _) = read_archive_with_recovery(path)?;
    if ar.version() != FORMAT_VERSION {
        bail!(
            "unsupported archive version {} (this build reads {FORMAT_VERSION})",
            ar.version()
        );
    }
    let mut meta = ar.section(sec::META)?;
    Ok(meta.get_str()? == KIND_CHECKPOINT)
}

/// Read only the job spec + progress out of a checkpoint (cheap relative to
/// a full restore only in intent — the archive is still parsed once; used
/// by `Coordinator` directory scans).
pub fn peek_job(path: &Path) -> Result<(FinetuneJob, u64)> {
    let (ar, _, _) = read_archive_with_recovery(path)?;
    check_header(&ar, KIND_CHECKPOINT)?;
    let mut js = ar.section(sec::JOB)?;
    let job = get_job(&mut js)?;
    let mut pg = ar.section(sec::PROGRESS)?;
    let steps_done = pg.get_u64()?;
    Ok((job, steps_done))
}

// ------------------------------------------------------------- bundles

/// Persist a server-prepared [`DistributionBundle`] (quantized model +
/// outlier registry + provenance). Crash-safe like checkpoints. Returns
/// the archive size in bytes.
pub fn save_bundle(path: &Path, bundle: &mut DistributionBundle) -> Result<usize> {
    let mut w = Writer::new(FORMAT_VERSION);
    let mut meta = SectionWriter::new();
    meta.put_str(KIND_BUNDLE);
    w.section(sec::META, meta);
    let mut info = SectionWriter::new();
    info.put_str(&bundle.preset);
    info.put_u8(method_tag(bundle.method));
    info.put_usize(bundle.payload_bytes);
    info.put_f64(bundle.outlier_overhead);
    w.section(sec::BUNDLE, info);
    let mut reg = SectionWriter::new();
    let entries: Vec<_> = bundle.registry.layers().collect();
    reg.put_u32(entries.len() as u32);
    for (name, set) in entries {
        reg.put_str(name);
        reg.put_usizes(&set.channels);
    }
    w.section(sec::REGISTRY, reg);
    encode_model(&mut w, &mut bundle.model);
    let bytes = w.finish();
    write_atomic_rotating(path, &bytes)?;
    Ok(bytes.len())
}

/// Load a [`DistributionBundle`] saved by [`save_bundle`]: the model comes
/// back with every linear in its persisted representation (int8 stores stay
/// int8 — no f32 base weights are materialized), ready to fine-tune or to
/// serve from an `infer::BatchEngine` directly.
pub fn load_bundle(path: &Path) -> Result<DistributionBundle> {
    let (ar, _, _) = read_archive_with_recovery(path)?;
    check_header(&ar, KIND_BUNDLE)?;
    let mut info = ar.section(sec::BUNDLE)?;
    let preset = info.get_str()?;
    let method = method_from_tag(info.get_u8()?)?;
    let payload_bytes = info.get_usize()?;
    let outlier_overhead = info.get_f64()?;
    let mut rs = ar.section(sec::REGISTRY)?;
    let n = rs.get_u32()? as usize;
    let mut registry = OutlierRegistry::new();
    for _ in 0..n {
        let name = rs.get_str()?;
        let channels = rs.get_usizes()?;
        registry.insert(&name, OutlierSet::new(channels));
    }
    let model = decode_model(&ar)?;
    Ok(DistributionBundle {
        model,
        registry,
        method,
        preset,
        payload_bytes,
        outlier_overhead,
    })
}

// ----------------------------------------------------------- artifacts

/// Persist a small auxiliary artifact (e.g. the OSSH telemetry state that
/// rides alongside a training checkpoint) through the same versioned,
/// CRC'd, crash-safe machinery as checkpoints and bundles. `kind` is the
/// artifact's identity string, written into the meta section and enforced
/// on load, so an artifact can never be mistaken for a checkpoint (or vice
/// versa). `build` appends the caller's sections to the archive. Returns
/// the archive size in bytes.
pub fn save_artifact(path: &Path, kind: &str, build: impl FnOnce(&mut Writer)) -> Result<usize> {
    let mut w = Writer::new(FORMAT_VERSION);
    let mut meta = SectionWriter::new();
    meta.put_str(kind);
    w.section(sec::META, meta);
    build(&mut w);
    let bytes = w.finish();
    write_atomic_rotating(path, &bytes)?;
    Ok(bytes.len())
}

/// Load an artifact saved by [`save_artifact`], with the same `.prev`
/// corrupt-tail recovery as checkpoints and strict version + kind checks.
pub fn load_artifact(path: &Path, kind: &str) -> Result<Archive> {
    let (ar, _, _) = read_archive_with_recovery(path)?;
    check_header(&ar, kind)?;
    Ok(ar)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("quaff_persist_unit_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn tiny_model() -> Model {
        let mut cfg = ModelConfig::preset("opt-tiny").unwrap();
        cfg.n_layers = 2;
        let mut m = Model::new(cfg, 11);
        m.attach_peft(PeftKind::Lora);
        m
    }

    fn tiny_job() -> FinetuneJob {
        let mut j = FinetuneJob::new(5, "gpqa", MethodKind::Naive, PeftKind::Lora);
        j.steps = 4;
        j
    }

    #[test]
    fn checkpoint_roundtrip_restores_job_progress_and_model_state() {
        let path = tmp("roundtrip.qckpt");
        let mut model = tiny_model();
        // make state nontrivial
        model.visit_params(&mut |_, p| {
            for (i, v) in p.value.data_mut().iter_mut().enumerate() {
                *v = (i % 5) as f32 * 0.25 - 0.5;
            }
        });
        for _ in 0..3 {
            model.tick_outliers();
        }
        let job = tiny_job();
        let trainer = Trainer::new(job.lr, job.max_len, job.grad_accum);
        let losses = vec![];
        save_train_checkpoint(&path, &job, &mut model, &trainer, 6, &losses, 123).unwrap();
        let loaded = load_train_checkpoint(&path).unwrap();
        assert!(!loaded.recovered_from_previous);
        let ck = loaded.ckpt;
        assert_eq!(ck.job.dataset, "gpqa");
        assert_eq!(ck.job.id, 5);
        assert_eq!(ck.cursor, 6);
        assert_eq!(ck.payload_bytes, 123);
        assert_eq!(ck.steps_done, 0);
        // params round-trip bit-exactly
        let mut want = Vec::new();
        model.visit_params(&mut |_, p| want.push(p.value.clone()));
        let mut restored = ck.model;
        let mut got = Vec::new();
        restored.visit_params(&mut |_, p| got.push(p.value.clone()));
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.data(), b.data());
        }
        // rng + injection state round-trip
        assert_eq!(model.rng.state(), restored.rng.state());
        assert_eq!(model.blocks[0].inj_down.gains, restored.blocks[0].inj_down.gains);
        assert_eq!(model.blocks[0].inj_down.hot, restored.blocks[0].inj_down.hot);
    }

    #[test]
    fn rotation_retains_previous_generation_and_recovers_from_corrupt_tail() {
        let path = tmp("rotate.qckpt");
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(previous_generation(&path));
        let mut model = tiny_model();
        let job = tiny_job();
        let trainer = Trainer::new(job.lr, job.max_len, job.grad_accum);
        save_train_checkpoint(&path, &job, &mut model, &trainer, 1, &[], 1).unwrap();
        assert!(!previous_generation(&path).exists());
        save_train_checkpoint(&path, &job, &mut model, &trainer, 2, &[], 1).unwrap();
        assert!(previous_generation(&path).exists(), "second save must rotate");
        // corrupt the tail of the current generation
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let loaded = load_train_checkpoint(&path).unwrap();
        assert!(loaded.recovered_from_previous);
        assert!(loaded.primary_error.is_some());
        assert_eq!(loaded.ckpt.cursor, 1, "recovery must serve the previous generation");
        // a subsequent save must NOT rotate the corrupt current generation
        // over the good previous one — it is dropped instead
        save_train_checkpoint(&path, &job, &mut model, &trainer, 3, &[], 1).unwrap();
        let prev_bytes = fs::read(previous_generation(&path)).unwrap();
        Archive::from_bytes(&prev_bytes).expect("previous generation must stay valid");
        let after = load_train_checkpoint(&path).unwrap();
        assert!(!after.recovered_from_previous);
        assert_eq!(after.ckpt.cursor, 3);
        // with both generations gone, the error is readable
        fs::remove_file(&path).unwrap();
        fs::remove_file(previous_generation(&path)).unwrap();
        let e = load_train_checkpoint(&path).unwrap_err().to_string();
        assert!(e.contains("unusable"), "{e}");
    }

    #[test]
    fn inconsistent_snapshots_are_rejected_not_panicked() {
        use crate::tensor::I8Matrix;
        // mismatched momentum factors vs outlier channels
        let bad = MethodSnapshot::Quaff {
            w_int: I8Matrix::zeros(4, 3),
            deltas: vec![0.1; 3],
            w_o: Matrix::zeros(1, 3),
            w_row_max: vec![1.0; 4],
            channels: vec![2],
            s_o: vec![1.0, 2.0],
            gamma: 0.2,
            momentum: true,
        };
        assert!(validate_snapshot(&bad).unwrap_err().to_string().contains("factors"));
        // out-of-range / unsorted channels
        let bad = MethodSnapshot::Quaff {
            w_int: I8Matrix::zeros(4, 3),
            deltas: vec![0.1; 3],
            w_o: Matrix::zeros(1, 3),
            w_row_max: vec![1.0; 4],
            channels: vec![9],
            s_o: vec![1.0],
            gamma: 0.2,
            momentum: true,
        };
        assert!(validate_snapshot(&bad).is_err());
        // gamma outside [0, 1]
        let bad = MethodSnapshot::Quaff {
            w_int: I8Matrix::zeros(4, 3),
            deltas: vec![0.1; 3],
            w_o: Matrix::zeros(1, 3),
            w_row_max: vec![1.0; 4],
            channels: vec![2],
            s_o: vec![1.0],
            gamma: 1.5,
            momentum: true,
        };
        assert!(validate_snapshot(&bad).unwrap_err().to_string().contains("gamma"));
        // step-size count mismatch on the int8 substrate
        let bad = MethodSnapshot::Naive {
            w_int: I8Matrix::zeros(4, 3),
            deltas: vec![0.1; 2],
        };
        assert!(validate_snapshot(&bad).is_err());
        // and a consistent one passes
        let good = MethodSnapshot::Naive {
            w_int: I8Matrix::zeros(4, 3),
            deltas: vec![0.1; 3],
        };
        assert!(validate_snapshot(&good).is_ok());
    }

    #[test]
    fn kind_and_version_are_enforced() {
        let path = tmp("kind.qckpt");
        let mut model = tiny_model();
        let job = tiny_job();
        let trainer = Trainer::new(job.lr, job.max_len, job.grad_accum);
        save_train_checkpoint(&path, &job, &mut model, &trainer, 0, &[], 0).unwrap();
        let e = load_bundle(&path).unwrap_err().to_string();
        assert!(e.contains("expected a 'distribution-bundle'"), "{e}");
        let (job2, steps) = peek_job(&path).unwrap();
        assert_eq!(job2.dataset, job.dataset);
        assert_eq!(steps, 0);
    }

    #[test]
    fn artifact_roundtrip_enforces_kind_and_rotates() {
        let path = tmp("telemetry.qart");
        let n = save_artifact(&path, "test-artifact", |w| {
            let mut s = SectionWriter::new();
            s.put_u64(42);
            s.put_f64s(&[1.0, f64::NAN, f64::INFINITY]);
            w.section("payload", s);
        })
        .unwrap();
        assert!(n > 0);
        let ar = load_artifact(&path, "test-artifact").unwrap();
        let mut s = ar.section("payload").unwrap();
        assert_eq!(s.get_u64().unwrap(), 42);
        let xs = s.get_f64s().unwrap();
        assert_eq!(xs[0], 1.0);
        assert!(xs[1].is_nan());
        assert_eq!(xs[2], f64::INFINITY);
        // wrong kind is refused with a readable error
        let e = load_artifact(&path, "other-kind").unwrap_err().to_string();
        assert!(e.contains("expected a 'other-kind'"), "{e}");
        // a second save rotates the first generation to .prev, and a
        // corrupted current generation falls back to it
        save_artifact(&path, "test-artifact", |w| {
            let mut s = SectionWriter::new();
            s.put_u64(43);
            w.section("payload", s);
        })
        .unwrap();
        assert!(previous_generation(&path).exists());
        fs::write(&path, b"garbage").unwrap();
        let ar = load_artifact(&path, "test-artifact").unwrap();
        assert_eq!(ar.section("payload").unwrap().get_u64().unwrap(), 42);
    }
}
