//! Quantized autoregressive inference: KV-cached decoding, sampling, and
//! the batched serving engine.
//!
//! This subsystem turns the fine-tuning stack into a *serving* stack. The
//! same [`QuantMethod`](crate::methods::QuantMethod) kernels that run the
//! teacher-forced training forward run generation, through three layers:
//!
//! * **[`KvCache`]** ([`kv`]) — pooled, grow-only, **paged** per-block
//!   K/V storage: fixed-size pages from the `Workspace` lane pools,
//!   shared across many concurrent request slots through per-slot page
//!   tables; preemption/eviction is a page-table edit.
//! * **Decode entry points** (`model::decode`) — `Model::prefill` fills a
//!   slot from a prompt; `Model::decode_step` extends many slots by one
//!   token as one stacked batch, so the int8 linear kernels shard across
//!   the `tensor::pool` threads. Both are frozen-state and row-local,
//!   which makes cached decoding **bit-identical** to a naive full
//!   re-forward for every quantization method (`tests/decode_parity.rs`)
//!   and paged decoding bit-identical to contiguous
//!   (`tests/serve_parity.rs`).
//! * **Drivers** — [`generate_cached`] / [`generate_uncached`] for single
//!   requests (greedy or temperature/top-k/top-p sampling via
//!   [`GenerateConfig`], deterministic under a fixed seed),
//!   [`BatchEngine`] ([`engine`]) for continuous batching with
//!   page-pressure preemption — optionally **self-speculative** under a
//!   [`SpecConfig`] ([`spec`]): truncated-layer drafting + one stacked
//!   full verify pass, bit-identical to plain greedy — and [`Server`]
//!   ([`serve`]) — the request front-end: bounded admission queue with
//!   backpressure, logical-clock deadlines, cancellation, and streaming
//!   token delivery via per-request [`TokenSink`]s. Requests may carry a
//!   tenant tag resolved against an [`AdapterRegistry`] ([`tenant`]):
//!   many tenants' LoRA/prompt stacks serve over one shared quantized
//!   base, mixed freely within a decode batch
//!   (`tests/tenant_parity.rs` proves mixing is bitwise-invisible).
//!
//! `benches/bench_infer.rs` records prefill/decode tokens-per-second and
//! `benches/bench_serve.rs` replays a seeded multi-client workload
//! (p50/p99 latency, tokens/sec, page high-water mark) into
//! `BENCH_infer.json` / `BENCH_serve.json` for the CI perf gate;
//! `examples/serve_batch.rs` demonstrates the serving path end to end.

pub mod engine;
pub mod kv;
pub mod serve;
pub mod spec;
pub mod tenant;

pub use engine::{
    Admission, BatchEngine, Completion, EngineStats, FinishReason, Request, StepEvent,
};
pub use kv::KvCache;
pub use serve::{Clock, Server, SubmitError, TokenSink, WallClock};
pub use spec::SpecConfig;
pub use tenant::AdapterRegistry;

use crate::model::Model;
use crate::tensor::Workspace;
use crate::util::prng::Rng;

/// How to turn logits into tokens, and when to stop.
#[derive(Clone, Debug)]
pub struct GenerateConfig {
    /// Maximum tokens to generate (the cache capacity may stop earlier).
    pub max_new: usize,
    /// Stop (without emitting) when this token is sampled.
    pub eos: Option<u32>,
    /// Softmax temperature; `<= 0` means greedy argmax decoding.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` most likely tokens (0 = full
    /// vocabulary). Ignored under greedy decoding.
    pub top_k: usize,
    /// Nucleus (top-p) cutoff: keep the smallest descending-probability
    /// prefix whose cumulative mass reaches `top_p`, renormalize, sample.
    /// `>= 1.0` disables the filter (the exact pre-nucleus code paths
    /// run); composes with `top_k` (the nucleus is taken inside the top-k
    /// candidate set); ignored under greedy decoding.
    pub top_p: f32,
    /// Seed for the sampling RNG (`util::prng`): a fixed seed yields a
    /// fixed token stream.
    pub seed: u64,
}

impl Default for GenerateConfig {
    fn default() -> Self {
        GenerateConfig {
            max_new: 32,
            eos: None,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
        }
    }
}

impl GenerateConfig {
    /// Greedy decoding for up to `max_new` tokens.
    pub fn greedy(max_new: usize) -> GenerateConfig {
        GenerateConfig {
            max_new,
            ..GenerateConfig::default()
        }
    }

    /// Temperature/top-k sampling for up to `max_new` tokens.
    pub fn sampled(max_new: usize, temperature: f32, top_k: usize, seed: u64) -> GenerateConfig {
        GenerateConfig {
            max_new,
            temperature,
            top_k,
            seed,
            ..GenerateConfig::default()
        }
    }

    /// Nucleus (top-p) sampling for up to `max_new` tokens.
    pub fn nucleus(max_new: usize, temperature: f32, top_p: f32, seed: u64) -> GenerateConfig {
        GenerateConfig {
            max_new,
            temperature,
            top_p,
            seed,
            ..GenerateConfig::default()
        }
    }
}

/// Greedy argmax keeping the **last** maximal element on ties — the one
/// shared copy of the crate's greedy convention (`Model::generate` and
/// `train::eval` follow it; the decode-parity suite compares against it).
pub fn argmax(row: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (j, &v) in row.iter().enumerate() {
        if v >= best_v {
            best_v = v;
            best = j;
        }
    }
    best as u32
}

/// Sample one token from a logits row under `cfg`: greedy when
/// `temperature <= 0`, else softmax over the `top_k` largest logits at the
/// given temperature, optionally nucleus-filtered to the smallest
/// descending-probability prefix reaching `top_p` cumulative mass. Fully
/// deterministic given the RNG state: exactly one uniform is drawn per
/// non-greedy call and candidates are walked in a fixed order (index
/// order for the full vocabulary, descending-logit order under
/// top-k/top-p), so a fixed seed yields a fixed stream. The degenerate
/// settings take the degenerate paths: `temperature <= 0` is argmax
/// (never touches the RNG), `top_p >= 1.0` runs the exact pre-nucleus
/// branches, `top_k = 0` imposes no candidate cut.
pub fn sample_token(logits: &[f32], cfg: &GenerateConfig, rng: &mut Rng) -> u32 {
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    let inv_t = 1.0 / cfg.temperature;
    let u = rng.uniform();
    if cfg.top_p < 1.0 {
        // nucleus (top-p): rank candidates by descending logit (ties by
        // index — same comparator as top-k), pre-filtered to the top_k
        // set when one is configured, keep the smallest prefix whose
        // cumulative probability reaches top_p, renormalize, and walk the
        // kept prefix in the same descending order.
        let desc = |a: &usize, b: &usize| logits[*b].total_cmp(&logits[*a]).then(a.cmp(b));
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if cfg.top_k > 0 && cfg.top_k < logits.len() {
            idx.select_nth_unstable_by(cfg.top_k - 1, desc);
            idx.truncate(cfg.top_k);
        }
        idx.sort_unstable_by(desc);
        let mx = logits[idx[0]];
        let sum: f32 = idx.iter().map(|&j| ((logits[j] - mx) * inv_t).exp()).sum();
        let inv = 1.0 / sum;
        // ≥ 1 candidate always survives, so top_p <= 0 degenerates to
        // the single most-likely token
        let mut kept = idx.len();
        let mut acc = 0.0f32;
        for (r, &j) in idx.iter().enumerate() {
            acc += ((logits[j] - mx) * inv_t).exp() * inv;
            if acc >= cfg.top_p {
                kept = r + 1;
                break;
            }
        }
        idx.truncate(kept);
        let nsum: f32 = idx.iter().map(|&j| ((logits[j] - mx) * inv_t).exp()).sum();
        let ninv = 1.0 / nsum;
        let mut acc = 0.0f32;
        for &j in &idx {
            acc += ((logits[j] - mx) * inv_t).exp() * ninv;
            if u < acc {
                return j as u32;
            }
        }
        return *idx.last().expect("nucleus keeps >= 1 candidate") as u32; // rounding slack
    }
    if cfg.top_k == 0 || cfg.top_k >= logits.len() {
        // full vocabulary: no ranking needed — softmax and walk in index
        // order (any fixed order samples the same categorical)
        let mx = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let sum: f32 = logits.iter().map(|&l| ((l - mx) * inv_t).exp()).sum();
        let inv = 1.0 / sum;
        let mut acc = 0.0f32;
        for (j, &l) in logits.iter().enumerate() {
            acc += ((l - mx) * inv_t).exp() * inv;
            if u < acc {
                return j as u32;
            }
        }
        return (logits.len() - 1) as u32; // rounding slack
    }
    // top-k: select the k largest (descending logit, ties broken by index
    // for reproducibility) without sorting the whole vocabulary
    let k = cfg.top_k.max(1);
    let desc = |a: &usize, b: &usize| logits[*b].total_cmp(&logits[*a]).then(a.cmp(b));
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.select_nth_unstable_by(k - 1, desc);
    idx.truncate(k);
    idx.sort_unstable_by(desc);
    let mx = logits[idx[0]];
    let sum: f32 = idx.iter().map(|&j| ((logits[j] - mx) * inv_t).exp()).sum();
    let inv = 1.0 / sum;
    let mut acc = 0.0f32;
    for &j in &idx {
        acc += ((logits[j] - mx) * inv_t).exp() * inv;
        if u < acc {
            return j as u32;
        }
    }
    idx[k - 1] as u32 // rounding slack: fall back to the least likely kept
}

/// KV-cached generation for one request in `slot` (reset here). Returns
/// the generated tokens (without the prompt, without EOS). An empty or
/// over-long prompt yields an empty completion.
pub fn generate_cached(
    model: &Model,
    prompt: &[u32],
    cfg: &GenerateConfig,
    kv: &mut KvCache,
    slot: usize,
    ws: &mut Workspace,
) -> Vec<u32> {
    let mut out = Vec::new();
    if prompt.is_empty()
        || cfg.max_new == 0
        || model.n_virtual() + prompt.len() > model.cfg.max_seq
    {
        return out;
    }
    kv.reset_slot(slot);
    let mut rng = Rng::new(cfg.seed);
    let logits = model.prefill(prompt, slot, kv, ws);
    let mut next = sample_token(logits.row(0), cfg, &mut rng);
    ws.recycle(logits);
    loop {
        if cfg.eos == Some(next) {
            break;
        }
        out.push(next);
        if out.len() >= cfg.max_new || kv.len(slot) >= model.cfg.max_seq {
            break;
        }
        let logits = model.decode_step(&[next], &[slot], kv, ws);
        next = sample_token(logits.row(0), cfg, &mut rng);
        ws.recycle(logits);
    }
    out
}

/// Reference decoding without a cache: re-forward the whole growing
/// sequence each step (frozen-state, like the cached path). Identical
/// output to [`generate_cached`] — kept as the parity oracle and as the
/// baseline `bench_infer` measures the cache against.
pub fn generate_uncached(
    model: &Model,
    prompt: &[u32],
    cfg: &GenerateConfig,
    ws: &mut Workspace,
) -> Vec<u32> {
    let mut out = Vec::new();
    let nv = model.n_virtual();
    if prompt.is_empty() || cfg.max_new == 0 || nv + prompt.len() > model.cfg.max_seq {
        return out;
    }
    let mut rng = Rng::new(cfg.seed);
    let mut seq = prompt.to_vec();
    let logits = model.forward_infer(&[seq.clone()], ws);
    let mut next = sample_token(logits.row(logits.rows() - 1), cfg, &mut rng);
    ws.recycle(logits);
    loop {
        if cfg.eos == Some(next) {
            break;
        }
        out.push(next);
        seq.push(next);
        // same stop rule as the cached path: the next step would embed at
        // cache position nv + seq.len() - 1, which must fit max_seq
        if out.len() >= cfg.max_new || nv + seq.len() > model.cfg.max_seq {
            break;
        }
        let logits = model.forward_infer(&[seq.clone()], ws);
        next = sample_token(logits.row(logits.rows() - 1), cfg, &mut rng);
        ws.recycle(logits);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_keeps_last_tied_max() {
        let mut rng = Rng::new(1);
        let cfg = GenerateConfig::greedy(4);
        assert_eq!(sample_token(&[0.0, 1.0, 1.0, -2.0], &cfg, &mut rng), 2);
    }

    #[test]
    fn sampling_is_seed_deterministic_and_respects_top_k() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32) * 0.3).collect();
        let cfg = GenerateConfig::sampled(4, 0.8, 3, 7);
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..64 {
            let ta = sample_token(&logits, &cfg, &mut a);
            let tb = sample_token(&logits, &cfg, &mut b);
            assert_eq!(ta, tb, "same RNG state must sample the same token");
            // top-3 of an increasing ramp = the last three indices
            assert!((13..16).contains(&(ta as usize)), "token {ta} outside top-k");
        }
    }

    #[test]
    fn greedy_ignores_top_p_and_never_touches_the_rng() {
        let logits = [0.0f32, 3.0, 1.0];
        let mut cfg = GenerateConfig::greedy(4);
        cfg.top_p = 0.01;
        let mut rng = Rng::new(5);
        let before = rng.uniform();
        let mut rng = Rng::new(5);
        for _ in 0..8 {
            assert_eq!(sample_token(&logits, &cfg, &mut rng), 1);
        }
        assert_eq!(rng.uniform(), before, "greedy must not consume the RNG");
    }

    #[test]
    fn nucleus_keeps_the_smallest_sufficient_prefix() {
        // probabilities at temperature 1: [8, 4, 2, 1] / 15
        let logits: Vec<f32> = [8.0f32, 4.0, 2.0, 1.0].iter().map(|p| p.ln()).collect();
        let mut rng = Rng::new(11);
        // p(0) ≈ 0.533 alone reaches 0.5 — the nucleus is exactly {0}
        let tight = GenerateConfig::nucleus(1, 1.0, 0.5, 0);
        for _ in 0..64 {
            assert_eq!(sample_token(&logits, &tight, &mut rng), 0);
        }
        // p(0) + p(1) ≈ 0.8 reaches 0.79 — the nucleus is exactly {0, 1}
        let wide = GenerateConfig::nucleus(1, 1.0, 0.79, 0);
        let mut seen = [0usize; 4];
        for _ in 0..400 {
            seen[sample_token(&logits, &wide, &mut rng) as usize] += 1;
        }
        assert_eq!(seen[2] + seen[3], 0, "outside the nucleus: {seen:?}");
        assert!(seen[0] > 0 && seen[1] > 0, "whole nucleus reachable: {seen:?}");
    }

    #[test]
    fn nucleus_is_seed_deterministic_and_composes_with_top_k() {
        let logits: Vec<f32> = (0..12).map(|i| (i as f32) * 0.4).collect();
        let mut cfg = GenerateConfig::nucleus(1, 0.9, 0.95, 0);
        cfg.top_k = 3; // nucleus taken inside the top-3 candidate set
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        for _ in 0..64 {
            let ta = sample_token(&logits, &cfg, &mut a);
            let tb = sample_token(&logits, &cfg, &mut b);
            assert_eq!(ta, tb, "same RNG state must sample the same token");
            assert!((9..12).contains(&(ta as usize)), "token {ta} outside top-k");
        }
    }

    #[test]
    fn top_p_one_runs_the_pre_nucleus_paths() {
        // the comparison below is only meaningful because top_p = 1.0 is
        // the *disabled* branch: both configs must walk the identical
        // full-vocab index-order path drawing the identical uniform
        let logits: Vec<f32> = (0..8).map(|i| ((i * 7) % 5) as f32 * 0.6).collect();
        let base = GenerateConfig::sampled(1, 1.3, 0, 0);
        let mut explicit = base.clone();
        explicit.top_p = 1.0;
        let mut a = Rng::new(33);
        let mut b = Rng::new(33);
        for _ in 0..64 {
            assert_eq!(
                sample_token(&logits, &base, &mut a),
                sample_token(&logits, &explicit, &mut b),
            );
        }
    }

    #[test]
    fn high_temperature_spreads_low_sharpens() {
        let logits = [0.0f32, 0.5, 1.0, 4.0];
        let mut rng = Rng::new(3);
        let mut hot = [0usize; 4];
        let cfg_hot = GenerateConfig::sampled(1, 8.0, 0, 0);
        for _ in 0..400 {
            hot[sample_token(&logits, &cfg_hot, &mut rng) as usize] += 1;
        }
        assert!(hot.iter().all(|&c| c > 0), "hot sampling must reach all tokens: {hot:?}");
        let cfg_cold = GenerateConfig::sampled(1, 0.05, 0, 0);
        for _ in 0..50 {
            assert_eq!(sample_token(&logits, &cfg_cold, &mut rng), 3);
        }
    }
}
