//! Request front-end for the serving tier: bounded admission queue with
//! backpressure, per-request deadlines and cancellation, and incremental
//! token delivery — a synchronous core around [`BatchEngine`].
//!
//! [`Server::submit`] enqueues a request (refusing with
//! [`SubmitError::QueueFull`] once `queue_cap` requests are waiting —
//! backpressure the caller must handle by retrying later), and each
//! [`Server::pump`] advances one scheduling round: expire deadlines,
//! admit from the queue while the engine has slots *and* pages, run one
//! [`BatchEngine::step`], and dispatch the resulting [`StepEvent`]s to
//! each request's [`TokenSink`]. The core is deliberately synchronous and
//! single-threaded — parallelism lives *inside* the stacked decode step
//! (`tensor::pool`), where it is proven bit-identical to serial — so an
//! async runtime can wrap `pump` in a timer loop without changing any
//! result.
//!
//! **Time is logical by default.** Deadlines are measured against
//! [`Server::now`], which (absent a clock) advances by exactly one per
//! pump round — so a scenario (submission schedule + deadlines + seed)
//! replays identically on any machine, which is what lets
//! `tests/serve_parity.rs` assert completions byte-for-byte and
//! `benches/bench_serve.rs` replay a fixed workload against the gate.
//! Deployments that want real-time deadlines plug a [`Clock`] in with
//! [`Server::set_clock`] — [`WallClock`] reads elapsed milliseconds from
//! [`std::time::Instant`] — and submit deadlines in that clock's unit.
//! `now` is clamped monotone non-decreasing regardless of the source, so
//! a misbehaving clock can revive nothing and expire nothing twice.
//!
//! **Arrival order does not change results.** A request's token stream
//! depends only on its id, prompt and the engine seed (row-local decode +
//! per-request RNG streams; see `model::decode` and `infer::engine`).
//! Queueing, slot assignment, paging and preemption decide only *when* a
//! request runs — never what it generates. Deadline expiry is the one
//! exception (a request cut short at tick `t` keeps its prefix), which is
//! why expiry happens at a deterministic point in the round.

use std::collections::VecDeque;

use super::engine::{Admission, BatchEngine, Completion, FinishReason, Request, StepEvent};
use super::GenerateConfig;
use crate::model::Model;
use crate::peft::TenantAdapters;

/// Receiver for a request's incremental output. Implementations get every
/// resolved token as it leaves the engine, then the final [`Completion`]
/// (whose `tokens` repeat the streamed prefix). Default methods discard.
pub trait TokenSink {
    /// A token was resolved into the request's output stream.
    fn on_token(&mut self, _token: u32) {}
    /// The request finished (any [`FinishReason`], including expiry and
    /// cancellation).
    fn on_finish(&mut self, _completion: &Completion) {}
}

/// Pluggable time source for [`Server`] deadlines. `reading` returns the
/// current absolute time in whatever unit the deployment's deadlines use;
/// the server clamps successive readings monotone non-decreasing, so a
/// clock that jumps backwards merely stalls `now` rather than reviving
/// expired requests. Without a clock installed, time is *logical*: one
/// tick per pump round.
pub trait Clock {
    /// Current absolute reading (same unit as submitted deadlines).
    fn reading(&mut self) -> u64;
}

/// Wall-clock [`Clock`]: milliseconds elapsed since construction, read
/// from [`std::time::Instant`] (monotonic by construction). Install with
/// [`Server::set_clock`] and submit deadlines in absolute milliseconds.
pub struct WallClock {
    start: std::time::Instant,
}

impl WallClock {
    /// A clock whose reading is `0` now and counts milliseconds upward.
    #[allow(clippy::new_without_default)]
    pub fn new() -> WallClock {
        WallClock {
            start: std::time::Instant::now(),
        }
    }
}

impl Clock for WallClock {
    fn reading(&mut self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }
}

/// Why [`Server::submit`] refused a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission queue is at capacity — backpressure; retry after
    /// pumping.
    QueueFull,
}

/// Where a submitted request currently lives.
enum State {
    /// Waiting in the admission queue.
    Queued,
    /// Admitted to the engine under this tag.
    Engine(u64),
    /// Finished (completion delivered).
    Done,
}

/// Per-request bookkeeping, indexed by ticket.
struct Entry {
    state: State,
    /// Absolute logical deadline (pump round); `None` = no deadline.
    deadline: Option<u64>,
    sink: Option<Box<dyn TokenSink>>,
}

/// Bounded-queue serving front-end over one [`BatchEngine`]. See the
/// module docs for semantics.
pub struct Server {
    engine: BatchEngine,
    queue: VecDeque<(u64, Request)>,
    queue_cap: usize,
    entries: Vec<Entry>,
    /// Engine tag → ticket, in admission order (tags strictly increase).
    tags: Vec<(u64, u64)>,
    finished: Vec<Completion>,
    events: Vec<StepEvent>,
    now: u64,
    /// Time source; `None` = logical time (one tick per pump).
    clock: Option<Box<dyn Clock>>,
}

impl Server {
    /// A server over the contiguous-equivalent cache: `slots` lanes, each
    /// able to hold a full sequence, and room for `queue_cap` waiting
    /// requests.
    pub fn new(model: &Model, slots: usize, queue_cap: usize, cfg: GenerateConfig) -> Server {
        Server::from_engine(BatchEngine::new(model, slots, cfg), queue_cap)
    }

    /// A server over a paged cache (`n_pages × page_rows` shared rows) —
    /// the production shape: more slots than the pool could hold at full
    /// length, relying on paging + preemption under pressure.
    pub fn with_paging(
        model: &Model,
        slots: usize,
        page_rows: usize,
        n_pages: usize,
        queue_cap: usize,
        cfg: GenerateConfig,
    ) -> Server {
        Server::from_engine(
            BatchEngine::with_paging(model, slots, page_rows, n_pages, cfg),
            queue_cap,
        )
    }

    /// A server over an engine built elsewhere — e.g. a speculative one
    /// ([`BatchEngine::with_spec`]) or one with pre-set tenant quotas.
    pub fn from_engine(engine: BatchEngine, queue_cap: usize) -> Server {
        assert!(queue_cap > 0, "a server needs a non-empty admission queue");
        Server {
            engine,
            queue: VecDeque::new(),
            queue_cap,
            entries: Vec::new(),
            tags: Vec::new(),
            finished: Vec::new(),
            events: Vec::new(),
            now: 0,
            clock: None,
        }
    }

    /// Install a time source for deadline expiry (e.g. [`WallClock`]).
    /// From the next [`Server::pump`] on, `now` follows the clock's
    /// readings (clamped monotone non-decreasing) instead of advancing by
    /// one per round. Deadlines already submitted are reinterpreted in
    /// the new clock's unit — install the clock before submitting.
    pub fn set_clock(&mut self, clock: Box<dyn Clock>) {
        self.clock = Some(clock);
    }

    /// Cap tenant `id` at `max_inflight` simultaneously admitted requests
    /// (`None` removes the cap). Requests over quota are rejected at
    /// admission with [`FinishReason::Quota`] — a distinct reason so
    /// callers can tell policy from capacity ([`SubmitError::QueueFull`]
    /// / engine `Busy`). Forwarded to [`BatchEngine::set_quota`].
    pub fn set_quota(&mut self, id: u64, max_inflight: Option<usize>) {
        self.engine.set_quota(id, max_inflight);
    }

    /// Submit a request with no deadline and no sink. Returns a ticket
    /// for [`Server::cancel`], or [`SubmitError::QueueFull`].
    pub fn submit(&mut self, req: Request) -> Result<u64, SubmitError> {
        self.submit_opts(req, None, None)
    }

    /// Submit with an optional **absolute** logical deadline (the request
    /// is expired with [`FinishReason::Deadline`] at the first pump round
    /// where `now ≥ deadline`, keeping any tokens generated so far) and
    /// an optional per-request sink for incremental delivery.
    pub fn submit_opts(
        &mut self,
        req: Request,
        deadline: Option<u64>,
        sink: Option<Box<dyn TokenSink>>,
    ) -> Result<u64, SubmitError> {
        if self.queue.len() >= self.queue_cap {
            return Err(SubmitError::QueueFull);
        }
        let ticket = self.entries.len() as u64;
        self.entries.push(Entry {
            state: State::Queued,
            deadline,
            sink,
        });
        self.queue.push_back((ticket, req));
        Ok(ticket)
    }

    /// Cancel a submitted request (queued or in flight). Its partial
    /// completion (reason [`FinishReason::Cancelled`]) is delivered like
    /// any other. Returns `false` if the ticket already finished.
    pub fn cancel(&mut self, ticket: u64) -> bool {
        let tag = match self.entries.get(ticket as usize).map(|e| &e.state) {
            None | Some(State::Done) => return false,
            Some(State::Queued) => None,
            Some(State::Engine(t)) => Some(*t),
        };
        self.retire(ticket, tag, FinishReason::Cancelled);
        true
    }

    /// One scheduling round. Returns `true` while any request is queued
    /// or in flight — `while server.pump(&model) {}` drains everything
    /// (see [`Server::run_until_idle`]).
    pub fn pump(&mut self, model: &Model) -> bool {
        self.now = match self.clock.as_mut() {
            // logical time: one tick per round, deterministic replay
            None => self.now + 1,
            // external time, clamped monotone so `now` never runs back
            Some(clock) => self.now.max(clock.reading()),
        };
        self.expire();
        // admit in submission order while the engine takes them; the
        // front blocks the line (no overtaking — keeps admission fair and
        // arrival-order reasoning simple)
        while let Some((ticket, req)) = self.queue.pop_front() {
            match self.engine.try_admit(model, &req) {
                Admission::Admitted(tag) => {
                    self.tags.push((tag, ticket));
                    self.entries[ticket as usize].state = State::Engine(tag);
                }
                Admission::Rejected(c) => self.finish(ticket, c),
                Admission::Busy => {
                    self.queue.push_front((ticket, req));
                    break;
                }
            }
        }
        let mut events = std::mem::take(&mut self.events);
        let more = self.engine.step(model, &mut events);
        for ev in events.drain(..) {
            match ev {
                StepEvent::Token { tag, token, .. } => {
                    let ticket = self.ticket_of(tag);
                    if let Some(sink) = self.entries[ticket as usize].sink.as_mut() {
                        sink.on_token(token);
                    }
                }
                StepEvent::Finished { tag, completion } => {
                    let ticket = self.ticket_of(tag);
                    self.finish(ticket, completion);
                }
                StepEvent::Preempted { .. } | StepEvent::Resumed { .. } => {}
            }
        }
        self.events = events;
        more || !self.queue.is_empty()
    }

    /// Pump until every submitted request has finished.
    pub fn run_until_idle(&mut self, model: &Model) {
        while self.pump(model) {}
    }

    /// Take all completions delivered since the last drain (finish
    /// order).
    pub fn drain_finished(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.finished)
    }

    /// Current time: pump rounds so far under logical time, or the last
    /// clamped [`Clock`] reading when one is installed.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Requests waiting in the admission queue.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// The underlying engine (stats, page gauges).
    pub fn engine(&self) -> &BatchEngine {
        &self.engine
    }

    /// The underlying engine, mutably (tenant registry administration).
    pub fn engine_mut(&mut self) -> &mut BatchEngine {
        &mut self.engine
    }

    /// Install (or hot-swap) tenant `id`'s adapter stack. Takes effect at
    /// the next [`Server::pump`]; requests already decoding for other
    /// tenants are bitwise-unaffected. Returns the replaced stack.
    pub fn install_tenant(&mut self, id: u64, adapters: TenantAdapters) -> Option<TenantAdapters> {
        self.engine.registry_mut().install(id, adapters)
    }

    /// Remove tenant `id`, returning its stack. In-flight requests of
    /// that tenant finish with [`FinishReason::Cancelled`] at the next
    /// pump; queued requests are rejected at admission.
    pub fn remove_tenant(&mut self, id: u64) -> Option<TenantAdapters> {
        self.engine.registry_mut().remove(id)
    }

    /// Expire every live request whose deadline has passed.
    fn expire(&mut self) {
        for ticket in 0..self.entries.len() as u64 {
            let e = &self.entries[ticket as usize];
            let tag = match (&e.state, e.deadline) {
                (State::Done, _) | (_, None) => continue,
                (_, Some(d)) if self.now < d => continue,
                (State::Queued, Some(_)) => None,
                (State::Engine(t), Some(_)) => Some(*t),
            };
            self.retire(ticket, tag, FinishReason::Deadline);
        }
    }

    /// Pull a live request out of the queue (`tag == None`) or the engine
    /// (`tag == Some`) and deliver its partial completion with `reason`.
    fn retire(&mut self, ticket: u64, tag: Option<u64>, reason: FinishReason) {
        let completion = match tag {
            None => {
                let qi = self
                    .queue
                    .iter()
                    .position(|(t, _)| *t == ticket)
                    .expect("queued entry is in the queue");
                let (_, req) = self.queue.remove(qi).expect("position is in range");
                Completion {
                    id: req.id,
                    prompt_len: req.prompt.len(),
                    tokens: Vec::new(),
                    reason,
                }
            }
            Some(tag) => self
                .engine
                .cancel(tag, reason)
                .expect("engine-state entry is in flight"),
        };
        self.finish(ticket, completion);
    }

    /// Deliver a completion: notify the sink, mark done, stash for
    /// [`Server::drain_finished`].
    fn finish(&mut self, ticket: u64, completion: Completion) {
        let e = &mut self.entries[ticket as usize];
        if let Some(sink) = e.sink.as_mut() {
            sink.on_finish(&completion);
        }
        e.state = State::Done;
        e.sink = None;
        self.finished.push(completion);
    }

    /// Ticket behind an engine tag (tags strictly increase → binary
    /// search).
    fn ticket_of(&self, tag: u64) -> u64 {
        let i = self
            .tags
            .binary_search_by_key(&tag, |&(t, _)| t)
            .expect("event tag was admitted here");
        self.tags[i].1
    }
}
