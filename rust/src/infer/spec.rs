//! Self-speculative decoding: truncated-layer drafting with a batched
//! full-model verify, **bit-identical** to plain greedy decode.
//!
//! One spec round per [`BatchEngine`](super::BatchEngine) scheduling step
//! replaces the plain stacked decode:
//!
//! 1. **Draft.** Each request runs `draft_len` cheap forward passes
//!    through only the first `draft_layers` blocks (the final LayerNorm +
//!    lm head applied to the mid-layer representation), proposing one
//!    token per pass. Draft K/V rows land in a dedicated per-slot draft
//!    page table ([`KvCache::begin_draft`](super::KvCache::begin_draft))
//!    drawn from the *same* shared page pool, so admission and preemption
//!    accounting stay exact while drafting.
//! 2. **Verify.** The pending token plus all `k` drafts run through the
//!    **full** model as one stacked `k+1`-row pass
//!    (`Model::verify_step_tenants`) writing the *main* page table. Row
//!    `j`'s argmax is the full model's next token after the first `j`
//!    drafts.
//! 3. **Accept.** The longest prefix of drafts matching the full model's
//!    argmaxes is accepted; the first non-matching verify row supplies
//!    the next pending token (a "bonus" token when every draft matched).
//!    Rejected rows are rolled back with
//!    [`KvCache::truncate_to`](super::KvCache::truncate_to) — a pure
//!    page-table truncation.
//!
//! **Why greedy acceptance is bitwise-lossless.** Every emitted token is
//! an argmax of *full-model* verify logits, and the verify pass is the
//! row-local [`Model::decode_step`](crate::model::Model::decode_step)
//! arithmetic stacked `k+1` rows deep — bitwise equal to `k+1` sequential
//! decode steps (`model::decode` docs). Draft tokens only *select which
//! positions get verified this round*; a wrong draft costs a rolled-back
//! row, never a changed token. Induction over rounds gives exact equality
//! with plain cached greedy decode — pinned for all six methods ×
//! {contiguous, paged} × thread widths by `tests/spec_parity.rs`.
//!
//! Sampled paths (`temperature > 0`) and tenant-mixed batches fall back
//! to plain decode — speculative sampling needs a rejection-sampling
//! acceptance rule to stay distribution-exact, which is follow-up work.

/// Speculative-decode geometry: how deep the draft model is and how many
/// tokens it proposes per verify.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpecConfig {
    /// Blocks the draft pass runs (`1..=n_layers`). Smaller is cheaper
    /// per draft but accepts fewer tokens per verify.
    pub draft_layers: usize,
    /// Draft tokens proposed per verify round (`>= 1`). A request with
    /// less cache or budget headroom drafts fewer; `k = 0` rounds
    /// degenerate to the plain single-row decode.
    pub draft_len: usize,
}

impl SpecConfig {
    /// Panics unless `draft_layers ∈ 1..=n_layers` and `draft_len >= 1`.
    pub fn validate(&self, n_layers: usize) {
        assert!(
            self.draft_layers >= 1 && self.draft_layers <= n_layers,
            "SpecConfig.draft_layers must be in 1..={n_layers}, got {}",
            self.draft_layers
        );
        assert!(self.draft_len >= 1, "SpecConfig.draft_len must be >= 1");
    }
}

/// Longest accepted draft prefix: the number of leading positions where
/// the drafted token equals the full model's verified token for the same
/// position. `verified[j]` is the full-model argmax *after* consuming
/// drafts `0..j`, so draft `j` is acceptable iff it equals `verified[j]`
/// and every earlier draft was accepted. `verified` has one extra row
/// (the bonus position); only the first `drafts.len()` entries are
/// consulted.
pub fn accepted_prefix(drafts: &[u32], verified: &[u32]) -> usize {
    debug_assert!(verified.len() > drafts.len(), "verify emits k+1 rows");
    drafts
        .iter()
        .zip(verified)
        .take_while(|(d, v)| d == v)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepted_prefix_is_the_longest_matching_prefix() {
        assert_eq!(accepted_prefix(&[], &[9]), 0);
        assert_eq!(accepted_prefix(&[7], &[7, 9]), 1);
        assert_eq!(accepted_prefix(&[7], &[8, 9]), 0);
        assert_eq!(accepted_prefix(&[1, 2, 3], &[1, 2, 3, 4]), 3);
        assert_eq!(accepted_prefix(&[1, 9, 3], &[1, 2, 3, 4]), 1);
        // a later match after a mismatch must NOT count: position 2's
        // verify row was conditioned on the rejected draft
        assert_eq!(accepted_prefix(&[9, 2, 3], &[1, 2, 3, 4]), 0);
    }

    #[test]
    fn validate_accepts_the_full_range() {
        SpecConfig {
            draft_layers: 1,
            draft_len: 1,
        }
        .validate(4);
        SpecConfig {
            draft_layers: 4,
            draft_len: 8,
        }
        .validate(4);
    }

    #[test]
    #[should_panic(expected = "draft_layers")]
    fn validate_rejects_zero_depth() {
        SpecConfig {
            draft_layers: 0,
            draft_len: 2,
        }
        .validate(4);
    }

    #[test]
    #[should_panic(expected = "draft_len")]
    fn validate_rejects_zero_len() {
        SpecConfig {
            draft_layers: 2,
            draft_len: 0,
        }
        .validate(4);
    }
}
