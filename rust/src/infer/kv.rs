//! Paged per-block key/value cache for incremental decoding.
//!
//! One [`KvCache`] holds the K and V activations of **every** decoder block
//! for a fixed number of request *slots*. Storage is a pool of fixed-size
//! **pages** (`page_rows` cache rows each) carved out of [`Workspace`] f32
//! lanes (two lanes per block: one K, one V), with a per-slot **page
//! table** mapping logical cache positions to physical pages:
//!
//! * in-flight requests share one page pool, so a short request holds
//!   `ceil(rows / page_rows)` pages instead of reserving `max_seq` rows;
//! * the same page table serves every layer — page `p` names rows
//!   `[p·page_rows, (p+1)·page_rows)` in **each** of the `2·n_layers`
//!   lanes, so allocating one page grows a slot in all blocks at once;
//! * preemption/eviction is a page-table edit ([`KvCache::reset_slot`]
//!   returns the slot's pages to the free list; nothing is copied or
//!   freed) and readmission is a fresh [`KvCache::reserve`] + re-prefill.
//!
//! [`KvCache::new`]/[`KvCache::for_model`] build the **contiguous
//! equivalent** — one `max_seq`-row page per slot — which behaves exactly
//! like the pre-paging cache (every slot can always hold a full sequence).
//! [`KvCache::paged`] picks the page geometry explicitly. Physical page
//! placement never affects decoded values (the page table only relocates
//! rows; their contents and read order are unchanged), so paged and
//! contiguous decode are **bitwise identical** — pinned for every method,
//! page size and thread width by `tests/serve_parity.rs`.
//!
//! Lane layout: lane `2·layer` is K, lane `2·layer + 1` is V; within a
//! lane, physical page `p`'s row `r` starts at `(p · page_rows + r) · d`.

use crate::model::Model;
use crate::tensor::Workspace;

/// Pooled, grow-only, paged K/V storage for `slots` concurrent requests.
/// See the module docs for the page-table layout.
pub struct KvCache {
    /// `2 · n_layers` workspace lanes (K then V per layer), each sized
    /// `n_pages · page_rows · d`. The pooled lane set may carry extra
    /// lanes from a wider earlier take; only the first `2 · n_layers` are
    /// used.
    lanes: Vec<Vec<f32>>,
    n_layers: usize,
    d: usize,
    max_seq: usize,
    page_rows: usize,
    n_pages: usize,
    slots: usize,
    /// Per-slot page table: physical page ids, in logical order. Cleared
    /// (capacity retained) on [`KvCache::reset_slot`].
    tables: Vec<Vec<usize>>,
    /// Cached rows per slot (counting virtual tokens). 0 = slot is free.
    lens: Vec<usize>,
    /// Per-slot **draft** page table (speculative decoding). Draft rows
    /// are packed relative to [`KvCache::draft_base`] and drawn from the
    /// same free pool as main pages, so admission/preemption accounting
    /// stays exact. Always empty outside a draft round.
    draft_tables: Vec<Vec<usize>>,
    /// Draft rows per slot (0 outside a draft round).
    draft_lens: Vec<usize>,
    /// Logical position draft row 0 maps to (= `lens[slot]` at
    /// [`KvCache::begin_draft`] time).
    draft_bases: Vec<usize>,
    /// Free physical pages (LIFO; seeded in descending order so pages
    /// allocate ascending — deterministic placement for diagnostics).
    free: Vec<usize>,
    /// Most pages ever simultaneously allocated (capacity-planning signal
    /// reported by `bench_serve`).
    hwm: usize,
}

impl KvCache {
    /// The contiguous equivalent: one `max_seq`-row page per slot, so
    /// every slot can always hold a full sequence (exactly the pre-paging
    /// behaviour). Backing buffers come from `ws` (key `"infer.kv"`), so
    /// building a cache after a release reuses the previous allocation.
    pub fn new(
        n_layers: usize,
        d: usize,
        max_seq: usize,
        slots: usize,
        ws: &mut Workspace,
    ) -> KvCache {
        KvCache::paged(n_layers, d, max_seq, max_seq, slots, slots, ws)
    }

    /// A paged cache: `n_pages` shared pages of `page_rows` rows each for
    /// `slots` concurrent requests. Requires `n_pages · page_rows ≥
    /// max_seq` so a single request can always run to the cache limit —
    /// without it a request could starve against its own pool.
    pub fn paged(
        n_layers: usize,
        d: usize,
        max_seq: usize,
        page_rows: usize,
        n_pages: usize,
        slots: usize,
        ws: &mut Workspace,
    ) -> KvCache {
        assert!(n_layers > 0 && d > 0 && max_seq > 0 && slots > 0);
        assert!(page_rows > 0 && n_pages > 0);
        assert!(
            n_pages * page_rows >= max_seq,
            "page pool ({n_pages} pages x {page_rows} rows) cannot hold one \
             max_seq ({max_seq}) request"
        );
        let mut lanes = ws.take_f32_lanes("infer.kv", 2 * n_layers);
        for lane in lanes.iter_mut().take(2 * n_layers) {
            lane.resize(n_pages * page_rows * d, 0.0);
        }
        KvCache {
            lanes,
            n_layers,
            d,
            max_seq,
            page_rows,
            n_pages,
            slots,
            tables: vec![Vec::new(); slots],
            lens: vec![0; slots],
            draft_tables: vec![Vec::new(); slots],
            draft_lens: vec![0; slots],
            draft_bases: vec![0; slots],
            free: (0..n_pages).rev().collect(),
            hwm: 0,
        }
    }

    /// [`KvCache::new`] (contiguous equivalent) sized from a model.
    pub fn for_model(model: &Model, slots: usize, ws: &mut Workspace) -> KvCache {
        KvCache::new(
            model.cfg.n_layers,
            model.cfg.d_model,
            model.cfg.max_seq,
            slots,
            ws,
        )
    }

    /// [`KvCache::paged`] sized from a model's layer count / width /
    /// sequence limit.
    pub fn for_model_paged(
        model: &Model,
        page_rows: usize,
        n_pages: usize,
        slots: usize,
        ws: &mut Workspace,
    ) -> KvCache {
        KvCache::paged(
            model.cfg.n_layers,
            model.cfg.d_model,
            model.cfg.max_seq,
            page_rows,
            n_pages,
            slots,
            ws,
        )
    }

    /// Hand the backing lanes back to the workspace pool.
    pub fn release(self, ws: &mut Workspace) {
        ws.put_f32_lanes("infer.kv", self.lanes);
    }

    /// Number of request slots.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Maximum cache positions per slot.
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Rows per page.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Total pages in the pool.
    pub fn pages_total(&self) -> usize {
        self.n_pages
    }

    /// Pages currently allocated to slots.
    pub fn pages_in_use(&self) -> usize {
        self.n_pages - self.free.len()
    }

    /// Most pages ever simultaneously allocated.
    pub fn pages_hwm(&self) -> usize {
        self.hwm
    }

    /// Cached rows for `slot` (0 = free / reset).
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// Free positions remaining in `slot` before the sequence limit (the
    /// shared pool may run out earlier — see [`KvCache::reserve`]).
    pub fn remaining(&self, slot: usize) -> usize {
        self.max_seq - self.lens[slot]
    }

    /// Rows `slot` can hold without another [`KvCache::reserve`].
    pub fn capacity_rows(&self, slot: usize) -> usize {
        self.tables[slot].len() * self.page_rows
    }

    /// Whether the free pool could back `rows` rows for a **reset** slot
    /// (the admission check for a new request of `rows` prompt rows).
    pub fn can_admit(&self, rows: usize) -> bool {
        rows <= self.max_seq && self.free.len() * self.page_rows >= rows
    }

    /// Ensure `slot` can hold `n` more rows, allocating pages from the
    /// free pool as needed. Returns `false` (with any partial allocation
    /// retained for a later retry) when the pool is exhausted — the
    /// caller preempts or waits. Idempotent once capacity covers the
    /// request.
    pub fn reserve(&mut self, slot: usize, n: usize) -> bool {
        let need = self.lens[slot] + n;
        assert!(need <= self.max_seq, "KvCache slot {slot} overflow");
        while self.tables[slot].len() * self.page_rows < need {
            match self.free.pop() {
                Some(p) => self.tables[slot].push(p),
                None => return false,
            }
            self.hwm = self.hwm.max(self.pages_in_use());
        }
        true
    }

    /// Mark `slot` empty and return its pages to the free pool — a pure
    /// page-table edit (rows are overwritten by the next user; nothing is
    /// copied or freed). Doubles as the preemption/eviction primitive.
    /// Draft pages (if a draft round was in flight) are freed too.
    pub fn reset_slot(&mut self, slot: usize) {
        let free = &mut self.free;
        self.tables[slot].drain(..).for_each(|p| free.push(p));
        self.draft_tables[slot].drain(..).for_each(|p| free.push(p));
        self.lens[slot] = 0;
        self.draft_lens[slot] = 0;
        self.draft_bases[slot] = 0;
    }

    /// Roll `slot` back to exactly `pos` cached rows, returning any pages
    /// past `ceil(pos / page_rows)` to the free pool — the speculative-
    /// decode rejection primitive. A pure page-table truncation: surviving
    /// rows are untouched, so a subsequent decode from position `pos`
    /// reads bitwise-identical K/V. `pages_hwm` is monotone (truncation
    /// never lowers it).
    pub fn truncate_to(&mut self, slot: usize, pos: usize) {
        assert!(
            pos <= self.lens[slot],
            "KvCache truncate_to({pos}) past slot {slot} len {}",
            self.lens[slot]
        );
        let keep = pos.div_ceil(self.page_rows);
        while self.tables[slot].len() > keep {
            let p = self.tables[slot].pop().expect("len > keep > 0");
            self.free.push(p);
        }
        self.lens[slot] = pos;
    }

    /// Reset every slot.
    pub fn reset_all(&mut self) {
        for s in 0..self.slots {
            self.reset_slot(s);
        }
    }

    /// Open a draft round for `slot`: draft row 0 will map to logical
    /// position `len(slot)`. The previous draft round (if any) must have
    /// been closed with [`KvCache::end_draft`].
    pub fn begin_draft(&mut self, slot: usize) {
        assert!(
            self.draft_tables[slot].is_empty() && self.draft_lens[slot] == 0,
            "KvCache slot {slot} already has an open draft round"
        );
        self.draft_bases[slot] = self.lens[slot];
    }

    /// Ensure `slot`'s draft table can hold `n` more draft rows, pulling
    /// pages from the shared free pool. Returns `false` (partial
    /// allocation retained) when the pool is exhausted — the caller
    /// shrinks the draft or falls back to plain decode.
    pub fn draft_reserve(&mut self, slot: usize, n: usize) -> bool {
        let need = self.draft_lens[slot] + n;
        assert!(
            self.draft_bases[slot] + need <= self.max_seq,
            "KvCache slot {slot} draft overflow"
        );
        while self.draft_tables[slot].len() * self.page_rows < need {
            match self.free.pop() {
                Some(p) => self.draft_tables[slot].push(p),
                None => return false,
            }
            self.hwm = self.hwm.max(self.pages_in_use());
        }
        true
    }

    /// Draft rows currently cached for `slot`.
    pub fn draft_len(&self, slot: usize) -> usize {
        self.draft_lens[slot]
    }

    /// Logical position draft row 0 of `slot` maps to.
    pub fn draft_base(&self, slot: usize) -> usize {
        self.draft_bases[slot]
    }

    /// `slot`'s draft page table (physical page ids, rows packed relative
    /// to [`KvCache::draft_base`]).
    pub fn draft_table(&self, slot: usize) -> &[usize] {
        &self.draft_tables[slot]
    }

    /// Close `slot`'s draft round, returning every draft page to the free
    /// pool. Draft K/V is always discarded: the verify pass rewrites the
    /// accepted positions into the main table from the full model.
    pub fn end_draft(&mut self, slot: usize) {
        let free = &mut self.free;
        self.draft_tables[slot].drain(..).for_each(|p| free.push(p));
        self.draft_lens[slot] = 0;
    }

    /// Record that `slot` gained `n` draft rows (rows must have been
    /// [`KvCache::draft_reserve`]d).
    pub(crate) fn draft_advance(&mut self, slot: usize, n: usize) {
        let len = self.draft_lens[slot] + n;
        assert!(
            len <= self.draft_tables[slot].len() * self.page_rows,
            "KvCache slot {slot} draft advanced past its reserved pages"
        );
        self.draft_lens[slot] = len;
    }

    /// Write one draft K row and V row for `layer` at absolute logical
    /// position `pos` (which must be ≥ [`KvCache::draft_base`] and
    /// covered by the slot's reserved draft pages).
    pub(crate) fn draft_write_row(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        assert!(layer < self.n_layers && slot < self.slots);
        let rel = pos
            .checked_sub(self.draft_bases[slot])
            .expect("draft write below draft_base");
        assert!(
            rel < self.draft_tables[slot].len() * self.page_rows,
            "KvCache draft write at unreserved position {pos} of slot {slot}"
        );
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        let page = self.draft_tables[slot][rel / self.page_rows];
        let off = (page * self.page_rows + rel % self.page_rows) * self.d;
        self.lanes[2 * layer][off..off + self.d].copy_from_slice(k);
        self.lanes[2 * layer + 1][off..off + self.d].copy_from_slice(v);
    }

    /// Bytes of K/V storage held (diagnostics / memory accounting).
    pub fn nbytes(&self) -> usize {
        2 * self.n_layers * self.n_pages * self.page_rows * self.d * 4
    }

    /// `slot`'s page table (physical page ids in logical-row order).
    pub fn table(&self, slot: usize) -> &[usize] {
        &self.tables[slot]
    }

    /// Record that `slot` gained `n` cached rows (called once per
    /// prefill/decode step, after every layer wrote its K/V rows). The
    /// rows must have been [`KvCache::reserve`]d.
    pub(crate) fn advance(&mut self, slot: usize, n: usize) {
        let len = self.lens[slot] + n;
        assert!(len <= self.max_seq, "KvCache slot {slot} overflow");
        assert!(
            len <= self.capacity_rows(slot),
            "KvCache slot {slot} advanced past its reserved pages"
        );
        self.lens[slot] = len;
    }

    /// Write one K row and one V row for `layer` at `(slot, pos)`. The
    /// position must be covered by the slot's reserved pages.
    pub(crate) fn write_row(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        assert!(layer < self.n_layers && slot < self.slots);
        assert!(
            pos < self.capacity_rows(slot),
            "KvCache write at unreserved position {pos} of slot {slot}"
        );
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        let page = self.tables[slot][pos / self.page_rows];
        let off = (page * self.page_rows + pos % self.page_rows) * self.d;
        self.lanes[2 * layer][off..off + self.d].copy_from_slice(k);
        self.lanes[2 * layer + 1][off..off + self.d].copy_from_slice(v);
    }

    /// Borrow `layer`'s full (K, V) lanes for attention reads (rows are
    /// located through a slot's [`KvCache::table`]).
    pub(crate) fn lanes(&self, layer: usize) -> (&[f32], &[f32]) {
        (&self.lanes[2 * layer], &self.lanes[2 * layer + 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip_and_reset() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::new(2, 4, 8, 3, &mut ws);
        assert_eq!((kv.slots(), kv.max_seq()), (3, 8));
        assert_eq!(kv.len(1), 0);
        assert!(kv.reserve(2, 1));
        kv.write_row(1, 2, 0, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        kv.advance(2, 1);
        assert_eq!(kv.len(2), 1);
        assert_eq!(kv.remaining(2), 7);
        let (k, v) = kv.lanes(1);
        let page = kv.table(2)[0];
        let off = page * 8 * 4;
        assert_eq!(&k[off..off + 4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&v[off..off + 4], &[5.0, 6.0, 7.0, 8.0]);
        kv.reset_slot(2);
        assert_eq!(kv.len(2), 0);
        assert_eq!(kv.pages_in_use(), 0);
    }

    #[test]
    fn release_pools_the_lanes() {
        let mut ws = Workspace::new();
        let kv = KvCache::new(3, 8, 16, 2, &mut ws);
        kv.release(&mut ws);
        let frozen = ws.fresh_allocs;
        let kv = KvCache::new(3, 8, 16, 2, &mut ws);
        assert_eq!(ws.fresh_allocs, frozen, "rebuild must reuse pooled lanes");
        kv.release(&mut ws);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn advance_past_capacity_panics() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::new(1, 2, 4, 1, &mut ws);
        kv.advance(0, 5);
    }

    #[test]
    fn paged_pool_is_shared_and_reserve_backpressures() {
        let mut ws = Workspace::new();
        // 8 one-row pages over 3 slots, max_seq 8
        let mut kv = KvCache::paged(1, 2, 8, 1, 8, 3, &mut ws);
        assert!(kv.reserve(0, 5));
        assert!(kv.reserve(1, 3));
        assert_eq!(kv.pages_in_use(), 8);
        assert!(!kv.reserve(2, 1), "exhausted pool must refuse");
        assert!(!kv.can_admit(1));
        // eviction is a page-table edit: slot 1's pages come straight back
        kv.reset_slot(1);
        assert_eq!(kv.pages_in_use(), 5);
        assert!(kv.can_admit(3));
        assert!(kv.reserve(2, 3));
        assert_eq!(kv.pages_hwm(), 8);
        kv.release(&mut ws);
    }

    #[test]
    fn reserve_is_idempotent_within_capacity() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::paged(1, 2, 8, 4, 2, 2, &mut ws);
        assert!(kv.reserve(0, 3));
        let used = kv.pages_in_use();
        assert!(kv.reserve(0, 1), "row 3 is already covered by page 0");
        assert_eq!(kv.pages_in_use(), used, "no page needed within capacity");
        kv.advance(0, 4);
        assert!(kv.reserve(0, 1), "row 4 crosses into a second page");
        assert_eq!(kv.pages_in_use(), used + 1);
        kv.release(&mut ws);
    }

    #[test]
    fn paged_writes_land_in_their_table_pages() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::paged(1, 2, 6, 2, 3, 2, &mut ws);
        // slot 1 first so its rows land in page 0 — placement must not
        // matter to reads
        assert!(kv.reserve(1, 1));
        kv.write_row(0, 1, 0, &[9.0, 9.5], &[-9.0, -9.5]);
        kv.advance(1, 1);
        assert!(kv.reserve(0, 3));
        for pos in 0..3 {
            let x = pos as f32;
            kv.write_row(0, 0, pos, &[x, x + 0.5], &[-x, -x - 0.5]);
        }
        kv.advance(0, 3);
        let (k, _v) = kv.lanes(0);
        for pos in 0..3 {
            let page = kv.table(0)[pos / 2];
            let off = (page * 2 + pos % 2) * 2;
            assert_eq!(&k[off..off + 2], &[pos as f32, pos as f32 + 0.5]);
        }
        let off = kv.table(1)[0] * 2 * 2;
        assert_eq!(&k[off..off + 2], &[9.0, 9.5]);
        kv.release(&mut ws);
    }

    #[test]
    #[should_panic(expected = "cannot hold one max_seq")]
    fn undersized_pool_is_rejected() {
        let mut ws = Workspace::new();
        let _ = KvCache::paged(1, 2, 16, 2, 4, 1, &mut ws);
    }

    #[test]
    fn truncate_to_zero_frees_everything() {
        let mut ws = Workspace::new();
        // 4-row pages, 4 pages, max_seq 16
        let mut kv = KvCache::paged(1, 2, 16, 4, 4, 2, &mut ws);
        assert!(kv.reserve(0, 10));
        kv.advance(0, 10);
        assert_eq!(kv.pages_in_use(), 3);
        assert!(!kv.can_admit(8), "only 1 free page = 4 rows");
        kv.truncate_to(0, 0);
        assert_eq!(kv.len(0), 0);
        assert_eq!(kv.pages_in_use(), 0);
        assert!(kv.can_admit(16), "freed pages must reappear in can_admit");
        assert_eq!(kv.pages_hwm(), 3, "hwm is monotone through truncation");
        kv.release(&mut ws);
    }

    #[test]
    fn truncate_to_mid_page_keeps_the_partial_page() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::paged(1, 2, 16, 4, 4, 1, &mut ws);
        assert!(kv.reserve(0, 11));
        for pos in 0..11 {
            let x = pos as f32;
            kv.write_row(0, 0, pos, &[x, x + 0.5], &[-x, -x - 0.5]);
        }
        kv.advance(0, 11);
        // 5 lands mid-page: rows 0..5 span pages 0 and 1; page 2 is freed
        kv.truncate_to(0, 5);
        assert_eq!(kv.len(0), 5);
        assert_eq!(kv.table(0).len(), 2);
        assert_eq!(kv.pages_in_use(), 2);
        // surviving rows are untouched — rollback is a page-table edit
        let (k, _v) = kv.lanes(0);
        for pos in 0..5 {
            let page = kv.table(0)[pos / 4];
            let off = (page * 4 + pos % 4) * 2;
            assert_eq!(&k[off..off + 2], &[pos as f32, pos as f32 + 0.5]);
        }
        kv.release(&mut ws);
    }

    #[test]
    fn truncate_to_exact_page_boundary() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::paged(1, 2, 16, 4, 4, 1, &mut ws);
        assert!(kv.reserve(0, 9));
        kv.advance(0, 9);
        assert_eq!(kv.pages_in_use(), 3);
        // 8 = exactly two full pages: the third page must be freed
        kv.truncate_to(0, 8);
        assert_eq!((kv.len(0), kv.table(0).len()), (8, 2));
        assert_eq!(kv.pages_in_use(), 2);
        // idempotent at the same boundary
        kv.truncate_to(0, 8);
        assert_eq!((kv.len(0), kv.table(0).len()), (8, 2));
        assert_eq!(kv.pages_hwm(), 3);
        kv.release(&mut ws);
    }

    #[test]
    #[should_panic(expected = "truncate_to")]
    fn truncate_past_len_panics() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::paged(1, 2, 8, 2, 4, 1, &mut ws);
        assert!(kv.reserve(0, 2));
        kv.advance(0, 2);
        kv.truncate_to(0, 3);
    }

    #[test]
    fn draft_pages_share_the_pool_and_release_on_end() {
        let mut ws = Workspace::new();
        // 8 one-row pages, 2 slots
        let mut kv = KvCache::paged(1, 2, 8, 1, 8, 2, &mut ws);
        assert!(kv.reserve(0, 4));
        kv.advance(0, 4);
        kv.begin_draft(0);
        assert_eq!(kv.draft_base(0), 4);
        assert!(kv.draft_reserve(0, 3));
        assert_eq!(kv.pages_in_use(), 7, "draft pages come from the pool");
        kv.draft_write_row(0, 0, 4, &[1.0, 2.0], &[3.0, 4.0]);
        kv.draft_advance(0, 1);
        assert_eq!(kv.draft_len(0), 1);
        // the draft row landed in the draft table, packed from rel 0
        let (k, _v) = kv.lanes(0);
        let off = kv.draft_table(0)[0] * 2; // page_rows = 1, d = 2
        assert_eq!(&k[off..off + 2], &[1.0, 2.0]);
        // drafting cannot starve admission silently: reserve refuses
        assert!(!kv.reserve(1, 2), "1 free page cannot back 2 rows");
        kv.end_draft(0);
        assert_eq!(kv.draft_len(0), 0);
        assert_eq!(kv.pages_in_use(), 4, "draft pages returned to the pool");
        assert!(kv.reserve(1, 2));
        assert_eq!(kv.pages_hwm(), 7);
        kv.release(&mut ws);
    }

    #[test]
    fn reset_slot_frees_draft_pages_too() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::paged(1, 2, 8, 2, 4, 1, &mut ws);
        assert!(kv.reserve(0, 3));
        kv.advance(0, 3);
        kv.begin_draft(0);
        assert!(kv.draft_reserve(0, 2));
        assert_eq!(kv.pages_in_use(), 3);
        kv.reset_slot(0);
        assert_eq!((kv.len(0), kv.draft_len(0)), (0, 0));
        assert_eq!(kv.pages_in_use(), 0);
        // a fresh draft round starts clean
        kv.begin_draft(0);
        assert_eq!(kv.draft_base(0), 0);
        kv.release(&mut ws);
    }
}
