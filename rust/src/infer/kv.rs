//! Per-block key/value cache for incremental decoding.
//!
//! One [`KvCache`] holds the K and V activations of **every** decoder block
//! for a fixed number of request *slots*. The backing buffers are f32 lanes
//! drawn from a [`Workspace`] (two lanes per block: one K, one V), so
//! caches are pooled across requests exactly like every other hot-path
//! buffer: grow-only, reused on [`KvCache::release`]/[`KvCache::new`], and
//! reset per request without freeing.
//!
//! Layout: lane `2·layer` is K, lane `2·layer + 1` is V; within a lane,
//! slot `s`'s row `p` (cache position `p`, counting PEFT virtual tokens)
//! starts at `(s · max_seq + p) · d`.

use crate::model::Model;
use crate::tensor::Workspace;

/// Pooled, grow-only K/V storage for `slots` concurrent requests. See the
/// module docs for the lane layout.
pub struct KvCache {
    /// `2 · n_layers` workspace lanes (K then V per layer). The pooled lane
    /// set may carry extra lanes from a wider earlier take; only the first
    /// `2 · n_layers` are used.
    lanes: Vec<Vec<f32>>,
    n_layers: usize,
    d: usize,
    max_seq: usize,
    slots: usize,
    /// Cached rows per slot (counting virtual tokens). 0 = slot is free.
    lens: Vec<usize>,
}

impl KvCache {
    /// A cache for `slots` concurrent requests of a model with `n_layers`
    /// blocks, width `d`, and `max_seq` positions. Backing buffers come
    /// from `ws` (key `"infer.kv"`), so building a cache after a release
    /// reuses the previous allocation.
    pub fn new(
        n_layers: usize,
        d: usize,
        max_seq: usize,
        slots: usize,
        ws: &mut Workspace,
    ) -> KvCache {
        assert!(n_layers > 0 && d > 0 && max_seq > 0 && slots > 0);
        let mut lanes = ws.take_f32_lanes("infer.kv", 2 * n_layers);
        for lane in lanes.iter_mut().take(2 * n_layers) {
            lane.resize(slots * max_seq * d, 0.0);
        }
        KvCache {
            lanes,
            n_layers,
            d,
            max_seq,
            slots,
            lens: vec![0; slots],
        }
    }

    /// [`KvCache::new`] sized from a model's configuration.
    pub fn for_model(model: &Model, slots: usize, ws: &mut Workspace) -> KvCache {
        KvCache::new(
            model.cfg.n_layers,
            model.cfg.d_model,
            model.cfg.max_seq,
            slots,
            ws,
        )
    }

    /// Hand the backing lanes back to the workspace pool.
    pub fn release(self, ws: &mut Workspace) {
        ws.put_f32_lanes("infer.kv", self.lanes);
    }

    /// Number of request slots.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Maximum cache positions per slot.
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Cached rows for `slot` (0 = free / reset).
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// Free positions remaining in `slot`.
    pub fn remaining(&self, slot: usize) -> usize {
        self.max_seq - self.lens[slot]
    }

    /// Mark `slot` empty (the rows are overwritten by the next prefill —
    /// nothing is freed).
    pub fn reset_slot(&mut self, slot: usize) {
        self.lens[slot] = 0;
    }

    /// Reset every slot.
    pub fn reset_all(&mut self) {
        self.lens.fill(0);
    }

    /// Bytes of K/V storage held (diagnostics / memory accounting).
    pub fn nbytes(&self) -> usize {
        2 * self.n_layers * self.slots * self.max_seq * self.d * 4
    }

    /// Record that `slot` gained `n` cached rows (called once per
    /// prefill/decode step, after every layer wrote its K/V rows).
    pub(crate) fn advance(&mut self, slot: usize, n: usize) {
        let len = self.lens[slot] + n;
        assert!(len <= self.max_seq, "KvCache slot {slot} overflow");
        self.lens[slot] = len;
    }

    /// Write one K row and one V row for `layer` at `(slot, pos)`.
    pub(crate) fn write_row(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        assert!(layer < self.n_layers && slot < self.slots && pos < self.max_seq);
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        let off = (slot * self.max_seq + pos) * self.d;
        self.lanes[2 * layer][off..off + self.d].copy_from_slice(k);
        self.lanes[2 * layer + 1][off..off + self.d].copy_from_slice(v);
    }

    /// Borrow `layer`'s full (K, V) lanes for attention reads.
    pub(crate) fn lanes(&self, layer: usize) -> (&[f32], &[f32]) {
        (&self.lanes[2 * layer], &self.lanes[2 * layer + 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip_and_reset() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::new(2, 4, 8, 3, &mut ws);
        assert_eq!((kv.slots(), kv.max_seq()), (3, 8));
        assert_eq!(kv.len(1), 0);
        kv.write_row(1, 2, 0, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        kv.advance(2, 1);
        assert_eq!(kv.len(2), 1);
        assert_eq!(kv.remaining(2), 7);
        let (k, v) = kv.lanes(1);
        let off = (2 * 8) * 4;
        assert_eq!(&k[off..off + 4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&v[off..off + 4], &[5.0, 6.0, 7.0, 8.0]);
        kv.reset_slot(2);
        assert_eq!(kv.len(2), 0);
    }

    #[test]
    fn release_pools_the_lanes() {
        let mut ws = Workspace::new();
        let kv = KvCache::new(3, 8, 16, 2, &mut ws);
        kv.release(&mut ws);
        let frozen = ws.fresh_allocs;
        let kv = KvCache::new(3, 8, 16, 2, &mut ws);
        assert_eq!(ws.fresh_allocs, frozen, "rebuild must reuse pooled lanes");
        kv.release(&mut ws);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn advance_past_capacity_panics() {
        let mut ws = Workspace::new();
        let mut kv = KvCache::new(1, 2, 4, 1, &mut ws);
        kv.advance(0, 5);
    }
}
