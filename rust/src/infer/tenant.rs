//! Multi-tenant adapter registry — per-tenant PEFT stacks over one shared
//! quantized base.
//!
//! The serving tier holds exactly one quantized [`crate::model::Model`]
//! (loaded from a `DistributionBundle`; the f32 masters are never
//! rematerialized). Each tenant contributes only its own tiny adapter
//! stack — per-block LoRA pairs and/or a soft prompt
//! ([`crate::peft::TenantAdapters`]) — and the [`AdapterRegistry`] maps a
//! `u64` tenant id to that stack. [`crate::infer::BatchEngine`] resolves a
//! request's tenant tag against the registry at admission and threads the
//! resolved stack through `prefill_tenant` / `decode_step_tenants`, so one
//! stacked decode batch can mix tenants while the shared int8 qgemm still
//! runs once per layer.
//!
//! Installation (hot-swap) is a plain map insert: it takes effect at the
//! **next** engine step and never perturbs co-batched tenants — every
//! decode op is row-local, so another tenant's rows are untouched by a
//! swap (`tests/tenant_parity.rs` proves this bitwise). Removing a tenant
//! with live requests finishes those requests with
//! [`crate::infer::FinishReason::Cancelled`] at the next step.

use std::collections::BTreeMap;

use crate::peft::TenantAdapters;

/// Tenant id → adapter stack map shared by all requests of a
/// [`crate::infer::BatchEngine`].
///
/// `BTreeMap`-backed so [`AdapterRegistry::ids`] (and hence every
/// iteration the engine does) is deterministically ordered — part of the
/// repo-wide bitwise-reproducibility contract.
#[derive(Debug, Default)]
pub struct AdapterRegistry {
    tenants: BTreeMap<u64, TenantAdapters>,
    swaps: u64,
}

impl AdapterRegistry {
    /// Empty registry: every request decodes the base/model-attached path.
    pub fn new() -> AdapterRegistry {
        AdapterRegistry { tenants: BTreeMap::new(), swaps: 0 }
    }

    /// True when no tenants are installed — the engine then takes the
    /// legacy `decode_step` fast path (no per-row adapter resolution).
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Number of installed tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// Installed tenant ids, ascending.
    pub fn ids(&self) -> Vec<u64> {
        self.tenants.keys().copied().collect()
    }

    /// The adapter stack for tenant `id`, if installed.
    pub fn get(&self, id: u64) -> Option<&TenantAdapters> {
        self.tenants.get(&id)
    }

    /// Install (or hot-swap) tenant `id`'s adapter stack, returning the
    /// previous stack if one was replaced. Takes effect at the next engine
    /// step; co-batched tenants are unaffected.
    pub fn install(&mut self, id: u64, adapters: TenantAdapters) -> Option<TenantAdapters> {
        let prev = self.tenants.insert(id, adapters);
        if prev.is_some() {
            self.swaps += 1;
        }
        prev
    }

    /// Remove tenant `id`, returning its stack. The engine cancels the
    /// tenant's in-flight requests at the next step.
    pub fn remove(&mut self, id: u64) -> Option<TenantAdapters> {
        self.tenants.remove(&id)
    }

    /// Number of hot-swaps (installs that replaced an existing stack).
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Total adapter payload across tenants, in bytes — the marginal
    /// serving cost of tenancy (the quantized base is shared).
    pub fn adapter_bytes(&self) -> usize {
        self.tenants.values().map(|t| t.adapter_bytes()).sum()
    }
}
