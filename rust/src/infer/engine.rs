//! Batched serving engine: continuous batching of decode steps over a
//! paged [`KvCache`], with preemption when the page pool runs dry.
//!
//! The engine is a **stepping core**: [`BatchEngine::try_admit`] places a
//! request into a free slot (prefill + first sample), and each
//! [`BatchEngine::step`] runs one scheduling round — readmit preempted
//! requests, resolve every active request's pending token, then **one
//! stacked [`Model::decode_step`] for all survivors** — emitting
//! [`StepEvent`]s for tokens, completions and preemption traffic. The
//! linear layers see an `(n_active × d)` batch and shard across the
//! `tensor::pool` threads, while attention reads each slot's paged
//! prefix. [`BatchEngine::run_requests`] keeps the original
//! whole-queue-in, completions-out driver as a loop over those two calls;
//! `infer::serve` builds the deadline/backpressure front-end on the same
//! surface.
//!
//! **Preemption is bitwise-invisible.** When [`KvCache::reserve`] fails
//! mid-round, the youngest active requests are parked: their slot's pages
//! go back to the pool ([`KvCache::reset_slot`]) and the request keeps
//! only its prompt, resolved tokens and RNG state. Readmission re-prefills
//! `prompt ++ tokens` — by the row-local decode invariant
//! (`model::decode`) this rebuilds the exact K/V rows and returns the
//! exact logits the skipped decode step would have produced, and sampling
//! resumes from the saved RNG state. A preempted-and-resumed request is
//! therefore byte-identical to one that never lost its slot
//! (`tests/serve_parity.rs`).
//!
//! Determinism: decoding is row-local, so a request's tokens are
//! identical whether it runs alone or batched with arbitrary neighbours,
//! at any thread count, page size or arrival order; each request samples
//! from its own RNG stream seeded by `cfg.seed ^ request.id`.
//!
//! **Speculative decoding** ([`super::spec`]): an engine built with a
//! [`SpecConfig`] replaces each greedy, tenant-free decode round with a
//! draft/verify round — `draft_len` truncated-layer passes propose
//! tokens into per-slot draft pages, ONE stacked full pass verifies them
//! all, and the longest matching prefix is accepted
//! ([`KvCache::truncate_to`] rolls the rest back). Token streams are
//! **bit-identical** to plain greedy decode; only the number of full
//! passes per token changes. Sampled configs and tenant-mixed batches
//! fall back to the plain path automatically.

use std::collections::{BTreeMap, VecDeque};

use super::spec::{accepted_prefix, SpecConfig};
use super::tenant::AdapterRegistry;
use super::{argmax, sample_token, GenerateConfig, KvCache};
use crate::model::Model;
use crate::peft::TenantAdapters;
use crate::tensor::Workspace;
use crate::util::prng::Rng;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen id, echoed on the [`Completion`] (and folded into the
    /// per-request sampling seed).
    pub id: u64,
    /// Prompt token ids (BOS and friends are the caller's concern).
    pub prompt: Vec<u32>,
    /// Per-request generation cap (bounded by the engine config's
    /// `max_new` semantics: this field *is* the cap used).
    pub max_new: usize,
    /// Tenant tag, resolved against the engine's [`AdapterRegistry`] at
    /// admission. `None` decodes the base/model-attached path (the legacy
    /// single-tenant behaviour, bit-identical). `Some(id)` decodes with
    /// tenant `id`'s LoRA/prompt stack; an unknown id is
    /// [`Admission::Rejected`].
    pub tenant: Option<u64>,
}

/// Why a request left the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The configured EOS token was sampled.
    Eos,
    /// The request's `max_new` cap (or the cache's position limit) was
    /// reached.
    Length,
    /// Refused at admission: empty/over-long prompt or `max_new == 0`.
    Rejected,
    /// Explicitly cancelled ([`BatchEngine::cancel`] / `serve`).
    Cancelled,
    /// The serving front-end expired the request's deadline.
    Deadline,
    /// Refused at admission: the request's tenant is already at its
    /// `max_inflight` quota ([`BatchEngine::set_quota`]).
    Quota,
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// Prompt length, for tokens-processed accounting.
    pub prompt_len: usize,
    /// Generated tokens (no prompt, no EOS).
    pub tokens: Vec<u32>,
    /// Why generation stopped.
    pub reason: FinishReason,
}

/// Aggregate throughput counters for one engine lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Batched decode steps executed.
    pub decode_steps: u64,
    /// Tokens produced by decode steps (sum of batch sizes).
    pub decode_tokens: u64,
    /// Prompt tokens processed by prefills (including virtual tokens and
    /// readmission re-prefills).
    pub prefill_tokens: u64,
    /// Requests parked because the page pool ran dry.
    pub preemptions: u64,
    /// Parked requests readmitted (re-prefilled).
    pub resumes: u64,
    /// Speculative draft/verify rounds executed.
    pub spec_rounds: u64,
    /// Draft tokens proposed across all spec rounds.
    pub spec_drafted: u64,
    /// Draft tokens accepted by full-model verification. Every accepted
    /// draft is one extra token emitted per full pass, so emitted tokens
    /// per spec round = accepted + 1 (the pending/bonus token).
    pub spec_accepted: u64,
}

impl EngineStats {
    /// Mean decode-batch occupancy (tokens per step).
    pub fn mean_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.decode_steps as f64
        }
    }

    /// Fraction of drafted tokens the full model accepted (0.0 before
    /// any spec round).
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_drafted as f64
        }
    }
}

/// Scheduling traffic emitted by [`BatchEngine::step`].
#[derive(Clone, Debug)]
pub enum StepEvent {
    /// A token was resolved into `tag`'s output stream.
    Token {
        /// Admission ticket of the request.
        tag: u64,
        /// The request's caller-chosen id.
        id: u64,
        /// The resolved token.
        token: u32,
    },
    /// The request finished; its slot and pages are already free.
    Finished {
        /// Admission ticket of the request.
        tag: u64,
        /// The finished request.
        completion: Completion,
    },
    /// The request was parked (pages reclaimed); it will be readmitted
    /// automatically when a slot and pages free up.
    Preempted {
        /// Admission ticket of the request.
        tag: u64,
        /// The request's caller-chosen id.
        id: u64,
    },
    /// A parked request was readmitted (re-prefilled).
    Resumed {
        /// Admission ticket of the request.
        tag: u64,
        /// The request's caller-chosen id.
        id: u64,
    },
}

/// Outcome of [`BatchEngine::try_admit`].
#[derive(Debug)]
pub enum Admission {
    /// Admitted and prefilled; the tag identifies it in [`StepEvent`]s.
    Admitted(u64),
    /// Refused outright (degenerate request) — completes empty with
    /// [`FinishReason::Rejected`].
    Rejected(Completion),
    /// No capacity right now (no free slot, not enough free pages, or
    /// parked requests have readmission priority). Retry after a step.
    Busy,
}

/// A request in flight.
struct Active {
    /// Admission ticket (unique per engine lifetime; ids need not be).
    tag: u64,
    id: u64,
    slot: usize,
    /// Admission sequence — the preemption victim is always the youngest
    /// (highest seq), so older requests drain first and progress is
    /// guaranteed.
    seq: u64,
    /// Owned prompt, kept for readmission re-prefill.
    prompt: Vec<u32>,
    max_new: usize,
    /// Tenant tag carried through preemption; re-resolved against the
    /// registry every round so removal cancels promptly.
    tenant: Option<u64>,
    rng: Rng,
    /// Last sampled token, not yet resolved into the output stream.
    next: u32,
    toks: Vec<u32>,
}

/// A preempted request waiting for pages: everything needed to rebuild
/// its cache state by re-prefilling `prompt ++ toks`.
struct Parked {
    tag: u64,
    id: u64,
    seq: u64,
    prompt: Vec<u32>,
    max_new: usize,
    tenant: Option<u64>,
    rng: Rng,
    toks: Vec<u32>,
}

/// Throughput-oriented batch decoder over a fixed slot count and a shared
/// page pool. Owns its [`KvCache`] and [`Workspace`], so one engine
/// instance serves many request queues without reallocating.
pub struct BatchEngine {
    cfg: GenerateConfig,
    kv: KvCache,
    ws: Workspace,
    registry: AdapterRegistry,
    active: Vec<Active>,
    parked: VecDeque<Parked>,
    free_slots: Vec<usize>,
    next_seq: u64,
    /// Speculative-decode geometry; `None` = plain decode only.
    spec: Option<SpecConfig>,
    /// Per-tenant `max_inflight` admission quotas (absent = unlimited).
    quotas: BTreeMap<u64, usize>,
    /// Lifetime throughput counters.
    pub stats: EngineStats,
}

impl BatchEngine {
    /// An engine with `slots` concurrent decode lanes for `model`, backed
    /// by the contiguous-equivalent cache (one `max_seq` page per slot —
    /// no preemption can ever trigger). Every linear layer's execution
    /// plan is pre-compiled into the engine's arena (sized for the full
    /// decode batch), so the first admitted request already runs the
    /// fused plan-driven pipeline.
    pub fn new(model: &Model, slots: usize, cfg: GenerateConfig) -> BatchEngine {
        let mut ws = Workspace::new();
        let kv = KvCache::for_model(model, slots, &mut ws);
        BatchEngine::from_parts(model, kv, ws, cfg, None)
    }

    /// An engine over an explicitly paged cache: `n_pages` shared pages
    /// of `page_rows` rows for `slots` slots. With fewer pooled rows than
    /// `slots · max_seq` the engine oversubscribes memory and preempts
    /// under pressure — output streams are unchanged (see module docs).
    pub fn with_paging(
        model: &Model,
        slots: usize,
        page_rows: usize,
        n_pages: usize,
        cfg: GenerateConfig,
    ) -> BatchEngine {
        let mut ws = Workspace::new();
        let kv = KvCache::for_model_paged(model, page_rows, n_pages, slots, &mut ws);
        BatchEngine::from_parts(model, kv, ws, cfg, None)
    }

    /// [`BatchEngine::new`] with self-speculative decoding enabled:
    /// greedy, tenant-free rounds draft `spec.draft_len` tokens through
    /// the first `spec.draft_layers` blocks and verify them in one
    /// stacked full pass — token streams stay bit-identical to plain
    /// greedy decode (`tests/spec_parity.rs`).
    pub fn with_spec(
        model: &Model,
        slots: usize,
        cfg: GenerateConfig,
        spec: SpecConfig,
    ) -> BatchEngine {
        let mut ws = Workspace::new();
        // contiguous equivalent plus one spare page per slot: pages are
        // max_seq rows, so one spare covers any draft_len — without it a
        // fully occupied engine has zero free pages and every round would
        // silently shrink to k = 0 (correct, but never speculative)
        let c = &model.cfg;
        let kv = KvCache::paged(
            c.n_layers,
            c.d_model,
            c.max_seq,
            c.max_seq,
            2 * slots,
            slots,
            &mut ws,
        );
        BatchEngine::from_parts(model, kv, ws, cfg, Some(spec))
    }

    /// [`BatchEngine::with_paging`] with self-speculative decoding
    /// enabled (see [`BatchEngine::with_spec`]).
    pub fn with_paging_spec(
        model: &Model,
        slots: usize,
        page_rows: usize,
        n_pages: usize,
        cfg: GenerateConfig,
        spec: SpecConfig,
    ) -> BatchEngine {
        let mut ws = Workspace::new();
        let kv = KvCache::for_model_paged(model, page_rows, n_pages, slots, &mut ws);
        BatchEngine::from_parts(model, kv, ws, cfg, Some(spec))
    }

    fn from_parts(
        model: &Model,
        kv: KvCache,
        mut ws: Workspace,
        cfg: GenerateConfig,
        spec: Option<SpecConfig>,
    ) -> BatchEngine {
        let slots = kv.slots();
        if let Some(s) = spec {
            s.validate(model.cfg.n_layers);
        }
        // the verify pass stacks up to draft_len + 1 rows per slot, so a
        // spec engine warms its plans for that batch shape up front (the
        // workspace is grow-only either way; this keeps the steady state
        // zero-alloc from the first round)
        let warm_rows = slots.max(1) * spec.map_or(1, |s| s.draft_len + 1);
        model.warm_plans(warm_rows, &mut ws);
        BatchEngine {
            cfg,
            kv,
            ws,
            registry: AdapterRegistry::new(),
            active: Vec::new(),
            parked: VecDeque::new(),
            free_slots: (0..slots).rev().collect(),
            next_seq: 0,
            spec,
            quotas: BTreeMap::new(),
            stats: EngineStats::default(),
        }
    }

    /// The engine's speculative-decode geometry, if enabled.
    pub fn spec(&self) -> Option<SpecConfig> {
        self.spec
    }

    /// Set (or clear, with `None`) tenant `tenant`'s admission quota:
    /// while the tenant has `max_inflight` requests in flight (active or
    /// parked), further admissions are refused with
    /// [`FinishReason::Quota`]. Quotas never touch requests already in
    /// flight, so a quota'd-out tenant's co-batched neighbours are
    /// bitwise unaffected (`tests/tenant_parity.rs`).
    pub fn set_quota(&mut self, tenant: u64, max_inflight: Option<usize>) {
        match max_inflight {
            Some(n) => {
                self.quotas.insert(tenant, n);
            }
            None => {
                self.quotas.remove(&tenant);
            }
        }
    }

    /// Tenant `tenant`'s requests currently in flight (active + parked).
    pub fn tenant_inflight(&self, tenant: u64) -> usize {
        let t = Some(tenant);
        self.active.iter().filter(|a| a.tenant == t).count()
            + self.parked.iter().filter(|p| p.tenant == t).count()
    }

    /// Number of concurrent decode slots.
    pub fn slots(&self) -> usize {
        self.kv.slots()
    }

    /// The engine's tenant adapter registry (read side).
    pub fn registry(&self) -> &AdapterRegistry {
        &self.registry
    }

    /// The engine's tenant adapter registry (install/remove/hot-swap).
    /// Changes take effect at the next [`BatchEngine::step`]; removing a
    /// tenant finishes its in-flight requests with
    /// [`FinishReason::Cancelled`] there.
    pub fn registry_mut(&mut self) -> &mut AdapterRegistry {
        &mut self.registry
    }

    /// Requests currently holding a slot.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Requests parked awaiting readmission.
    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }

    /// Page-pool gauge `(in_use, total)` of the underlying cache.
    pub fn pages(&self) -> (usize, usize) {
        (self.kv.pages_in_use(), self.kv.pages_total())
    }

    /// Most pages ever simultaneously allocated.
    pub fn pages_hwm(&self) -> usize {
        self.kv.pages_hwm()
    }

    /// Fresh-allocation counter of the engine's arena. Stops moving once
    /// the engine has served a request of a given shape — pinned by
    /// `tests/engine_memory.rs`.
    pub fn workspace_fresh_allocs(&self) -> u64 {
        self.ws.fresh_allocs
    }

    /// Bytes of pooled arena capacity (excluding the K/V cache, which
    /// [`BatchEngine::kv_bytes`] reports). Stable across same-shape
    /// request batches.
    pub fn workspace_pooled_bytes(&self) -> usize {
        self.ws.pooled_bytes()
    }

    /// Bytes held by the engine's K/V cache lanes (sized once at
    /// construction; never grows per request).
    pub fn kv_bytes(&self) -> usize {
        self.kv.nbytes()
    }

    /// Try to place `req` into a free slot: degenerate requests — and
    /// requests tagged with a tenant the registry doesn't know — are
    /// [`Admission::Rejected`] immediately; otherwise admission needs a
    /// free slot, enough free pages for the whole prompt, and an empty
    /// parked queue (preempted requests outrank new arrivals — they hold
    /// the oldest seqs). On success the request is prefilled (with its
    /// tenant's adapter stack, if tagged) and its first token sampled,
    /// ready for the next [`BatchEngine::step`].
    pub fn try_admit(&mut self, model: &Model, req: &Request) -> Admission {
        let tenant = match req.tenant {
            Some(id) => match self.registry.get(id) {
                Some(t) => Some(t),
                None => {
                    return Admission::Rejected(Completion {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        tokens: Vec::new(),
                        reason: FinishReason::Rejected,
                    })
                }
            },
            None => None,
        };
        let nv = tenant.map_or(model.n_virtual(), |t| t.n_virtual());
        let rows = nv + req.prompt.len();
        if req.prompt.is_empty() || req.max_new == 0 || rows > model.cfg.max_seq {
            return Admission::Rejected(Completion {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                reason: FinishReason::Rejected,
            });
        }
        // per-tenant quota: a tenant at its max_inflight is *rejected*
        // (distinct reason, no retry hint) rather than Busy — capacity
        // exists, the tenant's share of it doesn't
        if let Some(id) = req.tenant {
            if let Some(&max) = self.quotas.get(&id) {
                if self.tenant_inflight(id) >= max {
                    return Admission::Rejected(Completion {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        tokens: Vec::new(),
                        reason: FinishReason::Quota,
                    });
                }
            }
        }
        if !self.parked.is_empty() || self.free_slots.is_empty() || !self.kv.can_admit(rows) {
            return Admission::Busy;
        }
        let slot = self.free_slots.pop().expect("checked non-empty");
        let seq = self.next_seq;
        let tag = seq;
        self.next_seq += 1;
        self.kv.reset_slot(slot);
        let logits = model.prefill_tenant(&req.prompt, tenant, slot, &mut self.kv, &mut self.ws);
        self.stats.prefill_tokens += self.kv.len(slot) as u64;
        let mut rng = Rng::new(self.cfg.seed ^ req.id);
        let next = sample_token(logits.row(0), &self.cfg, &mut rng);
        self.ws.recycle(logits);
        self.active.push(Active {
            tag,
            id: req.id,
            slot,
            seq,
            prompt: req.prompt.clone(),
            max_new: req.max_new,
            tenant: req.tenant,
            rng,
            next,
            toks: Vec::new(),
        });
        Admission::Admitted(tag)
    }

    /// One scheduling round: readmit parked requests while capacity
    /// allows, resolve every active request's pending token (emitting
    /// [`StepEvent::Token`] / [`StepEvent::Finished`]), then run one
    /// stacked decode step for the survivors — parking the youngest
    /// actives if the page pool can't back every +1 row. Returns `true`
    /// while any request is still in flight.
    pub fn step(&mut self, model: &Model, events: &mut Vec<StepEvent>) -> bool {
        self.readmit(model, events);
        self.resolve(model, events);
        self.decode(model, events);
        !self.active.is_empty() || !self.parked.is_empty()
    }

    /// Cancel an in-flight request by tag (active or parked), freeing its
    /// slot and pages. Returns its partial completion, or `None` if the
    /// tag is not in flight (already finished / never admitted).
    pub fn cancel(&mut self, tag: u64, reason: FinishReason) -> Option<Completion> {
        if let Some(i) = self.active.iter().position(|a| a.tag == tag) {
            let a = self.active.remove(i);
            self.kv.reset_slot(a.slot);
            self.free_slots.push(a.slot);
            return Some(Completion {
                id: a.id,
                prompt_len: a.prompt.len(),
                tokens: a.toks,
                reason,
            });
        }
        if let Some(i) = self.parked.iter().position(|p| p.tag == tag) {
            let p = self.parked.remove(i).expect("position is in range");
            return Some(Completion {
                id: p.id,
                prompt_len: p.prompt.len(),
                tokens: p.toks,
                reason,
            });
        }
        None
    }

    /// Readmit parked requests in park order (FIFO) while a slot and
    /// enough pages for their full `prompt ++ toks` prefix are available.
    /// The front parks the line: skipping over it would let short
    /// requests starve a long one. A parked request whose tenant has been
    /// removed from the registry finishes here with
    /// [`FinishReason::Cancelled`] instead of readmitting.
    fn readmit(&mut self, model: &Model, events: &mut Vec<StepEvent>) {
        loop {
            let front = match self.parked.front() {
                Some(f) => f,
                None => return,
            };
            if front.tenant.is_some_and(|id| self.registry.get(id).is_none()) {
                let p = self.parked.pop_front().expect("front exists");
                events.push(StepEvent::Finished {
                    tag: p.tag,
                    completion: Completion {
                        id: p.id,
                        prompt_len: p.prompt.len(),
                        tokens: p.toks,
                        reason: FinishReason::Cancelled,
                    },
                });
                continue;
            }
            let nv = match front.tenant {
                Some(id) => self.registry.get(id).expect("checked installed").n_virtual(),
                None => model.n_virtual(),
            };
            let rows = nv + front.prompt.len() + front.toks.len();
            if self.free_slots.is_empty() || !self.kv.can_admit(rows) {
                return;
            }
            let p = self.parked.pop_front().expect("front exists");
            let slot = self.free_slots.pop().expect("checked non-empty");
            self.kv.reset_slot(slot);
            // Rebuild the cache by prefilling prompt ++ toks: row-local
            // decode makes the rows and the returned last-position logits
            // byte-identical to the state at preemption, so sampling from
            // the saved RNG resumes the exact token stream the skipped
            // decode step would have produced.
            let mut seqtoks = p.prompt.clone();
            seqtoks.extend_from_slice(&p.toks);
            let tenant = p.tenant.and_then(|id| self.registry.get(id));
            let logits = model.prefill_tenant(&seqtoks, tenant, slot, &mut self.kv, &mut self.ws);
            self.stats.prefill_tokens += self.kv.len(slot) as u64;
            self.stats.resumes += 1;
            let mut rng = p.rng;
            let next = sample_token(logits.row(0), &self.cfg, &mut rng);
            self.ws.recycle(logits);
            events.push(StepEvent::Resumed { tag: p.tag, id: p.id });
            let a = Active {
                tag: p.tag,
                id: p.id,
                slot,
                seq: p.seq,
                prompt: p.prompt,
                max_new: p.max_new,
                tenant: p.tenant,
                rng,
                next,
                toks: p.toks,
            };
            let at = self
                .active
                .binary_search_by_key(&a.seq, |x| x.seq)
                .expect_err("seqs are unique");
            self.active.insert(at, a);
        }
    }

    /// Resolve every active request's pending token: EOS finishes without
    /// emitting; otherwise the token joins the output stream and the
    /// request finishes when its cap or the cache limit is reached.
    fn resolve(&mut self, model: &Model, events: &mut Vec<StepEvent>) {
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            let eos_hit = self.cfg.eos == Some(a.next);
            if !eos_hit {
                a.toks.push(a.next);
                events.push(StepEvent::Token {
                    tag: a.tag,
                    id: a.id,
                    token: a.next,
                });
            }
            let exhausted = a.toks.len() >= a.max_new || self.kv.len(a.slot) >= model.cfg.max_seq;
            if eos_hit || exhausted {
                let a = self.active.remove(i);
                self.kv.reset_slot(a.slot);
                self.free_slots.push(a.slot);
                events.push(StepEvent::Finished {
                    tag: a.tag,
                    completion: Completion {
                        id: a.id,
                        prompt_len: a.prompt.len(),
                        tokens: a.toks,
                        reason: if eos_hit {
                            FinishReason::Eos
                        } else {
                            FinishReason::Length
                        },
                    },
                });
            } else {
                i += 1;
            }
        }
    }

    /// One stacked decode step for every active request, preempting the
    /// youngest actives when the page pool can't back a +1 row. The
    /// oldest active can always reserve once everything younger is parked
    /// (the pool holds ≥ `max_seq` rows by construction), so every round
    /// with a non-empty active set makes progress — no deadlock.
    fn decode(&mut self, model: &Model, events: &mut Vec<StepEvent>) {
        // tenant sweep: a request whose tenant was removed since the last
        // round must not decode against a missing stack — finish it with
        // Cancelled, pages back to the pool. Removal never perturbs the
        // co-batched survivors (row-local decode).
        let mut i = 0;
        while i < self.active.len() {
            let gone = self.active[i]
                .tenant
                .is_some_and(|id| self.registry.get(id).is_none());
            if gone {
                let a = self.active.remove(i);
                self.kv.reset_slot(a.slot);
                self.free_slots.push(a.slot);
                events.push(StepEvent::Finished {
                    tag: a.tag,
                    completion: Completion {
                        id: a.id,
                        prompt_len: a.prompt.len(),
                        tokens: a.toks,
                        reason: FinishReason::Cancelled,
                    },
                });
            } else {
                i += 1;
            }
        }
        // speculative rounds need greedy sampling (acceptance compares
        // argmaxes) and a tenant-free batch (the draft pass has no
        // per-row adapter plumbing yet); anything else decodes plain
        if let Some(spec) = self.spec {
            if self.cfg.temperature <= 0.0 && self.registry.is_empty() {
                self.spec_decode(model, spec, events);
                return;
            }
        }
        // reserve phase: walk oldest-first; on failure, park from the
        // youngest end until this request fits (or park it, if it *is*
        // the youngest survivor)
        let mut i = 0;
        while i < self.active.len() {
            let mut ok = self.kv.reserve(self.active[i].slot, 1);
            while !ok && self.active.len() > i + 1 {
                let victim = self.active.pop().expect("len > i+1 >= 1");
                self.park(victim, events);
                ok = self.kv.reserve(self.active[i].slot, 1);
            }
            if ok {
                i += 1;
            } else {
                let victim = self.active.remove(i);
                self.park(victim, events);
            }
        }
        if self.active.is_empty() {
            return;
        }
        let tokens: Vec<u32> = self.active.iter().map(|a| a.next).collect();
        let slots: Vec<usize> = self.active.iter().map(|a| a.slot).collect();
        let logits = if self.registry.is_empty() {
            // no tenants installed: literally the pre-tenancy decode path
            model.decode_step(&tokens, &slots, &mut self.kv, &mut self.ws)
        } else {
            let tenants: Vec<Option<&TenantAdapters>> = self
                .active
                .iter()
                .map(|a| a.tenant.and_then(|id| self.registry.get(id)))
                .collect();
            model.decode_step_tenants(&tokens, &slots, &tenants, &mut self.kv, &mut self.ws)
        };
        self.stats.decode_steps += 1;
        self.stats.decode_tokens += self.active.len() as u64;
        for (i, a) in self.active.iter_mut().enumerate() {
            a.next = sample_token(logits.row(i), &self.cfg, &mut a.rng);
        }
        self.ws.recycle(logits);
    }

    /// One speculative scheduling round (greedy, tenant-free): draft up
    /// to `spec.draft_len` tokens per request through the first
    /// `spec.draft_layers` blocks, verify every pending+draft token in
    /// ONE stacked full pass, accept the longest draft prefix matching
    /// the full model's argmaxes and roll the rejected rows back with a
    /// page-table truncation. Emitted tokens pass the exact
    /// resolve-equivalent EOS/length checks at the exact equivalent
    /// cache lengths, so the token streams and completions are
    /// bit-identical to plain greedy rounds (`tests/spec_parity.rs`).
    fn spec_decode(&mut self, model: &Model, spec: SpecConfig, events: &mut Vec<StepEvent>) {
        let max_seq = model.cfg.max_seq;
        // reserve phase (oldest first): k+1 main rows + k draft rows per
        // request, shrinking to k = 0 under pool pressure *before* any
        // neighbour is parked — the k = 0 round needs exactly the plain
        // path's one row, so the no-deadlock guarantee is unchanged
        let mut ks: Vec<usize> = Vec::with_capacity(self.active.len());
        let mut lens: Vec<usize> = Vec::with_capacity(self.active.len());
        let mut i = 0;
        while i < self.active.len() {
            let slot = self.active[i].slot;
            let len = self.kv.len(slot);
            // resolve() just ran: toks.len() < max_new and len < max_seq
            let remaining = self.active[i].max_new - self.active[i].toks.len();
            let mut k = spec.draft_len.min(remaining).min(max_seq - 1 - len);
            let mut ok;
            loop {
                ok = self.kv.reserve(slot, k + 1);
                if ok && k > 0 {
                    self.kv.begin_draft(slot);
                    if !self.kv.draft_reserve(slot, k) {
                        self.kv.end_draft(slot);
                        ok = false;
                    }
                }
                if ok || k == 0 {
                    break;
                }
                // not enough pool for the speculative extras: return the
                // over-reservation and retry as a plain one-row round
                self.kv.truncate_to(slot, len);
                k = 0;
            }
            while !ok && self.active.len() > i + 1 {
                let victim = self.active.pop().expect("len > i+1 >= 1");
                self.park(victim, events);
                ok = self.kv.reserve(slot, 1);
            }
            if ok {
                ks.push(k);
                lens.push(len);
                i += 1;
            } else {
                let victim = self.active.remove(i);
                self.park(victim, events);
            }
        }
        if self.active.is_empty() {
            return;
        }
        debug_assert_eq!(ks.len(), self.active.len());
        // draft phase: chains[i] = [pending, d1, d2, …] — each truncated
        // pass proposes one more token per still-drafting request
        let max_k = ks.iter().copied().max().unwrap_or(0);
        let mut chains: Vec<Vec<u32>> = self.active.iter().map(|a| vec![a.next]).collect();
        for j in 0..max_k {
            let mut tokens = Vec::new();
            let mut slots = Vec::new();
            let mut who = Vec::new();
            for (i, a) in self.active.iter().enumerate() {
                if ks[i] > j {
                    tokens.push(chains[i][j]);
                    slots.push(a.slot);
                    who.push(i);
                }
            }
            if tokens.is_empty() {
                break;
            }
            let logits =
                model.draft_step(&tokens, &slots, spec.draft_layers, &mut self.kv, &mut self.ws);
            for (r, &i) in who.iter().enumerate() {
                chains[i].push(argmax(logits.row(r)));
            }
            self.ws.recycle(logits);
        }
        // draft K/V has served its purpose (draft-position attention);
        // verify rewrites the accepted positions in the main table from
        // the full model, so the draft pages go back to the pool here
        for (i, a) in self.active.iter().enumerate() {
            if ks[i] > 0 {
                self.kv.end_draft(a.slot);
            }
        }
        // verify phase: ONE stacked full pass over every request's
        // pending token + drafts (k+1 rows each, slot-major)
        let tokens: Vec<u32> = chains.iter().flatten().copied().collect();
        let slots: Vec<usize> = self.active.iter().map(|a| a.slot).collect();
        let counts: Vec<usize> = ks.iter().map(|&k| k + 1).collect();
        let logits =
            model.verify_step_tenants(&tokens, &slots, &counts, &[], &mut self.kv, &mut self.ws);
        self.stats.decode_steps += 1;
        self.stats.decode_tokens += tokens.len() as u64;
        self.stats.spec_rounds += 1;
        self.stats.spec_drafted += ks.iter().map(|&k| k as u64).sum::<u64>();
        // accept phase: verify row j's argmax is the true token after j
        // accepted drafts; emit the accepted ones now (each through the
        // same EOS/length checks resolve() would apply, at the cache
        // length plain decode would have), hold the first non-matching
        // row's argmax as the next pending token, truncate the rest away
        let mut ai = 0usize;
        let mut row0 = 0usize;
        for (oi, &k) in ks.iter().enumerate() {
            let len0 = lens[oi];
            let verified: Vec<u32> = (0..=k).map(|j| argmax(logits.row(row0 + j))).collect();
            row0 += k + 1;
            let m = accepted_prefix(&chains[oi][1..], &verified);
            self.stats.spec_accepted += m as u64;
            let mut finished: Option<FinishReason> = None;
            for (j, &tok) in verified[..m].iter().enumerate() {
                if self.cfg.eos == Some(tok) {
                    finished = Some(FinishReason::Eos);
                    break;
                }
                let a = &mut self.active[ai];
                a.toks.push(tok);
                events.push(StepEvent::Token {
                    tag: a.tag,
                    id: a.id,
                    token: tok,
                });
                if a.toks.len() >= a.max_new || len0 + j + 1 >= max_seq {
                    finished = Some(FinishReason::Length);
                    break;
                }
            }
            if let Some(reason) = finished {
                let a = self.active.remove(ai);
                self.kv.reset_slot(a.slot);
                self.free_slots.push(a.slot);
                events.push(StepEvent::Finished {
                    tag: a.tag,
                    completion: Completion {
                        id: a.id,
                        prompt_len: a.prompt.len(),
                        tokens: a.toks,
                        reason,
                    },
                });
            } else {
                let a = &mut self.active[ai];
                a.next = verified[m];
                self.kv.truncate_to(a.slot, len0 + m + 1);
                ai += 1;
            }
        }
        self.ws.recycle(logits);
    }

    /// Park an active request: pages back to the pool, slot freed, state
    /// reduced to what readmission needs. `a.next` is *not* saved — it
    /// equals `a.toks.last()` at the decode phase (resolve already ran)
    /// and is regenerated by the readmission re-prefill.
    fn park(&mut self, a: Active, events: &mut Vec<StepEvent>) {
        self.kv.reset_slot(a.slot);
        self.free_slots.push(a.slot);
        self.stats.preemptions += 1;
        events.push(StepEvent::Preempted { tag: a.tag, id: a.id });
        // victims always carry the smallest seq in the parked set: parked
        // requests outrank every active (admission is blocked while any
        // request is parked), and victims come from the active set
        if let Some(front) = self.parked.front() {
            debug_assert!(a.seq < front.seq, "parked set must stay seq-sorted");
        }
        self.parked.push_front(Parked {
            tag: a.tag,
            id: a.id,
            seq: a.seq,
            prompt: a.prompt,
            max_new: a.max_new,
            tenant: a.tenant,
            rng: a.rng,
            toks: a.toks,
        });
    }

    /// Run every request to completion, admitting from the queue as
    /// capacity frees up. Completions are returned in request order.
    /// Degenerate requests (empty/over-long prompt, `max_new == 0`)
    /// complete empty with [`FinishReason::Rejected`].
    pub fn run_requests(&mut self, model: &Model, requests: &[Request]) -> Vec<Completion> {
        let mut done: Vec<Option<Completion>> = requests.iter().map(|_| None).collect();
        let mut tag_to_req: Vec<(u64, usize)> = Vec::with_capacity(requests.len());
        let mut events: Vec<StepEvent> = Vec::new();
        let mut queue = 0usize;
        loop {
            while queue < requests.len() {
                match self.try_admit(model, &requests[queue]) {
                    Admission::Admitted(tag) => {
                        tag_to_req.push((tag, queue));
                        queue += 1;
                    }
                    Admission::Rejected(c) => {
                        done[queue] = Some(c);
                        queue += 1;
                    }
                    Admission::Busy => break,
                }
            }
            let more = self.step(model, &mut events);
            for ev in events.drain(..) {
                if let StepEvent::Finished { tag, completion } = ev {
                    let (_, req) = *tag_to_req
                        .iter()
                        .find(|(t, _)| *t == tag)
                        .expect("finished tag was admitted here");
                    done[req] = Some(completion);
                }
            }
            if !more && queue >= requests.len() {
                break;
            }
        }
        done.into_iter()
            .map(|c| c.expect("every request resolves to a completion"))
            .collect()
    }
}
