//! Batched serving engine: continuous batching of decode steps over a
//! fixed set of [`KvCache`] slots.
//!
//! [`BatchEngine::run_requests`] admits queued requests into free slots,
//! prefills each admission, then repeatedly runs **one stacked
//! [`Model::decode_step`] for every active request** — the linear layers
//! see an `(n_active × d)` batch and shard across the `tensor::pool`
//! threads, while attention reads each slot's own cached prefix. Finished
//! requests free their slot immediately and the next queued request is
//! admitted mid-flight, so the decode batch stays as full as the queue
//! allows.
//!
//! Determinism: decoding is row-local (see `model::decode`), so a
//! request's tokens are identical whether it runs alone or batched with
//! arbitrary neighbours, at any thread count; each request samples from
//! its own RNG stream seeded by `cfg.seed ^ request.id`.

use super::{sample_token, GenerateConfig, KvCache};
use crate::model::Model;
use crate::tensor::Workspace;
use crate::util::prng::Rng;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen id, echoed on the [`Completion`] (and folded into the
    /// per-request sampling seed).
    pub id: u64,
    /// Prompt token ids (BOS and friends are the caller's concern).
    pub prompt: Vec<u32>,
    /// Per-request generation cap (bounded by the engine config's
    /// `max_new` semantics: this field *is* the cap used).
    pub max_new: usize,
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// Prompt length, for tokens-processed accounting.
    pub prompt_len: usize,
    /// Generated tokens (no prompt, no EOS).
    pub tokens: Vec<u32>,
}

/// Aggregate throughput counters for one engine lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Batched decode steps executed.
    pub decode_steps: u64,
    /// Tokens produced by decode steps (sum of batch sizes).
    pub decode_tokens: u64,
    /// Prompt tokens processed by prefills (including virtual tokens).
    pub prefill_tokens: u64,
}

impl EngineStats {
    /// Mean decode-batch occupancy (tokens per step).
    pub fn mean_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.decode_steps as f64
        }
    }
}

/// A request in flight.
struct Active {
    slot: usize,
    req: usize,
    rng: Rng,
    /// Last sampled token, not yet resolved into the output stream.
    next: u32,
    toks: Vec<u32>,
}

/// Throughput-oriented batch decoder over a fixed slot count. Owns its
/// [`KvCache`] and [`Workspace`], so one engine instance serves many
/// request queues without reallocating.
pub struct BatchEngine {
    cfg: GenerateConfig,
    kv: KvCache,
    ws: Workspace,
    /// Lifetime throughput counters.
    pub stats: EngineStats,
}

impl BatchEngine {
    /// An engine with `slots` concurrent decode lanes for `model`. Every
    /// linear layer's execution plan is pre-compiled into the engine's
    /// arena (sized for the full decode batch), so the first admitted
    /// request already runs the fused plan-driven pipeline.
    pub fn new(model: &Model, slots: usize, cfg: GenerateConfig) -> BatchEngine {
        let mut ws = Workspace::new();
        let kv = KvCache::for_model(model, slots, &mut ws);
        model.warm_plans(slots.max(1), &mut ws);
        BatchEngine {
            cfg,
            kv,
            ws,
            stats: EngineStats::default(),
        }
    }

    /// Number of concurrent decode slots.
    pub fn slots(&self) -> usize {
        self.kv.slots()
    }

    /// Fresh-allocation counter of the engine's arena. Stops moving once
    /// the engine has served a request of a given shape — pinned by
    /// `tests/engine_memory.rs`.
    pub fn workspace_fresh_allocs(&self) -> u64 {
        self.ws.fresh_allocs
    }

    /// Bytes of pooled arena capacity (excluding the K/V cache, which
    /// [`BatchEngine::kv_bytes`] reports). Stable across same-shape
    /// request batches.
    pub fn workspace_pooled_bytes(&self) -> usize {
        self.ws.pooled_bytes()
    }

    /// Bytes held by the engine's K/V cache lanes (sized once at
    /// construction; never grows per request).
    pub fn kv_bytes(&self) -> usize {
        self.kv.nbytes()
    }

    /// Run every request to completion, admitting from the queue as slots
    /// free up. Completions are returned in request order. Degenerate
    /// requests (empty/over-long prompt, `max_new == 0`) complete empty.
    pub fn run_requests(&mut self, model: &Model, requests: &[Request]) -> Vec<Completion> {
        let mut done: Vec<Option<Completion>> = requests.iter().map(|_| None).collect();
        let mut free: Vec<usize> = (0..self.kv.slots()).rev().collect();
        let mut queue = 0usize;
        let mut active: Vec<Active> = Vec::new();
        while queue < requests.len() || !active.is_empty() {
            // admit into free slots
            while let (Some(&slot), true) = (free.last(), queue < requests.len()) {
                let req = queue;
                queue += 1;
                let r = &requests[req];
                let overlong = model.n_virtual() + r.prompt.len() > model.cfg.max_seq;
                if r.prompt.is_empty() || r.max_new == 0 || overlong {
                    done[req] = Some(Completion {
                        id: r.id,
                        prompt_len: r.prompt.len(),
                        tokens: Vec::new(),
                    });
                    continue;
                }
                free.pop();
                self.kv.reset_slot(slot);
                let logits = model.prefill(&r.prompt, slot, &mut self.kv, &mut self.ws);
                self.stats.prefill_tokens += self.kv.len(slot) as u64;
                let mut rng = Rng::new(self.cfg.seed ^ r.id);
                let next = sample_token(logits.row(0), &self.cfg, &mut rng);
                self.ws.recycle(logits);
                active.push(Active {
                    slot,
                    req,
                    rng,
                    next,
                    toks: Vec::new(),
                });
            }
            // resolve the last sampled token of every active request
            let mut still = Vec::with_capacity(active.len());
            for mut a in active.drain(..) {
                let r = &requests[a.req];
                let eos_hit = self.cfg.eos == Some(a.next);
                if !eos_hit {
                    a.toks.push(a.next);
                }
                let exhausted =
                    a.toks.len() >= r.max_new || self.kv.len(a.slot) >= model.cfg.max_seq;
                if eos_hit || exhausted {
                    done[a.req] = Some(Completion {
                        id: r.id,
                        prompt_len: r.prompt.len(),
                        tokens: std::mem::take(&mut a.toks),
                    });
                    free.push(a.slot);
                } else {
                    still.push(a);
                }
            }
            active = still;
            if active.is_empty() {
                continue; // admit more, or fall out of the loop when drained
            }
            // one stacked decode step for every active request
            let tokens: Vec<u32> = active.iter().map(|a| a.next).collect();
            let slots: Vec<usize> = active.iter().map(|a| a.slot).collect();
            let logits = model.decode_step(&tokens, &slots, &mut self.kv, &mut self.ws);
            self.stats.decode_steps += 1;
            self.stats.decode_tokens += active.len() as u64;
            for (i, a) in active.iter_mut().enumerate() {
                a.next = sample_token(logits.row(i), &self.cfg, &mut a.rng);
            }
            self.ws.recycle(logits);
        }
        done.into_iter()
            .map(|c| c.expect("every request resolves to a completion"))
            .collect()
    }
}
