//! OSSH validation instruments.
//!
//! * [`HitRateTracker`] — fraction of dynamically-detected outlier channels
//!   that fall inside the pre-identified set `O` (Figs. 3, 8, 9, 10;
//!   Table 6). hit rate = |O_rt ∩ O_pre| / |O_rt| per iteration.
//! * [`SimilarityTracker`] — Pearson correlation between static
//!   (calibration-time) and dynamic (current) scaling factors over the top
//!   channels (Fig. 11), the measurement showing why static scaling decays.

use super::OutlierSet;
use crate::util::{pearson, Stats};

/// Per-layer hit-rate accumulator across fine-tuning iterations.
#[derive(Clone, Debug)]
pub struct HitRateTracker {
    pub layer: String,
    predefined: OutlierSet,
    per_iter: Vec<f64>,
}

impl HitRateTracker {
    pub fn new(layer: &str, predefined: OutlierSet) -> Self {
        HitRateTracker {
            layer: layer.to_string(),
            predefined,
            per_iter: Vec::new(),
        }
    }

    /// Rebuild a tracker from persisted state (the OSSH telemetry resume
    /// path): the reference set plus the already-recorded series.
    pub fn from_parts(layer: &str, predefined: OutlierSet, per_iter: Vec<f64>) -> Self {
        HitRateTracker {
            layer: layer.to_string(),
            predefined,
            per_iter,
        }
    }

    /// The current reference set hits are scored against.
    pub fn reference(&self) -> &OutlierSet {
        &self.predefined
    }

    /// Replace the reference set — the adaptive re-detection hot-swap:
    /// subsequent records score against the new set while the already
    /// recorded series is kept intact.
    pub fn set_reference(&mut self, set: OutlierSet) {
        self.predefined = set;
    }

    /// Record one fine-tuning iteration's dynamically-detected set.
    /// Iterations with no real-time outliers count as a perfect hit (there
    /// was nothing to miss) — matching the paper's per-layer averages that
    /// stay at 100 % for layers whose outliers vanish under drift.
    pub fn record(&mut self, realtime: &OutlierSet) {
        let rate = if realtime.is_empty() {
            1.0
        } else {
            self.predefined.intersection_size(realtime) as f64 / realtime.len() as f64
        };
        self.per_iter.push(rate);
    }

    pub fn iterations(&self) -> usize {
        self.per_iter.len()
    }

    /// Mean and std of the hit rate across iterations (the line + shaded
    /// band of Fig. 3).
    pub fn summary(&self) -> (f64, f64) {
        let mut s = Stats::new();
        for &r in &self.per_iter {
            s.push(r);
        }
        (s.mean(), s.std())
    }

    pub fn series(&self) -> &[f64] {
        &self.per_iter
    }
}

/// Pearson similarity between the static calibration-time scaling factors
/// and the per-iteration dynamic factors over a fixed top-channel subset.
#[derive(Clone, Debug)]
pub struct SimilarityTracker {
    pub layer: String,
    /// Channels tracked (top 1 % by calibration magnitude in Fig. 11).
    channels: Vec<usize>,
    /// Static factors s_static over `channels`.
    static_factors: Vec<f32>,
    per_iter: Vec<f32>,
}

impl SimilarityTracker {
    pub fn new(layer: &str, channels: Vec<usize>, static_factors: Vec<f32>) -> Self {
        assert_eq!(channels.len(), static_factors.len());
        SimilarityTracker {
            layer: layer.to_string(),
            channels,
            static_factors,
            per_iter: Vec::new(),
        }
    }

    /// Rebuild a tracker from persisted state (the OSSH telemetry resume
    /// path).
    pub fn from_parts(
        layer: &str,
        channels: Vec<usize>,
        static_factors: Vec<f32>,
        per_iter: Vec<f32>,
    ) -> Self {
        assert_eq!(channels.len(), static_factors.len());
        SimilarityTracker {
            layer: layer.to_string(),
            channels,
            static_factors,
            per_iter,
        }
    }

    pub fn channels(&self) -> &[usize] {
        &self.channels
    }

    /// The frozen static factors over [`SimilarityTracker::channels`].
    pub fn static_factors(&self) -> &[f32] {
        &self.static_factors
    }

    /// Record one iteration's dynamic factors over the full channel axis;
    /// the tracker gathers its subset. Tracked channels beyond the supplied
    /// axis (a reference set wider than the live activation, e.g. after a
    /// config change) are skipped pairwise rather than panicking, keeping
    /// the correlation defined over the channels both sides actually have.
    pub fn record_full(&mut self, dynamic_all: &[f32]) {
        let mut stat_sub = Vec::with_capacity(self.channels.len());
        let mut dyn_sub = Vec::with_capacity(self.channels.len());
        for (i, &c) in self.channels.iter().enumerate() {
            if c < dynamic_all.len() {
                stat_sub.push(self.static_factors[i]);
                dyn_sub.push(dynamic_all[c]);
            }
        }
        self.per_iter.push(pearson(&stat_sub, &dyn_sub));
    }

    /// The similarity time series (Fig. 11's per-layer curve).
    pub fn series(&self) -> &[f32] {
        &self.per_iter
    }

    /// Final-iteration similarity.
    pub fn last(&self) -> Option<f32> {
        self.per_iter.last().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_hits_when_subset() {
        let pre = OutlierSet::new(vec![1, 2, 3, 4]);
        let mut t = HitRateTracker::new("l", pre);
        t.record(&OutlierSet::new(vec![2, 3]));
        t.record(&OutlierSet::new(vec![1, 4]));
        let (mean, std) = t.summary();
        assert_eq!(mean, 1.0);
        assert_eq!(std, 0.0);
    }

    #[test]
    fn misses_lower_rate() {
        let pre = OutlierSet::new(vec![1, 2]);
        let mut t = HitRateTracker::new("l", pre);
        t.record(&OutlierSet::new(vec![1, 9])); // 1/2
        t.record(&OutlierSet::new(vec![8, 9])); // 0/2
        let (mean, _) = t.summary();
        assert!((mean - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_realtime_counts_as_hit() {
        let mut t = HitRateTracker::new("l", OutlierSet::new(vec![1]));
        t.record(&OutlierSet::default());
        assert_eq!(t.summary().0, 1.0);
    }

    #[test]
    fn empty_predefined_set_scores_zero_against_any_detection() {
        // Zero-channel edge: nothing was pre-identified, so every
        // real-time detection is a miss — and nothing panics.
        let mut t = HitRateTracker::new("l", OutlierSet::default());
        t.record(&OutlierSet::new(vec![3, 4]));
        assert_eq!(t.summary().0, 0.0);
        // ...while an empty detection still counts as a perfect hit
        t.record(&OutlierSet::default());
        assert_eq!(t.iterations(), 2);
        assert_eq!(t.summary().0, 0.5);
    }

    #[test]
    fn all_outlier_layer_hits_perfectly() {
        // All-outlier edge: predefined covers the whole axis, so any
        // detected subset is a 100 % hit.
        let full = OutlierSet::new((0..16).collect());
        let mut t = HitRateTracker::new("l", full.clone());
        t.record(&full);
        t.record(&OutlierSet::new(vec![0, 15]));
        let (mean, std) = t.summary();
        assert_eq!(mean, 1.0);
        assert_eq!(std, 0.0);
    }

    #[test]
    fn zero_iteration_summary_is_defined() {
        let t = HitRateTracker::new("l", OutlierSet::new(vec![1]));
        assert_eq!(t.iterations(), 0);
        let (mean, std) = t.summary();
        assert_eq!((mean, std), (0.0, 0.0));
        assert!(t.series().is_empty());
    }

    #[test]
    fn similarity_tracker_with_zero_channels_is_total() {
        // Pearson over an empty subset is degenerate → 0.0, not a panic.
        let mut t = SimilarityTracker::new("l", Vec::new(), Vec::new());
        t.record_full(&[1.0, 2.0, 3.0]);
        assert_eq!(t.series(), &[0.0]);
        assert_eq!(t.last(), Some(0.0));
        assert!(t.channels().is_empty());
    }

    #[test]
    fn similarity_tracker_constant_factors_are_degenerate_zero() {
        // A constant factor vector has zero variance → correlation is
        // defined as 0 (see util::pearson).
        let mut t = SimilarityTracker::new("l", vec![0, 1, 2], vec![2.0, 2.0, 2.0]);
        t.record_full(&[5.0, 1.0, 3.0]);
        assert_eq!(t.series(), &[0.0]);
    }

    #[test]
    fn reference_set_wider_than_axis_is_defined() {
        // Reference-set-larger-than-cin edge: a tracker built over 6
        // channels fed a 3-wide axis must not panic or emit NaN — the
        // out-of-range channels are skipped pairwise.
        let channels = vec![0, 1, 2, 3, 4, 5];
        let stat = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut t = SimilarityTracker::new("l", channels, stat);
        t.record_full(&[1.0, 2.0, 3.0]);
        let s = t.series();
        assert_eq!(s.len(), 1);
        assert!(s[0].is_finite());
        assert!((s[0] - 1.0).abs() < 1e-6); // in-range pairs correlate perfectly
        // Entirely out-of-range axis → degenerate zero, still defined.
        let mut t2 = SimilarityTracker::new("l", vec![10, 11], vec![1.0, 2.0]);
        t2.record_full(&[0.5]);
        assert_eq!(t2.series(), &[0.0]);
    }

    #[test]
    fn hit_rate_reference_wider_than_axis_is_defined() {
        // A predefined set referencing channels beyond cin still yields
        // rates in [0, 1]: intersection is over indices, no indexing occurs.
        let pre = OutlierSet::new((0..64).collect());
        let mut t = HitRateTracker::new("l", pre);
        t.record(&OutlierSet::new(vec![0, 1, 2]));
        assert_eq!(t.summary().0, 1.0);
        t.record(&OutlierSet::new(vec![100, 200]));
        let (mean, std) = t.summary();
        assert!((mean - 0.5).abs() < 1e-12);
        assert!(std.is_finite());
    }

    #[test]
    fn set_reference_swaps_scoring_and_keeps_series() {
        let mut t = HitRateTracker::new("l", OutlierSet::new(vec![0, 1]));
        t.record(&OutlierSet::new(vec![0, 1])); // 1.0 vs old reference
        assert_eq!(t.reference().channels, vec![0, 1]);
        t.set_reference(OutlierSet::new(vec![8, 9]));
        t.record(&OutlierSet::new(vec![0, 1])); // 0.0 vs new reference
        assert_eq!(t.series(), &[1.0, 0.0]);
        assert_eq!(t.reference().channels, vec![8, 9]);
    }

    #[test]
    fn from_parts_round_trips_state() {
        let t = HitRateTracker::from_parts("l", OutlierSet::new(vec![3]), vec![1.0, 0.5]);
        assert_eq!(t.iterations(), 2);
        assert_eq!(t.series(), &[1.0, 0.5]);
        let s = SimilarityTracker::from_parts("l", vec![0, 2], vec![1.0, 3.0], vec![0.9]);
        assert_eq!(s.channels(), &[0, 2]);
        assert_eq!(s.series(), &[0.9]);
        assert_eq!(s.last(), Some(0.9));
    }

    #[test]
    fn similarity_decays_with_drift() {
        let channels = vec![0, 1, 2, 3, 4];
        let stat = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut t = SimilarityTracker::new("l", channels, stat.clone());
        // iteration 0: identical factors → similarity 1
        t.record_full(&[1.0, 2.0, 3.0, 4.0, 5.0, 99.0]);
        // later: factors reshuffled → similarity drops
        t.record_full(&[5.0, 1.0, 4.0, 2.0, 3.0, 99.0]);
        let s = t.series();
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!(s[1] < 0.5);
        assert_eq!(t.last(), Some(s[1]));
    }
}
