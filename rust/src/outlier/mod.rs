//! Outlier-channel machinery: calibration statistics, the Eq. 6 detection
//! criterion, the non-uniform per-layer-type budget allocator (§3.3 / §B),
//! and the OSSH validation instruments (hit-rate + scaling-similarity
//! trackers used for Figs. 3, 8–11 and Table 6).

mod budget;
mod detect;
mod hitrate;

pub use budget::{BudgetAllocator, BudgetPolicy, LayerKind};
pub use detect::{ChannelStats, OutlierDetector};
pub use hitrate::{HitRateTracker, SimilarityTracker};

/// The pre-identified outlier channel set `O` of one linear layer, fixed
/// before fine-tuning under OSSH.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OutlierSet {
    /// Sorted channel indices.
    pub channels: Vec<usize>,
}

impl OutlierSet {
    pub fn new(mut channels: Vec<usize>) -> OutlierSet {
        channels.sort_unstable();
        channels.dedup();
        OutlierSet { channels }
    }

    pub fn len(&self) -> usize {
        self.channels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.channels.is_empty()
    }

    pub fn contains(&self, ch: usize) -> bool {
        self.channels.binary_search(&ch).is_ok()
    }

    /// |self ∩ other| — the hit count for OSSH validation.
    pub fn intersection_size(&self, other: &OutlierSet) -> usize {
        let mut i = 0;
        let mut j = 0;
        let mut n = 0;
        while i < self.channels.len() && j < other.channels.len() {
            match self.channels[i].cmp(&other.channels[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        n
    }
}

/// A registry mapping every linear layer (by name) to its outlier set —
/// the output of the calibration phase, part of the coordinator's
/// distribution bundle.
#[derive(Clone, Debug, Default)]
pub struct OutlierRegistry {
    entries: std::collections::BTreeMap<String, OutlierSet>,
}

impl OutlierRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, layer: &str, set: OutlierSet) {
        self.entries.insert(layer.to_string(), set);
    }

    pub fn get(&self, layer: &str) -> Option<&OutlierSet> {
        self.entries.get(layer)
    }

    pub fn layers(&self) -> impl Iterator<Item = (&String, &OutlierSet)> {
        self.entries.iter()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total outlier channels across layers.
    pub fn total_channels(&self) -> usize {
        self.entries.values().map(|s| s.len()).sum()
    }

    /// Overall overhead fraction given total input channels across layers —
    /// the "≤5 %" budget check from §3.3.
    pub fn overhead_fraction(&self, total_cin: usize) -> f64 {
        if total_cin == 0 {
            0.0
        } else {
            self.total_channels() as f64 / total_cin as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outlier_set_sorted_dedup() {
        let s = OutlierSet::new(vec![5, 1, 3, 1, 5]);
        assert_eq!(s.channels, vec![1, 3, 5]);
        assert!(s.contains(3));
        assert!(!s.contains(2));
    }

    #[test]
    fn intersection_size() {
        let a = OutlierSet::new(vec![1, 2, 3, 8]);
        let b = OutlierSet::new(vec![2, 3, 4, 9]);
        assert_eq!(a.intersection_size(&b), 2);
        assert_eq!(b.intersection_size(&a), 2);
        assert_eq!(a.intersection_size(&OutlierSet::default()), 0);
    }

    #[test]
    fn registry_overhead() {
        let mut r = OutlierRegistry::new();
        r.insert("l0.q_proj", OutlierSet::new(vec![0, 1]));
        r.insert("l0.down_proj", OutlierSet::new(vec![3, 4, 5]));
        assert_eq!(r.total_channels(), 5);
        assert!((r.overhead_fraction(100) - 0.05).abs() < 1e-12);
    }
}
