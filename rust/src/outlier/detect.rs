//! Calibration-time channel statistics and the Eq. 6 outlier criterion.
//!
//! For each calibration sample `i`, a channel `o` scores a vote when its
//! column magnitude dominates the typical magnitude of the sample:
//! `ξ_o = Σ_i 1[ max|X^i_{:,o}| > τ · ref(|X^i|) ]` (Eq. 6 uses τ=100× the
//! *typical* activation; we parameterize τ and use the sample median of
//! per-channel maxima as the reference, which matches the paper's "100×
//! larger than typical activations" reading and is robust to the outliers
//! themselves inflating the reference).

use super::OutlierSet;
use crate::tensor::Matrix;

/// Streaming per-channel activation statistics for one linear layer's input.
#[derive(Clone, Debug)]
pub struct ChannelStats {
    /// Number of input channels (c_in).
    pub channels: usize,
    /// Per-channel running max of |X|.
    pub abs_max: Vec<f32>,
    /// Per-channel sum of per-sample maxima (for means).
    sum_max: Vec<f64>,
    /// Eq. 6 votes per channel.
    pub votes: Vec<u32>,
    /// Number of samples observed.
    pub samples: u32,
}

impl ChannelStats {
    pub fn new(channels: usize) -> ChannelStats {
        ChannelStats {
            channels,
            abs_max: vec![0.0; channels],
            sum_max: vec![0.0; channels],
            votes: vec![0; channels],
            samples: 0,
        }
    }

    /// Observe one calibration sample's activations `X^i (tokens × c_in)`,
    /// casting Eq. 6 votes with dominance ratio `tau`.
    pub fn observe(&mut self, x: &Matrix, tau: f32) {
        assert_eq!(x.cols(), self.channels, "channel count mismatch");
        let col_max = x.col_abs_max();
        // Reference level: median of per-channel maxima for this sample.
        let mut sorted = col_max.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let reference = sorted[sorted.len() / 2].max(1e-12);
        for (o, &m) in col_max.iter().enumerate() {
            if m > self.abs_max[o] {
                self.abs_max[o] = m;
            }
            self.sum_max[o] += m as f64;
            if m > tau * reference {
                self.votes[o] += 1;
            }
        }
        self.samples += 1;
    }

    /// Mean per-sample channel maximum.
    pub fn mean_max(&self, o: usize) -> f32 {
        if self.samples == 0 {
            0.0
        } else {
            (self.sum_max[o] / self.samples as f64) as f32
        }
    }
}

/// Outlier detector: ranks channels by Eq. 6 votes (ties broken by magnitude)
/// and selects up to a budget.
#[derive(Clone, Debug)]
pub struct OutlierDetector {
    /// Dominance ratio τ in Eq. 6 (paper: 100).
    pub tau: f32,
}

impl Default for OutlierDetector {
    fn default() -> Self {
        OutlierDetector { tau: 100.0 }
    }
}

impl OutlierDetector {
    pub fn new(tau: f32) -> Self {
        OutlierDetector { tau }
    }

    /// Select up to `budget` outlier channels from calibration stats.
    ///
    /// Channels with zero votes are only admitted if the budget demands it
    /// and their magnitude still dominates (`rank_by_magnitude`); with no
    /// qualified channels the returned set may be smaller than the budget —
    /// we never pad with normal channels (that would waste W_O memory).
    pub fn select(&self, stats: &ChannelStats, budget: usize) -> OutlierSet {
        let mut ranked: Vec<usize> = (0..stats.channels).collect();
        ranked.sort_by(|&a, &b| {
            stats.votes[b]
                .cmp(&stats.votes[a])
                .then_with(|| {
                    stats.abs_max[b]
                        .partial_cmp(&stats.abs_max[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .then_with(|| a.cmp(&b))
        });
        let picked: Vec<usize> = ranked
            .into_iter()
            .take(budget)
            .filter(|&o| stats.votes[o] > 0)
            .collect();
        OutlierSet::new(picked)
    }

    /// Real-time detection over a single batch's activations — the
    /// "dynamically detected channels" side of the OSSH hit-rate measurement
    /// (and LLM.int8's per-step detector). Returns the top channels whose
    /// magnitude dominates the batch median by `tau`.
    pub fn detect_realtime(&self, x: &Matrix, max_channels: usize) -> OutlierSet {
        let col_max = x.col_abs_max();
        let mut sorted = col_max.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let reference = sorted[sorted.len() / 2].max(1e-12);
        let mut qualified: Vec<usize> = (0..x.cols())
            .filter(|&o| col_max[o] > self.tau * reference)
            .collect();
        qualified.sort_by(|&a, &b| {
            col_max[b]
                .partial_cmp(&col_max[a])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        qualified.truncate(max_channels);
        OutlierSet::new(qualified)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Build activations with planted outliers at `hot` channels.
    fn planted(rng: &mut Rng, tokens: usize, cin: usize, hot: &[usize], gain: f32) -> Matrix {
        let mut x = Matrix::randn(tokens, cin, rng, 1.0);
        for &c in hot {
            for t in 0..tokens {
                let v = x.get(t, c);
                x.set(t, c, v * gain);
            }
        }
        x
    }

    #[test]
    fn detects_planted_channels() {
        let mut rng = Rng::new(1);
        let hot = vec![7, 42, 99];
        let mut stats = ChannelStats::new(128);
        for _ in 0..16 {
            let x = planted(&mut rng, 32, 128, &hot, 500.0);
            stats.observe(&x, 100.0);
        }
        let det = OutlierDetector::new(100.0);
        let set = det.select(&stats, 3);
        assert_eq!(set.channels, hot);
    }

    #[test]
    fn no_outliers_means_empty_set_even_with_budget() {
        let mut rng = Rng::new(2);
        let mut stats = ChannelStats::new(64);
        for _ in 0..8 {
            let x = Matrix::randn(16, 64, &mut rng, 1.0);
            stats.observe(&x, 100.0);
        }
        let det = OutlierDetector::default();
        let set = det.select(&stats, 10);
        assert!(set.is_empty(), "picked {:?}", set.channels);
    }

    #[test]
    fn budget_caps_selection() {
        let mut rng = Rng::new(3);
        let hot: Vec<usize> = (0..10).collect();
        let mut stats = ChannelStats::new(64);
        for _ in 0..8 {
            let x = planted(&mut rng, 16, 64, &hot, 300.0);
            stats.observe(&x, 50.0);
        }
        let det = OutlierDetector::new(50.0);
        let set = det.select(&stats, 4);
        assert_eq!(set.len(), 4);
        assert!(set.channels.iter().all(|c| hot.contains(c)));
    }

    #[test]
    fn votes_monotone_in_gain() {
        // Property: a channel with a larger planted gain never gets fewer
        // votes than the same channel with a smaller gain.
        let votes_for_gain = |gain: f32| {
            let mut rng = Rng::new(4);
            let mut stats = ChannelStats::new(32);
            for _ in 0..12 {
                let x = planted(&mut rng, 8, 32, &[5], gain);
                stats.observe(&x, 30.0);
            }
            stats.votes[5]
        };
        assert!(votes_for_gain(500.0) >= votes_for_gain(50.0));
        assert!(votes_for_gain(50.0) >= votes_for_gain(1.0));
    }

    #[test]
    fn realtime_matches_planted() {
        let mut rng = Rng::new(5);
        let x = planted(&mut rng, 64, 128, &[3, 77], 400.0);
        let det = OutlierDetector::new(100.0);
        let set = det.detect_realtime(&x, 8);
        assert_eq!(set.channels, vec![3, 77]);
    }

    #[test]
    fn tie_breaking_is_deterministic_under_equal_magnitudes() {
        // Property: when every channel has identical votes and identical
        // abs_max, selection falls through to the index tie-break and must
        // pick the lowest indices — stably, on every call.
        crate::util::prop::check(
            "detect tie-break deterministic",
            0xDE7EC7,
            64,
            |rng| {
                let cin = 3 + rng.below(29);
                let budget = 1 + rng.below(cin);
                let amp = rng.range(10.0, 100.0);
                (cin, budget, amp)
            },
            |&(cin, budget, amp)| {
                // Plant an equal-magnitude hot group strictly smaller than
                // half the axis so the per-sample median reference stays at
                // the low-magnitude majority and every hot channel votes.
                let hot_n = (cin / 3).max(1);
                let mut data = vec![0.001_f32; cin];
                for item in data.iter_mut().take(hot_n) {
                    *item = amp;
                }
                let x = Matrix::from_vec(1, cin, data);
                let mut stats = ChannelStats::new(cin);
                stats.observe(&x, 2.0);
                let det = OutlierDetector::new(2.0);
                let a = det.select(&stats, budget);
                let b = det.select(&stats, budget);
                if a.channels != b.channels {
                    return Err(format!("unstable selection: {:?} vs {:?}", a.channels, b.channels));
                }
                let expect: Vec<usize> = (0..hot_n.min(budget)).collect();
                if a.channels != expect {
                    return Err(format!(
                        "tie-break not lowest-index-first: got {:?}, want {:?} (cin={cin}, budget={budget})",
                        a.channels, expect
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn degenerate_activations_never_panic() {
        // Property: all-zero, single-channel (cin=1) and single-token
        // activations are all well-defined — empty or tiny sets, no panics.
        crate::util::prop::check(
            "detect degenerate shapes",
            0x0551,
            64,
            |rng| {
                let tokens = 1 + rng.below(8);
                let tau = rng.range(2.0, 100.0);
                (tokens, tau)
            },
            |&(tokens, tau)| {
                let det = OutlierDetector::new(tau);
                // All-zero activations: median reference floors at 1e-12,
                // nothing dominates, empty set.
                let zero = Matrix::zeros(tokens, 16);
                let mut stats = ChannelStats::new(16);
                stats.observe(&zero, tau);
                if !det.select(&stats, 8).is_empty() {
                    return Err("all-zero activations produced outliers".into());
                }
                if !det.detect_realtime(&zero, 8).is_empty() {
                    return Err("all-zero realtime detection produced outliers".into());
                }
                // cin=1: the sole channel IS the median, can never dominate
                // itself by tau > 1 — and nothing indexes out of range.
                let one = Matrix::from_vec(tokens, 1, vec![3.5; tokens]);
                let mut s1 = ChannelStats::new(1);
                s1.observe(&one, tau);
                let sel = det.select(&s1, 4);
                if sel.len() > 1 {
                    return Err(format!("cin=1 selected {} channels", sel.len()));
                }
                let rt = det.detect_realtime(&one, 4);
                if rt.len() > 1 {
                    return Err(format!("cin=1 realtime found {} channels", rt.len()));
                }
                // Single token, mixed magnitudes: still defined.
                let single = Matrix::from_vec(1, 4, vec![0.01, 500.0, 0.02, 0.01]);
                let rt2 = det.detect_realtime(&single, 4);
                if rt2.len() > 1 {
                    return Err("single-token detection over-selected".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn selection_is_repeatable_across_observation_replay() {
        // Property: replaying the same observations into a fresh
        // ChannelStats yields the identical selection (no hidden state).
        crate::util::prop::check(
            "detect replay stable",
            0x5EED5,
            32,
            |rng| rng.next_u64(),
            |&seed| {
                let run = || {
                    let mut rng = Rng::new(seed);
                    let mut stats = ChannelStats::new(24);
                    for _ in 0..6 {
                        let x = planted(&mut rng, 8, 24, &[2, 17], 200.0);
                        stats.observe(&x, 20.0);
                    }
                    OutlierDetector::new(20.0).select(&stats, 6).channels
                };
                let (a, b) = (run(), run());
                if a != b {
                    return Err(format!("replay diverged: {a:?} vs {b:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn mean_max_tracks_average() {
        let mut stats = ChannelStats::new(2);
        let a = Matrix::from_vec(1, 2, vec![1.0, -2.0]);
        let b = Matrix::from_vec(1, 2, vec![3.0, 0.0]);
        stats.observe(&a, 100.0);
        stats.observe(&b, 100.0);
        assert!((stats.mean_max(0) - 2.0).abs() < 1e-6);
        assert!((stats.mean_max(1) - 1.0).abs() < 1e-6);
        assert_eq!(stats.abs_max, vec![3.0, 2.0]);
    }
}
