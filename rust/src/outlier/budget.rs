//! Non-uniform per-layer-type outlier budget allocation (§3.3, Appendix B).
//!
//! The paper allocates 0.03 %·c_in to q/k/v/up projections, 4 %·c_in to
//! o_proj and 10 %·c_in to down_proj, keeping the model-wide overhead below
//! 5 %. Appendix B's Fig. 9 shows the uniform alternative collapses hit rate
//! on volatile layers — both policies are implemented so the ablation can be
//! regenerated.

/// The six linear-layer types of a decoder block the paper distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LayerKind {
    QProj,
    KProj,
    VProj,
    OProj,
    UpProj,
    DownProj,
    /// LM head / anything else: treated like a stable projection.
    Other,
}

impl LayerKind {
    /// Parse from a layer-name suffix (e.g. "blocks.3.attn.q_proj").
    pub fn from_name(name: &str) -> LayerKind {
        if name.ends_with("q_proj") {
            LayerKind::QProj
        } else if name.ends_with("k_proj") {
            LayerKind::KProj
        } else if name.ends_with("v_proj") {
            LayerKind::VProj
        } else if name.ends_with("o_proj") {
            LayerKind::OProj
        } else if name.ends_with("up_proj") {
            LayerKind::UpProj
        } else if name.ends_with("down_proj") {
            LayerKind::DownProj
        } else {
            LayerKind::Other
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LayerKind::QProj => "q_proj",
            LayerKind::KProj => "k_proj",
            LayerKind::VProj => "v_proj",
            LayerKind::OProj => "o_proj",
            LayerKind::UpProj => "up_proj",
            LayerKind::DownProj => "down_proj",
            LayerKind::Other => "other",
        }
    }
}

/// Budget policy: paper's non-uniform allocation or the uniform ablation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BudgetPolicy {
    /// §3.3 allocation: 0.03 % (q/k/v/up), 4 % (o), 10 % (down).
    PaperNonUniform,
    /// Fig. 9 ablation: the same overall budget spread uniformly.
    Uniform(f64),
    /// Scale every layer's non-uniform fraction by `x` (Table 7 sweep:
    /// overall budgets of 5/3/1/0.1/0 %).
    ScaledNonUniform(f64),
}

/// Computes per-layer channel budgets.
#[derive(Clone, Debug)]
pub struct BudgetAllocator {
    pub policy: BudgetPolicy,
}

impl BudgetAllocator {
    pub fn new(policy: BudgetPolicy) -> Self {
        BudgetAllocator { policy }
    }

    /// Paper fractions per layer kind.
    fn paper_fraction(kind: LayerKind) -> f64 {
        match kind {
            LayerKind::QProj | LayerKind::KProj | LayerKind::VProj | LayerKind::UpProj => 0.0003,
            LayerKind::OProj => 0.04,
            LayerKind::DownProj => 0.10,
            LayerKind::Other => 0.0003,
        }
    }

    /// Channel budget for a layer of kind `kind` with `cin` input channels.
    /// Non-zero fractions grant at least one channel so tiny simulated models
    /// can still exercise the mechanism (at 0.03 % of c_in=256 the paper's
    /// formula would round to zero everywhere); over-unity fractions clamp
    /// to `cin` (all-outlier), and a zero-channel layer gets 0 — the
    /// min-1-channel floor must not outgrow the layer.
    pub fn channels_for(&self, kind: LayerKind, cin: usize) -> usize {
        let frac = match self.policy {
            BudgetPolicy::PaperNonUniform => Self::paper_fraction(kind),
            BudgetPolicy::Uniform(f) => f,
            // Scale each layer-type fraction relative to the paper's ~5 %
            // envelope, so ScaledNonUniform(0.05) == PaperNonUniform.
            BudgetPolicy::ScaledNonUniform(x) => Self::paper_fraction(kind) * (x / 0.05),
        };
        if frac <= 0.0 || cin == 0 {
            return 0;
        }
        ((cin as f64 * frac).round() as usize).clamp(1, cin)
    }

    /// Model-wide overhead fraction for a list of `(kind, cin)` layers —
    /// used to assert the ≤5 % envelope of §3.3.
    pub fn overall_fraction(&self, layers: &[(LayerKind, usize)]) -> f64 {
        let total: usize = layers.iter().map(|&(_, cin)| cin).sum();
        if total == 0 {
            return 0.0;
        }
        let used: usize = layers
            .iter()
            .map(|&(k, cin)| self.channels_for(k, cin))
            .sum();
        used as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_kind_parsing() {
        assert_eq!(LayerKind::from_name("blocks.0.attn.q_proj"), LayerKind::QProj);
        assert_eq!(LayerKind::from_name("blocks.11.mlp.down_proj"), LayerKind::DownProj);
        assert_eq!(LayerKind::from_name("lm_head"), LayerKind::Other);
    }

    #[test]
    fn paper_budgets_ordering() {
        let a = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
        let cin = 4096;
        let q = a.channels_for(LayerKind::QProj, cin);
        let o = a.channels_for(LayerKind::OProj, cin);
        let d = a.channels_for(LayerKind::DownProj, cin);
        assert!(q < o && o < d, "q={q} o={o} d={d}");
        assert_eq!(o, (4096.0_f64 * 0.04).round() as usize);
        assert_eq!(d, (4096.0_f64 * 0.10).round() as usize);
    }

    #[test]
    fn overall_under_five_percent_for_transformer_shape() {
        // One decoder block at LLaMA-ish ratios: d=4096, ff=11008.
        let d = 4096;
        let ff = 11008;
        let layers = vec![
            (LayerKind::QProj, d),
            (LayerKind::KProj, d),
            (LayerKind::VProj, d),
            (LayerKind::OProj, d),
            (LayerKind::UpProj, d),
            (LayerKind::DownProj, ff),
        ];
        let a = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
        let f = a.overall_fraction(&layers);
        assert!(f < 0.05, "overall fraction {f} exceeds 5%");
    }

    #[test]
    fn min_one_channel_for_nonzero_fraction() {
        let a = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
        assert_eq!(a.channels_for(LayerKind::QProj, 256), 1);
    }

    #[test]
    fn zero_budget_gives_zero() {
        let a = BudgetAllocator::new(BudgetPolicy::ScaledNonUniform(0.0));
        assert_eq!(a.channels_for(LayerKind::DownProj, 1024), 0);
        let u = BudgetAllocator::new(BudgetPolicy::Uniform(0.0));
        assert_eq!(u.channels_for(LayerKind::DownProj, 1024), 0);
    }

    #[test]
    fn scaled_budget_scales_linearly() {
        let full = BudgetAllocator::new(BudgetPolicy::ScaledNonUniform(0.05));
        let fifth = BudgetAllocator::new(BudgetPolicy::ScaledNonUniform(0.01));
        let cin = 10_000;
        let f = full.channels_for(LayerKind::DownProj, cin);
        let s = fifth.channels_for(LayerKind::DownProj, cin);
        assert_eq!(f, 1000); // 10% of 10k
        assert_eq!(s, 200); // scaled by 1/5
    }

    #[test]
    fn zero_channel_layer_gets_zero_budget_for_every_policy() {
        // Regression: the min-1-channel floor used to clamp(1, 0), which
        // panics — a zero-width layer must simply get no budget.
        for policy in [
            BudgetPolicy::PaperNonUniform,
            BudgetPolicy::Uniform(0.5),
            BudgetPolicy::ScaledNonUniform(0.05),
        ] {
            let a = BudgetAllocator::new(policy);
            for kind in [
                LayerKind::QProj,
                LayerKind::KProj,
                LayerKind::VProj,
                LayerKind::OProj,
                LayerKind::UpProj,
                LayerKind::DownProj,
                LayerKind::Other,
            ] {
                assert_eq!(a.channels_for(kind, 0), 0, "{policy:?}/{kind:?}");
            }
        }
        assert_eq!(
            BudgetAllocator::new(BudgetPolicy::PaperNonUniform).overall_fraction(&[]),
            0.0
        );
    }

    #[test]
    fn budget_clamps_at_full_width_for_over_unity_fractions() {
        // All-outlier: a fraction ≥ 1 can never grant more channels than
        // the layer has.
        let u = BudgetAllocator::new(BudgetPolicy::Uniform(2.0));
        assert_eq!(u.channels_for(LayerKind::QProj, 10), 10);
        assert_eq!(u.channels_for(LayerKind::DownProj, 1), 1);
        let s = BudgetAllocator::new(BudgetPolicy::ScaledNonUniform(1.0));
        // down_proj fraction 0.10 * (1.0/0.05) = 2.0 → clamp to cin
        assert_eq!(s.channels_for(LayerKind::DownProj, 64), 64);
        // the min-1 floor at the other extreme: tiny fraction, tiny layer
        let t = BudgetAllocator::new(BudgetPolicy::Uniform(1e-9));
        assert_eq!(t.channels_for(LayerKind::QProj, 1), 1);
    }

    #[test]
    fn uniform_policy_uniform_across_kinds() {
        let u = BudgetAllocator::new(BudgetPolicy::Uniform(0.05));
        let cin = 2048;
        let q = u.channels_for(LayerKind::QProj, cin);
        let d = u.channels_for(LayerKind::DownProj, cin);
        assert_eq!(q, d);
        assert_eq!(q, 102);
    }
}
