//! Integration tests for the `bench_gate` binary itself — the gate guards
//! every perf claim in CI, so its CLI behaviour is pinned here by driving
//! the real executable over fixture JSON: seeding mode, the ±tolerance
//! pass/fail verdicts, `--update` baseline promotion, `--meta` stamp
//! printing, and the exit-2 refusals (unstamped records, cross-ISA
//! comparisons, unparseable input).
//!
//! Exit-code contract: 0 = pass/seeding, 1 = regression, 2 = unusable
//! input (refuse to compare rather than pass vacuously).

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use quaff::util::json::Json;

/// Fresh per-test fixture directory (tests in this binary run in
/// parallel, so each gets its own).
fn fixture_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("quaff_gate_cli_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create fixture dir");
    dir
}

/// A stamped single-kernel bench record with the given mean.
fn record(bench: &str, name: &str, ns: f64, isa: &str) -> String {
    format!(
        r#"{{"bench":"{bench}","meta":{{"isa":"{isa}","tile":"4x8","threads":4}},
           "kernels":[{{"name":"{name}","ns_per_op":{ns},"p50_ns":{p50}}}]}}"#,
        p50 = ns * 2.0
    )
}

fn write(path: &Path, text: &str) {
    std::fs::write(path, text).expect("write fixture");
}

/// Run the real gate binary with `args`, all paths absolute so the test
/// is independent of the harness working directory.
fn gate(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_bench_gate"))
        .args(args)
        .output()
        .expect("spawn bench_gate")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// An empty baseline puts the gate in seeding mode: exit 0, the fresh
/// entries are recorded in the diff, and the output explains how to arm.
#[test]
fn empty_baseline_is_seeding_mode() {
    let dir = fixture_dir("seed");
    let baseline = dir.join("BENCH_baseline.json");
    let fresh = dir.join("BENCH_serve.json");
    let diff = dir.join("diff.json");
    write(&baseline, r#"{"tolerance":0.25,"entries":{}}"#);
    write(&fresh, &record("serve", "mixed", 100.0, "avx2"));
    let out = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        fresh.to_str().unwrap(),
        "--diff",
        diff.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(0), "seeding must pass: {}", stderr(&out));
    assert!(stdout(&out).contains("seeding"), "stdout: {}", stdout(&out));
    assert!(stdout(&out).contains("--update"), "must explain how to arm the gate");
    let diff_json = Json::parse(&std::fs::read_to_string(&diff).unwrap()).unwrap();
    assert_eq!(diff_json.get("pass"), Some(&Json::Bool(true)));
    let findings = diff_json.get("findings").and_then(Json::as_arr).unwrap();
    assert_eq!(findings.len(), 2, "ns_per_op + p50_ns recorded as new");
    assert!(findings
        .iter()
        .all(|f| f.get("verdict").and_then(Json::as_str) == Some("new")));
}

/// Within ±25% the armed gate passes (exit 0); beyond it fails (exit 1)
/// and names the regressed entry in stdout and the diff artifact.
#[test]
fn tolerance_splits_pass_from_fail() {
    let dir = fixture_dir("tol");
    let baseline = dir.join("BENCH_baseline.json");
    let fresh = dir.join("BENCH_serve.json");
    let diff = dir.join("diff.json");
    write(
        &baseline,
        r#"{"tolerance":0.25,"entries":{"serve/mixed/ns_per_op":100.0,"serve/mixed/p50_ns":200.0}}"#,
    );
    let run = |ns: f64| {
        write(&fresh, &record("serve", "mixed", ns, "avx2"));
        gate(&[
            "--baseline",
            baseline.to_str().unwrap(),
            "--fresh",
            fresh.to_str().unwrap(),
            "--diff",
            diff.to_str().unwrap(),
        ])
    };

    let ok = run(120.0); // +20% — inside the band
    assert_eq!(ok.status.code(), Some(0), "{}", stderr(&ok));
    assert!(stdout(&ok).contains("PASS"), "stdout: {}", stdout(&ok));

    let fail = run(130.0); // +30% — regression
    assert_eq!(fail.status.code(), Some(1), "a regression must exit 1");
    assert!(stdout(&fail).contains("REGRESSED"));
    assert!(stdout(&fail).contains("serve/mixed/ns_per_op"), "names the entry");
    assert!(stdout(&fail).contains("FAIL"));
    let diff_json = Json::parse(&std::fs::read_to_string(&diff).unwrap()).unwrap();
    assert_eq!(diff_json.get("pass"), Some(&Json::Bool(false)));
    assert!(
        diff_json.get("findings").and_then(Json::as_arr).unwrap().iter().any(|f| {
            f.get("id").and_then(Json::as_str) == Some("serve/mixed/ns_per_op")
                && f.get("verdict").and_then(Json::as_str) == Some("regressed")
        }),
        "diff artifact carries the machine-readable verdict"
    );

    let improved = run(60.0); // -40% — faster is never a failure
    assert_eq!(improved.status.code(), Some(0));
    assert!(stdout(&improved).contains("improved"));

    // a baselined entry with no fresh record is a silently-skipped bench
    write(&fresh, &record("serve", "other", 100.0, "avx2"));
    let missing = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        fresh.to_str().unwrap(),
        "--diff",
        diff.to_str().unwrap(),
    ]);
    assert_eq!(missing.status.code(), Some(1), "missing records fail the gate");
    assert!(stdout(&missing).contains("MISSING"));
}

/// `--update` rewrites the baseline from the fresh records, propagating
/// the meta stamp; the rewritten baseline then passes against the same
/// records. Updating from nothing is refused (would disarm the gate).
#[test]
fn update_promotes_fresh_records_with_stamp() {
    let dir = fixture_dir("update");
    let baseline = dir.join("BENCH_baseline.json");
    let fresh = dir.join("BENCH_serve.json");
    let diff = dir.join("diff.json");
    write(&baseline, r#"{"tolerance":0.25,"entries":{}}"#);
    write(&fresh, &record("serve", "mixed", 100.0, "avx2"));
    let out = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        fresh.to_str().unwrap(),
        "--diff",
        diff.to_str().unwrap(),
        "--update",
    ]);
    assert_eq!(out.status.code(), Some(0), "{}", stderr(&out));
    assert!(stdout(&out).contains("updated"));
    let promoted = Json::parse(&std::fs::read_to_string(&baseline).unwrap()).unwrap();
    let entries = match promoted.get("entries") {
        Some(Json::Obj(m)) => m,
        other => panic!("baseline has no entries object: {other:?}"),
    };
    assert_eq!(entries.get("serve/mixed/ns_per_op").and_then(Json::as_f64), Some(100.0));
    assert_eq!(entries.get("serve/mixed/p50_ns").and_then(Json::as_f64), Some(200.0));
    assert_eq!(
        promoted.get("meta").and_then(|m| m.get("isa")).and_then(Json::as_str),
        Some("avx2"),
        "the measurement stamp must ride into the baseline"
    );
    // the promoted baseline is immediately green against the same records
    let recheck = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        fresh.to_str().unwrap(),
        "--diff",
        diff.to_str().unwrap(),
    ]);
    assert_eq!(recheck.status.code(), Some(0));
    assert!(stdout(&recheck).contains("PASS"));

    // --update with zero fresh entries would disarm the gate: refuse
    let none = dir.join("does_not_exist.json");
    let refused = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        none.to_str().unwrap(),
        "--diff",
        diff.to_str().unwrap(),
        "--update",
    ]);
    assert_eq!(refused.status.code(), Some(2));
    assert!(stderr(&refused).contains("refusing"));
}

/// `--meta` prints each record's `{isa, tile, threads}` stamp; any
/// missing or unstamped record exits 2 so CI can't compare blind.
#[test]
fn meta_prints_stamps_and_rejects_unstamped() {
    let dir = fixture_dir("meta");
    let stamped = dir.join("BENCH_serve.json");
    let unstamped = dir.join("BENCH_legacy.json");
    write(&stamped, &record("serve", "mixed", 100.0, "avx2"));
    write(&unstamped, r#"{"bench":"legacy","kernels":[{"name":"k","ns_per_op":1.0}]}"#);

    let ok = gate(&["--fresh", stamped.to_str().unwrap(), "--meta"]);
    assert_eq!(ok.status.code(), Some(0), "{}", stderr(&ok));
    assert!(stdout(&ok).contains("isa=avx2"));
    assert!(stdout(&ok).contains("tile=4x8"));
    assert!(stdout(&ok).contains("threads=4"));

    let both = format!("{},{}", stamped.to_str().unwrap(), unstamped.to_str().unwrap());
    let bad = gate(&["--fresh", &both, "--meta"]);
    assert_eq!(bad.status.code(), Some(2), "unstamped records must refuse");
    assert!(stderr(&bad).contains("no meta stamp"));
    assert!(stdout(&bad).contains("isa=avx2"), "stamped records still print");

    let gone = dir.join("missing.json");
    let absent = gate(&["--fresh", gone.to_str().unwrap(), "--meta"]);
    assert_eq!(absent.status.code(), Some(2), "a missing record is not a pass");
}

/// A stamped baseline and stamped fresh records measured under different
/// ISAs refuse to compare (exit 2): cross-ISA ns deltas are machine
/// differences, not regressions.
#[test]
fn cross_isa_comparison_is_refused() {
    let dir = fixture_dir("isa");
    let baseline = dir.join("BENCH_baseline.json");
    let fresh = dir.join("BENCH_serve.json");
    let diff = dir.join("diff.json");
    write(
        &baseline,
        r#"{"tolerance":0.25,"meta":{"isa":"scalar","tile":"1x1","threads":1},
           "entries":{"serve/mixed/ns_per_op":100.0}}"#,
    );
    write(&fresh, &record("serve", "mixed", 500.0, "avx2"));
    let out = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        fresh.to_str().unwrap(),
        "--diff",
        diff.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(2), "cross-ISA must refuse, not fail or pass");
    assert!(stderr(&out).contains("ISA mismatch"));
    assert!(stderr(&out).contains("--update"), "points at the re-seed workflow");

    // two fresh records spanning ISAs are refused for the same reason
    let fresh2 = dir.join("BENCH_other.json");
    write(&fresh2, &record("other", "k", 10.0, "neon"));
    let both = format!("{},{}", fresh.to_str().unwrap(), fresh2.to_str().unwrap());
    let mixed = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        &both,
        "--diff",
        diff.to_str().unwrap(),
    ]);
    assert_eq!(mixed.status.code(), Some(2));
    assert!(stderr(&mixed).contains("multiple ISAs"));
}

/// Unparseable input exits 2 — a corrupt record or baseline must never
/// read as "no regressions".
#[test]
fn corrupt_json_is_refused() {
    let dir = fixture_dir("corrupt");
    let baseline = dir.join("BENCH_baseline.json");
    let fresh = dir.join("BENCH_serve.json");
    let diff = dir.join("diff.json");
    write(&baseline, r#"{"tolerance":0.25,"entries":{}}"#);
    write(&fresh, "{not json");
    let out = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        fresh.to_str().unwrap(),
        "--diff",
        diff.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr(&out).contains("cannot parse"));

    write(&fresh, &record("serve", "mixed", 100.0, "avx2"));
    write(&baseline, "also {not json");
    let out = gate(&[
        "--baseline",
        baseline.to_str().unwrap(),
        "--fresh",
        fresh.to_str().unwrap(),
        "--diff",
        diff.to_str().unwrap(),
    ]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr(&out).contains("cannot parse"));

    let unknown = gate(&["--definitely-not-a-flag"]);
    assert_eq!(unknown.status.code(), Some(2), "unknown flags are an argument error");
    assert!(stderr(&unknown).contains("unknown argument"));
}
