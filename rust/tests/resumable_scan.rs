//! `coordinator::resumable_jobs` over a messy checkpoint directory: valid
//! checkpoints come back in deterministic (path-sorted) order wired to
//! resume in place, a checkpoint whose current generation is corrupt but
//! whose `.prev` survives is recovered silently, a checkpoint corrupt in
//! its only generation surfaces as a readable `scan <path>` error, other
//! archive kinds sharing the `.qckpt` extension are skipped, and files
//! with other extensions are ignored outright.

use std::fs;
use std::path::{Path, PathBuf};

use quaff::coordinator::{
    resumable_jobs, run_job, CheckpointSpec, FinetuneJob, PreprocessServer, ServerConfig,
};
use quaff::methods::MethodKind;
use quaff::peft::PeftKind;
use quaff::persist;

fn server_cfg() -> ServerConfig {
    let mut cfg = ServerConfig::default();
    cfg.preset = "opt-tiny".to_string();
    cfg.calib_samples = 8;
    cfg.calib_batch = 4;
    cfg
}

fn tiny_job(id: u64, steps: u64, path: &Path) -> FinetuneJob {
    let mut j = FinetuneJob::new(id, "gpqa", MethodKind::Quaff, PeftKind::Lora);
    j.steps = steps;
    j.batch_size = 2;
    j.train_pool = 8;
    j.eval_samples = 4;
    j.max_len = 128;
    j.checkpoint = Some(CheckpointSpec { path: path.to_path_buf(), every: 1 });
    j
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("quaff_scan_{}_{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).expect("create scan dir");
    dir
}

/// Chop the second half off an archive — `tests/persist_resume.rs` pins
/// that this is detected as truncation.
fn truncate_archive(path: &Path) {
    let intact = fs::read(path).expect("read archive");
    fs::write(path, &intact[..intact.len() / 2]).expect("truncate archive");
}

#[test]
fn scan_orders_recovers_skips_and_ignores() {
    let dir = tmp_dir("mixed");
    let server = PreprocessServer::new(server_cfg());

    // z-named but lowest id: proves the order is path-sorted, not id-sorted
    let a = dir.join("a_interrupted.qckpt");
    run_job(&server, &tiny_job(30, 1, &a)).expect("write checkpoint a");
    let z = dir.join("z_interrupted.qckpt");
    run_job(&server, &tiny_job(10, 1, &z)).expect("write checkpoint z");

    // two generations (steps 2, every 1), then a corrupt current gen: the
    // scan must fall back to `.prev` instead of erroring
    let r = dir.join("m_recovered.qckpt");
    run_job(&server, &tiny_job(20, 2, &r)).expect("write checkpoint m");
    assert!(persist::previous_generation(&r).exists(), "two saves retain a .prev");
    truncate_archive(&r);

    // a saved DistributionBundle shares the extension — skipped, not fatal
    let mut bundle = server.prepare(MethodKind::Naive, PeftKind::Lora);
    bundle.save(&dir.join("k_bundle.qckpt")).expect("save bundle");

    // non-checkpoint extensions are ignored outright
    fs::write(dir.join("notes.txt"), "not an archive").unwrap();
    fs::write(dir.join("report.json"), "{}").unwrap();

    let jobs = resumable_jobs(&dir).expect("mixed dir scans cleanly");
    assert_eq!(
        jobs.iter().map(|j| j.id).collect::<Vec<_>>(),
        vec![30, 20, 10],
        "jobs come back in path-sorted order (a_, m_, z_), not id order"
    );
    for (job, path) in jobs.iter().zip([&a, &r, &z]) {
        let spec = job.checkpoint.as_ref().expect("wired to resume in place");
        assert_eq!(&spec.path, path, "spec points at the scanned file");
        assert_eq!(spec.every, 1);
        assert_eq!(job.dataset, "gpqa", "recorded spec fields survive the round trip");
    }

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_only_generation_is_a_readable_error() {
    let dir = tmp_dir("corrupt");
    let server = PreprocessServer::new(server_cfg());

    let good = dir.join("a_good.qckpt");
    run_job(&server, &tiny_job(1, 1, &good)).expect("write good checkpoint");
    // one step → one generation, no `.prev` to recover from
    let bad = dir.join("b_bad.qckpt");
    run_job(&server, &tiny_job(2, 1, &bad)).expect("write bad checkpoint");
    assert!(
        !persist::previous_generation(&bad).exists(),
        "a single save leaves no previous generation"
    );
    truncate_archive(&bad);

    let err = resumable_jobs(&dir).expect_err("corrupt-only checkpoint must not scan");
    let msg = format!("{err:#}");
    assert!(msg.contains("scan"), "error names the operation: {msg}");
    assert!(msg.contains("b_bad.qckpt"), "error names the file: {msg}");
    assert!(
        msg.contains("unusable") && msg.contains("previous generation"),
        "error explains both failed generations: {msg}"
    );

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn empty_and_missing_directories() {
    let dir = tmp_dir("empty");
    assert!(resumable_jobs(&dir).expect("empty dir is fine").is_empty());

    let gone = dir.join("never_created");
    let err = resumable_jobs(&gone).expect_err("missing dir is an error, not a panic");
    let msg = format!("{err:#}");
    assert!(msg.contains("scan"), "{msg}");
    assert!(msg.contains("never_created"), "{msg}");

    let _ = fs::remove_dir_all(&dir);
}
