//! Decode-parity suite: KV-cached incremental decoding must be
//! **bit-identical** to naive full re-forward decoding — for every WAQ
//! method, under PEFT adapters, batched against arbitrary neighbours, and
//! for any thread-pool width. Plus: sampling is seed-deterministic.
//!
//! One `#[test]` body because it flips the process-global active thread
//! width (`pool::set_active_threads`) between legs, like
//! `thread_determinism.rs`.

use quaff::infer::{self, BatchEngine, GenerateConfig, KvCache, Request};
use quaff::methods::{MethodConfig, MethodKind};
use quaff::model::{Model, ModelConfig};
use quaff::outlier::{BudgetAllocator, BudgetPolicy, OutlierDetector};
use quaff::peft::PeftKind;
use quaff::tensor::{pool, Workspace};
use quaff::util::prng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 64,
        ln_eps: 1e-5,
        inject_outliers: true,
        lora_rank: 4,
        lora_alpha: 8.0,
        lora_dropout: 0.0,
        n_virtual: 4,
    }
}

fn batch(rng: &mut Rng, b: usize, s: usize, vocab: usize) -> Vec<Vec<u32>> {
    (0..b)
        .map(|_| (0..s).map(|_| rng.below(vocab) as u32).collect())
        .collect()
}

/// Calibrate + convert a fresh tiny model to `kind` (optionally with a
/// PEFT adapter attached before calibration).
fn quantized_model(kind: MethodKind, peft: Option<PeftKind>, seed: u64) -> Model {
    let mut m = Model::new(tiny_cfg(), seed);
    if let Some(p) = peft {
        m.attach_peft(p);
    }
    let mut r = Rng::new(seed ^ 0xC0FFEE);
    // give LoRA a nonzero B so the adapter actually contributes at decode
    if peft == Some(PeftKind::Lora) {
        for b in &mut m.blocks {
            if let Some(l) = &mut b.q_proj.lora {
                let (rows, cols) = (l.b.value.rows(), l.b.value.cols());
                l.b.value = quaff::tensor::Matrix::randn(rows, cols, &mut r, 0.1);
            }
        }
    }
    m.start_calibration();
    for _ in 0..3 {
        let toks = batch(&mut r, 2, 10, 64);
        let _ = m.forward(&toks, false);
    }
    let calib = m.finish_calibration();
    let alloc = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
    let det = OutlierDetector::new(20.0);
    let _ = m.apply_method(kind, &calib, &alloc, &MethodConfig::default(), &det);
    m
}

/// Step-by-step logits parity: prefill + decode_step vs full re-forward.
fn check_stepwise(m: &Model, label: &str) {
    let mut ws = Workspace::new();
    let prompt = [1u32, 2, 3, 4, 5];
    let mut kv = KvCache::for_model(m, 1, &mut ws);
    let logits_c = m.prefill(&prompt, 0, &mut kv, &mut ws);
    let logits_u = m.forward_infer(&[prompt.to_vec()], &mut ws);
    assert_eq!(
        logits_c.row(0),
        logits_u.row(logits_u.rows() - 1),
        "{label}: prefill logits != full-forward logits"
    );
    let mut seq = prompt.to_vec();
    let mut next = infer::argmax(logits_c.row(0));
    for step in 0..6 {
        seq.push(next);
        let lc = m.decode_step(&[next], &[0], &mut kv, &mut ws);
        let lu = m.forward_infer(&[seq.clone()], &mut ws);
        assert_eq!(
            lc.row(0),
            lu.row(lu.rows() - 1),
            "{label}: decode step {step} logits diverged"
        );
        next = infer::argmax(lc.row(0));
        ws.recycle(lc);
        ws.recycle(lu);
    }
    kv.release(&mut ws);
}

/// Token-stream parity through the public drivers (greedy + sampled).
fn check_drivers(m: &Model, label: &str) {
    let mut ws = Workspace::new();
    let mut kv = KvCache::for_model(m, 1, &mut ws);
    let prompt = [3u32, 1, 4, 1, 5];
    for cfg in [
        GenerateConfig::greedy(8),
        GenerateConfig::sampled(8, 0.9, 12, 42),
    ] {
        let cached = infer::generate_cached(m, &prompt, &cfg, &mut kv, 0, &mut ws);
        let uncached = infer::generate_uncached(m, &prompt, &cfg, &mut ws);
        assert_eq!(cached, uncached, "{label}: cached vs uncached streams");
        assert!(!cached.is_empty(), "{label}: no tokens generated");
    }
    kv.release(&mut ws);
}

/// Batched decode must equal solo decode token-for-token (row-locality
/// across arbitrary batch neighbours), including under slot contention.
fn check_engine_matches_solo(m: &Model) {
    let mut r = Rng::new(77);
    let requests: Vec<Request> = (0..5)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..3 + i).map(|_| r.below(64) as u32).collect(),
            max_new: 7,
            tenant: None,
        })
        .collect();
    let cfg = GenerateConfig::greedy(7);
    for slots in [2usize, 4] {
        let mut engine = BatchEngine::new(m, slots, cfg.clone());
        let done = engine.run_requests(m, &requests);
        assert_eq!(done.len(), requests.len());
        assert!(engine.stats.decode_steps > 0);
        assert!(engine.stats.mean_batch() > 1.0, "batching never happened");
        let mut ws = Workspace::new();
        let mut kv = KvCache::for_model(m, 1, &mut ws);
        for (c, req) in done.iter().zip(&requests) {
            assert_eq!(c.id, req.id);
            let solo = infer::generate_cached(m, &req.prompt, &cfg, &mut kv, 0, &mut ws);
            assert_eq!(
                c.tokens, solo,
                "request {} diverged between batched and solo decode ({slots} slots)",
                req.id
            );
        }
        kv.release(&mut ws);
    }
}

/// Same seed ⇒ same sampled stream; the stream really is stochastic.
fn check_sampling_determinism(m: &Model) {
    let mut ws = Workspace::new();
    let mut kv = KvCache::for_model(m, 1, &mut ws);
    let prompt = [2u32, 7, 2, 7];
    let cfg_a = GenerateConfig::sampled(10, 1.1, 0, 1234);
    let a1 = infer::generate_cached(m, &prompt, &cfg_a, &mut kv, 0, &mut ws);
    let a2 = infer::generate_cached(m, &prompt, &cfg_a, &mut kv, 0, &mut ws);
    assert_eq!(a1, a2, "fixed seed must yield a fixed token stream");
    let gcfg = GenerateConfig::greedy(10);
    let greedy = infer::generate_cached(m, &prompt, &gcfg, &mut kv, 0, &mut ws);
    let mut diverged = false;
    for seed in 0..8u64 {
        let cfg = GenerateConfig::sampled(10, 1.1, 0, 5000 + seed);
        if infer::generate_cached(m, &prompt, &cfg, &mut kv, 0, &mut ws) != greedy {
            diverged = true;
            break;
        }
    }
    assert!(diverged, "temperature sampling never left the greedy path");
    kv.release(&mut ws);
}

#[test]
fn cached_decode_bit_identical_to_full_reforward() {
    // 8-wide pool so the 4-wide legs genuinely shard even on serial CI legs
    pool::init(pool::ThreadConfig { threads: 8 });
    for width in [1usize, 4] {
        pool::set_active_threads(width);
        // every WAQ method, no adapters
        for kind in MethodKind::ALL {
            let m = quantized_model(kind, None, 0xDEC0 + width as u64);
            let label = format!("{kind:?} @ {width}t");
            check_stepwise(&m, &label);
            check_drivers(&m, &label);
        }
        // PEFT coverage under Quaff: LoRA (adapter on the linear path) and
        // Prompt (virtual tokens occupy cache positions)
        for peft in [PeftKind::Lora, PeftKind::Prompt] {
            let m = quantized_model(MethodKind::Quaff, Some(peft), 0xADA0 + width as u64);
            let label = format!("Quaff+{peft:?} @ {width}t");
            check_stepwise(&m, &label);
            check_drivers(&m, &label);
        }
    }
    // cross-width parity: the same model must stream identical tokens at
    // width 1 and width 4 (sharded attention + linears are deterministic)
    let m = quantized_model(MethodKind::Quaff, None, 0xBEEF);
    let mut ws = Workspace::new();
    let mut kv = KvCache::for_model(&m, 1, &mut ws);
    let cfg = GenerateConfig::greedy(10);
    pool::set_active_threads(1);
    let t1 = infer::generate_cached(&m, &[9, 8, 7], &cfg, &mut kv, 0, &mut ws);
    pool::set_active_threads(4);
    let t4 = infer::generate_cached(&m, &[9, 8, 7], &cfg, &mut kv, 0, &mut ws);
    assert_eq!(t1, t4, "decode diverged between 1 and 4 threads");
    kv.release(&mut ws);

    check_engine_matches_solo(&m);
    check_sampling_determinism(&m);
    // leave the default width behind for any later in-process user
    pool::set_active_threads(pool::global().threads());
}
