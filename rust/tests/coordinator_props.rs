//! Property tests on coordinator + substrate invariants (in-house harness;
//! see `util::prop`): bundle routing, state monotonicity, decomposition
//! identities at model scale.

use quaff::coordinator::{run_job, FinetuneJob, PreprocessServer, ServerConfig};
use quaff::methods::MethodKind;
use quaff::outlier::OutlierSet;
use quaff::peft::PeftKind;
use quaff::quant;
use quaff::scaling::{self, MomentumScaler};
use quaff::tensor::Matrix;
use quaff::util::prop;

fn server() -> PreprocessServer {
    let mut cfg = ServerConfig::default();
    cfg.preset = "opt-tiny".to_string();
    cfg.calib_samples = 8;
    cfg.calib_batch = 4;
    PreprocessServer::new(cfg)
}

#[test]
fn prop_eq5_decomposition_identity_large_shapes() {
    // The algebraic core of the paper at realistic layer sizes.
    prop::check("eq5-large", 0x51, 10, |r| {
        let t = 8 + r.below(24);
        let cin = 64 + r.below(192);
        let cout = 32 + r.below(128);
        let x = Matrix::randn(t, cin, r, 1.0);
        let w = Matrix::randn(cin, cout, r, 0.3);
        let k = 1 + r.below(8);
        let chans = r.sample_indices(cin, k);
        let s: Vec<f32> = (0..k).map(|_| r.range(1.0, 30.0)).collect();
        (x, w, OutlierSet::new(chans), s)
    }, |(x, w, o, s)| {
        let want = x.matmul(w);
        let mut x_hat = x.clone();
        scaling::apply_targeted_inverse_scale(&mut x_hat, o, s);
        let mut got = x_hat.matmul(w);
        let corr = x_hat
            .select_cols(&o.channels)
            .matmul(&scaling::build_outlier_correction(w, o, s));
        got.add_assign(&corr);
        prop::all_close(got.data(), want.data(), 1e-2, 1e-2)
    });
}

#[test]
fn prop_quantize_dequantize_monotone_in_magnitude() {
    // Per-token quantization error grows with the planted outlier gain.
    prop::check("quant-monotone", 0x52, 16, |r| {
        let x = Matrix::randn(8, 64, r, 1.0);
        let gain = r.range(10.0, 200.0);
        (x, gain)
    }, |(x, gain)| {
        let base = quant::error_per_token(x).mse;
        let mut hot = x.clone();
        for t in 0..hot.rows() {
            let v = hot.get(t, 0);
            hot.set(t, 0, v * gain);
        }
        let inflated = quant::error_per_token(&hot).mse;
        if inflated > base {
            Ok(())
        } else {
            Err(format!("gain {gain}: error {inflated} !> {base}"))
        }
    });
}

#[test]
fn prop_momentum_scaler_bounded_and_convergent() {
    prop::check("momentum-bounds", 0x53, 24, |r| {
        let gamma = r.range(0.0, 0.95);
        let targets: Vec<f32> = (0..4).map(|_| r.range(1.0, 40.0)).collect();
        (gamma, targets)
    }, |(gamma, targets)| {
        let o = OutlierSet::new((0..targets.len()).collect());
        let mut m = MomentumScaler::new(*gamma, o);
        let xmax: Vec<f32> = targets.iter().map(|&t| t * t).collect();
        let wmax = vec![1.0f32; targets.len()];
        for _ in 0..500 {
            m.update(&xmax, &wmax);
            // invariant: factors never drop below 1 (Eq. 8 floor)
            if m.factors().iter().any(|&s| s < 1.0 - 1e-6) {
                return Err("factor below 1".into());
            }
        }
        prop::all_close(m.factors(), targets, 0.05, 0.05)
    });
}

#[test]
fn prop_bundle_payload_monotone_in_method_precision() {
    // For any seed, the quantized payload is always smaller than FP32's.
    let server = server();
    for method in [MethodKind::Naive, MethodKind::Quaff, MethodKind::SmoothStatic] {
        let q = server.prepare(method, PeftKind::Lora);
        let f = server.prepare(MethodKind::Fp32, PeftKind::Lora);
        assert!(
            q.payload_bytes < f.payload_bytes,
            "{:?} payload {} !< fp32 {}",
            method,
            q.payload_bytes,
            f.payload_bytes
        );
    }
}

#[test]
fn prop_job_reports_deterministic_given_seed() {
    let server = server();
    let mut job = FinetuneJob::new(0, "gpqa", MethodKind::Quaff, PeftKind::Lora);
    job.steps = 3;
    job.batch_size = 2;
    job.train_pool = 8;
    job.eval_samples = 4;
    let a = run_job(&server, &job).unwrap();
    let b = run_job(&server, &job).unwrap();
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits(), "nondeterministic training");
    assert_eq!(a.metric("acc").to_bits(), b.metric("acc").to_bits());
}

#[test]
fn prop_registry_channels_within_layer_bounds() {
    let server = server();
    let bundle = server.prepare(MethodKind::Quaff, PeftKind::Lora);
    // map layer name → c_in
    let mut cin_by_name = std::collections::BTreeMap::new();
    for b in &bundle.model.blocks {
        for l in b.linears_ref() {
            cin_by_name.insert(l.name.clone(), l.cin());
        }
    }
    for (name, set) in bundle.registry.layers() {
        let cin = cin_by_name[name];
        for &c in &set.channels {
            assert!(c < cin, "{name}: channel {c} out of range (c_in = {cin})");
        }
        assert!(set.len() <= cin);
    }
}
