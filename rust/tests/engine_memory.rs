//! Regression: `BatchEngine` memory is stable across requests. The
//! `Workspace` arena and `KvCache` lane pools stop growing after the first
//! request batch of a given shape, and steady-state batches perform an
//! *identical* (bounded) number of heap allocations — extending the
//! counting-allocator approach of `tests/zero_alloc.rs` to the serving
//! layer.
//!
//! Single `#[test]` so no concurrent test perturbs the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

use quaff::infer::{BatchEngine, GenerateConfig, Request};
use quaff::methods::{MethodConfig, MethodKind};
use quaff::model::{Model, ModelConfig};
use quaff::outlier::{BudgetAllocator, BudgetPolicy, OutlierDetector};
use quaff::util::prng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 64,
        ln_eps: 1e-5,
        inject_outliers: true,
        lora_rank: 4,
        lora_alpha: 8.0,
        lora_dropout: 0.0,
        n_virtual: 4,
    }
}

/// Calibrate + convert a tiny model to Quaff (the serving-path method).
fn quantized_model() -> Model {
    let mut m = Model::new(tiny_cfg(), 5);
    let mut r = Rng::new(6);
    m.start_calibration();
    for _ in 0..3 {
        let toks: Vec<Vec<u32>> = (0..2)
            .map(|_| (0..10).map(|_| r.below(64) as u32).collect())
            .collect();
        let _ = m.forward(&toks, false);
    }
    let calib = m.finish_calibration();
    let alloc = BudgetAllocator::new(BudgetPolicy::PaperNonUniform);
    let det = OutlierDetector::new(20.0);
    let _ = m.apply_method(
        MethodKind::Quaff,
        &calib,
        &alloc,
        &MethodConfig::default(),
        &det,
    );
    m
}

fn run_round(engine: &mut BatchEngine, model: &Model, reqs: &[Request]) -> Vec<Vec<u32>> {
    engine
        .run_requests(model, reqs)
        .into_iter()
        .map(|c| c.tokens)
        .collect()
}

#[test]
fn engine_memory_is_stable_across_same_shape_request_batches() {
    // Serial pool width: sharded launches enqueue O(threads) channel nodes
    // per kernel, which would add benign-but-nonzero allocator traffic.
    quaff::tensor::pool::set_active_threads(1);
    let model = quantized_model();
    let mut engine = BatchEngine::new(&model, 3, GenerateConfig::greedy(8));
    let kv0 = engine.kv_bytes();
    assert!(kv0 > 0);
    // 6 requests over 3 slots: admission, completion, and slot reuse all
    // exercised. Two rounds warm the arena; rounds 3 and 4 are steady.
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i as u64,
            prompt: vec![1, 2, 3, 1 + (i % 5) as u32],
            max_new: 8,
            tenant: None,
        })
        .collect();
    let first = run_round(&mut engine, &model, &reqs);
    let _ = run_round(&mut engine, &model, &reqs);
    let fresh_warm = engine.workspace_fresh_allocs();
    let pooled_warm = engine.workspace_pooled_bytes();

    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    let second = run_round(&mut engine, &model, &reqs);
    let allocs_round3 = ALLOC_CALLS.load(Ordering::Relaxed) - before;
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    let third = run_round(&mut engine, &model, &reqs);
    let allocs_round4 = ALLOC_CALLS.load(Ordering::Relaxed) - before;

    // the pools stopped growing after the warm rounds...
    assert_eq!(
        engine.workspace_fresh_allocs(),
        fresh_warm,
        "workspace arena grew during steady-state rounds"
    );
    assert_eq!(
        engine.workspace_pooled_bytes(),
        pooled_warm,
        "pooled capacity changed during steady-state rounds"
    );
    assert_eq!(engine.kv_bytes(), kv0, "KV lanes must never grow per request");
    // ...steady-state rounds allocate identically (no creep)...
    assert_eq!(
        allocs_round4, allocs_round3,
        "allocation count must not creep across identical request batches"
    );
    // ...and the engine still serves deterministically.
    assert_eq!(first, second);
    assert_eq!(second, third);
}
