//! Thread-determinism suite: every pool-sharded path must be **bit-identical**
//! to its single-threaded execution, for any thread count, including across
//! repeated runs against a reused [`Workspace`].
//!
//! The whole suite is one `#[test]` because it flips the process-global
//! active thread width ([`pool::set_active_threads`]) between legs; a single
//! test body keeps the flips strictly sequential.

use quaff::methods::{build_method, MethodConfig, MethodKind};
use quaff::model::{Model, ModelConfig};
use quaff::outlier::{ChannelStats, OutlierDetector, OutlierSet};
use quaff::peft::PeftKind;
use quaff::quant;
use quaff::tensor::{kernels, pool, I8Matrix, Matrix, Workspace};
use quaff::train::Trainer;
use quaff::util::prng::Rng;

/// Shapes big enough that the 4-wide legs actually shard (work ≫
/// `pool::MIN_SHARD_WORK`); the 1-wide legs run the same cores serially.
const T: usize = 96;
const CIN: usize = 128;
const COUT: usize = 192;

fn calib(rng: &mut Rng, cin: usize, hot: &[usize]) -> (ChannelStats, OutlierSet) {
    let mut stats = ChannelStats::new(cin);
    for _ in 0..4 {
        let mut x = Matrix::randn(8, cin, rng, 1.0);
        for &c in hot {
            for t in 0..8 {
                let v = x.get(t, c);
                x.set(t, c, v * 80.0);
            }
        }
        stats.observe(&x, 30.0);
    }
    let set = OutlierDetector::new(30.0).select(&stats, hot.len());
    (stats, set)
}

fn hot_x(rng: &mut Rng, t: usize, cin: usize, hot: &[usize]) -> Matrix {
    let mut x = Matrix::randn(t, cin, rng, 1.0);
    for &c in hot {
        for ti in 0..t {
            let v = x.get(ti, c);
            x.set(ti, c, v * 60.0);
        }
    }
    x
}

/// Run `f` at the given active width, returning its output.
fn at_width<R>(width: usize, f: impl FnOnce() -> R) -> R {
    pool::set_active_threads(width);
    f()
}

/// Fresh-buffer wrappers over the `_into` quantizers (the removed
/// allocating conveniences, kept local to this suite).
fn qpt(x: &Matrix) -> (I8Matrix, Vec<f32>) {
    let mut q = I8Matrix::zeros(x.rows(), x.cols());
    let mut d = Vec::with_capacity(x.rows());
    quant::quantize_per_token_into(x, &mut q, &mut d);
    (q, d)
}

fn dqt(q: &I8Matrix, d: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(q.rows(), q.cols());
    quant::dequantize_per_token_into(q, d, &mut out);
    out
}

fn dqoc(w: &I8Matrix, d: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(w.rows(), w.cols());
    quant::dequantize_per_oc_into(w, d, &mut out);
    out
}

fn check_kernels(rng: &mut Rng) {
    let a = Matrix::randn(T, CIN, rng, 1.0);
    let b = Matrix::randn(CIN, COUT, rng, 1.0);
    let dy = Matrix::randn(T, COUT, rng, 1.0);
    let wide = Matrix::randn(700, 300, rng, 2.0);

    // f32 matmul family
    let mm1 = at_width(1, || a.matmul(&b));
    let mm4 = at_width(4, || a.matmul(&b));
    assert_eq!(mm1.data(), mm4.data(), "matmul_into threads≠serial");
    let bt1 = at_width(1, || dy.matmul_bt(&b));
    let bt4 = at_width(4, || dy.matmul_bt(&b));
    assert_eq!(bt1.data(), bt4.data(), "matmul_bt_into threads≠serial");
    let at1 = at_width(1, || a.matmul_at(&dy));
    let at4 = at_width(4, || a.matmul_at(&dy));
    assert_eq!(at1.data(), at4.data(), "matmul_at_into threads≠serial");

    // col_abs_max (tree-reduced when threaded) — plain and workspace paths
    let c1 = at_width(1, || wide.col_abs_max());
    let c4 = at_width(4, || wide.col_abs_max());
    assert_eq!(c1, c4, "col_abs_max threads≠serial");
    let mut ws = Workspace::new();
    let mut c4ws = vec![0.0f32; wide.cols()];
    at_width(4, || kernels::col_abs_max_ws(&wide, &mut c4ws, &mut ws));
    assert_eq!(c1, c4ws, "col_abs_max_ws threads≠serial");

    // quantize / dequantize — on `wide`, whose work sits well above the
    // shard threshold so the 4-wide legs genuinely split
    let (q1w, d1w) = at_width(1, || qpt(&wide));
    let (q4w, d4w) = at_width(4, || qpt(&wide));
    assert_eq!(q1w.data(), q4w.data(), "quantize_per_token threads≠serial");
    assert_eq!(d1w, d4w);
    let (w1, wd1) = at_width(1, || quant::quantize_per_oc(&wide));
    let (w4, wd4) = at_width(4, || quant::quantize_per_oc(&wide));
    assert_eq!(w1.data(), w4.data(), "quantize_per_oc threads≠serial");
    assert_eq!(wd1, wd4);
    let dq1 = at_width(1, || dqt(&q1w, &d1w));
    let dq4 = at_width(4, || dqt(&q1w, &d1w));
    assert_eq!(dq1.data(), dq4.data(), "dequantize_per_token threads≠serial");
    let do1 = at_width(1, || dqoc(&w1, &wd1));
    let do4 = at_width(4, || dqoc(&w1, &wd1));
    assert_eq!(do1.data(), do4.data(), "dequantize_per_oc threads≠serial");
    // per-token quantization of the matmul input feeds the int8 leg below
    let (q1, d1) = at_width(1, || qpt(&a));

    // int8 matmuls (exact integer math, but the dequant epilogue is f32)
    let ai = I8Matrix::random(T, CIN, rng);
    let bi = I8Matrix::random(CIN, COUT, rng);
    let i1 = at_width(1, || ai.matmul_i32(&bi));
    let i4 = at_width(4, || ai.matmul_i32(&bi));
    assert_eq!(i1, i4, "matmul_i32 threads≠serial");
    let qw = quant::QuantizedWeights::quantize(&b);
    let mut y1 = vec![0.0f32; T * COUT];
    let mut y4 = vec![0.0f32; T * COUT];
    at_width(1, || qw.matmul_ws(&q1, &d1, &mut ws, &mut y1));
    at_width(4, || qw.matmul_ws(&q1, &d1, &mut ws, &mut y4));
    assert_eq!(y1, y4, "packed int8 matmul threads≠serial");
    // run-to-run identity with the same (now warm) workspace
    let mut y4b = vec![0.0f32; T * COUT];
    at_width(4, || qw.matmul_ws(&q1, &d1, &mut ws, &mut y4b));
    assert_eq!(y4, y4b, "packed int8 matmul not reproducible on warm arena");
}

fn check_methods(rng: &mut Rng) {
    let hot = vec![5, 40, 100];
    let (stats, oset) = calib(rng, CIN, &hot);
    let w = Matrix::randn(CIN, COUT, rng, 0.3);
    let cfg = MethodConfig::default();
    let kinds = [
        MethodKind::Fp32,
        MethodKind::Naive,
        MethodKind::LlmInt8,
        MethodKind::SmoothStatic,
        MethodKind::SmoothDynamic,
        MethodKind::Quaff,
        MethodKind::QuaffNoMomentum,
    ];
    // Pre-generate a shared step sequence so stateful methods (momentum,
    // dynamic scaling) see identical histories on both legs.
    let steps: Vec<(Matrix, Matrix)> = (0..3)
        .map(|_| {
            (
                hot_x(rng, T, CIN, &hot),
                Matrix::randn(T, COUT, rng, 1.0),
            )
        })
        .collect();
    for kind in kinds {
        let mut m1 = build_method(kind, w.clone(), &stats, &oset, &cfg);
        let mut m4 = build_method(kind, w.clone(), &stats, &oset, &cfg);
        let mut ws1 = Workspace::new();
        let mut ws4 = Workspace::new();
        for (step, (x, dy)) in steps.iter().enumerate() {
            let y1 = at_width(1, || m1.forward(x, &mut ws1));
            let y4 = at_width(4, || m4.forward(x, &mut ws4));
            assert_eq!(
                y1.data(),
                y4.data(),
                "{} forward threads≠serial at step {step}",
                m1.name()
            );
            let dx1 = at_width(1, || m1.backward_input(dy, &mut ws1));
            let dx4 = at_width(4, || m4.backward_input(dy, &mut ws4));
            assert_eq!(
                dx1.data(),
                dx4.data(),
                "{} backward threads≠serial at step {step}",
                m1.name()
            );
            ws1.recycle(y1);
            ws1.recycle(dx1);
            ws4.recycle(y4);
            ws4.recycle(dx4);
        }
    }
}

/// End-to-end: identical models trained for a few steps at width 1 and
/// width 4 must produce bit-identical losses and adapter parameters —
/// forward, loss, backward, gradient accumulation, and Adam all included.
fn check_trainer_end_to_end() {
    let cfg = ModelConfig {
        vocab: quaff::data::VOCAB_SIZE,
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        d_ff: 256,
        max_seq: 96,
        ln_eps: 1e-5,
        inject_outliers: false,
        lora_rank: 8,
        lora_alpha: 8.0,
        lora_dropout: 0.0,
        n_virtual: 4,
    };
    let task = quaff::data::SynthTask::by_name("oasst1").expect("embedded task");
    let run = |width: usize| {
        pool::set_active_threads(width);
        let mut m = Model::new(cfg.clone(), 33);
        m.attach_peft(PeftKind::Lora);
        let mut srng = Rng::new(17);
        let samples: Vec<_> = (0..4).map(|_| task.sample(&mut srng)).collect();
        let refs: Vec<&quaff::data::Sample> = samples.iter().collect();
        let mut trainer = Trainer::new(1e-3, 64, 1);
        let mut losses = Vec::new();
        for _ in 0..4 {
            losses.push(trainer.step(&mut m, &[refs.clone()]).loss);
        }
        let mut params: Vec<(String, Vec<f32>)> = Vec::new();
        m.visit_params(&mut |name, p| params.push((name.to_string(), p.value.data().to_vec())));
        (losses, params)
    };
    let (loss1, params1) = run(1);
    let (loss4, params4) = run(4);
    assert_eq!(loss1, loss4, "losses diverged between 1 and 4 threads");
    assert_eq!(params1.len(), params4.len());
    for ((n1, v1), (n4, v4)) in params1.iter().zip(&params4) {
        assert_eq!(n1, n4);
        assert_eq!(v1, v4, "param {n1} diverged between 1 and 4 threads");
    }
}

#[test]
fn threaded_paths_bit_identical_to_serial() {
    // Ask for an 8-wide pool regardless of QUAFF_THREADS so the 4-wide legs
    // genuinely shard even on the serial CI leg (this test *is* the
    // serial-vs-threaded comparison).
    pool::init(pool::ThreadConfig { threads: 8 });
    let mut rng = Rng::new(4242);
    check_kernels(&mut rng);
    check_methods(&mut rng);
    check_trainer_end_to_end();
    // leave the default width behind for any later in-process user
    pool::set_active_threads(pool::global().threads());
}
